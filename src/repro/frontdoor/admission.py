"""Admission control — per-tenant weighted fair-share quotas at the door.

The engine's :class:`~repro.core.scheduler.FairShareScheduler` already
split-charges stage execution across the studies it serves (PR 5); this
module adds the *cluster-level* layer PipeTune motivates: studies arrive
continuously from many tenants, and the system — not the submitter —
decides who runs now, who waits, and what can never run at all.

Three mechanisms, in decision order:

* **capacity gate** — work the fleet can *never* place (a study whose
  stages need more devices than the widest worker slot) is refused
  outright with :class:`CapacityError`; queueing it would be a silent
  forever-wait.
* **bounded queues** — each tenant has ``max_queued`` admission slots;
  beyond them :class:`AdmissionQueueFull` pushes back on the submitter
  (back-pressure beats unbounded memory growth).
* **weighted fair-share dequeue** — when a running slot frees, the queued
  submission of the tenant with the lowest *weighted* usage (split-charged
  GPU-seconds / quota weight) is admitted; ``priority`` breaks ties within
  a tenant's and across equal-usage tenants' submissions, then arrival
  order.  A tenant with weight 2 is charged half, so it reaches "most
  served" twice as late — weighted shares without starving anyone
  (usage only grows while you run; a starved tenant's weighted usage
  stays minimal and wins every future dequeue).

The controller is deliberately engine-agnostic: *usage* is injected per
decision by the gateway (computed live from ``EngineStats.by_study`` via
the tenant ledger), so the controller itself carries only quotas, the
queue and counters — exactly what the gateway snapshot persists.
"""

from __future__ import annotations

from dataclasses import dataclass
from typing import Any, Dict, List, Optional, Tuple

__all__ = ["TenantQuota", "Submission", "AdmissionController",
           "AdmissionQueueFull", "CapacityError"]


class CapacityError(RuntimeError):
    """The fleet can never place this work — refused, not queued."""


class AdmissionQueueFull(RuntimeError):
    """The tenant's bounded admission queue is full (back-pressure)."""


@dataclass(frozen=True)
class TenantQuota:
    """Per-tenant admission policy.

    ``weight`` scales the tenant's fair share (2.0 = twice the share —
    applied both at the admission dequeue and, through
    ``FairShareScheduler.set_study_weights``, inside shared sessions).
    ``max_queued`` bounds the tenant's admission queue.  ``max_running``
    caps the tenant's concurrently *running* studies (None = only the
    gateway-wide ``max_concurrent`` applies).
    """

    weight: float = 1.0
    max_queued: int = 16
    max_running: Optional[int] = None

    def __post_init__(self):
        if self.weight <= 0:
            raise ValueError(f"quota weight must be > 0, got {self.weight}")
        if self.max_queued < 0:
            raise ValueError("max_queued must be >= 0")

    def to_json(self) -> Dict[str, Any]:
        return {"weight": self.weight, "max_queued": self.max_queued,
                "max_running": self.max_running}

    @classmethod
    def from_json(cls, d: Dict[str, Any]) -> "TenantQuota":
        return cls(weight=d.get("weight", 1.0),
                   max_queued=d.get("max_queued", 16),
                   max_running=d.get("max_running"))


@dataclass
class Submission:
    """One study waiting at (or passing through) the door."""

    tenant: str
    priority: int          # larger = more urgent; breaks fair-share ties
    seq: int               # global arrival order (final tie-break)
    key: str               # plan key (routing target)
    tuner: Any
    study_id: Optional[str] = None
    min_devices: int = 1   # devices one worker must offer this study
    arrival: Optional[float] = None   # requested at= on the global clock


class AdmissionController:
    """Quota bookkeeping + the admission queue.  The gateway drives it:
    ``offer`` at submit time, ``pop_admissible`` whenever running slots
    may have freed, ``on_started`` / ``on_finished`` around each study's
    life cycle."""

    def __init__(self, quotas: Optional[Dict[str, TenantQuota]] = None,
                 max_concurrent: Optional[int] = None,
                 default_quota: Optional[TenantQuota] = None):
        self.quotas: Dict[str, TenantQuota] = dict(quotas or {})
        self.max_concurrent = max_concurrent
        self.default_quota = default_quota or TenantQuota()
        self.queue: List[Submission] = []
        # (plan key, study id) -> tenant, for every currently-running study
        self.running: Dict[Tuple[str, str], str] = {}
        self.seq = 0
        self.admission_faults = 0      # deferred-by-injected-fault count

    # ------------------------------------------------------------- quotas
    def quota(self, tenant: str) -> TenantQuota:
        return self.quotas.get(tenant, self.default_quota)

    def _running_of(self, tenant: str) -> int:
        return sum(1 for t in self.running.values() if t == tenant)

    def _queued_of(self, tenant: str) -> int:
        return sum(1 for s in self.queue if s.tenant == tenant)

    # ------------------------------------------------------------ the gate
    def check_capacity(self, min_devices: int,
                       slot_widths: List[int]) -> None:
        """Refuse work the fleet can never place: no slot at all, or every
        slot narrower than the study's per-worker device requirement.
        Queueing such work would be a silent forever-wait — the error is
        the honest answer."""
        if not slot_widths:
            raise CapacityError("the fleet has no worker slots")
        widest = max(slot_widths)
        if min_devices > widest:
            raise CapacityError(
                f"study needs {min_devices} devices per worker but the "
                f"widest fleet slot has {widest} — no rebalancing can ever "
                "place it")

    def can_admit(self, sub: Submission) -> bool:
        """Would admitting ``sub`` right now violate a concurrency cap?"""
        if (self.max_concurrent is not None
                and len(self.running) >= self.max_concurrent):
            return False
        cap = self.quota(sub.tenant).max_running
        return cap is None or self._running_of(sub.tenant) < cap

    # ---------------------------------------------------------- life cycle
    def offer(self, sub: Submission) -> bool:
        """Route one submission: True = admit now, False = queued
        (``queued_admission``).  Raises :class:`AdmissionQueueFull` when
        the tenant's bounded queue cannot hold it either."""
        if self.can_admit(sub):
            return True
        if self._queued_of(sub.tenant) >= self.quota(sub.tenant).max_queued:
            raise AdmissionQueueFull(
                f"tenant {sub.tenant!r} admission queue is full "
                f"({self.quota(sub.tenant).max_queued} waiting) — retry "
                "after a study finishes")
        self.queue.append(sub)
        return False

    def defer(self, sub: Submission) -> None:
        """Force one submission into the queue (gateway-level injected
        admission fault): the control plane lost the request this round;
        the next pump retries it.  Bypasses the bounded-queue check — the
        work was already accepted, dropping it would lose it."""
        self.admission_faults += 1
        self.queue.append(sub)

    def pop_admissible(self, weighted_usage) -> Optional[Submission]:
        """Remove and return the queued submission to admit next, or None.

        ``weighted_usage(tenant)`` is injected by the gateway (tenant
        ledger GPU-seconds / quota weight).  Order: least weighted usage
        first (weighted fair share), then higher priority, then arrival
        sequence — deterministic for equal inputs."""
        candidates = [s for s in self.queue if self.can_admit(s)]
        if not candidates:
            return None
        best = min(candidates, key=lambda s: (weighted_usage(s.tenant),
                                              -s.priority, s.seq))
        self.queue.remove(best)
        return best

    def on_started(self, key: str, study_id: str, tenant: str) -> None:
        self.running[(key, study_id)] = tenant

    def on_finished(self, key: str, study_id: str) -> None:
        self.running.pop((key, study_id), None)

    def next_seq(self) -> int:
        """Next global arrival sequence number (0-based: the gateway also
        derives default study ids ``study-<seq>`` from it, matching the
        legacy session's ``study-0``-first naming)."""
        seq = self.seq
        self.seq += 1
        return seq
