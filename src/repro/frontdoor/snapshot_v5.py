"""Schema'd session/gateway snapshots — the v5 on-disk format.

The v2-v4 session snapshot was a bare versioned pickle: opaque, fragile
to inspect, and silently corruptible (truncation surfaced only as an
``UnpicklingError`` somewhere inside the stream).  v5 retires it for the
checkpoint plane's blob conventions (``repro.train.checkpoint``)::

    [8-byte big-endian header length]
    [UTF-8 JSON header:
        {"magic": "hippo-snapshot", "version": 5,
         "kind": "session" | "gateway",
         "manifest": {... typed, kind-specific ...},
         "records": [{"name", "kind", "offset", "length", "digest"}, ...]}]
    [payload records, concatenated]

Everything with a stable schema lives **typed in the JSON manifest** —
plan key, engine knobs, the full :class:`EngineStats` (including
``by_study``), worker rows, the committed-checkpoint index, tenant maps,
quotas, leases, the admission queue's metadata.  Components that are
inherently Python object graphs (the search plan, the event heap, tuners,
scheduling-policy memory) ride as named **pickle records**, each
independently blake2b-digested, so a torn tail or bit rot is detected at
load (and the rotation reader falls back a slot) instead of surfacing as
a confusing unpickle error.  A **gateway** envelope nests one complete
session record per plan key plus the front-door control state
(:class:`GatewayState`), so one SIGKILL'd file restores the whole
deployment.

Cross-version story: the manifest's typed fields migrate like dataclass
defaults — a reader fills fields the file lacks and ignores fields it
does not know — and legacy v2-v4 *pickle* files are still accepted by
:func:`repro.core.engine.session.load_session` (sniffed by pickle's
``\\x80`` magic byte, then migrated forward by ``migrate_session``).
"""

from __future__ import annotations

import dataclasses
import hashlib
import json
import pickle
from dataclasses import dataclass, field
from typing import Any, Dict, List, Optional, Tuple

from repro.core.engine.session import SESSION_FORMAT_VERSION, SessionState

__all__ = ["GatewayState", "encode_snapshot", "decode_snapshot",
           "is_v5_snapshot", "SNAPSHOT_MAGIC"]

SNAPSHOT_MAGIC = "hippo-snapshot"


def _digest(buf: bytes) -> str:
    return hashlib.blake2b(buf, digest_size=16).hexdigest()


# --------------------------------------------------------------------------
# Gateway envelope state
# --------------------------------------------------------------------------


@dataclass
class GatewayState:
    """Complete front-door state: every per-key session plus the control
    plane around them (admission queues, quotas, tenant map, worker
    leases, the global clock, and the mid-run fault-schedule state)."""

    version: int
    time: float                                  # global virtual clock
    max_concurrent: Optional[int]
    seq: int                                     # admission sequence counter
    quotas: Dict[str, Dict[str, Any]]            # tenant -> quota fields
    default_quota: Dict[str, Any]
    tenants: Dict[str, Dict[str, str]]           # plan key -> {study: tenant}
    sessions: List[Tuple[str, SessionState]]     # (key, state), creation order
    slot_meshes: List[Any]                       # fleet slots (WorkerMesh|None)
    leases: List[Tuple[int, str, int, bool]]     # (slot, key, wid, draining)
    queued: List[Any]                            # admission.Submission objects
    retired: List[Tuple[str, Any, List[Any]]]    # (key, EngineStats, futures)
    injector_state: Optional[Dict[str, Any]] = None
    service: Dict[str, Any] = field(default_factory=dict)


# --------------------------------------------------------------------------
# Record container
# --------------------------------------------------------------------------


class _Records:
    """Payload builder: named, digested records after the JSON header."""

    def __init__(self):
        self.metas: List[Dict[str, Any]] = []
        self.chunks: List[bytes] = []
        self._off = 0

    def add(self, name: str, kind: str, payload: bytes) -> None:
        self.metas.append({"name": name, "kind": kind, "offset": self._off,
                           "length": len(payload),
                           "digest": _digest(payload)})
        self.chunks.append(payload)
        self._off += len(payload)

    def pickle(self, name: str, obj: Any) -> None:
        self.add(name, "pickle", pickle.dumps(obj))

    def pack(self, kind: str, manifest: Dict[str, Any]) -> bytes:
        header = json.dumps({
            "magic": SNAPSHOT_MAGIC, "version": SESSION_FORMAT_VERSION,
            "kind": kind, "manifest": manifest,
            "records": self.metas}).encode("utf-8")
        return (len(header).to_bytes(8, "big") + header
                + b"".join(self.chunks))


def _read_container(data: bytes) -> Tuple[Dict[str, Any],
                                          Dict[str, Tuple[str, bytes]]]:
    """(header, {record name: (kind, verified payload bytes)})."""
    if len(data) < 8:
        raise ValueError("snapshot truncated before the header length")
    hlen = int.from_bytes(data[:8], "big")
    if hlen <= 0 or 8 + hlen > len(data):
        raise ValueError("snapshot header length out of bounds")
    try:
        header = json.loads(data[8:8 + hlen])
    except Exception:
        raise ValueError("unreadable snapshot header")
    if not isinstance(header, dict) or header.get("magic") != SNAPSHOT_MAGIC:
        raise ValueError("not a repro snapshot (bad magic)")
    base = 8 + hlen
    records: Dict[str, Tuple[str, bytes]] = {}
    for meta in header.get("records", []):
        lo = base + meta["offset"]
        hi = lo + meta["length"]
        if hi > len(data):
            raise ValueError(
                f"snapshot record {meta['name']!r} truncated")
        payload = data[lo:hi]
        if _digest(payload) != meta["digest"]:
            raise ValueError(
                f"snapshot record {meta['name']!r} failed digest check "
                "(torn write or bit rot)")
        records[meta["name"]] = (meta["kind"], payload)
    return header, records


def _record(records, name: str, default=None):
    entry = records.get(name)
    if entry is None:
        return default
    kind, payload = entry
    if kind == "pickle":
        return pickle.loads(payload)
    return payload                               # "blob": raw bytes


def is_v5_snapshot(data: bytes) -> bool:
    """Cheap sniff: is this the v5 container (vs a legacy pickle, whose
    first byte is pickle's ``\\x80`` protocol marker)?"""
    try:
        if len(data) < 8:
            return False
        hlen = int.from_bytes(data[:8], "big")
        if hlen <= 0 or 8 + hlen > len(data):
            return False
        header = json.loads(data[8:8 + hlen])
        return (isinstance(header, dict)
                and header.get("magic") == SNAPSHOT_MAGIC)
    except Exception:
        return False


# --------------------------------------------------------------------------
# EngineStats <-> typed JSON
# --------------------------------------------------------------------------


def _stats_to_json(stats) -> Dict[str, Any]:
    return dataclasses.asdict(stats)


def _build_dataclass(cls, values: Dict[str, Any]):
    """Instantiate ``cls`` from a JSON dict: unknown fields are ignored,
    missing ones keep their dataclass defaults — the typed counterpart of
    ``migrate_session``'s stats backfill."""
    obj = cls()
    for name in cls.__dataclass_fields__:
        if name in values and name != "by_study":
            setattr(obj, name, values[name])
    return obj


def _stats_from_json(d: Dict[str, Any]):
    from repro.core.engine.engine import EngineStats, StudyStats

    stats = _build_dataclass(EngineStats, d)
    stats.by_study = {sid: _build_dataclass(StudyStats, sd)
                      for sid, sd in (d.get("by_study") or {}).items()}
    return stats


# --------------------------------------------------------------------------
# Session encode/decode
# --------------------------------------------------------------------------

_KNOBS = ("n_workers", "gpus_per_worker", "share", "max_steps_per_chain",
          "batch_siblings", "chain_fusion")

# the object-graph components that ride together as ONE pickle record:
# event payloads, the waiter table, handles, trials and the scheduler all
# alias the same live objects (a stage event's handle IS the handle the
# service re-wires) — pickling them separately would sever that sharing
# and restore a session whose events update orphaned copies
_SESSION_GRAPH = ("plan", "events", "scheduler", "waiters", "killed",
                  "trials", "handles", "study_trials", "started",
                  "cancelled", "store_mem", "service")


def _encode_session(state: SessionState) -> bytes:
    recs = _Records()
    recs.pickle("graph", {name: getattr(state, name)
                          for name in _SESSION_GRAPH})
    # worker rows: typed scalars in the manifest, mesh objects (sharding
    # rules are arbitrary Python) in one aligned pickle record
    rows = [tuple(row) for row in state.workers]
    recs.pickle("worker_meshes", [row[3] for row in rows])
    manifest = {
        "plan_key": state.plan_key,
        "knobs": {k: getattr(state, k) for k in _KNOBS},
        "stats": _stats_to_json(state.stats),
        "workers": [[row[0], row[1], row[2], row[4], row[5], row[6],
                     bool(row[7])] for row in rows],
        "store_cids": sorted(state.store_cids),
    }
    return recs.pack("session", manifest)


def _decode_session(header: Dict[str, Any],
                    records: Dict[str, Tuple[str, bytes]]) -> SessionState:
    man = header["manifest"]
    knobs = man.get("knobs", {})
    meshes = _record(records, "worker_meshes", [])
    workers = []
    for i, row in enumerate(man.get("workers", [])):
        mesh = meshes[i] if i < len(meshes) else None
        wid, busy, idle, fails, quars, quntil, draining = row
        workers.append((wid, busy, idle, mesh, fails, quars, quntil,
                        bool(draining)))
    graph = _record(records, "graph", {})
    return SessionState(
        version=int(header.get("version", SESSION_FORMAT_VERSION)),
        plan_key=man["plan_key"],
        n_workers=knobs.get("n_workers", len(workers)),
        gpus_per_worker=knobs.get("gpus_per_worker", 1),
        share=knobs.get("share", True),
        max_steps_per_chain=knobs.get("max_steps_per_chain"),
        batch_siblings=knobs.get("batch_siblings", False),
        chain_fusion=knobs.get("chain_fusion", False),
        plan=graph.get("plan"),
        events=graph.get("events"),
        scheduler=graph.get("scheduler"),
        stats=_stats_from_json(man.get("stats", {})),
        workers=workers,
        waiters=graph.get("waiters", {}),
        killed=graph.get("killed", set()),
        trials=graph.get("trials", {}),
        handles=graph.get("handles", []),
        study_trials=graph.get("study_trials", {}),
        started=graph.get("started", set()),
        cancelled=graph.get("cancelled", set()),
        store_cids=set(man.get("store_cids", [])),
        store_mem=graph.get("store_mem"),
        service=graph.get("service", {}),
    )


# --------------------------------------------------------------------------
# Gateway encode/decode
# --------------------------------------------------------------------------


def _encode_gateway(state: GatewayState) -> bytes:
    recs = _Records()
    for i, (key, sess) in enumerate(state.sessions):
        recs.add(f"session.{i}", "blob", _encode_session(sess))
    recs.pickle("slot_meshes", state.slot_meshes)
    recs.pickle("queued_tuners", [sub.tuner for sub in state.queued])
    recs.pickle("retired_futures", [futs for _, _, futs in state.retired])
    recs.pickle("injector_state", state.injector_state)
    recs.pickle("service", state.service)
    manifest = {
        "time": state.time,
        "max_concurrent": state.max_concurrent,
        "seq": state.seq,
        "quotas": state.quotas,
        "default_quota": state.default_quota,
        "tenants": state.tenants,
        "session_keys": [key for key, _ in state.sessions],
        "leases": [list(lease) for lease in state.leases],
        "queued": [{"tenant": sub.tenant, "priority": sub.priority,
                    "seq": sub.seq, "key": sub.key,
                    "study_id": sub.study_id,
                    "min_devices": sub.min_devices,
                    "arrival": sub.arrival} for sub in state.queued],
        "retired": [{"key": key, "stats": _stats_to_json(stats)}
                    for key, stats, _ in state.retired],
    }
    return recs.pack("gateway", manifest)


def _decode_gateway(header: Dict[str, Any],
                    records: Dict[str, Tuple[str, bytes]]) -> GatewayState:
    from repro.frontdoor.admission import Submission

    man = header["manifest"]
    sessions = []
    for i, key in enumerate(man.get("session_keys", [])):
        blob = _record(records, f"session.{i}")
        shdr, srecs = _read_container(blob)
        if shdr.get("kind") != "session":
            raise ValueError(f"gateway record session.{i} is not a session")
        sessions.append((key, _decode_session(shdr, srecs)))
    tuners = _record(records, "queued_tuners", [])
    queued = []
    for i, row in enumerate(man.get("queued", [])):
        queued.append(Submission(
            tenant=row["tenant"], priority=row["priority"], seq=row["seq"],
            key=row["key"], tuner=tuners[i] if i < len(tuners) else None,
            study_id=row.get("study_id"),
            min_devices=row.get("min_devices", 1),
            arrival=row.get("arrival")))
    retired_futs = _record(records, "retired_futures", [])
    retired = []
    for i, row in enumerate(man.get("retired", [])):
        futs = retired_futs[i] if i < len(retired_futs) else []
        retired.append((row["key"], _stats_from_json(row["stats"]), futs))
    return GatewayState(
        version=int(header.get("version", SESSION_FORMAT_VERSION)),
        time=man.get("time", 0.0),
        max_concurrent=man.get("max_concurrent"),
        seq=man.get("seq", 0),
        quotas=man.get("quotas", {}),
        default_quota=man.get("default_quota", {}),
        tenants=man.get("tenants", {}),
        sessions=sessions,
        slot_meshes=_record(records, "slot_meshes", []),
        leases=[tuple(lease) for lease in man.get("leases", [])],
        queued=queued,
        retired=retired,
        injector_state=_record(records, "injector_state"),
        service=_record(records, "service", {}),
    )


# --------------------------------------------------------------------------
# Public entry points
# --------------------------------------------------------------------------


def encode_snapshot(state) -> bytes:
    """Serialize a :class:`SessionState` or :class:`GatewayState` into the
    v5 container."""
    if isinstance(state, SessionState):
        return _encode_session(state)
    if isinstance(state, GatewayState):
        return _encode_gateway(state)
    raise TypeError(
        f"cannot snapshot {type(state).__name__!r} — expected SessionState "
        "or GatewayState")


def decode_snapshot(data: bytes):
    """Parse a v5 container into a :class:`SessionState` or
    :class:`GatewayState` (dispatched on the header's ``kind``); every
    record is digest-verified.  Raises ``ValueError`` on corruption, so
    rotation readers fall back to an older slot."""
    header, records = _read_container(data)
    kind = header.get("kind")
    if kind == "session":
        return _decode_session(header, records)
    if kind == "gateway":
        return _decode_gateway(header, records)
    raise ValueError(f"unknown snapshot kind {kind!r}")
