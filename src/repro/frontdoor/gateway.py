"""Front door — the multi-tenant study gateway.

One :class:`~repro.core.study.StudyService` drives ONE stage forest (one
search-plan key); production traffic is messier: many tenants submit
studies over many keys, continuously.  :class:`StudyGateway` is the
process front door over that traffic:

* **routing** — submissions are routed by plan key to a per-key session,
  spawned on demand and retired (closed, stats archived) once its forest
  drains; same-key submissions from *different tenants* merge into one
  forest exactly as before — the paper's cross-study sharing now happens
  across tenants, with each tenant split-charged for what it used.
* **admission control** (:mod:`repro.frontdoor.admission`) — per-tenant
  weighted fair-share quotas with bounded queues; over-quota studies wait
  at the door (future status ``queued_admission``) and are admitted
  least-weighted-usage-first, priorities breaking ties; work the fleet
  can never place is refused outright.
* **worker leasing** (:mod:`repro.frontdoor.leases`) — the gateway owns
  the worker fleet and continuously rebalances it across live sessions
  as forests drain or new keys arrive; revocation lands only at chain
  boundaries (where the fault plane guarantees committed boundary
  checkpoints), so moving a worker never loses work.
* **one global virtual clock** — the gateway always steps the session
  holding the globally-earliest pending event (creation order breaks
  ties), and stamps lease grants and admissions with the global time, so
  makespans across sessions are honestly comparable and a run is fully
  deterministic (and therefore snapshot/restorable mid-flight).

``snapshot()`` persists the *whole deployment* — every session plus the
gateway's own control state — in the schema'd v5 container
(:mod:`repro.frontdoor.snapshot_v5`); :meth:`StudyGateway.restore`
revives all of it and continues the identical event stream, including
the mid-run fault schedule.
"""

from __future__ import annotations

from dataclasses import dataclass
from typing import Any, Dict, List, Optional, Tuple, Union

from repro.core.db import SearchPlanDB
from repro.core.engine import EngineStats, StudyStats, Tuner
from repro.core.engine.session import (SESSION_FORMAT_VERSION,
                                       capture_session, load_latest_session,
                                       load_session, save_session,
                                       save_session_rotated)
from repro.core.study import (PlanKeyMismatch, Study, StudyFuture,
                              StudyService, StudySpec)
from repro.core.trainer import TrainerBackend
from repro.frontdoor.admission import (AdmissionController, Submission,
                                       TenantQuota)
from repro.frontdoor.leases import Lease, WorkerLeaseManager
from repro.frontdoor.snapshot_v5 import GatewayState

__all__ = ["StudyGateway", "GatewayFuture"]

DEFAULT_TENANT = "default"


@dataclass
class GatewayFuture:
    """Handle on one submission's life at the gateway.

    Status: ``queued_admission`` (waiting at the door for quota) →
    then the inner :class:`~repro.core.study.StudyFuture`'s life cycle
    (``queued`` → ``running`` → ``done`` / ``cancelled``); cancelling
    while still at the door withdraws the submission without it ever
    touching a session.
    """

    gateway: "StudyGateway"
    tenant: str
    key: str
    inner: Optional[StudyFuture] = None        # set at admission
    submission: Optional[Submission] = None    # set while at the door
    _finished_recorded: bool = False           # admission slot released
    _cancelled_queued: bool = False            # withdrawn at the door

    # ------------------------------------------------------------ inspection
    @property
    def status(self) -> str:
        if self.inner is not None:
            return self.inner.status
        return "cancelled" if self._cancelled_queued else "queued_admission"

    @property
    def study_id(self) -> Optional[str]:
        if self.inner is not None:
            return self.inner.study_id
        return self.submission.study_id if self.submission else None

    def done(self) -> bool:
        return self.status == "done"

    def cancelled(self) -> bool:
        return self.status == "cancelled"

    @property
    def stats(self) -> StudyStats:
        """Per-study accounting slice — live while the session runs,
        served from the gateway's archive once it retires."""
        if self.inner is not None and self.inner.service is not None:
            return self.inner.stats
        return self.gateway._stats_of(self.key, self.study_id)

    # --------------------------------------------------------------- control
    def result(self) -> StudyStats:
        """Drive the whole gateway until this study completes."""
        while (self.status in ("queued_admission", "queued", "running")
               and self.gateway.step()):
            pass
        if self.status == "cancelled":
            raise RuntimeError(f"study {self.study_id!r} was cancelled")
        if self.status != "done":
            raise RuntimeError(
                f"gateway quiescent but study {self.study_id!r} is not done "
                "— it is starved by a quota cap no finishing study will "
                "ever release, or its tuner waits on an unsubmitted request")
        return self.stats

    def cancel(self) -> bool:
        """Cancel the study (False if it already finished).  At the door:
        the submission is withdrawn.  In a session: detached mid-run like
        any :meth:`StudyFuture.cancel`, and its admission slot freed."""
        if self.status == "done":
            return False
        if self.status == "cancelled":
            return True
        if self.inner is None:
            self.gateway._withdraw(self)
            self._cancelled_queued = True
            return True
        ok = self.inner.cancel()
        self.gateway._pump()
        return ok


class StudyGateway:
    """The front door: multi-tenant, multi-key study traffic over one
    worker fleet (see module docstring).

    ``slot_meshes`` defines the fleet — one entry per worker slot
    (``None`` = classic thread worker, or a
    :class:`~repro.dist.meshes.WorkerMesh`); ``n_slots`` is shorthand for
    ``[None] * n``.  Remaining keyword arguments are forwarded to each
    per-key :class:`StudyService` it spawns (policy, share,
    gpus_per_worker, ...).
    """

    def __init__(self, db: SearchPlanDB, backend: TrainerBackend,
                 n_slots: Optional[int] = None,
                 slot_meshes: Optional[List[Any]] = None,
                 quotas: Optional[Dict[str, TenantQuota]] = None,
                 default_quota: Optional[TenantQuota] = None,
                 max_concurrent: Optional[int] = None,
                 fault_injector=None, store_factory=None, **session_kw):
        if slot_meshes is None:
            slot_meshes = [None] * (4 if n_slots is None else n_slots)
        elif n_slots is not None and n_slots != len(slot_meshes):
            raise ValueError(
                f"n_slots={n_slots} but {len(slot_meshes)} slot meshes")
        self.db = db
        self.backend = backend
        self.fault_injector = fault_injector
        self.store_factory = store_factory       # plan key -> CheckpointStore
        self.session_kw = dict(session_kw)
        self.leases = WorkerLeaseManager(slot_meshes)
        self.admission = AdmissionController(quotas, max_concurrent,
                                             default_quota)
        # plan key -> live session; dict insertion IS creation order (the
        # global clock's tie-break and the snapshot's session order)
        self._sessions: Dict[str, StudyService] = {}
        # plan key -> {study id -> tenant}; never pruned on retirement —
        # study ids are globally unique (study-<seq>), so the archive and
        # any same-key successor session coexist in one map
        self._tenants: Dict[str, Dict[str, str]] = {}
        self._futures: List[GatewayFuture] = []
        self._queued: Dict[int, GatewayFuture] = {}   # submission seq -> fut
        # drained sessions' archive: (key, final EngineStats, futures)
        self._retired: List[Tuple[str, EngineStats, List[StudyFuture]]] = []
        self._time = 0.0                          # global virtual clock
        self._closed = False
        self._auto_snapshot: Optional[Tuple[str, float, int]] = None
        self._next_snapshot_due: Optional[float] = None

    # ------------------------------------------------------------ properties
    @property
    def time(self) -> float:
        """Global virtual clock: the time of the last event stepped in
        any session (monotonic across the whole deployment)."""
        return self._time

    @property
    def sessions(self) -> Dict[str, StudyService]:
        return dict(self._sessions)

    @property
    def futures(self) -> List[GatewayFuture]:
        return list(self._futures)

    @property
    def quiescent(self) -> bool:
        return self._earliest()[0] is None

    # -------------------------------------------------------------- admission
    def submit(self, study: Union[StudySpec, Study, str], tuner: Tuner,
               tenant: str = DEFAULT_TENANT, priority: int = 0,
               study_id: Optional[str] = None, at: Optional[float] = None,
               min_devices: int = 1) -> GatewayFuture:
        """Admit one study through the front door; returns its future.

        Raises :class:`~repro.frontdoor.admission.CapacityError` for work
        the fleet can never place, and
        :class:`~repro.frontdoor.admission.AdmissionQueueFull` when the
        tenant's bounded admission queue is full.  Otherwise the study is
        either admitted now (routed to its plan key's session, spawned on
        demand) or waits at the door (``queued_admission``) until the
        weighted fair-share dequeue picks it."""
        if self._closed:
            raise RuntimeError("gateway is closed — create a new one")
        key = StudyService._key_of(study)
        self.admission.check_capacity(min_devices, self.leases.slot_widths())
        sub = Submission(tenant, priority, self.admission.next_seq(), key,
                         tuner, study_id=study_id, min_devices=min_devices,
                         arrival=at)
        fut = GatewayFuture(self, tenant=tenant, key=key, submission=sub)
        deferred = (self.fault_injector is not None
                    and self.fault_injector.on_admission(f"submit:{key}"))
        if deferred:
            # injected control-plane fault: the admission decision was
            # lost this round; the study queues and the next pump retries
            self.admission.defer(sub)
            self._queued[sub.seq] = fut
        elif self.admission.offer(sub):        # may raise AdmissionQueueFull
            self._admit(sub, fut)
        else:
            self._queued[sub.seq] = fut
        self._futures.append(fut)
        self._pump()
        return fut

    def _admit(self, sub: Submission, fut: GatewayFuture) -> None:
        """Route one admitted submission into its per-key session."""
        svc = self._session_for(sub.key)
        sid = sub.study_id if sub.study_id is not None else f"study-{sub.seq}"
        at = self._time if sub.arrival is None else max(sub.arrival,
                                                        self._time)
        try:
            inner = svc.submit(sub.key, sub.tuner, study_id=sid, at=at)
        except PlanKeyMismatch as exc:
            # the routing table pointed at a session driving a different
            # forest (a hand-registered or mis-restored session): re-file
            # it under the key it actually serves — authoritative on the
            # structured error — and route this submission to a fresh
            # session for its own key
            misfiled = self._sessions.pop(sub.key)
            self._sessions.setdefault(exc.session_key, misfiled)
            inner = self._session_for(sub.key).submit(
                sub.key, sub.tuner, study_id=sid, at=at)
            svc = self._sessions[sub.key]
        fut.inner = inner
        fut.submission = None
        self._tenants.setdefault(sub.key, {})[sid] = sub.tenant
        self.admission.on_started(sub.key, sid, sub.tenant)
        # tenant quota weight flows into the session's fair-share policy,
        # so weighted shares also hold INSIDE a shared (multi-tenant) forest
        weight = self.admission.quota(sub.tenant).weight
        if hasattr(svc.scheduler, "set_study_weights"):
            svc.scheduler.set_study_weights({sid: weight})

    def _session_for(self, key: str) -> StudyService:
        svc = self._sessions.get(key)
        if svc is None:
            store = self.store_factory(key) if self.store_factory else None
            # sessions start with ZERO workers — every worker they ever
            # run arrives as a lease grant from the gateway's fleet
            svc = StudyService(self.db, self.backend, n_workers=0,
                               store=store,
                               fault_injector=self.fault_injector,
                               **self.session_kw)
            self._sessions[key] = svc
        return svc

    def _withdraw(self, fut: GatewayFuture) -> None:
        sub = fut.submission
        if sub is not None and sub in self.admission.queue:
            self.admission.queue.remove(sub)
        if sub is not None:
            self._queued.pop(sub.seq, None)

    # ------------------------------------------------------------- the pump
    def _weighted_usage(self, tenant: str) -> float:
        return (self._tenant_gpu_seconds(tenant)
                / self.admission.quota(tenant).weight)

    def _tenant_gpu_seconds(self, tenant: str) -> float:
        total = 0.0
        for key, stats, _ in self._retired:
            total += self._credit_of(key, stats, tenant)
        for key, svc in self._sessions.items():
            total += self._credit_of(key, svc.stats, tenant)
        return total

    def _credit_of(self, key: str, stats: EngineStats, tenant: str) -> float:
        tmap = self._tenants.get(key, {})
        return sum(ss.gpu_seconds for sid, ss in stats.by_study.items()
                   if tmap.get(sid, DEFAULT_TENANT) == tenant)

    def _demand(self, key: str) -> int:
        """A session's claim on the fleet: its unfinished studies."""
        return sum(1 for f in self._futures
                   if f.key == key and f.inner is not None
                   and f.inner.status in ("queued", "running"))

    def _pump(self) -> None:
        """Settle finished studies, retire drained sessions, admit queued
        submissions, and follow demand with the fleet.  Idempotent —
        called around every step and submission."""
        for fut in self._futures:
            if (fut.inner is not None and not fut._finished_recorded
                    and fut.inner.status in ("done", "cancelled")):
                self.admission.on_finished(fut.key, fut.inner.study_id)
                fut._finished_recorded = True
        self._retire_drained()
        while True:
            sub = self.admission.pop_admissible(self._weighted_usage)
            if sub is None:
                break
            self._admit(sub, self._queued.pop(sub.seq))
        demands = {key: self._demand(key) for key in self._sessions}
        engines = {key: svc.engine for key, svc in self._sessions.items()}
        self.leases.rebalance(demands, engines, at=self._time)

    def _retire_drained(self) -> None:
        """Close and archive sessions whose forest has fully drained and
        that no live or queued submission still targets."""
        for key in list(self._sessions):
            svc = self._sessions[key]
            if svc.engine is None or not svc.quiescent:
                continue
            if self._demand(key) > 0:
                continue
            if any(s.key == key for s in self.admission.queue):
                continue
            self.leases.release_key(key, svc.engine)
            stats = svc.close()
            self._retired.append((key, stats, svc.futures))
            del self._sessions[key]

    # ------------------------------------------------------------ the session
    def _earliest(self) -> Tuple[Optional[str], Optional[float]]:
        """The session holding the globally-earliest pending event
        (creation order breaks time ties)."""
        best_key, best_t = None, None
        for key, svc in self._sessions.items():
            eng = svc.engine
            if eng is None:
                continue
            ev = eng.events.peek()
            if ev is not None and (best_t is None or ev.time < best_t):
                best_key, best_t = key, ev.time
        return best_key, best_t

    def step(self) -> bool:
        """Advance the deployment by exactly one event: the globally
        earliest one across every session.  False at quiescence."""
        self._pump()
        key, t = self._earliest()
        if key is None:
            return False
        self._time = max(self._time, t)
        self._sessions[key].step()
        self._pump()
        self._maybe_auto_snapshot()
        return True

    def run_until(self, t: float) -> None:
        """Drive every event scheduled at or before global time ``t``."""
        while True:
            self._pump()
            key, nxt = self._earliest()
            if key is None or nxt > t:
                break
            self.step()

    def join(self) -> None:
        """Drive everything to completion; raises if any study can never
        finish (stuck at the door or inside a session)."""
        while self.step():
            pass
        stuck = [f.study_id or f"seq-{f.submission.seq}"
                 for f in self._futures
                 if f.status in ("queued_admission", "queued", "running")]
        if stuck:
            raise RuntimeError(
                f"gateway quiescent but studies not done: {stuck} — either "
                "starved by a quota cap nothing will release, or a tuner "
                "waits on a request that was never submitted")

    def close(self) -> List[Tuple[str, EngineStats]]:
        """Drain everything, close every session, return the archive:
        one ``(plan key, final EngineStats)`` per retired session, in
        retirement order."""
        if not self._closed:
            try:
                self.join()
            finally:
                self._closed = True
                for key in list(self._sessions):
                    # join() raised mid-drain: still run each session's
                    # durability barrier before abandoning it
                    svc = self._sessions.pop(key)
                    if svc.engine is not None:
                        svc._closed = True
                        svc.engine.finish()
                        self.db.checkpoint(key)
        return [(key, stats) for key, stats, _ in self._retired]

    def __enter__(self) -> "StudyGateway":
        return self

    def __exit__(self, exc_type, exc, tb) -> None:
        if exc_type is None:
            if not self._closed:
                self.close()
        else:
            self._closed = True
            for svc in self._sessions.values():
                if svc.engine is not None:
                    svc._closed = True
                    svc.engine.finish()

    # -------------------------------------------------------------- reporting
    def _stats_of(self, key: str, study_id: Optional[str]) -> StudyStats:
        svc = self._sessions.get(key)
        if svc is not None and study_id in svc.stats.by_study:
            return svc.stats.by_study[study_id]
        for k, stats, _ in reversed(self._retired):
            if k == key and study_id in stats.by_study:
                return stats.by_study[study_id]
        return StudyStats()

    def tenant_ledger(self) -> Dict[str, Dict[str, float]]:
        """Per-tenant accounting across the whole deployment — live and
        retired sessions alike.  ``gpu_seconds`` is the tenant's
        split-charged share of every forest it ran in (the sum across
        tenants equals the sum of ``EngineStats.by_study`` shares);
        ``studies``/``running``/``queued`` count its submissions."""
        ledger: Dict[str, Dict[str, float]] = {}

        def entry(t: str) -> Dict[str, float]:
            return ledger.setdefault(t, {"gpu_seconds": 0.0, "studies": 0,
                                         "running": 0, "queued": 0})

        for tenant in self.admission.quotas:
            entry(tenant)
        seen = [(key, stats) for key, stats, _ in self._retired]
        seen += [(key, svc.stats) for key, svc in self._sessions.items()]
        for key, stats in seen:
            tmap = self._tenants.get(key, {})
            for sid, ss in stats.by_study.items():
                entry(tmap.get(sid, DEFAULT_TENANT))["gpu_seconds"] += \
                    ss.gpu_seconds
        for f in self._futures:
            e = entry(f.tenant)
            e["studies"] += 1
            if f.status == "queued_admission":
                e["queued"] += 1
            elif f.status in ("queued", "running"):
                e["running"] += 1
        return ledger

    # ------------------------------------------------------------ persistence
    def _capture(self) -> GatewayState:
        sessions = []
        for key, svc in self._sessions.items():
            if svc.engine is None:
                continue
            sessions.append((key, capture_session(
                svc.engine, service={"futures": svc._futures})))
        return GatewayState(
            version=SESSION_FORMAT_VERSION,
            time=self._time,
            max_concurrent=self.admission.max_concurrent,
            seq=self.admission.seq,
            quotas={t: q.to_json()
                    for t, q in self.admission.quotas.items()},
            default_quota=self.admission.default_quota.to_json(),
            tenants={k: dict(v) for k, v in self._tenants.items()},
            sessions=sessions,
            slot_meshes=list(self.leases.slot_meshes),
            leases=[(l.slot, l.key, l.wid, l.draining)
                    for _, l in sorted(self.leases.leases.items())],
            queued=list(self.admission.queue),
            retired=list(self._retired),
            injector_state=(self.fault_injector.snapshot_state()
                            if self.fault_injector is not None else None),
            service={"auto_snapshot": self._auto_snapshot,
                     "admission_faults": self.admission.admission_faults},
        )

    def snapshot(self, path: str) -> str:
        """Persist the whole deployment — every session plus the gateway
        control plane — as one v5 gateway envelope (flushes each
        session's write-behind store first)."""
        return save_session(self._capture(), path)

    def enable_auto_snapshot(self, base: str, every: float,
                             keep: int = 3) -> None:
        """Continuous durability at deployment scope: one rotated gateway
        envelope ``base.<seq>`` after the first event past each ``every``
        global virtual seconds (newest ``keep`` retained)."""
        if every <= 0:
            raise ValueError(f"snapshot interval must be > 0, got {every}")
        self._auto_snapshot = (base, float(every), int(keep))
        self._next_snapshot_due = None

    def _maybe_auto_snapshot(self) -> None:
        if self._auto_snapshot is None or not self._sessions:
            return
        base, every, keep = self._auto_snapshot
        if self._next_snapshot_due is None:
            self._next_snapshot_due = (self._time // every + 1) * every
        if self._time < self._next_snapshot_due:
            return
        self.snapshot_rotated()
        while self._next_snapshot_due <= self._time:
            self._next_snapshot_due += every

    def snapshot_rotated(self) -> str:
        if self._auto_snapshot is None:
            raise RuntimeError("call enable_auto_snapshot(base, every) first")
        base, every, keep = self._auto_snapshot
        return save_session_rotated(self._capture(), base, keep=keep)

    @classmethod
    def restore(cls, db: SearchPlanDB, path: str, backend: TrainerBackend,
                store_factory=None, fault_injector=None,
                **session_kw) -> "StudyGateway":
        """Revive a snapshotted deployment against a fresh backend: every
        session continues its exact event stream, the lease table and
        admission queue pick up where they were, and a supplied
        ``fault_injector`` resumes the captured mid-run fault schedule
        (continuing it, not replaying it from the seed)."""
        return cls._restore_state(db, load_session(path), backend,
                                  store_factory, fault_injector,
                                  **session_kw)

    @classmethod
    def restore_latest(cls, db: SearchPlanDB, base: str,
                       backend: TrainerBackend, store_factory=None,
                       fault_injector=None, **session_kw) -> "StudyGateway":
        """:meth:`restore` from the newest readable rotation slot of
        ``base``; re-enables the captured auto-snapshot cadence."""
        state, _ = load_latest_session(base)
        return cls._restore_state(db, state, backend, store_factory,
                                  fault_injector, **session_kw)

    @classmethod
    def _restore_state(cls, db, state, backend, store_factory,
                       fault_injector, **session_kw) -> "StudyGateway":
        if not isinstance(state, GatewayState):
            raise ValueError(
                "snapshot holds a single session, not a gateway envelope — "
                "restore it with repro.core.study.StudyService.restore")
        gw = cls(db, backend,
                 slot_meshes=state.slot_meshes,
                 quotas={t: TenantQuota.from_json(q)
                         for t, q in state.quotas.items()},
                 default_quota=TenantQuota.from_json(state.default_quota),
                 max_concurrent=state.max_concurrent,
                 fault_injector=fault_injector,
                 store_factory=store_factory, **session_kw)
        if fault_injector is not None and state.injector_state is not None:
            fault_injector.restore_state(state.injector_state)
        gw._time = state.time
        gw.admission.seq = state.seq
        gw.admission.queue = list(state.queued)
        gw.admission.admission_faults = state.service.get(
            "admission_faults", 0)
        gw._tenants = {k: dict(v) for k, v in state.tenants.items()}
        gw._retired = list(state.retired)
        for key, sess in state.sessions:
            store = store_factory(key) if store_factory else None
            gw._sessions[key] = StudyService._restore_state(
                db, sess, backend, store, fault_injector)
        for slot, key, wid, draining in state.leases:
            gw.leases.leases[slot] = Lease(slot, key, wid, bool(draining))
        # rebuild the future table deterministically: retired archive
        # first, then live sessions in creation order, then the admission
        # queue (scheduler weights travel inside each session's pickled
        # policy; only admission slots re-register)
        for key, _, futs in gw._retired:
            tmap = gw._tenants.get(key, {})
            for inner in futs:
                gw._futures.append(GatewayFuture(
                    gw, tenant=tmap.get(inner.study_id, DEFAULT_TENANT),
                    key=key, inner=inner, _finished_recorded=True))
        for key, svc in gw._sessions.items():
            tmap = gw._tenants.get(key, {})
            for inner in svc._futures:
                tenant = tmap.get(inner.study_id, DEFAULT_TENANT)
                fut = GatewayFuture(gw, tenant=tenant, key=key, inner=inner)
                if inner.status in ("done", "cancelled"):
                    fut._finished_recorded = True
                else:
                    gw.admission.on_started(key, inner.study_id, tenant)
                gw._futures.append(fut)
        for sub in gw.admission.queue:
            fut = GatewayFuture(gw, tenant=sub.tenant, key=sub.key,
                                submission=sub)
            gw._queued[sub.seq] = fut
            gw._futures.append(fut)
        auto = state.service.get("auto_snapshot")
        if auto:
            gw.enable_auto_snapshot(*auto)
        return gw
