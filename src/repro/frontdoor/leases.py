"""Worker leases — one fleet, many sessions, boundary-safe rebalancing.

The gateway owns a fixed fleet of worker *slots* (each optionally a
:class:`~repro.dist.meshes.WorkerMesh`); sessions own none.  Every worker
a session runs is a **lease** of one slot, granted and revoked here.  The
PR 9 fault plane makes revocation lossless: the engine only ever releases
a worker at a *chain boundary* (``ExecutionEngine.remove_worker`` marks a
busy worker draining; it departs when its idle event fires), and every
boundary checkpoint is committed by then — so moving a worker between
sessions never forfeits work, it only moves future capacity.

``rebalance`` recomputes a target allocation proportional to each live
session's demand (its unfinished studies), floor-of-share plus
largest-remainder so targets always sum to the fleet, with every
demanding session guaranteed one slot when the fleet is large enough.
Surplus sessions drain their latest-granted (idle-first) leases; freed
slots are granted to deficit sessions in creation order.  The pump is
eventually consistent: a draining lease frees its slot at the next
``reap`` after the chain boundary, and the following rebalance hands it
on — capacity follows demand at chain granularity.

All iteration orders are explicit (slot order, session creation order,
wid order), so a gateway run — and its snapshot/restore — is
deterministic.
"""

from __future__ import annotations

from dataclasses import dataclass
from typing import Dict, List, Optional

__all__ = ["Lease", "WorkerLeaseManager"]


@dataclass
class Lease:
    """One fleet slot currently (or still, while draining) owned by a
    session."""

    slot: int              # fleet slot index (mesh descriptor lives there)
    key: str               # plan key of the owning session
    wid: int               # worker id inside the session's engine
    draining: bool = False  # revoked; departs at its chain boundary


class WorkerLeaseManager:
    """Owns the fleet's slots and the lease table over them."""

    def __init__(self, slot_meshes: List[Optional[object]]):
        self.slot_meshes = list(slot_meshes)
        self.leases: Dict[int, Lease] = {}    # slot -> lease

    # ----------------------------------------------------------- inspection
    @property
    def n_slots(self) -> int:
        return len(self.slot_meshes)

    def slot_widths(self) -> List[int]:
        """Device width of every slot (1 for classic thread workers) —
        the admission capacity gate's input."""
        return [m.n_devices if m is not None else 1
                for m in self.slot_meshes]

    def free_slots(self) -> List[int]:
        return [s for s in range(self.n_slots) if s not in self.leases]

    def held(self, key: str, include_draining: bool = False) -> List[Lease]:
        return [l for l in self.leases.values()
                if l.key == key and (include_draining or not l.draining)]

    # ---------------------------------------------------------- grant/revoke
    def grant(self, slot: int, key: str, engine,
              at: Optional[float] = None) -> Lease:
        """Lease ``slot`` to ``key``'s engine: the engine grows a worker
        that cannot start before global time ``at`` (a worker moved over
        from another session must not compute in the receiver's past)."""
        if slot in self.leases:
            raise RuntimeError(f"slot {slot} is already leased "
                               f"to {self.leases[slot].key!r}")
        w = engine.add_worker(mesh=self.slot_meshes[slot], at=at)
        lease = Lease(slot, key, w.wid)
        self.leases[slot] = lease
        return lease

    def revoke(self, lease: Lease, engine) -> bool:
        """Revoke one lease.  An idle worker leaves immediately (slot
        freed, True); a busy one drains to its chain boundary (False) and
        frees the slot at a later :meth:`reap`."""
        if engine is None or engine.remove_worker(lease.wid):
            del self.leases[lease.slot]
            return True
        lease.draining = True
        return False

    def release_key(self, key: str, engine) -> None:
        """Revoke every lease a (retiring) session holds."""
        for lease in sorted(self.held(key, include_draining=True),
                            key=lambda l: l.slot):
            if not lease.draining:
                self.revoke(lease, engine)
            elif engine is None or engine.worker(lease.wid) is None:
                del self.leases[lease.slot]

    def reap(self, engines: Dict[str, object]) -> List[int]:
        """Free the slots of draining leases whose worker has departed
        (its chain boundary passed); returns the freed slot ids."""
        freed = []
        for slot in sorted(self.leases):
            lease = self.leases[slot]
            if not lease.draining:
                continue
            eng = engines.get(lease.key)
            if eng is None or eng.worker(lease.wid) is None:
                del self.leases[slot]
                freed.append(slot)
        return freed

    # ------------------------------------------------------------ rebalance
    def targets(self, demands: Dict[str, int]) -> Dict[str, int]:
        """Slot targets proportional to demand (floor + largest
        remainder), each demanding key guaranteed one slot when the fleet
        has enough.  ``demands`` iterates in session-creation order, which
        breaks every tie deterministically."""
        active = [k for k, d in demands.items() if d > 0]
        if not active:
            return {k: 0 for k in demands}
        total = self.n_slots
        floor_each = 1 if total >= len(active) else 0
        spare = total - floor_each * len(active)
        weight = sum(demands[k] for k in active)
        shares = [(k, spare * demands[k] / weight) for k in active]
        out = {k: floor_each + int(s) for k, s in shares}
        leftover = total - sum(out.values())
        # largest fractional remainder first; creation order breaks ties
        by_rem = sorted(shares, key=lambda ks: -(ks[1] - int(ks[1])))
        for k, _ in by_rem:
            if leftover <= 0:
                break
            out[k] += 1
            leftover -= 1
        for k in demands:
            out.setdefault(k, 0)
        return out

    def rebalance(self, demands: Dict[str, int], engines: Dict[str, object],
                  at: Optional[float] = None) -> int:
        """One rebalance pump: reap drained leases, revoke surpluses,
        grant free slots to deficits.  Returns the number of lease moves
        (revocations + grants) — zero when the allocation already matches
        the targets."""
        self.reap(engines)
        target = self.targets(demands)
        moves = 0
        # shrink surpluses first so their slots can serve deficits (idle
        # workers free immediately; busy ones free at their boundary)
        for key in demands:
            eng = engines.get(key)
            held = sorted(self.held(key), key=lambda l: l.slot)
            surplus = len(held) - target.get(key, 0)
            if surplus <= 0 or eng is None:
                continue
            # idle workers first (their slot frees right now), then the
            # latest-granted — the longest-held leases keep their locality
            def _order(l):
                w = eng.worker(l.wid)
                return (0 if (w is not None and w.idle) else 1, -l.slot)
            for lease in sorted(held, key=_order)[:surplus]:
                self.revoke(lease, eng)
                moves += 1
        # grow deficits from whatever is free, creation order first
        free = self.free_slots()
        for key in demands:
            eng = engines.get(key)
            if eng is None:
                continue
            deficit = target.get(key, 0) - len(self.held(key))
            while deficit > 0 and free:
                self.grant(free.pop(0), key, eng, at=at)
                moves += 1
                deficit -= 1
        return moves
