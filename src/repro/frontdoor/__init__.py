"""Front door: multi-tenant study gateway over the service plane.

``StudyGateway`` routes continuously-arriving studies from many tenants
to per-plan-key :class:`~repro.core.study.StudyService` sessions, applies
per-tenant weighted fair-share admission control, leases one worker fleet
across every live session, and persists the whole deployment as one
schema'd v5 snapshot.  See :mod:`repro.frontdoor.gateway`.
"""

from repro.frontdoor.admission import (AdmissionController,
                                       AdmissionQueueFull, CapacityError,
                                       Submission, TenantQuota)
from repro.frontdoor.gateway import GatewayFuture, StudyGateway
from repro.frontdoor.leases import Lease, WorkerLeaseManager
from repro.frontdoor.snapshot_v5 import (SNAPSHOT_MAGIC, GatewayState,
                                         decode_snapshot, encode_snapshot,
                                         is_v5_snapshot)

__all__ = [
    "StudyGateway", "GatewayFuture",
    "AdmissionController", "TenantQuota", "Submission",
    "AdmissionQueueFull", "CapacityError",
    "WorkerLeaseManager", "Lease",
    "GatewayState", "encode_snapshot", "decode_snapshot", "is_v5_snapshot",
    "SNAPSHOT_MAGIC",
]
