"""Checkpointable data pipeline (Hippo §5.1).

The paper's two pipeline requirements, implemented for JAX:

1. **Position-in-dataset checkpointing** — "the current permutation of the
   dataset [is] part of the checkpoint".  The pipeline state is
   ``(seed, epoch, cursor)``; the epoch's permutation is *re-derived* from
   ``(seed, epoch)`` (deterministic threefry), so the state is three ints —
   cheap to checkpoint yet bit-exact to resume: a trial resumed from a
   shared stage checkpoint sees exactly the sample stream it would have
   seen training straight through.

2. **Runtime batch-size change** — ``set_batch_size`` re-batches from the
   current cursor (the PyTorch analogue flushes prefetch queues and
   relaunches workers; here there is nothing to flush — the next batch is
   simply sliced at the new size).

Works over any dict-of-arrays dataset (token corpora, image/label pairs).
"""

from __future__ import annotations

from typing import Any, Dict, Optional, Tuple

import numpy as np

__all__ = ["DataPipeline", "synthetic_lm_dataset", "synthetic_cifar"]


class DataPipeline:
    def __init__(self, data: Dict[str, np.ndarray], batch_size: int,
                 seed: int = 0, drop_last: bool = True):
        sizes = {k: len(v) for k, v in data.items()}
        assert len(set(sizes.values())) == 1, f"ragged dataset: {sizes}"
        self.data = data
        self.n = next(iter(sizes.values()))
        self.batch_size = int(batch_size)
        self.seed = int(seed)
        self.drop_last = drop_last
        self.epoch = 0
        self.cursor = 0
        self._perm_epoch: Optional[int] = None
        self._perm: Optional[np.ndarray] = None

    # ---------------------------------------------------------- permutation
    def _permutation(self, epoch: int) -> np.ndarray:
        if self._perm_epoch != epoch:
            rng = np.random.default_rng((self.seed, epoch))
            self._perm = rng.permutation(self.n)
            self._perm_epoch = epoch
        return self._perm

    # -------------------------------------------------------------- batches
    def next_batch(self) -> Dict[str, np.ndarray]:
        if self.cursor + self.batch_size > self.n:
            # wrap to a fresh epoch (drop the ragged tail)
            self.epoch += 1
            self.cursor = 0
        perm = self._permutation(self.epoch)
        idx = perm[self.cursor:self.cursor + self.batch_size]
        self.cursor += self.batch_size
        return {k: v[idx] for k, v in self.data.items()}

    def next_batches(self, count: int) -> Dict[str, np.ndarray]:
        """Prefetch ``count`` consecutive batches as one stacked slab.

        Returns ``{field: array[count, batch_size, ...]}`` and advances the
        pipeline state exactly as ``count`` calls of :meth:`next_batch`
        would — same permutation walk, same epoch wraps, bit-identical rows
        — but gathers each run of in-epoch batches with a single fancy
        index instead of one gather per step.  This is the data slab the
        fused trainer feeds to a whole-stage executable.
        """
        assert self.batch_size <= self.n, (
            f"batch_size {self.batch_size} exceeds dataset size {self.n}")
        chunks: Dict[str, list] = {k: [] for k in self.data}
        remaining = int(count)
        while remaining > 0:
            if self.cursor + self.batch_size > self.n:
                self.epoch += 1
                self.cursor = 0
            perm = self._permutation(self.epoch)
            fit = (self.n - self.cursor) // self.batch_size
            take = min(remaining, fit)
            idx = perm[self.cursor:self.cursor + take * self.batch_size]
            idx = idx.reshape(take, self.batch_size)
            for k, v in self.data.items():
                chunks[k].append(v[idx])
            self.cursor += take * self.batch_size
            remaining -= take
        return {k: (c[0] if len(c) == 1 else np.concatenate(c))
                for k, c in chunks.items()}

    def set_batch_size(self, batch_size: int) -> None:
        """§5.1: change batch size mid-study; position is preserved."""
        self.batch_size = int(batch_size)

    # ------------------------------------------------------------ ckpt state
    def state(self) -> Tuple[int, int, int, int]:
        return (self.seed, self.epoch, self.cursor, self.batch_size)

    def restore(self, state) -> None:
        self.seed, self.epoch, self.cursor, self.batch_size = (
            int(state[0]), int(state[1]), int(state[2]), int(state[3]))
        self._perm_epoch = None  # re-derive lazily


# ---------------------------------------------------------------------------
# synthetic datasets (offline container: no downloads)
# ---------------------------------------------------------------------------


def synthetic_lm_dataset(n: int, seq_len: int, vocab: int,
                         seed: int = 0) -> Dict[str, np.ndarray]:
    """Markov-ish token stream: learnable (next token correlates with
    current), so loss actually decreases under training."""
    rng = np.random.default_rng(seed)
    base = rng.integers(0, vocab, size=(n, 1), dtype=np.int32)
    drift = rng.integers(0, 7, size=(n, seq_len), dtype=np.int32)
    toks = (base + np.cumsum(drift, axis=1)) % vocab
    return {"tokens": toks.astype(np.int32)}


def synthetic_cifar(n: int, seed: int = 0) -> Dict[str, np.ndarray]:
    """CIFAR-shaped synthetic classification set (10 classes, 32×32×3).
    Class-conditional Gaussian blobs — linearly separable enough that a
    small ResNet trains to high accuracy in a few hundred steps."""
    rng = np.random.default_rng(seed)
    labels = rng.integers(0, 10, size=n).astype(np.int32)
    protos = rng.normal(0, 1.0, size=(10, 8)).astype(np.float32)
    proj = rng.normal(0, 1.0, size=(8, 32 * 32 * 3)).astype(np.float32) / 8.0
    x = protos[labels] @ proj + rng.normal(0, 0.5, size=(n, 32 * 32 * 3))
    images = x.reshape(n, 32, 32, 3).astype(np.float32)
    return {"images": images, "labels": labels}
