"""Data pipeline substrate."""

from repro.data.pipeline import DataPipeline, synthetic_cifar, synthetic_lm_dataset
