"""Stable content hashing for search-plan keys and checkpoint addressing.

Everything that identifies a computation (hyper-parameter functions, trial
prefixes, study keys) is hashed through a canonical JSON encoding so that
equality is structural, reproducible across processes, and journal-safe.
"""

from __future__ import annotations

import hashlib
import json
from typing import Any


def _canon(obj: Any) -> Any:
    """Recursively convert to a canonical JSON-encodable form."""
    if isinstance(obj, dict):
        return {str(k): _canon(v) for k, v in sorted(obj.items(), key=lambda kv: str(kv[0]))}
    if isinstance(obj, (list, tuple)):
        return [_canon(v) for v in obj]
    if isinstance(obj, float):
        # canonical float formatting (repr round-trips in python3)
        return float(repr(obj))
    if isinstance(obj, (str, int, bool)) or obj is None:
        return obj
    # objects exposing a canonical encoding
    to_json = getattr(obj, "to_json", None)
    if callable(to_json):
        return _canon(to_json())
    raise TypeError(f"cannot canonically hash object of type {type(obj)!r}: {obj!r}")


def stable_hash(obj: Any) -> str:
    """SHA-1 hex digest of the canonical JSON encoding of ``obj``."""
    payload = json.dumps(_canon(obj), sort_keys=True, separators=(",", ":"))
    return hashlib.sha1(payload.encode("utf-8")).hexdigest()


def short_hash(obj: Any, n: int = 10) -> str:
    return stable_hash(obj)[:n]
