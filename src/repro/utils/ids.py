"""Deterministic id generation (no wall-clock / randomness: journal-safe)."""

from __future__ import annotations

import itertools


class IdGen:
    """Monotonic id generator with a string prefix, e.g. ``stage-17``."""

    def __init__(self, prefix: str, start: int = 0):
        self.prefix = prefix
        self._counter = itertools.count(start)

    def __call__(self) -> str:
        return f"{self.prefix}-{next(self._counter)}"
