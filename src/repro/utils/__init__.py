"""Shared utilities: stable hashing, id generation, simple logging."""

from repro.utils.hashing import stable_hash, short_hash
from repro.utils.ids import IdGen

__all__ = ["stable_hash", "short_hash", "IdGen"]
