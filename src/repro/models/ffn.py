"""Feed-forward blocks: SwiGLU MLP and Mixture-of-Experts.

The MoE layer covers both assigned MoE architectures:

* grok-1-314b:    8 routed experts, top-2, no shared experts;
* qwen2-moe-a2.7b: 60 routed experts (d_ff 1408), top-4, plus 4 shared
  experts implemented as one always-on SwiGLU of hidden 4×1408
  (= ``shared_d_ff``).

Dispatch uses the standard capacity-based one-hot formulation: tokens are
combined into per-expert buffers with an einsum whose expert dimension is
sharded over the ``model`` mesh axis — under GSPMD this lowers to the
expert-parallel all-to-all the paper's technique cares about.  An auxiliary
load-balancing loss (Shazeer-style) is returned for the train step.
"""

from __future__ import annotations

from typing import Any, Dict, Tuple

import jax
import jax.numpy as jnp

from repro.models.config import ModelConfig
from repro.models.layers import dense_init

__all__ = ["init_mlp", "mlp_forward", "init_moe", "moe_forward"]


# ---------------------------------------------------------------------------
# dense SwiGLU
# ---------------------------------------------------------------------------


def init_mlp(d_model: int, d_ff: int, key, dtype, gated: bool = True
             ) -> Dict[str, Any]:
    k1, k2, k3 = jax.random.split(key, 3)
    p = {
        "wi": dense_init(k1, (d_model, d_ff), dtype=dtype),     # up
        "wo": dense_init(k3, (d_ff, d_model), dtype=dtype),
    }
    if gated:
        p["wg"] = dense_init(k2, (d_model, d_ff), dtype=dtype)  # gate
    return p


def mlp_forward(params, x: jnp.ndarray) -> jnp.ndarray:
    if "wg" in params:
        return (jax.nn.silu(x @ params["wg"]) * (x @ params["wi"])) @ params["wo"]
    return jax.nn.gelu(x @ params["wi"]) @ params["wo"]


# ---------------------------------------------------------------------------
# mixture of experts
# ---------------------------------------------------------------------------


def init_moe(cfg: ModelConfig, key, dtype) -> Dict[str, Any]:
    E, D, F = cfg.n_experts, cfg.d_model, cfg.moe_d_ff
    k1, k2, k3, k4, k5 = jax.random.split(key, 5)
    p = {
        "router": dense_init(k1, (D, E), scale=D ** -0.5, dtype=jnp.float32),
        "wi": dense_init(k2, (E, D, F), dtype=dtype),
        "wg": dense_init(k3, (E, D, F), dtype=dtype),
        "wo": dense_init(k4, (E, F, D), dtype=dtype),
    }
    if cfg.n_shared_experts:
        p["shared"] = init_mlp(D, cfg.shared_d_ff, k5, dtype)
    return p


_GROUP_TOKENS = 4096  # dispatch-group size (MaxText-style token groups)


def moe_forward(params, cfg: ModelConfig, x: jnp.ndarray
                ) -> Tuple[jnp.ndarray, jnp.ndarray]:
    """x: (B, S, D) → (out, aux_loss).

    Token-grouped top-k routing: tokens are split into groups of ~4096 and
    each group gets its own expert capacity ``C = ceil(cf · Tg·k / E)`` —
    the dispatch one-hots are (G, Tg, E, C) instead of a single global
    (T, E, C) whose capacity (and memory) would scale with the *global*
    batch.  The group dim inherits the batch's data sharding; the (g → e)
    buffer einsum is the expert-parallel all-to-all.  Overflow tokens are
    dropped (standard Switch behaviour).
    """
    B, S, D = x.shape
    E, K = cfg.n_experts, cfg.top_k
    T = B * S
    G = T // _GROUP_TOKENS if T % _GROUP_TOKENS == 0 else 1
    Tg = T // G
    xt = x.reshape(G, Tg, D)

    logits = (xt.astype(jnp.float32) @ params["router"])            # (G,Tg,E)
    probs = jax.nn.softmax(logits, axis=-1)
    gate_vals, expert_idx = jax.lax.top_k(probs, K)                 # (G,Tg,K)
    gate_vals = gate_vals / jnp.sum(gate_vals, -1, keepdims=True)

    # ---- aux load-balance loss: E * sum_e f_e * p_e (global means)
    me = jnp.mean(probs, axis=(0, 1))                               # (E,)
    ce = jnp.mean(jax.nn.one_hot(expert_idx[..., 0], E), axis=(0, 1))
    aux = E * jnp.sum(me * ce)

    capacity = int(max(K, cfg.capacity_factor * Tg * K / E))
    # position of each (token, k) within its expert's per-group buffer
    onehot = jax.nn.one_hot(expert_idx, E, dtype=jnp.int32)         # (G,Tg,K,E)
    flat = onehot.reshape(G, Tg * K, E)
    pos_in_expert = jnp.cumsum(flat, axis=1) * flat - 1
    pos = pos_in_expert.reshape(G, Tg, K, E).max(-1)                # (G,Tg,K)
    keep = pos < capacity

    # dispatch / combine one-hots; overflow (pos >= capacity) maps to the
    # out-of-range index `capacity`, which one_hot encodes as all-zeros
    e_onehot = jax.nn.one_hot(expert_idx, E, dtype=xt.dtype)        # (G,Tg,K,E)
    c_onehot = jax.nn.one_hot(jnp.where(keep, pos, capacity), capacity,
                              dtype=xt.dtype)                       # (G,Tg,K,C)
    disp = jnp.einsum("gtke,gtkc->gtec", e_onehot, c_onehot)        # (G,Tg,E,C)
    buf = jnp.einsum("gtd,gtec->gecd", xt, disp)                    # (G,E,C,D)

    # expert computation; the (g,e) layout is where expert parallelism
    # lives — E sharded over the ep axis makes this the all-to-all
    h = jax.nn.silu(jnp.einsum("gecd,edf->gecf", buf, params["wg"]))
    h = h * jnp.einsum("gecd,edf->gecf", buf, params["wi"])
    out_buf = jnp.einsum("gecf,efd->gecd", h, params["wo"])         # (G,E,C,D)

    comb = jnp.einsum("gtke,gtkc,gtk->gtec", e_onehot, c_onehot,
                      (gate_vals * keep).astype(xt.dtype))          # (G,Tg,E,C)
    out = jnp.einsum("gecd,gtec->gtd", out_buf, comb)

    if cfg.n_shared_experts:
        out = out + mlp_forward(params["shared"], xt)
    return out.reshape(B, S, D), aux.astype(jnp.float32)
