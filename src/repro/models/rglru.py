"""RG-LRU recurrent block (Griffin / RecurrentGemma, arXiv:2402.19427).

Real-gated linear recurrent unit::

    r_t = σ(g_r ⊙ u_t)                       (recurrence gate, per-channel)
    a_t = exp(c · r_t · log σ(Λ))            (gated per-channel decay, c=8)
    h_t = a_t ⊙ h_{t-1} + sqrt(1 − a_t²) ⊙ u_t

wrapped in the Griffin recurrent block: input/gate linear branches, a short
depthwise temporal conv on the recurrent branch, GeLU gating and an output
projection.  The linear recurrence runs as a ``jax.lax.associative_scan``
(log-depth, TPU-friendly), giving O(S log S) work with O(1) decode state —
``long_500k`` is native for the hybrid family.

This uses per-channel (diagonal) gates — the lightweight variant — rather
than Griffin's block-diagonal gate matrices; noted in DESIGN.md §9.
"""

from __future__ import annotations

from typing import Any, Dict, Optional, Tuple

import jax
import jax.numpy as jnp

from repro.models.config import ModelConfig
from repro.models.layers import dense_init

__all__ = ["init_rglru", "rglru_forward", "rglru_decode", "init_rglru_cache"]

_C = 8.0  # Griffin's fixed gate sharpness


def init_rglru(cfg: ModelConfig, key, dtype) -> Dict[str, Any]:
    D = cfg.d_model
    W = cfg.rglru_width or D
    ks = jax.random.split(key, 4)
    # Λ init so that σ(Λ) ∈ (0.9, 0.999) — long memories (Griffin §2.4)
    u = jax.random.uniform(ks[3], (W,), minval=0.9, maxval=0.999)
    lam = jnp.log(u / (1 - u))
    return {
        "w_in": dense_init(ks[0], (D, W), dtype=dtype),
        "w_gate": dense_init(ks[1], (D, W), dtype=dtype),
        "w_out": dense_init(ks[2], (W, D), dtype=dtype),
        "conv_w": (jax.random.normal(ks[3], (cfg.ssm_conv, W))
                   * (cfg.ssm_conv ** -0.5)).astype(dtype),
        "lam": lam.astype(jnp.float32),
        "g_r": jnp.ones((W,), jnp.float32),
    }


def _gates(params, u: jnp.ndarray):
    """Per-step decay a_t and input scale from the branch activations."""
    r = jax.nn.sigmoid(u.astype(jnp.float32) * params["g_r"])
    log_a = _C * r * jax.nn.log_sigmoid(params["lam"])       # (B,S,W) ≤ 0
    a = jnp.exp(log_a)
    scale = jnp.sqrt(jnp.maximum(1.0 - jnp.exp(2.0 * log_a), 1e-12))
    return a, scale


def _linear_scan(a: jnp.ndarray, b: jnp.ndarray) -> jnp.ndarray:
    """h_t = a_t h_{t-1} + b_t along axis 1, via associative scan."""
    def combine(x, y):
        a1, b1 = x
        a2, b2 = y
        return a1 * a2, a2 * b1 + b2
    aa, hh = jax.lax.associative_scan(combine, (a, b), axis=1)
    return hh


def _conv(x: jnp.ndarray, w: jnp.ndarray, state: Optional[jnp.ndarray] = None):
    K = w.shape[0]
    pad = (jnp.zeros((x.shape[0], K - 1, x.shape[2]), x.dtype)
           if state is None else state.astype(x.dtype))
    xp = jnp.concatenate([pad, x], axis=1)
    y = sum(xp[:, i:i + x.shape[1]] * w[i] for i in range(K))
    return y, (xp[:, -(K - 1):] if K > 1 else None)


def rglru_forward(params, cfg: ModelConfig, x: jnp.ndarray) -> jnp.ndarray:
    """x: (B,S,D) → (B,S,D)."""
    u = x @ params["w_in"]
    u, _ = _conv(u, params["conv_w"])
    a, scale = _gates(params, u)
    h = _linear_scan(a, scale * u.astype(jnp.float32)).astype(x.dtype)
    gate = jax.nn.gelu(x @ params["w_gate"])
    return (h * gate) @ params["w_out"]


# ---------------------------------------------------------------------------
# decode
# ---------------------------------------------------------------------------


def init_rglru_cache(cfg: ModelConfig, batch: int, dtype) -> Dict[str, jnp.ndarray]:
    W = cfg.rglru_width or cfg.d_model
    return {
        "h": jnp.zeros((batch, W), jnp.float32),
        "conv": jnp.zeros((batch, cfg.ssm_conv - 1, W), dtype),
    }


def rglru_decode(params, cfg: ModelConfig, x: jnp.ndarray, cache
                 ) -> Tuple[jnp.ndarray, Dict[str, jnp.ndarray]]:
    """x: (B,1,D); O(1) state update."""
    u = x @ params["w_in"]
    u, conv_state = _conv(u, params["conv_w"], cache["conv"])
    a, scale = _gates(params, u)                             # (B,1,W)
    h = a[:, 0] * cache["h"] + (scale * u.astype(jnp.float32))[:, 0]
    gate = jax.nn.gelu(x @ params["w_gate"])
    out = (h[:, None].astype(x.dtype) * gate) @ params["w_out"]
    return out, {"h": h, "conv": conv_state}
