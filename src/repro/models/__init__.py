"""Model substrate: the six architecture families in pure JAX."""

from repro.models.config import ModelConfig
from repro.models.transformer import LM
