"""Compact CIFAR ResNet (the paper-faithful example model family).

The paper's single-study experiments tune ResNet56/MobileNetV2 on
CIFAR-10.  This is a functional JAX ResNet of the same shape family
(3 stages × n blocks, channels 16/32/64, stride-2 stage transitions) —
``n=9`` gives ResNet56; the CPU examples default to ``n=1`` (ResNet8).
Normalization is channel RMS-norm (stateless — keeps training a pure
function of (params, batch), which the losslessness property test relies
on; BN's running stats would work too but add checkpoint state for no
benefit at this scale).
"""

from __future__ import annotations

from typing import Any, Dict, Tuple

import jax
import jax.numpy as jnp

__all__ = ["ResNet"]


def _conv_init(key, k, cin, cout):
    fan_in = k * k * cin
    return jax.random.truncated_normal(key, -2, 2, (k, k, cin, cout)) * (
        2.0 / fan_in) ** 0.5


def _conv(x, w, stride=1):
    return jax.lax.conv_general_dilated(
        x, w, (stride, stride), "SAME",
        dimension_numbers=("NHWC", "HWIO", "NHWC"))


def _norm(x, g):
    var = jnp.mean(x * x, axis=-1, keepdims=True)
    return x * jax.lax.rsqrt(var + 1e-6) * g


class ResNet:
    def __init__(self, n: int = 1, num_classes: int = 10, width: int = 16):
        self.n = n
        self.num_classes = num_classes
        self.width = width
        self.depth = 6 * n + 2

    # ------------------------------------------------------------------ init
    def init(self, rng) -> Dict[str, Any]:
        w = self.width
        chans = [w, 2 * w, 4 * w]
        keys = jax.random.split(rng, 3 * self.n * 2 + 2)
        ki = 0
        params: Dict[str, Any] = {
            "stem": _conv_init(keys[ki], 3, 3, w), "stem_g": jnp.ones((w,))}
        ki += 1
        stages = []
        cin = w
        for s, c in enumerate(chans):
            blocks = []
            for b in range(self.n):
                stride = 2 if (s > 0 and b == 0) else 1
                k1, k2, k3 = jax.random.split(keys[ki], 3)
                ki += 1
                blk = {
                    "c1": _conv_init(k1, 3, cin, c), "g1": jnp.ones((c,)),
                    "c2": _conv_init(k2, 3, c, c), "g2": jnp.ones((c,)),
                }
                if stride != 1 or cin != c:
                    blk["proj"] = _conv_init(k3, 1, cin, c)
                blocks.append(blk)
                cin = c
            stages.append(blocks)
        params["stages"] = stages
        params["head"] = jax.random.truncated_normal(
            keys[ki], -2, 2, (chans[-1], self.num_classes)) * chans[-1] ** -0.5
        params["head_b"] = jnp.zeros((self.num_classes,))
        return params

    # --------------------------------------------------------------- forward
    def forward(self, params, batch) -> jnp.ndarray:
        x = batch["images"]
        x = jax.nn.relu(_norm(_conv(x, params["stem"]), params["stem_g"]))
        for s, blocks in enumerate(params["stages"]):
            for b, blk in enumerate(blocks):
                stride = 2 if (s > 0 and b == 0) else 1
                h = jax.nn.relu(_norm(_conv(x, blk["c1"], stride), blk["g1"]))
                h = _norm(_conv(h, blk["c2"]), blk["g2"])
                sc = _conv(x, blk["proj"], stride) if "proj" in blk else x
                x = jax.nn.relu(sc + h)
        x = jnp.mean(x, axis=(1, 2))
        return x @ params["head"] + params["head_b"]

    # ------------------------------------------------------------------ loss
    def loss(self, params, batch) -> Tuple[jnp.ndarray, Dict[str, jnp.ndarray]]:
        logits = self.forward(params, batch)
        labels = batch["labels"]
        logp = jax.nn.log_softmax(logits)
        nll = -jnp.take_along_axis(logp, labels[:, None], axis=-1)[:, 0]
        acc = jnp.mean((jnp.argmax(logits, -1) == labels).astype(jnp.float32))
        return jnp.mean(nll), {"acc": acc}
