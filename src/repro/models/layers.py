"""Shared neural building blocks: norms, embeddings, RoPE / M-RoPE."""

from __future__ import annotations

from typing import Optional, Tuple

import jax
import jax.numpy as jnp

__all__ = ["rms_norm", "init_rms", "embed_init", "rope_angles", "apply_rope",
           "mrope_angles", "dense_init", "Param"]

Param = jnp.ndarray


def dense_init(key, shape, scale: Optional[float] = None, dtype=jnp.float32):
    """Truncated-normal fan-in init (what llama-family checkpoints use)."""
    fan_in = shape[0] if len(shape) > 1 else shape[-1]
    s = scale if scale is not None else fan_in ** -0.5
    return (jax.random.truncated_normal(key, -2.0, 2.0, shape) * s).astype(dtype)


def embed_init(key, vocab: int, d: int, dtype=jnp.float32):
    return (jax.random.normal(key, (vocab, d)) * 0.02).astype(dtype)


def init_rms(d: int, dtype=jnp.float32) -> Param:
    return jnp.ones((d,), dtype)


def rms_norm(x: jnp.ndarray, w: Param, eps: float = 1e-6) -> jnp.ndarray:
    dt = x.dtype
    x = x.astype(jnp.float32)
    var = jnp.mean(x * x, axis=-1, keepdims=True)
    out = x * jax.lax.rsqrt(var + eps)
    return (out * w.astype(jnp.float32)).astype(dt)


# ---------------------------------------------------------------------------
# RoPE
# ---------------------------------------------------------------------------


def rope_angles(positions: jnp.ndarray, head_dim: int,
                theta: float = 1e4) -> Tuple[jnp.ndarray, jnp.ndarray]:
    """cos/sin tables for plain RoPE.  positions: (..., S) int32 →
    (..., S, head_dim/2) each."""
    half = head_dim // 2
    freq = 1.0 / (theta ** (jnp.arange(half, dtype=jnp.float32) / half))
    ang = positions.astype(jnp.float32)[..., None] * freq
    return jnp.cos(ang), jnp.sin(ang)


def mrope_angles(positions: jnp.ndarray, head_dim: int, sections,
                 theta: float = 1e4) -> Tuple[jnp.ndarray, jnp.ndarray]:
    """Multimodal RoPE (Qwen2-VL §2): the frequency axis is partitioned into
    (temporal, height, width) sections, each rotated by its own position id.

    positions: (3, ..., S); sections sum to head_dim/2.
    Returns cos/sin of shape (..., S, head_dim/2).
    """
    half = head_dim // 2
    assert sum(sections) == half, (sections, half)
    freq = 1.0 / (theta ** (jnp.arange(half, dtype=jnp.float32) / half))
    # section id of each frequency slot
    parts = []
    off = 0
    for i, sec in enumerate(sections):
        pos_i = positions[i].astype(jnp.float32)[..., None]          # (...,S,1)
        parts.append(pos_i * freq[off:off + sec])
        off += sec
    ang = jnp.concatenate(parts, axis=-1)                            # (...,S,half)
    return jnp.cos(ang), jnp.sin(ang)


def apply_rope(x: jnp.ndarray, cos: jnp.ndarray, sin: jnp.ndarray) -> jnp.ndarray:
    """x: (..., S, n_heads, head_dim); cos/sin: (..., S, head_dim/2)."""
    half = x.shape[-1] // 2
    x1, x2 = x[..., :half], x[..., half:]
    c = cos[..., None, :]  # broadcast over heads
    s = sin[..., None, :]
    return jnp.concatenate([x1 * c - x2 * s, x2 * c + x1 * s], axis=-1).astype(x.dtype)
