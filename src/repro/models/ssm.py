"""Mamba2 — state-space duality (SSD) layer (Dao & Gu, arXiv:2405.21060).

The layer computes, per head ``h`` with scalar decay ``A_h < 0``::

    state_t = exp(dt_t A) state_{t-1} + dt_t · B_t ⊗ x_t      (N×P state)
    y_t     = C_t · state_t + D ⊙ x_t

Computation is *chunked* (the SSD algorithm): the sequence is split into
chunks of ``Q`` steps; each chunk does a quadratic attention-like intra-
chunk term (the part the Pallas kernel accelerates — MXU-friendly Q×Q
matmuls) and a rank-1 state hand-off between chunks via ``lax.scan`` —
O(S·Q) total, which is what makes ``long_500k`` native for this family.

B and C are shared across heads (``ngroups=1``, Mamba2 default — the MQA
analogue).  The block wraps SSD with the usual in-projection producing
(z, x, B, C, dt), a causal depthwise conv over (x,B,C), gated RMSNorm and
an out-projection.
"""

from __future__ import annotations

from typing import Any, Dict, Optional, Tuple

import jax
import jax.numpy as jnp

from repro.models.config import ModelConfig
from repro.models.layers import dense_init, init_rms, rms_norm

__all__ = ["init_ssm", "ssm_forward", "ssm_decode", "init_ssm_cache",
           "ssd_chunked", "ssd_sequential"]


# ---------------------------------------------------------------------------
# SSD core
# ---------------------------------------------------------------------------


def _segsum(lt: jnp.ndarray) -> jnp.ndarray:
    """lt: (..., Q) per-step log-decays → (..., Q, Q) matrix
    ``M[i, j] = sum(lt[j+1..i])`` for j ≤ i, -inf above the diagonal."""
    Q = lt.shape[-1]
    cs = jnp.cumsum(lt, axis=-1)
    diff = cs[..., :, None] - cs[..., None, :]          # cum_i - cum_j
    mask = jnp.tril(jnp.ones((Q, Q), bool), 0)
    return jnp.where(mask, diff, -jnp.inf)


def ssd_chunked(x: jnp.ndarray, dt: jnp.ndarray, A: jnp.ndarray,
                Bm: jnp.ndarray, Cm: jnp.ndarray, chunk: int,
                init_state: Optional[jnp.ndarray] = None,
                use_kernel: bool = False
                ) -> Tuple[jnp.ndarray, jnp.ndarray]:
    """Chunked SSD scan.

    x  (B,S,H,P)   dt (B,S,H)   A (H,)   Bm,Cm (B,S,N)  (shared over heads)
    Returns (y (B,S,H,P), final_state (B,H,P,N)).
    """
    Bsz, S, H, P = x.shape
    N = Bm.shape[-1]
    assert S % chunk == 0, (S, chunk)
    nc, Q = S // chunk, chunk

    xr = x.reshape(Bsz, nc, Q, H, P)
    dtr = dt.reshape(Bsz, nc, Q, H)
    Br = Bm.reshape(Bsz, nc, Q, N)
    Cr = Cm.reshape(Bsz, nc, Q, N)

    lt = dtr * A                                         # (B,nc,Q,H) log-decay
    ltT = jnp.moveaxis(lt, -1, -2)                       # (B,nc,H,Q)
    cum = jnp.cumsum(ltT, axis=-1)                       # (B,nc,H,Q)

    if use_kernel:
        from repro.kernels import ops as kops
        y_intra = kops.ssd_intra(xr, dtr, ltT, Br, Cr)
    else:
        # ---- intra-chunk (quadratic in Q): att[i,j] = (C_i·B_j)·exp(seg)·dt_j
        seg = _segsum(ltT)                               # (B,nc,H,Q,Q)
        cb = jnp.einsum("bcin,bcjn->bcij", Cr, Br)       # (B,nc,Q,Q)
        att = cb[:, :, None] * jnp.exp(seg) * jnp.moveaxis(dtr, -1, -2)[..., None, :]
        y_intra = jnp.einsum("bchij,bcjhp->bcihp", att.astype(x.dtype), xr)

    # ---- per-chunk end state: sum_j exp(cum_Q - cum_j) dt_j B_j ⊗ x_j
    decay_to_end = jnp.exp(cum[..., -1:] - cum)          # (B,nc,H,Q)
    w = (jnp.moveaxis(dtr, -1, -2) * decay_to_end)       # (B,nc,H,Q)
    chunk_states = jnp.einsum("bchq,bcqn,bcqhp->bchpn",
                              w.astype(x.dtype), Br, xr)  # (B,nc,H,P,N)
    total_decay = jnp.exp(cum[..., -1])                  # (B,nc,H)

    # ---- inter-chunk recurrence over nc chunks
    s0 = (jnp.zeros((Bsz, H, P, N), x.dtype)
          if init_state is None else init_state.astype(x.dtype))

    def step(s, inp):
        cs, td = inp                                     # (B,H,P,N), (B,H)
        s_in = s
        s = s * td[..., None, None].astype(x.dtype) + cs
        return s, s_in

    cs_t = jnp.moveaxis(chunk_states, 1, 0)              # (nc,B,H,P,N)
    td_t = jnp.moveaxis(total_decay, 1, 0)               # (nc,B,H)
    final, prev_states = jax.lax.scan(step, s0, (cs_t, td_t))
    prev_states = jnp.moveaxis(prev_states, 0, 1)        # (B,nc,H,P,N)

    # ---- inter-chunk output: y_inter[i] = exp(cum_i) · C_i @ S_prev
    dec_in = jnp.exp(cum)                                # (B,nc,H,Q)
    y_inter = jnp.einsum("bcqn,bchpn,bchq->bcqhp", Cr, prev_states,
                         dec_in.astype(x.dtype))
    y = (y_intra + y_inter).reshape(Bsz, S, H, P)
    return y, final


def ssd_sequential(x, dt, A, Bm, Cm, init_state=None):
    """Step-by-step oracle for tests (O(S) sequential scan)."""
    Bsz, S, H, P = x.shape
    N = Bm.shape[-1]
    s0 = (jnp.zeros((Bsz, H, P, N), jnp.float32)
          if init_state is None else init_state.astype(jnp.float32))

    def step(h, inp):
        x_t, dt_t, B_t, C_t = inp                        # (B,H,P),(B,H),(B,N),(B,N)
        dA = jnp.exp(dt_t * A)                           # (B,H)
        h = h * dA[..., None, None] + jnp.einsum(
            "bhp,bn->bhpn", x_t * dt_t[..., None], B_t)
        y = jnp.einsum("bhpn,bn->bhp", h, C_t)
        return h, y

    xs = (jnp.moveaxis(x.astype(jnp.float32), 1, 0),
          jnp.moveaxis(dt, 1, 0),
          jnp.moveaxis(Bm.astype(jnp.float32), 1, 0),
          jnp.moveaxis(Cm.astype(jnp.float32), 1, 0))
    final, ys = jax.lax.scan(step, s0, xs)
    return jnp.moveaxis(ys, 0, 1).astype(x.dtype), final.astype(x.dtype)


# ---------------------------------------------------------------------------
# full block
# ---------------------------------------------------------------------------


def init_ssm(cfg: ModelConfig, key, dtype) -> Dict[str, Any]:
    """Projections are stored as separate matrices (z, x, B, C, dt) rather
    than one fused ``in_proj`` so each can carry its own sharding: z/x are
    head-sharded over the ``model`` axis (tensor parallelism), B/C/dt are
    small and replicated on that axis.  Same parameter count as the fused
    form; the depthwise conv likewise splits per stream."""
    D, inner, N, H = cfg.d_model, cfg.ssm_inner, cfg.ssm_state, cfg.ssm_heads
    ks = jax.random.split(key, 8)
    K = cfg.ssm_conv
    return {
        "in_z": dense_init(ks[0], (D, inner), dtype=dtype),
        "in_x": dense_init(ks[1], (D, inner), dtype=dtype),
        "in_B": dense_init(ks[2], (D, N), dtype=dtype),
        "in_C": dense_init(ks[3], (D, N), dtype=dtype),
        "in_dt": dense_init(ks[4], (D, H), dtype=dtype),
        "conv_x": (jax.random.normal(ks[5], (K, inner)) * K ** -0.5).astype(dtype),
        "conv_B": (jax.random.normal(ks[6], (K, N)) * K ** -0.5).astype(dtype),
        "conv_C": (jax.random.normal(ks[7], (K, N)) * K ** -0.5).astype(dtype),
        "A_log": jnp.log(jnp.linspace(1.0, 16.0, H)).astype(jnp.float32),
        "D": jnp.ones((H,), dtype),
        "dt_bias": jnp.zeros((H,), jnp.float32),
        "gate_norm": init_rms(inner, dtype),
        "out_proj": dense_init(ks[3], (inner, D), dtype=dtype),
    }


def _causal_conv(x: jnp.ndarray, w: jnp.ndarray,
                 state: Optional[jnp.ndarray] = None):
    """Depthwise causal conv. x (B,S,C), w (K,C).  ``state`` (B,K-1,C) is the
    carried left context for decode; returns (y, new_state)."""
    K = w.shape[0]
    if state is None:
        pad = jnp.zeros((x.shape[0], K - 1, x.shape[2]), x.dtype)
    else:
        pad = state.astype(x.dtype)
    xp = jnp.concatenate([pad, x], axis=1)               # (B,S+K-1,C)
    y = sum(xp[:, i:i + x.shape[1]] * w[i] for i in range(K))
    new_state = xp[:, -(K - 1):] if K > 1 else None
    return y, new_state


def _ssm_project(params, cfg: ModelConfig, x: jnp.ndarray,
                 conv_state=None):
    """Project and run the causal conv per stream; returns
    (z, xs, Bm, Cm, dt_raw, new_conv_state)."""
    z = x @ params["in_z"]
    xs = x @ params["in_x"]
    Bm = x @ params["in_B"]
    Cm = x @ params["in_C"]
    dt_raw = x @ params["in_dt"]
    cs = conv_state or {}
    xs, s_x = _causal_conv(xs, params["conv_x"], cs.get("x"))
    Bm, s_B = _causal_conv(Bm, params["conv_B"], cs.get("B"))
    Cm, s_C = _causal_conv(Cm, params["conv_C"], cs.get("C"))
    xs, Bm, Cm = jax.nn.silu(xs), jax.nn.silu(Bm), jax.nn.silu(Cm)
    return z, xs, Bm, Cm, dt_raw, {"x": s_x, "B": s_B, "C": s_C}


def _ssm_post(params, cfg: ModelConfig, y, z, x_in):
    H, P = cfg.ssm_heads, cfg.ssm_head_dim
    y = y + x_in * params["D"][None, None, :, None].astype(y.dtype)
    y = y.reshape(*y.shape[:-2], H * P)
    y = rms_norm(y * jax.nn.silu(z), params["gate_norm"], cfg.norm_eps)
    return y @ params["out_proj"]


def ssm_forward(params, cfg: ModelConfig, x: jnp.ndarray,
                use_kernel: bool = False) -> jnp.ndarray:
    """x: (B,S,D) → (B,S,D)."""
    B, S, D = x.shape
    H, P = cfg.ssm_heads, cfg.ssm_head_dim
    z, xs, Bm, Cm, dt_raw, _ = _ssm_project(params, cfg, x)
    dt = jax.nn.softplus(dt_raw.astype(jnp.float32) + params["dt_bias"])
    A = -jnp.exp(params["A_log"])
    xh = xs.reshape(B, S, H, P)
    y, _ = ssd_chunked(xh, dt, A, Bm, Cm, cfg.ssm_chunk, use_kernel=use_kernel)
    return _ssm_post(params, cfg, y, z, xh)


# ---------------------------------------------------------------------------
# decode
# ---------------------------------------------------------------------------


def init_ssm_cache(cfg: ModelConfig, batch: int, dtype) -> Dict[str, jnp.ndarray]:
    inner, N = cfg.ssm_inner, cfg.ssm_state
    K = cfg.ssm_conv
    return {
        "state": jnp.zeros((batch, cfg.ssm_heads, cfg.ssm_head_dim, N), dtype),
        "conv": {"x": jnp.zeros((batch, K - 1, inner), dtype),
                 "B": jnp.zeros((batch, K - 1, N), dtype),
                 "C": jnp.zeros((batch, K - 1, N), dtype)},
    }


def ssm_decode(params, cfg: ModelConfig, x: jnp.ndarray, cache
               ) -> Tuple[jnp.ndarray, Dict[str, jnp.ndarray]]:
    """One-token decode: x (B,1,D) → (B,1,D); O(1) state update."""
    B = x.shape[0]
    H, P = cfg.ssm_heads, cfg.ssm_head_dim
    z, xs, Bm, Cm, dt_raw, conv_state = _ssm_project(params, cfg, x,
                                                     cache["conv"])
    dt = jax.nn.softplus(dt_raw.astype(jnp.float32) + params["dt_bias"])  # (B,1,H)
    A = -jnp.exp(params["A_log"])
    xh = xs.reshape(B, 1, H, P)

    dA = jnp.exp(dt[:, 0] * A)                           # (B,H)
    state = cache["state"].astype(jnp.float32)
    state = (state * dA[..., None, None]
             + jnp.einsum("bhp,bn->bhpn",
                          (xh[:, 0] * dt[:, 0, :, None]).astype(jnp.float32),
                          Bm[:, 0].astype(jnp.float32)))
    y = jnp.einsum("bhpn,bn->bhp", state, Cm[:, 0].astype(jnp.float32))
    y = y[:, None].astype(x.dtype)                       # (B,1,H,P)
    out = _ssm_post(params, cfg, y, z, xh)
    return out, {"state": state.astype(cache["state"].dtype), "conv": conv_state}
