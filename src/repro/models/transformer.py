"""Model assembly for all six architecture families.

One :class:`LM` covers dense / MoE / SSM / hybrid / VLM / audio by
composing per-layer *blocks* (attention, local attention, RG-LRU, SSD)
according to ``cfg.layer_pattern``:

* homogeneous stacks (pattern length 1) and hybrid cycles alike run as a
  ``jax.lax.scan`` over stacked per-cycle parameters → HLO size independent
  of depth (88-layer granite compiles as fast as the 2-layer smoke
  variants), with optional per-cycle ``jax.checkpoint`` (remat);
* layers that do not fill a whole cycle (26 = 8×3 + 2 for recurrentgemma)
  run unrolled after the scan;
* decode threading: each block kind owns a cache pytree (ring-buffer KV,
  SSD state, RG-LRU state) scanned alongside the parameters.

Blocks are pre-norm residual: ``x += mixer(norm1(x)); x += ffn(norm2(x))``
(SSD blocks carry no FFN, matching Mamba2).
"""

from __future__ import annotations

import dataclasses
from typing import Any, Dict, List, Optional, Tuple

import jax
import jax.numpy as jnp

from repro.models.attention import (attention_decode, attention_forward,
                                    init_attention, init_kv_cache)
from repro.models.config import ModelConfig
from repro.models.ffn import init_mlp, init_moe, mlp_forward, moe_forward
from repro.models.layers import dense_init, embed_init, init_rms, rms_norm
from repro.models.rglru import (init_rglru, init_rglru_cache, rglru_decode,
                                rglru_forward)
from repro.models.ssm import init_ssm, init_ssm_cache, ssm_decode, ssm_forward

__all__ = ["LM"]


def _dtype(cfg: ModelConfig):
    return {"bfloat16": jnp.bfloat16, "float32": jnp.float32}[cfg.dtype]


def _has_ffn(cfg: ModelConfig, kind: str) -> bool:
    if kind == "ssm":
        return False
    return cfg.d_ff > 0 or cfg.n_experts > 0


# ---------------------------------------------------------------------------
# blocks
# ---------------------------------------------------------------------------


def _init_block(cfg: ModelConfig, kind: str, key, dtype) -> Dict[str, Any]:
    k1, k2 = jax.random.split(key)
    p: Dict[str, Any] = {"norm1": init_rms(cfg.d_model, dtype)}
    if kind in ("attn", "local"):
        p["attn"] = init_attention(cfg, k1, dtype)
    elif kind == "rglru":
        p["rglru"] = init_rglru(cfg, k1, dtype)
    elif kind == "ssm":
        p["ssm"] = init_ssm(cfg, k1, dtype)
    else:
        raise ValueError(kind)
    if _has_ffn(cfg, kind):
        p["norm2"] = init_rms(cfg.d_model, dtype)
        p["ffn"] = (init_moe(cfg, k2, dtype) if cfg.n_experts
                    else init_mlp(cfg.d_model, cfg.d_ff, k2, dtype,
                                  gated=cfg.mlp_gated))
    return p


def _block_forward(cfg: ModelConfig, kind: str, p, x, positions,
                   use_kernel: bool) -> Tuple[jnp.ndarray, jnp.ndarray]:
    h = rms_norm(x, p["norm1"], cfg.norm_eps)
    if kind == "attn":
        h = attention_forward(p["attn"], cfg, h, positions,
                              window=cfg.sliding_window, use_kernel=use_kernel)
    elif kind == "local":
        h = attention_forward(p["attn"], cfg, h, positions,
                              window=cfg.local_window, use_kernel=use_kernel)
    elif kind == "rglru":
        h = rglru_forward(p["rglru"], cfg, h)
    elif kind == "ssm":
        h = ssm_forward(p["ssm"], cfg, h, use_kernel=use_kernel)
    x = x + h
    aux = jnp.zeros((), jnp.float32)
    if _has_ffn(cfg, kind):
        h = rms_norm(x, p["norm2"], cfg.norm_eps)
        if cfg.n_experts:
            h, aux = moe_forward(p["ffn"], cfg, h)
        else:
            h = mlp_forward(p["ffn"], h)
        x = x + h
    return x, aux


def _block_cache(cfg: ModelConfig, kind: str, batch: int, max_len: int,
                 dtype) -> Dict[str, Any]:
    if kind == "attn":
        return init_kv_cache(cfg, batch, max_len, cfg.sliding_window, dtype)
    if kind == "local":
        return init_kv_cache(cfg, batch, max_len, cfg.local_window, dtype)
    if kind == "rglru":
        return init_rglru_cache(cfg, batch, dtype)
    if kind == "ssm":
        return init_ssm_cache(cfg, batch, dtype)
    raise ValueError(kind)


def _block_decode(cfg: ModelConfig, kind: str, p, x, cache, index
                  ) -> Tuple[jnp.ndarray, Any]:
    h = rms_norm(x, p["norm1"], cfg.norm_eps)
    if kind == "attn":
        h, cache = attention_decode(p["attn"], cfg, h, cache, index,
                                    window=cfg.sliding_window)
    elif kind == "local":
        h, cache = attention_decode(p["attn"], cfg, h, cache, index,
                                    window=cfg.local_window)
    elif kind == "rglru":
        h, cache = rglru_decode(p["rglru"], cfg, h, cache)
    elif kind == "ssm":
        h, cache = ssm_decode(p["ssm"], cfg, h, cache)
    x = x + h
    if _has_ffn(cfg, kind):
        h = rms_norm(x, p["norm2"], cfg.norm_eps)
        if cfg.n_experts:
            h, _ = moe_forward(p["ffn"], cfg, h)
        else:
            h = mlp_forward(p["ffn"], h)
        x = x + h
    return x, cache


# ---------------------------------------------------------------------------
# the model
# ---------------------------------------------------------------------------


class LM:
    """Decoder LM / encoder (causal=False) over any layer pattern."""

    def __init__(self, cfg: ModelConfig, use_kernel: bool = False,
                 unroll: bool = False, constrain=None):
        self.cfg = cfg
        self.use_kernel = use_kernel
        # unroll=True replaces the layer-scan with a Python loop — used by
        # the dry-run so ``cost_analysis()`` counts every layer (XLA's cost
        # analysis counts a while-loop body once, ignoring trip count).
        self.unroll = unroll
        # optional activation-sharding constraint applied to the residual
        # stream between blocks (sequence parallelism, §Perf variants)
        self.constrain = constrain or (lambda x: x)
        self.pattern = cfg.layer_pattern
        self.n_cycle = len(self.pattern)
        self.n_full = cfg.num_layers // self.n_cycle
        self.rest_kinds = cfg.layer_kinds()[self.n_full * self.n_cycle:]

    # ------------------------------------------------------------------ init
    def init(self, rng) -> Dict[str, Any]:
        cfg = self.cfg
        dt = _dtype(cfg)
        keys = jax.random.split(rng, cfg.num_layers + 4)
        params: Dict[str, Any] = {
            "embed": embed_init(keys[0], cfg.vocab_size, cfg.d_model, dt),
            "final_norm": init_rms(cfg.d_model, dt),
        }
        if not cfg.tie_embeddings:
            params["lm_head"] = dense_init(keys[1], (cfg.d_model, cfg.vocab_size),
                                           dtype=dt)
        if cfg.frontend_dim:
            params["frontend_proj"] = dense_init(
                keys[2], (cfg.frontend_dim, cfg.d_model), dtype=dt)

        # stacked cycles: slot s holds an (n_full, ...) stacked pytree
        cycles: List[Any] = []
        ki = 4
        for s, kind in enumerate(self.pattern):
            per_cycle = []
            for c in range(self.n_full):
                per_cycle.append(_init_block(cfg, kind, keys[ki], dt))
                ki += 1
            cycles.append(jax.tree.map(lambda *xs: jnp.stack(xs), *per_cycle)
                          if self.n_full > 1 else
                          jax.tree.map(lambda x: x[None], per_cycle[0]))
        params["cycles"] = cycles
        params["rest"] = [
            _init_block(cfg, kind, keys[ki + i], dt)
            for i, kind in enumerate(self.rest_kinds)
        ]
        return params

    # --------------------------------------------------------------- forward
    def _embed(self, params, batch) -> Tuple[jnp.ndarray, jnp.ndarray]:
        """Returns (hidden (B,S,D), positions)."""
        cfg = self.cfg
        if cfg.frontend == "audio":
            # stub carve-out: precomputed frame embeddings from input_specs
            x = batch["features"] @ params["frontend_proj"]
            B, S = x.shape[:2]
            pos = jnp.broadcast_to(jnp.arange(S, dtype=jnp.int32)[None], (B, S))
            return x, pos
        tok = params["embed"][batch["tokens"]]
        if cfg.frontend == "vision":
            patches = batch["patches"] @ params["frontend_proj"]
            x = jnp.concatenate([patches, tok], axis=1)
            pos = batch["positions"]                      # (3, B, S) M-RoPE ids
        else:
            x = tok
            B, S = x.shape[:2]
            pos = jnp.broadcast_to(jnp.arange(S, dtype=jnp.int32)[None], (B, S))
        return x, pos

    def forward(self, params, batch) -> Tuple[jnp.ndarray, Dict[str, jnp.ndarray]]:
        cfg = self.cfg
        x, positions = self._embed(params, batch)
        aux0 = jnp.zeros((), jnp.float32)

        def cycle_body(carry, cycle_params):
            h, aux = carry
            for s, kind in enumerate(self.pattern):
                h, a = _block_forward(cfg, kind, cycle_params[s], h, positions,
                                      self.use_kernel)
                h = self.constrain(h)
                aux = aux + a
            return (h, aux), None

        body = jax.checkpoint(cycle_body) if cfg.remat else cycle_body
        if self.unroll:
            carry = (x, aux0)
            for i in range(self.n_full):
                cyc = jax.tree.map(lambda a: a[i], tuple(params["cycles"]))
                carry, _ = body(carry, cyc)
            x, aux = carry
        else:
            (x, aux), _ = jax.lax.scan(body, (x, aux0),
                                       tuple(params["cycles"]))
        for p, kind in zip(params["rest"], self.rest_kinds):
            x, a = _block_forward(cfg, kind, p, x, positions, self.use_kernel)
            aux = aux + a

        x = rms_norm(x, params["final_norm"], cfg.norm_eps)
        head = (params["embed"].T if cfg.tie_embeddings else params["lm_head"])
        logits = (x @ head).astype(jnp.float32)
        return logits, {"moe_aux": aux}

    # ------------------------------------------------------------------ loss
    def loss(self, params, batch) -> Tuple[jnp.ndarray, Dict[str, jnp.ndarray]]:
        cfg = self.cfg
        logits, aux = self.forward(params, batch)
        if cfg.is_encoder_only:
            labels = batch["labels"]                     # framewise targets
            lg, lb = logits, labels
        elif cfg.frontend == "vision":
            # text tokens sit after the patch prefix: logits[:, P+i]
            # predicts text token i+1
            P = batch["patches"].shape[1]
            n_text = batch["tokens"].shape[1]
            lg, lb = logits[:, P:P + n_text - 1], batch["tokens"][:, 1:]
        else:
            lg, lb = logits[:, :-1], batch["tokens"][:, 1:]
        logp = jax.nn.log_softmax(lg, axis=-1)
        nll = -jnp.take_along_axis(logp, lb[..., None], axis=-1)[..., 0]
        loss = jnp.mean(nll)
        if cfg.n_experts:
            loss = loss + cfg.router_aux_weight * aux["moe_aux"] / max(
                1, cfg.num_layers)
        return loss, {"nll": jnp.mean(nll), "moe_aux": aux["moe_aux"]}

    # ---------------------------------------------------------------- decode
    def init_cache(self, batch: int, max_len: int) -> Dict[str, Any]:
        cfg = self.cfg
        dt = _dtype(cfg)
        cycles = []
        for s, kind in enumerate(self.pattern):
            per = [_block_cache(cfg, kind, batch, max_len, dt)
                   for _ in range(self.n_full)]
            cycles.append(jax.tree.map(lambda *xs: jnp.stack(xs), *per)
                          if self.n_full > 1 else
                          jax.tree.map(lambda x: x[None], per[0]))
        rest = [_block_cache(cfg, kind, batch, max_len, dt)
                for kind in self.rest_kinds]
        return {"cycles": cycles, "rest": rest}

    def decode_step(self, params, cache, tokens: jnp.ndarray, index
                    ) -> Tuple[jnp.ndarray, Dict[str, Any]]:
        """tokens: (B, 1) int32; index: scalar absolute position."""
        cfg = self.cfg
        assert not cfg.is_encoder_only, "encoder-only models have no decode"
        x = params["embed"][tokens]

        def cycle_body(h, xs):
            cycle_params, cycle_cache = xs
            new_caches = []
            for s, kind in enumerate(self.pattern):
                h2, c2 = _block_decode(cfg, kind, cycle_params[s], h,
                                       cycle_cache[s], index)
                h = h2
                new_caches.append(c2)
            return h, tuple(new_caches)

        if self.unroll:
            outs = []
            for i in range(self.n_full):
                cyc = jax.tree.map(lambda a: a[i], tuple(params["cycles"]))
                cch = jax.tree.map(lambda a: a[i], tuple(cache["cycles"]))
                x, nc = cycle_body(x, (cyc, cch))
                outs.append(nc)
            new_cycles = jax.tree.map(lambda *xs: jnp.stack(xs), *outs) \
                if len(outs) > 1 else jax.tree.map(lambda a: a[None], outs[0])
        else:
            x, new_cycles = jax.lax.scan(
                cycle_body, x,
                (tuple(params["cycles"]), tuple(cache["cycles"])))
        new_rest = []
        for p, c, kind in zip(params["rest"], cache["rest"], self.rest_kinds):
            x, c2 = _block_decode(cfg, kind, p, x, c, index)
            new_rest.append(c2)

        x = rms_norm(x, params["final_norm"], cfg.norm_eps)
        head = (params["embed"].T if cfg.tie_embeddings else params["lm_head"])
        logits = (x @ head).astype(jnp.float32)
        return logits, {"cycles": list(new_cycles), "rest": new_rest}
