"""Model configuration — one frozen dataclass covers all six arch families.

Every assigned architecture (see ``repro/configs/``) instantiates this with
its exact published shape; smoke tests use ``reduced()`` variants of the
same family (2 layers, d_model ≤ 512, ≤ 4 experts).
"""

from __future__ import annotations

import dataclasses
from dataclasses import dataclass
from typing import Optional, Tuple

__all__ = ["ModelConfig", "LayerKind"]

LayerKind = str  # "attn" | "local" | "rglru" | "ssm"


@dataclass(frozen=True)
class ModelConfig:
    name: str
    arch_type: str                  # dense | moe | ssm | hybrid | vlm | audio
    num_layers: int
    d_model: int
    vocab_size: int
    # ----- attention (unused for pure-SSM layers)
    num_heads: int = 0
    num_kv_heads: int = 0
    head_dim: Optional[int] = None
    qkv_bias: bool = False
    qk_norm: bool = False
    rope_theta: float = 1e4
    mrope_sections: Tuple[int, ...] = ()    # qwen2-vl M-RoPE (t, h, w) splits
    sliding_window: int = 0                 # >0: sliding-window attention
    causal: bool = True                     # False → encoder-only
    # ----- ffn
    d_ff: int = 0
    mlp_gated: bool = True                  # False → 2-matrix GeLU MLP (GPTBigCode)
    # ----- moe
    n_experts: int = 0
    n_shared_experts: int = 0
    top_k: int = 0
    moe_d_ff: int = 0                       # per-expert hidden (routed experts)
    shared_d_ff: int = 0                    # shared-experts hidden
    capacity_factor: float = 1.25
    router_aux_weight: float = 0.01
    # ----- ssm (mamba2 / SSD)
    ssm_state: int = 0
    ssm_heads: int = 0
    ssm_head_dim: int = 64
    ssm_expand: int = 2
    ssm_chunk: int = 64
    ssm_conv: int = 4
    # ----- hybrid layer pattern (cycled); homogeneous archs leave default
    layer_pattern: Tuple[LayerKind, ...] = ("attn",)
    local_window: int = 2048                # window for "local" layers
    rglru_width: Optional[int] = None       # recurrence width (default d_model)
    # ----- modality frontend stubs
    frontend: str = "none"                  # none | vision | audio
    frontend_dim: int = 0                   # embedding dim supplied by the stub
    frontend_tokens: int = 0                # prefix tokens supplied by the stub
    # ----- misc
    tie_embeddings: bool = False
    norm_eps: float = 1e-6
    dtype: str = "bfloat16"
    remat: bool = False                     # activation checkpointing per layer

    # ------------------------------------------------------------- derived
    @property
    def resolved_head_dim(self) -> int:
        if self.head_dim is not None:
            return self.head_dim
        return self.d_model // max(1, self.num_heads)

    @property
    def ssm_inner(self) -> int:
        return self.ssm_expand * self.d_model

    def layer_kinds(self) -> Tuple[LayerKind, ...]:
        """Per-layer kind, cycling ``layer_pattern``."""
        pat = self.layer_pattern
        return tuple(pat[i % len(pat)] for i in range(self.num_layers))

    @property
    def uses_attention(self) -> bool:
        return any(k in ("attn", "local") for k in self.layer_kinds())

    @property
    def is_encoder_only(self) -> bool:
        return not self.causal

    @property
    def subquadratic(self) -> bool:
        """True iff no layer does full-sequence quadratic attention (the
        requirement for the ``long_500k`` shape)."""
        kinds = set(self.layer_kinds())
        if "attn" in kinds and self.sliding_window <= 0:
            return False
        return True

    # ------------------------------------------------------------- variants
    def reduced(self, num_layers: int = 2, d_model: int = 256,
                vocab_size: int = 512) -> "ModelConfig":
        """Smoke-test variant of the same family."""
        ratio = d_model / self.d_model
        scale = lambda x, lo=1: max(lo, int(round(x * ratio)))
        head_dim = 32
        n_heads = max(1, d_model // 64) if self.num_heads else 0
        n_kv = max(1, min(n_heads, max(1, int(round(
            n_heads * self.num_kv_heads / max(1, self.num_heads)))))) if self.num_kv_heads else 0
        pat = self.layer_pattern
        return dataclasses.replace(
            self,
            name=self.name + "-smoke",
            num_layers=num_layers if len(pat) == 1 else max(num_layers, len(pat)),
            d_model=d_model,
            vocab_size=vocab_size,
            num_heads=n_heads,
            num_kv_heads=n_kv,
            head_dim=head_dim if self.num_heads else None,
            d_ff=scale(self.d_ff) if self.d_ff else 0,
            n_experts=min(4, self.n_experts),
            n_shared_experts=min(1, self.n_shared_experts),
            top_k=min(2, self.top_k),
            moe_d_ff=scale(self.moe_d_ff) if self.moe_d_ff else 0,
            shared_d_ff=scale(self.shared_d_ff) if self.shared_d_ff else 0,
            ssm_state=min(32, self.ssm_state),
            ssm_heads=max(1, d_model * self.ssm_expand // 64) if self.ssm_heads else 0,
            ssm_head_dim=64 if self.ssm_heads else self.ssm_head_dim,
            ssm_chunk=16 if self.ssm_heads else self.ssm_chunk,
            sliding_window=min(self.sliding_window, 64) if self.sliding_window else 0,
            local_window=min(self.local_window, 64),
            mrope_sections=(8, 4, 4) if self.mrope_sections else (),
            frontend_dim=min(self.frontend_dim, 128) if self.frontend_dim else 0,
            frontend_tokens=min(self.frontend_tokens, 16) if self.frontend_tokens else 0,
            rglru_width=None,
            dtype="float32",
            remat=False,
        )

    # --------------------------------------------------------------- counts
    def param_count(self) -> int:
        """Exact parameter count of this configuration."""
        D, V = self.d_model, self.vocab_size
        total = V * D                                   # embedding
        if not self.tie_embeddings and not self.is_encoder_only:
            total += D * V                              # lm head
        if self.is_encoder_only:
            total += D * V                              # classifier head
        if self.frontend_dim:
            total += self.frontend_dim * D              # frontend projector
        hd = self.resolved_head_dim
        for kind in self.layer_kinds():
            # pre-norm per mixer + per ffn (SSD blocks carry no FFN)
            total += D if kind == "ssm" else 2 * D
            if kind in ("attn", "local"):
                q = D * self.num_heads * hd
                kv = 2 * D * self.num_kv_heads * hd
                o = self.num_heads * hd * D
                total += q + kv + o
                if self.qkv_bias:
                    total += (self.num_heads + 2 * self.num_kv_heads) * hd
                if self.qk_norm:
                    total += 2 * hd
            elif kind == "rglru":
                W = self.rglru_width or D
                total += 2 * D * W + W * D              # in (x,gate branches), out
                total += 2 * W                          # recurrence gates a, input gate
                total += W * self.ssm_conv              # temporal conv
            elif kind == "ssm":
                inner = self.ssm_inner
                nh, hd_s = self.ssm_heads, self.ssm_head_dim
                total += D * (2 * inner + 2 * self.ssm_state + nh)  # in_proj(z,x,B,C,dt)
                total += self.ssm_conv * (inner + 2 * self.ssm_state)
                total += nh * 3                          # A_log, D, dt_bias
                total += inner                           # gating norm
                total += inner * D                       # out proj
            # ffn
            if kind in ("attn", "local", "rglru") or self.arch_type != "ssm":
                if self.n_experts:
                    total += D * self.n_experts          # router
                    total += self.n_experts * 3 * D * self.moe_d_ff
                    if self.n_shared_experts:
                        total += 3 * D * self.shared_d_ff
                elif self.d_ff:
                    nmat = 3 if self.mlp_gated else 2
                    total += nmat * D * self.d_ff        # swiglu / gelu mlp
        total += D                                       # final norm
        return total

    def active_param_count(self) -> int:
        """Parameters touched per token (MoE: routed top-k + shared only)."""
        if not self.n_experts:
            return self.param_count()
        full = self.param_count()
        routed_all = 0
        routed_active = 0
        for kind in self.layer_kinds():
            routed_all += self.n_experts * 3 * self.d_model * self.moe_d_ff
            routed_active += self.top_k * 3 * self.d_model * self.moe_d_ff
        return full - routed_all + routed_active
