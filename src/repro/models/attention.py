"""Grouped-query attention with RoPE/M-RoPE, qk-norm, QKV bias, windowing.

Covers every attention variant the assigned architectures need:

* GQA with arbitrary (num_heads, num_kv_heads) — yi-34b 56/8, granite MQA
  48/1, hubert MHA 16/16;
* ``qkv_bias`` (qwen2), ``qk_norm`` (qwen3: RMSNorm over each head's q,k);
* plain RoPE or M-RoPE (qwen2-vl 3-axis sections);
* masks: causal, sliding-window causal, local (hybrid "local" layers),
  bidirectional (encoder-only);
* decode with a ring-buffer KV cache (window-bounded for sliding-window →
  O(window) memory at 524k context).

Training/prefill attention can route through the Pallas flash kernel
(``repro.kernels.ops.flash_attention``) via ``use_kernel=True``; the jnp
path below is the reference and the default on CPU.
"""

from __future__ import annotations

from typing import Any, Dict, Optional, Tuple

import jax
import jax.numpy as jnp

from repro.models.config import ModelConfig
from repro.models.layers import (apply_rope, dense_init, init_rms,
                                 mrope_angles, rms_norm, rope_angles)

__all__ = ["init_attention", "attention_forward", "attention_decode",
           "init_kv_cache", "make_mask"]


# ---------------------------------------------------------------------------
# params
# ---------------------------------------------------------------------------


def init_attention(cfg: ModelConfig, key, dtype) -> Dict[str, Any]:
    """Projection weights are stored with an explicit head axis —
    (D, H, hd) — so tensor parallelism shards whole heads: a flat
    (D, H·hd) layout lets GSPMD split *within* a head whenever H does not
    divide the mesh axis (yi-34b: 56 q / 8 kv heads on model=16), which
    turns every score einsum into a partial-sum all-reduce of the full
    (B, S, S) tensor — the dominant collective of the naive baseline
    (see EXPERIMENTS.md §Perf iteration 1)."""
    hd = cfg.resolved_head_dim
    D = cfg.d_model
    ks = jax.random.split(key, 4)
    p = {
        "wq": dense_init(ks[0], (D, cfg.num_heads * hd),
                         dtype=dtype).reshape(D, cfg.num_heads, hd),
        "wk": dense_init(ks[1], (D, cfg.num_kv_heads * hd),
                         dtype=dtype).reshape(D, cfg.num_kv_heads, hd),
        "wv": dense_init(ks[2], (D, cfg.num_kv_heads * hd),
                         dtype=dtype).reshape(D, cfg.num_kv_heads, hd),
        "wo": dense_init(ks[3], (cfg.num_heads * hd, D),
                         dtype=dtype).reshape(cfg.num_heads, hd, D),
    }
    if cfg.qkv_bias:
        p["bq"] = jnp.zeros((cfg.num_heads, hd), dtype)
        p["bk"] = jnp.zeros((cfg.num_kv_heads, hd), dtype)
        p["bv"] = jnp.zeros((cfg.num_kv_heads, hd), dtype)
    if cfg.qk_norm:
        p["q_norm"] = init_rms(hd, dtype)
        p["k_norm"] = init_rms(hd, dtype)
    return p


def _project_qkv(params, cfg: ModelConfig, x):
    """x (B,S,D) → q (B,S,Hq,hd), k/v (B,S,Hkv,hd), head axis explicit."""
    q = jnp.einsum("bsd,dhk->bshk", x, params["wq"])
    k = jnp.einsum("bsd,dhk->bshk", x, params["wk"])
    v = jnp.einsum("bsd,dhk->bshk", x, params["wv"])
    if cfg.qkv_bias:
        q, k, v = q + params["bq"], k + params["bk"], v + params["bv"]
    if cfg.qk_norm:
        q = rms_norm(q, params["q_norm"], cfg.norm_eps)
        k = rms_norm(k, params["k_norm"], cfg.norm_eps)
    return q, k, v


# ---------------------------------------------------------------------------
# masks
# ---------------------------------------------------------------------------


def make_mask(q_len: int, kv_len: int, *, causal: bool, window: int = 0,
              q_offset: int = 0) -> Optional[jnp.ndarray]:
    """Boolean (q_len, kv_len) mask; True = attend.  ``window > 0`` keeps
    only keys within ``window`` positions behind the query (sliding window /
    local attention).  ``q_offset`` is the absolute position of query 0
    (prefill chunking)."""
    if not causal and window <= 0:
        return None
    q_pos = jnp.arange(q_len)[:, None] + q_offset
    k_pos = jnp.arange(kv_len)[None, :]
    mask = jnp.ones((q_len, kv_len), bool)
    if causal:
        mask &= k_pos <= q_pos
    if window > 0:
        mask &= k_pos > q_pos - window
    return mask


# ---------------------------------------------------------------------------
# core attention
# ---------------------------------------------------------------------------


def _split_heads(x, n, hd):
    return x.reshape(*x.shape[:-1], n, hd)


def _qk_rope(cfg: ModelConfig, q, k, positions):
    hd = cfg.resolved_head_dim
    if cfg.mrope_sections:
        cos, sin = mrope_angles(positions, hd, cfg.mrope_sections, cfg.rope_theta)
    else:
        cos, sin = rope_angles(positions, hd, cfg.rope_theta)
    return apply_rope(q, cos, sin), apply_rope(k, cos, sin)


def _sdpa(q, k, v, mask, n_kv: int):
    """(B,S,Hq,hd) x (B,T,Hkv,hd) grouped attention, fp32 softmax."""
    B, S, Hq, hd = q.shape
    T = k.shape[1]
    group = Hq // n_kv
    q = q.reshape(B, S, n_kv, group, hd)
    scores = jnp.einsum("bsngh,btnh->bngst", q, k).astype(jnp.float32)
    scores *= hd ** -0.5
    if mask is not None:
        scores = jnp.where(mask[None, None, None], scores, -1e30)
    probs = jax.nn.softmax(scores, axis=-1).astype(v.dtype)
    out = jnp.einsum("bngst,btnh->bsngh", probs, v)
    return out.reshape(B, S, Hq, hd)


def attention_forward(params, cfg: ModelConfig, x: jnp.ndarray,
                      positions: jnp.ndarray, *, window: int = 0,
                      use_kernel: bool = False) -> jnp.ndarray:
    """Full-sequence attention (training / prefill).

    x: (B, S, D); positions: (B, S) or (3, B, S) for M-RoPE.
    ``window``: 0 = per-config full/causal; >0 overrides with that window.
    """
    B, S, D = x.shape
    hd = cfg.resolved_head_dim
    q, k, v = _project_qkv(params, cfg, x)
    q, k = _qk_rope(cfg, q, k, positions)

    if use_kernel:
        from repro.kernels import ops as kops
        out = kops.flash_attention(q, k, v, causal=cfg.causal, window=window)
    else:
        mask = make_mask(S, S, causal=cfg.causal, window=window)
        out = _sdpa(q, k, v, mask, cfg.num_kv_heads)
    return jnp.einsum("bshk,hkd->bsd", out, params["wo"])


# ---------------------------------------------------------------------------
# decode (single new token against a KV cache)
# ---------------------------------------------------------------------------


def init_kv_cache(cfg: ModelConfig, batch: int, max_len: int, window: int,
                  dtype) -> Dict[str, jnp.ndarray]:
    """Ring-buffer cache.  Buffer length = window if sliding, else max_len —
    the window bound is what makes ``long_500k`` decode O(window) for the
    dense archs."""
    L = window if window > 0 else max_len
    hd = cfg.resolved_head_dim
    return {
        "k": jnp.zeros((batch, L, cfg.num_kv_heads, hd), dtype),
        "v": jnp.zeros((batch, L, cfg.num_kv_heads, hd), dtype),
    }


def attention_decode(params, cfg: ModelConfig, x: jnp.ndarray,
                     cache: Dict[str, jnp.ndarray], index: jnp.ndarray,
                     *, window: int = 0) -> Tuple[jnp.ndarray, Dict[str, jnp.ndarray]]:
    """One-token decode.  x: (B, 1, D); index: scalar int32 — the absolute
    position of the new token.  Returns (out (B,1,D), new cache)."""
    B = x.shape[0]
    hd = cfg.resolved_head_dim
    L = cache["k"].shape[1]

    q, k, v = _project_qkv(params, cfg, x)
    pos = jnp.full((B, 1), index, jnp.int32)
    if cfg.mrope_sections:
        pos = jnp.broadcast_to(pos[None], (3, B, 1))
    q, k = _qk_rope(cfg, q, k, pos)

    slot = jnp.mod(index, L)
    ck = jax.lax.dynamic_update_slice(cache["k"], k, (0, slot, 0, 0))
    cv = jax.lax.dynamic_update_slice(cache["v"], v, (0, slot, 0, 0))

    # validity: slot s holds absolute position p(s) = s + L*floor(...) — with
    # a ring buffer the live positions are (index-L, index]; all slots are
    # live once index >= L-1, and window-expiry is implicit in the overwrite.
    k_slots = jnp.arange(L)
    live = k_slots <= index                       # before wrap: only filled slots
    scores_mask = live[None, :]                   # (1, L)

    group = cfg.num_heads // cfg.num_kv_heads
    qh = q.reshape(B, 1, cfg.num_kv_heads, group, hd)
    scores = jnp.einsum("bsngh,btnh->bngst", qh, ck).astype(jnp.float32)
    scores *= hd ** -0.5
    scores = jnp.where(scores_mask[None, None, None], scores, -1e30)
    probs = jax.nn.softmax(scores, axis=-1).astype(cv.dtype)
    out = jnp.einsum("bngst,btnh->bsngh", probs, cv)
    out = out.reshape(B, 1, cfg.num_heads, hd)
    out = jnp.einsum("bshk,hkd->bsd", out, params["wo"])
    return out, {"k": ck, "v": cv}
