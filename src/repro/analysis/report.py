"""Render EXPERIMENTS.md §Dry-run / §Roofline tables from dry-run JSONL.

    PYTHONPATH=src python -m repro.analysis.report results/dryrun_singlepod.jsonl
"""

from __future__ import annotations

import json
import sys
from typing import Any, Dict, List

from repro.analysis.roofline import HW, model_flops, roofline_terms
from repro.configs import SHAPES

__all__ = ["load", "dryrun_table", "roofline_table"]


def load(path: str) -> List[Dict[str, Any]]:
    out = []
    with open(path) as f:
        for line in f:
            out.append(json.loads(line))
    # keep the latest record per (arch, shape, mesh)
    dedup: Dict = {}
    for r in out:
        dedup[(r["arch"], r["shape"], r.get("multi_pod", False))] = r
    return list(dedup.values())


def _chips(rec) -> int:
    return 512 if rec.get("multi_pod") else 256


def _tokens(rec) -> int:
    shape = SHAPES[rec["shape"]]
    if shape.kind == "decode":
        return shape.global_batch          # one new token per request
    return shape.global_batch * shape.seq_len


def _fmt(x, unit="", nd=2):
    if x is None:
        return "—"
    if x == 0:
        return "0"
    for scale, suff in ((1e12, "T"), (1e9, "G"), (1e6, "M"), (1e3, "k")):
        if abs(x) >= scale:
            return f"{x/scale:.{nd}f}{suff}{unit}"
    return f"{x:.{nd}g}{unit}"


def dryrun_table(recs: List[Dict]) -> str:
    rows = ["| arch | shape | mesh | status | lower s | compile s | "
            "HLO flops/dev | bytes/dev | collective B/dev (AG/AR/RS/A2A/CP) |",
            "|---|---|---|---|---|---|---|---|---|"]
    for r in sorted(recs, key=lambda r: (r["shape"], r["arch"],
                                         r.get("multi_pod", False))):
        mesh = "2×16×16" if r.get("multi_pod") else "16×16"
        if r["status"] != "ok":
            rows.append(f"| {r['arch']} | {r['shape']} | {mesh} | "
                        f"{r['status']}: {r.get('reason', r.get('error',''))[:40]} "
                        f"| | | | | |")
            continue
        c = r.get("cost", {})
        col = r.get("collectives", {})
        parts = "/".join(_fmt(col.get(k, 0), nd=1) for k in (
            "all-gather", "all-reduce", "reduce-scatter", "all-to-all",
            "collective-permute"))
        rows.append(
            f"| {r['arch']} | {r['shape']} | {mesh} | ok | {r['lower_s']} | "
            f"{r['compile_s']} | {_fmt(c.get('flops'))} | "
            f"{_fmt(c.get('bytes accessed'))} | {parts} |")
    return "\n".join(rows)


def roofline_table(recs: List[Dict], single_pod_only: bool = True) -> str:
    rows = ["| arch | shape | compute s | memory s | collective s | "
            "dominant | MODEL_FLOPS | useful/HLO | bound step s |",
            "|---|---|---|---|---|---|---|---|---|"]
    for r in sorted(recs, key=lambda r: (r["shape"], r["arch"])):
        if single_pod_only and r.get("multi_pod"):
            continue
        if r["status"] != "ok":
            if r["status"] == "skipped":
                rows.append(f"| {r['arch']} | {r['shape']} | — | — | — | "
                            f"skip: {r.get('reason','')[:32]} | — | — | — |")
            continue
        chips = _chips(r)
        t = roofline_terms(r, chips)
        shape = SHAPES[r["shape"]]
        mf = model_flops(r, _tokens(r), shape.kind)
        useful = mf / max(1e-9, t["hlo_flops_global"])
        rows.append(
            f"| {r['arch']} | {r['shape']} | {t['compute_s']:.4g} | "
            f"{t['memory_s']:.4g} | {t['collective_s']:.4g} | "
            f"**{t['dominant']}** | {_fmt(mf)} | {useful:.2f} | "
            f"{t['bound_step_s']:.4g} |")
    return "\n".join(rows)


def main():
    path = sys.argv[1] if len(sys.argv) > 1 else \
        "results/dryrun_singlepod.jsonl"
    recs = load(path)
    print("## §Dry-run\n")
    print(dryrun_table(recs))
    print("\n## §Roofline (single-pod 16×16, per-device terms)\n")
    print(roofline_table(recs))


if __name__ == "__main__":
    main()
