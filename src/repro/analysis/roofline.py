"""Roofline analysis from compiled dry-run artifacts (no real hardware).

Three terms per (arch × shape × mesh), in seconds:

    compute    = HLO_FLOPs  / (chips × 197 TFLOP/s bf16)
    memory     = HLO_bytes  / (chips × 819 GB/s HBM)
    collective = collective_bytes / (chips × 50 GB/s ICI per link)

``cost_analysis()`` of the SPMD-partitioned executable reports *per-device*
flops/bytes, so we scale by ``chips`` to get the global numerators (the
division then cancels — the terms are effectively per-device time, which
is what a roofline wants).  Collective bytes are not in cost_analysis:
we scan the partitioned HLO and sum the operand sizes of every
all-gather / all-reduce / reduce-scatter / all-to-all / collective-permute.

Also reported: MODEL_FLOPS = 6·N·D (6·N_active·D for MoE) and the ratio
MODEL_FLOPS / HLO_FLOPs — how much of compiled compute is "useful"
(catches remat/redundancy waste).
"""

from __future__ import annotations

import re
from typing import Any, Dict

__all__ = ["collective_bytes_from_hlo", "roofline_terms", "HW"]

HW = {
    "peak_flops": 197e12,     # bf16 per chip (TPU v5e class)
    "hbm_bw": 819e9,          # bytes/s per chip
    "link_bw": 50e9,          # bytes/s per ICI link
}

_DTYPE_BYTES = {
    "pred": 1, "s8": 1, "u8": 1, "s16": 2, "u16": 2, "f16": 2, "bf16": 2,
    "s32": 4, "u32": 4, "f32": 4, "s64": 8, "u64": 8, "f64": 8, "c64": 8,
    "c128": 16,
}

_COLLECTIVES = ("all-gather", "all-reduce", "reduce-scatter", "all-to-all",
                "collective-permute")

_DTYPES_PAT = "|".join(_DTYPE_BYTES)
# instruction definition: %name = dtype[dims]... op-name(...operands...)
_DEF_RE = re.compile(
    r"%([\w.\-]+)\s*=\s*(?:\()?(" + _DTYPES_PAT + r")\[([0-9,]*)\]")
_OP_RE = re.compile(
    r"%[\w.\-]+\s*=\s*[^=]*?\s(" +
    "|".join(c.replace("-", r"\-") for c in _COLLECTIVES) +
    r")(-start|-done)?\(")
_OPERAND_RE = re.compile(r"%([\w.\-]+)")


def _shape_bytes(dtype: str, dims: str) -> int:
    n = 1
    if dims:
        for d in dims.split(","):
            n *= int(d)
    return n * _DTYPE_BYTES[dtype]


def collective_bytes_from_hlo(hlo: str) -> Dict[str, float]:
    """Per-device bytes of every collective in the partitioned HLO.

    Operand types are not printed inline in post-compile HLO, so we build a
    symbol table (instruction name → result bytes) first, then resolve each
    collective's operands.  The per-op transfer estimate is
    ``max(Σ operand bytes, result bytes)`` — an all-gather's traffic is its
    (large) result, a reduce-scatter's its (large) input; the max covers
    both directions of the ring.
    """
    sizes: Dict[str, int] = {}
    for m in _DEF_RE.finditer(hlo):
        sizes[m.group(1)] = _shape_bytes(m.group(2), m.group(3))

    out: Dict[str, float] = {c: 0.0 for c in _COLLECTIVES}
    counts: Dict[str, int] = {c: 0 for c in _COLLECTIVES}
    for line in hlo.splitlines():
        m = _OP_RE.search(line)
        if not m:
            continue
        kind, suffix = m.group(1), m.group(2)
        if suffix == "-done":
            continue  # counted at -start
        name_m = re.match(r"\s*(?:ROOT\s+)?%([\w.\-]+)", line)
        result_b = sizes.get(name_m.group(1), 0) if name_m else 0
        call = line[m.end():]
        # strip attribute tail (operands come before the first '), ' attr)
        call = call.split("), ")[0]
        operand_b = sum(sizes.get(nm, 0) for nm in _OPERAND_RE.findall(call))
        out[kind] += max(operand_b, result_b)
        counts[kind] += 1
    out["total"] = sum(out[c] for c in _COLLECTIVES)
    out["counts"] = counts  # type: ignore[assignment]
    return out


def roofline_terms(record: Dict[str, Any], chips: int) -> Dict[str, Any]:
    """Derive the three terms (seconds) from a dry-run record."""
    cost = record.get("cost", {})
    flops_dev = cost.get("flops", 0.0)
    bytes_dev = cost.get("bytes accessed", 0.0)
    coll = record.get("collectives", {})
    coll_dev = coll.get("total", 0.0)

    t_compute = flops_dev / HW["peak_flops"]
    t_memory = bytes_dev / HW["hbm_bw"]
    t_coll = coll_dev / HW["link_bw"]

    terms = {"compute_s": t_compute, "memory_s": t_memory,
             "collective_s": t_coll}
    dominant = max(terms, key=terms.get)
    total = max(terms.values())

    out = dict(terms)
    out["dominant"] = dominant.replace("_s", "")
    out["hlo_flops_per_device"] = flops_dev
    out["hlo_bytes_per_device"] = bytes_dev
    out["collective_bytes_per_device"] = coll_dev
    out["hlo_flops_global"] = flops_dev * chips
    out["bound_step_s"] = total
    return out


def model_flops(record: Dict[str, Any], tokens: int, kind: str) -> float:
    """6·N·D rule (N = active params, D = tokens); forward-only passes
    (prefill/decode) use 2·N·D."""
    n = record.get("active_params") or record.get("params") or 0
    mult = 6.0 if kind == "train" else 2.0
    return mult * n * tokens
