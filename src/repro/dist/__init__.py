"""Distribution plane: mesh-axis sharding rules for params/batches/caches.

``repro.dist.sharding`` maps every assigned architecture's pytrees to
``PartitionSpec`` trees on the production meshes (see
:mod:`repro.launch.mesh`) and on local smoke meshes.  Pure tree logic — no
device allocation happens here.
"""

from repro.dist.meshes import WorkerMesh, plan_worker_meshes
from repro.dist.sharding import (MESH_SIZES, ShardingRules, batch_specs,
                                 cache_specs, generic_param_specs,
                                 param_specs, seq_constrainer)

__all__ = ["MESH_SIZES", "ShardingRules", "WorkerMesh", "batch_specs",
           "cache_specs", "generic_param_specs", "param_specs",
           "plan_worker_meshes", "seq_constrainer"]
