"""Worker meshes: the device-set descriptor behind a dispatcher worker.

ROADMAP item 2 ("one stage forest, many meshes"): the paper's workers are
GPU *servers* — a stage runs on a set of devices, not a thread.  A
:class:`WorkerMesh` is the picklable descriptor of one worker's device
set: the global device ids it owns, the named axis layout over them, the
:class:`~repro.dist.sharding.ShardingRules` preset mapping placement
roles onto those axes, and the host the devices are attached to (the
dispatcher's device-to-device handoff is host-local; cross-host resumes
fall back to the checkpoint store).

The descriptor is deliberately inert — no device allocation happens at
construction, so session snapshots can pickle it and the simulator can
schedule against capacities that do not exist locally.  Only
:meth:`WorkerMesh.jax_mesh` touches the runtime, materializing a
``jax.sharding.Mesh`` over ``jax.devices()`` for backends that execute
sharded (``JaxTrainer.set_mesh``).

Compatibility is the PR 3 divisibility gate, reused: a worker can host a
sharded stage when at least one parameter dimension divides its shard
axes (:func:`repro.dist.sharding.generic_param_specs`); a mesh nothing
shards on is rejected by placement (``EngineStats.placement_rejections``)
so the scheduler keeps it for work it can actually accelerate.
"""

from __future__ import annotations

import dataclasses
import math
from typing import Dict, Optional, Sequence, Tuple

from repro.dist.sharding import ShardingRules

__all__ = ["WorkerMesh", "plan_worker_meshes"]


@dataclasses.dataclass(frozen=True)
class WorkerMesh:
    """One worker's device set (see module docstring).

    ``axes`` is the named layout over ``device_ids`` in row-major order —
    ``(("data", 4),)`` is a flat 4-device FSDP mesh, ``(("data", 2),
    ("model", 2))`` a 2×2 FSDP×TP mesh.  The axis-size product must equal
    ``len(device_ids)``.
    """

    device_ids: Tuple[int, ...]
    axes: Tuple[Tuple[str, int], ...]
    rules: ShardingRules
    host: str = "host0"

    def __post_init__(self):
        if not self.device_ids:
            raise ValueError("a WorkerMesh needs at least one device")
        prod = math.prod(n for _, n in self.axes) if self.axes else 1
        if prod != len(self.device_ids):
            raise ValueError(
                f"axis sizes {dict(self.axes)} cover {prod} devices but the "
                f"mesh owns {len(self.device_ids)}")

    # ------------------------------------------------------------ inspection
    @property
    def n_devices(self) -> int:
        return len(self.device_ids)

    @property
    def sizes(self) -> Dict[str, int]:
        """Axis-name → size mapping (the divisibility gate's ``sizes``)."""
        return dict(self.axes)

    @property
    def key(self) -> Tuple:
        """Stable hashable identity — executable-cache key component."""
        return (self.device_ids, self.axes, self.host)

    # --------------------------------------------------------------- runtime
    def jax_mesh(self):
        """Materialize the live ``jax.sharding.Mesh`` over ``jax.devices()``
        (the only method that touches the runtime — everything else is
        inert and picklable)."""
        import numpy as np
        import jax

        devs = jax.devices()
        missing = [i for i in self.device_ids if i >= len(devs)]
        if missing:
            raise ValueError(
                f"mesh device ids {missing} exceed the {len(devs)} visible "
                "devices (set --xla_force_host_platform_device_count for "
                "CPU smoke meshes)")
        shape = tuple(n for _, n in self.axes) or (1,)
        grid = np.array([devs[i] for i in self.device_ids]).reshape(shape)
        return jax.sharding.Mesh(grid, tuple(n for n, _ in self.axes))

    # ---------------------------------------------------------- construction
    @classmethod
    def build(cls, device_ids: Sequence[int],
              axes: Optional[Sequence[Tuple[str, int]]] = None,
              rules: Optional[ShardingRules] = None,
              host: str = "host0") -> "WorkerMesh":
        """Descriptor with the production defaults: a flat ``data`` axis
        over the devices and the single-pod :meth:`ShardingRules.for_mesh`
        preset (FSDP over ``data``, TP over ``model`` when present)."""
        ids = tuple(int(i) for i in device_ids)
        if axes is None:
            axes = (("data", len(ids)),)
        axes = tuple((str(n), int(s)) for n, s in axes)
        if rules is None:
            rules = ShardingRules.for_mesh(
                multi_pod=any(n == "pod" for n, _ in axes))
        return cls(device_ids=ids, axes=axes, rules=rules, host=host)


def plan_worker_meshes(n_workers: int, devices_per_worker: int,
                       host: str = "host0",
                       rules: Optional[ShardingRules] = None
                       ) -> Tuple[Optional[WorkerMesh], ...]:
    """Homogeneous worker fleet: ``n_workers`` meshes of consecutive
    ``devices_per_worker``-device blocks on one host.  ``devices_per_worker
    <= 0`` yields all-``None`` (plain thread workers)."""
    if devices_per_worker <= 0:
        return tuple(None for _ in range(n_workers))
    return tuple(
        WorkerMesh.build(
            range(w * devices_per_worker, (w + 1) * devices_per_worker),
            rules=rules, host=host)
        for w in range(n_workers))
