"""Sharding rules: pytree → ``PartitionSpec`` tree for every assigned arch.

The placement vocabulary is four roles mapped onto mesh axes by
:class:`ShardingRules`:

* ``fsdp`` — fully-sharded data parallelism: weight matrices sharded over
  the ``data`` axis on their d_model-sized dimension (all-gathered per
  layer under GSPMD);
* ``tp``  — tensor parallelism: head / hidden dimensions sharded over the
  ``model`` axis (whole heads, whole expert-hidden columns);
* ``dp``  — batch-dimension data parallelism, possibly over several axes
  (``("pod", "data")`` on the multi-pod mesh);
* ``pod`` — the cross-pod (DCN) axis; only gradient all-reduce and MoE
  expert parallelism cross it, so it doubles as the expert-parallel axis
  on the multi-pod mesh and is ``None`` on a single pod.

Every proposed axis passes a divisibility gate: an axis is dropped
(replicated) whenever its mesh size does not divide the tensor dimension —
this is what makes e.g. mamba2's vocab (50280 % 16 != 0) fall back to
replication while its d_model stays FSDP-sharded, and what lets the same
rules drive a 1-device smoke mesh (every dimension divides 1).

Spec trees mirror the input tree exactly (``PartitionSpec`` leaves), so
``jax.tree.map(lambda s: NamedSharding(mesh, s), specs, is_leaf=...)``
produces sharding trees for ``jit``'s ``in_shardings`` / ``device_put``.
Stacked per-cycle parameters (anything under a ``"cycles"`` entry, see
:class:`repro.models.transformer.LM`) carry one extra leading layer axis,
which is never sharded.
"""

from __future__ import annotations

import dataclasses
import math
from typing import Any, Dict, Mapping, Optional, Sequence, Tuple, Union

import jax
from jax.sharding import PartitionSpec as P

from repro.models.config import ModelConfig

__all__ = ["MESH_SIZES", "ShardingRules", "param_specs", "batch_specs",
           "cache_specs", "seq_constrainer", "mesh_sizes_of",
           "generic_param_specs"]

Axis = Union[None, str, Tuple[str, ...]]

# Production mesh axis sizes (mirrors repro.launch.mesh: single pod
# (data=16, model=16) = 256 chips, multi-pod adds (pod=2) over DCN).
MESH_SIZES: Dict[str, int] = {"pod": 2, "data": 16, "model": 16}


def _axis_size(ax: Axis, sizes: Mapping[str, int]) -> int:
    """Number of shards an axis entry induces (1 for ``None``; products for
    multi-axis entries like ``("pod", "data")``)."""
    if ax is None:
        return 1
    if isinstance(ax, (tuple, list)):
        return math.prod(_axis_size(a, sizes) for a in ax)
    return sizes[ax]


def mesh_sizes_of(mesh) -> Dict[str, int]:
    """Axis-name → size mapping of a live mesh (for the divisibility gate)."""
    return dict(zip(mesh.axis_names, mesh.devices.shape))


@dataclasses.dataclass(frozen=True)
class ShardingRules:
    """Role → mesh-axis assignment.  ``None`` disables a role (the §Perf
    hillclimb variants toggle roles via ``dataclasses.replace``)."""

    fsdp: Optional[str] = None
    tp: Optional[str] = None
    dp: Tuple[str, ...] = ()
    seq: Optional[str] = None       # sequence parallelism (residual stream)
    pod: Optional[str] = None       # DCN axis == expert-parallel axis

    @classmethod
    def for_mesh(cls, multi_pod: bool) -> "ShardingRules":
        """Preset for the production meshes: FSDP over ``data``, tensor
        parallelism over ``model``; the multi-pod mesh adds the ``pod``
        axis to data parallelism and enables expert parallelism over it."""
        if multi_pod:
            return cls(fsdp="data", tp="model", dp=("pod", "data"),
                       seq=None, pod="pod")
        return cls(fsdp="data", tp="model", dp=("data",), seq=None, pod=None)

    @property
    def dp_axis(self) -> Axis:
        """The batch-dim spec entry: a bare axis name for one axis, a tuple
        for several, ``None`` when data parallelism is off."""
        if not self.dp:
            return None
        return self.dp if len(self.dp) > 1 else self.dp[0]


def seq_constrainer(rules: ShardingRules):
    """Residual-stream (B, S, D) sequence-parallel sharding constraint, or
    ``None`` when ``rules.seq`` is off.  Passed as ``LM(constrain=...)``."""
    if rules.seq is None:
        return None
    dp = rules.dp_axis

    def constrain(x):
        return jax.lax.with_sharding_constraint(x, P(dp, rules.seq, None))

    return constrain


# ---------------------------------------------------------------------------
# spec assembly
# ---------------------------------------------------------------------------


def _path_names(path) -> Tuple[str, ...]:
    """Dict-key names along a ``tree_util`` key path (list indices skipped)."""
    names = []
    for entry in path:
        key = getattr(entry, "key", None)
        if isinstance(key, str):
            names.append(key)
    return tuple(names)


def _spec(leaf, roles: Sequence[Axis], n_lead: int,
          sizes: Mapping[str, int]) -> P:
    """Pad ``roles`` to the leaf's rank (leading stack dims and trailing
    dims replicated) and drop any axis failing the divisibility gate."""
    axes = [None] * n_lead + list(roles)
    if len(axes) > leaf.ndim:
        raise ValueError(f"role tuple {roles} too long for shape {leaf.shape}")
    axes += [None] * (leaf.ndim - len(axes))
    gated = [ax if ax is not None and dim % _axis_size(ax, sizes) == 0
             else None
             for dim, ax in zip(leaf.shape, axes)]
    return P(*gated)


# ---------------------------------------------------------------------------
# parameters (and optimizer-state trees, which mirror the param tree)
# ---------------------------------------------------------------------------


def _param_roles(names: Tuple[str, ...], base_rank: int,
                 rules: ShardingRules) -> Tuple[Axis, ...]:
    """Placement roles for a parameter leaf, keyed on its dict-path names.

    ``base_rank`` is the leaf rank minus the stacked-cycle dim, which
    disambiguates the MoE (E, D, F) from the dense (D, F) FFN layout."""
    name = names[-1] if names else ""
    parent = names[-2] if len(names) >= 2 else ""
    fsdp, tp, ep = rules.fsdp, rules.tp, rules.pod

    # top-level tensors (same names inside optimizer-state subtrees)
    if name == "embed":
        return (tp, fsdp)                         # (vocab, d_model)
    if name == "lm_head":
        return (fsdp, tp)                         # (d_model, vocab)
    if name == "frontend_proj":
        return (None, fsdp)                       # (frontend_dim, d_model)

    if parent == "attn":
        if name in ("wq", "wk", "wv"):
            return (fsdp, tp, None)               # (D, heads, head_dim)
        if name == "wo":
            return (tp, None, fsdp)               # (heads, head_dim, D)
        if name in ("bq", "bk", "bv"):
            return (tp, None)
        return ()                                 # q_norm / k_norm

    if parent in ("ffn", "shared"):
        if name in ("wi", "wg"):
            return ((ep, fsdp, tp) if base_rank == 3   # MoE (E, D, F)
                    else (fsdp, tp))                   # dense (D, F)
        if name == "wo":
            return ((ep, tp, fsdp) if base_rank == 3   # MoE (E, F, D)
                    else (tp, fsdp))                   # dense (F, D)
        if name == "router":
            return (fsdp, None)                   # (D, E) — small, fp32
        return ()

    if parent == "rglru":
        if name in ("w_in", "w_gate"):
            return (fsdp, tp)                     # (D, W)
        if name == "w_out":
            return (tp, fsdp)                     # (W, D)
        if name == "conv_w":
            return (None, tp)                     # (K, W) depthwise conv
        return ()                                 # lam / g_r

    if parent == "ssm":
        if name in ("in_z", "in_x"):
            return (fsdp, tp)                     # (D, inner)
        if name in ("in_B", "in_C", "in_dt"):
            return (fsdp, None)                   # B/C/dt small: replicate
        if name == "conv_x":
            return (None, tp)                     # (K, inner)
        if name == "out_proj":
            return (tp, fsdp)                     # (inner, D)
        return ()                                 # convs/A_log/D/gate_norm

    return ()                                     # norms and anything unknown


def param_specs(shapes: Any, rules: ShardingRules,
                sizes: Optional[Mapping[str, int]] = None) -> Any:
    """``PartitionSpec`` tree for an ``LM`` parameter tree (or an optimizer
    state that mirrors it).  ``shapes`` is any pytree of shaped leaves
    (``jax.eval_shape`` output or live arrays)."""
    sizes = MESH_SIZES if sizes is None else sizes

    def leaf_spec(path, leaf):
        names = _path_names(path)
        n_lead = 1 if "cycles" in names else 0
        roles = _param_roles(names, leaf.ndim - n_lead, rules)
        return _spec(leaf, roles, n_lead, sizes)

    return jax.tree_util.tree_map_with_path(leaf_spec, shapes)


def generic_param_specs(shapes: Any, rules: ShardingRules,
                        sizes: Optional[Mapping[str, int]] = None,
                        n_lead: int = 0) -> Any:
    """Best-effort at-rest placement for *arbitrary* parameter trees (tasks
    the name-keyed :func:`param_specs` table does not know — ResNets, MLPs,
    anything a worker mesh hosts).

    Per leaf: the largest dimension passing the divisibility gate shards
    over ``rules.fsdp``, the largest remaining one over ``rules.tp``;
    everything else (and any leaf nothing divides on) replicates.  Roles
    whose mesh axis is absent from ``sizes`` are skipped, so the single-
    axis worker meshes reuse the production preset unchanged.  The first
    ``n_lead`` dims (member-stacked group carries) are never sharded.
    """
    sizes = MESH_SIZES if sizes is None else sizes

    def usable(ax: Axis) -> bool:
        names = ax if isinstance(ax, (tuple, list)) else (ax,)
        return all(a in sizes for a in names)

    roles = [ax for ax in (rules.fsdp, rules.tp)
             if ax is not None and usable(ax) and _axis_size(ax, sizes) > 1]

    def leaf_spec(leaf) -> P:
        axes: list = [None] * leaf.ndim
        free = list(range(n_lead, leaf.ndim))
        for ax in roles:
            n = _axis_size(ax, sizes)
            cands = [i for i in free if leaf.shape[i] % n == 0
                     and leaf.shape[i] > 0]
            if not cands:
                continue
            pick = max(cands, key=lambda i: leaf.shape[i])
            axes[pick] = ax
            free.remove(pick)
        return P(*axes)

    return jax.tree.map(leaf_spec, shapes)


# ---------------------------------------------------------------------------
# batches
# ---------------------------------------------------------------------------


def batch_specs(cfg: ModelConfig, batch: Any, rules: ShardingRules,
                sizes: Optional[Mapping[str, int]] = None) -> Any:
    """Specs for a training/prefill batch struct (see
    :func:`repro.launch.specs.batch_struct`): batch dim over ``dp``,
    everything else replicated (sequence parallelism enters via the
    residual-stream constraint, not the input placement)."""
    sizes = MESH_SIZES if sizes is None else sizes
    dp = rules.dp_axis

    def leaf_spec(path, leaf):
        names = _path_names(path)
        name = names[-1] if names else ""
        if name == "positions":                   # (3, B, S) M-RoPE ids
            return _spec(leaf, (None, dp), 0, sizes)
        return _spec(leaf, (dp,), 0, sizes)       # tokens/labels/features/...

    return jax.tree_util.tree_map_with_path(leaf_spec, batch)


# ---------------------------------------------------------------------------
# decode caches
# ---------------------------------------------------------------------------


def cache_specs(cfg: ModelConfig, cache: Any, rules: ShardingRules,
                global_batch: int,
                sizes: Optional[Mapping[str, int]] = None) -> Any:
    """Specs for an ``LM.init_cache`` tree: batch dim over ``dp`` (dropped
    when ``global_batch`` does not divide, e.g. the batch-1 ``long_500k``
    shape), KV-head / SSM-head / recurrence-width dims over ``tp``."""
    sizes = MESH_SIZES if sizes is None else sizes
    dp: Axis = rules.dp_axis
    if dp is not None and global_batch % _axis_size(dp, sizes) != 0:
        dp = None
    tp = rules.tp

    def leaf_spec(path, leaf):
        names = _path_names(path)
        name = names[-1] if names else ""
        n_lead = 1 if "cycles" in names else 0
        if name in ("k", "v"):                    # (B, L, n_kv, head_dim)
            roles: Tuple[Axis, ...] = (dp, None, tp, None)
        elif name == "h":                         # RG-LRU state (B, W)
            roles = (dp, tp)
        elif name == "state":                     # SSD state (B, H, P, N)
            roles = (dp, tp, None, None)
        elif name == "conv":                      # RG-LRU conv (B, K-1, W)
            roles = (dp, None, tp)
        elif names[-2:-1] == ("conv",):           # SSD conv streams
            roles = (dp, None, tp) if name == "x" else (dp, None, None)
        else:
            roles = (dp,)
        return _spec(leaf, roles, n_lead, sizes)

    return jax.tree_util.tree_map_with_path(leaf_spec, cache)
