"""Production meshes.

Single pod: (data=16, model=16) = 256 chips (TPU v5e pod slice).
Multi-pod: (pod=2, data=16, model=16) = 512 chips; the ``pod`` axis maps
to the DCN dimension — only data parallelism (gradient all-reduce) and
expert parallelism cross it.

``make_production_mesh`` is a *function* so importing this module never
touches jax device state (the dry-run must set
``XLA_FLAGS=--xla_force_host_platform_device_count=512`` before first
jax initialization).
"""

from __future__ import annotations

import jax

__all__ = ["make_production_mesh", "mesh_axes"]


def mesh_axes(*, multi_pod: bool = False):
    return ("pod", "data", "model") if multi_pod else ("data", "model")


def make_production_mesh(*, multi_pod: bool = False):
    shape = (2, 16, 16) if multi_pod else (16, 16)
    axes = mesh_axes(multi_pod=multi_pod)
    return jax.make_mesh(shape, axes)
