"""Launcher: real training of any assigned architecture on the local mesh.

On this CPU container it trains the *reduced* variants (one device); on a
TPU slice the same entry point builds the production mesh and shards per
:mod:`repro.dist.sharding`.

    PYTHONPATH=src python -m repro.launch.train --arch qwen2-0.5b \
        --reduced --steps 30 --batch 8 --seq 128
"""

from __future__ import annotations

import argparse
import time

import jax
import jax.numpy as jnp

from repro.configs import get_config, list_archs
from repro.data import DataPipeline, synthetic_lm_dataset
from repro.kernels.ops import KERNEL_STATS
from repro.dist.sharding import (ShardingRules, batch_specs, mesh_sizes_of,
                                 param_specs)
from repro.launch.specs import batch_struct
from repro.models import LM
from repro.train.optimizer import init_opt_state
from repro.train.step import build_train_step, shardings_for


def local_mesh():
    n = len(jax.devices())
    # largest (data, model) factorization of the local device count
    model = 1
    for m in (16, 8, 4, 2, 1):
        if n % m == 0:
            model = m
            break
    return jax.make_mesh((n // model, model), ("data", "model"))


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="qwen2-0.5b", choices=list_archs())
    ap.add_argument("--reduced", action="store_true",
                    help="train the smoke-scale variant (CPU-friendly)")
    ap.add_argument("--steps", type=int, default=30)
    ap.add_argument("--batch", type=int, default=8)
    ap.add_argument("--seq", type=int, default=128)
    ap.add_argument("--lr", type=float, default=3e-4)
    ap.add_argument("--use-kernel", action="store_true")
    args = ap.parse_args()

    cfg = get_config(args.arch)
    if args.reduced:
        cfg = cfg.reduced(d_model=256)
    if cfg.frontend != "none":
        raise SystemExit(f"{args.arch} needs frontend embeddings; use the "
                         "dry-run for its full pipeline")

    mesh = local_mesh()
    rules = ShardingRules(fsdp="data", tp="model", dp=("data",))
    model = LM(cfg, use_kernel=args.use_kernel)
    print(f"training {cfg.name} ({cfg.param_count()/1e6:.1f}M params) on "
          f"mesh {dict(zip(mesh.axis_names, mesh.devices.shape))}")

    params = model.init(jax.random.PRNGKey(0))
    opt = init_opt_state("adamw", params)
    data = DataPipeline(
        synthetic_lm_dataset(4096, args.seq, cfg.vocab_size), args.batch)

    ns = lambda t: shardings_for(mesh, t)
    sizes = mesh_sizes_of(mesh)     # gate divisibility on the live mesh
    pshard = ns(param_specs(jax.eval_shape(lambda: params), rules, sizes))
    params = jax.device_put(params, pshard)
    opt = jax.device_put(opt, ns(param_specs(jax.eval_shape(lambda: opt),
                                             rules, sizes)))
    bshard = ns(batch_specs(cfg, batch_struct(cfg, args.batch, args.seq),
                            rules, sizes))

    # XLA:CPU has no buffer donation (and warns per call) — gate it off
    donate = (0, 1) if jax.default_backend() != "cpu" else ()
    step_fn = jax.jit(build_train_step(model), donate_argnums=donate)
    t0 = time.time()
    for i in range(args.steps):
        batch = jax.device_put(
            {k: jnp.asarray(v) for k, v in data.next_batch().items()}, bshard)
        params, opt, loss = step_fn(params, opt, batch,
                                    jnp.float32(args.lr), jnp.int32(i))
        if i % 10 == 0 or i == args.steps - 1:
            print(f"step {i:4d}  loss {float(loss):.4f}  "
                  f"({(time.time()-t0)/(i+1):.2f}s/step)")
    print(f"done: {args.steps} steps in {time.time()-t0:.1f}s; "
          f"final loss {float(loss):.4f}")
    if args.use_kernel:
        print(f"kernel plane: {KERNEL_STATS.calls} call sites, "
              f"{KERNEL_STATS.fallbacks} fallbacks")


if __name__ == "__main__":
    main()
