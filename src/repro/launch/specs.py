"""ShapeDtypeStruct stand-ins for every model input (dry-run inputs).

``input_specs`` mirrors what the data pipeline / serving frontend would
feed each step: token ids for LM training, patch/frame embeddings for the
stubbed VLM/audio frontends, (cache, token, index) for decode.  No device
memory is allocated — these drive ``jit(...).lower()`` only.
"""

from __future__ import annotations

from typing import Any, Dict, Tuple

import jax
import jax.numpy as jnp

from repro.configs import InputShape
from repro.models.config import ModelConfig
from repro.models.transformer import LM

__all__ = ["input_specs", "batch_struct"]


def _sds(shape, dtype):
    return jax.ShapeDtypeStruct(shape, dtype)


def batch_struct(cfg: ModelConfig, batch: int, seq: int) -> Dict[str, Any]:
    """Training/prefill batch for one global step."""
    act = jnp.bfloat16 if cfg.dtype == "bfloat16" else jnp.float32
    if cfg.frontend == "audio":
        return {"features": _sds((batch, seq, cfg.frontend_dim), act),
                "labels": _sds((batch, seq), jnp.int32)}
    if cfg.frontend == "vision":
        P = cfg.frontend_tokens
        assert seq > P, (seq, P)
        return {"tokens": _sds((batch, seq - P), jnp.int32),
                "patches": _sds((batch, P, cfg.frontend_dim), act),
                "positions": _sds((3, batch, seq), jnp.int32)}
    return {"tokens": _sds((batch, seq), jnp.int32)}


def input_specs(cfg: ModelConfig, shape: InputShape
                ) -> Tuple[str, Dict[str, Any]]:
    """Returns (step kind, kwargs structs) for the shape's lowered step.

    * train_4k            → ``train_step(params, opt, batch, step)``
    * prefill_32k         → ``prefill_step(params, batch)``
    * decode_32k/long_500k→ ``serve_step(params, cache, tokens, index)``
    """
    if shape.kind in ("train", "prefill"):
        return shape.kind, {
            "batch": batch_struct(cfg, shape.global_batch, shape.seq_len)}

    model = LM(cfg)
    cache = jax.eval_shape(
        lambda: model.init_cache(shape.global_batch, shape.seq_len))
    return "decode", {
        "cache": cache,
        "tokens": _sds((shape.global_batch, 1), jnp.int32),
        "index": _sds((), jnp.int32),
    }
