"""Multi-pod dry-run: lower + compile every (arch × shape × mesh) case.

This is the proof that the distribution config is coherent without real
hardware: ``jit(step).lower(**ShapeDtypeStructs).compile()`` must succeed
on the production meshes — 256-chip single-pod (16×16) and 512-chip
multi-pod (2×16×16) — for all 10 architectures × 4 input shapes (minus the
assignment-mandated skips).  ``memory_analysis()`` proves the state fits;
``cost_analysis()`` + the HLO collective scan feed §Roofline.

Usage::

    python -m repro.launch.dryrun --arch yi-34b --shape train_4k
    python -m repro.launch.dryrun --all [--multi-pod] [--out results.json]
"""

import argparse
import os
import dataclasses
import json
import time
import traceback
from typing import Any, Dict, Optional

import jax
import jax.numpy as jnp
from jax.sharding import NamedSharding, PartitionSpec as P

from repro.configs import (SHAPES, config_for_shape, get_config, list_archs,
                           shape_applicable)
from repro.dist.sharding import (ShardingRules, batch_specs, cache_specs,
                                 mesh_sizes_of, param_specs, seq_constrainer)
from repro.launch.mesh import make_production_mesh
from repro.launch.specs import input_specs
from repro.models.transformer import LM
from repro.train.optimizer import init_opt_state
from repro.train.step import (build_prefill_step, build_serve_step,
                              build_train_step, shardings_for)

__all__ = ["run_case", "main"]

_ns = shardings_for


def _mesh_context(mesh):
    """``jax.set_mesh`` where available (jax >= 0.6); older releases use the
    ``Mesh`` object itself as the context manager."""
    return jax.set_mesh(mesh) if hasattr(jax, "set_mesh") else mesh


def _collect(lowered, compiled) -> Dict[str, Any]:
    out: Dict[str, Any] = {}
    try:
        ca = compiled.cost_analysis()
        if isinstance(ca, (list, tuple)):
            ca = ca[0]
        out["cost"] = {k: float(v) for k, v in ca.items()
                       if isinstance(v, (int, float))}
    except Exception as e:  # pragma: no cover
        out["cost_error"] = repr(e)
    try:
        ma = compiled.memory_analysis()
        if ma is not None:
            out["memory"] = {
                k: int(getattr(ma, k)) for k in (
                    "argument_size_in_bytes", "output_size_in_bytes",
                    "temp_size_in_bytes", "generated_code_size_in_bytes")
                if hasattr(ma, k)}
    except Exception as e:  # pragma: no cover
        out["memory_error"] = repr(e)
    return out


def run_case(arch: str, shape_name: str, *, multi_pod: bool = False,
             rules: Optional[ShardingRules] = None,
             collect_hlo: bool = True, verbose: bool = True,
             use_scan: bool = False,
             cfg_overrides: Optional[Dict[str, Any]] = None,
             tag: str = "", reduced: bool = False) -> Dict[str, Any]:
    """Lower + compile one (arch, shape, mesh) case; returns the record.

    ``reduced=True`` is the 1-device smoke path: the arch's reduced variant
    and a shrunk input shape compiled on a local (data=1, model=1) mesh —
    the structural proof that rules → specs → step wiring is coherent
    without 512 placeholder devices.
    """
    shape = SHAPES[shape_name]
    base = get_config(arch)
    rec: Dict[str, Any] = {"arch": arch, "shape": shape_name,
                           "multi_pod": multi_pod, "reduced": reduced}
    if not shape_applicable(base, shape):
        rec["status"] = "skipped"
        rec["reason"] = ("encoder-only: no decode step"
                         if base.is_encoder_only else "inapplicable")
        return rec

    cfg = config_for_shape(base, shape)
    if shape.kind == "train":
        cfg = dataclasses.replace(cfg, remat=True)
    if reduced:
        if multi_pod:
            raise ValueError("--reduced runs on the local single mesh")
        cfg = cfg.reduced()
        shape = dataclasses.replace(shape, global_batch=4, seq_len=64)
        collect_hlo = False
    if cfg_overrides:
        cfg = dataclasses.replace(cfg, **cfg_overrides)
        rec["cfg_overrides"] = dict(cfg_overrides)
    rec["tag"] = tag
    rec["sliding_window"] = cfg.sliding_window

    mesh = (jax.make_mesh((1, 1), ("data", "model")) if reduced
            else make_production_mesh(multi_pod=multi_pod))
    sizes = mesh_sizes_of(mesh)
    rules = rules or ShardingRules.for_mesh(multi_pod)
    rec["rules"] = dataclasses.asdict(rules)
    # unroll → exact per-layer flop accounting (XLA counts a while body
    # once); scan → small HLO for the fast multi-pod sharding-proof pass
    model = LM(cfg, unroll=not use_scan, constrain=seq_constrainer(rules))
    rec["layer_scan"] = use_scan

    t0 = time.time()
    params_shape = jax.eval_shape(model.init, jax.random.PRNGKey(0))
    pspecs = param_specs(params_shape, rules, sizes)
    pshard = _ns(mesh, pspecs)
    scalar = NamedSharding(mesh, P())
    kind, kw = input_specs(cfg, shape)

    with _mesh_context(mesh):
        if kind == "train":
            opt_shape = jax.eval_shape(
                lambda p: init_opt_state("adamw", p), params_shape)
            oshard = _ns(mesh, param_specs(opt_shape, rules, sizes))
            bshard = _ns(mesh, batch_specs(cfg, kw["batch"], rules, sizes))
            fn = build_train_step(model)
            jf = jax.jit(fn,
                         in_shardings=(pshard, oshard, bshard, scalar, scalar),
                         out_shardings=(pshard, oshard, scalar),
                         donate_argnums=(0, 1))
            lowered = jf.lower(params_shape, opt_shape, kw["batch"],
                               jax.ShapeDtypeStruct((), jnp.float32),
                               jax.ShapeDtypeStruct((), jnp.int32))
        elif kind == "prefill":
            fn = build_prefill_step(model)
            bshard = _ns(mesh, batch_specs(cfg, kw["batch"], rules, sizes))
            jf = jax.jit(fn, in_shardings=(pshard, bshard))
            lowered = jf.lower(params_shape, kw["batch"])
        else:  # decode
            cshard = _ns(mesh, cache_specs(cfg, kw["cache"], rules,
                                           shape.global_batch, sizes))
            dp = rules.dp_axis
            tshard = NamedSharding(
                mesh, P(dp, None) if shape.global_batch > 1 and dp is not None
                else P(None, None))
            fn = build_serve_step(model)
            jf = jax.jit(fn, in_shardings=(pshard, cshard, tshard, scalar),
                         out_shardings=(None, cshard), donate_argnums=(1,))
            lowered = jf.lower(params_shape, kw["cache"], kw["tokens"],
                               kw["index"])
        rec["lower_s"] = round(time.time() - t0, 2)

        t1 = time.time()
        compiled = lowered.compile(
            compiler_options={"xla_backend_optimization_level": 0})
        rec["compile_s"] = round(time.time() - t1, 2)

    rec.update(_collect(lowered, compiled))
    if collect_hlo:
        import gzip
        from repro.analysis.roofline import collective_bytes_from_hlo
        try:
            hlo = compiled.as_text()
        except Exception:
            hlo = lowered.as_text()
        rec["collectives"] = collective_bytes_from_hlo(hlo)
        os.makedirs("results/hlo", exist_ok=True)
        tag_ = f"{arch}_{shape_name}_{'mp' if multi_pod else 'sp'}"
        if tag:
            tag_ += "_" + tag
        with gzip.open(f"results/hlo/{tag_}.hlo.gz", "wt") as f:
            f.write(hlo)
        rec["hlo_path"] = f"results/hlo/{tag_}.hlo.gz"
    rec["status"] = "ok"
    rec["params"] = cfg.param_count()
    rec["active_params"] = cfg.active_param_count()
    if verbose:
        mem = rec.get("memory", {})
        mesh_tag = ("1x1" if reduced else
                    "2x16x16" if multi_pod else "16x16")
        print(f"[{arch} × {shape_name} × {mesh_tag}] "
              f"lower {rec['lower_s']}s compile {rec['compile_s']}s "
              f"flops={rec.get('cost', {}).get('flops', float('nan')):.3e} "
              f"temp={mem.get('temp_size_in_bytes', 0)/2**30:.2f}GiB")
    return rec


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default=None)
    ap.add_argument("--shape", default=None)
    ap.add_argument("--all", action="store_true")
    ap.add_argument("--multi-pod", action="store_true")
    ap.add_argument("--both-meshes", action="store_true")
    ap.add_argument("--out", default=None, help="append JSONL records here")
    ap.add_argument("--scan", action="store_true",
                    help="layer-scan model (fast compile, body-once flops)")
    ap.add_argument("--skip-done", action="store_true",
                    help="skip cases already ok/skipped in --out")
    ap.add_argument("--reduced", action="store_true",
                    help="1-device smoke: reduced arch variants on a local "
                         "(1, 1) mesh, no placeholder devices")
    args = ap.parse_args()
    if args.reduced and (args.multi_pod or args.both_meshes):
        ap.error("--reduced runs on the local single mesh")
    if not args.reduced:
        # The production dry-run needs 512 placeholder devices.  jax locks
        # the device count at first backend init (not at import), so this
        # must precede the first device use below; set here rather than at
        # module import so merely importing this module never mutates the
        # process environment (tests import it, and a mutated XLA_FLAGS
        # would leak into any subprocess they spawn).
        os.environ["XLA_FLAGS"] = (
            "--xla_force_host_platform_device_count=512 "
            + os.environ.get("XLA_FLAGS", ""))

    archs = list_archs() if args.arch is None or args.all else [args.arch]
    cheap_first = ["decode_32k", "long_500k", "prefill_32k", "train_4k"]
    shapes = cheap_first if args.shape is None or args.all else [args.shape]
    meshes = [False, True] if args.both_meshes else [args.multi_pod]

    done = set()
    if args.skip_done and args.out and os.path.exists(args.out):
        with open(args.out) as f:
            for line in f:
                r = json.loads(line)
                if r.get("status") in ("ok", "skipped"):
                    # reduced smoke records must not satisfy full-size
                    # cases (or vice versa) — the flag is part of the key
                    done.add((r["arch"], r["shape"], r["multi_pod"],
                              r.get("reduced", False)))

    records = []
    for shape in shapes:
        for arch in archs:
            for mp in meshes:
                if (arch, shape, mp, args.reduced) in done:
                    continue
                try:
                    rec = run_case(arch, shape, multi_pod=mp,
                                   use_scan=args.scan or mp,
                                   reduced=args.reduced)
                except Exception as e:
                    rec = {"arch": arch, "shape": shape, "multi_pod": mp,
                           "status": "error", "error": repr(e),
                           "traceback": traceback.format_exc()}
                    print(f"[{arch} × {shape}] ERROR {e!r}")
                records.append(rec)
                if args.out:
                    with open(args.out, "a") as f:
                        f.write(json.dumps(rec) + "\n")

    ok = sum(r["status"] == "ok" for r in records)
    sk = sum(r["status"] == "skipped" for r in records)
    er = sum(r["status"] == "error" for r in records)
    print(f"\ndry-run: {ok} ok, {sk} skipped (by design), {er} errors "
          f"of {len(records)} cases")
    if er:
        raise SystemExit(1)


if __name__ == "__main__":
    main()
