"""Launcher: drive a long-lived StudyService under staggered traffic.

The operational entry point for the service plane — a deployment's
supervisor would run exactly this loop: keep one session open, admit
studies as they arrive, snapshot periodically, and (after a crash or a
rolling restart) resume from the newest snapshot instead of recomputing.

    PYTHONPATH=src python -m repro.launch.serve_studies \\
        --studies 4 --arrival-gap 3600 --workers 40
    PYTHONPATH=src python -m repro.launch.serve_studies \\
        --studies 4 --snapshot-at 9000 --session /tmp/hippo-session.pkl

``--snapshot-at T`` drives the session to virtual time ``T``, snapshots,
then **kills the live session** and finishes from the snapshot via
``StudyService.restore`` — proving the resume path end-to-end (the final
stats are identical to an uninterrupted run).  Uses the simulator backend;
swap ``SimulatedTrainer`` for ``JaxTrainer`` to serve real training.
"""

from __future__ import annotations

import argparse
import signal
import sys
import time

from repro.core import FaultInjector, SearchPlanDB, StudyService, StudySpec
from repro.core.engine import session_rotation
from repro.core.trainer import SimulatedTrainer
from repro.core.tuners import GridSearchSpace, GridTuner
from repro.core.hpseq import Constant, Exponential, MultiStep, StepLR, Warmup
from repro.dist.meshes import plan_worker_meshes
from repro.train.checkpoint import CheckpointStore, DirectoryObjectStore


def _space(seed: int, steps: int) -> GridSearchSpace:
    lrs = [StepLR(0.1, 0.1, [90, 135]),
           StepLR(0.1, 0.1, [100, 150]),
           Warmup(5, 0.1, StepLR(0.1, 0.1, [90, 135])),
           Warmup(5, 0.1, Exponential(0.1, 0.95))]
    # rotate the lr menu per arriving team: heavy overlap, not identity
    lrs = lrs[seed % len(lrs):] + lrs[:seed % len(lrs)]
    return GridSearchSpace(
        fns={"lr": lrs[:3],
             "bs": [Constant(128), MultiStep(128, [70], values=[128, 256])]})


def _submit_all(svc: StudyService, args) -> None:
    spec = StudySpec(args.model, args.dataset, ("lr", "bs"))
    for i in range(args.studies):
        svc.submit(spec, GridTuner(_space(i, args.steps).trials(args.steps)),
                   at=i * args.arrival_gap)


def _report(stats) -> None:
    print(f"served: {stats.gpu_hours:.1f} GPU-h, "
          f"e2e {stats.end_to_end / 3600:.2f} h, "
          f"{stats.steps_run} steps, {stats.rounds} scheduling rounds")
    if stats.ckpt_bytes_written:
        print(f"ckpt plane: {stats.ckpt_bytes_written / 1e6:.1f} MB written "
              f"({stats.ckpt_delta_commits} delta commits, "
              f"dedup {stats.dedup_ratio:.2f}x), tiers "
              f"mem/disk/remote {stats.ckpt_mem_hits}/{stats.ckpt_disk_hits}"
              f"/{stats.ckpt_remote_hits} hits, "
              f"{stats.ckpt_tier_demotions} demotions, "
              f"{stats.ckpt_tier_promotions} promotions, "
              f"{stats.ckpt_tmp_reclaimed} stale tmp reclaimed")
    if stats.mesh_placements:
        print(f"mesh plane: {stats.mesh_placements} mesh placements, "
              f"{stats.placement_rejections} rejections, "
              f"{stats.d2d_handoffs} d2d handoffs")
    if stats.stage_failures or stats.faults_injected:
        print(f"fault plane: {stats.faults_injected} faults injected, "
              f"{stats.stage_failures} stage failures, "
              f"{stats.stage_retries} retries, "
              f"{stats.groups_degraded} groups degraded, "
              f"{stats.workers_quarantined} quarantines, "
              f"{stats.wasted_gpu_seconds / 3600:.2f} GPU-h wasted")
    for sid, ss in sorted(stats.by_study.items()):
        print(f"  {sid}: {ss.gpu_seconds / 3600:7.1f} GPU-h  "
              f"{ss.steps_run:6d} steps served  "
              f"{ss.instant_results:3d} instant")


def _build_store(args):
    """Tiered checkpoint plane from the CLI knobs (None = in-memory)."""
    if not args.ckpt_dir:
        return None
    remote = (DirectoryObjectStore(args.remote_dir) if args.remote_dir
              else None)
    cap = (int(args.disk_capacity_mb * 1e6)
           if args.disk_capacity_mb else None)
    return CheckpointStore(args.ckpt_dir, remote=remote,
                           disk_capacity_bytes=cap)


def main() -> None:
    ap = argparse.ArgumentParser(
        description="long-lived study service under staggered arrivals "
                    "(simulated backend)")
    ap.add_argument("--studies", type=int, default=4)
    ap.add_argument("--steps", type=int, default=160)
    ap.add_argument("--workers", type=int, default=40)
    ap.add_argument("--arrival-gap", type=float, default=3600.0,
                    help="virtual seconds between study arrivals")
    ap.add_argument("--model", default="resnet20")
    ap.add_argument("--dataset", default="cifar10")
    ap.add_argument("--policy", default="fair_share")
    ap.add_argument("--sec-per-step", type=float, default=60.0)
    ap.add_argument("--session", default=None,
                    help="session snapshot path (required by --snapshot-at)")
    ap.add_argument("--snapshot-at", type=float, default=None,
                    help="virtual time to snapshot at; the live session is "
                         "then discarded and the run finishes via restore")
    ap.add_argument("--snapshot-every", type=float, default=None,
                    help="continuous durability: rotate a session snapshot "
                         "to --session every T virtual seconds; on startup "
                         "the service resumes from the newest readable "
                         "rotation slot (a SIGKILL loses at most one "
                         "interval)")
    ap.add_argument("--snapshot-keep", type=int, default=3,
                    help="rotation slots kept by --snapshot-every")
    ap.add_argument("--inject-faults", type=int, default=None, metavar="SEED",
                    help="deterministic fault injection: worker crashes, "
                         "transient stage failures and store outages drawn "
                         "from this seed (same seed => same fault schedule)")
    ap.add_argument("--fault-rates", default="0.05,0.02,0.01",
                    metavar="STAGE,CRASH,OUTAGE",
                    help="per-draw probabilities used by --inject-faults")
    ap.add_argument("--throttle", type=float, default=0.0,
                    help="wall seconds to sleep between engine steps "
                         "(paces the virtual-time simulator for demos and "
                         "for exercising the signal handlers)")
    ap.add_argument("--ckpt-dir", default=None,
                    help="directory for the checkpoint plane (enables "
                         "delta-encoded durable checkpoints; default: "
                         "in-memory store)")
    ap.add_argument("--remote-dir", default=None,
                    help="directory standing in for the remote object-store "
                         "tier (requires --ckpt-dir)")
    ap.add_argument("--disk-capacity-mb", type=float, default=None,
                    help="local disk tier capacity; LRU blobs past it "
                         "demote to --remote-dir")
    ap.add_argument("--devices-per-worker", type=int, default=0,
                    help="give every worker a mesh of this many devices "
                         "(distribution plane v2; 0 = plain thread "
                         "workers).  The simulator accounts the mesh "
                         "width; real backends shard over it.")
    ap.add_argument("--mesh-host", default="host0",
                    help="host label for the worker meshes (device-to-"
                         "device checkpoint handoff is host-local)")
    args = ap.parse_args()
    if args.remote_dir and not args.ckpt_dir:
        ap.error("--remote-dir requires --ckpt-dir")
    if args.disk_capacity_mb and not args.remote_dir:
        # the capacity only drives demotion to the remote tier; without one
        # it would be silently ignored
        ap.error("--disk-capacity-mb requires --remote-dir")

    if args.snapshot_every is not None and not args.session:
        ap.error("--snapshot-every requires --session PATH")

    def backend():
        return SimulatedTrainer(base_seconds_per_step=args.sec_per_step,
                                horizon=args.steps)

    def injector():
        if args.inject_faults is None:
            return None
        stage, crash, outage = (float(x) for x
                                in args.fault_rates.split(","))
        return FaultInjector(args.inject_faults, stage_fault_rate=stage,
                             crash_rate=crash, outage_rate=outage)

    meshes = (plan_worker_meshes(args.workers, args.devices_per_worker,
                                 host=args.mesh_host)
              if args.devices_per_worker > 0 else None)
    restored = False
    if args.session and session_rotation(args.session):
        # a prior --snapshot-every run left rotated snapshots: resume from
        # the newest readable slot instead of recomputing (the restored
        # state carries the pending futures AND the snapshot cadence)
        svc = StudyService.restore_latest(SearchPlanDB(), args.session,
                                          backend(), store=_build_store(args),
                                          fault_injector=injector())
        restored = True
        print(f"restored session at t={svc.time:.0f}s from newest "
              f"rotation slot ({len(svc.futures)} studies attached)")
    else:
        db = SearchPlanDB()
        svc = StudyService(db, backend(), n_workers=args.workers,
                           policy=args.policy, store=_build_store(args),
                           worker_meshes=meshes,
                           fault_injector=injector())
        _submit_all(svc, args)
    if args.snapshot_every is not None:
        svc.enable_auto_snapshot(args.session, args.snapshot_every,
                                 keep=args.snapshot_keep)

    # graceful shutdown: SIGTERM/SIGINT finish the current engine step,
    # snapshot the session to --session, and exit cleanly — a supervisor's
    # rolling restart then resumes via the startup restore above
    shutdown = {"sig": None}

    def _on_signal(signum, frame):
        shutdown["sig"] = signum

    prev_handlers = {s: signal.signal(s, _on_signal)
                     for s in (signal.SIGTERM, signal.SIGINT)}

    if args.snapshot_at is not None and not restored:
        if not args.session:
            ap.error("--snapshot-at requires --session PATH")
        svc.run_until(args.snapshot_at)
        path = svc.snapshot(args.session)
        done = sum(f.done() for f in svc.futures)
        print(f"snapshot at t={svc.time:.0f}s -> {path} "
              f"({done}/{len(svc.futures)} studies done); "
              "discarding live session, resuming from disk")
        del svc                       # the "crash"
        # a fresh store over the same tiers: committed blobs (local or
        # demoted to remote) are re-indexed at init and picked up by the
        # restore's eager recompute-on-miss check
        svc = StudyService.restore(SearchPlanDB(), args.session, backend(),
                                   store=_build_store(args),
                                   fault_injector=injector())

    try:
        while svc.step():
            if args.throttle:
                time.sleep(args.throttle)
            if shutdown["sig"] is not None:
                name = signal.Signals(shutdown["sig"]).name
                if args.session:
                    # with rotation on, the final snapshot must become the
                    # newest slot — restore_latest only scans slots, so a
                    # plain base-path write would be ignored on restart
                    if args.snapshot_every is not None:
                        path = svc.snapshot_rotated()
                    else:
                        path = svc.snapshot(args.session)
                    print(f"{name}: final snapshot at t={svc.time:.0f}s "
                          f"-> {path}; exiting")
                else:
                    print(f"{name}: no --session configured, exiting "
                          "without a snapshot")
                sys.exit(0)
    finally:
        # main() runs in-process under the launcher tests: put the
        # process's previous handlers back
        for s, h in prev_handlers.items():
            signal.signal(s, h)
    stats = svc.close()
    _report(stats)


if __name__ == "__main__":
    main()
