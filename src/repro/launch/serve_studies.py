"""Launcher: drive the front-door study gateway under mixed traffic.

The operational entry point for the deployment — a supervisor would run
exactly this loop: keep one :class:`~repro.frontdoor.StudyGateway` open,
admit studies from many tenants over many plan keys as they arrive, lease
the worker fleet across the per-key sessions, snapshot periodically, and
(after a crash or a rolling restart) resume from the newest snapshot
instead of recomputing.

Examples::

    # one key, default tenant — the classic single-session service
    PYTHONPATH=src python -m repro.launch.serve_studies \\
        --studies 4 --arrival-gap 3600 --workers 40

    # multi-tenant: weighted quotas, bounded queues, a concurrency cap
    PYTHONPATH=src python -m repro.launch.serve_studies \\
        --studies 8 --keys 2 --workers 12 --max-concurrent 4 \\
        --tenant-quota alice:2.0 --tenant-quota bob:1.0:8:2

    # kill/restore proof: snapshot mid-run, discard the live gateway,
    # finish from disk — served totals match the uninterrupted run
    PYTHONPATH=src python -m repro.launch.serve_studies \\
        --studies 4 --snapshot-at 9000 --session /tmp/hippo-gw.snap

``--snapshot-at T`` drives the deployment to global virtual time ``T``,
snapshots the whole gateway envelope (every session + admission state +
lease table), then **kills the live gateway** and finishes from the
snapshot via ``StudyGateway.restore``.  Uses the simulator backend; swap
``SimulatedTrainer`` for ``JaxTrainer`` to serve real training.
"""

from __future__ import annotations

import argparse
import os
import signal
import sys
import time

from repro.core import FaultInjector, SearchPlanDB, StudySpec
from repro.core.engine import session_rotation
from repro.core.trainer import SimulatedTrainer
from repro.core.tuners import GridSearchSpace, GridTuner
from repro.core.hpseq import Constant, Exponential, MultiStep, StepLR, Warmup
from repro.dist.meshes import plan_worker_meshes
from repro.frontdoor import StudyGateway, TenantQuota
from repro.train.checkpoint import CheckpointStore, DirectoryObjectStore

EXAMPLES = """\
examples:
  # one key, one tenant (the classic single-session service)
  serve_studies --studies 4 --arrival-gap 3600 --workers 40

  # two teams with weighted fair shares (alice gets 2x bob's share) and a
  # bounded queue + running cap for bob; studies spread over 2 plan keys
  serve_studies --studies 8 --keys 2 --workers 12 --max-concurrent 4 \\
      --tenant-quota alice:2.0 --tenant-quota bob:1.0:8:2

  # continuous durability: rotated gateway snapshots every 600 virtual
  # seconds; on restart the deployment resumes from the newest slot
  serve_studies --studies 6 --snapshot-every 600 --session /tmp/gw.snap

  # prove the kill/restore path end-to-end
  serve_studies --studies 4 --snapshot-at 9000 --session /tmp/gw.snap
"""


def _space(seed: int, steps: int) -> GridSearchSpace:
    lrs = [StepLR(0.1, 0.1, [90, 135]),
           StepLR(0.1, 0.1, [100, 150]),
           Warmup(5, 0.1, StepLR(0.1, 0.1, [90, 135])),
           Warmup(5, 0.1, Exponential(0.1, 0.95))]
    # rotate the lr menu per arriving team: heavy overlap, not identity
    lrs = lrs[seed % len(lrs):] + lrs[:seed % len(lrs)]
    return GridSearchSpace(
        fns={"lr": lrs[:3],
             "bs": [Constant(128), MultiStep(128, [70], values=[128, 256])]})


def _parse_quota(text: str):
    """NAME:WEIGHT[:MAX_QUEUED[:MAX_RUNNING]] -> (name, TenantQuota)."""
    parts = text.split(":")
    if len(parts) < 2 or len(parts) > 4:
        raise argparse.ArgumentTypeError(
            f"bad --tenant-quota {text!r}: expected "
            "NAME:WEIGHT[:MAX_QUEUED[:MAX_RUNNING]]")
    name = parts[0]
    try:
        weight = float(parts[1])
        max_queued = int(parts[2]) if len(parts) > 2 else 16
        max_running = int(parts[3]) if len(parts) > 3 else None
        return name, TenantQuota(weight=weight, max_queued=max_queued,
                                 max_running=max_running)
    except (ValueError, argparse.ArgumentTypeError) as exc:
        raise argparse.ArgumentTypeError(
            f"bad --tenant-quota {text!r}: {exc}")


def _submit_all(gw: StudyGateway, args, tenants) -> None:
    for i in range(args.studies):
        model = (args.model if args.keys == 1
                 else f"{args.model}-v{i % args.keys}")
        spec = StudySpec(model, args.dataset, ("lr", "bs"))
        gw.submit(spec, GridTuner(_space(i, args.steps).trials(args.steps)),
                  tenant=tenants[i % len(tenants)],
                  at=i * args.arrival_gap)


def _report_session(stats, label: str = "") -> None:
    if label:
        print(f"session {label}:")
    print(f"served: {stats.gpu_hours:.1f} GPU-h, "
          f"e2e {stats.end_to_end / 3600:.2f} h, "
          f"{stats.steps_run} steps, {stats.rounds} scheduling rounds")
    if stats.ckpt_bytes_written:
        print(f"ckpt plane: {stats.ckpt_bytes_written / 1e6:.1f} MB written "
              f"({stats.ckpt_delta_commits} delta commits, "
              f"dedup {stats.dedup_ratio:.2f}x), tiers "
              f"mem/disk/remote {stats.ckpt_mem_hits}/{stats.ckpt_disk_hits}"
              f"/{stats.ckpt_remote_hits} hits, "
              f"{stats.ckpt_tier_demotions} demotions, "
              f"{stats.ckpt_tier_promotions} promotions, "
              f"{stats.ckpt_tmp_reclaimed} stale tmp reclaimed")
    if stats.mesh_placements:
        print(f"mesh plane: {stats.mesh_placements} mesh placements, "
              f"{stats.placement_rejections} rejections, "
              f"{stats.d2d_handoffs} d2d handoffs")
    if stats.stage_failures or stats.faults_injected:
        print(f"fault plane: {stats.faults_injected} faults injected, "
              f"{stats.stage_failures} stage failures, "
              f"{stats.stage_retries} retries, "
              f"{stats.groups_degraded} groups degraded, "
              f"{stats.workers_quarantined} quarantines, "
              f"{stats.wasted_gpu_seconds / 3600:.2f} GPU-h wasted")
    for sid, ss in sorted(stats.by_study.items()):
        print(f"  {sid}: {ss.gpu_seconds / 3600:7.1f} GPU-h  "
              f"{ss.steps_run:6d} steps served  "
              f"{ss.instant_results:3d} instant")


def _report(gw: StudyGateway, archive) -> None:
    multi = len(archive) > 1
    for key, stats in archive:
        _report_session(stats, label=key[:12] if multi else "")
    ledger = gw.tenant_ledger()
    if len(ledger) > 1 or set(ledger) != {"default"}:
        for tenant in sorted(ledger):
            e = ledger[tenant]
            print(f"tenant {tenant}: {e['gpu_seconds'] / 3600:.1f} GPU-h "
                  f"over {e['studies']:.0f} studies "
                  f"({e['queued']:.0f} still queued at the door)")


def _store_factory(args):
    """Per-plan-key tiered checkpoint plane from the CLI knobs (None =
    every session gets its own in-memory store)."""
    if not args.ckpt_dir:
        return None

    def factory(key: str) -> CheckpointStore:
        d = os.path.join(args.ckpt_dir, key[:16])
        remote = (DirectoryObjectStore(os.path.join(args.remote_dir,
                                                    key[:16]))
                  if args.remote_dir else None)
        cap = (int(args.disk_capacity_mb * 1e6)
               if args.disk_capacity_mb else None)
        return CheckpointStore(d, remote=remote, disk_capacity_bytes=cap)

    return factory


def main() -> None:
    ap = argparse.ArgumentParser(
        description="front-door study gateway under mixed multi-tenant "
                    "traffic (simulated backend)",
        epilog=EXAMPLES,
        formatter_class=argparse.RawDescriptionHelpFormatter)
    ap.add_argument("--studies", type=int, default=4)
    ap.add_argument("--steps", type=int, default=160)
    ap.add_argument("--workers", type=int, default=40,
                    help="worker slots in the gateway-owned fleet (leased "
                         "across the per-key sessions)")
    ap.add_argument("--keys", type=int, default=1,
                    help="distinct plan keys to spread the studies over "
                         "(model name is varied); each key gets its own "
                         "session, fleet share follows demand")
    ap.add_argument("--arrival-gap", type=float, default=3600.0,
                    help="virtual seconds between study arrivals")
    ap.add_argument("--model", default="resnet20")
    ap.add_argument("--dataset", default="cifar10")
    ap.add_argument("--policy", default="fair_share")
    ap.add_argument("--sec-per-step", type=float, default=60.0)
    ap.add_argument("--tenant-quota", action="append", default=[],
                    metavar="NAME:WEIGHT[:MAX_QUEUED[:MAX_RUNNING]]",
                    help="per-tenant admission quota (repeatable).  WEIGHT "
                         "scales the tenant's fair share at the door and "
                         "inside shared sessions; MAX_QUEUED bounds its "
                         "admission queue (default 16); MAX_RUNNING caps "
                         "its concurrently-running studies.  Studies are "
                         "submitted round-robin across the named tenants.")
    ap.add_argument("--max-concurrent", type=int, default=None,
                    help="gateway-wide cap on concurrently-running studies; "
                         "over-cap submissions wait at the door "
                         "(queued_admission) and are admitted least-"
                         "weighted-usage-first")
    ap.add_argument("--session", default=None,
                    help="gateway snapshot path (required by --snapshot-at)")
    ap.add_argument("--snapshot-at", type=float, default=None,
                    help="global virtual time to snapshot at; the live "
                         "gateway is then discarded and the run finishes "
                         "via restore")
    ap.add_argument("--snapshot-every", type=float, default=None,
                    help="continuous durability: rotate a gateway snapshot "
                         "to --session every T virtual seconds; on startup "
                         "the deployment resumes from the newest readable "
                         "rotation slot (a SIGKILL loses at most one "
                         "interval)")
    ap.add_argument("--snapshot-keep", type=int, default=3,
                    help="rotation slots kept by --snapshot-every")
    ap.add_argument("--inject-faults", type=int, default=None, metavar="SEED",
                    help="deterministic fault injection: worker crashes, "
                         "transient stage failures, store outages and "
                         "admission deferrals drawn from this seed (same "
                         "seed => same fault schedule)")
    ap.add_argument("--fault-rates", default="0.05,0.02,0.01",
                    metavar="STAGE,CRASH,OUTAGE[,ADMISSION]",
                    help="per-draw probabilities used by --inject-faults")
    ap.add_argument("--throttle", type=float, default=0.0,
                    help="wall seconds to sleep between engine steps "
                         "(paces the virtual-time simulator for demos and "
                         "for exercising the signal handlers)")
    ap.add_argument("--ckpt-dir", default=None,
                    help="directory for the checkpoint plane (enables "
                         "delta-encoded durable checkpoints, one "
                         "subdirectory per plan key; default: in-memory "
                         "stores)")
    ap.add_argument("--remote-dir", default=None,
                    help="directory standing in for the remote object-store "
                         "tier (requires --ckpt-dir)")
    ap.add_argument("--disk-capacity-mb", type=float, default=None,
                    help="local disk tier capacity; LRU blobs past it "
                         "demote to --remote-dir")
    ap.add_argument("--devices-per-worker", type=int, default=0,
                    help="give every worker slot a mesh of this many "
                         "devices (distribution plane v2; 0 = plain thread "
                         "workers).  The simulator accounts the mesh "
                         "width; real backends shard over it.")
    ap.add_argument("--mesh-host", default="host0",
                    help="host label for the worker meshes (device-to-"
                         "device checkpoint handoff is host-local)")
    args = ap.parse_args()
    if args.remote_dir and not args.ckpt_dir:
        ap.error("--remote-dir requires --ckpt-dir")
    if args.disk_capacity_mb and not args.remote_dir:
        # the capacity only drives demotion to the remote tier; without one
        # it would be silently ignored
        ap.error("--disk-capacity-mb requires --remote-dir")
    if args.snapshot_every is not None and not args.session:
        ap.error("--snapshot-every requires --session PATH")
    if args.keys < 1:
        ap.error("--keys must be >= 1")

    try:
        quotas = dict(_parse_quota(q) for q in args.tenant_quota)
    except argparse.ArgumentTypeError as exc:
        ap.error(str(exc))
    tenants = sorted(quotas) or ["default"]

    def backend():
        return SimulatedTrainer(base_seconds_per_step=args.sec_per_step,
                                horizon=args.steps)

    def injector():
        if args.inject_faults is None:
            return None
        rates = [float(x) for x in args.fault_rates.split(",")]
        stage, crash, outage = rates[:3]
        admission = rates[3] if len(rates) > 3 else 0.0
        return FaultInjector(args.inject_faults, stage_fault_rate=stage,
                             crash_rate=crash, outage_rate=outage,
                             admission_fault_rate=admission)

    meshes = (plan_worker_meshes(args.workers, args.devices_per_worker,
                                 host=args.mesh_host)
              if args.devices_per_worker > 0 else None)
    restored = False
    if args.session and session_rotation(args.session):
        # a prior --snapshot-every run left rotated snapshots: resume the
        # whole deployment from the newest readable slot (the restored
        # envelope carries every session, the admission queue, the lease
        # table AND the snapshot cadence)
        gw = StudyGateway.restore_latest(SearchPlanDB(), args.session,
                                         backend(),
                                         store_factory=_store_factory(args),
                                         fault_injector=injector())
        restored = True
        print(f"restored gateway at t={gw.time:.0f}s from newest rotation "
              f"slot ({len(gw.sessions)} sessions, "
              f"{len(gw.futures)} studies attached)")
    else:
        gw = StudyGateway(SearchPlanDB(), backend(),
                          n_slots=None if meshes else args.workers,
                          slot_meshes=meshes, quotas=quotas,
                          max_concurrent=args.max_concurrent,
                          fault_injector=injector(),
                          store_factory=_store_factory(args),
                          policy=args.policy)
        _submit_all(gw, args, tenants)
    if args.snapshot_every is not None:
        gw.enable_auto_snapshot(args.session, args.snapshot_every,
                                keep=args.snapshot_keep)

    # graceful shutdown: SIGTERM/SIGINT finish the current engine step,
    # snapshot the gateway to --session, and exit cleanly — a supervisor's
    # rolling restart then resumes via the startup restore above
    shutdown = {"sig": None}

    def _on_signal(signum, frame):
        shutdown["sig"] = signum

    prev_handlers = {s: signal.signal(s, _on_signal)
                     for s in (signal.SIGTERM, signal.SIGINT)}

    if args.snapshot_at is not None and not restored:
        if not args.session:
            ap.error("--snapshot-at requires --session PATH")
        gw.run_until(args.snapshot_at)
        path = gw.snapshot(args.session)
        done = sum(f.done() for f in gw.futures)
        print(f"snapshot at t={gw.time:.0f}s -> {path} "
              f"({done}/{len(gw.futures)} studies done); "
              "discarding live gateway, resuming from disk")
        del gw                        # the "crash"
        # fresh stores over the same tiers: committed blobs (local or
        # demoted to remote) are re-indexed at init and picked up by the
        # restore's eager recompute-on-miss check
        gw = StudyGateway.restore(SearchPlanDB(), args.session, backend(),
                                  store_factory=_store_factory(args),
                                  fault_injector=injector())

    try:
        while gw.step():
            if args.throttle:
                time.sleep(args.throttle)
            if shutdown["sig"] is not None:
                name = signal.Signals(shutdown["sig"]).name
                if args.session:
                    # with rotation on, the final snapshot must become the
                    # newest slot — restore_latest only scans slots, so a
                    # plain base-path write would be ignored on restart
                    if args.snapshot_every is not None:
                        path = gw.snapshot_rotated()
                    else:
                        path = gw.snapshot(args.session)
                    print(f"{name}: final snapshot at t={gw.time:.0f}s "
                          f"-> {path}; exiting")
                else:
                    print(f"{name}: no --session configured, exiting "
                          "without a snapshot")
                sys.exit(0)
    finally:
        # main() runs in-process under the launcher tests: put the
        # process's previous handlers back
        for s, h in prev_handlers.items():
            signal.signal(s, h)
    archive = gw.close()
    _report(gw, archive)


if __name__ == "__main__":
    main()
