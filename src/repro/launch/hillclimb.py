"""Perf hillclimbing driver (§Perf): re-lower a case under variant
sharding/config rules and compare roofline terms against the baseline.

    python -m repro.launch.hillclimb --arch grok-1-314b --shape train_4k \
        --variant no-fsdp seqpar --out results/hillclimb.jsonl
"""

import argparse
import dataclasses
import json
import os

from repro.analysis.roofline import roofline_terms
from repro.dist.sharding import ShardingRules
from repro.launch.dryrun import run_case

# name → (rules overrides, cfg overrides)
VARIANTS = {
    "baseline": ({}, {}),
    # drop FSDP: weights replicated over `data` — kills the per-layer
    # all-gather at the cost of per-device weight memory
    "no-fsdp": ({"fsdp": None}, {}),
    # sequence parallelism: residual stream sharded over `model` between
    # blocks — activation memory / HBM traffic ÷16
    "seqpar": ({"seq": "model"}, {}),
    # pure data parallel (tp off): no tensor collectives, replicated weights
    "dp-only": ({"tp": None, "fsdp": None}, {}),
    # no activation checkpointing: recompute off → compute term down,
    # activation memory up
    "no-remat": ({}, {"remat": False}),
    # MoE: tighter capacity → smaller dispatch buffers / all-to-all
    "cap-1.0": ({}, {"capacity_factor": 1.0}),
    # bf16 → f32 master activations comparison
    "f32": ({}, {"dtype": "float32"}),
}


def run_variant(arch, shape, variant, multi_pod=False):
    r_over, c_over = VARIANTS[variant]
    rules = dataclasses.replace(ShardingRules.for_mesh(multi_pod), **r_over)
    rec = run_case(arch, shape, multi_pod=multi_pod, rules=rules,
                   cfg_overrides=c_over or None, tag=variant, verbose=True)
    if rec["status"] == "ok":
        rec["roofline"] = roofline_terms(rec, 512 if multi_pod else 256)
    rec["variant"] = variant
    return rec


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", required=True)
    ap.add_argument("--shape", required=True)
    ap.add_argument("--variant", nargs="+", default=["baseline"])
    ap.add_argument("--multi-pod", action="store_true")
    ap.add_argument("--out", default="results/hillclimb.jsonl")
    args = ap.parse_args()
    # 512 placeholder devices for the production meshes; set here (before
    # the first backend init inside run_case) rather than at import so
    # importing this module never mutates the process environment
    os.environ["XLA_FLAGS"] = ("--xla_force_host_platform_device_count=512 "
                               + os.environ.get("XLA_FLAGS", ""))

    for v in args.variant:
        rec = run_variant(args.arch, args.shape, v, args.multi_pod)
        t = rec.get("roofline", {})
        print(f"{args.arch} × {args.shape} [{v}]: "
              f"compute {t.get('compute_s', float('nan')):.4g}s  "
              f"memory {t.get('memory_s', float('nan')):.4g}s  "
              f"collective {t.get('collective_s', float('nan')):.4g}s  "
              f"dominant={t.get('dominant')}")
        with open(args.out, "a") as f:
            f.write(json.dumps(rec) + "\n")


if __name__ == "__main__":
    main()
