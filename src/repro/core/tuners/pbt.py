"""Population Based Training (Jaderberg et al. 2017) on stage sharing.

PBT is the algorithm most naturally served by Hippo's representation: an
*exploit* copies a winner's weights and perturbs its hyper-parameters —
i.e. the loser's new configuration is, by construction, a trial whose
hyper-parameter sequence shares the winner's entire prefix.  Expressed as
``Seq((winner_fn, t), (Constant(perturbed), ...))`` the search plan
dedups the copy automatically: the exploited member resumes from the
winner's checkpoint without any weight-copy plumbing.

Decisions are deterministic (hash-seeded) so runs are journal-replayable.
"""

from __future__ import annotations

import math
from typing import Dict, List, Optional

from repro.core.engine import StudyHandle, Tuner
from repro.core.hpseq import Constant, HpConfig, Seq
from repro.core.trial import Trial
from repro.utils import stable_hash

__all__ = ["PBTTuner", "extend_config"]


def extend_config(cfg: HpConfig, at: int, new_values: Dict[str, float]) -> HpConfig:
    """cfg's values on [0, at), then constant ``new_values[k]`` afterwards."""
    fns = {}
    for name, fn in cfg.fns.items():
        if name in new_values:
            fns[name] = Seq((fn, at), (Constant(new_values[name]), None))
        else:
            fns[name] = fn
    return HpConfig(fns, dict(cfg.static))


class _Member:
    def __init__(self, idx: int, cfg: HpConfig):
        self.idx = idx
        self.cfg = cfg
        self.score: float = -math.inf


class PBTTuner(Tuner):
    def __init__(self, configs: List[HpConfig], interval: int,
                 generations: int, mutable: Optional[List[str]] = None,
                 quantile: float = 0.25, factors=(0.8, 1.25),
                 objective: str = "val_acc", mode: str = "max"):
        self.members = [_Member(i, c) for i, c in enumerate(configs)]
        self.interval = interval
        self.generations = generations
        self.mutable = mutable  # None = all sequence hps
        self.quantile = quantile
        self.factors = factors
        self.objective, self.mode = objective, mode
        self._gen = 0
        self._pending: Dict[str, _Member] = {}
        self._handle: Optional[StudyHandle] = None
        self._done = False
        self.best_score = -math.inf
        self.best_cfg: Optional[HpConfig] = None

    # ---------------------------------------------------------------- rounds
    def start(self, handle: StudyHandle) -> None:
        self._handle = handle
        self._launch()

    def _launch(self) -> None:
        step = (self._gen + 1) * self.interval
        self._pending.clear()
        for m in self.members:
            t = Trial(m.cfg, step)
            self._pending[t.trial_id] = m
            self._handle.submit(t)

    def on_result(self, trial: Trial, step: int, metrics: Dict[str, float]) -> None:
        m = self._pending.pop(trial.trial_id, None)
        if m is None:
            return
        m.score = self.score(metrics)
        if m.score > self.best_score:
            self.best_score, self.best_cfg = m.score, m.cfg
        if self._pending:
            return
        self._gen += 1
        if self._gen >= self.generations:
            self._done = True
            return
        self._exploit_explore()
        self._launch()

    # ------------------------------------------------------ exploit/explore
    def _pick(self, seed_obj, options: List):
        h = int(stable_hash(seed_obj)[:8], 16)
        return options[h % len(options)]

    def _exploit_explore(self) -> None:
        t = self._gen * self.interval
        ranked = sorted(self.members, key=lambda m: m.score, reverse=True)
        k = max(1, int(len(ranked) * self.quantile))
        top, bottom = ranked[:k], ranked[-k:]
        for loser in bottom:
            winner = self._pick(("exploit", self._gen, loser.idx),
                                [m.idx for m in top])
            wcfg = self.members[winner].cfg
            new_vals = {}
            names = self.mutable if self.mutable is not None else list(wcfg.fns)
            for name in names:
                cur = wcfg.fns[name].value(t)
                f = self._pick(("explore", self._gen, loser.idx, name),
                               list(self.factors))
                new_vals[name] = cur * f
            loser.cfg = extend_config(wcfg, t, new_vals)

    def is_done(self) -> bool:
        return self._done
