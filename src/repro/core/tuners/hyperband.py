"""Hyperband (Li et al. 2017): brackets of Successive Halving.

Bracket ``s`` starts ``n_s`` configurations at ``max_steps / eta^s`` and
runs SHA on them; brackets trade breadth for per-trial budget.  Because
every bracket's trials land in the same search plan, stage sharing applies
*across brackets* too — a beyond-paper corollary of the multi-study
mechanism.
"""

from __future__ import annotations

import math
from typing import Dict, List, Optional

from repro.core.engine import StudyHandle, Tuner
from repro.core.trial import Trial
from repro.core.tuners.sha import SHATuner

__all__ = ["HyperbandTuner"]


class HyperbandTuner(Tuner):
    def __init__(self, trials: List[Trial], max_steps: int, eta: int = 4,
                 objective: str = "val_acc", mode: str = "max"):
        self.objective, self.mode = objective, mode
        s_max = int(math.floor(math.log(max_steps, eta)))
        self.brackets: List[SHATuner] = []
        i = 0
        for s in range(s_max, -1, -1):
            n = max(1, int(math.ceil((s_max + 1) / (s + 1) * eta ** s)))
            chunk = trials[i:i + n]
            i += n
            if not chunk:
                break
            min_steps = max(1, max_steps // (eta ** s))
            self.brackets.append(SHATuner(
                chunk, min_steps=min_steps, max_steps=max_steps, eta=eta,
                objective=objective, mode=mode))

    def start(self, handle: StudyHandle) -> None:
        for b in self.brackets:
            b.start(handle)

    def on_result(self, trial: Trial, step: int, metrics: Dict[str, float]) -> None:
        for b in self.brackets:
            b.on_result(trial, step, metrics)

    def is_done(self) -> bool:
        return all(b.is_done() for b in self.brackets)

    @property
    def best(self) -> Optional[Trial]:
        done = [b for b in self.brackets if b.best is not None]
        if not done:
            return None
        return max(done, key=lambda b: b.best_score).best
