"""HPO algorithms provided by the client library (Hippo §5.2).

All tuners run on top of the stage-sharing execution engine — they submit
trial requests ``(hp_config, steps)`` and react to metric reports; the
engine/search-plan layer transparently dedups whatever computation their
trials share.
"""

from repro.core.tuners.space import GridSearchSpace
from repro.core.tuners.grid import GridTuner
from repro.core.tuners.sha import SHATuner
from repro.core.tuners.asha import ASHATuner
from repro.core.tuners.hyperband import HyperbandTuner
from repro.core.tuners.median import MedianStoppingTuner
from repro.core.tuners.pbt import PBTTuner

__all__ = [
    "GridSearchSpace", "GridTuner", "SHATuner", "ASHATuner",
    "HyperbandTuner", "MedianStoppingTuner", "PBTTuner",
]
