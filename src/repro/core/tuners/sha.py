"""Successive Halving (SHA, Jamieson & Talwalkar 2016) — synchronous rungs.

Rung ``r`` trains ``n / eta^r`` configurations to ``min_steps * eta^r``
steps; when *all* of a rung's results are in, the top ``1/eta`` fraction is
promoted to the next rung.  Promotion re-submits the same trial with a
larger step budget — the search plan resumes it from its own rung
checkpoint, and (under stage sharing) from *any* trial's checkpoint with
the same hp prefix.

Paper policy for ResNet56: ``reduction=4, min=15, max=120`` (Table 1).
"""

from __future__ import annotations

import math
from typing import Dict, List, Optional

from repro.core.engine import StudyHandle, Tuner
from repro.core.trial import Trial

__all__ = ["SHATuner", "sha_rungs"]


def sha_rungs(min_steps: int, max_steps: int, eta: int) -> List[int]:
    rungs = []
    s = min_steps
    while s < max_steps:
        rungs.append(s)
        s *= eta
    rungs.append(max_steps)
    return rungs


class SHATuner(Tuner):
    def __init__(self, trials: List[Trial], min_steps: int, max_steps: int,
                 eta: int = 4, objective: str = "val_acc", mode: str = "max"):
        self.all_trials = list(trials)
        self.eta = eta
        self.rungs = sha_rungs(min_steps, max_steps, eta)
        self.objective, self.mode = objective, mode
        self._rung = 0
        self._active: List[Trial] = list(trials)
        self._scores: Dict[str, float] = {}
        self._pending: set = set()
        self._handle: Optional[StudyHandle] = None
        self._done = False
        self.best: Optional[Trial] = None
        self.best_score: float = -math.inf

    def start(self, handle: StudyHandle) -> None:
        self._handle = handle
        self._launch_rung()

    def _launch_rung(self) -> None:
        step = self.rungs[self._rung]
        self._scores.clear()
        self._pending = {t.trial_id for t in self._active}
        for t in self._active:
            self._handle.submit(t, upto=min(step, t.total_steps))

    def on_result(self, trial: Trial, step: int, metrics: Dict[str, float]) -> None:
        if trial.trial_id not in self._pending:
            return
        rung_step = min(self.rungs[self._rung], trial.total_steps)
        if step != rung_step:
            return
        self._pending.discard(trial.trial_id)
        s = self.score(metrics)
        self._scores[trial.trial_id] = s
        if s > self.best_score:
            self.best_score, self.best = s, trial
        if self._pending:
            return
        # rung complete — promote top 1/eta
        if self._rung == len(self.rungs) - 1:
            self._done = True
            return
        k = max(1, len(self._active) // self.eta)
        ranked = sorted(self._active, key=lambda t: self._scores[t.trial_id],
                        reverse=True)
        survivors, dropped = ranked[:k], ranked[k:]
        for t in dropped:
            self._handle.kill(t)
        self._active = survivors
        self._rung += 1
        self._launch_rung()

    def is_done(self) -> bool:
        return self._done
