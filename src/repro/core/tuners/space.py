"""Search-space definition (Hippo Figure 10).

Users express each hyper-parameter directly as a list of *sequence
functions*; the grid product of the per-hp choices (optionally filtered)
yields the trial configurations.  Static (non-sequential) hyper-parameters
— optimizer choice, weight decay in the paper's Tables 2-4 — are given as
plain value lists and land in ``HpConfig.static``.
"""

from __future__ import annotations

import itertools
from typing import Any, Callable, Dict, List, Optional, Sequence

from repro.core.hpseq import HpConfig, HpFunction
from repro.core.trial import Trial

__all__ = ["GridSearchSpace"]


class GridSearchSpace:
    def __init__(self, fns: Dict[str, Sequence[HpFunction]],
                 static: Optional[Dict[str, Sequence[Any]]] = None,
                 filter_fn: Optional[Callable[[HpConfig], bool]] = None):
        self.fns = {k: list(v) for k, v in sorted(fns.items())}
        self.static = {k: list(v) for k, v in sorted((static or {}).items())}
        self.filter_fn = filter_fn

    def configs(self) -> List[HpConfig]:
        fn_names = list(self.fns)
        st_names = list(self.static)
        out: List[HpConfig] = []
        for fn_choice in itertools.product(*(self.fns[k] for k in fn_names)):
            for st_choice in itertools.product(*(self.static[k] for k in st_names)):
                cfg = HpConfig(dict(zip(fn_names, fn_choice)),
                               dict(zip(st_names, st_choice)))
                if self.filter_fn is None or self.filter_fn(cfg):
                    out.append(cfg)
        return out

    def trials(self, total_steps: int) -> List[Trial]:
        return [Trial(cfg, total_steps) for cfg in self.configs()]

    def __len__(self) -> int:
        n = 1
        for v in self.fns.values():
            n *= len(v)
        for v in self.static.values():
            n *= len(v)
        if self.filter_fn is not None:
            return len(self.configs())
        return n
