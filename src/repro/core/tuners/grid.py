"""Grid search: every configuration trained to the full step budget.

Paper §6.1 runs grid search for MobileNetV2 and BERT-Base; its GPU-hour
saving under stage-based execution matches the search space's merge rate
``p`` almost exactly (3.15x vs p=3.144), which is the headline sanity check
for the faithful reproduction.
"""

from __future__ import annotations

from typing import Dict, List, Optional

from repro.core.engine import StudyHandle, Tuner
from repro.core.trial import Trial

__all__ = ["GridTuner"]


class GridTuner(Tuner):
    def __init__(self, trials: List[Trial], objective: str = "val_acc",
                 mode: str = "max", extra_steps_for_best: int = 0):
        self.trials = list(trials)
        self.objective, self.mode = objective, mode
        self.extra_steps_for_best = extra_steps_for_best
        self._pending = {t.trial_id for t in trials}
        self._results: Dict[str, float] = {}
        self._handle: Optional[StudyHandle] = None
        self._extra_pending: Optional[str] = None
        self.best: Optional[Trial] = None
        self.best_metrics: Optional[Dict[str, float]] = None
        self.best_score: float = float("-inf")

    def start(self, handle: StudyHandle) -> None:
        self._handle = handle
        for t in self.trials:
            handle.submit(t)

    def on_result(self, trial: Trial, step: int, metrics: Dict[str, float]) -> None:
        if self._extra_pending == trial.trial_id:
            self._extra_pending = None
            self.best_metrics = metrics
            return
        if trial.trial_id not in self._pending:
            return
        self._pending.discard(trial.trial_id)
        s = self.score(metrics)
        self._results[trial.trial_id] = s
        if s > self.best_score:
            self.best_score = s
        if not self._pending:
            best_id = max(self._results, key=self._results.get)
            self.best = next(t for t in self.trials if t.trial_id == best_id)
            self.best_metrics = metrics if best_id == trial.trial_id else None
            if self.extra_steps_for_best:
                # §6.1: "Only the trial with the highest accuracy is trained
                # for 100 additional epochs."
                extended = Trial(self.best.hp_config,
                                 self.best.total_steps + self.extra_steps_for_best,
                                 trial_id=self.best.trial_id + "-extra")
                self._extra_pending = extended.trial_id
                self._handle.submit(extended)

    def is_done(self) -> bool:
        return not self._pending and self._extra_pending is None
