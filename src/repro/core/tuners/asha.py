"""Asynchronous Successive Halving (ASHA, Li et al. 2020).

Unlike synchronous SHA, promotion decisions are made *immediately* as each
result arrives: a trial reporting at rung ``r`` is promoted to rung
``r+1`` iff its score is within the top ``1/eta`` of all rung-``r`` results
seen *so far*.  No barrier → no stragglers, but (as the paper observes in
§6.1) fewer trials end up promoted than synchronous SHA, so Hippo-trial
under ASHA already beats Ray Tune's synchronous behaviour.

Re-implemented per the original paper (the Hippo authors likewise
re-implemented ASHA on Ray Tune "as the implementation provided by Ray
Tune was different from the original paper").
"""

from __future__ import annotations

import math
from typing import Dict, List, Optional, Set

from repro.core.engine import StudyHandle, Tuner
from repro.core.trial import Trial
from repro.core.tuners.sha import sha_rungs

__all__ = ["ASHATuner"]


class ASHATuner(Tuner):
    def __init__(self, trials: List[Trial], min_steps: int, max_steps: int,
                 eta: int = 4, objective: str = "val_acc", mode: str = "max"):
        self.all_trials = list(trials)
        self.eta = eta
        self.rungs = sha_rungs(min_steps, max_steps, eta)
        self.objective, self.mode = objective, mode
        # rung index -> {trial_id: score}
        self._rung_results: List[Dict[str, float]] = [dict() for _ in self.rungs]
        # rung index -> promoted trial ids
        self._promoted: List[Set[str]] = [set() for _ in self.rungs]
        self._trial_rung: Dict[str, int] = {}
        self._outstanding: Set[str] = set()
        self._finished: Set[str] = set()
        self._handle: Optional[StudyHandle] = None
        self.best: Optional[Trial] = None
        self.best_score: float = -math.inf

    def start(self, handle: StudyHandle) -> None:
        self._handle = handle
        for t in self.all_trials:
            self._trial_rung[t.trial_id] = 0
            self._outstanding.add(t.trial_id)
            handle.submit(t, upto=min(self.rungs[0], t.total_steps))

    def _top_k_cut(self, rung: int) -> float:
        scores = sorted(self._rung_results[rung].values(), reverse=True)
        k = len(scores) // self.eta
        if k == 0:
            return math.inf  # not enough results yet to justify promotion
        return scores[k - 1]

    def on_result(self, trial: Trial, step: int, metrics: Dict[str, float]) -> None:
        tid = trial.trial_id
        if tid not in self._outstanding:
            return
        rung = self._trial_rung[tid]
        expect = min(self.rungs[rung], trial.total_steps)
        if step != expect:
            return
        self._outstanding.discard(tid)
        s = self.score(metrics)
        self._rung_results[rung][tid] = s
        if s > self.best_score:
            self.best_score, self.best = s, trial
        if rung == len(self.rungs) - 1 or expect >= trial.total_steps:
            self._finished.add(tid)
        # try to promote any promotable trial at any rung (newly arrived
        # results can make older trials promotable)
        self._promote_all()
        if not self._outstanding and not self._promotable_exists():
            # everything left would never be promoted — mark finished
            for r, results in enumerate(self._rung_results[:-1]):
                for t in results:
                    self._finished.add(t)
            for t in self._rung_results[-1]:
                self._finished.add(t)

    def _promotable_exists(self) -> bool:
        for r in range(len(self.rungs) - 1):
            cut = self._top_k_cut(r)
            for tid, s in self._rung_results[r].items():
                if tid not in self._promoted[r] and s >= cut:
                    return True
        return False

    def _promote_all(self) -> None:
        for r in range(len(self.rungs) - 1):
            cut = self._top_k_cut(r)
            for tid, s in sorted(self._rung_results[r].items(),
                                 key=lambda kv: -kv[1]):
                if tid in self._promoted[r] or s < cut:
                    continue
                trial = next(t for t in self.all_trials if t.trial_id == tid)
                if self.rungs[r] >= trial.total_steps:
                    continue
                self._promoted[r].add(tid)
                self._trial_rung[tid] = r + 1
                self._outstanding.add(tid)
                self._finished.discard(tid)
                self._handle.submit(
                    trial, upto=min(self.rungs[r + 1], trial.total_steps))

    def is_done(self) -> bool:
        return not self._outstanding
