"""Median stopping rule (Google Vizier, Golovin et al. 2017).

Trials report at fixed milestones; a trial is killed at milestone ``m`` if
its best score so far is strictly worse than the *median of the running
averages* of all other trials' scores up to ``m``.
"""

from __future__ import annotations

import statistics
from typing import Dict, List, Optional

from repro.core.engine import StudyHandle, Tuner
from repro.core.trial import Trial

__all__ = ["MedianStoppingTuner"]


class MedianStoppingTuner(Tuner):
    def __init__(self, trials: List[Trial], milestones: List[int],
                 grace_milestones: int = 1, objective: str = "val_acc",
                 mode: str = "max"):
        self.all_trials = list(trials)
        self.milestones = sorted(milestones)
        self.grace = grace_milestones
        self.objective, self.mode = objective, mode
        self._idx: Dict[str, int] = {}            # trial -> milestone index
        self._history: Dict[str, List[float]] = {}
        self._alive: set = {t.trial_id for t in trials}
        self._outstanding: set = set()
        self._handle: Optional[StudyHandle] = None
        self.best: Optional[Trial] = None
        self.best_score = float("-inf")

    def start(self, handle: StudyHandle) -> None:
        self._handle = handle
        for t in self.all_trials:
            self._idx[t.trial_id] = 0
            self._history[t.trial_id] = []
            self._outstanding.add(t.trial_id)
            handle.submit(t, upto=min(self.milestones[0], t.total_steps))

    def on_result(self, trial: Trial, step: int, metrics: Dict[str, float]) -> None:
        tid = trial.trial_id
        if tid not in self._outstanding:
            return
        i = self._idx[tid]
        if step != min(self.milestones[i], trial.total_steps):
            return
        self._outstanding.discard(tid)
        s = self.score(metrics)
        self._history[tid].append(s)
        if s > self.best_score:
            self.best_score, self.best = s, trial

        last = (i == len(self.milestones) - 1
                or self.milestones[i] >= trial.total_steps)
        if last:
            return
        if i + 1 > self.grace:
            others = [statistics.fmean(h[:i + 1])
                      for t, h in self._history.items()
                      if t != tid and len(h) >= i + 1]
            if others and max(self._history[tid]) < statistics.median(others):
                self._alive.discard(tid)
                self._handle.kill(trial)
                return
        self._idx[tid] = i + 1
        self._outstanding.add(tid)
        self._handle.submit(trial,
                            upto=min(self.milestones[i + 1], trial.total_steps))

    def is_done(self) -> bool:
        return not self._outstanding
