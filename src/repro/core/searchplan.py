"""Search plan — Hippo's persistent study representation (§3.2, Figure 6).

The search plan is a tree of *plan nodes*.  Each node represents "a
hyper-parameter configuration starting from a certain training step": the
node's ``desc`` is the offset-normalized functional-piece descriptor (one
piece per hyper-parameter) and ``start`` is the global step at which the
configuration takes over (= the integer annotation on the edge from its
parent).  Node identity is therefore ``(parent, start, desc)`` — two trials
whose hyper-parameter values coincide on ``[0, s)`` traverse exactly the
same nodes up to step ``s``, which is what makes prefix sharing automatic.

Nodes are **never removed** when new trials arrive (§3.2): a trial that
needs a shorter stage than previously materialized simply adds another
integer to an existing node's ``requests`` field.  Stage trees are
generated transiently from the plan (see :mod:`repro.core.stagetree`).

Per-node fields mirror Figure 6:

* ``desc``      — canonical hp-piece descriptors (hp_config of the node),
* ``ckpts``     — {global step: checkpoint key} trained under this path,
* ``metrics``   — {global step: metrics dict},
* ``requests``  — set of global steps requested (train + report metrics),
* ``running``   — subset of requests currently executing on a worker,
* ``refcount`` / ``trials`` — bookkeeping for GC and multi-study sharing,
* ``profile``   — measured seconds/step under this configuration (used by
  the critical-path scheduler).
"""

from __future__ import annotations

from dataclasses import dataclass, field
from typing import Any, Dict, Iterable, List, Optional, Set, Tuple

from repro.core.trial import Trial
from repro.utils import stable_hash

__all__ = ["PlanNode", "SearchPlan", "Request"]

ROOT = "ROOT"  # virtual root id; real roots are children of this sentinel.


@dataclass
class PlanNode:
    node_id: str
    parent: Optional[str]           # parent node id (ROOT children have parent=None)
    start: int                      # global step where this config takes over
    desc: Dict[str, Any]            # canonical piece descriptor
    ckpts: Dict[int, str] = field(default_factory=dict)
    metrics: Dict[int, Dict[str, float]] = field(default_factory=dict)
    requests: Set[int] = field(default_factory=set)
    running: Set[int] = field(default_factory=set)
    refcount: int = 0
    trials: Set[str] = field(default_factory=set)
    profile: Optional[float] = None  # seconds / step (None = unprofiled)
    meta: Dict[str, Any] = field(default_factory=dict)

    def desc_hash(self) -> str:
        return stable_hash(self.desc)

    def latest_ckpt_at_or_before(self, step: int) -> Optional[int]:
        """Largest checkpointed step s with node.start <= s <= step."""
        cands = [s for s in self.ckpts if self.start <= s <= step]
        return max(cands) if cands else None

    def to_json(self) -> Dict[str, Any]:
        return {
            "node_id": self.node_id, "parent": self.parent, "start": self.start,
            "desc": self.desc,
            "ckpts": {str(k): v for k, v in self.ckpts.items()},
            "metrics": {str(k): v for k, v in self.metrics.items()},
            "requests": sorted(self.requests),
            "refcount": self.refcount,
            "trials": sorted(self.trials),
            "profile": self.profile,
            "meta": self.meta,
        }

    @classmethod
    def from_json(cls, d: Dict[str, Any]) -> "PlanNode":
        return cls(
            node_id=d["node_id"], parent=d["parent"], start=d["start"],
            desc=d["desc"],
            ckpts={int(k): v for k, v in d["ckpts"].items()},
            metrics={int(k): v for k, v in d["metrics"].items()},
            requests=set(d["requests"]),
            refcount=d.get("refcount", 0),
            trials=set(d.get("trials", [])),
            profile=d.get("profile"),
            meta=d.get("meta") or {},
        )


@dataclass(frozen=True)
class Request:
    """A pending unit of work: train the path of ``node`` up to ``step``."""

    node_id: str
    step: int


class SearchPlan:
    """The search-plan database entry for one (model, dataset, hp-set) key.

    Multiple studies over the same key share one SearchPlan — that is the
    entire multi-study merging mechanism (§2.2 "sharing computations across
    studies"): their trials land in the same node tree.
    """

    def __init__(self, key: str = "default"):
        self.key = key
        self.nodes: Dict[str, PlanNode] = {}
        self.children: Dict[Optional[str], List[str]] = {None: []}
        # (parent, start, desc_hash) -> node_id
        self._index: Dict[Tuple[Optional[str], int, str], str] = {}
        self._counter = 0
        # trial_id -> (leaf node id, total steps)  for each submitted request
        self.trial_paths: Dict[str, List[str]] = {}
        self.default_profile: float = 1.0  # seconds/step fallback

    # ------------------------------------------------------------- structure
    def _new_node(self, parent: Optional[str], start: int, desc: Dict[str, Any]) -> PlanNode:
        nid = f"H{self._counter}"
        self._counter += 1
        node = PlanNode(nid, parent, start, desc)
        self.nodes[nid] = node
        self.children.setdefault(parent, []).append(nid)
        self.children.setdefault(nid, [])
        self._index[(parent, start, stable_hash(desc))] = nid
        return node

    def get_or_create(self, parent: Optional[str], start: int, desc: Dict[str, Any]) -> PlanNode:
        key = (parent, start, stable_hash(desc))
        nid = self._index.get(key)
        if nid is not None:
            return self.nodes[nid]
        return self._new_node(parent, start, desc)

    def node(self, node_id: str) -> PlanNode:
        return self.nodes[node_id]

    def parent_of(self, node: PlanNode) -> Optional[PlanNode]:
        return self.nodes[node.parent] if node.parent is not None else None

    def path_to_root(self, node_id: str) -> List[PlanNode]:
        """Nodes from root to ``node_id`` inclusive."""
        out = []
        cur: Optional[str] = node_id
        while cur is not None:
            n = self.nodes[cur]
            out.append(n)
            cur = n.parent
        return list(reversed(out))

    def path_key(self, node_id: str) -> str:
        """Content hash identifying the value trajectory of a node's path.

        Checkpoints are addressed by (path_key, step): any two trials whose
        hp values coincide up to ``step`` share the path and therefore the
        checkpoint — across studies too.
        """
        path = [(n.start, n.desc) for n in self.path_to_root(node_id)]
        return stable_hash({"plan_key": self.key, "path": path})

    # ------------------------------------------------------------ insertion
    def submit(self, trial: Trial, upto: Optional[int] = None) -> Tuple[PlanNode, int, bool]:
        """Insert (or match) a trial's prefix up to ``upto`` steps and record
        a request.  Returns (leaf node, step, satisfied) where satisfied is
        True iff metrics for that exact step are already present (§3.2 "in
        case metrics and checkpoints ... already present, a response is
        returned immediately")."""
        step = trial.total_steps if upto is None else min(upto, trial.total_steps)
        segs = trial.segments(step)
        parent: Optional[str] = None
        node: Optional[PlanNode] = None
        for seg in segs:
            node = self.get_or_create(parent, seg.start, seg.desc)
            if trial.trial_id not in node.trials:
                node.trials.add(trial.trial_id)
                node.refcount += 1
            parent = node.node_id
        assert node is not None, "trial with zero steps"
        self.trial_paths.setdefault(trial.trial_id, [])
        path_ids = [n.node_id for n in self.path_to_root(node.node_id)]
        self.trial_paths[trial.trial_id] = path_ids
        if step in node.metrics:
            return node, step, True
        node.requests.add(step)
        return node, step, False

    # ------------------------------------------------------------- requests
    def pending_requests(self) -> List[Request]:
        """Requests with no metrics yet and not currently running."""
        out = []
        for n in self.nodes.values():
            for s in sorted(n.requests):
                if s in n.metrics or s in n.running:
                    continue
                out.append(Request(n.node_id, s))
        return out

    def mark_running(self, reqs: Iterable[Request]) -> None:
        for r in reqs:
            self.nodes[r.node_id].running.add(r.step)

    def clear_running(self, reqs: Iterable[Request]) -> None:
        for r in reqs:
            self.nodes[r.node_id].running.discard(r.step)

    def is_satisfied(self, node_id: str, step: int) -> bool:
        return step in self.nodes[node_id].metrics

    # ------------------------------------------------------------ aggregation
    def record_result(self, node_id: str, step: int, ckpt: Optional[str],
                      metrics: Optional[Dict[str, float]]) -> None:
        n = self.nodes[node_id]
        if ckpt is not None:
            n.ckpts[step] = ckpt
        if metrics is not None:
            n.metrics[step] = dict(metrics)
        n.running.discard(step)

    def record_profile(self, node_id: str, seconds_per_step: float) -> None:
        n = self.nodes[node_id]
        if n.profile is None:
            n.profile = seconds_per_step
        else:  # exponential moving average keeps the estimate current
            n.profile = 0.7 * n.profile + 0.3 * seconds_per_step

    def profile_of(self, node_id: str) -> float:
        p = self.nodes[node_id].profile
        return self.default_profile if p is None else p

    # -------------------------------------------------------------- ckpt GC
    def release_trial(self, trial_id: str) -> List[str]:
        """Drop a trial's references; return node ids whose refcount hit 0
        (their checkpoints are GC candidates — beyond-paper eviction)."""
        dead = []
        for nid in self.trial_paths.pop(trial_id, []):
            n = self.nodes[nid]
            if trial_id in n.trials:
                n.trials.discard(trial_id)
                n.refcount -= 1
                if n.refcount <= 0:
                    dead.append(nid)
        return dead

    # ------------------------------------------------------------- metrics
    def metrics_for(self, node_id: str, step: int) -> Optional[Dict[str, float]]:
        return self.nodes[node_id].metrics.get(step)

    # ---------------------------------------------------------------- stats
    def total_requested_steps(self) -> int:
        """Sum over trials of their max requested step (trial-based cost)."""
        total = 0
        for tid, path in self.trial_paths.items():
            leaf = self.nodes[path[-1]]
            reqs = [s for s in leaf.requests | set(leaf.metrics)]
            total += max(reqs) if reqs else 0
        return total

    def to_json(self) -> Dict[str, Any]:
        return {
            "key": self.key,
            "counter": self._counter,
            "nodes": {nid: n.to_json() for nid, n in self.nodes.items()},
            "trial_paths": self.trial_paths,
            "default_profile": self.default_profile,
        }

    @classmethod
    def from_json(cls, d: Dict[str, Any]) -> "SearchPlan":
        plan = cls(d["key"])
        plan._counter = d["counter"]
        plan.default_profile = d.get("default_profile", 1.0)
        for nid, nd in d["nodes"].items():
            node = PlanNode.from_json(nd)
            plan.nodes[nid] = node
            plan.children.setdefault(node.parent, []).append(nid)
            plan.children.setdefault(nid, [])
            plan._index[(node.parent, node.start, stable_hash(node.desc))] = nid
        plan.trial_paths = {k: list(v) for k, v in d["trial_paths"].items()}
        return plan
