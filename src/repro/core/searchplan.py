"""Search plan — Hippo's persistent study representation (§3.2, Figure 6).

The search plan is a tree of *plan nodes*.  Each node represents "a
hyper-parameter configuration starting from a certain training step": the
node's ``desc`` is the offset-normalized functional-piece descriptor (one
piece per hyper-parameter) and ``start`` is the global step at which the
configuration takes over (= the integer annotation on the edge from its
parent).  Node identity is therefore ``(parent, start, desc)`` — two trials
whose hyper-parameter values coincide on ``[0, s)`` traverse exactly the
same nodes up to step ``s``, which is what makes prefix sharing automatic.

Nodes are **never removed** when new trials arrive (§3.2): a trial that
needs a shorter stage than previously materialized simply adds another
integer to an existing node's ``requests`` field.  Stage trees are
generated transiently from the plan (see :mod:`repro.core.stagetree`).

Per-node fields mirror Figure 6:

* ``desc``      — canonical hp-piece descriptors (hp_config of the node),
* ``ckpts``     — {global step: checkpoint key} trained under this path,
* ``metrics``   — {global step: metrics dict},
* ``requests``  — set of global steps requested (train + report metrics),
* ``running``   — subset of requests currently executing on a worker,
* ``refcount`` / ``trials`` — bookkeeping for GC and multi-study sharing,
* ``profile``   — measured seconds/step under this configuration (used by
  the critical-path scheduler).

Incremental control plane (beyond-paper, semantics-preserving): the plan
keeps a monotonic ``revision`` counter plus a **per-node revision map** —
for each node, the revision of its last stage-tree-relevant mutation
(checkpoints, metrics, running marks), kept in recency order so
``changes_since(rev)`` walks only the nodes touched after ``rev``.  Unlike
the earlier append-only change log this is bounded: at most one entry per
node ever touched, however long the plan lives.  The plan also maintains a
**pending-request index** so ``pending_requests()`` is O(pending) instead
of a full node scan.  Consumers like
:class:`~repro.core.stagetree.StageTreeBuilder` keep their own frontier
revision and pass it to ``changes_since`` to memoize Algorithm-1
resolutions across scheduling rounds.  All mutations must therefore go
through the plan's methods (``submit`` / ``record_result`` /
``mark_running`` / ``clear_running`` / ``drop_request`` /
``release_trial`` / ``evict_ckpts`` / ``forget_ckpt``) — never poke node
fields directly.
"""

from __future__ import annotations

from collections import OrderedDict
from dataclasses import dataclass, field
from typing import Any, Dict, Iterable, List, NamedTuple, Optional, Set, Tuple

from repro.core.trial import Trial
from repro.utils import stable_hash

__all__ = ["PlanNode", "SearchPlan", "Request"]

ROOT = "ROOT"  # virtual root id; real roots are children of this sentinel.


@dataclass
class PlanNode:
    node_id: str
    parent: Optional[str]           # parent node id (ROOT children have parent=None)
    start: int                      # global step where this config takes over
    desc: Dict[str, Any]            # canonical piece descriptor
    ckpts: Dict[int, str] = field(default_factory=dict)
    metrics: Dict[int, Dict[str, float]] = field(default_factory=dict)
    requests: Set[int] = field(default_factory=set)
    running: Set[int] = field(default_factory=set)
    refcount: int = 0
    trials: Set[str] = field(default_factory=set)
    profile: Optional[float] = None  # seconds / step (None = unprofiled)
    meta: Dict[str, Any] = field(default_factory=dict)

    def desc_hash(self) -> str:
        return stable_hash(self.desc)

    def latest_ckpt_at_or_before(self, step: int) -> Optional[int]:
        """Largest checkpointed step s with node.start <= s <= step."""
        cands = [s for s in self.ckpts if self.start <= s <= step]
        return max(cands) if cands else None

    def to_json(self) -> Dict[str, Any]:
        return {
            "node_id": self.node_id, "parent": self.parent, "start": self.start,
            "desc": self.desc,
            "ckpts": {str(k): v for k, v in self.ckpts.items()},
            "metrics": {str(k): v for k, v in self.metrics.items()},
            "requests": sorted(self.requests),
            "refcount": self.refcount,
            "trials": sorted(self.trials),
            "profile": self.profile,
            "meta": self.meta,
        }

    @classmethod
    def from_json(cls, d: Dict[str, Any]) -> "PlanNode":
        return cls(
            node_id=d["node_id"], parent=d["parent"], start=d["start"],
            desc=d["desc"],
            ckpts={int(k): v for k, v in d["ckpts"].items()},
            metrics={int(k): v for k, v in d["metrics"].items()},
            requests=set(d["requests"]),
            refcount=d.get("refcount", 0),
            trials=set(d.get("trials", [])),
            profile=d.get("profile"),
            meta=d.get("meta") or {},
        )


class Request(NamedTuple):
    """A pending unit of work: train the path of ``node`` up to ``step``.

    A NamedTuple (not a dataclass): requests are hashed millions of times as
    memo keys in the incremental stage-tree builder, and tuple hashing is
    several times faster than dataclass field hashing.
    """

    node_id: str
    step: int


class SearchPlan:
    """The search-plan database entry for one (model, dataset, hp-set) key.

    Multiple studies over the same key share one SearchPlan — that is the
    entire multi-study merging mechanism (§2.2 "sharing computations across
    studies"): their trials land in the same node tree.
    """

    def __init__(self, key: str = "default"):
        self.key = key
        self.nodes: Dict[str, PlanNode] = {}
        self.children: Dict[Optional[str], List[str]] = {None: []}
        # (parent, start, desc_hash) -> node_id
        self._index: Dict[Tuple[Optional[str], int, str], str] = {}
        self._counter = 0
        # trial_id -> (leaf node id, total steps)  for each submitted request
        self.trial_paths: Dict[str, List[str]] = {}
        self.default_profile: float = 1.0  # seconds/step fallback
        # trial_id -> study ids that submitted it (fair-share scheduling)
        self.trial_studies: Dict[str, Set[str]] = {}
        # ---- incremental control plane ----
        self.revision = 0                       # bumps on every mutation
        # node id -> revision of its last resolution-relevant change, kept in
        # recency order (most recent last); bounded at one entry per node
        self._node_rev: "OrderedDict[str, int]" = OrderedDict()
        self._pending: Dict[str, Set[int]] = {}  # node_id -> pending steps
        self._order: Dict[str, int] = {}        # node_id -> creation seq
        self._depth: Dict[str, int] = {}        # node_id -> path length
        self._path_keys: Dict[str, str] = {}    # node_id -> cached path_key
        self._static_hashes: Dict[str, str] = {}  # node_id -> static-hp hash

    # -------------------------------------------------------- change tracking
    def _touch(self, node_id: Optional[str] = None) -> None:
        """Bump ``revision``; record ``node_id`` when the mutation can change
        Algorithm-1 resolutions (checkpoints / running marks / metrics)."""
        self.revision += 1
        if node_id is not None:
            self._node_rev[node_id] = self.revision
            self._node_rev.move_to_end(node_id)

    def changes_since(self, rev: int) -> Tuple[int, Set[str]]:
        """(current revision, node ids with resolution-relevant mutations
        after revision ``rev``) — O(changed) via the recency-ordered map;
        callers (the stage-tree builder) keep ``rev`` as their frontier."""
        dirty: Set[str] = set()
        for nid, r in reversed(self._node_rev.items()):
            if r <= rev:
                break
            dirty.add(nid)
        return self.revision, dirty

    def _refresh_pending(self, node: PlanNode, step: int) -> None:
        """Re-derive the pending-index membership of one (node, step)."""
        if (step in node.requests and step not in node.metrics
                and step not in node.running):
            self._pending.setdefault(node.node_id, set()).add(step)
        else:
            steps = self._pending.get(node.node_id)
            if steps is not None:
                steps.discard(step)
                if not steps:
                    del self._pending[node.node_id]

    # ------------------------------------------------------------- structure
    def _new_node(self, parent: Optional[str], start: int, desc: Dict[str, Any]) -> PlanNode:
        nid = f"H{self._counter}"
        self._counter += 1
        node = PlanNode(nid, parent, start, desc)
        self.nodes[nid] = node
        self.children.setdefault(parent, []).append(nid)
        self.children.setdefault(nid, [])
        self._index[(parent, start, stable_hash(desc))] = nid
        self._order[nid] = len(self._order)
        self._depth[nid] = 1 if parent is None else self.depth_of(parent) + 1
        return node

    def get_or_create(self, parent: Optional[str], start: int, desc: Dict[str, Any]) -> PlanNode:
        key = (parent, start, stable_hash(desc))
        nid = self._index.get(key)
        if nid is not None:
            return self.nodes[nid]
        return self._new_node(parent, start, desc)

    def node(self, node_id: str) -> PlanNode:
        return self.nodes[node_id]

    def parent_of(self, node: PlanNode) -> Optional[PlanNode]:
        return self.nodes[node.parent] if node.parent is not None else None

    def path_to_root(self, node_id: str) -> List[PlanNode]:
        """Nodes from root to ``node_id`` inclusive."""
        out = []
        cur: Optional[str] = node_id
        while cur is not None:
            n = self.nodes[cur]
            out.append(n)
            cur = n.parent
        return list(reversed(out))

    def path_key(self, node_id: str) -> str:
        """Content hash identifying the value trajectory of a node's path.

        Checkpoints are addressed by (path_key, step): any two trials whose
        hp values coincide up to ``step`` share the path and therefore the
        checkpoint — across studies too.  A node's path is immutable, so the
        key is computed once (O(depth)) and cached forever.
        """
        key = self._path_keys.get(node_id)
        if key is None:
            path = [(n.start, n.desc) for n in self.path_to_root(node_id)]
            key = stable_hash({"plan_key": self.key, "path": path})
            self._path_keys[node_id] = key
        return key

    def static_hash(self, node_id: str) -> str:
        """Content hash of a node's static hps.  Descriptors are immutable,
        so the hash is computed once and cached — the sibling-grouping pass
        reads it every scheduling round."""
        h = self._static_hashes.get(node_id)
        if h is None:
            h = stable_hash(self.nodes[node_id].desc.get("static") or {})
            self._static_hashes[node_id] = h
        return h

    def depth_of(self, node_id: str) -> int:
        """Path length root→node (cached; equals len(path_to_root))."""
        d = self._depth.get(node_id)
        if d is None:
            n = self.nodes[node_id]
            d = 1 if n.parent is None else self.depth_of(n.parent) + 1
            self._depth[node_id] = d
        return d

    # ------------------------------------------------------------ insertion
    def submit(self, trial: Trial, upto: Optional[int] = None,
               study: Optional[str] = None) -> Tuple[PlanNode, int, bool]:
        """Insert (or match) a trial's prefix up to ``upto`` steps and record
        a request.  Returns (leaf node, step, satisfied) where satisfied is
        True iff metrics for that exact step are already present (§3.2 "in
        case metrics and checkpoints ... already present, a response is
        returned immediately")."""
        step = trial.total_steps if upto is None else min(upto, trial.total_steps)
        segs = trial.segments(step)
        parent: Optional[str] = None
        node: Optional[PlanNode] = None
        for seg in segs:
            node = self.get_or_create(parent, seg.start, seg.desc)
            if trial.trial_id not in node.trials:
                node.trials.add(trial.trial_id)
                node.refcount += 1
            parent = node.node_id
        assert node is not None, "trial with zero steps"
        self.trial_paths.setdefault(trial.trial_id, [])
        path_ids = [n.node_id for n in self.path_to_root(node.node_id)]
        self.trial_paths[trial.trial_id] = path_ids
        if study is not None:
            self.trial_studies.setdefault(trial.trial_id, set()).add(study)
        self._touch()  # new nodes / requests invalidate cached stage trees
        if step in node.metrics:
            return node, step, True
        node.requests.add(step)
        self._refresh_pending(node, step)
        return node, step, False

    # ------------------------------------------------------------- requests
    def pending_requests(self) -> List[Request]:
        """Requests with no metrics yet and not currently running.

        Served from the maintained index — O(pending), not O(plan) — in the
        same (node creation, step) order the full scan produces.
        """
        out = []
        for nid in sorted(self._pending, key=self._order.__getitem__):
            for s in sorted(self._pending[nid]):
                out.append(Request(nid, s))
        return out

    def pending_requests_scan(self) -> List[Request]:
        """Reference full scan of every node (the pre-index implementation).
        Kept for equivalence tests and control-plane benchmarks."""
        out = []
        for n in self.nodes.values():
            for s in sorted(n.requests):
                if s in n.metrics or s in n.running:
                    continue
                out.append(Request(n.node_id, s))
        return out

    def mark_running(self, reqs: Iterable[Request]) -> None:
        for r in reqs:
            n = self.nodes[r.node_id]
            n.running.add(r.step)
            self._refresh_pending(n, r.step)
            self._touch(r.node_id)

    def clear_running(self, reqs: Iterable[Request]) -> None:
        for r in reqs:
            n = self.nodes[r.node_id]
            n.running.discard(r.step)
            self._refresh_pending(n, r.step)
            self._touch(r.node_id)

    def drop_request(self, node_id: str, step: int) -> None:
        """Withdraw a pending request (kill path) — index-safe removal."""
        n = self.nodes[node_id]
        n.requests.discard(step)
        self._refresh_pending(n, step)
        self._touch()

    def is_satisfied(self, node_id: str, step: int) -> bool:
        return step in self.nodes[node_id].metrics

    # ------------------------------------------------------------ aggregation
    def record_result(self, node_id: str, step: int, ckpt: Optional[str],
                      metrics: Optional[Dict[str, float]]) -> None:
        n = self.nodes[node_id]
        if ckpt is not None:
            n.ckpts[step] = ckpt
        if metrics is not None:
            n.metrics[step] = dict(metrics)
        n.running.discard(step)
        self._refresh_pending(n, step)
        self._touch(node_id)

    def record_profile(self, node_id: str, seconds_per_step: float) -> None:
        n = self.nodes[node_id]
        if n.profile is None:
            n.profile = seconds_per_step
        else:  # exponential moving average keeps the estimate current
            n.profile = 0.7 * n.profile + 0.3 * seconds_per_step

    def profile_of(self, node_id: str) -> float:
        p = self.nodes[node_id].profile
        return self.default_profile if p is None else p

    # -------------------------------------------------------------- ckpt GC
    def release_trial(self, trial_id: str) -> List[str]:
        """Drop a trial's references; return node ids whose refcount hit 0
        (their checkpoints are GC candidates — beyond-paper eviction)."""
        dead = []
        for nid in self.trial_paths.pop(trial_id, []):
            n = self.nodes[nid]
            if trial_id in n.trials:
                n.trials.discard(trial_id)
                n.refcount -= 1
                if n.refcount <= 0:
                    dead.append(nid)
        self.trial_studies.pop(trial_id, None)
        return dead

    def evict_ckpts(self, node_id: str) -> List[str]:
        """Forget a node's checkpoints (store eviction upstream); returns the
        checkpoint ids so the caller can drop them from the store.  Logged as
        a resolution-relevant change: Algorithm 1 must stop resuming here."""
        n = self.nodes[node_id]
        cids = list(n.ckpts.values())
        if cids:
            n.ckpts.clear()
            self._touch(node_id)
        return cids

    def forget_ckpt(self, node_id: str, step: int) -> Optional[str]:
        """Drop a single checkpoint entry whose blob vanished from the store
        (external eviction, discovered by the dispatcher at load time):
        Algorithm 1 must stop resuming there so the request re-derives from
        whatever remains — an earlier checkpoint, an ancestor, or a fresh
        model.  Returns the forgotten checkpoint id (None if absent)."""
        n = self.nodes[node_id]
        cid = n.ckpts.pop(step, None)
        if cid is not None:
            self._touch(node_id)
        return cid

    def detach_study(self, trial_id: str, study: str) -> None:
        """Remove one study's attribution from a trial (service-plane
        cancel).  The trial itself survives if other studies submitted it;
        fair-share and per-study accounting stop crediting the detached
        study from here on."""
        studies = self.trial_studies.get(trial_id)
        if studies is not None:
            studies.discard(study)
            if not studies:
                del self.trial_studies[trial_id]

    def studies_of_trial(self, trial_id: str) -> Set[str]:
        return self.trial_studies.get(trial_id, set())

    # ------------------------------------------------------------- metrics
    def metrics_for(self, node_id: str, step: int) -> Optional[Dict[str, float]]:
        return self.nodes[node_id].metrics.get(step)

    # ---------------------------------------------------------------- stats
    def total_requested_steps(self) -> int:
        """Sum over trials of their max requested step (trial-based cost)."""
        total = 0
        for tid, path in self.trial_paths.items():
            leaf = self.nodes[path[-1]]
            reqs = [s for s in leaf.requests | set(leaf.metrics)]
            total += max(reqs) if reqs else 0
        return total

    def to_json(self) -> Dict[str, Any]:
        return {
            "key": self.key,
            "counter": self._counter,
            "nodes": {nid: n.to_json() for nid, n in self.nodes.items()},
            "trial_paths": self.trial_paths,
            "default_profile": self.default_profile,
            "trial_studies": {t: sorted(s) for t, s in self.trial_studies.items()},
        }

    @classmethod
    def from_json(cls, d: Dict[str, Any]) -> "SearchPlan":
        plan = cls(d["key"])
        plan._counter = d["counter"]
        plan.default_profile = d.get("default_profile", 1.0)
        for nid, nd in d["nodes"].items():
            node = PlanNode.from_json(nd)
            plan.nodes[nid] = node
            plan.children.setdefault(node.parent, []).append(nid)
            plan.children.setdefault(nid, [])
            plan._index[(node.parent, node.start, stable_hash(node.desc))] = nid
            plan._order[nid] = len(plan._order)
            for s in node.requests:
                plan._refresh_pending(node, s)
        plan.trial_paths = {k: list(v) for k, v in d["trial_paths"].items()}
        plan.trial_studies = {t: set(s)
                              for t, s in d.get("trial_studies", {}).items()}
        plan._touch()
        return plan
