"""Trials and their canonical segment decomposition (Hippo §3.1).

A *trial* is a pair ``(hp_config, total_steps)`` — exactly the "trial
request" of §4.1: "a pair of a hyper-parameter sequence configuration and
the number of training steps".

A trial is canonically decomposed into *segments*: maximal step intervals
on which every hyper-parameter function stays within a single functional
piece.  Segment descriptors are offset-normalized (see
``HpFunction.piece_descriptor``) so that two trials produce *equal
descriptors* on a step range iff their hyper-parameter values coincide
there structurally — this is the prefix-matching relation the search plan
uses to merge trials into shared nodes.
"""

from __future__ import annotations

from dataclasses import dataclass, field
from typing import Any, Dict, List, Optional

from repro.core.hpseq import HpConfig
from repro.utils import stable_hash

__all__ = ["Segment", "Trial"]


@dataclass(frozen=True)
class Segment:
    """A maximal step interval of a trial under one functional piece."""

    start: int
    stop: int
    desc: Any  # canonical descriptor: {"hps": {...}, "static": {...}}

    @property
    def steps(self) -> int:
        return self.stop - self.start

    def desc_hash(self) -> str:
        return stable_hash(self.desc)


@dataclass
class Trial:
    """A trial request: hyper-parameter sequences + total training steps.

    ``eval_steps`` optionally lists intermediate steps at which the trial
    wants metrics reported (tuner rungs add these dynamically as separate
    requests instead).
    """

    hp_config: HpConfig
    total_steps: int
    trial_id: Optional[str] = None
    meta: Dict[str, Any] = field(default_factory=dict)

    def __post_init__(self):
        if self.trial_id is None:
            self.trial_id = "trial-" + stable_hash(
                {"hp": self.hp_config.to_json(), "steps": self.total_steps})[:12]

    # -------------------------------------------------------------- segments
    def segments(self, upto: Optional[int] = None) -> List[Segment]:
        """Canonical decomposition of [0, upto) into functional segments."""
        total = self.total_steps if upto is None else min(upto, self.total_steps)
        cuts = [0] + self.hp_config.boundaries(total) + [total]
        segs: List[Segment] = []
        for a, b in zip(cuts[:-1], cuts[1:]):
            if b <= a:
                continue
            desc = {
                "hps": {k: fn.piece_descriptor(a, b)
                        for k, fn in self.hp_config.fns.items()},
                "static": self.hp_config.static,
            }
            segs.append(Segment(a, b, desc))
        return segs

    # ------------------------------------------------------------- hp values
    def hp_at(self, step: int) -> Dict[str, Any]:
        return self.hp_config.values_dict(step)

    def to_json(self):
        return {"trial_id": self.trial_id,
                "hp_config": self.hp_config.to_json(),
                "total_steps": self.total_steps,
                "meta": self.meta}

    @classmethod
    def from_json(cls, d) -> "Trial":
        return cls(HpConfig.from_json(d["hp_config"]), d["total_steps"],
                   trial_id=d.get("trial_id"), meta=d.get("meta") or {})

    def __repr__(self):
        return f"Trial({self.trial_id}, steps={self.total_steps})"
