"""Study — the user-facing client facade (Hippo §5.2, Figure 11).

A study binds (model, dataset, hp-set) to a search plan in the DB and runs
tuners against it through an execution engine.  Multiple studies created
with the same key share a plan — submitting them to one engine yields the
paper's multi-study merging (§6.2).

Typical use (mirrors Figure 11)::

    db = SearchPlanDB()
    study = Study.create(db, model="resnet56", dataset="cifar10",
                         hp_set=("lr", "bs"))
    tuner = SHATuner(space.trials(120), min_steps=15, max_steps=120, eta=4)
    stats = study.run(tuner, backend=SimulatedTrainer(), n_workers=40)
"""

from __future__ import annotations

from typing import List, Optional, Sequence, Tuple, Union

from repro.core.db import SearchPlanDB, study_key
from repro.core.engine import EngineStats, ExecutionEngine, Tuner
from repro.core.scheduler import (CriticalPathScheduler, SchedulingPolicy,
                                  make_policy)
from repro.core.trainer import TrainerBackend
from repro.train.checkpoint import CheckpointStore

__all__ = ["Study", "run_studies"]


class Study:
    def __init__(self, db: SearchPlanDB, key: str, name: str = ""):
        self.db = db
        self.key = key
        self.name = name or key

    @classmethod
    def create(cls, db: SearchPlanDB, model: str, dataset: str,
               hp_set: Sequence[str], name: str = "") -> "Study":
        return cls(db, study_key(model, dataset, tuple(hp_set)),
                   name or f"{model}/{dataset}")

    def engine(self, backend: TrainerBackend, n_workers: int = 4,
               gpus_per_worker: int = 1, share: bool = True,
               weighted_paths: bool = False,
               policy: Union[str, SchedulingPolicy, None] = None,
               store: Optional[CheckpointStore] = None,
               max_steps_per_chain: Optional[int] = None,
               batch_siblings: Optional[bool] = None,
               chain_fusion: Optional[bool] = None) -> ExecutionEngine:
        """``policy`` selects the scheduling policy by name ("critical_path",
        "weighted_fanout", "fifo", "fair_share") or instance; the legacy
        ``weighted_paths`` flag is kept as a shorthand for the default.
        ``batch_siblings`` forces sibling-trial batching on/off and
        ``chain_fusion`` forces chain-fused execution (device-resident
        carries + write-behind boundary checkpoints) on/off (defaults:
        whatever the backend supports)."""
        if policy is not None and weighted_paths:
            raise ValueError(
                "pass either policy=... or the legacy weighted_paths=True "
                "(= policy='weighted_fanout'), not both")
        if policy is None:
            scheduler: SchedulingPolicy = CriticalPathScheduler(
                weighted=weighted_paths)
        elif isinstance(policy, str):
            scheduler = make_policy(policy)
        else:
            scheduler = policy
        return ExecutionEngine(
            self.db.get(self.key), backend, n_workers=n_workers,
            gpus_per_worker=gpus_per_worker,
            scheduler=scheduler,
            store=store, share=share,
            max_steps_per_chain=max_steps_per_chain,
            batch_siblings=batch_siblings, chain_fusion=chain_fusion)

    def run(self, tuner: Tuner, backend: TrainerBackend, n_workers: int = 4,
            **kw) -> EngineStats:
        eng = self.engine(backend, n_workers=n_workers, **kw)
        stats = eng.run([tuner])
        self.db.checkpoint(self.key)
        return stats


def run_studies(studies: List[Tuple[Study, Tuner]], backend: TrainerBackend,
                n_workers: int = 4, share: bool = True,
                **kw) -> EngineStats:
    """Run several studies concurrently on one engine (multi-study, §6.2).

    All studies must share the same key (same model/dataset/hp-set) — the
    paper's setting; their trials merge into one plan.
    """
    keys = {s.key for s, _ in studies}
    assert len(keys) == 1, "multi-study merging requires a common study key"
    study0 = studies[0][0]
    eng = study0.engine(backend, n_workers=n_workers, share=share, **kw)
    stats = eng.run([t for _, t in studies])
    study0.db.checkpoint(study0.key)
    return stats
