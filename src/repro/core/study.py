"""Study client API — specs, long-lived service sessions, futures.

Hippo §5.2's client facade, redesigned around the multi-study scenario of
§6.2: studies over the same (model, dataset, hp-set) arrive **over time**
and merge into one live stage forest.  The :class:`StudyService` is the
long-lived session a production deployment keeps open under continuous
traffic (PipeTune-style dynamic job arrival); :class:`Study.run` /
:func:`run_studies` remain as thin wrappers over a one-shot session, so
the batch world keeps working unchanged.

Typical service use::

    db = SearchPlanDB()
    svc = StudyService(db, SimulatedTrainer(), n_workers=40)
    spec = StudySpec("resnet56", "cifar10", ("lr", "bs"))
    fut1 = svc.submit(spec, SHATuner(space.trials(120), 15, 120, eta=4))
    fut2 = svc.submit(spec, GridTuner(more_trials), at=3600.0)  # arrives later
    fut1.result()                 # drive until study 1 finishes
    svc.snapshot("session.pkl")   # durable point-in-time session state
    stats = svc.close()           # drain everything, flush, stamp end-to-end
    print(stats.by_study)

A study submitted while others are in flight is admitted as an event on
the virtual clock: the dispatcher wakes, its requests merge into the live
stage forest, and anything the plan already holds answers instantly
(``StudyStats.instant_results``).  ``snapshot()`` /
:meth:`StudyService.restore` persist and revive the whole session — plan
revisions, event heap, waiter table, per-study accounting, committed
checkpoint index — so a killed service resumes without recomputation
beyond write-behind puts that had not committed by the snapshot (see
:mod:`repro.core.engine.session` for the format).

Legacy one-shot use (mirrors the paper's Figure 11)::

    study = Study.create(db, model="resnet56", dataset="cifar10",
                         hp_set=("lr", "bs"))
    stats = study.run(tuner, backend=SimulatedTrainer(), n_workers=40)
"""

from __future__ import annotations

from dataclasses import dataclass, field
from typing import List, Optional, Sequence, Tuple, Union

from repro.core.db import SearchPlanDB, study_key
from repro.core.engine import (EngineStats, ExecutionEngine, StudyStats,
                               Tuner)
from repro.core.engine.session import (capture_session, load_latest_session,
                                       load_session, restore_engine,
                                       save_session, save_session_rotated)
from repro.core.scheduler import (CriticalPathScheduler, SchedulingPolicy,
                                  make_policy)
from repro.core.trainer import TrainerBackend
from repro.train.checkpoint import CheckpointStore

__all__ = ["Study", "StudySpec", "StudyFuture", "StudyService",
           "PlanKeyMismatch", "run_studies"]


class PlanKeyMismatch(ValueError):
    """A study was submitted to a session driving a different plan key.

    Structured (it carries both keys) so a router — the front-door
    :class:`~repro.frontdoor.gateway.StudyGateway` — can catch it and
    re-route the submission to the right per-key session instead of
    string-matching an error message.  Subclasses ``ValueError`` for
    backward compatibility with callers that caught the old bare error.
    """

    def __init__(self, session_key: str, submitted_key: str):
        self.session_key = session_key
        self.submitted_key = submitted_key
        super().__init__(
            f"study key {submitted_key!r} differs from this session's "
            f"{session_key!r} — one StudyService drives one stage forest "
            "(same model/dataset/hp-set); start another service for a "
            "different key")


def _resolve_policy(policy: Union[str, SchedulingPolicy, None],
                    weighted_paths: bool) -> SchedulingPolicy:
    """Shared policy resolution for Study.engine and StudyService."""
    if policy is not None and weighted_paths:
        raise ValueError(
            "pass either policy=... or the legacy weighted_paths=True "
            "(= policy='weighted_fanout'), not both")
    if policy is None:
        return CriticalPathScheduler(weighted=weighted_paths)
    if isinstance(policy, str):
        return make_policy(policy)
    return policy


@dataclass(frozen=True)
class StudySpec:
    """Declarative study identity: what is being tuned, not how.

    Two specs with the same (model, dataset, hp-set) map to the same
    search-plan key — submitting them to one service merges their trials
    into one stage forest (§6.2).  ``name`` is display-only.
    """

    model: str
    dataset: str
    hp_set: Tuple[str, ...]
    name: str = ""

    def __post_init__(self):
        object.__setattr__(self, "hp_set", tuple(self.hp_set))

    @property
    def key(self) -> str:
        return study_key(self.model, self.dataset, self.hp_set)

    @property
    def display_name(self) -> str:
        return self.name or f"{self.model}/{self.dataset}"


class Study:
    def __init__(self, db: SearchPlanDB, key: str, name: str = ""):
        self.db = db
        self.key = key
        self.name = name or key

    @classmethod
    def create(cls, db: SearchPlanDB, model: str, dataset: str,
               hp_set: Sequence[str], name: str = "") -> "Study":
        return cls(db, study_key(model, dataset, tuple(hp_set)),
                   name or f"{model}/{dataset}")

    @classmethod
    def from_spec(cls, db: SearchPlanDB, spec: StudySpec) -> "Study":
        return cls(db, spec.key, spec.display_name)

    def engine(self, backend: TrainerBackend, n_workers: int = 4,
               gpus_per_worker: int = 1, share: bool = True,
               weighted_paths: bool = False,
               policy: Union[str, SchedulingPolicy, None] = None,
               store: Optional[CheckpointStore] = None,
               max_steps_per_chain: Optional[int] = None,
               batch_siblings: Optional[bool] = None,
               chain_fusion: Optional[bool] = None,
               worker_meshes: Optional[Sequence] = None,
               fault_injector=None) -> ExecutionEngine:
        """``policy`` selects the scheduling policy by name ("critical_path",
        "weighted_fanout", "fifo", "fair_share") or instance; the legacy
        ``weighted_paths`` flag is kept as a shorthand for the default.
        ``batch_siblings`` forces sibling-trial batching on/off and
        ``chain_fusion`` forces chain-fused execution (device-resident
        carries + write-behind boundary checkpoints) on/off (defaults:
        whatever the backend supports).  ``worker_meshes`` gives workers
        device sets (:class:`repro.dist.meshes.WorkerMesh`; None entries =
        thread workers).  ``fault_injector`` (a
        :class:`repro.core.faults.FaultInjector`) wraps the backend and
        store in the deterministic fault plane."""
        return ExecutionEngine(
            self.db.get(self.key), backend, n_workers=n_workers,
            gpus_per_worker=gpus_per_worker,
            scheduler=_resolve_policy(policy, weighted_paths),
            store=store, share=share,
            max_steps_per_chain=max_steps_per_chain,
            batch_siblings=batch_siblings, chain_fusion=chain_fusion,
            worker_meshes=worker_meshes, fault_injector=fault_injector)

    def run(self, tuner: Tuner, backend: TrainerBackend, n_workers: int = 4,
            **kw) -> EngineStats:
        """One-shot wrapper over a :class:`StudyService` session."""
        svc = StudyService(self.db, backend, n_workers=n_workers, **kw)
        svc.submit(self, tuner)
        return svc.close()


# ---------------------------------------------------------------------------
# Service plane
# ---------------------------------------------------------------------------


@dataclass
class StudyFuture:
    """Handle on one submitted study's progress within a service session.

    Life cycle: ``queued`` (admission scheduled on the virtual clock) →
    ``running`` (tuner started, merged into the stage forest) → ``done``
    (tuner reports complete) or ``cancelled`` (detached; nodes no other
    study references released into checkpoint GC).
    """

    service: "StudyService"
    study_id: str
    plan_key: str
    tuner: Tuner
    arrival: float
    status: str = "queued"

    # ------------------------------------------------------------ inspection
    def done(self) -> bool:
        return self.status == "done"

    def cancelled(self) -> bool:
        return self.status == "cancelled"

    @property
    def stats(self) -> StudyStats:
        """Per-study accounting slice (live — updates as the session runs)."""
        return self.service.stats.study(self.study_id)

    # --------------------------------------------------------------- control
    def result(self) -> StudyStats:
        """Drive the session until this study completes; returns its stats
        slice (the tuner's best trial lives on ``self.tuner``)."""
        while self.status in ("queued", "running") and self.service.step():
            pass
        if self.status == "cancelled":
            raise RuntimeError(f"study {self.study_id!r} was cancelled")
        if self.status != "done":
            raise RuntimeError(
                f"service quiescent but study {self.study_id!r} is not done "
                "— its tuner waits on a request that was never submitted")
        return self.stats

    def cancel(self) -> bool:
        """Detach the study mid-run (False if it already finished): its
        waiters are dropped and every trial no other live study shares is
        killed — releasing plan nodes into checkpoint GC."""
        if self.status in ("done", "cancelled"):
            return self.status == "cancelled"
        self.status = "cancelled"
        self.service._engine.cancel_study(self.study_id)
        return True

    def __getstate__(self):
        # snapshots re-wire the owning service on restore
        d = self.__dict__.copy()
        d["service"] = None
        return d


class StudyService:
    """A long-lived engine session serving studies as they arrive.

    One service drives ONE stage forest (one search-plan key): every
    submitted study must share the same (model, dataset, hp-set) — the
    paper's multi-study setting.  A different key raises; run a second
    service for it.  The session is single-threaded and deterministic:
    callers drive it via :meth:`step` / :meth:`run_until` /
    ``future.result()`` / :meth:`join`, and late submissions are admission
    *events* on the virtual clock, so arrival order is replayable.

    ``snapshot()`` persists the complete session; :meth:`restore` revives
    it against a fresh backend/store and continues the identical event
    stream.
    """

    def __init__(self, db: SearchPlanDB, backend: TrainerBackend,
                 n_workers: int = 4, gpus_per_worker: int = 1,
                 share: bool = True, weighted_paths: bool = False,
                 policy: Union[str, SchedulingPolicy, None] = None,
                 store: Optional[CheckpointStore] = None,
                 max_steps_per_chain: Optional[int] = None,
                 batch_siblings: Optional[bool] = None,
                 chain_fusion: Optional[bool] = None,
                 worker_meshes: Optional[Sequence] = None,
                 fault_injector=None):
        self.db = db
        self.backend = backend
        self.n_workers = n_workers
        self.gpus_per_worker = gpus_per_worker
        self.share = share
        self.scheduler = _resolve_policy(policy, weighted_paths)
        self.store = store
        self.max_steps_per_chain = max_steps_per_chain
        self.batch_siblings = batch_siblings
        self.chain_fusion = chain_fusion
        self.worker_meshes = worker_meshes
        self.fault_injector = fault_injector
        self._engine: Optional[ExecutionEngine] = None
        self._key: Optional[str] = None
        self._futures: List[StudyFuture] = []
        self._closed = False
        # continuous durability (enable_auto_snapshot): (base, every, keep)
        self._auto_snapshot: Optional[Tuple[str, float, int]] = None
        self._next_snapshot_due: Optional[float] = None

    # ------------------------------------------------------------ properties
    @property
    def time(self) -> float:
        return self._engine.time if self._engine is not None else 0.0

    @property
    def stats(self) -> EngineStats:
        if self._engine is None:
            return EngineStats()
        return self._engine.stats

    @property
    def futures(self) -> List[StudyFuture]:
        return list(self._futures)

    @property
    def quiescent(self) -> bool:
        return self._engine is None or self._engine.quiescent

    @property
    def engine(self) -> Optional[ExecutionEngine]:
        """The live engine (None until the first submission) — the
        front-door lease manager grows/shrinks its worker fleet."""
        return self._engine

    @property
    def key(self) -> Optional[str]:
        """The plan key this session drives (None until first submit)."""
        return self._key

    # ------------------------------------------------------------- admission
    @staticmethod
    def _key_of(study: Union[StudySpec, Study, str]) -> str:
        if isinstance(study, StudySpec):
            return study.key
        if isinstance(study, Study):
            return study.key
        if isinstance(study, str):
            return study
        raise TypeError(
            f"submit() takes a StudySpec, Study or plan key, not {study!r}")

    def _ensure_engine(self, key: str) -> ExecutionEngine:
        if self._closed:
            raise RuntimeError("service is closed — create a new one")
        if self._engine is None:
            self._key = key
            self._engine = ExecutionEngine(
                self.db.get(key), self.backend, n_workers=self.n_workers,
                gpus_per_worker=self.gpus_per_worker,
                scheduler=self.scheduler, store=self.store, share=self.share,
                max_steps_per_chain=self.max_steps_per_chain,
                batch_siblings=self.batch_siblings,
                chain_fusion=self.chain_fusion,
                worker_meshes=self.worker_meshes,
                fault_injector=self.fault_injector)
        elif key != self._key:
            raise PlanKeyMismatch(self._key, key)
        return self._engine

    def submit(self, study: Union[StudySpec, Study, str], tuner: Tuner,
               study_id: Optional[str] = None,
               at: Optional[float] = None) -> StudyFuture:
        """Admit a study into the live session; returns its future.

        ``at`` schedules the arrival on the virtual clock (default: now).
        A study admitted while others are mid-flight merges into the
        in-flight stage forest — the admission event wakes the dispatcher;
        no fresh ``run()`` is needed, and results the plan already holds
        answer instantly."""
        eng = self._ensure_engine(self._key_of(study))
        taken = {f.study_id for f in self._futures}
        if study_id is None:
            n = len(self._futures)
            while f"study-{n}" in taken:   # skip explicitly-supplied ids
                n += 1
            sid = f"study-{n}"
        elif study_id in taken:
            raise ValueError(f"study id {study_id!r} already submitted")
        else:
            sid = study_id
        h = eng.admit(tuner, sid, at=at)
        fut = StudyFuture(self, sid, self._key, tuner,
                          arrival=at if at is not None else eng.time)
        self._futures.append(fut)
        return fut

    # ------------------------------------------------------------ the session
    def step(self) -> bool:
        """Advance the session by one event (False at quiescence)."""
        if self._engine is None or not self._engine.step():
            return False
        self._refresh_futures()
        self._maybe_auto_snapshot()
        return True

    def run_until(self, t: float) -> None:
        """Drive every event scheduled at or before virtual time ``t``."""
        while self._engine is not None:
            nxt = self._engine.events.peek()
            if nxt is None or nxt.time > t:
                break
            self.step()

    def join(self) -> EngineStats:
        """Drive the session to quiescence; every non-cancelled study must
        be done (otherwise a tuner waits on a request that was never
        submitted — the session is stuck, not slow)."""
        while self.step():
            pass
        stuck = [f.study_id for f in self._futures
                 if f.status in ("queued", "running")]
        if stuck:
            raise RuntimeError(
                f"service quiescent but studies not done: {stuck} — a tuner "
                "is waiting on a request that was never submitted")
        return self.stats

    def close(self) -> EngineStats:
        """Drain, then terminate: flush the write-behind store, stamp
        ``end_to_end``, journal the plan.  Flushing happens even when the
        drain errors (the durability barrier of ``ExecutionEngine.run``)."""
        try:
            self.join()
        finally:
            self._closed = True
            if self._engine is not None:
                self._engine.finish()
                self.db.checkpoint(self._key)
        return self.stats

    def __enter__(self) -> "StudyService":
        return self

    def __exit__(self, exc_type, exc, tb) -> None:
        if exc_type is None:
            if not self._closed:
                self.close()
        elif self._engine is not None:   # error exit: barrier, don't drain
            self._closed = True
            self._engine.finish()

    def _refresh_futures(self) -> None:
        eng = self._engine
        for fut in self._futures:
            if fut.status == "queued" and fut.study_id in eng._started:
                fut.status = "running"
            if fut.status == "running" and fut.tuner.is_done():
                fut.status = "done"

    # ----------------------------------------------------------- persistence
    def enable_auto_snapshot(self, base: str, every: float,
                             keep: int = 3) -> None:
        """Continuous durability: after the first event past each
        ``every`` virtual seconds, write an atomic rotated snapshot
        ``base.<seq>`` keeping the newest ``keep`` (see
        :func:`~repro.core.engine.session.save_session_rotated`).  With
        :meth:`restore_latest` on startup, a SIGKILL at any instant loses
        at most one interval of progress."""
        if every <= 0:
            raise ValueError(f"snapshot interval must be > 0, got {every}")
        self._auto_snapshot = (base, float(every), int(keep))
        self._next_snapshot_due = None   # first step() aligns to the clock

    def _maybe_auto_snapshot(self) -> None:
        if self._auto_snapshot is None or self._engine is None:
            return
        base, every, keep = self._auto_snapshot
        if self._next_snapshot_due is None:
            # align the schedule to interval boundaries so a restored
            # session continues the same cadence its snapshot recorded
            self._next_snapshot_due = (self.time // every + 1) * every
        if self.time < self._next_snapshot_due:
            return
        self.snapshot_rotated()
        while self._next_snapshot_due <= self.time:
            self._next_snapshot_due += every

    def snapshot_rotated(self) -> str:
        """One rotated snapshot now (the timer path calls this; callers
        may too, e.g. a graceful-shutdown handler).  Requires
        :meth:`enable_auto_snapshot`."""
        if self._auto_snapshot is None:
            raise RuntimeError("call enable_auto_snapshot(base, every) first")
        if self._engine is None:
            raise RuntimeError("nothing submitted yet — snapshot is empty")
        base, every, keep = self._auto_snapshot
        state = capture_session(
            self._engine, service={"futures": self._futures,
                                   "auto_snapshot": self._auto_snapshot})
        return save_session_rotated(state, base, keep=keep)

    def snapshot(self, path: str) -> str:
        """Persist the complete session (durable point-in-time state; see
        :mod:`repro.core.engine.session` for the format).  Flushes the
        write-behind store first, so everything the plan records is
        committed on disk/in the snapshot at the moment of capture."""
        if self._engine is None:
            raise RuntimeError("nothing submitted yet — snapshot is empty")
        state = capture_session(self._engine,
                                service={"futures": self._futures})
        return save_session(state, path)

    @classmethod
    def restore(cls, db: SearchPlanDB, path: str, backend: TrainerBackend,
                store: Optional[CheckpointStore] = None,
                fault_injector=None) -> "StudyService":
        """Revive a snapshotted session against a fresh backend/store.

        The restored session continues the exact event stream captured by
        :meth:`snapshot` — final stats (including the per-study breakdown)
        match an uninterrupted run.  Plan checkpoints the supplied store
        cannot serve (writes after the snapshot's flush barrier, external
        evictions) are forgotten eagerly and recomputed on demand.  Older
        snapshot formats (v2/v3) are migrated forward on the fly."""
        return cls._restore_state(db, load_session(path), backend, store,
                                  fault_injector)

    @classmethod
    def restore_latest(cls, db: SearchPlanDB, base: str,
                       backend: TrainerBackend,
                       store: Optional[CheckpointStore] = None,
                       fault_injector=None) -> "StudyService":
        """:meth:`restore` from the newest *readable* rotation slot of
        ``base`` (``enable_auto_snapshot``'s output), falling back through
        corrupt/truncated slots; re-enables the captured auto-snapshot
        cadence.  Raises ``FileNotFoundError`` when no slot is readable."""
        state, _ = load_latest_session(base)
        return cls._restore_state(db, state, backend, store, fault_injector)

    @classmethod
    def _restore_state(cls, db: SearchPlanDB, state, backend: TrainerBackend,
                       store: Optional[CheckpointStore],
                       fault_injector) -> "StudyService":
        from repro.core.engine.session import SessionState
        if not isinstance(state, SessionState):
            raise ValueError(
                "snapshot holds a gateway envelope (multiple sessions) — "
                "restore it with repro.frontdoor.StudyGateway.restore, not "
                "StudyService.restore")
        eng = restore_engine(state, backend, store,
                             fault_injector=fault_injector)
        db.put(state.plan_key, state.plan)
        svc = cls(db, backend, n_workers=state.n_workers,
                  gpus_per_worker=state.gpus_per_worker, share=state.share,
                  policy=state.scheduler, store=eng.store,
                  max_steps_per_chain=state.max_steps_per_chain,
                  batch_siblings=state.batch_siblings,
                  chain_fusion=state.chain_fusion,
                  worker_meshes=[row[3] for row in state.workers],
                  fault_injector=fault_injector)
        svc._engine = eng
        svc._key = state.plan_key
        svc._futures = list(state.service.get("futures", []))
        for fut in svc._futures:
            fut.service = svc
        auto = state.service.get("auto_snapshot")
        if auto:
            svc.enable_auto_snapshot(*auto)
        return svc


def run_studies(studies: List[Tuple[Study, Tuner]], backend: TrainerBackend,
                n_workers: int = 4, share: bool = True,
                **kw) -> EngineStats:
    """Run several studies concurrently on one session (multi-study, §6.2).

    All studies must share the same key (same model/dataset/hp-set) — the
    paper's setting; their trials merge into one plan.  A thin wrapper
    over an upfront-submission :class:`StudyService` session.
    """
    keys = {s.key for s, _ in studies}
    if len(keys) != 1:
        raise ValueError(
            "multi-study merging requires a common study key (same model/"
            f"dataset/hp-set); got {len(keys)} distinct keys — run separate "
            "studies, or a StudyService per key")
    svc = StudyService(studies[0][0].db, backend, n_workers=n_workers,
                       share=share, **kw)
    for st, tuner in studies:
        svc.submit(st, tuner)
    return svc.close()
