"""Pluggable stateless scheduling policies (Hippo §4.3, beyond-paper).

Every policy receives a transient stage tree, estimates each stage's
execution time as ``steps × profiled seconds-per-step`` (profile stored in
the search plan, §4.3), and extracts whole root-to-leaf *chains* ("batch of
stages") for idle workers — scheduling whole paths instead of single stages
avoids checkpoint save/load transitions.

The policies keep **no execution state about stages**: callers re-generate
a fresh stage tree from the search plan every scheduling round, and stages
already covered by running work simply never appear in the new tree (they
are deferred by Algorithm 1's running check).  ``FairShareScheduler`` does
carry *accounting* state (GPU-seconds charged per study) — that is policy
memory, not execution state, and the paper's stateless-stage-tree property
is untouched.

Policies:

* :class:`CriticalPathScheduler` — the paper's policy: repeatedly extract
  the root-to-leaf path with the longest remaining estimated time.
* :class:`WeightedFanoutScheduler` — beyond-paper: weight each path by the
  number of pending report-leaves it unblocks divided by its length; shared
  prefixes with high fan-out get scheduled first, improving end-to-end time
  at equal GPU-hours (see EXPERIMENTS.md §Perf).
* :class:`FIFOScheduler` — chains in stage-creation (= request arrival)
  order; the Ray-Tune-like baseline, useful to quantify what critical-path
  ordering buys.
* :class:`FairShareScheduler` — multi-study scenario (§6.2): prefer chains
  serving the study with the least GPU-time charged so far, so one study
  with many long trials cannot starve a small concurrent study.
"""

from __future__ import annotations

from typing import Any, Callable, Dict, List, Optional, Set

from repro.core.searchplan import SearchPlan
from repro.core.stagetree import Stage, StageTree

__all__ = ["SchedulingPolicy", "CriticalPathScheduler",
           "WeightedFanoutScheduler", "FIFOScheduler", "FairShareScheduler",
           "POLICIES", "make_policy"]


class SchedulingPolicy:
    """Interface the execution engine drives each scheduling round."""

    name = "base"

    def next_path(self, plan: SearchPlan, tree: StageTree,
                  taken: set) -> Optional[List[Stage]]:
        """The next chain of unscheduled stages, or None when exhausted.

        A chain starts at a stage whose parent is either absent or already
        taken and extends downward through children; implementations must
        add every returned stage id to ``taken``.
        """
        raise NotImplementedError

    def on_path_assigned(self, plan: SearchPlan, path: List[Stage]) -> None:
        """Hook invoked once per extracted chain (accounting policies)."""

    def on_stages_unassigned(self, plan: SearchPlan,
                             stages: List[Stage]) -> None:
        """Hook invoked by the dispatcher for extracted stages that did NOT
        execute this round (chain truncation, deferred input, a sibling
        group that fell apart, a vanished resume checkpoint) — accounting
        policies refund them here; they will be re-extracted later."""

    def on_round_start(self, plan: SearchPlan, tree: StageTree) -> None:
        """Hook invoked once per scheduling round before extraction
        (per-round caches of accounting policies)."""

    def placement_hint(self, plan: SearchPlan, chains: List[List[Stage]],
                       workers: List[Any]) -> str:
        """Which of the mesh-compatible idle ``workers`` should host this
        work unit (``chains``: one chain, or a sibling-chain group)?

        Returns ``"wide"`` (narrowest mesh — spend devices on batching
        more trials elsewhere), ``"deep"`` (widest mesh — spend devices
        on sharding this chain), or ``"any"`` (first compatible).  The
        default trades the two parallelism axes per unit: sibling groups
        already parallelize *across trials*, so they take the narrowest
        compatible worker, while solo chains take the widest mesh and
        parallelize *within the model*.  With a homogeneous fleet every
        hint degenerates to the first idle worker."""
        return "wide" if len(chains) > 1 else "deep"

    def assign(self, plan: SearchPlan, tree: StageTree, n_paths: int,
               taken: Optional[set] = None) -> List[List[Stage]]:
        """Extract up to ``n_paths`` disjoint chains for idle workers.

        ``taken`` pre-seeds stages the dispatcher already placed this round
        (batched sibling groups): they are never re-extracted, and their
        children qualify as chain heads — chaining off the in-round states
        the groups produce."""
        taken = set() if taken is None else taken
        self.on_round_start(plan, tree)
        out = []
        for _ in range(n_paths):
            p = self.next_path(plan, tree, taken)
            if p is None:
                break
            self.on_path_assigned(plan, p)
            out.append(p)
        return out

    # ------------------------------------------------------------- estimates
    def stage_time(self, plan: SearchPlan, stage: Stage) -> float:
        return stage.steps * plan.profile_of(stage.node_id)


class CriticalPathScheduler(SchedulingPolicy):
    """The paper's critical-path extraction (§4.3).

    ``weighted=True`` is a compatibility alias for
    :class:`WeightedFanoutScheduler` priorities.
    """

    name = "critical_path"

    def __init__(self, weighted: bool = False):
        self.weighted = weighted

    # ------------------------------------------------------------ scheduling
    def _head_priority(self, stage: Stage, remaining: Dict[str, float],
                       fanout: Dict[str, int]):
        """Priority of a candidate chain head; subclass hook."""
        t = remaining[stage.stage_id]
        if self.weighted:
            return fanout[stage.stage_id] / max(t, 1e-9)
        return t

    def next_path(self, plan: SearchPlan, tree: StageTree,
                  taken: set) -> Optional[List[Stage]]:
        """The highest-priority maximal chain of unscheduled stages.

        A chain starts at a stage whose parent is either absent or already
        taken, and extends through the child subtree maximizing remaining
        time (critical path).  Returns None when every stage is taken.
        """
        # remaining[s] = est time of the heaviest downward path from s
        remaining: Dict[str, float] = {}
        fanout: Dict[str, int] = {}

        def walk(sid: str) -> float:
            st = tree.stages[sid]
            best_child = 0.0
            fo = 1 if st.report else 0
            for c in st.children:
                best_child = max(best_child, walk(c))
                fo += fanout[c]
            t = (0.0 if sid in taken else self.stage_time(plan, st)) + best_child
            remaining[sid] = t
            fanout[sid] = fo
            return t

        for r in tree.roots:
            walk(r)

        # candidate chain heads: unscheduled stages whose parent is taken/None
        heads = [
            s for s in tree.stages.values()
            if s.stage_id not in taken
            and (s.parent is None or s.parent in taken)
        ]
        if not heads:
            return None

        head = max(heads, key=lambda s: self._head_priority(s, remaining,
                                                            fanout))

        # extend the chain downward along the heaviest child
        path, cur = [], head
        while True:
            path.append(cur)
            taken.add(cur.stage_id)
            nxt = None
            for c in cur.children:
                if c in taken:
                    continue
                if nxt is None or remaining[c] > remaining[nxt.stage_id]:
                    nxt = tree.stages[c]
            if nxt is None:
                return path
            cur = nxt


class WeightedFanoutScheduler(CriticalPathScheduler):
    """Fan-out-per-second priority: unblock many report leaves early."""

    name = "weighted_fanout"

    def __init__(self):
        super().__init__(weighted=True)


class FIFOScheduler(SchedulingPolicy):
    """Chains in stage-creation order — request arrival order, since stage
    numbering follows pending-request order.  No time estimates used."""

    name = "fifo"

    def next_path(self, plan: SearchPlan, tree: StageTree,
                  taken: set) -> Optional[List[Stage]]:
        head = next(
            (s for s in tree.stages.values()
             if s.stage_id not in taken
             and (s.parent is None or s.parent in taken)), None)
        if head is None:
            return None
        path, cur = [], head
        while True:
            path.append(cur)
            taken.add(cur.stage_id)
            nxt = next((c for c in cur.children if c not in taken), None)
            if nxt is None:
                return path
            cur = tree.stages[nxt]


class FairShareScheduler(CriticalPathScheduler):
    """Per-study fair share for concurrent studies on one plan (§6.2).

    Each extracted stage's estimated GPU-seconds are **split** across the
    studies whose trials it serves — a stage shared by k studies charges
    each of them 1/k, so reuse shows up as every sharing study paying
    less, and a study that merges heavily cannot be priced out of the
    cluster by costs it never caused.  Candidate heads are ranked by the
    *least-served* study they would serve, with critical-path remaining
    time as tie-break.  Stages the dispatcher could not actually run this
    round (truncated tails, deferred chains, collapsed sibling groups)
    are refunded via ``on_stages_unassigned`` with the same split, so
    rescheduling never double-charges.

    Tenant quotas (front door): :meth:`set_study_weights` assigns each
    study a fair-share *weight* — ranking divides charged usage by it, so
    a study with weight 2 is served as if it had paid half, i.e. receives
    twice the share before the policy considers it "served".  The
    :class:`~repro.frontdoor.gateway.StudyGateway` maps per-tenant quota
    weights onto the study ids it admits.  The default weight is 1.0, so
    sessions without a front door schedule exactly as before.
    """

    name = "fair_share"

    def __init__(self):
        super().__init__()
        self.usage: Dict[str, float] = {}   # study id -> charged GPU-seconds
        self.weights: Dict[str, float] = {}  # study id -> fair-share weight
        self._plan_studies: Dict[str, frozenset] = {}

    def set_study_weights(self, weights: Dict[str, float]) -> None:
        """Assign fair-share weights (> 0) per study id; missing studies
        keep weight 1.0.  Snapshot-safe: the policy object is captured
        whole, so restored sessions keep their quota weights."""
        if not hasattr(self, "weights"):   # unpickled from a v4 snapshot
            self.weights = {}
        for sid, w in weights.items():
            if w <= 0:
                raise ValueError(f"fair-share weight for {sid!r} must be "
                                 f"> 0, got {w}")
            self.weights[sid] = float(w)

    def _weighted_usage(self, study: str) -> float:
        # getattr: policy objects unpickled from pre-weight snapshots
        # have no ``weights`` dict — they keep the default weight 1.0
        weights = getattr(self, "weights", None) or {}
        return self.usage.get(study, 0.0) / weights.get(study, 1.0)

    def _studies_of(self, plan: SearchPlan, stage: Stage) -> Set[str]:
        studies: Set[str] = set()
        for tid in plan.node(stage.node_id).trials:
            studies |= plan.studies_of_trial(tid)
        return studies

    def _head_priority(self, stage, remaining, fanout):
        studies = self._plan_studies.get(stage.stage_id, frozenset())
        if studies:
            least = min(self._weighted_usage(s) for s in studies)
        else:
            # no study attribution (submit() without study=): rank as the
            # most-served so unattributed work never starves real studies
            least = max(self.usage.values(), default=0.0)
        # smaller charged usage → higher priority; remaining time tie-break
        return (-least, remaining[stage.stage_id])

    def on_round_start(self, plan, tree):
        # cache stage → studies once per round; every extraction on the same
        # tree reuses it (rebuilt each round even when the dispatcher seeds
        # ``taken`` with batched groups)
        self._plan_studies = {sid: frozenset(self._studies_of(plan, st))
                              for sid, st in tree.stages.items()}

    def _charge(self, plan: SearchPlan, stages: List[Stage],
                sign: float) -> None:
        for st in stages:
            studies = self._studies_of(plan, st)
            if not studies:
                continue
            # split-charge: a chain shared by k studies costs each 1/k —
            # refunds (sign=-1) recompute the same split, so a stage
            # charged and refunded within one round nets to exactly zero
            cost = sign * self.stage_time(plan, st) / len(studies)
            for s in studies:
                self.usage[s] = self.usage.get(s, 0.0) + cost

    def on_path_assigned(self, plan: SearchPlan, path: List[Stage]) -> None:
        self._charge(plan, path, 1.0)

    def on_stages_unassigned(self, plan: SearchPlan,
                             stages: List[Stage]) -> None:
        self._charge(plan, stages, -1.0)


POLICIES: Dict[str, Callable[[], SchedulingPolicy]] = {
    "critical_path": CriticalPathScheduler,
    "weighted_fanout": WeightedFanoutScheduler,
    "fifo": FIFOScheduler,
    "fair_share": FairShareScheduler,
}


def make_policy(name: str) -> SchedulingPolicy:
    try:
        return POLICIES[name]()
    except KeyError:
        raise ValueError(
            f"unknown scheduling policy {name!r}; one of {sorted(POLICIES)}")
