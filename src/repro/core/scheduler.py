"""Stateless critical-path scheduler (Hippo §4.3).

The scheduler receives a transient stage tree, estimates each stage's
execution time as ``steps × profiled seconds-per-step`` (profile stored in
the search plan, §4.3), and repeatedly extracts the *critical path* — the
root-to-leaf path with the longest remaining estimated time — assigning the
whole path to one idle worker.  Scheduling whole paths ("batch of stages")
instead of single stages avoids checkpoint save/load transitions and
prioritizes end-to-end completion time.

The scheduler keeps **no execution state**: callers re-generate a fresh
stage tree from the search plan every scheduling round, and stages already
covered by running work simply never appear in the new tree (they are
deferred by Algorithm 1's running check).

Beyond-paper option: ``weighted=True`` weights each path by the number of
pending report-leaves it unblocks, divided by its length — shared prefixes
with high fan-out get scheduled first, improving end-to-end time at equal
GPU-hours (see EXPERIMENTS.md §Perf).
"""

from __future__ import annotations

from typing import Dict, List, Optional

from repro.core.searchplan import SearchPlan
from repro.core.stagetree import Stage, StageTree

__all__ = ["CriticalPathScheduler"]


class CriticalPathScheduler:
    def __init__(self, weighted: bool = False):
        self.weighted = weighted

    # ------------------------------------------------------------- estimates
    def stage_time(self, plan: SearchPlan, stage: Stage) -> float:
        return stage.steps * plan.profile_of(stage.node_id)

    # ------------------------------------------------------------ scheduling
    def next_path(self, plan: SearchPlan, tree: StageTree,
                  taken: set) -> Optional[List[Stage]]:
        """The highest-priority maximal chain of unscheduled stages.

        A chain starts at a stage whose parent is either absent or already
        taken, and extends through the child subtree maximizing remaining
        time (critical path).  Returns None when every stage is taken.
        """
        # remaining[s] = est time of the heaviest downward path from s
        remaining: Dict[str, float] = {}
        fanout: Dict[str, int] = {}

        def walk(sid: str) -> float:
            st = tree.stages[sid]
            best_child = 0.0
            fo = 1 if st.report else 0
            for c in st.children:
                best_child = max(best_child, walk(c))
                fo += fanout[c]
            t = (0.0 if sid in taken else self.stage_time(plan, st)) + best_child
            remaining[sid] = t
            fanout[sid] = fo
            return t

        for r in tree.roots:
            walk(r)

        # candidate chain heads: unscheduled stages whose parent is taken/None
        heads = [
            s for s in tree.stages.values()
            if s.stage_id not in taken
            and (s.parent is None or s.parent in taken)
        ]
        if not heads:
            return None

        def priority(s: Stage) -> float:
            t = remaining[s.stage_id]
            if self.weighted:
                return fanout[s.stage_id] / max(t, 1e-9)
            return t

        head = max(heads, key=priority)

        # extend the chain downward along the heaviest child
        path, cur = [], head
        while True:
            path.append(cur)
            taken.add(cur.stage_id)
            nxt = None
            for c in cur.children:
                if c in taken:
                    continue
                if nxt is None or remaining[c] > remaining[nxt.stage_id]:
                    nxt = tree.stages[c]
            if nxt is None:
                return path
            cur = nxt

    def assign(self, plan: SearchPlan, tree: StageTree,
               n_paths: int) -> List[List[Stage]]:
        """Extract up to ``n_paths`` disjoint chains for idle workers."""
        taken: set = set()
        out = []
        for _ in range(n_paths):
            p = self.next_path(plan, tree, taken)
            if p is None:
                break
            out.append(p)
        return out
