"""Trainer backends — the worker-side training logic (Hippo §5.2, Figure 9).

The paper's users override a ``Trainer`` with ``setup(hp)`` (hot-update of
hyper-parameter values), ``train`` (one logical iteration), ``evaluate``,
``save`` and ``load``.  Here a backend executes whole *stages*: it receives
the stage's node descriptor (the canonical hyper-parameter piece), the step
range, and the state loaded from the resume checkpoint, and returns the new
state plus (optionally) evaluation metrics.

Backends:

* :class:`SimulatedTrainer` — a deterministic analytic response surface.
  Used by the discrete-event cluster simulator that reproduces the paper's
  GPU-hour / end-to-end numbers.  Crucially, its state is a pure function
  of the *hyper-parameter value trajectory* (never the trial id), so two
  trials sharing a prefix produce bit-identical states on the shared range
  — the same property real deterministic training has, and the premise of
  stage sharing.

* ``JaxTrainer`` (:mod:`repro.train.jax_trainer`) — real JAX training with
  per-step hyper-parameter arrays folded into whole-stage compiled chunk
  executables, plus batched execution of sibling-stage groups; used by the
  runnable examples and the losslessness tests.
"""

from __future__ import annotations

import copy
import math
from dataclasses import dataclass
from typing import Any, Dict, List, Optional, Sequence, Tuple

from repro.core.values import desc_static, desc_values

__all__ = ["TrainerBackend", "SimulatedTrainer", "StageContext"]


@dataclass(frozen=True)
class StageContext:
    """What a backend needs to execute one stage."""

    node_id: str
    desc: Dict[str, Any]      # canonical hp-piece descriptor of the node
    node_start: int           # global step where the node's config takes over
    start: int                # stage [start, stop)
    stop: int
    path_key: str             # content hash of the node's path (ckpt address)


class TrainerBackend:
    """Interface between the execution engine and the training substrate."""

    #: True when :meth:`run_stages_batched` executes a whole sibling group in
    #: one device call (the dispatcher then runs its grouping pass); the
    #: default sequential fallback keeps simulated/unfused backends correct
    #: without pretending they batch.
    supports_batched_stages: bool = False

    #: True when :meth:`run_chain` keeps the state carry on device across
    #: stage boundaries (no host round-trip between consecutive stages of a
    #: chain).  The dispatcher then executes whole scheduler-extracted
    #: chains through it and write-behinds the boundary checkpoints.
    supports_chain_fusion: bool = False

    def init_state(self) -> Any:
        """Fresh model state (step 0)."""
        raise NotImplementedError

    def run_stage(self, state: Any, ctx: StageContext) -> Any:
        """Train from ctx.start to ctx.stop under ctx.desc; return new state."""
        raise NotImplementedError

    def run_stages_batched(self, states: Sequence[Any],
                           ctxs: Sequence[StageContext]) -> List[Any]:
        """Execute a group of sibling stages — same ``[start, stop)``, same
        static hyper-parameters and batch shapes, divergent hp *values* —
        returning one new state per member.  Backends that can fuse the
        group into a single compiled call override this (and set
        ``supports_batched_stages``); the default runs members sequentially,
        which is always semantically equivalent."""
        return [self.run_stage(s, c) for s, c in zip(states, ctxs)]

    def run_chain(self, state: Any, ctxs: Sequence[StageContext]) -> List[Any]:
        """Execute a whole chain — consecutive stages, each starting where
        the previous stopped — returning the state at EVERY stage boundary
        (``len(ctxs)`` states; the dispatcher checkpoints each one and posts
        per-stage events, so the virtual clock keeps stage granularity).
        Backends that keep the carry on device across boundaries override
        this (and set ``supports_chain_fusion``); the default per-stage loop
        is always semantically equivalent."""
        out: List[Any] = []
        for ctx in ctxs:
            if ctx.stop > ctx.start:
                state = self.run_stage(state, ctx)
            out.append(state)
        return out

    def run_chains_batched(self, states: Sequence[Any],
                           chains: Sequence[Sequence[StageContext]]
                           ) -> List[List[Any]]:
        """Execute a group of parallel sibling *chains* — equal depth, and
        stage-wise identical ``[start, stop)`` / static hps / hp names /
        batch-size schedules, divergent hp values — returning the per-stage
        boundary states of every member (``[member][stage]``).  Fusing
        backends run each stage level as one batched call over member-
        stacked carries; the default runs member chains sequentially."""
        return [self.run_chain(s, c) for s, c in zip(states, chains)]

    def evaluate(self, state: Any, ctx: StageContext) -> Dict[str, float]:
        """Metrics of the model at ``ctx.stop``."""
        raise NotImplementedError

    def stage_seconds(self, ctx: StageContext) -> Optional[float]:
        """Virtual duration of the stage (simulated backends); None = measure
        wall-clock (real backends)."""
        return None

    def overheads(self) -> Tuple[float, float]:
        """(checkpoint-load seconds, checkpoint-save seconds)."""
        return (0.0, 0.0)

    # ------------------------------------------------------- mesh protocol
    def set_mesh(self, mesh: Optional[Any]) -> None:
        """Bind subsequent ``run_*`` calls to the dispatching worker's
        device mesh (a :class:`repro.dist.meshes.WorkerMesh`), or reset
        with ``None``.  Host-only backends ignore it — the dispatcher
        calls this before every execution, so sharded backends must treat
        it as cheap (cache the materialized mesh)."""

    def mesh_compatible(self, mesh: Any,
                        ctxs: Sequence[StageContext]) -> bool:
        """Can the work described by ``ctxs`` run on ``mesh``?  The
        dispatcher skips incompatible workers during placement (counting
        ``placement_rejections``).  Default: any mesh hosts any work."""
        return True

    def clone_state(self, state: Any) -> Any:
        """An independent copy of a state pytree — the dispatcher's
        copy-on-fanout when one resume load feeds several sibling group
        members.  Backends with immutable leaves (JAX arrays) override
        with a cheap container copy."""
        return copy.deepcopy(state)

    def device_transfer(self, state: Any, mesh: Optional[Any]) -> Any:
        """Device-to-device handoff of a boundary state to a worker bound
        to ``mesh``, bypassing the checkpoint store.  Must return a state
        safe to hand to one consumer (a fresh copy, or one with immutable
        leaves); return ``None`` to decline — the dispatcher then falls
        back to the store."""
        return self.clone_state(state)


# ---------------------------------------------------------------------------
# Simulated trainer
# ---------------------------------------------------------------------------


class SimulatedTrainer(TrainerBackend):
    """Deterministic analytic model of training dynamics.

    The state carries accumulated *progress*; each step contributes
    ``gain(lr, bs, momentum, step)`` where the gain peaks when the learning
    rate tracks an ideal annealing trajectory ``lr*(step) = lr0 / (1 + step/T)``
    — so schedules that decay (StepLR, cosine, exponential) dominate
    constants, as in the paper's Figure 2.  Validation accuracy saturates
    with progress: ``acc = a_max · (1 − exp(−progress / T))``.

    ``seconds_per_step`` scales linearly with batch size over the reference
    batch (data-parallel cost model) — this drives the simulator clock and
    the critical-path profile.
    """

    def __init__(self, lr0: float = 0.1, horizon: int = 200,
                 a_max: float = 0.95, base_seconds_per_step: float = 1.0,
                 ref_batch: float = 128.0, load_seconds: float = 2.0,
                 save_seconds: float = 2.0, eval_seconds: float = 5.0):
        self.lr0 = lr0
        self.horizon = horizon
        self.a_max = a_max
        self.base_seconds_per_step = base_seconds_per_step
        self.ref_batch = ref_batch
        self.load_seconds = load_seconds
        self.save_seconds = save_seconds
        self.eval_seconds = eval_seconds

    # ------------------------------------------------------------- dynamics
    def init_state(self) -> Dict[str, float]:
        return {"progress": 0.0, "step": 0}

    def _gain(self, step: int, hp: Dict[str, float]) -> float:
        lr = hp.get("lr", self.lr0)
        if lr <= 0:
            return 0.0
        ideal = self.lr0 / (1.0 + step / max(1.0, self.horizon / 4))
        # log-distance to the ideal annealed lr; too-high lr hurts more.
        d = math.log(lr / ideal)
        gain = math.exp(-(d * d) / (2.0 * 1.2 ** 2))
        mom = hp.get("momentum", 0.9)
        gain *= 1.0 - 0.5 * abs(mom - 0.9)
        bs = hp.get("bs", self.ref_batch)
        # larger batches take fewer, bigger steps: mild sub-linear utility
        gain *= (bs / self.ref_batch) ** 0.5
        return gain

    def run_stage(self, state: Dict[str, float], ctx: StageContext) -> Dict[str, float]:
        assert state["step"] == ctx.start, (
            f"state at step {state['step']} cannot run stage starting {ctx.start}")
        vals = desc_values(ctx.desc, ctx.node_start, ctx.start, ctx.stop)
        static = desc_static(ctx.desc)
        # float() detaches from the (read-only, cache-shared) restored leaf:
        # += on a 0-d numpy view would mutate the checkpoint store's cached
        # tree in place
        progress = float(state["progress"])
        names = list(vals)
        for i, step in enumerate(range(ctx.start, ctx.stop)):
            hp = {k: vals[k][i] for k in names}
            hp.update({k: v for k, v in static.items() if isinstance(v, (int, float))})
            progress += self._gain(step, hp)
        return {"progress": progress, "step": ctx.stop}

    def evaluate(self, state: Dict[str, float], ctx: StageContext) -> Dict[str, float]:
        # deterministic "noise" keyed by the computation path, NOT the trial:
        # two merged trials must observe the same metric.
        jitter = (int(ctx.path_key[:8], 16) % 1000) / 1000.0 - 0.5
        acc = self.a_max * (1.0 - math.exp(-state["progress"] / (self.horizon / 3)))
        acc *= 1.0 + 0.01 * jitter
        return {"val_acc": acc, "loss": max(0.02, 2.3 * math.exp(
            -state["progress"] / (self.horizon / 3)))}

    # --------------------------------------------------------------- timing
    def stage_seconds(self, ctx: StageContext) -> float:
        vals = desc_values(ctx.desc, ctx.node_start, ctx.start, ctx.stop)
        bs = vals.get("bs")
        sec = 0.0
        for i in range(ctx.stop - ctx.start):
            scale = (bs[i] / self.ref_batch) if bs else 1.0
            sec += self.base_seconds_per_step * scale
        return sec

    def overheads(self) -> Tuple[float, float]:
        return (self.load_seconds, self.save_seconds)
