"""Evaluate hyper-parameter values from canonical piece descriptors.

Search-plan nodes store offset-normalized *descriptors* of functional
pieces (see ``HpFunction.piece_descriptor``), not the original functions —
that is what makes structurally identical trajectories collide into one
node.  Workers, however, need concrete per-step values to train with.
``desc_values`` reconstructs them:

* ``{"kind": "const", "value": v}``            — v at every step,
* ``{"kind": k, "fn": j, "offset": o}``        — ``from_json(j).value(local)``
  where ``local = step - node_start + o`` (the piece saw local step ``o`` at
  the node's global ``start``).
"""

from __future__ import annotations

from typing import Any, Dict, List

from repro.core.hpseq import from_json

__all__ = ["desc_value_at", "desc_values", "desc_static"]


def _piece_value(piece: Dict[str, Any], node_start: int, step: int) -> float:
    if piece["kind"] == "const":
        return piece["value"]
    fn = from_json(piece["fn"])
    return fn.value(step - node_start + piece.get("offset", node_start))


def desc_value_at(desc: Dict[str, Any], node_start: int, step: int) -> Dict[str, float]:
    """Hyper-parameter values of a node's configuration at a global step."""
    return {name: _piece_value(p, node_start, step)
            for name, p in desc["hps"].items()}


def desc_values(desc: Dict[str, Any], node_start: int, start: int,
                stop: int) -> Dict[str, List[float]]:
    """Per-step value arrays on ``[start, stop)`` (one list per hp)."""
    out: Dict[str, List[float]] = {}
    for name, p in desc["hps"].items():
        if p["kind"] == "const":
            out[name] = [p["value"]] * (stop - start)
        else:
            fn = from_json(p["fn"])
            off = p.get("offset", node_start)
            out[name] = [fn.value(s - node_start + off) for s in range(start, stop)]
    return out


def desc_static(desc: Dict[str, Any]) -> Dict[str, Any]:
    return dict(desc.get("static") or {})
