"""Hyper-parameter sequence functions (Hippo §2.1, §3.1, Figure 10).

A hyper-parameter in Hippo is not a scalar but a *function of the training
step*.  Trials are identified by the exact sequence of values their
hyper-parameters take, so two trials share computation exactly on the step
range where *all* of their hyper-parameter functions agree.

Every sequence function here provides:

  * ``value(step)``       — the hyper-parameter value at a global step,
  * ``boundaries(total)`` — the steps at which the function's *piece*
                            changes (used to derive canonical stage
                            boundaries, §3.1 "we follow the convention of
                            dividing hyper-parameter sequences to set stage
                            boundaries"),
  * ``to_json()``         — canonical encoding, making structural equality
                            (and therefore prefix matching) well defined,
  * ``prefix_equal(other, upto)`` — True iff the two functions produce the
                            same values on ``[0, upto)``.

``Seq`` composition (e.g. warm-up followed by decay) concatenates functions
along the step axis, matching the paper's "sequential combinations of
functions".

The catalogue mirrors Tables 2-4 of the paper: Constant, MultiStep/StepLR,
Exponential, Linear, Cosine annealing (with warm restarts), CyclicLR,
Warmup, and Piecewise for arbitrary user-defined sequences.
"""

from __future__ import annotations

import math
from typing import Any, Dict, List, Sequence, Tuple

from repro.utils import stable_hash

__all__ = [
    "HpFunction",
    "Constant",
    "MultiStep",
    "StepLR",
    "Exponential",
    "Linear",
    "Cosine",
    "CosineWarmRestarts",
    "Cyclic",
    "Warmup",
    "Seq",
    "Piecewise",
    "from_json",
    "HpConfig",
]


class HpFunction:
    """Base class for a hyper-parameter as a function of training step."""

    kind: str = "base"

    # ------------------------------------------------------------------ value
    def value(self, step: int) -> float:
        raise NotImplementedError

    def values(self, start: int, stop: int) -> List[float]:
        return [self.value(s) for s in range(start, stop)]

    # ------------------------------------------------------------- boundaries
    def boundaries(self, total_steps: int) -> List[int]:
        """Steps in ``(0, total_steps)`` at which the functional *piece*
        changes.  Smooth functions (exponential, cosine...) have no interior
        boundaries — a stage may hold a non-constant sequence (§3.1)."""
        return []

    # ------------------------------------------------------------- canonical
    def to_json(self) -> Dict[str, Any]:
        raise NotImplementedError

    def __eq__(self, other: object) -> bool:
        return isinstance(other, HpFunction) and self.to_json() == other.to_json()

    def __hash__(self) -> int:
        return hash(stable_hash(self.to_json()))

    def __repr__(self) -> str:
        d = self.to_json()
        kind = d.pop("kind")
        args = ", ".join(f"{k}={v}" for k, v in d.items())
        return f"{kind}({args})"

    # ------------------------------------------------------- prefix equality
    def prefix_equal(self, other: "HpFunction", upto: int) -> bool:
        """True iff self and other agree on every step in [0, upto).

        Structural fast path first; falls back to piecewise comparison at
        boundary-delimited sample points for mixed kinds.
        """
        if self.to_json() == other.to_json():
            return True
        pts = sorted(
            set([0, max(0, upto - 1)])
            | {b for b in self.boundaries(upto) if 0 <= b < upto}
            | {b - 1 for b in self.boundaries(upto) if 1 <= b <= upto}
            | {b for b in other.boundaries(upto) if 0 <= b < upto}
            | {b - 1 for b in other.boundaries(upto) if 1 <= b <= upto}
        )
        # Piecewise-*constant* pieces are fully determined by their endpoint
        # samples; smooth pieces need structural equality of the piece.
        sp, op = self.pieces(upto), other.pieces(upto)
        if _pieces_prefix_equal(sp, op, upto):
            return True
        # Last resort: exact pointwise check (bounded; only for small ranges)
        if upto <= 4096:
            return all(self.value(s) == other.value(s) for s in range(upto))
        return all(self.value(s) == other.value(s) for s in pts)

    # ------------------------------------------------------------ pieces
    def pieces(self, total_steps: int) -> List[Tuple[int, int, Dict[str, Any]]]:
        """Decompose into (start, stop, canonical-piece-descriptor) tuples.

        The descriptor of a piece is normalized so that the same value
        trajectory yields the same descriptor regardless of how it was
        constructed (e.g. Constant(0.1) vs the first piece of
        MultiStep(0.1, [100], 0.1)).
        """
        bs = [0] + [b for b in self.boundaries(total_steps) if 0 < b < total_steps] + [total_steps]
        out = []
        for a, b in zip(bs[:-1], bs[1:]):
            out.append((a, b, self.piece_descriptor(a, b)))
        return out

    def piece_descriptor(self, start: int, stop: int) -> Dict[str, Any]:
        """Canonical descriptor of this function restricted to [start, stop).

        Default: if the restriction is constant, normalize to a constant
        descriptor; otherwise describe by kind + offset so that identical
        trajectories compare equal only when structurally identical.
        """
        v0 = self.value(start)
        if stop - start <= 1 or all(
            self.value(s) == v0 for s in _probe_steps(start, stop)
        ):
            # constant on the probes: verify cheaply via boundaries contract —
            # pieces are maximal intervals without interior boundaries, so a
            # piecewise-constant function is constant on each piece.
            if self._piecewise_constant():
                return {"kind": "const", "value": float(v0)}
        return {"kind": self.kind, "fn": self.to_json(), "offset": start}

    def _piecewise_constant(self) -> bool:
        return False


def _probe_steps(start: int, stop: int, k: int = 5) -> List[int]:
    if stop - start <= k:
        return list(range(start, stop))
    stride = (stop - start) // k
    return sorted({start, stop - 1, *range(start, stop, stride)})


def _pieces_prefix_equal(a, b, upto: int) -> bool:
    """Compare two piece decompositions on [0, upto)."""
    # Refine both to the union of boundaries.
    cuts = sorted({p[0] for p in a} | {p[1] for p in a} | {p[0] for p in b} | {p[1] for p in b})
    cuts = [c for c in cuts if 0 <= c <= upto]
    if not cuts or cuts[0] != 0 or cuts[-1] != upto:
        return False

    def find(pieces, s, e):
        for (pa, pb, d) in pieces:
            if pa <= s and e <= pb:
                return d
        return None

    for s, e in zip(cuts[:-1], cuts[1:]):
        da, db = find(a, s, e), find(b, s, e)
        if da is None or db is None:
            return False
        if da.get("kind") == "const" and db.get("kind") == "const":
            if da["value"] != db["value"]:
                return False
        elif da != db:
            return False
    return True


# ---------------------------------------------------------------------------
# Concrete function families
# ---------------------------------------------------------------------------


class Constant(HpFunction):
    kind = "constant"

    def __init__(self, v: float):
        self.v = float(v)

    def value(self, step: int) -> float:
        return self.v

    def to_json(self):
        return {"kind": self.kind, "v": self.v}

    def piece_descriptor(self, start, stop):
        return {"kind": "const", "value": float(self.v)}

    def _piecewise_constant(self):
        return True


class MultiStep(HpFunction):
    """Piecewise-constant: value -> value*gamma at each milestone.

    ``MultiStep(128, [40], 2)`` == batch size 128 then 256 from step 40
    (Figure 10).  ``values`` form: explicit per-segment values.
    """

    kind = "multistep"

    def __init__(self, base: float, milestones: Sequence[int], gamma: float = None,
                 values: Sequence[float] = None):
        self.base = base
        self.milestones = sorted(int(m) for m in milestones)
        if values is not None:
            assert len(values) == len(self.milestones) + 1
            self.segment_values = [float(v) for v in values]
            self.gamma = None
        else:
            g = 0.1 if gamma is None else gamma
            self.gamma = g
            self.segment_values = [base * (g ** i) for i in range(len(self.milestones) + 1)]

    @classmethod
    def from_values(cls, values: Sequence[float], milestones: Sequence[int]) -> "MultiStep":
        return cls(values[0], milestones, values=values)

    def value(self, step: int) -> float:
        i = 0
        for m in self.milestones:
            if step >= m:
                i += 1
        return self.segment_values[i]

    def boundaries(self, total_steps: int) -> List[int]:
        return [m for m in self.milestones if 0 < m < total_steps]

    def to_json(self):
        return {"kind": self.kind, "base": self.base,
                "milestones": list(self.milestones),
                "values": list(self.segment_values)}

    def piece_descriptor(self, start, stop):
        return {"kind": "const", "value": float(self.value(start))}

    def _piecewise_constant(self):
        return True


def StepLR(base: float, gamma: float, milestones: Sequence[int]) -> MultiStep:
    """PyTorch-style alias used in the paper's Tables 2-3."""
    return MultiStep(base, milestones, gamma=gamma)


class Exponential(HpFunction):
    """v(step) = base * gamma**(step / period)."""

    kind = "exponential"

    def __init__(self, base: float, gamma: float, period: int = 1):
        self.base, self.gamma, self.period = base, gamma, int(period)

    def value(self, step: int) -> float:
        return self.base * (self.gamma ** (step / self.period))

    def to_json(self):
        return {"kind": self.kind, "base": self.base, "gamma": self.gamma,
                "period": self.period}


class Linear(HpFunction):
    """Linear from ``base`` to ``end`` over ``total`` steps, then clamped."""

    kind = "linear"

    def __init__(self, base: float, total: int, end: float = 0.0):
        self.base, self.total, self.end = base, int(total), end

    def value(self, step: int) -> float:
        if step >= self.total:
            return self.end
        f = step / self.total
        return self.base + (self.end - self.base) * f

    def boundaries(self, total_steps: int) -> List[int]:
        return [self.total] if 0 < self.total < total_steps else []

    def to_json(self):
        return {"kind": self.kind, "base": self.base, "total": self.total,
                "end": self.end}


class Cosine(HpFunction):
    """Cosine annealing from base to eta_min over t_max steps."""

    kind = "cosine"

    def __init__(self, base: float, t_max: int, eta_min: float = 0.0):
        self.base, self.t_max, self.eta_min = base, int(t_max), eta_min

    def value(self, step: int) -> float:
        s = min(step, self.t_max)
        return self.eta_min + 0.5 * (self.base - self.eta_min) * (
            1 + math.cos(math.pi * s / self.t_max))

    def boundaries(self, total_steps: int) -> List[int]:
        return [self.t_max] if 0 < self.t_max < total_steps else []

    def to_json(self):
        return {"kind": self.kind, "base": self.base, "t_max": self.t_max,
                "eta_min": self.eta_min}


class CosineWarmRestarts(HpFunction):
    """SGDR: cosine annealing with period t_0 (optionally growing by t_mult)."""

    kind = "cosine_warm_restarts"

    def __init__(self, base: float, t_0: int, t_mult: int = 1, eta_min: float = 0.0):
        self.base, self.t_0, self.t_mult, self.eta_min = base, int(t_0), int(t_mult), eta_min

    def _cycle(self, step: int) -> Tuple[int, int]:
        """Return (position within cycle, cycle length)."""
        t, length = step, self.t_0
        while t >= length:
            t -= length
            length *= self.t_mult if self.t_mult > 1 else 1
            if self.t_mult == 1:
                # fixed-length cycles: position is just modulo
                return step % self.t_0, self.t_0
        return t, length

    def value(self, step: int) -> float:
        t, length = self._cycle(step)
        return self.eta_min + 0.5 * (self.base - self.eta_min) * (
            1 + math.cos(math.pi * t / length))

    def boundaries(self, total_steps: int) -> List[int]:
        out, t, length = [], self.t_0, self.t_0
        while t < total_steps:
            out.append(t)
            length = length * self.t_mult if self.t_mult > 1 else length
            t += length
        return out

    def to_json(self):
        return {"kind": self.kind, "base": self.base, "t_0": self.t_0,
                "t_mult": self.t_mult, "eta_min": self.eta_min}


class Cyclic(HpFunction):
    """CyclicLR (triangular): base_lr <-> max_lr with step_size_up."""

    kind = "cyclic"

    def __init__(self, base_lr: float, max_lr: float, step_size_up: int,
                 step_size_down: int = None):
        self.base_lr, self.max_lr = base_lr, max_lr
        self.step_size_up = int(step_size_up)
        self.step_size_down = int(step_size_down or step_size_up)

    def value(self, step: int) -> float:
        cycle_len = self.step_size_up + self.step_size_down
        t = step % cycle_len
        if t < self.step_size_up:
            f = t / self.step_size_up
        else:
            f = 1.0 - (t - self.step_size_up) / self.step_size_down
        return self.base_lr + (self.max_lr - self.base_lr) * f

    def boundaries(self, total_steps: int) -> List[int]:
        out, cycle_len = [], self.step_size_up + self.step_size_down
        t = self.step_size_up
        while t < total_steps:
            out.append(t)
            t += self.step_size_down if (len(out) % 2 == 1) else self.step_size_up
        return out

    def to_json(self):
        return {"kind": self.kind, "base_lr": self.base_lr, "max_lr": self.max_lr,
                "step_size_up": self.step_size_up,
                "step_size_down": self.step_size_down}


class Seq(HpFunction):
    """Sequential composition: fn_i applies for dur_i steps, last runs forever.

    ``Seq((Linear(0,5,0.1), 5), (MultiStep(0.1,[90,135]), None))`` is the
    paper's "Warmup(5, 0.1), StepLR(...)" row of Table 2.
    """

    kind = "seq"

    def __init__(self, *parts: Tuple[HpFunction, int]):
        assert parts, "Seq needs at least one part"
        self.parts = []
        for fn, dur in parts:
            self.parts.append((fn, None if dur is None else int(dur)))
        for fn, dur in self.parts[:-1]:
            assert dur is not None, "only the final Seq part may be unbounded"

    def _locate(self, step: int) -> Tuple[HpFunction, int]:
        offset = 0
        for fn, dur in self.parts:
            if dur is None or step < offset + dur:
                return fn, step - offset
            offset += dur
        fn, dur = self.parts[-1]
        return fn, step - (offset - (dur or 0))

    def value(self, step: int) -> float:
        fn, local = self._locate(step)
        return fn.value(local)

    def boundaries(self, total_steps: int) -> List[int]:
        out, offset = [], 0
        for fn, dur in self.parts:
            horizon = total_steps - offset if dur is None else min(dur, total_steps - offset)
            if horizon <= 0:
                break
            out.extend(offset + b for b in fn.boundaries(horizon))
            if dur is not None:
                offset += dur
                if 0 < offset < total_steps:
                    out.append(offset)
        return sorted(set(b for b in out if 0 < b < total_steps))

    def to_json(self):
        return {"kind": self.kind,
                "parts": [[fn.to_json(), dur] for fn, dur in self.parts]}

    def piece_descriptor(self, start, stop):
        fn, local = self._locate(start)
        fn_end, local_end = self._locate(max(start, stop - 1))
        if fn is fn_end:
            return fn.piece_descriptor(local, local + (stop - start))
        return super().piece_descriptor(start, stop)

    def _piecewise_constant(self):
        return all(fn._piecewise_constant() for fn, _ in self.parts)


def Warmup(duration: int, target: float, then: HpFunction = None,
           start: float = 0.0) -> HpFunction:
    """Paper Table 2 notation: linear warm-up to ``target`` over ``duration``
    steps, followed by ``then`` (which sees local step 0 at the hand-off)."""
    ramp = Linear(start, duration, end=target)
    if then is None:
        return Seq((ramp, duration), (Constant(target), None))
    return Seq((ramp, duration), (then, None))


class Piecewise(HpFunction):
    """Arbitrary user-defined piecewise-constant sequence.

    ``Piecewise([(0, 0.1), (100, 0.01)])`` == 0.1 on [0,100), 0.01 after.
    """

    kind = "piecewise"

    def __init__(self, points: Sequence[Tuple[int, float]]):
        pts = sorted((int(s), float(v)) for s, v in points)
        assert pts and pts[0][0] == 0, "Piecewise must start at step 0"
        self.points = pts

    def value(self, step: int) -> float:
        v = self.points[0][1]
        for s, pv in self.points:
            if step >= s:
                v = pv
        return v

    def boundaries(self, total_steps: int) -> List[int]:
        return [s for s, _ in self.points if 0 < s < total_steps]

    def to_json(self):
        return {"kind": self.kind, "points": [[s, v] for s, v in self.points]}

    def piece_descriptor(self, start, stop):
        return {"kind": "const", "value": float(self.value(start))}

    def _piecewise_constant(self):
        return True


# ---------------------------------------------------------------------------
# Deserialization
# ---------------------------------------------------------------------------

def from_json(d: Dict[str, Any]) -> HpFunction:
    kind = d["kind"]
    if kind == "constant":
        return Constant(d["v"])
    if kind == "multistep":
        return MultiStep(d["base"], d["milestones"], values=d["values"])
    if kind == "exponential":
        return Exponential(d["base"], d["gamma"], d.get("period", 1))
    if kind == "linear":
        return Linear(d["base"], d["total"], d.get("end", 0.0))
    if kind == "cosine":
        return Cosine(d["base"], d["t_max"], d.get("eta_min", 0.0))
    if kind == "cosine_warm_restarts":
        return CosineWarmRestarts(d["base"], d["t_0"], d.get("t_mult", 1),
                                  d.get("eta_min", 0.0))
    if kind == "cyclic":
        return Cyclic(d["base_lr"], d["max_lr"], d["step_size_up"],
                      d.get("step_size_down"))
    if kind == "seq":
        return Seq(*[(from_json(fj), dur) for fj, dur in d["parts"]])
    if kind == "piecewise":
        return Piecewise([(s, v) for s, v in d["points"]])
    raise ValueError(f"unknown hp function kind {kind!r}")


# ---------------------------------------------------------------------------
# HpConfig: a named bundle of hyper-parameter functions
# ---------------------------------------------------------------------------


class HpConfig:
    """A full hyper-parameter configuration: name -> HpFunction.

    Non-numeric hyper-parameters tuned as single values (optimizer choice,
    weight decay in the paper's search spaces) are wrapped as ``Constant`` or
    carried in ``static`` (strings: optimizer name, etc.)."""

    def __init__(self, fns: Dict[str, HpFunction], static: Dict[str, Any] = None):
        self.fns = dict(sorted(fns.items()))
        self.static = dict(sorted((static or {}).items()))

    def value(self, step: int) -> Dict[str, float]:
        return {k: fn.value(step) for k, fn in self.fns.items()}

    def values_dict(self, step: int) -> Dict[str, Any]:
        d = self.value(step)
        d.update(self.static)
        return d

    def boundaries(self, total_steps: int) -> List[int]:
        out = set()
        for fn in self.fns.values():
            out.update(fn.boundaries(total_steps))
        return sorted(b for b in out if 0 < b < total_steps)

    def prefix_equal(self, other: "HpConfig", upto: int) -> bool:
        if set(self.fns) != set(other.fns) or self.static != other.static:
            return False
        return all(self.fns[k].prefix_equal(other.fns[k], upto) for k in self.fns)

    def to_json(self):
        return {"fns": {k: fn.to_json() for k, fn in self.fns.items()},
                "static": self.static}

    @classmethod
    def from_json(cls, d) -> "HpConfig":
        return cls({k: from_json(v) for k, v in d["fns"].items()}, d.get("static"))

    def __eq__(self, other):
        return isinstance(other, HpConfig) and self.to_json() == other.to_json()

    def __hash__(self):
        return hash(stable_hash(self.to_json()))

    def __repr__(self):
        inner = ", ".join(f"{k}={fn!r}" for k, fn in self.fns.items())
        if self.static:
            inner += ", " + ", ".join(f"{k}={v!r}" for k, v in self.static.items())
        return f"HpConfig({inner})"
