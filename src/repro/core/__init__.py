"""Hippo's core: hp sequences, search plans, stage trees, scheduler, engine."""

from repro.core.hpseq import (
    Constant, Cosine, CosineWarmRestarts, Cyclic, Exponential, HpConfig,
    Linear, MultiStep, Piecewise, Seq, StepLR, Warmup,
)
from repro.core.trial import Trial
from repro.core.searchplan import SearchPlan
from repro.core.stagetree import (StageTreeBuilder, build_stage_tree,
                                  sibling_chain_groups, sibling_groups,
                                  stage_trees_equal)
from repro.core.scheduler import (POLICIES, CriticalPathScheduler,
                                  FIFOScheduler, FairShareScheduler,
                                  SchedulingPolicy, WeightedFanoutScheduler,
                                  make_policy)
from repro.core.engine import EngineStats, ExecutionEngine, StudyStats, Tuner
from repro.core.faults import (FatalStageError, FaultError, FaultInjector,
                               FaultyBackend, FaultyStore, StoreOutageError,
                               TransientStageError, WorkerCrashed)
from repro.core.trainer import SimulatedTrainer, StageContext, TrainerBackend
from repro.core.db import SearchPlanDB, study_key
from repro.core.merge import k_wise_merge_rate, merge_rate, total_steps, unique_steps
from repro.core.study import (PlanKeyMismatch, Study, StudyFuture,
                              StudyService, StudySpec, run_studies)
