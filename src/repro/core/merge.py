"""Merge rates (Hippo §6, "Merge rate").

``p  = total training iterations / unique training iterations`` for one
study; ``q`` is the k-wise analogue over several studies' trial sets
combined.  *Total* counts every trial trained independently to its maximum
budget; *unique* is the step count after prefix merging — computed exactly
by inserting all trials into a fresh search plan and summing the per-node
unique step ranges.
"""

from __future__ import annotations

from typing import Iterable, List, Sequence

from repro.core.searchplan import SearchPlan
from repro.core.trial import Trial

__all__ = ["unique_steps", "total_steps", "merge_rate", "k_wise_merge_rate"]


def total_steps(trials: Iterable[Trial]) -> int:
    return sum(t.total_steps for t in trials)


def unique_steps(trials: Iterable[Trial]) -> int:
    """Steps needed with zero redundant computation (merged stage count)."""
    plan = SearchPlan("merge-rate")
    per_node_max: dict = {}
    for t in trials:
        node, step, _ = plan.submit(t)
        # the full path up to `step` is required: each node on the path is
        # needed up to the child's start (or `step` for the leaf)
    unique = 0
    for nid, node in plan.nodes.items():
        # the range a node must be trained for = max over (requests on the
        # node, children starts)
        tops = set(node.requests)
        for cid in plan.children.get(nid, []):
            tops.add(plan.nodes[cid].start)
        if tops:
            unique += max(tops) - node.start
    return unique


def merge_rate(trials: Sequence[Trial]) -> float:
    u = unique_steps(trials)
    return total_steps(trials) / u if u else float("inf")


def k_wise_merge_rate(studies: Sequence[Sequence[Trial]]) -> float:
    """q over k studies: totals add up; uniqueness is computed jointly."""
    all_trials: List[Trial] = [t for s in studies for t in s]
    u = unique_steps(all_trials)
    return total_steps(all_trials) / u if u else float("inf")
