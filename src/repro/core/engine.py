"""Discrete-event execution engine — Hippo's scheduler/worker/aggregator loop.

This is the system of §4 run as a deterministic discrete-event simulation
over ``n_workers`` virtual workers (a *worker* is one GPU server slot in
the paper; one mesh slice in the TPU mapping).  The engine drives the real
components:

* the **search plan** is the single source of truth (stateless scheduling),
* every scheduling round regenerates a **stage tree** (Algorithm 1) and the
  **critical-path scheduler** extracts whole chains for idle workers,
* chains execute through a :class:`~repro.core.trainer.TrainerBackend` —
  either real JAX training (wall-clock measured) or the analytic simulator
  (virtual durations) — and deposit checkpoints/metrics through the
  **aggregator** at their virtual completion times,
* **tuners** observe metrics and submit/kill trials, closing the HPO loop.

Accounting matches the paper's two measurements: ``gpu_seconds`` (sum of
busy time × GPUs per worker) and ``end-to-end`` time (virtual clock at
completion).

``share=False`` turns the engine into the **trial-based baseline**
(Ray Tune / "Hippo-trial"): every submitted trial is salted so its plan
nodes never merge with other trials' — identical scheduling machinery,
zero cross-trial reuse.  A trial still reuses *its own* checkpoints when a
tuner promotes it to a longer step budget, exactly like a paused/resumed
Ray Tune trial.
"""

from __future__ import annotations

import heapq
import itertools
import time as _time
from dataclasses import dataclass, field
from typing import Any, Callable, Dict, List, Optional, Set, Tuple

from repro.core.hpseq import HpConfig
from repro.core.scheduler import CriticalPathScheduler
from repro.core.searchplan import SearchPlan
from repro.core.stagetree import Stage, build_stage_tree
from repro.core.trainer import StageContext, TrainerBackend
from repro.core.trial import Trial
from repro.train.checkpoint import CheckpointStore

__all__ = ["ExecutionEngine", "Tuner", "StudyHandle", "EngineStats"]


class Tuner:
    """Base class for HPO algorithms (client-library tuners, §5.2)."""

    objective: str = "val_acc"
    mode: str = "max"  # or "min"

    def start(self, handle: "StudyHandle") -> None:
        raise NotImplementedError

    def on_result(self, trial: Trial, step: int, metrics: Dict[str, float]) -> None:
        pass

    def is_done(self) -> bool:
        raise NotImplementedError

    # ------------------------------------------------------------- helpers
    def score(self, metrics: Dict[str, float]) -> float:
        v = metrics[self.objective]
        return v if self.mode == "max" else -v


@dataclass
class StudyHandle:
    """The submission interface a tuner sees (the client library's view)."""

    engine: "ExecutionEngine"
    tuner: Tuner
    study_id: str = "study-0"

    def submit(self, trial: Trial, upto: Optional[int] = None) -> None:
        self.engine._submit(self, trial, upto)

    def kill(self, trial: Trial) -> None:
        self.engine._kill(self, trial)


@dataclass
class EngineStats:
    gpu_seconds: float = 0.0
    end_to_end: float = 0.0
    stages_run: int = 0
    steps_run: int = 0
    evals_run: int = 0
    ckpt_loads: int = 0
    ckpt_saves: int = 0
    rounds: int = 0

    @property
    def gpu_hours(self) -> float:
        return self.gpu_seconds / 3600.0


@dataclass
class _Worker:
    wid: int
    busy_until: float = 0.0
    idle: bool = True


@dataclass(order=True)
class _Event:
    time: float
    seq: int
    kind: str = field(compare=False)
    payload: Any = field(compare=False)


class ExecutionEngine:
    def __init__(self, plan: SearchPlan, backend: TrainerBackend,
                 n_workers: int = 4, gpus_per_worker: int = 1,
                 scheduler: Optional[CriticalPathScheduler] = None,
                 store: Optional[CheckpointStore] = None,
                 share: bool = True,
                 max_steps_per_chain: Optional[int] = None):
        self.plan = plan
        self.backend = backend
        self.workers = [_Worker(i) for i in range(n_workers)]
        self.gpus_per_worker = gpus_per_worker
        self.scheduler = scheduler or CriticalPathScheduler()
        self.store = store or CheckpointStore()
        self.share = share
        self.max_steps_per_chain = max_steps_per_chain
        self.time = 0.0
        self.stats = EngineStats()
        self._events: List[_Event] = []
        self._seq = itertools.count()
        # (node_id, step) -> list of (handle, trial) waiting on the result
        self._waiters: Dict[Tuple[str, int], List[Tuple[StudyHandle, Trial]]] = {}
        self._trials: Dict[str, Trial] = {}
        self._killed: Set[str] = set()
        self._handles: List[StudyHandle] = []

    # ------------------------------------------------------------------ API
    def handle(self, tuner: Tuner, study_id: str = None) -> StudyHandle:
        h = StudyHandle(self, tuner, study_id or f"study-{len(self._handles)}")
        self._handles.append(h)
        return h

    def run(self, tuners: List[Tuner]) -> EngineStats:
        """Run tuners to completion; returns accounting stats."""
        handles = [self.handle(t) for t in tuners]
        for h in handles:
            h.tuner.start(h)
        self._drain()
        not_done = [h.tuner for h in handles if not h.tuner.is_done()]
        if not_done:
            raise RuntimeError(
                f"engine drained but {len(not_done)} tuner(s) not done — "
                "a tuner is waiting on a request that was never submitted")
        self.stats.end_to_end = self.time
        return self.stats

    # ------------------------------------------------------------- internal
    def _push(self, t: float, kind: str, payload: Any) -> None:
        heapq.heappush(self._events, _Event(t, next(self._seq), kind, payload))

    def _salted(self, trial: Trial, study_id: str) -> Trial:
        """Trial-based baseline: make the plan treat every (study, trial)
        pair as unshareable — the salt must include the study id, or two
        identical studies would still dedup across each other."""
        if self.share:
            return trial
        cfg = trial.hp_config
        static = dict(cfg.static)
        static["_trial_salt"] = f"{study_id}/{trial.trial_id}"
        return Trial(HpConfig(dict(cfg.fns), static), trial.total_steps,
                     trial_id=trial.trial_id, meta=dict(trial.meta))

    def _submit(self, handle: StudyHandle, trial: Trial,
                upto: Optional[int]) -> None:
        trial = self._salted(trial, handle.study_id)
        self._trials[trial.trial_id] = trial
        node, step, satisfied = self.plan.submit(trial, upto)
        if satisfied:
            # §3.2: results already present → respond immediately (still an
            # event so tuner callbacks observe a consistent clock).
            metrics = self.plan.metrics_for(node.node_id, step)
            self._push(self.time, "reply", (handle, trial, step, metrics))
            return
        self._waiters.setdefault((node.node_id, step), []).append((handle, trial))

    def _kill(self, handle: StudyHandle, trial: Trial) -> None:
        tid = trial.trial_id
        if tid in self._killed:
            return
        self._killed.add(tid)
        path = list(self.plan.trial_paths.get(tid, []))
        self.plan.release_trial(tid)
        # drop this trial's pending requests nobody else wants
        for nid in path:
            node = self.plan.nodes[nid]
            for s in sorted(node.requests):
                key = (nid, s)
                ws = self._waiters.get(key)
                if ws:
                    ws[:] = [(h, t) for (h, t) in ws if t.trial_id != tid]
                if not ws and s not in node.running and s not in node.metrics:
                    node.requests.discard(s)
                    self._waiters.pop(key, None)

    # ------------------------------------------------------------ main loop
    def _drain(self) -> None:
        self._assign()
        while self._events:
            ev = heapq.heappop(self._events)
            assert ev.time >= self.time - 1e-9
            self.time = max(self.time, ev.time)
            if ev.kind == "stage":
                self._on_stage_done(ev.payload)
            elif ev.kind == "reply":
                handle, trial, step, metrics = ev.payload
                handle.tuner.on_result(trial, step, metrics)
            elif ev.kind == "idle":
                self.workers[ev.payload].idle = True
            self._assign()

    # ------------------------------------------------------------ scheduling
    def _assign(self) -> None:
        idle = [w for w in self.workers if w.idle]
        if not idle:
            return
        tree = build_stage_tree(self.plan)
        if not tree.stages:
            return
        self.stats.rounds += 1
        paths = self.scheduler.assign(self.plan, tree, len(idle))
        # stage_id -> (state, finish_time) for cross-chain chaining this round
        produced: Dict[str, Tuple[Any, float]] = {}
        for path, worker in zip(paths, idle):
            if self.max_steps_per_chain:
                path = self._truncate(path)
            self._execute_chain(path, worker, produced)

    def _truncate(self, path: List[Stage]) -> List[Stage]:
        out, steps = [], 0
        for st in path:
            out.append(st)
            steps += st.steps
            if steps >= self.max_steps_per_chain:
                break
        return out

    def _execute_chain(self, path: List[Stage], worker: _Worker,
                       produced: Dict[str, Tuple[Any, float]]) -> None:
        head = path[0]
        t = max(self.time, worker.busy_until)
        load_s, save_s = self.backend.overheads()

        # ------- input state
        if head.resume is not None:
            nid, step = head.resume
            cid = self.plan.node(nid).ckpts[step]
            state = self.store.get(cid)
            t += load_s
            self.stats.gpu_seconds += load_s * self.gpus_per_worker
            self.stats.ckpt_loads += 1
        elif head.parent is not None:
            if head.parent not in produced:
                # parent chain was truncated before producing our input —
                # leave the requests pending; a later round reschedules them
                worker.idle = True
                return
            # produced by another chain in this same round
            state, parent_done = produced[head.parent]
            t = max(t, parent_done) + load_s
            self.stats.gpu_seconds += load_s * self.gpus_per_worker
            self.stats.ckpt_loads += 1
        else:
            state = self.backend.init_state()

        worker.idle = False
        for st in path:
            node = self.plan.node(st.node_id)
            ctx = StageContext(
                node_id=st.node_id, desc=node.desc, node_start=node.start,
                start=st.start, stop=st.stop,
                path_key=self.plan.path_key(st.node_id))
            node.running.add(st.stop)

            wall0 = _time.perf_counter()
            if st.steps > 0:
                state = self.backend.run_stage(state, ctx)
            metrics = self.backend.evaluate(state, ctx) if st.report else None
            wall = _time.perf_counter() - wall0

            sim = self.backend.stage_seconds(ctx)
            dur = sim if sim is not None else wall
            if st.report:
                dur += getattr(self.backend, "eval_seconds", 0.0)
                self.stats.evals_run += 1
            dur += save_s  # checkpoint at every stage boundary
            self.stats.ckpt_saves += 1
            t += dur
            self.stats.gpu_seconds += dur * self.gpus_per_worker
            self.stats.stages_run += 1
            self.stats.steps_run += st.steps

            if st.steps > 0:
                self.plan.record_profile(
                    st.node_id, (sim if sim is not None else wall) / st.steps)
            cid = self.store.put(ctx.path_key, st.stop, state)
            produced[st.stage_id] = (state, t)
            self._push(t, "stage", {
                "node_id": st.node_id, "stop": st.stop, "cid": cid,
                "metrics": metrics, "worker": worker.wid,
                "last": st is path[-1]})
        worker.busy_until = t

    # ----------------------------------------------------------- aggregation
    def _on_stage_done(self, p: Dict[str, Any]) -> None:
        self.plan.record_result(p["node_id"], p["stop"], p["cid"], p["metrics"])
        if p["metrics"] is not None:
            key = (p["node_id"], p["stop"])
            for handle, trial in self._waiters.pop(key, []):
                if trial.trial_id not in self._killed:
                    handle.tuner.on_result(trial, p["stop"], p["metrics"])
        if p["last"]:
            self._push(self.time, "idle", p["worker"])
