"""Stage trees — transient scheduling representation (Hippo §3.1, Algorithm 1).

A *stage* is an executable step interval ``[start, stop)`` of one search-plan
node's hyper-parameter configuration.  Stage trees are generated on demand
from the search plan (they are "transient representations, used solely for
creating scheduling units, and are not kept in the system"), so the scheduler
stays stateless: all persistent state (checkpoints, metrics, requests) lives
in the plan.

``build_stage_tree`` implements the paper's Algorithm 1:

* ``find_latest_checkpoint`` resolves every not-yet-satisfied request to the
  nearest resume point — a checkpoint in the request's own node, a checkpoint
  in an ancestor (via a recursive parent request), or a fresh initialization.
  The lookup table memoizes resolutions and doubles as the set of stage
  boundary cuts.
* Requests whose resume path crosses a *currently running* node range are
  deferred (resolved to ``null`` in the paper): when the running stage
  finishes and checkpoints, a later stage tree picks the request up — exactly
  the "computation for A3 may be repeated again, later" behaviour of §3.2.
* Consecutive cuts inside one node become chained stages; the first stage of
  a node attaches either to its resume checkpoint or to the parent node's
  stage ending at ``node.start``.

:class:`StageTreeBuilder` is the incremental flavour of the same algorithm:
it memoizes ``find_latest_checkpoint`` resolutions across scheduling rounds,
keyed on the plan's ``revision``, and invalidates only the subtrees touched
by new results / running marks / checkpoint evictions.  The produced trees
are *identical* (same stages in the same order, same resumes / parents /
report flags) to a from-scratch ``build_stage_tree`` — ``stage_trees_equal``
is the property-style check, and ``StageTreeBuilder(plan, verify=True)``
asserts it on every build.
"""

from __future__ import annotations

from dataclasses import dataclass, field
from typing import Dict, List, Optional, Set, Tuple

from repro.core.searchplan import Request, SearchPlan
from repro.core.values import desc_values

__all__ = ["Stage", "StageTree", "StageTreeBuilder", "build_stage_tree",
           "sibling_groups", "sibling_chain_groups", "stage_trees_equal"]


@dataclass
class Stage:
    """A schedulable unit: train node ``node_id`` over ``[start, stop)``.

    ``resume`` is ``(node_id, step)`` of the checkpoint to load, or ``None``
    for stages that either start from a fresh model (root, start=0) or chain
    directly after ``parent`` (same worker or cross-worker dependency).
    """

    stage_id: str
    node_id: str
    start: int
    stop: int
    resume: Optional[Tuple[str, int]] = None
    parent: Optional[str] = None                 # parent stage id
    children: List[str] = field(default_factory=list)
    report: bool = False                         # a request is satisfied at ``stop``

    @property
    def steps(self) -> int:
        return self.stop - self.start

    def __repr__(self):
        src = f"ckpt{self.resume}" if self.resume else (
            f"after {self.parent}" if self.parent else "fresh")
        return (f"Stage({self.stage_id}: {self.node_id}[{self.start}->{self.stop}]"
                f" {src}{' *report' if self.report else ''})")


class StageTree:
    """A forest of stages (multiple roots when requests resume from
    checkpoints at different points)."""

    def __init__(self):
        self.stages: Dict[str, Stage] = {}
        self.roots: List[str] = []
        self._counter = 0

    def new_stage(self, **kw) -> Stage:
        sid = f"stage-{self._counter}"
        self._counter += 1
        st = Stage(stage_id=sid, **kw)
        self.stages[sid] = st
        if st.parent is None:
            self.roots.append(sid)
        else:
            self.stages[st.parent].children.append(sid)
        return st

    def __len__(self):
        return len(self.stages)

    def total_steps(self) -> int:
        return sum(s.steps for s in self.stages.values())

    def leaves(self) -> List[Stage]:
        return [s for s in self.stages.values() if not s.children]

    def path_to_root(self, stage_id: str) -> List[Stage]:
        out, cur = [], stage_id
        while cur is not None:
            st = self.stages[cur]
            out.append(st)
            cur = st.parent
        return list(reversed(out))

    def __repr__(self):
        return f"StageTree({len(self.stages)} stages, {len(self.roots)} roots)"


# --------------------------------------------------------------------------
# Algorithm 1
# --------------------------------------------------------------------------

_FRESH = ("fresh", None, 0)
_DEFER = ("defer", None, 0)


def _find_latest_checkpoint(plan: SearchPlan, req: Request, lookup: Dict,
                            index: Optional[Dict[str, Set[Request]]] = None,
                            ) -> None:
    """Resolve ``req`` to a resume point, memoized in ``lookup``.

    lookup[req] is one of
      ("ckpt",  node_id, step) — load this checkpoint,
      ("parent", Request)      — chain after the parent request's stage,
      ("fresh", None, 0)       — train from a fresh model,
      ("defer", None, 0)       — a running execution covers part of the path;
                                 revisit in a later stage tree.

    ``index`` (incremental builder) maps node_id → requests whose resolution
    is cached for that node; every insertion is recorded there so the builder
    can invalidate exactly the entries a node mutation makes stale.
    """
    if req in lookup:                                            # memoized (line 18)
        return
    node = plan.node(req.node_id)
    if index is not None:
        index.setdefault(req.node_id, set()).add(req)

    # A running execution on this node will deposit checkpoints through the
    # range we need — defer instead of duplicating (Algorithm 1 line 15-16:
    # "if r.hp_config is running -> L.put(r, null)").
    if node.running:
        lookup[req] = _DEFER
        return

    # Nearest checkpoint within this node at or before the requested step
    # (lines 21-25, with the linear scan replaced by a dict lookup).
    ck = node.latest_ckpt_at_or_before(req.step)
    if ck is not None:
        lookup[req] = ("ckpt", node.node_id, ck)
        return

    if node.parent is None:                                      # line 18 (root)
        lookup[req] = _FRESH
        return

    # Recurse to the parent configuration at this node's start (lines 26-28).
    parent_req = Request(node.parent, node.start)
    _find_latest_checkpoint(plan, parent_req, lookup, index)
    if lookup[parent_req][0] == "defer":
        lookup[req] = _DEFER
    else:
        lookup[req] = ("parent", parent_req)


def build_stage_tree(plan: SearchPlan) -> StageTree:
    """Algorithm 1: generate the stage tree of all pending requests."""
    lookup: Dict[Request, tuple] = {}
    pending = plan.pending_requests()
    for req in pending:                                          # lines 3-5
        _find_latest_checkpoint(plan, req, lookup)
    return _emit_tree(plan, lookup, pending)


def _emission_inputs(plan: SearchPlan, lookup: Dict[Request, tuple]
                     ) -> Dict[str, Dict]:
    """Per-node cuts/resume derived from resolved lookup entries.

    Cuts are the resume step plus every requested step on the node that made
    it into the lookup table (original or intermediate parent requests).
    """
    by_node: Dict[str, Dict] = {}
    for req, res in lookup.items():
        if res[0] == "defer":
            continue
        info = by_node.setdefault(req.node_id, {"cuts": set(), "resume": None})
        info["cuts"].add(req.step)
        if res[0] == "ckpt":
            _, nid, step = res
            assert nid == req.node_id
            prev = info["resume"]
            # several requests may resolve to different ckpts in one node;
            # keep the earliest as the chain anchor and add the others as cuts
            if prev is None or step < prev:
                if prev is not None:
                    info["cuts"].add(prev)
                info["resume"] = step
            else:
                info["cuts"].add(step)
        elif res[0] == "fresh":
            node = plan.node(req.node_id)
            prev = info["resume"]
            if prev is None or node.start < prev:
                if prev is not None:
                    info["cuts"].add(prev)
                info["resume"] = node.start
    return by_node


def _node_segments(plan: SearchPlan, node_id: str, info: Dict,
                   pending_set: Set[Request]) -> Dict:
    """Pure per-node emission (Algorithm 1 lines 6-14, node-local part):
    ordered segment specs independent of global stage numbering, so the
    incremental builder can cache them across rounds.

    Returns ``{"segs": ((lo, hi, report), ...), "resume_ckpt", "via_parent",
    "parent_ckpt"}`` — ``lo == hi`` marks the zero-length eval-only stage
    (checkpoint present at a requested step but metrics missing).
    """
    node = plan.node(node_id)
    resume = info["resume"]
    anchor = resume if resume is not None else node.start
    cuts = sorted(c for c in info["cuts"] if c > anchor)
    resume_ckpt = (node_id, resume) if (
        resume is not None and resume in node.ckpts) else None
    via_parent = resume is None and node.parent is not None
    parent_ckpt = None
    if via_parent and node.start in plan.node(node.parent).ckpts:
        # parent resolved to a checkpoint exactly at node.start: load it
        # (used only when the parent emits no stage ending at node.start)
        parent_ckpt = (node.parent, node.start)
    segs: List[Tuple[int, int, bool]] = []
    if anchor in info["cuts"] and Request(node_id, anchor) in pending_set:
        segs.append((anchor, anchor, True))
    lo = anchor
    for hi in cuts:
        segs.append((lo, hi, Request(node_id, hi) in pending_set))
        lo = hi
    return {"segs": tuple(segs), "resume_ckpt": resume_ckpt,
            "via_parent": via_parent, "parent_ckpt": parent_ckpt}


def _emit_from_segments(plan: SearchPlan, order: List[str],
                        node_info: Dict[str, Dict]) -> StageTree:
    """Global numbering/linking pass: instantiate the stage forest from
    per-node segments, parents before children, in deterministic order."""
    tree = StageTree()
    made: Dict[Tuple[str, int], str] = {}   # (node_id, stop step) -> stage id
    done: Set[str] = set()

    def emit(node_id: str) -> None:
        if node_id in done:
            return
        done.add(node_id)
        info = node_info[node_id]
        node = plan.node(node_id)
        resume_ckpt = info["resume_ckpt"]
        parent_stage: Optional[str] = None
        if info["via_parent"]:
            # chain after parent node's stage ending at node.start
            if node.parent in node_info:
                emit(node.parent)
            parent_stage = made.get((node.parent, node.start))
            if parent_stage is None:
                resume_ckpt = info["parent_ckpt"]
        prev_stage: Optional[str] = None
        for lo, hi, report in info["segs"]:
            if lo == hi:  # zero-length eval-only stage
                st = tree.new_stage(
                    node_id=node_id, start=lo, stop=hi,
                    resume=resume_ckpt, parent=parent_stage, report=report)
                made[(node_id, hi)] = st.stage_id
                continue
            st = tree.new_stage(
                node_id=node_id, start=lo, stop=hi,
                resume=resume_ckpt if prev_stage is None else None,
                parent=prev_stage if prev_stage is not None else parent_stage,
                report=report)
            made[(node_id, hi)] = st.stage_id
            prev_stage = st.stage_id

    # Emit parents before children (requests on ancestors appear in order).
    for nid in order:
        emit(nid)
    return tree


def _emit_tree(plan: SearchPlan, lookup: Dict[Request, tuple],
               pending: List[Request]) -> StageTree:
    """Turn resolved requests into the stage forest (Algorithm 1 lines 6-14).

    ``lookup`` iteration order determines stage numbering; callers must pass
    entries in resolution order (ancestors before the requests that chain to
    them) so incremental and from-scratch builds emit identical trees.
    """
    pending_set: Set[Request] = set(pending)
    by_node = _emission_inputs(plan, lookup)
    order = sorted(by_node, key=plan.depth_of)
    node_info = {nid: _node_segments(plan, nid, by_node[nid], pending_set)
                 for nid in order}
    return _emit_from_segments(plan, order, node_info)


# --------------------------------------------------------------------------
# Sibling-trial batching groups (data-plane helper)
# --------------------------------------------------------------------------


def sibling_groups(plan: SearchPlan, tree: StageTree,
                   min_size: int = 2) -> List[List[Stage]]:
    """Ready sibling stages executable as ONE batched backend call.

    A stage qualifies when it is a chain head (no parent stage — its input
    is a resume checkpoint or a fresh model) with real training work; two
    such stages group when they train the same ``[start, stop)`` with the
    same static hyper-parameters (same optimizer — and ``share=False`` trial
    salts land here, so the trial-based baseline never batches), the same
    per-step hp names and the same batch-size schedule.  Members then share
    compiled executable and batch *shapes* and diverge only in hp *values*
    — exactly what the fused data plane vectorizes over a stacked trial
    axis (``TrainerBackend.run_stages_batched``).

    Groups preserve stage emission order; stages that fit no group (fewer
    than ``min_size`` members) are left to the ordinary chain scheduler.

    Two-phase signature: stages first bucket on the cheap structural key
    (step range, static hps, hp names); only buckets that could actually
    group materialize the per-step batch-size schedule, so rounds full of
    ungroupable ready stages never pay O(stage length) per stage.
    """
    buckets: Dict[Tuple, List[Stage]] = {}
    for st in tree.stages.values():
        if st.parent is not None or st.steps <= 0:
            continue
        node = plan.node(st.node_id)
        sig = (st.start, st.stop, plan.static_hash(st.node_id),
               tuple(sorted(node.desc["hps"])))
        buckets.setdefault(sig, []).append(st)

    out: List[List[Stage]] = []
    for cands in buckets.values():
        if len(cands) < min_size:
            continue
        by_bs: Dict[Optional[Tuple], List[Stage]] = {}
        for st in cands:
            by_bs.setdefault(_bs_signature(plan, st), []).append(st)
        out.extend(g for g in by_bs.values() if len(g) >= min_size)
    return out


def _bs_signature(plan: SearchPlan, st: Stage) -> Optional[Tuple]:
    """Per-step batch-size schedule of a stage (None = no bs sequence)."""
    node = plan.node(st.node_id)
    bs_piece = node.desc["hps"].get("bs")
    if bs_piece is None:
        return None
    bs = desc_values({"hps": {"bs": bs_piece}}, node.start,
                     st.start, st.stop)["bs"]
    return tuple(int(round(v)) for v in bs)


def _stage_signature(plan: SearchPlan, st: Stage) -> Tuple:
    """Full batchability signature: two stages with equal signatures can be
    one level of a batched sibling-chain group (same step range, static
    hps, hp names and bs schedule; hp *values* are free to diverge)."""
    node = plan.node(st.node_id)
    return (st.start, st.stop, plan.static_hash(st.node_id),
            tuple(sorted(node.desc["hps"])), _bs_signature(plan, st))


def sibling_chain_groups(plan: SearchPlan, tree: StageTree,
                         min_size: int = 2) -> List[List[List[Stage]]]:
    """Parallel sibling *chains* executable as one batched call per stage
    level (``TrainerBackend.run_chains_batched``).

    Each group starts from a :func:`sibling_groups` head group and extends
    downward while every member has exactly ONE child stage with real
    training work and all the children share the batchability signature
    (same ``[start, stop)``, static hps, hp names and bs schedule).  A fork
    (a member with several children) or a signature divergence stops the
    extension — the tails fall back to the ordinary chain scheduler.
    ``report`` flags are free to differ level by level: evaluation happens
    per member outside the batched call, at the boundary snapshot.

    Returns ``[group][member] -> chain (list of stages, depth >= 1)``; the
    depth-1 case is exactly the old sibling group.
    """
    out: List[List[List[Stage]]] = []
    for heads in sibling_groups(plan, tree, min_size):
        chains = [[st] for st in heads]
        frontier = heads
        while True:
            nexts: List[Stage] = []
            for st in frontier:
                if len(st.children) != 1:
                    break
                child = tree.stages[st.children[0]]
                if child.steps <= 0:
                    break
                nexts.append(child)
            else:
                sigs = {_stage_signature(plan, nx) for nx in nexts}
                if len(sigs) == 1:
                    for chain, nx in zip(chains, nexts):
                        chain.append(nx)
                    frontier = nexts
                    continue
            break
        out.append(chains)
    return out


# --------------------------------------------------------------------------
# Incremental builder
# --------------------------------------------------------------------------


def stage_trees_equal(a: StageTree, b: StageTree) -> bool:
    """Structural identity: same stage ids, intervals, resumes, parents,
    children order and report flags."""
    if list(a.stages) != list(b.stages) or a.roots != b.roots:
        return False
    for sid, sa in a.stages.items():
        sb = b.stages[sid]
        if (sa.node_id, sa.start, sa.stop, sa.resume, sa.parent,
                sa.children, sa.report) != (
                sb.node_id, sb.start, sb.stop, sb.resume, sb.parent,
                sb.children, sb.report):
            return False
    return True


class StageTreeBuilder:
    """Incremental Algorithm 1: memoize resolutions across scheduling rounds.

    The builder keeps the ``find_latest_checkpoint`` lookup table alive
    between builds.  Each build consumes the plan's change log and drops
    cached resolutions for every touched node *and its whole subtree* —
    a resolution only ever depends on the node's own checkpoints/running
    marks and those of its ancestors, so descendants of a changed node are
    exactly the entries that can go stale.  Requests are then resolved
    against the surviving cache (new/invalidated ones recompute, the rest
    hit), and the transient stage forest is emitted fresh, in from-scratch
    order, so the result is bit-identical to ``build_stage_tree(plan)``.

    When the plan's revision is unchanged since the previous build the
    previous tree is returned as-is (stage trees are read-only to the
    scheduler), making no-op scheduling rounds O(1).

    Emission is incremental too: the emitted forest persists across rounds,
    and since it is a pure function of the resolved request map and the
    pending list, a rebuild whose resolutions and pending set come out
    unchanged returns the previous forest outright — a round whose revision
    bumped without resolution effect (e.g. a submit that was satisfied
    immediately, or a no-op kill) re-emits nothing.

    Instrumentation: ``builds`` / ``tree_cache_hits`` count full builds vs
    same-revision returns; ``resolves`` / ``resolve_hits`` count Algorithm-1
    resolutions computed vs served from the memo; ``forest_reuses`` counts
    changed-revision rounds that still reused the emitted forest.
    """

    def __init__(self, plan: SearchPlan, verify: bool = False):
        self.plan = plan
        self.verify = verify
        self._lookup: Dict[Request, tuple] = {}
        self._by_node: Dict[str, Set[Request]] = {}
        self._seen_rev = 0
        self._cached_revision: Optional[int] = None
        self._cached_tree: Optional[StageTree] = None
        self._last_active: Optional[Dict[Request, tuple]] = None
        self._last_pending: Optional[List[Request]] = None
        self.builds = 0
        self.tree_cache_hits = 0
        self.resolves = 0
        self.resolve_hits = 0
        self.invalidated_nodes = 0
        self.forest_reuses = 0

    # ------------------------------------------------------------ invalidation
    def _invalidate(self, dirty: Set[str]) -> None:
        stack, seen = list(dirty), set()
        while stack:
            nid = stack.pop()
            if nid in seen:
                continue
            seen.add(nid)
            for req in self._by_node.pop(nid, ()):
                self._lookup.pop(req, None)
            stack.extend(self.plan.children.get(nid, ()))
        self.invalidated_nodes += len(seen)

    # ------------------------------------------------------------------ build
    def build(self) -> StageTree:
        plan = self.plan
        if (self._cached_tree is not None
                and plan.revision == self._cached_revision):
            self.tree_cache_hits += 1
            return self._cached_tree

        self._seen_rev, dirty = plan.changes_since(self._seen_rev)
        if dirty:
            self._invalidate(dirty)

        pending = plan.pending_requests()
        # Rebuild the *active* lookup — the closure of pending requests under
        # ("parent", req) links — in from-scratch insertion order: for each
        # pending request, its unresolved ancestor chain first (deepest
        # ancestor → request), skipping entries already active.
        active: Dict[Request, tuple] = {}
        lookup = self._lookup
        for req in pending:
            chain: List[Request] = []
            cur: Optional[Request] = req
            while cur is not None and cur not in active:
                res = lookup.get(cur)
                if res is None:
                    self.resolves += 1
                    _find_latest_checkpoint(plan, cur, lookup, self._by_node)
                    res = lookup[cur]
                else:
                    self.resolve_hits += 1
                chain.append(cur)
                cur = res[1] if res[0] == "parent" else None
            for r in reversed(chain):
                active[r] = lookup[r]

        # ---- incremental emission: the forest is a pure function of the
        # resolved request map and the pending list (every plan mutation
        # that could change emission either changes `pending` or touches a
        # node, which invalidates and re-resolves the affected entries), so
        # when both are unchanged the previous forest is returned without
        # re-emitting — a round whose revision bumped with no resolution
        # effect (e.g. a submit satisfied immediately) costs no emission ----
        if (self._cached_tree is not None and active == self._last_active
                and pending == self._last_pending):
            tree = self._cached_tree
            self.forest_reuses += 1
        else:
            tree = _emit_tree(plan, active, pending)
            self._last_active = active
            self._last_pending = pending
        self._cached_revision = plan.revision
        self._cached_tree = tree
        self.builds += 1
        if self.verify:
            ref = build_stage_tree(plan)
            assert stage_trees_equal(tree, ref), (
                f"incremental stage tree diverged from scratch build:\n"
                f"  incremental: {sorted(map(repr, tree.stages.values()))}\n"
                f"  scratch:     {sorted(map(repr, ref.stages.values()))}")
        return tree
