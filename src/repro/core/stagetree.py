"""Stage trees — transient scheduling representation (Hippo §3.1, Algorithm 1).

A *stage* is an executable step interval ``[start, stop)`` of one search-plan
node's hyper-parameter configuration.  Stage trees are generated on demand
from the search plan (they are "transient representations, used solely for
creating scheduling units, and are not kept in the system"), so the scheduler
stays stateless: all persistent state (checkpoints, metrics, requests) lives
in the plan.

``build_stage_tree`` implements the paper's Algorithm 1:

* ``find_latest_checkpoint`` resolves every not-yet-satisfied request to the
  nearest resume point — a checkpoint in the request's own node, a checkpoint
  in an ancestor (via a recursive parent request), or a fresh initialization.
  The lookup table memoizes resolutions and doubles as the set of stage
  boundary cuts.
* Requests whose resume path crosses a *currently running* node range are
  deferred (resolved to ``null`` in the paper): when the running stage
  finishes and checkpoints, a later stage tree picks the request up — exactly
  the "computation for A3 may be repeated again, later" behaviour of §3.2.
* Consecutive cuts inside one node become chained stages; the first stage of
  a node attaches either to its resume checkpoint or to the parent node's
  stage ending at ``node.start``.

:class:`StageTreeBuilder` is the incremental flavour of the same algorithm:
it memoizes ``find_latest_checkpoint`` resolutions across scheduling rounds,
keyed on the plan's ``revision``, and invalidates only the subtrees touched
by new results / running marks / checkpoint evictions.  The produced trees
are *identical* (same stages in the same order, same resumes / parents /
report flags) to a from-scratch ``build_stage_tree`` — ``stage_trees_equal``
is the property-style check, and ``StageTreeBuilder(plan, verify=True)``
asserts it on every build.
"""

from __future__ import annotations

from dataclasses import dataclass, field
from typing import Dict, List, Optional, Set, Tuple

from repro.core.searchplan import Request, SearchPlan

__all__ = ["Stage", "StageTree", "StageTreeBuilder", "build_stage_tree",
           "stage_trees_equal"]


@dataclass
class Stage:
    """A schedulable unit: train node ``node_id`` over ``[start, stop)``.

    ``resume`` is ``(node_id, step)`` of the checkpoint to load, or ``None``
    for stages that either start from a fresh model (root, start=0) or chain
    directly after ``parent`` (same worker or cross-worker dependency).
    """

    stage_id: str
    node_id: str
    start: int
    stop: int
    resume: Optional[Tuple[str, int]] = None
    parent: Optional[str] = None                 # parent stage id
    children: List[str] = field(default_factory=list)
    report: bool = False                         # a request is satisfied at ``stop``

    @property
    def steps(self) -> int:
        return self.stop - self.start

    def __repr__(self):
        src = f"ckpt{self.resume}" if self.resume else (
            f"after {self.parent}" if self.parent else "fresh")
        return (f"Stage({self.stage_id}: {self.node_id}[{self.start}->{self.stop}]"
                f" {src}{' *report' if self.report else ''})")


class StageTree:
    """A forest of stages (multiple roots when requests resume from
    checkpoints at different points)."""

    def __init__(self):
        self.stages: Dict[str, Stage] = {}
        self.roots: List[str] = []
        self._counter = 0

    def new_stage(self, **kw) -> Stage:
        sid = f"stage-{self._counter}"
        self._counter += 1
        st = Stage(stage_id=sid, **kw)
        self.stages[sid] = st
        if st.parent is None:
            self.roots.append(sid)
        else:
            self.stages[st.parent].children.append(sid)
        return st

    def __len__(self):
        return len(self.stages)

    def total_steps(self) -> int:
        return sum(s.steps for s in self.stages.values())

    def leaves(self) -> List[Stage]:
        return [s for s in self.stages.values() if not s.children]

    def path_to_root(self, stage_id: str) -> List[Stage]:
        out, cur = [], stage_id
        while cur is not None:
            st = self.stages[cur]
            out.append(st)
            cur = st.parent
        return list(reversed(out))

    def __repr__(self):
        return f"StageTree({len(self.stages)} stages, {len(self.roots)} roots)"


# --------------------------------------------------------------------------
# Algorithm 1
# --------------------------------------------------------------------------

_FRESH = ("fresh", None, 0)
_DEFER = ("defer", None, 0)


def _find_latest_checkpoint(plan: SearchPlan, req: Request, lookup: Dict,
                            index: Optional[Dict[str, Set[Request]]] = None,
                            ) -> None:
    """Resolve ``req`` to a resume point, memoized in ``lookup``.

    lookup[req] is one of
      ("ckpt",  node_id, step) — load this checkpoint,
      ("parent", Request)      — chain after the parent request's stage,
      ("fresh", None, 0)       — train from a fresh model,
      ("defer", None, 0)       — a running execution covers part of the path;
                                 revisit in a later stage tree.

    ``index`` (incremental builder) maps node_id → requests whose resolution
    is cached for that node; every insertion is recorded there so the builder
    can invalidate exactly the entries a node mutation makes stale.
    """
    if req in lookup:                                            # memoized (line 18)
        return
    node = plan.node(req.node_id)
    if index is not None:
        index.setdefault(req.node_id, set()).add(req)

    # A running execution on this node will deposit checkpoints through the
    # range we need — defer instead of duplicating (Algorithm 1 line 15-16:
    # "if r.hp_config is running -> L.put(r, null)").
    if node.running:
        lookup[req] = _DEFER
        return

    # Nearest checkpoint within this node at or before the requested step
    # (lines 21-25, with the linear scan replaced by a dict lookup).
    ck = node.latest_ckpt_at_or_before(req.step)
    if ck is not None:
        lookup[req] = ("ckpt", node.node_id, ck)
        return

    if node.parent is None:                                      # line 18 (root)
        lookup[req] = _FRESH
        return

    # Recurse to the parent configuration at this node's start (lines 26-28).
    parent_req = Request(node.parent, node.start)
    _find_latest_checkpoint(plan, parent_req, lookup, index)
    if lookup[parent_req][0] == "defer":
        lookup[req] = _DEFER
    else:
        lookup[req] = ("parent", parent_req)


def build_stage_tree(plan: SearchPlan) -> StageTree:
    """Algorithm 1: generate the stage tree of all pending requests."""
    lookup: Dict[Request, tuple] = {}
    pending = plan.pending_requests()
    for req in pending:                                          # lines 3-5
        _find_latest_checkpoint(plan, req, lookup)
    return _emit_tree(plan, lookup, pending)


def _emit_tree(plan: SearchPlan, lookup: Dict[Request, tuple],
               pending: List[Request]) -> StageTree:
    """Turn resolved requests into the stage forest (Algorithm 1 lines 6-14).

    ``lookup`` iteration order determines stage numbering; callers must pass
    entries in resolution order (ancestors before the requests that chain to
    them) so incremental and from-scratch builds emit identical trees.
    """
    tree = StageTree()
    pending_set: Set[Request] = set(pending)

    # Per-node cuts: resume step + every requested step on the node that made
    # it into the lookup table (original or intermediate parent requests).
    by_node: Dict[str, Dict] = {}
    for req, res in lookup.items():
        if res[0] == "defer":
            continue
        info = by_node.setdefault(req.node_id, {"cuts": set(), "resume": None})
        info["cuts"].add(req.step)
        if res[0] == "ckpt":
            _, nid, step = res
            assert nid == req.node_id
            prev = info["resume"]
            # several requests may resolve to different ckpts in one node;
            # keep the earliest as the chain anchor and add the others as cuts
            if prev is None or step < prev:
                if prev is not None:
                    info["cuts"].add(prev)
                info["resume"] = step
            else:
                info["cuts"].add(step)
        elif res[0] == "fresh":
            node = plan.node(req.node_id)
            prev = info["resume"]
            if prev is None or node.start < prev:
                if prev is not None:
                    info["cuts"].add(prev)
                info["resume"] = node.start

    # Nodes reached only through ("parent", ...) have resume=None: they chain
    # from the parent node's stage ending at node.start.
    made: Dict[Tuple[str, int], str] = {}   # (node_id, stop step) -> stage id
    done: Set[str] = set()                  # nodes fully emitted

    def emit_node(node_id: str) -> None:
        if node_id in done:
            return
        info = by_node[node_id]
        node = plan.node(node_id)
        resume = info["resume"]
        anchor_step = resume if resume is not None else node.start
        cuts = sorted(c for c in info["cuts"] if c > anchor_step)
        prev_stage: Optional[str] = None
        resume_ckpt = (node_id, resume) if (
            resume is not None and resume in node.ckpts) else None
        parent_stage: Optional[str] = None
        if resume is None and node.parent is not None:
            # chain after parent node's stage ending at node.start
            emit_node_if_needed(node.parent)
            parent_stage = made.get((node.parent, node.start))
            if parent_stage is None:
                # parent resolved to a checkpoint exactly at node.start: load it
                pnode = plan.node(node.parent)
                if node.start in pnode.ckpts:
                    resume_ckpt = (node.parent, node.start)
        # Checkpoint exists exactly at a requested step but metrics are
        # missing: emit a zero-length eval-only stage.
        if (anchor_step in info["cuts"]
                and Request(node_id, anchor_step) in pending_set):
            st = tree.new_stage(
                node_id=node_id, start=anchor_step, stop=anchor_step,
                resume=resume_ckpt, parent=parent_stage, report=True)
            made[(node_id, anchor_step)] = st.stage_id

        lo = anchor_step
        for hi in cuts:
            st = tree.new_stage(
                node_id=node_id, start=lo, stop=hi,
                resume=resume_ckpt if prev_stage is None else None,
                parent=prev_stage if prev_stage is not None else parent_stage,
                report=Request(node_id, hi) in pending_set,
            )
            made[(node_id, hi)] = st.stage_id
            prev_stage = st.stage_id
            lo = hi
        done.add(node_id)

    def emit_node_if_needed(node_id: str) -> None:
        if node_id in by_node and node_id not in done:
            emit_node(node_id)

    # Emit parents before children (requests on ancestors appear in by_node).
    order = sorted(by_node, key=plan.depth_of)
    for nid in order:
        emit_node_if_needed(nid)

    return tree


# --------------------------------------------------------------------------
# Incremental builder
# --------------------------------------------------------------------------


def stage_trees_equal(a: StageTree, b: StageTree) -> bool:
    """Structural identity: same stage ids, intervals, resumes, parents,
    children order and report flags."""
    if list(a.stages) != list(b.stages) or a.roots != b.roots:
        return False
    for sid, sa in a.stages.items():
        sb = b.stages[sid]
        if (sa.node_id, sa.start, sa.stop, sa.resume, sa.parent,
                sa.children, sa.report) != (
                sb.node_id, sb.start, sb.stop, sb.resume, sb.parent,
                sb.children, sb.report):
            return False
    return True


class StageTreeBuilder:
    """Incremental Algorithm 1: memoize resolutions across scheduling rounds.

    The builder keeps the ``find_latest_checkpoint`` lookup table alive
    between builds.  Each build consumes the plan's change log and drops
    cached resolutions for every touched node *and its whole subtree* —
    a resolution only ever depends on the node's own checkpoints/running
    marks and those of its ancestors, so descendants of a changed node are
    exactly the entries that can go stale.  Requests are then resolved
    against the surviving cache (new/invalidated ones recompute, the rest
    hit), and the transient stage forest is emitted fresh, in from-scratch
    order, so the result is bit-identical to ``build_stage_tree(plan)``.

    When the plan's revision is unchanged since the previous build the
    previous tree is returned as-is (stage trees are read-only to the
    scheduler), making no-op scheduling rounds O(1).

    Instrumentation: ``builds`` / ``tree_cache_hits`` count full builds vs
    same-revision returns; ``resolves`` / ``resolve_hits`` count Algorithm-1
    resolutions computed vs served from the memo.
    """

    def __init__(self, plan: SearchPlan, verify: bool = False):
        self.plan = plan
        self.verify = verify
        self._lookup: Dict[Request, tuple] = {}
        self._by_node: Dict[str, Set[Request]] = {}
        self._log_pos = 0
        self._cached_revision: Optional[int] = None
        self._cached_tree: Optional[StageTree] = None
        self.builds = 0
        self.tree_cache_hits = 0
        self.resolves = 0
        self.resolve_hits = 0
        self.invalidated_nodes = 0

    # ------------------------------------------------------------ invalidation
    def _invalidate(self, dirty: Set[str]) -> None:
        stack, seen = list(dirty), set()
        while stack:
            nid = stack.pop()
            if nid in seen:
                continue
            seen.add(nid)
            for req in self._by_node.pop(nid, ()):
                self._lookup.pop(req, None)
            stack.extend(self.plan.children.get(nid, ()))
        self.invalidated_nodes += len(seen)

    # ------------------------------------------------------------------ build
    def build(self) -> StageTree:
        plan = self.plan
        if (self._cached_tree is not None
                and plan.revision == self._cached_revision):
            self.tree_cache_hits += 1
            return self._cached_tree

        self._log_pos, dirty = plan.changes_since(self._log_pos)
        if dirty:
            self._invalidate(dirty)

        pending = plan.pending_requests()
        # Rebuild the *active* lookup — the closure of pending requests under
        # ("parent", req) links — in from-scratch insertion order: for each
        # pending request, its unresolved ancestor chain first (deepest
        # ancestor → request), skipping entries already active.
        active: Dict[Request, tuple] = {}
        lookup = self._lookup
        for req in pending:
            chain: List[Request] = []
            cur: Optional[Request] = req
            while cur is not None and cur not in active:
                res = lookup.get(cur)
                if res is None:
                    self.resolves += 1
                    _find_latest_checkpoint(plan, cur, lookup, self._by_node)
                    res = lookup[cur]
                else:
                    self.resolve_hits += 1
                chain.append(cur)
                cur = res[1] if res[0] == "parent" else None
            for r in reversed(chain):
                active[r] = lookup[r]

        tree = _emit_tree(plan, active, pending)
        self._cached_revision = plan.revision
        self._cached_tree = tree
        self.builds += 1
        if self.verify:
            ref = build_stage_tree(plan)
            assert stage_trees_equal(tree, ref), (
                f"incremental stage tree diverged from scratch build:\n"
                f"  incremental: {sorted(map(repr, tree.stages.values()))}\n"
                f"  scratch:     {sorted(map(repr, ref.stages.values()))}")
        return tree
