"""Public execution-engine facade — Hippo's scheduler/worker/aggregator loop.

This is the system of §4 run as a deterministic discrete-event simulation
over ``n_workers`` virtual workers (a *worker* is one GPU server slot in
the paper; one mesh slice in the TPU mapping).  The facade wires the real
components and keeps the seed module's public API:

* the **search plan** is the single source of truth (stateless scheduling),
* every scheduling round obtains a **stage tree** (Algorithm 1) from the
  incremental :class:`~repro.core.stagetree.StageTreeBuilder` — identical
  trees to a from-scratch build, O(changed requests) per round — and the
  scheduling policy extracts whole chains for idle workers
  (:mod:`repro.core.engine.dispatch`),
* chains execute through a :class:`~repro.core.trainer.TrainerBackend` —
  either real JAX training (wall-clock measured) or the analytic simulator
  (virtual durations) — and deposit checkpoints/metrics through the
  **aggregator** (:mod:`repro.core.engine.aggregator`) at their virtual
  completion times.  Chain-capable backends run whole chains **fused**
  (device-resident carry across stage boundaries) with **write-behind**
  boundary checkpoints (``CheckpointStore.put_async``; ``run()`` flushes
  the store before returning) — per-stage events, metrics and the virtual
  clock are unchanged,
* **tuners** observe metrics and submit/kill trials, closing the HPO loop.

Session model (service plane): the engine is a **long-lived session**, not
a batch call.  :meth:`step` processes exactly one event and re-runs the
dispatcher — the re-entrant unit the :class:`~repro.core.study.StudyService`
drives.  *Quiescence* (``quiescent``: the event heap is empty — nothing
running, nothing scheduled) is distinct from *termination* (:meth:`finish`:
the write-behind store flushed, ``end_to_end`` stamped): a quiescent
session stays open for late arrivals.  :meth:`admit` schedules a tuner's
arrival as an ``admit`` event on the virtual clock, so a study submitted
mid-drain wakes the dispatcher and merges into the in-flight stage forest
instead of requiring a fresh ``run()``.  Consecutive admissions at the
same virtual time start together before the next scheduling round —
upfront submission through the session is event-for-event identical to the
legacy batch ``run(tuners)``.  :meth:`cancel_study` detaches a study
mid-run: its waiters are dropped, and trials no other live study shares
are killed, releasing their plan nodes into checkpoint GC.

Accounting matches the paper's two measurements: ``gpu_seconds`` (sum of
busy time × GPUs per worker) and ``end-to-end`` time (virtual clock at
completion), plus ``ckpt_evictions`` for the beyond-paper checkpoint GC.
``EngineStats.by_study`` breaks execution down per study: a shared stage's
cost is split evenly across the studies it serves (reuse is free capacity),
while ``steps_run`` counts every step advanced *on behalf of* the study —
so the per-study step sums exceed the physical ``steps_run`` exactly when
stages are shared.

``share=False`` turns the engine into the **trial-based baseline**
(Ray Tune / "Hippo-trial"): every submitted trial is salted so its plan
nodes never merge with other trials' — identical scheduling machinery,
zero cross-trial reuse.  A trial still reuses *its own* checkpoints when a
tuner promotes it to a longer step budget, exactly like a paused/resumed
Ray Tune trial.
"""

from __future__ import annotations

from dataclasses import dataclass, field
from typing import Dict, List, Optional, Sequence, Set

from repro.core.hpseq import HpConfig
from repro.core.scheduler import CriticalPathScheduler, SchedulingPolicy
from repro.core.searchplan import SearchPlan
from repro.core.stagetree import StageTreeBuilder
from repro.core.engine.aggregator import Aggregator
from repro.core.engine.dispatch import Dispatcher, Worker
from repro.core.engine.events import EventLoop
from repro.core.faults import FaultyBackend, FaultyStore
from repro.core.trainer import TrainerBackend
from repro.core.trial import Trial
from repro.train.checkpoint import CheckpointStore

__all__ = ["ExecutionEngine", "Tuner", "StudyHandle", "EngineStats",
           "StudyStats"]


class Tuner:
    """Base class for HPO algorithms (client-library tuners, §5.2)."""

    objective: str = "val_acc"
    mode: str = "max"  # or "min"

    def start(self, handle: "StudyHandle") -> None:
        raise NotImplementedError

    def on_result(self, trial: Trial, step: int, metrics: Dict[str, float]) -> None:
        pass

    def is_done(self) -> bool:
        raise NotImplementedError

    # ------------------------------------------------------------- helpers
    def score(self, metrics: Dict[str, float]) -> float:
        v = metrics[self.objective]
        return v if self.mode == "max" else -v


@dataclass
class StudyHandle:
    """The submission interface a tuner sees (the client library's view)."""

    engine: "ExecutionEngine"
    tuner: Tuner
    study_id: str = "study-0"

    def submit(self, trial: Trial, upto: Optional[int] = None) -> None:
        self.engine._submit(self, trial, upto)

    def kill(self, trial: Trial) -> None:
        self.engine._kill(self, trial)

    def __getstate__(self):
        # session snapshots never capture the engine (it holds the backend
        # and the store's writer thread); StudyService.restore re-wires it
        d = self.__dict__.copy()
        d["engine"] = None
        return d


@dataclass
class StudyStats:
    """Per-study slice of the engine accounting.

    ``gpu_seconds`` is the study's *split share* of stage execution time
    (a stage serving k studies charges each 1/k — reuse shows up as each
    study paying less), excluding resume-load overheads.  ``steps_run`` /
    ``stages_run`` count work advanced **on behalf of** the study in full,
    so their sum across studies exceeds the engine totals exactly when
    stages are shared.  ``instant_results`` counts requests answered
    straight from already-present plan metrics (§3.2's immediate response
    — the purest form of cross-study reuse a late arrival sees).
    """

    gpu_seconds: float = 0.0
    steps_run: int = 0
    stages_run: int = 0
    trials: int = 0
    instant_results: int = 0


@dataclass
class EngineStats:
    gpu_seconds: float = 0.0
    end_to_end: float = 0.0
    stages_run: int = 0
    steps_run: int = 0
    evals_run: int = 0
    ckpt_loads: int = 0
    ckpt_saves: int = 0
    ckpt_evictions: int = 0
    rounds: int = 0
    chains_deferred: int = 0  # chains whose in-round input was truncated away
    batched_groups: int = 0   # sibling groups executed as one backend call
    batched_stages: int = 0   # stages covered by those groups
    ckpt_misses: int = 0      # vanished resume ckpts degraded to recompute
    chain_fused_stages: int = 0   # stages advanced via backend.run_chain(s)
    ckpt_async_writes: int = 0    # write-behind boundary checkpoints
    kernel_calls: int = 0         # kernel-plane call sites traced (backend-
                                  # cumulative; see JaxTrainer.kernel_calls)
    kernel_fallbacks: int = 0     # kernel→oracle fallbacks traced
    ckpt_save_seconds: float = 0.0  # synchronous slice of store puts
    ckpt_load_seconds: float = 0.0  # store gets (resume loads)
    # ---- distribution plane v2 (mesh workers; see dispatch.py) ----
    d2d_handoffs: int = 0           # resumes served device-to-device (no
                                    # store round-trip; same-host producer)
    mesh_placements: int = 0        # chains/groups executed on mesh workers
    placement_rejections: int = 0   # idle mesh workers skipped for a work
                                    # unit (backend divisibility gate)
    # ---- checkpoint plane v2 (mirrored from CheckpointStore as growth
    # deltas per attached dispatcher; see Dispatcher._sync_store_stats) ----
    ckpt_delta_bytes: int = 0       # file bytes of delta-encoded commits
    ckpt_full_bytes: int = 0        # file bytes of full-snapshot commits
    ckpt_logical_bytes: int = 0     # full-serialization-equivalent bytes
    ckpt_bytes_written: int = 0     # physical bytes committed (delta+full)
    ckpt_delta_commits: int = 0
    ckpt_delta_rebases: int = 0     # depth-bound chains rebased to full
    ckpt_mem_hits: int = 0          # gets served from pending/memory/LRU
    ckpt_disk_hits: int = 0         # gets served from the local disk tier
    ckpt_remote_hits: int = 0       # gets served from the remote tier
    ckpt_store_misses: int = 0      # gets no tier could serve (KeyError)
    ckpt_tier_promotions: int = 0   # remote blobs rehydrated onto disk
    ckpt_tier_demotions: int = 0    # LRU disk blobs pushed to remote
    ckpt_tmp_reclaimed: int = 0     # stale temp files swept at store init
    # ---- fault plane (see core/faults.py + the dispatcher failure
    # domains).  wasted_gpu_seconds is charged separately from
    # gpu_seconds and NEVER split-charged into by_study — a retry is the
    # engine's waste, not the sharing studies' bill. ----
    stage_failures: int = 0         # failed execution attempts absorbed
    stage_retries: int = 0          # retries scheduled (transient faults)
    workers_quarantined: int = 0    # quarantine entries (repeat crashers)
    groups_degraded: int = 0        # batched groups degraded to solo runs
    faults_injected: int = 0        # injector faults fired (delta-mirrored
                                    # like the store counters)
    wasted_gpu_seconds: float = 0.0  # GPU time burned by failed attempts
    by_study: Dict[str, StudyStats] = field(default_factory=dict)

    @property
    def gpu_hours(self) -> float:
        return self.gpu_seconds / 3600.0

    @property
    def dedup_ratio(self) -> float:
        """Full-serialization bytes per physical byte this engine wrote
        (>1 ⇔ delta encoding is saving storage)."""
        return (self.ckpt_logical_bytes / self.ckpt_bytes_written
                if self.ckpt_bytes_written else 1.0)

    def study(self, study_id: str) -> StudyStats:
        return self.by_study.setdefault(study_id, StudyStats())


class ExecutionEngine:
    def __init__(self, plan: SearchPlan, backend: TrainerBackend,
                 n_workers: int = 4, gpus_per_worker: int = 1,
                 scheduler: Optional[SchedulingPolicy] = None,
                 store: Optional[CheckpointStore] = None,
                 share: bool = True,
                 max_steps_per_chain: Optional[int] = None,
                 batch_siblings: Optional[bool] = None,
                 chain_fusion: Optional[bool] = None,
                 worker_meshes: Optional[Sequence] = None,
                 fault_injector=None):
        # fault plane: wrap backend and store in the injector's fault
        # surface BEFORE anything reads capability flags or touches the
        # store — the whole engine then sees the faulty views, and the
        # dispatcher discovers the injector via backend.fault_injector
        if fault_injector is not None:
            backend = FaultyBackend(backend, fault_injector)
        self.fault_injector = fault_injector
        self.plan = plan
        self.backend = backend
        # worker_meshes: per-worker WorkerMesh descriptors (None entries =
        # classic thread workers); shorter lists pad with None
        meshes = list(worker_meshes or [])
        if len(meshes) > n_workers:
            raise ValueError(
                f"{len(meshes)} worker meshes for {n_workers} workers")
        meshes += [None] * (n_workers - len(meshes))
        self.workers = [Worker(i, mesh=m) for i, m in enumerate(meshes)]
        self._next_wid = n_workers    # ids are never reused (dynamic fleets)
        self.gpus_per_worker = gpus_per_worker
        self.scheduler = scheduler or CriticalPathScheduler()
        # NOT `store or ...`: an empty CheckpointStore is falsy (__len__ == 0)
        # and would be silently replaced, orphaning the caller's store
        self.store = CheckpointStore() if store is None else store
        if fault_injector is not None and not isinstance(self.store,
                                                         FaultyStore):
            self.store = FaultyStore(self.store, fault_injector)
        self.share = share
        self.max_steps_per_chain = max_steps_per_chain
        # sibling-trial batching defaults to whatever the backend supports
        # (one vmapped/fused call per ready sibling group; see dispatch.py)
        if batch_siblings is None:
            batch_siblings = bool(getattr(backend, "supports_batched_stages",
                                          False))
        self.batch_siblings = batch_siblings
        # chain fusion (device-resident carries across stage boundaries +
        # write-behind boundary checkpoints) defaults to backend support;
        # unlike batch_siblings, forcing True cannot override a backend
        # without run_chain support — there is no correct way to fuse it
        supported = bool(getattr(backend, "supports_chain_fusion", False))
        self.chain_fusion = (supported if chain_fusion is None
                             else chain_fusion and supported)
        self.stats = EngineStats()
        self.events = EventLoop()
        self.builder = StageTreeBuilder(plan)
        self.dispatcher = Dispatcher(
            plan, backend, self.scheduler, self.store, self.events,
            self.stats, self.workers, gpus_per_worker=gpus_per_worker,
            max_steps_per_chain=max_steps_per_chain, builder=self.builder,
            batch_siblings=batch_siblings, chain_fusion=self.chain_fusion)
        self.aggregator = Aggregator(plan, self.store, self.stats, self.events)
        self._trials: Dict[str, Trial] = {}
        self._handles: List[StudyHandle] = []
        self._study_trials: Dict[str, Set[str]] = {}
        self._started: Set[str] = set()      # study ids whose tuner ran start()
        self._cancelled: Set[str] = set()    # study ids detached by cancel

    # ------------------------------------------------------------ properties
    @property
    def time(self) -> float:
        """Virtual clock (owned by the event loop)."""
        return self.events.time

    @property
    def quiescent(self) -> bool:
        """True when nothing is running or scheduled (the event heap is
        empty).  Quiescence is NOT termination: a quiescent session stays
        open — a later :meth:`admit` wakes it again."""
        return not self.events

    # ----------------------------------------------------------- worker fleet
    def worker(self, wid: int) -> Optional[Worker]:
        """The live worker with id ``wid`` (None once removed).  Workers
        are keyed by id, not list position — dynamic fleets (front-door
        leases) remove workers mid-session, so positions shift."""
        for w in self.workers:
            if w.wid == wid:
                return w
        return None

    def add_worker(self, mesh=None, at: Optional[float] = None) -> Worker:
        """Grow the fleet by one worker (front-door lease grant).

        The worker is idle immediately but cannot *start* work before
        ``at`` (default: now) — ``busy_until`` gates its first chain, so a
        worker leased over from another session at global time T does not
        retroactively compute in the past."""
        t = self.events.time if at is None else max(at, self.events.time)
        w = Worker(self._next_wid, busy_until=t, mesh=mesh)
        self._next_wid += 1
        self.workers.append(w)     # the dispatcher shares this list object
        if mesh is not None:
            self.dispatcher._d2d_enabled = True
        # a session that drained its event queue while starved of workers
        # has nothing left to trigger a dispatcher round — the grant itself
        # must be schedulable, or waiting stages would never start
        self.events.push(t, "wake", w.wid)
        return w

    def remove_worker(self, wid: int) -> bool:
        """Shrink the fleet (front-door lease revocation).  An idle worker
        leaves immediately (True); a busy one is marked draining and leaves
        when its current chain's idle event fires (False) — revocation
        only ever lands at a chain boundary, where every boundary
        checkpoint is already committed, so no work is lost."""
        w = self.worker(wid)
        if w is None:
            return True
        if w.idle:
            self.workers.remove(w)
            return True
        w.draining = True
        return False

    # ------------------------------------------------------------------ API
    def handle(self, tuner: Tuner, study_id: Optional[str] = None) -> StudyHandle:
        h = StudyHandle(self, tuner, study_id or f"study-{len(self._handles)}")
        self._handles.append(h)
        return h

    def admit(self, tuner: Tuner, study_id: Optional[str] = None,
              at: Optional[float] = None) -> StudyHandle:
        """Schedule a study's arrival on the virtual clock (service plane).

        The tuner starts when the ``admit`` event fires — at ``max(at,
        now)`` — and the dispatcher immediately merges its requests into
        the in-flight stage forest.  Admissions landing at the same
        virtual time start together before the next scheduling round, so
        a batch admitted at the current time is indistinguishable from a
        legacy ``run([tuners])``."""
        h = self.handle(tuner, study_id)
        t = self.events.time if at is None else max(at, self.events.time)
        self.events.push(t, "admit", h)
        return h

    def run(self, tuners: List[Tuner]) -> EngineStats:
        """One-shot session: run tuners to completion; returns stats."""
        handles = [self.handle(t) for t in tuners]
        for h in handles:
            self._start_handle(h)
        try:
            self.drain()
            not_done = [h.tuner for h in handles
                        if h.study_id not in self._cancelled
                        and not h.tuner.is_done()]
            if not_done:
                raise RuntimeError(
                    f"engine drained but {len(not_done)} tuner(s) not done — "
                    "a tuner is waiting on a request that was never submitted")
        finally:
            self.finish()
        return self.stats

    # ------------------------------------------------------------- internal
    def _salted(self, trial: Trial, study_id: str) -> Trial:
        """Trial-based baseline: make the plan treat every (study, trial)
        pair as unshareable — the salt must include the study id, or two
        identical studies would still dedup across each other."""
        if self.share:
            return trial
        cfg = trial.hp_config
        static = dict(cfg.static)
        static["_trial_salt"] = f"{study_id}/{trial.trial_id}"
        return Trial(HpConfig(dict(cfg.fns), static), trial.total_steps,
                     trial_id=trial.trial_id, meta=dict(trial.meta))

    def _submit(self, handle: StudyHandle, trial: Trial,
                upto: Optional[int]) -> None:
        trial = self._salted(trial, handle.study_id)
        self._trials[trial.trial_id] = trial
        owned = self._study_trials.setdefault(handle.study_id, set())
        if trial.trial_id not in owned:
            owned.add(trial.trial_id)
            self.stats.study(handle.study_id).trials += 1
        node, step, satisfied = self.plan.submit(trial, upto,
                                                 study=handle.study_id)
        if satisfied:
            # §3.2: results already present → respond immediately (still an
            # event so tuner callbacks observe a consistent clock).
            self.stats.study(handle.study_id).instant_results += 1
            metrics = self.plan.metrics_for(node.node_id, step)
            self.events.push(self.events.time, "reply",
                             (handle, trial, step, metrics))
            return
        self.aggregator.add_waiter(node.node_id, step, handle, trial)

    def _kill(self, handle: StudyHandle, trial: Trial) -> None:
        self.aggregator.kill(trial.trial_id)

    # ----------------------------------------------------------- cancellation
    def cancel_study(self, study_id: str) -> None:
        """Detach a study mid-run: drop its waiters, and kill every trial
        no other live study shares — releasing their plan nodes into
        checkpoint GC.  Nodes (and trials) another study still references
        are untouched; in-flight stages keep running, and results landing
        on nodes the cancel left unreferenced are evicted on arrival."""
        if study_id in self._cancelled:
            return
        self._cancelled.add(study_id)
        self.aggregator.detach_study(study_id)
        for tid in sorted(self._study_trials.get(study_id, ())):
            self.plan.detach_study(tid, study_id)
            if not self.plan.studies_of_trial(tid) - self._cancelled:
                self.aggregator.kill(tid)

    # ------------------------------------------------------------ main loop
    def step(self) -> bool:
        """Process exactly one event, then re-run the dispatcher.  The
        re-entrant unit of the session loop — returns False at quiescence
        (nothing left to do until the next admission)."""
        if not self.events:
            return False
        ev = self.events.pop()
        if ev.kind == "stage":
            self.aggregator.on_stage_done(ev.payload)
        elif ev.kind == "reply":
            handle, trial, step, metrics = ev.payload
            if (trial.trial_id not in self.aggregator.killed
                    and handle.study_id not in self._cancelled):
                handle.tuner.on_result(trial, step, metrics)
        elif ev.kind == "idle":
            # keyed by wid, not list index: dynamic fleets (front-door
            # leases) remove workers mid-session, so positions shift and
            # an event may outlive its worker
            w = self.worker(ev.payload)
            if w is not None:
                if w.draining:
                    # revoked lease: the chain boundary has been reached —
                    # the worker departs instead of rejoining the pool
                    self.workers.remove(w)
                else:
                    w.idle = True
        elif ev.kind == "wake":
            # lease grant landed: nothing to mutate — the dispatcher round
            # below hands the new worker any stages that were waiting for
            # capacity
            pass
        elif ev.kind == "retry":
            # backoff expired: release the failed stages' running marks so
            # Algorithm 1 re-derives them from the last boundary checkpoint
            # in the dispatcher round below
            self.dispatcher.on_retry(ev.payload)
        elif ev.kind == "admit":
            # start every admission landing at this instant before the next
            # scheduling round: same-time arrivals merge as one batch,
            # making upfront service submission identical to run(tuners)
            self._start_handle(ev.payload)
            while self.events:
                nxt = self.events.peek()
                if nxt.kind != "admit" or nxt.time > self.events.time:
                    break
                self._start_handle(self.events.pop().payload)
        self.dispatcher.assign()
        return True

    def drain(self) -> None:
        """Run to quiescence (the legacy ``_drain`` loop, re-entrant)."""
        self.dispatcher.assign()
        while self.step():
            pass

    def finish(self) -> EngineStats:
        """Terminate the session: barrier the write-behind store (every
        pending boundary checkpoint durably committed, writer failures
        surfaced) and stamp ``end_to_end``.  Idempotent."""
        self.store.flush()
        # pick up counter growth from the flushed write-behind commits
        self.dispatcher._sync_store_stats()
        self.dispatcher._sync_fault_stats()
        self.stats.end_to_end = self.events.time
        return self.stats

    def _start_handle(self, h: StudyHandle) -> None:
        if h.study_id in self._cancelled or h.study_id in self._started:
            return
        self._started.add(h.study_id)
        h.tuner.start(h)
