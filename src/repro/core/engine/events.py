"""Event heap and virtual clock for the discrete-event engine.

The engine is a deterministic discrete-event simulation: every state change
(stage completion, tuner reply, worker going idle) is an :class:`Event` on
one monotonic heap, ordered by (time, insertion seq) so simultaneous events
replay in submission order — the property that makes runs byte-reproducible.
"""

from __future__ import annotations

import heapq
import itertools
from dataclasses import dataclass, field
from typing import Any, List, Optional

__all__ = ["Event", "EventLoop"]


@dataclass(order=True)
class Event:
    time: float
    seq: int
    kind: str = field(compare=False)
    payload: Any = field(compare=False)


class EventLoop:
    """Min-heap of events plus the virtual clock they advance."""

    def __init__(self):
        self.time = 0.0
        self._events: List[Event] = []
        self._seq = itertools.count()

    def push(self, t: float, kind: str, payload: Any) -> None:
        heapq.heappush(self._events, Event(t, next(self._seq), kind, payload))

    def pop(self) -> Event:
        """Pop the earliest event and advance the clock to it."""
        ev = heapq.heappop(self._events)
        assert ev.time >= self.time - 1e-9
        self.time = max(self.time, ev.time)
        return ev

    def peek(self) -> Optional[Event]:
        """The earliest event without popping it (None when empty)."""
        return self._events[0] if self._events else None

    def __bool__(self) -> bool:
        return bool(self._events)

    def __len__(self) -> int:
        return len(self._events)
