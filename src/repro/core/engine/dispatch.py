"""Chain dispatch — scheduling rounds and worker-side chain execution.

Each round the dispatcher asks the :class:`~repro.core.stagetree.StageTreeBuilder`
for the current stage tree (incrementally maintained — O(changed requests),
not O(plan)), runs its **grouping pass** (when the backend batches sibling
stages: collect ready siblings with identical step range / static hps /
batch shapes via :func:`~repro.core.stagetree.sibling_groups` and execute
each group as ONE batched backend call on one worker), hands the remaining
tree to the scheduling policy, and executes the extracted chains on idle
virtual workers: load the resume checkpoint (or chain off a state produced
earlier in the same round — including states a batched group produced), run
each stage through the trainer backend, checkpoint at every stage boundary,
and post a ``stage`` event at the virtual completion time for the
aggregator.

Recompute-on-miss: a resume checkpoint the plan still lists but the store
has dropped (external eviction) does not raise — the dispatcher counts a
``ckpt_miss``, tells the plan to forget the stale entry, refunds the
scheduler, and re-runs the round: Algorithm 1 re-derives the request from
whatever remains (an earlier checkpoint, an ancestor, or a fresh model).
"""

from __future__ import annotations

import time as _time
from dataclasses import dataclass
from typing import Any, Dict, List, Optional, Tuple

from repro.core.scheduler import SchedulingPolicy
from repro.core.searchplan import Request, SearchPlan
from repro.core.stagetree import Stage, StageTreeBuilder, sibling_groups
from repro.core.engine.events import EventLoop
from repro.core.trainer import StageContext, TrainerBackend
from repro.train.checkpoint import CheckpointStore

__all__ = ["Worker", "Dispatcher"]


@dataclass
class Worker:
    wid: int
    busy_until: float = 0.0
    idle: bool = True


class Dispatcher:
    def __init__(self, plan: SearchPlan, backend: TrainerBackend,
                 scheduler: SchedulingPolicy, store: CheckpointStore,
                 events: EventLoop, stats, workers: List[Worker],
                 gpus_per_worker: int = 1,
                 max_steps_per_chain: Optional[int] = None,
                 builder: Optional[StageTreeBuilder] = None,
                 batch_siblings: bool = False):
        self.plan = plan
        self.backend = backend
        self.scheduler = scheduler
        self.store = store
        self.events = events
        self.stats = stats
        self.workers = workers
        self.gpus_per_worker = gpus_per_worker
        self.max_steps_per_chain = max_steps_per_chain
        self.builder = builder or StageTreeBuilder(plan)
        self.batch_siblings = batch_siblings

    # ------------------------------------------------------------ scheduling
    def assign(self) -> None:
        # a checkpoint miss mutates the plan (the stale entry is forgotten)
        # and leaves its requests pending with the worker still idle: re-run
        # the round so Algorithm 1 re-derives them.  Each retry forgets at
        # least one stale checkpoint entry, so the loop terminates.
        while self._assign_round():
            pass

    def _assign_round(self) -> bool:
        """One scheduling round; True when a checkpoint miss warrants a
        retry (idle workers remain and requests were re-derived)."""
        idle = [w for w in self.workers if w.idle]
        if not idle:
            return False
        tree = self.builder.build()
        if not tree.stages:
            return False
        self.stats.rounds += 1
        missed = False
        # stage_id -> (state, finish_time) for cross-chain chaining this round
        produced: Dict[str, Tuple[Any, float]] = {}
        taken: set = set()

        if self.batch_siblings:
            for group in sibling_groups(self.plan, tree):
                if not idle:
                    break
                ran, miss = self._execute_group(group, idle[0], produced,
                                                taken)
                missed |= miss
                if ran:
                    idle.pop(0)

        paths = self.scheduler.assign(self.plan, tree, len(idle), taken=taken)
        for path, worker in zip(paths, idle):
            if self.max_steps_per_chain:
                full = path
                path = self._truncate(full)
                if len(path) < len(full):
                    # refund the cut tail: it reschedules in a later round
                    self.scheduler.on_stages_unassigned(
                        self.plan, full[len(path):])
            missed |= self._execute_chain(path, worker, produced)
        return missed and any(w.idle for w in self.workers)

    def _truncate(self, path: List[Stage]) -> List[Stage]:
        out, steps = [], 0
        for st in path:
            out.append(st)
            steps += st.steps
            if steps >= self.max_steps_per_chain:
                break
        return out

    # ---------------------------------------------------------- resume input
    def _load_resume(self, nid: str, step: int) -> Optional[Any]:
        """State of checkpoint (node, step), or None after degrading a
        vanished checkpoint to recompute: count the miss and make the plan
        forget the stale entry so the next round re-derives the request.
        A checkpoint the plan no longer lists (already forgotten earlier
        this round) is not a fresh miss — one eviction counts once."""
        cid = self.plan.node(nid).ckpts.get(step)
        if cid is not None:
            try:
                return self.store.get(cid)
            except KeyError:
                pass
            self.stats.ckpt_misses += 1
            self.plan.forget_ckpt(nid, step)
        return None

    def _ctx_for(self, st: Stage) -> StageContext:
        node = self.plan.node(st.node_id)
        return StageContext(
            node_id=st.node_id, desc=node.desc, node_start=node.start,
            start=st.start, stop=st.stop,
            path_key=self.plan.path_key(st.node_id))

    def _compile_adjusted_wall(self, wall0: float, comp0: float) -> float:
        """Measured wall minus the backend's compile-time delta: one-time
        executable compilation must not pollute seconds/step profiles or
        the virtual clock (it amortizes across the study)."""
        wall = _time.perf_counter() - wall0
        comp = getattr(self.backend, "compile_seconds", 0.0) - comp0
        return max(0.0, wall - comp)

    # ------------------------------------------------------- chain execution
    def _execute_chain(self, path: List[Stage], worker: Worker,
                       produced: Dict[str, Tuple[Any, float]]) -> bool:
        """Execute one chain; True when a checkpoint miss deferred it."""
        head = path[0]
        t = max(self.events.time, worker.busy_until)
        load_s, save_s = self.backend.overheads()

        # ------- input state
        if head.resume is not None:
            nid, step = head.resume
            state = self._load_resume(nid, step)
            if state is None:
                # resume checkpoint externally dropped — leave the requests
                # pending; the retried round re-derives them from the plan
                self.scheduler.on_stages_unassigned(self.plan, path)
                return True
            t += load_s
            self.stats.gpu_seconds += load_s * self.gpus_per_worker
            self.stats.ckpt_loads += 1
        elif head.parent is not None:
            if head.parent not in produced:
                # parent chain was truncated before producing our input —
                # leave the requests pending; a later round reschedules them
                worker.idle = True
                self.stats.chains_deferred += 1
                self.scheduler.on_stages_unassigned(self.plan, path)
                return False
            # produced by another chain in this same round
            state, parent_done = produced[head.parent]
            t = max(t, parent_done) + load_s
            self.stats.gpu_seconds += load_s * self.gpus_per_worker
            self.stats.ckpt_loads += 1
        else:
            state = self.backend.init_state()

        worker.idle = False
        for st in path:
            ctx = self._ctx_for(st)
            self.plan.mark_running([Request(st.node_id, st.stop)])

            comp0 = getattr(self.backend, "compile_seconds", 0.0)
            wall0 = _time.perf_counter()
            if st.steps > 0:
                state = self.backend.run_stage(state, ctx)
            metrics = self.backend.evaluate(state, ctx) if st.report else None
            wall = self._compile_adjusted_wall(wall0, comp0)

            sim = self.backend.stage_seconds(ctx)
            dur = sim if sim is not None else wall
            if st.report:
                dur += getattr(self.backend, "eval_seconds", 0.0)
                self.stats.evals_run += 1
            dur += save_s  # checkpoint at every stage boundary
            self.stats.ckpt_saves += 1
            t += dur
            self.stats.gpu_seconds += dur * self.gpus_per_worker
            self.stats.stages_run += 1
            self.stats.steps_run += st.steps

            if st.steps > 0:
                self.plan.record_profile(
                    st.node_id, (sim if sim is not None else wall) / st.steps)
            cid = self.store.put(ctx.path_key, st.stop, state)
            produced[st.stage_id] = (state, t)
            self.events.push(t, "stage", {
                "node_id": st.node_id, "stop": st.stop, "cid": cid,
                "metrics": metrics, "worker": worker.wid,
                "last": st is path[-1]})
        worker.busy_until = t
        return False

    # ------------------------------------------------------- group execution
    def _execute_group(self, group: List[Stage], worker: Worker,
                       produced: Dict[str, Tuple[Any, float]],
                       taken: set) -> Tuple[bool, bool]:
        """Execute a sibling group as one batched backend call on ``worker``.

        Returns ``(ran, missed)``.  Members whose resume checkpoint vanished
        are refunded to the scheduler and left pending (recompute-on-miss);
        if fewer than two members survive, the whole group is refunded and
        its stages fall through to the ordinary chain scheduler this round.
        """
        t = max(self.events.time, worker.busy_until)
        load_s, save_s = self.backend.overheads()
        missed = False
        members: List[Stage] = []
        states: List[Any] = []
        loaded: Dict[str, Any] = {}   # resume cid -> state (dedup sibling loads)
        for st in group:
            self.scheduler.on_path_assigned(self.plan, [st])
            if st.resume is not None:
                nid, step = st.resume
                cid = self.plan.node(nid).ckpts.get(step)
                state = loaded.get(cid) if cid is not None else None
                if state is None:
                    state = self._load_resume(nid, step)
                    if state is None:
                        missed = True
                        self.scheduler.on_stages_unassigned(self.plan, [st])
                        continue
                    loaded[cid] = state
            else:
                state = self.backend.init_state()
            members.append(st)
            states.append(state)
        if len(members) < 2:
            # group fell apart — refund survivors; the chain scheduler picks
            # them up (they are not marked taken)
            for st in members:
                self.scheduler.on_stages_unassigned(self.plan, [st])
            return False, missed

        n_loads = len(loaded)
        t += load_s * n_loads
        self.stats.gpu_seconds += load_s * n_loads * self.gpus_per_worker
        self.stats.ckpt_loads += n_loads

        ctxs = []
        for st in members:
            ctxs.append(self._ctx_for(st))
            taken.add(st.stage_id)
        self.plan.mark_running([Request(st.node_id, st.stop)
                                for st in members])
        worker.idle = False

        comp0 = getattr(self.backend, "compile_seconds", 0.0)
        wall0 = _time.perf_counter()
        try:
            new_states = self.backend.run_stages_batched(states, ctxs)
            batched = True
        except ValueError:
            # in-flight incompatibility (e.g. divergent restored batch
            # sizes): fall back to member-sequential execution — same
            # semantics, no batching credit
            new_states = [self.backend.run_stage(s, c)
                          for s, c in zip(states, ctxs)]
            batched = False
        # evaluation is part of the measured window, as in the chain path
        metrics_l = [self.backend.evaluate(s, c) if st.report else None
                     for st, c, s in zip(members, ctxs, new_states)]
        wall = self._compile_adjusted_wall(wall0, comp0)

        sims = [self.backend.stage_seconds(c) for c in ctxs]
        dur = wall if any(s is None for s in sims) else sum(sims)
        entries = []
        for st, ctx, state, sim in zip(members, ctxs, new_states, sims):
            if st.report:
                dur += getattr(self.backend, "eval_seconds", 0.0)
                self.stats.evals_run += 1
            dur += save_s  # checkpoint per member at the stage boundary
            self.stats.ckpt_saves += 1
            self.stats.stages_run += 1
            self.stats.steps_run += st.steps
            if st.steps > 0:
                per_step = (sim if sim is not None
                            else wall / len(members)) / st.steps
                self.plan.record_profile(st.node_id, per_step)
            entries.append((ctx.path_key, st.stop, state))
        cids = self.store.put_stacked(entries)

        t += dur
        self.stats.gpu_seconds += dur * self.gpus_per_worker
        if batched:
            self.stats.batched_groups += 1
            self.stats.batched_stages += len(members)

        for i, (st, state, cid, metrics) in enumerate(
                zip(members, new_states, cids, metrics_l)):
            produced[st.stage_id] = (state, t)
            self.events.push(t, "stage", {
                "node_id": st.node_id, "stop": st.stop, "cid": cid,
                "metrics": metrics, "worker": worker.wid,
                "last": i == len(members) - 1})
        worker.busy_until = t
        return True, missed
