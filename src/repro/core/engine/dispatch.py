"""Chain dispatch — scheduling rounds and worker-side chain execution.

Each round the dispatcher asks the :class:`~repro.core.stagetree.StageTreeBuilder`
for the current stage tree (incrementally maintained — O(changed requests),
not O(plan)), runs its **grouping pass** (when the backend batches sibling
stages: collect ready sibling chains with stage-wise identical signatures
via :func:`~repro.core.stagetree.sibling_chain_groups` and execute each
group as batched backend calls on one worker), hands the remaining tree to
the scheduling policy, and executes the extracted chains on idle virtual
workers.

Chain-fused execution (``chain_fusion``, default on for capable backends):
a whole scheduler-extracted chain runs through ``backend.run_chain`` — the
state carry stays on device across stage boundaries, with no
``store.get``/``store.put`` round-trip and no re-dispatch between
consecutive stages — and every boundary checkpoint is deposited
**write-behind** (``store.put_async``: pending cache + background commit),
so the worker never stalls on checkpoint I/O.  The virtual clock keeps
stage granularity: the measured chain wall is apportioned over the stages
by step count (simulated backends keep exact per-stage durations), and a
``stage`` event still lands per boundary, so aggregation, tuner callbacks,
kills and GC observe exactly the per-stage event stream of the unfused
loop.  A kill that lands mid-chain therefore behaves as before: the
completed prefix's checkpoints are already recorded (pending writes are
served to readers and cancelled by eviction), the dead suffix is evicted
on arrival.

Checkpoint-plane accounting: ``ckpt_save_seconds`` / ``ckpt_load_seconds``
time every store interaction, and the synchronous slice of in-window saves
is subtracted from measured stage walls exactly like ``compile_seconds`` —
profiles and the virtual clock stay execution-only.

Recompute-on-miss: a resume checkpoint the plan still lists but the store
has dropped (external eviction) does not raise — the dispatcher counts a
``ckpt_miss``, tells the plan to forget the stale entry, refunds the
scheduler, and re-runs the round: Algorithm 1 re-derives the request from
whatever remains (an earlier checkpoint, an ancestor, or a fresh model).

Mesh workers (distribution plane v2): a worker may own a device set
(:class:`~repro.dist.meshes.WorkerMesh`).  Placement then goes through
:meth:`Dispatcher._place`: workers whose mesh the backend rejects for the
work (``backend.mesh_compatible`` — the PR 3 divisibility gate) are
skipped (``placement_rejections``), and among the compatible ones the
scheduling policy's ``placement_hint`` picks narrow ("wide": sibling
groups batch trials) or wide ("deep": solo chains shard the model) — the
two orthogonal parallelism axes traded per work unit.  Boundary states of
finished chains additionally populate a small host-local **d2d cache**:
a resume whose producer ran on the same host is served by
``backend.device_transfer`` (``d2d_handoffs``; no store round-trip, same
virtual-clock/accounting costs), falling back to the tiered store across
hosts or after eviction — content addressing makes the cache trivially
coherent.
"""

from __future__ import annotations

import time as _time
from collections import OrderedDict
from dataclasses import dataclass
from typing import Any, Dict, List, Optional, Tuple

from repro.core.scheduler import SchedulingPolicy
from repro.core.searchplan import Request, SearchPlan
from repro.core.stagetree import (Stage, StageTreeBuilder,
                                  sibling_chain_groups, sibling_groups)
from repro.core.engine.events import EventLoop
from repro.core.faults import WorkerCrashed, is_transient, raw_store
from repro.core.trainer import StageContext, TrainerBackend
from repro.dist.meshes import WorkerMesh
from repro.train.checkpoint import CheckpointStore

__all__ = ["Worker", "Dispatcher"]


@dataclass
class Worker:
    wid: int
    busy_until: float = 0.0
    idle: bool = True
    #: device set this worker owns (None = classic 1-slot thread worker)
    mesh: Optional[WorkerMesh] = None
    # ---- fault plane: crash record feeding quarantine (see
    # Dispatcher._crash_worker).  A quarantined worker simply stays
    # non-idle until its probation "idle" event fires — no placement-path
    # filtering needed, and quarantine always expires. ----
    failures: int = 0               # crashes since the last success
    times_quarantined: int = 0      # consecutive quarantines (backoff exp)
    quarantined_until: float = 0.0  # virtual time probation starts
    # ---- front door (lease revocation; see repro.frontdoor.leases).  A
    # draining worker finishes its current chain but is never offered new
    # work; the engine removes it when its idle event fires — revocation
    # lands exactly at a chain boundary, where the PR 9 retry machinery
    # guarantees every boundary checkpoint is committed. ----
    draining: bool = False

    @property
    def host(self) -> str:
        return self.mesh.host if self.mesh is not None else "host0"

    @property
    def devices(self) -> int:
        return self.mesh.n_devices if self.mesh is not None else 1


class Dispatcher:
    def __init__(self, plan: SearchPlan, backend: TrainerBackend,
                 scheduler: SchedulingPolicy, store: CheckpointStore,
                 events: EventLoop, stats, workers: List[Worker],
                 gpus_per_worker: int = 1,
                 max_steps_per_chain: Optional[int] = None,
                 builder: Optional[StageTreeBuilder] = None,
                 batch_siblings: bool = False,
                 chain_fusion: bool = False):
        self.plan = plan
        self.backend = backend
        self.scheduler = scheduler
        self.store = store
        self.events = events
        self.stats = stats
        self.workers = workers
        self.gpus_per_worker = gpus_per_worker
        self.max_steps_per_chain = max_steps_per_chain
        self.builder = builder or StageTreeBuilder(plan)
        self.batch_siblings = batch_siblings
        self.chain_fusion = chain_fusion
        # store counters at attach time: EngineStats mirrors *deltas* over
        # this baseline, so a restored session (fresh store, zero counters)
        # accumulates onto its snapshot totals instead of clobbering them
        self._store_base = self._seed_store_base()
        # d2d handoff cache: boundary cid -> (state, producing host).  Only
        # active on mesh fleets so classic thread-worker runs keep their
        # store-counter behavior bit-for-bit; transient by design (not
        # snapshotted — a restored session falls back to the store).
        self._d2d_enabled = any(w.mesh is not None for w in workers)
        # cid -> (state, producing host, producing wid); the wid lets a
        # worker crash invalidate the boundary states its devices held
        self._d2d: "OrderedDict[str, Tuple[Any, str, int]]" = OrderedDict()
        self._d2d_cap = 16
        # ---- fault plane (failure domains; see core/faults.py) ----
        # Retry backoff runs on the VIRTUAL clock: a failed work unit keeps
        # its requests marked running (Algorithm 1 defers them), and a
        # "retry" event at t_fail + backoff clears the marks so the next
        # round re-derives the work from the boundary checkpoint.
        self.retry_backoff_base = 2.0
        self.retry_backoff_cap = 60.0
        self.max_stage_retries = 8       # per work unit; beyond -> fatal
        self.quarantine_after = 2        # crashes before quarantine
        self.quarantine_seconds = 120.0  # base probation (doubles, capped 8x)
        self._retry_attempts: Dict[str, int] = {}
        self._injector = getattr(backend, "fault_injector", None)
        self._fault_base = self._injector.injected if self._injector else 0

    # ------------------------------------------------------------ scheduling
    def assign(self) -> None:
        # a checkpoint miss mutates the plan (the stale entry is forgotten)
        # and leaves its requests pending with the worker still idle: re-run
        # the round so Algorithm 1 re-derives them.  Each retry forgets at
        # least one stale checkpoint entry, so the loop terminates.
        while self._assign_round():
            pass
        self._sync_kernel_stats()
        self._sync_store_stats()
        self._sync_fault_stats()

    def _sync_kernel_stats(self) -> None:
        """Mirror the backend's kernel-plane counters (trace-time call/
        fallback counts, cumulative per backend) into ``EngineStats``."""
        calls = getattr(self.backend, "kernel_calls", None)
        if calls is not None:
            self.stats.kernel_calls = calls
            self.stats.kernel_fallbacks = self.backend.kernel_fallbacks

    # EngineStats field <- CheckpointStore counter (mirrored as deltas)
    _STORE_MIRROR = {
        "ckpt_delta_bytes": "delta_bytes",
        "ckpt_full_bytes": "full_bytes",
        "ckpt_logical_bytes": "logical_bytes",
        "ckpt_bytes_written": "bytes_written",
        "ckpt_delta_commits": "delta_commits",
        "ckpt_delta_rebases": "delta_rebases",
        "ckpt_mem_hits": "mem_hits",
        "ckpt_disk_hits": "disk_hits",
        "ckpt_remote_hits": "remote_hits",
        "ckpt_store_misses": "store_misses",
        "ckpt_tier_promotions": "tier_promotions",
        "ckpt_tier_demotions": "tier_demotions",
        "ckpt_tmp_reclaimed": "tmp_reclaimed",
    }

    def _store_counters(self) -> Dict[str, int]:
        base = {f: getattr(self.store, a, 0)
                for f, a in self._STORE_MIRROR.items()}
        return base

    def _seed_store_base(self) -> Dict[str, int]:
        base = self._store_counters()
        # the init-time temp sweep happened before any dispatcher could
        # attach; zero its baseline so the first sync surfaces the count
        base["ckpt_tmp_reclaimed"] = 0
        return base

    def _sync_store_stats(self) -> None:
        """Mirror the checkpoint-plane counters into ``EngineStats``.

        The store outlives engines (service sessions share one store
        across studies, restores attach a fresh store to snapshot stats),
        so each dispatcher accumulates only the counter *growth since it
        attached* — snapshot/restore identity of the logical run is
        preserved while physical-store counters still sum correctly."""
        now = self._store_counters()
        for field, _ in self._STORE_MIRROR.items():
            grown = now[field] - self._store_base[field]
            if grown:
                setattr(self.stats, field,
                        getattr(self.stats, field) + grown)
        self._store_base = now

    def _sync_fault_stats(self) -> None:
        """Mirror the injector's fired-fault count into ``EngineStats`` as
        growth deltas (like the store counters: a restored session keeps
        its snapshot total and accumulates from there)."""
        if self._injector is None:
            return
        grown = self._injector.injected - self._fault_base
        if grown:
            self.stats.faults_injected += grown
            self._fault_base = self._injector.injected

    def _assign_round(self) -> bool:
        """One scheduling round; True when a checkpoint miss warrants a
        retry (idle workers remain and requests were re-derived)."""
        idle = [w for w in self.workers if w.idle and not w.draining]
        if not idle:
            return False
        tree = self.builder.build()
        if not tree.stages:
            return False
        self.stats.rounds += 1
        missed = False
        # stage_id -> (state, finish_time, cid) for cross-chain chaining
        # this round; the cid seeds delta encoding in consumer chains
        produced: Dict[str, Tuple[Any, float, Optional[str]]] = {}
        taken: set = set()

        if self.batch_siblings:
            if self.chain_fusion:
                # groups extend down parallel chains with identical
                # per-stage signatures (batched multi-stage chains); the
                # per-dispatch work cap applies to them like any chain
                groups = sibling_chain_groups(self.plan, tree)
                if self.max_steps_per_chain:
                    # members share per-level step counts, so one member's
                    # truncation depth bounds the whole group; cut levels
                    # were never claimed and reschedule in a later round
                    cuts = [len(self._truncate(g[0])) for g in groups]
                    groups = [[c[:cut] for c in g]
                              for g, cut in zip(groups, cuts)]
            else:
                groups = [[[st] for st in g]
                          for g in sibling_groups(self.plan, tree)]
            for group in groups:
                if not idle:
                    break
                # policy-routed placement (not a hardwired idle[0]): the
                # mesh gate filters, the placement hint picks
                worker = self._place(idle, group)
                if worker is None:
                    # no compatible idle worker — the stages were never
                    # claimed and fall through to the chain pass / a later
                    # round
                    continue
                ran, miss = self._execute_group(group, worker, produced,
                                                taken)
                missed |= miss
                if ran:
                    idle.remove(worker)

        # chain pass over an explicit in-round pool: a deferred chain's
        # worker returns to the pool and is offered another path (it used
        # to strand idle for the rest of the round), and a refill asks the
        # scheduler for more chains when deferrals freed capacity
        pool = list(idle)
        pending: List[List[Stage]] = []
        exhausted = False

        def refill() -> None:
            nonlocal exhausted
            if exhausted or not pool:
                return
            got = self.scheduler.assign(self.plan, tree, len(pool),
                                        taken=taken)
            if len(got) < len(pool):
                exhausted = True
            pending.extend(got)

        refill()
        while pool and pending:
            path = pending.pop(0)
            if self.max_steps_per_chain:
                full = path
                path = self._truncate(full)
                if len(path) < len(full):
                    # refund the cut tail: it reschedules in a later round
                    self.scheduler.on_stages_unassigned(
                        self.plan, full[len(path):])
            worker = self._place(pool, [path])
            if worker is None:
                # every compatible worker is busy — refund; the stages stay
                # taken this round and re-extract in a later one
                self.scheduler.on_stages_unassigned(self.plan, path)
            else:
                pool.remove(worker)
                status = self._execute_chain(path, worker, produced)
                if status == "miss":
                    missed = True
                elif status in ("deferred", "failed"):
                    # "failed": the unit failed before claiming the worker
                    # (resume-load outage) — the retry is scheduled and the
                    # worker can still host other work this round
                    pool.append(worker)
            if not pending:
                refill()
        return missed and any(w.idle and not w.draining
                              for w in self.workers)

    # -------------------------------------------------------------- placement
    def _place(self, candidates: List[Worker],
               chains: List[List[Stage]]) -> Optional[Worker]:
        """Pick a worker for one work unit (a chain, or a sibling-chain
        group) from ``candidates``: drop mesh workers the backend rejects
        for this work (``placement_rejections``), then let the scheduling
        policy's placement hint trade batch width against shard width.
        Ties resolve to the earliest candidate, so a homogeneous fleet
        places exactly like the classic first-idle dispatcher.

        Rejection redirects work when an alternative exists; when EVERY
        candidate is rejected the narrowest one hosts the work anyway
        (backends run replicated on a mesh they cannot shard over) — an
        all-incompatible fleet must degrade, not starve the plan."""
        ctxs = [self._ctx_for(st) for chain in chains for st in chain]
        eligible = []
        for w in candidates:
            if w.mesh is not None and not self.backend.mesh_compatible(
                    w.mesh, ctxs):
                self.stats.placement_rejections += 1
                continue
            eligible.append(w)
        if not eligible:
            return min(candidates, key=lambda w: w.devices)
        hint = self.scheduler.placement_hint(self.plan, chains, eligible)
        if hint == "wide":
            return min(eligible, key=lambda w: w.devices)
        if hint == "deep":
            return max(eligible, key=lambda w: w.devices)
        return eligible[0]

    def _worker_gpus(self, worker: Worker) -> int:
        """Accounting width of a worker: its mesh size, or the engine-wide
        ``gpus_per_worker`` for classic thread workers."""
        return (worker.mesh.n_devices if worker.mesh is not None
                else self.gpus_per_worker)

    def _truncate(self, path: List[Stage]) -> List[Stage]:
        out, steps = [], 0
        for st in path:
            out.append(st)
            steps += st.steps
            if steps >= self.max_steps_per_chain:
                break
        return out

    # ---------------------------------------------------------- resume input
    def _load_resume(self, nid: str, step: int,
                     worker: Optional[Worker] = None
                     ) -> Optional[Tuple[Any, str]]:
        """(state, cid) of checkpoint (node, step), or None after degrading
        a vanished checkpoint to recompute: count the miss and make the
        plan forget the stale entry so the next round re-derives the
        request.  A checkpoint the plan no longer lists (already forgotten
        earlier this round) is not a fresh miss — one eviction counts once.
        The cid rides along as the fork-point parent for delta-encoding
        the chain's first boundary checkpoint.

        On mesh fleets, a boundary state produced on ``worker``'s host is
        served device-to-device (``backend.device_transfer``) with no
        store round-trip; the virtual-clock and ``ckpt_loads`` accounting
        is the caller's and stays identical either way."""
        cid = self.plan.node(nid).ckpts.get(step)
        if cid is None:
            return None
        if self._d2d_enabled and worker is not None:
            entry = self._d2d.get(cid)
            if entry is not None and entry[1] == worker.host:
                moved = self.backend.device_transfer(entry[0], worker.mesh)
                if moved is not None:
                    self._d2d.move_to_end(cid)
                    self.stats.d2d_handoffs += 1
                    return moved, cid
        t0 = _time.perf_counter()
        try:
            return self.store.get(cid), cid
        except KeyError:
            pass
        finally:
            self.stats.ckpt_load_seconds += _time.perf_counter() - t0
        self.stats.ckpt_misses += 1
        self.plan.forget_ckpt(nid, step)
        return None

    def _d2d_put(self, cid: str, state: Any, worker: Worker) -> None:
        """Retain a boundary state for host-local handoff (LRU-bounded;
        content addressing keeps a stale entry harmless — the plan simply
        stops asking for its cid)."""
        if not self._d2d_enabled:
            return
        self._d2d[cid] = (state, worker.host, worker.wid)
        self._d2d.move_to_end(cid)
        while len(self._d2d) > self._d2d_cap:
            self._d2d.popitem(last=False)

    def _put_boundary(self, path_key: str, stop: int, state: Any,
                      parent_cid: Optional[str] = None) -> str:
        """Deposit one stage-boundary checkpoint — write-behind under chain
        fusion (enqueue only; the commit overlaps the next stage's
        compute), synchronous otherwise.  The synchronous slice is timed
        into ``ckpt_save_seconds`` either way."""
        if self._injector is not None:
            self._assert_retry_identical(path_key, stop, state)
        t0 = _time.perf_counter()
        if self.chain_fusion:
            cid = self.store.put_async(path_key, stop, state,
                                       parent_cid=parent_cid)
            self.stats.ckpt_async_writes += 1
        else:
            cid = self.store.put(path_key, stop, state,
                                 parent_cid=parent_cid)
        self.stats.ckpt_save_seconds += _time.perf_counter() - t0
        self.stats.ckpt_saves += 1
        return cid

    def _assert_retry_identical(self, path_key: str, stop: int,
                                state: Any) -> None:
        """Retry determinism assertion (fault schedules only): a re-put of
        an already-committed boundary cid means the stage was recomputed —
        after a retry or a recompute-on-miss — and content addressing
        demands the recomputed state be bit-identical to the committed
        one.  Verified against the raw store (no outage draws) so the
        check never perturbs the fault schedule."""
        store = raw_store(self.store)
        cid = store.ckpt_id(path_key, stop)
        try:
            prior = store.get(cid)
        except KeyError:
            return
        from repro.train.checkpoint import _tree_flatten
        import numpy as np
        old_l, old_def = _tree_flatten(prior)
        new_l, new_def = _tree_flatten(state)
        same = (old_def == new_def and len(old_l) == len(new_l) and all(
            np.asarray(a).tobytes() == np.asarray(b).tobytes()
            for a, b in zip(old_l, new_l)))
        if not same:
            raise RuntimeError(
                f"retry produced a different state for committed boundary "
                f"{cid} ({path_key}@{stop}) — stage execution is not "
                "deterministic, content addressing is violated")
        self._injector.retries_verified += 1

    # --------------------------------------------------------- failure domain
    def _unit_key(self, stages: List[Stage]) -> str:
        return f"{stages[0].node_id}:{stages[0].stop}"

    def _fail_unit(self, worker: Worker, stages: List[Stage],
                   exc: BaseException, t_fail: float, waste: float,
                   release_worker: bool) -> float:
        """Absorb one failed work unit (a chain, a batched group, or one
        member of a degraded group).

        The attempt's cost goes to ``wasted_gpu_seconds`` only — never
        ``gpu_seconds`` and never the sharing studies' fair-share split.
        The scheduler is refunded, the failed stages' requests stay marked
        running (Algorithm 1 defers them — the backoff), and a ``retry``
        event at ``t_fail + backoff`` clears the marks so the next round
        re-executes from the boundary checkpoint.  A crash additionally
        feeds the worker's quarantine record.  ``release_worker`` pushes
        the idle event for callers that consumed the worker (a quarantined
        worker returns when probation starts).  Fatal or retry-exhausted
        faults re-raise after the books are balanced.  Returns the
        worker's rejoin time (``t_fail``, or probation start after a
        quarantining crash)."""
        self.stats.stage_failures += 1
        if waste > 0:
            self.stats.wasted_gpu_seconds += waste
        self.scheduler.on_stages_unassigned(self.plan, stages)
        reqs = [Request(st.node_id, st.stop) for st in stages]
        back_at = t_fail
        if isinstance(exc, WorkerCrashed):
            back_at = self._crash_worker(worker, t_fail)
        if release_worker:
            worker.busy_until = back_at
            self.events.push(back_at, "idle", worker.wid)
        key = self._unit_key(stages)
        attempts = self._retry_attempts.get(key, 0) + 1
        self._retry_attempts[key] = attempts
        if not is_transient(exc) or attempts > self.max_stage_retries:
            # release the running marks so a supervisor restart (session
            # restore) can re-derive the work, then propagate
            self.plan.clear_running(reqs)
            raise exc
        self.stats.stage_retries += 1
        backoff = min(self.retry_backoff_cap,
                      self.retry_backoff_base * 2 ** (attempts - 1))
        self.plan.mark_running(reqs)
        self.events.push(t_fail + backoff, "retry",
                         [(st.node_id, st.stop) for st in stages])
        return back_at

    def _crash_worker(self, worker: Worker, t_fail: float) -> float:
        """Record one crash; returns the virtual time the worker rejoins
        the pool.  Repeat crashers are quarantined with exponentially
        growing (capped) probation; any boundary states their devices held
        in the d2d cache are invalidated.  Quarantine is just a delayed
        idle event, so it always expires — probation re-admission is the
        default, and a worker that then succeeds clears its record."""
        worker.failures += 1
        for cid in [c for c, e in self._d2d.items() if e[2] == worker.wid]:
            del self._d2d[cid]
        if worker.failures < self.quarantine_after:
            return t_fail
        worker.times_quarantined += 1
        dur = self.quarantine_seconds * min(
            8.0, 2.0 ** (worker.times_quarantined - 1))
        worker.quarantined_until = t_fail + dur
        self.stats.workers_quarantined += 1
        return worker.quarantined_until

    def _worker_recovered(self, worker: Worker) -> None:
        """A unit completed on ``worker``: probation over, record cleared."""
        worker.failures = 0
        worker.times_quarantined = 0

    def _unit_succeeded(self, stages: List[Stage]) -> None:
        """A unit completed: reset its retry budget.  ``max_stage_retries``
        bounds *consecutive* failures of one unit — without the reset, a
        unit that fails, recovers, and fails again across a long session
        accrues attempts across unrelated incidents until a perfectly
        recoverable fault is misclassified as exhausted."""
        self._retry_attempts.pop(self._unit_key(stages), None)

    def on_retry(self, reqs: List[Tuple[str, int]]) -> None:
        """A retry backoff expired (engine ``retry`` event): clear the
        running marks so the dispatcher round that follows re-derives the
        requests — Algorithm 1 resumes them from the last boundary
        checkpoint that actually committed."""
        self.plan.clear_running([Request(nid, stop) for nid, stop in reqs])

    def _waste_of(self, stages: List[Stage], wall: float,
                  gpus: int) -> float:
        """GPU-seconds burned by a failed attempt over ``stages``:
        simulated durations when the backend provides them (virtual-clock
        backends), else the measured wall."""
        total = 0.0
        for st in stages:
            sim = self.backend.stage_seconds(self._ctx_for(st))
            total += sim if sim is not None else wall / max(1, len(stages))
        return total * gpus

    # ------------------------------------------------------ study accounting
    def _credit_stage(self, st: Stage, dur: float, gpus: int) -> None:
        """Per-study breakdown (``EngineStats.by_study``): split the
        stage's execution seconds evenly across the studies it serves
        (reuse is free capacity — each sharing study pays 1/k), but count
        ``steps_run``/``stages_run`` in full per serving study, so the
        per-study step sums exceed the physical total exactly when stages
        are shared.  ``gpus`` is the executing worker's device width.
        Work with no study attribution (direct ``plan.submit`` without
        ``study=``) is left out of the breakdown."""
        studies = set()
        for tid in self.plan.node(st.node_id).trials:
            studies |= self.plan.studies_of_trial(tid)
        if not studies:
            return
        share = dur * gpus / len(studies)
        for s in sorted(studies):
            ss = self.stats.study(s)
            ss.gpu_seconds += share
            ss.stages_run += 1
            ss.steps_run += st.steps

    def _ctx_for(self, st: Stage) -> StageContext:
        node = self.plan.node(st.node_id)
        return StageContext(
            node_id=st.node_id, desc=node.desc, node_start=node.start,
            start=st.start, stop=st.stop,
            path_key=self.plan.path_key(st.node_id))

    def _adjusted_wall(self, wall0: float, comp0: float,
                       save0: float) -> float:
        """Measured wall minus the backend's compile-time delta and the
        synchronous slice of in-window checkpoint saves: one-time
        compilation amortizes across the study and write-behind saves
        overlap the next stage, so neither may pollute seconds/step
        profiles or the virtual clock."""
        wall = _time.perf_counter() - wall0
        comp = getattr(self.backend, "compile_seconds", 0.0) - comp0
        save = self.stats.ckpt_save_seconds - save0
        return max(0.0, wall - comp - save)

    def _compile_adjusted_wall(self, wall0: float, comp0: float) -> float:
        return self._adjusted_wall(wall0, comp0, self.stats.ckpt_save_seconds)

    # ------------------------------------------------------- chain execution
    def _execute_chain(self, path: List[Stage], worker: Worker,
                       produced: Dict[str, Tuple[Any, float,
                                                 Optional[str]]]) -> str:
        """Execute one chain on ``worker``.  Returns ``"ran"``, ``"miss"``
        (checkpoint vanished — the caller retries the round),
        ``"deferred"`` (in-round input truncated away — the caller returns
        the worker to the round's pool) or ``"failed"`` (the resume load
        failed before the worker was claimed — the retry is scheduled and
        the worker returns to the pool).  A failure mid-execution returns
        ``"ran"``: the worker burned time on the attempt and its idle
        event is scheduled by the failure domain."""
        head = path[0]
        t = max(self.events.time, worker.busy_until)
        load_s, save_s = self.backend.overheads()
        gpus = self._worker_gpus(worker)

        # ------- input state (parent_cid = the fork-point checkpoint the
        # chain's first boundary delta-encodes against)
        if head.resume is not None:
            nid, step = head.resume
            try:
                loaded = self._load_resume(nid, step, worker)
            except Exception as exc:
                # store outage (or kin) on the resume load: the worker was
                # never claimed — refund, schedule the retry, keep the
                # worker in the round's pool
                self._fail_unit(worker, path, exc, t, 0.0,
                                release_worker=False)
                return "failed"
            if loaded is None:
                # resume checkpoint externally dropped — leave the requests
                # pending; the retried round re-derives them from the plan
                self.scheduler.on_stages_unassigned(self.plan, path)
                return "miss"
            state, parent_cid = loaded
            t += load_s
            self.stats.gpu_seconds += load_s * gpus
            self.stats.ckpt_loads += 1
        elif head.parent is not None:
            if head.parent not in produced:
                # parent chain was truncated before producing our input —
                # leave the requests pending; a later round reschedules them
                worker.idle = True
                self.stats.chains_deferred += 1
                self.scheduler.on_stages_unassigned(self.plan, path)
                return "deferred"
            # produced by another chain in this same round
            state, parent_done, parent_cid = produced[head.parent]
            t = max(t, parent_done) + load_s
            self.stats.gpu_seconds += load_s * gpus
            self.stats.ckpt_loads += 1
        else:
            state = self.backend.init_state()
            parent_cid = None

        worker.idle = False
        self.backend.set_mesh(worker.mesh)
        if worker.mesh is not None:
            self.stats.mesh_placements += 1
        if self.chain_fusion:
            self._run_chain_fused(path, worker, state, t, produced,
                                  parent_cid)
            return "ran"

        for i, st in enumerate(path):
            ctx = self._ctx_for(st)
            self.plan.mark_running([Request(st.node_id, st.stop)])

            comp0 = getattr(self.backend, "compile_seconds", 0.0)
            wall0 = _time.perf_counter()
            try:
                if st.steps > 0:
                    state = self.backend.run_stage(state, ctx)
                metrics = (self.backend.evaluate(state, ctx) if st.report
                           else None)
                wall = self._compile_adjusted_wall(wall0, comp0)
                sim = self.backend.stage_seconds(ctx)
                # commit the boundary BEFORE any accounting: a failed put
                # leaves this stage entirely un-happened (no stats, no
                # event) and the whole suffix retries from the last
                # committed boundary
                cid = self._put_boundary(ctx.path_key, st.stop, state,
                                         parent_cid=parent_cid)
            except Exception as exc:
                rest = path[i:]
                waste = self._waste_of([st],
                                       _time.perf_counter() - wall0, gpus)
                self._fail_unit(worker, rest, exc, t, waste,
                                release_worker=True)
                return "ran"   # worker consumed; idle event is scheduled

            dur = sim if sim is not None else wall
            if st.report:
                dur += getattr(self.backend, "eval_seconds", 0.0)
                self.stats.evals_run += 1
            dur += save_s  # checkpoint at every stage boundary
            t += dur
            self.stats.gpu_seconds += dur * gpus
            self.stats.stages_run += 1
            self.stats.steps_run += st.steps
            self._credit_stage(st, dur, gpus)

            if st.steps > 0:
                self.plan.record_profile(
                    st.node_id, (sim if sim is not None else wall) / st.steps)
            parent_cid = cid   # next boundary deltas against this one
            self._d2d_put(cid, state, worker)
            produced[st.stage_id] = (state, t, cid)
            self.events.push(t, "stage", {
                "node_id": st.node_id, "stop": st.stop, "cid": cid,
                "metrics": metrics, "worker": worker.wid,
                "last": st is path[-1]})
        worker.busy_until = t
        self._worker_recovered(worker)
        self._unit_succeeded(path)
        return "ran"

    # ------------------------------------------------- fused chain execution
    def _run_chain_fused(self, path: List[Stage], worker: Worker,
                         state: Any, t: float,
                         produced: Dict[str, Tuple[Any, float,
                                                   Optional[str]]],
                         parent_cid: Optional[str] = None) -> None:
        """Execute the whole chain through ``backend.run_chain``: one fused
        call, device-resident carry across boundaries, write-behind
        checkpoints — with per-stage events, profiles and virtual durations
        identical in structure to the unfused loop."""
        _, save_s = self.backend.overheads()
        gpus = self._worker_gpus(worker)
        ctxs = [self._ctx_for(st) for st in path]
        self.plan.mark_running([Request(st.node_id, st.stop) for st in path])

        comp0 = getattr(self.backend, "compile_seconds", 0.0)
        save0 = self.stats.ckpt_save_seconds
        wall0 = _time.perf_counter()
        try:
            try:
                bstates = self.backend.run_chain(state, ctxs)
                fused = True
            except ValueError:
                # in-flight incompatibility: per-stage fallback, same
                # semantics, no fusion credit
                fused = False
                bstates = []
                for st, ctx in zip(path, ctxs):
                    if st.steps > 0:
                        state = self.backend.run_stage(state, ctx)
                    bstates.append(state)
            # boundary checkpoints enter the pending cache here
            # (write-behind); the enqueue slice is measured and subtracted
            # from the wall below.  Each boundary deltas against the
            # previous one (the head against the chain's fork point), so a
            # chain commits one delta per stage.
            cids = []
            for st, ctx, s in zip(path, ctxs, bstates):
                cid = self._put_boundary(ctx.path_key, st.stop, s,
                                         parent_cid=parent_cid)
                self._d2d_put(cid, s, worker)
                cids.append(cid)
                parent_cid = cid
            metrics_l = [self.backend.evaluate(s, ctx) if st.report else None
                         for st, ctx, s in zip(path, ctxs, bstates)]
        except Exception as exc:
            # whole-chain failure domain: the attempt (and any boundary
            # that did commit — content addressing makes the re-put a
            # verified no-op) retries from the chain's fork point
            waste = self._waste_of(path, _time.perf_counter() - wall0, gpus)
            self._fail_unit(worker, path, exc, t, waste,
                            release_worker=True)
            return
        wall = self._adjusted_wall(wall0, comp0, save0)

        sims = [self.backend.stage_seconds(c) for c in ctxs]
        total_steps = sum(st.steps for st in path)
        for st, s, cid, metrics, sim in zip(path, bstates, cids, metrics_l,
                                            sims):
            share = (wall * st.steps / total_steps if total_steps
                     else wall / len(path))
            exec_dur = sim if sim is not None else share
            if st.steps > 0:
                self.plan.record_profile(st.node_id, exec_dur / st.steps)
            dur = exec_dur
            if st.report:
                dur += getattr(self.backend, "eval_seconds", 0.0)
                self.stats.evals_run += 1
            dur += save_s  # checkpoint at every stage boundary
            t += dur
            self.stats.gpu_seconds += dur * gpus
            self.stats.stages_run += 1
            self.stats.steps_run += st.steps
            self._credit_stage(st, dur, gpus)
            if fused:
                self.stats.chain_fused_stages += 1
            produced[st.stage_id] = (s, t, cid)
            self.events.push(t, "stage", {
                "node_id": st.node_id, "stop": st.stop, "cid": cid,
                "metrics": metrics, "worker": worker.wid,
                "last": st is path[-1]})
        worker.busy_until = t
        self._worker_recovered(worker)
        self._unit_succeeded(path)

    # ------------------------------------------------------- group execution
    def _execute_group(self, group: List[List[Stage]], worker: Worker,
                       produced: Dict[str, Tuple[Any, float,
                                                 Optional[str]]],
                       taken: set) -> Tuple[bool, bool]:
        """Execute a sibling-chain group as batched backend calls on
        ``worker`` (one call per stage level; depth 1 is the classic
        sibling-stage group).

        Returns ``(ran, missed)``.  Members whose resume checkpoint
        vanished are refunded to the scheduler and left pending
        (recompute-on-miss); if fewer than two members survive, the whole
        group is refunded and its stages fall through to the ordinary
        chain scheduler this round.
        """
        t = max(self.events.time, worker.busy_until)
        load_s, save_s = self.backend.overheads()
        gpus = self._worker_gpus(worker)
        missed = False
        members: List[List[Stage]] = []
        states: List[Any] = []
        # per-member fork-point cid — seeds delta encoding of each
        # member's first boundary checkpoint (siblings share the parent)
        parents: List[Optional[str]] = []
        loaded: Dict[str, Any] = {}   # resume cid -> state (dedup sibling loads)
        for chain in group:
            head = chain[0]
            self.scheduler.on_path_assigned(self.plan, chain)
            if head.resume is not None:
                nid, step = head.resume
                cid = self.plan.node(nid).ckpts.get(step)
                if cid is not None and cid in loaded:
                    # copy-on-fanout: a dedup'd sibling load must never hand
                    # the SAME pytree object to two members — an in-place
                    # backend (or donation under fused mesh execution)
                    # would alias their carries
                    state = self.backend.clone_state(loaded[cid])
                else:
                    try:
                        got = self._load_resume(nid, step, worker)
                    except Exception as exc:
                        # store outage on one member's resume load: fail
                        # that member alone (refund + retry); the group
                        # continues with the survivors
                        self._fail_unit(worker, chain, exc, t, 0.0,
                                        release_worker=False)
                        continue
                    if got is None:
                        missed = True
                        self.scheduler.on_stages_unassigned(self.plan, chain)
                        continue
                    state, cid = got
                    loaded[cid] = state
            else:
                state = self.backend.init_state()
                cid = None
            members.append(chain)
            states.append(state)
            parents.append(cid)
        if len(members) < 2:
            # group fell apart — refund survivors; the chain scheduler picks
            # them up (they are not marked taken)
            for chain in members:
                self.scheduler.on_stages_unassigned(self.plan, chain)
            return False, missed

        n_loads = len(loaded)
        t += load_s * n_loads
        self.stats.gpu_seconds += load_s * n_loads * gpus
        self.stats.ckpt_loads += n_loads

        depth = len(members[0])
        ctx_chains = [[self._ctx_for(st) for st in chain]
                      for chain in members]
        for chain in members:
            for st in chain:
                taken.add(st.stage_id)
        self.plan.mark_running([Request(st.node_id, st.stop)
                                for chain in members for st in chain])
        worker.idle = False
        self.backend.set_mesh(worker.mesh)
        if worker.mesh is not None:
            self.stats.mesh_placements += 1

        comp0 = getattr(self.backend, "compile_seconds", 0.0)
        save0 = self.stats.ckpt_save_seconds
        wall0 = _time.perf_counter()
        crash_rejoin: Optional[float] = None
        try:
            try:
                if depth == 1:
                    outs = [[s] for s in self.backend.run_stages_batched(
                        states, [ctxs[0] for ctxs in ctx_chains])]
                else:
                    outs = self.backend.run_chains_batched(states, ctx_chains)
                batched = True
            except ValueError:
                # in-flight incompatibility (e.g. divergent restored batch
                # sizes): fall back to member-sequential execution — same
                # semantics, no batching credit
                outs = [self.backend.run_chain(s, ctxs)
                        for s, ctxs in zip(states, ctx_chains)]
                batched = False
        except Exception as exc:
            group_wall = _time.perf_counter() - wall0
            flat = [st for chain in members for st in chain]
            waste = self._waste_of(flat, group_wall, gpus)
            t_fail = t + waste / gpus   # the attempt burns virtual time
            if isinstance(exc, WorkerCrashed) or not is_transient(exc):
                # the worker died under the whole group (or the fault is
                # fatal): fail the group wholesale as one retry unit
                self._fail_unit(worker, flat, exc, t_fail, waste,
                                release_worker=True)
                return True, missed
            # transient batched-call failure: degrade gracefully — the
            # batched attempt is waste; members re-run solo and fail (or
            # succeed) independently
            self.stats.groups_degraded += 1
            self.stats.stage_failures += 1
            self.stats.wasted_gpu_seconds += waste
            t = t_fail
            (members, states, parents, ctx_chains, outs,
             crash_rejoin) = self._run_group_degraded(
                members, states, parents, ctx_chains, worker, t)
            batched = False
            if not members:
                # no member survived solo either; every retry is scheduled
                # — release the worker (a crash delays it to probation)
                back_at = crash_rejoin if crash_rejoin is not None else t
                worker.busy_until = back_at
                self.events.push(back_at, "idle", worker.wid)
                return True, missed
            depth = len(members[0])
        # write-behind boundary checkpoints for every (member, stage);
        # content addressing dedups exactly as per-stage puts.  Each
        # member threads its own parent down the chain, so every sibling
        # deltas against the shared fork point and then its own boundary.
        # A member whose put fails (store outage) is failed alone — its
        # computed state is waste, the survivors keep their results.
        ok: List[int] = []
        cids: List[List[str]] = []
        metrics_l: List[List[Any]] = []
        for i, (chain, ctxs, out, pcid) in enumerate(
                zip(members, ctx_chains, outs, parents)):
            try:
                member_cids = []
                for st, ctx, s in zip(chain, ctxs, out):
                    cid = self._put_boundary(ctx.path_key, st.stop, s,
                                             parent_cid=pcid)
                    self._d2d_put(cid, s, worker)
                    member_cids.append(cid)
                    pcid = cid
                member_metrics = [
                    self.backend.evaluate(s, ctx) if st.report else None
                    for st, ctx, s in zip(chain, ctxs, out)]
            except Exception as exc:
                self._fail_unit(worker, chain, exc, t,
                                self._waste_of(chain, 0.0, gpus),
                                release_worker=False)
                continue
            ok.append(i)
            cids.append(member_cids)
            metrics_l.append(member_metrics)
        if len(ok) < len(members):
            members = [members[i] for i in ok]
            ctx_chains = [ctx_chains[i] for i in ok]
            outs = [outs[i] for i in ok]
            if not members:
                back_at = crash_rejoin if crash_rejoin is not None else t
                worker.busy_until = back_at
                self.events.push(back_at, "idle", worker.wid)
                return True, missed
        wall = self._adjusted_wall(wall0, comp0, save0)

        sims = [[self.backend.stage_seconds(c) for c in ctxs]
                for ctxs in ctx_chains]
        total_steps = sum(st.steps for st in members[0])
        fused_chain = depth > 1 and self.chain_fusion
        for j in range(depth):
            level = [chain[j] for chain in members]
            lvl_sims = [s[j] for s in sims]
            steps_j = level[0].steps
            lvl_wall = (wall * steps_j / total_steps if total_steps
                        else wall / depth)
            dur = (lvl_wall if any(s is None for s in lvl_sims)
                   else sum(lvl_sims))
            for m, st in enumerate(level):
                member_dur = (lvl_sims[m] if lvl_sims[m] is not None
                              else lvl_wall / len(members))
                if st.report:
                    dur += getattr(self.backend, "eval_seconds", 0.0)
                    member_dur += getattr(self.backend, "eval_seconds", 0.0)
                    self.stats.evals_run += 1
                dur += save_s  # checkpoint per member at the stage boundary
                member_dur += save_s
                self.stats.stages_run += 1
                self.stats.steps_run += st.steps
                self._credit_stage(st, member_dur, gpus)
                if fused_chain:
                    self.stats.chain_fused_stages += 1
                if st.steps > 0:
                    per_step = (lvl_sims[m] if lvl_sims[m] is not None
                                else lvl_wall / len(members)) / st.steps
                    self.plan.record_profile(st.node_id, per_step)
            t += dur
            self.stats.gpu_seconds += dur * gpus
            for m, st in enumerate(level):
                produced[st.stage_id] = (outs[m][j], t, cids[m][j])
                self.events.push(t, "stage", {
                    "node_id": st.node_id, "stop": st.stop,
                    "cid": cids[m][j], "metrics": metrics_l[m][j],
                    "worker": worker.wid,
                    # a crash during degradation delays the idle event to
                    # probation (pushed below) instead of the last stage
                    "last": crash_rejoin is None and j == depth - 1
                            and m == len(members) - 1})
        if batched:
            self.stats.batched_groups += 1
            self.stats.batched_stages += len(members) * depth
        for chain in members:          # surviving members completed
            self._unit_succeeded(chain)
        if crash_rejoin is not None:
            worker.busy_until = max(t, crash_rejoin)
            self.events.push(worker.busy_until, "idle", worker.wid)
        else:
            worker.busy_until = t
            self._worker_recovered(worker)
        return True, missed

    def _run_group_degraded(self, members, states, parents, ctx_chains,
                            worker: Worker, t: float):
        """Graceful degradation of a failed batched group: re-run each
        member solo (``backend.run_chain`` over a cloned carry — the
        batched attempt may have donated/aliased the originals).  Members
        that fail solo are failed independently (refund + retry); a
        member that crashes the worker fails, the not-yet-run members are
        failed as transient no-shows (no extra crash accrual — one
        incident, one crash), and the survivors computed before the crash
        keep their results.  Returns the surviving
        ``(members, states, parents, ctx_chains, outs, crash_rejoin)``;
        ``crash_rejoin`` is the worker's probation rejoin time when it
        crashed mid-degradation (None otherwise)."""
        from repro.core.faults import TransientStageError
        gpus = self._worker_gpus(worker)
        ok_m, ok_s, ok_p, ok_c, ok_o = [], [], [], [], []
        crash_rejoin: Optional[float] = None
        for chain, s, pcid, ctxs in zip(members, states, parents,
                                        ctx_chains):
            if crash_rejoin is not None:
                self._fail_unit(
                    worker, chain,
                    TransientStageError("worker crashed earlier in the "
                                        "degraded group"),
                    t, 0.0, release_worker=False)
                continue
            wall0 = _time.perf_counter()
            try:
                try:
                    out = self.backend.run_chain(
                        self.backend.clone_state(s), ctxs)
                except ValueError:
                    # per-stage fallback, same semantics as run_chain
                    out, ss = [], self.backend.clone_state(s)
                    for st, ctx in zip(chain, ctxs):
                        if st.steps > 0:
                            ss = self.backend.run_stage(ss, ctx)
                        out.append(ss)
            except Exception as exc:
                back = self._fail_unit(
                    worker, chain, exc, t,
                    self._waste_of(chain, _time.perf_counter() - wall0,
                                   gpus),
                    release_worker=False)
                if isinstance(exc, WorkerCrashed):
                    crash_rejoin = back
                continue
            ok_m.append(chain)
            ok_s.append(s)
            ok_p.append(pcid)
            ok_c.append(ctxs)
            ok_o.append(out)
        return ok_m, ok_s, ok_p, ok_c, ok_o, crash_rejoin
