"""Chain dispatch — scheduling rounds and worker-side chain execution.

Each round the dispatcher asks the :class:`~repro.core.stagetree.StageTreeBuilder`
for the current stage tree (incrementally maintained — O(changed requests),
not O(plan)), hands it to the scheduling policy, and executes the extracted
chains on idle virtual workers: load the resume checkpoint (or chain off a
state produced earlier in the same round), run each stage through the
trainer backend, checkpoint at every stage boundary, and post a ``stage``
event at the virtual completion time for the aggregator.
"""

from __future__ import annotations

import time as _time
from dataclasses import dataclass
from typing import Any, Dict, List, Optional, Tuple

from repro.core.scheduler import SchedulingPolicy
from repro.core.searchplan import Request, SearchPlan
from repro.core.stagetree import Stage, StageTreeBuilder
from repro.core.engine.events import EventLoop
from repro.core.trainer import StageContext, TrainerBackend
from repro.train.checkpoint import CheckpointStore

__all__ = ["Worker", "Dispatcher"]


@dataclass
class Worker:
    wid: int
    busy_until: float = 0.0
    idle: bool = True


class Dispatcher:
    def __init__(self, plan: SearchPlan, backend: TrainerBackend,
                 scheduler: SchedulingPolicy, store: CheckpointStore,
                 events: EventLoop, stats, workers: List[Worker],
                 gpus_per_worker: int = 1,
                 max_steps_per_chain: Optional[int] = None,
                 builder: Optional[StageTreeBuilder] = None):
        self.plan = plan
        self.backend = backend
        self.scheduler = scheduler
        self.store = store
        self.events = events
        self.stats = stats
        self.workers = workers
        self.gpus_per_worker = gpus_per_worker
        self.max_steps_per_chain = max_steps_per_chain
        self.builder = builder or StageTreeBuilder(plan)

    # ------------------------------------------------------------ scheduling
    def assign(self) -> None:
        idle = [w for w in self.workers if w.idle]
        if not idle:
            return
        tree = self.builder.build()
        if not tree.stages:
            return
        self.stats.rounds += 1
        paths = self.scheduler.assign(self.plan, tree, len(idle))
        # stage_id -> (state, finish_time) for cross-chain chaining this round
        produced: Dict[str, Tuple[Any, float]] = {}
        for path, worker in zip(paths, idle):
            if self.max_steps_per_chain:
                full = path
                path = self._truncate(full)
                if len(path) < len(full):
                    # refund the cut tail: it reschedules in a later round
                    self.scheduler.on_stages_unassigned(
                        self.plan, full[len(path):])
            self._execute_chain(path, worker, produced)

    def _truncate(self, path: List[Stage]) -> List[Stage]:
        out, steps = [], 0
        for st in path:
            out.append(st)
            steps += st.steps
            if steps >= self.max_steps_per_chain:
                break
        return out

    def _execute_chain(self, path: List[Stage], worker: Worker,
                       produced: Dict[str, Tuple[Any, float]]) -> None:
        head = path[0]
        t = max(self.events.time, worker.busy_until)
        load_s, save_s = self.backend.overheads()

        # ------- input state
        if head.resume is not None:
            nid, step = head.resume
            cid = self.plan.node(nid).ckpts[step]
            state = self.store.get(cid)
            t += load_s
            self.stats.gpu_seconds += load_s * self.gpus_per_worker
            self.stats.ckpt_loads += 1
        elif head.parent is not None:
            if head.parent not in produced:
                # parent chain was truncated before producing our input —
                # leave the requests pending; a later round reschedules them
                worker.idle = True
                self.stats.chains_deferred += 1
                self.scheduler.on_stages_unassigned(self.plan, path)
                return
            # produced by another chain in this same round
            state, parent_done = produced[head.parent]
            t = max(t, parent_done) + load_s
            self.stats.gpu_seconds += load_s * self.gpus_per_worker
            self.stats.ckpt_loads += 1
        else:
            state = self.backend.init_state()

        worker.idle = False
        for st in path:
            node = self.plan.node(st.node_id)
            ctx = StageContext(
                node_id=st.node_id, desc=node.desc, node_start=node.start,
                start=st.start, stop=st.stop,
                path_key=self.plan.path_key(st.node_id))
            self.plan.mark_running([Request(st.node_id, st.stop)])

            wall0 = _time.perf_counter()
            if st.steps > 0:
                state = self.backend.run_stage(state, ctx)
            metrics = self.backend.evaluate(state, ctx) if st.report else None
            wall = _time.perf_counter() - wall0

            sim = self.backend.stage_seconds(ctx)
            dur = sim if sim is not None else wall
            if st.report:
                dur += getattr(self.backend, "eval_seconds", 0.0)
                self.stats.evals_run += 1
            dur += save_s  # checkpoint at every stage boundary
            self.stats.ckpt_saves += 1
            t += dur
            self.stats.gpu_seconds += dur * self.gpus_per_worker
            self.stats.stages_run += 1
            self.stats.steps_run += st.steps

            if st.steps > 0:
                self.plan.record_profile(
                    st.node_id, (sim if sim is not None else wall) / st.steps)
            cid = self.store.put(ctx.path_key, st.stop, state)
            produced[st.stage_id] = (state, t)
            self.events.push(t, "stage", {
                "node_id": st.node_id, "stop": st.stop, "cid": cid,
                "metrics": metrics, "worker": worker.wid,
                "last": st is path[-1]})
        worker.busy_until = t
