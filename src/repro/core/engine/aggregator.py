"""Aggregator — result recording, waiter wakeup, and checkpoint GC.

The aggregator consumes ``stage`` events: it records checkpoints/metrics
into the search plan (the single source of truth), wakes every tuner
waiting on the satisfied (node, step) request, and frees the worker.

It also owns the beyond-paper checkpoint GC: when a kill releases the last
trial referencing a plan node (``refcount`` hits 0 — counted across *all*
studies sharing the plan, so a node another study still uses is never
touched), the node's checkpoints are evicted from the store and forgotten
by the plan, so Algorithm 1 stops resolving resumes to them.  Results that
arrive for already-dead nodes (a kill raced a running stage) are evicted on
arrival for the same reason.

Chain fusion changes none of this: a fused chain still posts one ``stage``
event per boundary, so a kill that lands mid-chain sees the completed
prefix recorded stage by stage and the dead suffix evicted on arrival.
Under the write-behind checkpoint plane those suffix evictions may hit
checkpoints whose host commit is still in flight — ``store.evict`` cancels
the pending write (the bytes are never materialized), which is exactly the
GC-correct outcome.
"""

from __future__ import annotations

from typing import Any, Dict, List, Set, Tuple

from repro.core.searchplan import SearchPlan
from repro.core.engine.events import EventLoop
from repro.train.checkpoint import CheckpointStore

__all__ = ["Aggregator"]


class Aggregator:
    def __init__(self, plan: SearchPlan, store: CheckpointStore,
                 stats, events: EventLoop):
        self.plan = plan
        self.store = store
        self.stats = stats
        self.events = events
        # (node_id, step) -> list of (handle, trial) waiting on the result
        self.waiters: Dict[Tuple[str, int], List[Tuple[Any, Any]]] = {}
        self.killed: Set[str] = set()

    # -------------------------------------------------------------- waiters
    def add_waiter(self, node_id: str, step: int, handle, trial) -> None:
        self.waiters.setdefault((node_id, step), []).append((handle, trial))

    # ----------------------------------------------------------- aggregation
    def on_stage_done(self, p: Dict[str, Any]) -> None:
        self.plan.record_result(p["node_id"], p["stop"], p["cid"], p["metrics"])
        if p["metrics"] is not None:
            key = (p["node_id"], p["stop"])
            for handle, trial in self.waiters.pop(key, []):
                if trial.trial_id not in self.killed:
                    handle.tuner.on_result(trial, p["stop"], p["metrics"])
        if self.plan.nodes[p["node_id"]].refcount <= 0:
            # result for a node killed while running — nothing will resume
            # from it, reclaim the checkpoint immediately
            self._evict_node(p["node_id"])
        if p["last"]:
            self.events.push(self.events.time, "idle", p["worker"])

    def detach_study(self, study_id: str) -> None:
        """Cancel path: drop every waiter belonging to ``study_id`` and
        withdraw the pending requests no other study's waiter still wants
        (running and satisfied steps are left alone — in-flight work
        completes and records normally).  Trials the study shares with
        live studies survive; the engine kills the rest separately."""
        for key in list(self.waiters):
            ws = self.waiters[key]
            ws[:] = [(h, t) for (h, t) in ws if h.study_id != study_id]
            if not ws:
                del self.waiters[key]
                nid, step = key
                node = self.plan.nodes[nid]
                if (step in node.requests and step not in node.running
                        and step not in node.metrics):
                    self.plan.drop_request(nid, step)

    # ------------------------------------------------------------------ kill
    def kill(self, trial_id: str) -> None:
        """Release a trial: drop its refs, cancel requests nobody else
        wants, and evict checkpoints of nodes left unreferenced."""
        if trial_id in self.killed:
            return
        self.killed.add(trial_id)
        path = list(self.plan.trial_paths.get(trial_id, []))
        dead = self.plan.release_trial(trial_id)
        # drop this trial's pending requests nobody else wants
        for nid in path:
            node = self.plan.nodes[nid]
            for s in sorted(node.requests):
                key = (nid, s)
                ws = self.waiters.get(key)
                if ws:
                    ws[:] = [(h, t) for (h, t) in ws if t.trial_id != trial_id]
                if not ws and s not in node.running and s not in node.metrics:
                    self.plan.drop_request(nid, s)
                    self.waiters.pop(key, None)
        for nid in dead:
            self._evict_node(nid)

    # -------------------------------------------------------------- ckpt GC
    def _evict_node(self, nid: str) -> None:
        for cid in self.plan.evict_ckpts(nid):
            if self.store.evict(cid):
                self.stats.ckpt_evictions += 1
