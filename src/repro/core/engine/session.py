"""Durable engine sessions — snapshot/restore for the service plane.

A :class:`SessionState` is the complete picklable state of one live
:class:`~repro.core.engine.engine.ExecutionEngine` session at an event
boundary: the search plan (with revision map, pending index and running
marks), the event heap and virtual clock, the waiter table, per-study
accounting, the scheduling policy (with its fair-share usage memory), the
worker states, and the committed-checkpoint index.  What it deliberately
does NOT contain:

* the **backend** (real trainers hold devices/executables) — re-supplied
  at restore,
* the **store object** (its write-behind writer thread is unpicklable) —
  the snapshot records the committed cid index instead, plus the raw
  cid→tree map when the store is memory-backed, so a restored in-memory
  session resumes with every checkpoint it had; directory stores are
  already durable on disk,
* transient scheduling state — the stage-tree builder is a pure memo over
  the plan and is rebuilt cold (identical trees, Algorithm 1 is a pure
  function of the plan).

``capture_session`` flushes the write-behind store first, so the snapshot
is a durability barrier: everything the plan records is committed at the
moment of capture.  On restore, plan checkpoint entries whose blob the
(possibly different) store cannot serve are forgotten up front — exactly
the recompute-on-miss degradation, applied eagerly — so a killed service
recomputes nothing beyond the write-behind puts that had not committed by
the last snapshot.

Snapshots must be taken at an event boundary (between ``engine.step()``
calls — the :class:`~repro.core.study.StudyService` enforces this): at
that point no dispatchable work is in limbo, so the event heap plus the
plan are the whole truth.  Restoring replays the identical event stream —
final :class:`~repro.core.engine.engine.EngineStats` (including the
per-study breakdown) are equal to an uninterrupted run's.

The on-disk format is a versioned pickle (``SESSION_FORMAT_VERSION``);
tuners and trials therefore must be picklable.  ``StudyHandle`` /
``StudyFuture`` drop their engine/service references when pickled and are
re-wired on restore.
"""

from __future__ import annotations

import os
import pickle
from dataclasses import dataclass, field
from typing import Any, Dict, List, Optional, Set, Tuple

from repro.core.engine.events import EventLoop
from repro.core.scheduler import SchedulingPolicy
from repro.core.searchplan import SearchPlan
from repro.core.trainer import TrainerBackend
from repro.train.checkpoint import CheckpointStore

__all__ = ["SessionState", "SESSION_FORMAT_VERSION", "capture_session",
           "restore_engine", "save_session", "load_session"]

# v2: EngineStats grew the checkpoint-plane v2 counters (delta/full bytes,
# per-tier hits, promotions/demotions) — v1 snapshots lack the fields and
# must be re-captured with the matching repro version
# v3: worker tuples carry the WorkerMesh descriptor (distribution plane
# v2) and EngineStats grew d2d/mesh-placement counters — v2 snapshots
# would restore a mesh fleet as thread workers, silently changing
# placement and accounting, so they are rejected like v1
SESSION_FORMAT_VERSION = 3


@dataclass
class SessionState:
    """Picklable engine-session state (see module docstring for scope)."""

    version: int
    plan_key: str
    # ---- engine construction knobs ----
    n_workers: int
    gpus_per_worker: int
    share: bool
    max_steps_per_chain: Optional[int]
    batch_siblings: bool
    chain_fusion: bool
    # ---- live session state ----
    plan: SearchPlan
    events: EventLoop
    scheduler: SchedulingPolicy
    stats: Any                                   # EngineStats
    workers: List[Tuple[int, float, bool, Any]]  # (wid, busy_until, idle,
                                                 #  WorkerMesh | None)
    waiters: Dict[Tuple[str, int], List[Tuple[Any, Any]]]
    killed: Set[str]
    trials: Dict[str, Any]
    handles: List[Any]                           # StudyHandle (engine=None)
    study_trials: Dict[str, Set[str]]
    started: Set[str]
    cancelled: Set[str]
    # ---- committed-checkpoint index ----
    store_cids: Set[str] = field(default_factory=set)
    store_mem: Optional[Dict[str, Any]] = None   # memory-backed stores only
    # ---- service plane (opaque to the engine) ----
    service: Dict[str, Any] = field(default_factory=dict)


def capture_session(engine, service: Optional[Dict[str, Any]] = None
                    ) -> SessionState:
    """Freeze a live engine into a :class:`SessionState`.  Flushes the
    write-behind store (durability barrier) before indexing it."""
    engine.store.flush()
    return SessionState(
        version=SESSION_FORMAT_VERSION,
        plan_key=engine.plan.key,
        n_workers=len(engine.workers),
        gpus_per_worker=engine.gpus_per_worker,
        share=engine.share,
        max_steps_per_chain=engine.max_steps_per_chain,
        batch_siblings=engine.batch_siblings,
        chain_fusion=engine.chain_fusion,
        plan=engine.plan,
        events=engine.events,
        scheduler=engine.scheduler,
        stats=engine.stats,
        workers=[(w.wid, w.busy_until, w.idle, w.mesh)
                 for w in engine.workers],
        waiters=engine.aggregator.waiters,
        killed=engine.aggregator.killed,
        trials=engine._trials,
        handles=engine._handles,
        study_trials=engine._study_trials,
        started=engine._started,
        cancelled=engine._cancelled,
        store_cids=engine.store.committed_ids(),
        store_mem=engine.store.snapshot_trees(),
        service=dict(service or {}),
    )


def restore_engine(state: SessionState, backend: TrainerBackend,
                   store: Optional[CheckpointStore] = None):
    """Rebuild a live engine from ``state`` + a fresh backend/store.

    The restored engine continues the exact event stream of the captured
    one: same plan object graph, same heap, same clock, same accounting.
    Plan checkpoint entries the supplied store cannot serve are forgotten
    eagerly (recompute-on-miss, applied up front), so a store that lost
    blobs since the snapshot degrades to recomputation instead of
    KeyErrors."""
    from repro.core.engine.engine import ExecutionEngine  # cycle-free import

    if state.version != SESSION_FORMAT_VERSION:
        raise ValueError(
            f"session format v{state.version} is not v{SESSION_FORMAT_VERSION}"
            " — re-snapshot with the matching repro version")
    if store is None:
        store = CheckpointStore()
    if state.store_mem is not None and not store.directory:
        store.load_trees(state.store_mem)

    eng = ExecutionEngine(
        state.plan, backend, n_workers=state.n_workers,
        gpus_per_worker=state.gpus_per_worker, scheduler=state.scheduler,
        store=store, share=state.share,
        max_steps_per_chain=state.max_steps_per_chain,
        batch_siblings=state.batch_siblings, chain_fusion=state.chain_fusion,
        worker_meshes=[mesh for (_, _, _, mesh) in state.workers])

    # splice the captured session state into the freshly wired components —
    # the dispatcher/aggregator hold references, so patch both sides
    eng.events = state.events
    eng.stats = state.stats
    eng.dispatcher.events = state.events
    eng.dispatcher.stats = state.stats
    eng.aggregator.events = state.events
    eng.aggregator.stats = state.stats
    eng.aggregator.waiters = state.waiters
    eng.aggregator.killed = state.killed
    for w, (wid, busy_until, idle, mesh) in zip(eng.workers, state.workers):
        w.wid, w.busy_until, w.idle, w.mesh = wid, busy_until, idle, mesh
    eng._trials = state.trials
    eng._handles = state.handles
    eng._study_trials = state.study_trials
    eng._started = state.started
    eng._cancelled = state.cancelled
    for h in state.handles:
        h.engine = eng

    # eager recompute-on-miss: forget plan checkpoints the store lost
    # (anything written after the snapshot's flush barrier, or an external
    # eviction between snapshot and restore)
    for nid, node in state.plan.nodes.items():
        for step, cid in list(node.ckpts.items()):
            if cid not in state.store_cids or not store.contains(cid):
                state.plan.forget_ckpt(nid, step)
    return eng


# ---------------------------------------------------------------- file I/O
def save_session(state: SessionState, path: str) -> str:
    """Atomically pickle ``state`` to ``path`` (tmp + rename)."""
    tmp = f"{path}.tmp"
    with open(tmp, "wb") as f:
        pickle.dump(state, f)
    os.replace(tmp, path)
    return path


def load_session(path: str) -> SessionState:
    with open(path, "rb") as f:
        state = pickle.load(f)
    if not isinstance(state, SessionState):
        raise ValueError(f"{path!r} is not a repro session snapshot")
    return state
