"""Durable engine sessions — snapshot/restore for the service plane.

A :class:`SessionState` is the complete picklable state of one live
:class:`~repro.core.engine.engine.ExecutionEngine` session at an event
boundary: the search plan (with revision map, pending index and running
marks), the event heap and virtual clock, the waiter table, per-study
accounting, the scheduling policy (with its fair-share usage memory), the
worker states, and the committed-checkpoint index.  What it deliberately
does NOT contain:

* the **backend** (real trainers hold devices/executables) — re-supplied
  at restore,
* the **store object** (its write-behind writer thread is unpicklable) —
  the snapshot records the committed cid index instead, plus the raw
  cid→tree map when the store is memory-backed, so a restored in-memory
  session resumes with every checkpoint it had; directory stores are
  already durable on disk,
* transient scheduling state — the stage-tree builder is a pure memo over
  the plan and is rebuilt cold (identical trees, Algorithm 1 is a pure
  function of the plan).

``capture_session`` flushes the write-behind store first, so the snapshot
is a durability barrier: everything the plan records is committed at the
moment of capture.  On restore, plan checkpoint entries whose blob the
(possibly different) store cannot serve are forgotten up front — exactly
the recompute-on-miss degradation, applied eagerly — so a killed service
recomputes nothing beyond the write-behind puts that had not committed by
the last snapshot.

Snapshots must be taken at an event boundary (between ``engine.step()``
calls — the :class:`~repro.core.study.StudyService` enforces this): at
that point no dispatchable work is in limbo, so the event heap plus the
plan are the whole truth.  Restoring replays the identical event stream —
final :class:`~repro.core.engine.engine.EngineStats` (including the
per-study breakdown) are equal to an uninterrupted run's.

The on-disk format is a versioned pickle (``SESSION_FORMAT_VERSION``);
tuners and trials therefore must be picklable.  ``StudyHandle`` /
``StudyFuture`` drop their engine/service references when pickled and are
re-wired on restore.
"""

from __future__ import annotations

import os
import pickle
import threading
from dataclasses import dataclass, field
from typing import Any, Dict, List, Optional, Set, Tuple

from repro.core.engine.events import EventLoop
from repro.core.scheduler import SchedulingPolicy
from repro.core.searchplan import SearchPlan
from repro.core.trainer import TrainerBackend
from repro.train.checkpoint import CheckpointStore

__all__ = ["SessionState", "SESSION_FORMAT_VERSION", "capture_session",
           "restore_engine", "migrate_session", "save_session",
           "load_session", "save_session_rotated", "load_latest_session",
           "session_rotation", "sweep_session_tmps"]

# v2: EngineStats grew the checkpoint-plane v2 counters (delta/full bytes,
# per-tier hits, promotions/demotions) — v1 snapshots lack the fields and
# must be re-captured with the matching repro version
# v3: worker tuples carry the WorkerMesh descriptor (distribution plane
# v2) and EngineStats grew d2d/mesh-placement counters
# v4: worker tuples carry the fault-plane crash record (failures,
# times_quarantined, quarantined_until) and EngineStats grew the fault
# counters.  v2/v3 snapshots are MIGRATED forward on restore (missing
# mesh -> thread worker, missing fault fields -> clean record, missing
# stats fields -> dataclass defaults) — rolling upgrades keep old
# snapshots restorable.  v1 predates the versioned stats migration and
# stays rejected.
# v5: the on-disk envelope is no longer a bare pickle — it is the
# schema'd container of :mod:`repro.frontdoor.snapshot_v5` (8-byte
# length-prefixed JSON manifest + digest-verified typed/pickle records,
# the checkpoint plane's blob conventions), worker tuples carry the
# front-door ``draining`` flag, and a gateway envelope can nest one
# session record per plan key.  v2-v4 *pickle* files remain readable
# (forward migration: sniffed by magic byte, then migrated as before).
SESSION_FORMAT_VERSION = 5


@dataclass
class SessionState:
    """Picklable engine-session state (see module docstring for scope)."""

    version: int
    plan_key: str
    # ---- engine construction knobs ----
    n_workers: int
    gpus_per_worker: int
    share: bool
    max_steps_per_chain: Optional[int]
    batch_siblings: bool
    chain_fusion: bool
    # ---- live session state ----
    plan: SearchPlan
    events: EventLoop
    scheduler: SchedulingPolicy
    stats: Any                                   # EngineStats
    workers: List[Tuple]                         # (wid, busy_until, idle,
                                                 #  WorkerMesh | None,
                                                 #  failures, times_quar.,
                                                 #  quarantined_until,
                                                 #  draining)
    waiters: Dict[Tuple[str, int], List[Tuple[Any, Any]]]
    killed: Set[str]
    trials: Dict[str, Any]
    handles: List[Any]                           # StudyHandle (engine=None)
    study_trials: Dict[str, Set[str]]
    started: Set[str]
    cancelled: Set[str]
    # ---- committed-checkpoint index ----
    store_cids: Set[str] = field(default_factory=set)
    store_mem: Optional[Dict[str, Any]] = None   # memory-backed stores only
    # ---- service plane (opaque to the engine) ----
    service: Dict[str, Any] = field(default_factory=dict)


def capture_session(engine, service: Optional[Dict[str, Any]] = None
                    ) -> SessionState:
    """Freeze a live engine into a :class:`SessionState`.  Flushes the
    write-behind store (durability barrier) before indexing it."""
    engine.store.flush()
    return SessionState(
        version=SESSION_FORMAT_VERSION,
        plan_key=engine.plan.key,
        n_workers=len(engine.workers),
        gpus_per_worker=engine.gpus_per_worker,
        share=engine.share,
        max_steps_per_chain=engine.max_steps_per_chain,
        batch_siblings=engine.batch_siblings,
        chain_fusion=engine.chain_fusion,
        plan=engine.plan,
        events=engine.events,
        scheduler=engine.scheduler,
        stats=engine.stats,
        workers=[(w.wid, w.busy_until, w.idle, w.mesh, w.failures,
                  w.times_quarantined, w.quarantined_until, w.draining)
                 for w in engine.workers],
        waiters=engine.aggregator.waiters,
        killed=engine.aggregator.killed,
        trials=engine._trials,
        handles=engine._handles,
        study_trials=engine._study_trials,
        started=engine._started,
        cancelled=engine._cancelled,
        store_cids=engine.store.committed_ids(),
        store_mem=engine.store.snapshot_trees(),
        service=dict(service or {}),
    )


def migrate_session(state: SessionState) -> SessionState:
    """Upgrade an older readable snapshot to the current format in place.

    * v2 worker rows ``(wid, busy, idle)`` gain ``mesh=None`` (thread
      workers — the only kind v2 could express),
    * v3 rows ``(wid, busy, idle, mesh)`` gain a clean fault record,
    * v4 rows gain ``draining=False`` (no lease was being revoked),
    * a pickled ``EngineStats``/``StudyStats`` restores ``__dict__``
      as-was, so fields added since the snapshot are simply absent —
      fill every missing field with its dataclass default.

    v1 predates versioned stats migration and stays rejected."""
    from repro.core.engine.engine import EngineStats, StudyStats

    if state.version not in (2, 3, 4, SESSION_FORMAT_VERSION):
        raise ValueError(
            f"session format v{state.version} is not migratable to "
            f"v{SESSION_FORMAT_VERSION} — re-snapshot with a matching "
            "repro version")
    rows = []
    for row in state.workers:
        row = tuple(row)
        if len(row) == 3:                      # v2: (wid, busy, idle)
            row += (None,)
        if len(row) == 4:                      # v3: ... + mesh
            row += (0, 0, 0.0)
        if len(row) == 7:                      # v4: ... + fault record
            row += (False,)
        rows.append(row)
    state.workers = rows
    defaults = EngineStats()
    for f in defaults.__dataclass_fields__:
        if not hasattr(state.stats, f):
            setattr(state.stats, f, getattr(defaults, f))
    sdefaults = StudyStats()
    for ss in state.stats.by_study.values():
        for f in sdefaults.__dataclass_fields__:
            if not hasattr(ss, f):
                setattr(ss, f, getattr(sdefaults, f))
    state.version = SESSION_FORMAT_VERSION
    return state


def restore_engine(state: SessionState, backend: TrainerBackend,
                   store: Optional[CheckpointStore] = None,
                   fault_injector=None):
    """Rebuild a live engine from ``state`` + a fresh backend/store.

    The restored engine continues the exact event stream of the captured
    one: same plan object graph, same heap, same clock, same accounting.
    Plan checkpoint entries the supplied store cannot serve are forgotten
    eagerly (recompute-on-miss, applied up front), so a store that lost
    blobs since the snapshot degrades to recomputation instead of
    KeyErrors.  Older snapshot formats are migrated forward (see
    :func:`migrate_session`)."""
    from repro.core.engine.engine import ExecutionEngine  # cycle-free import

    migrate_session(state)
    if store is None:
        store = CheckpointStore()
    if state.store_mem is not None and not store.directory:
        store.load_trees(state.store_mem)

    eng = ExecutionEngine(
        state.plan, backend, n_workers=state.n_workers,
        gpus_per_worker=state.gpus_per_worker, scheduler=state.scheduler,
        store=store, share=state.share,
        max_steps_per_chain=state.max_steps_per_chain,
        batch_siblings=state.batch_siblings, chain_fusion=state.chain_fusion,
        worker_meshes=[row[3] for row in state.workers],
        fault_injector=fault_injector)

    # splice the captured session state into the freshly wired components —
    # the dispatcher/aggregator hold references, so patch both sides
    eng.events = state.events
    eng.stats = state.stats
    eng.dispatcher.events = state.events
    eng.dispatcher.stats = state.stats
    eng.aggregator.events = state.events
    eng.aggregator.stats = state.stats
    eng.aggregator.waiters = state.waiters
    eng.aggregator.killed = state.killed
    for w, (wid, busy_until, idle, mesh, fails, quars, quntil,
            draining) in zip(eng.workers, state.workers):
        w.wid, w.busy_until, w.idle, w.mesh = wid, busy_until, idle, mesh
        w.failures, w.times_quarantined = fails, quars
        w.quarantined_until = quntil
        w.draining = draining
    # ids keep growing where the captured fleet left off — a restored
    # session's next lease grant must not collide with a live wid
    eng._next_wid = 1 + max((row[0] for row in state.workers), default=-1)
    eng._trials = state.trials
    eng._handles = state.handles
    eng._study_trials = state.study_trials
    eng._started = state.started
    eng._cancelled = state.cancelled
    for h in state.handles:
        h.engine = eng

    # eager recompute-on-miss: forget plan checkpoints the store lost
    # (anything written after the snapshot's flush barrier, or an external
    # eviction between snapshot and restore)
    for nid, node in state.plan.nodes.items():
        for step, cid in list(node.ckpts.items()):
            if cid not in state.store_cids or not store.contains(cid):
                state.plan.forget_ckpt(nid, step)
    return eng


# ---------------------------------------------------------------- file I/O
def save_session(state, path: str) -> str:
    """Atomically write ``state`` to ``path`` (tmp + rename) in the v5
    schema'd container format (:mod:`repro.frontdoor.snapshot_v5` — JSON
    manifest + digest-verified records; ``state`` may be a
    :class:`SessionState` or a gateway envelope).

    The tmp name is pid/thread-unique (like the checkpoint store's):
    overlapping snapshotters — a rolling restart where old and new
    processes both snapshot the same path — each write their own tmp and
    the rename race resolves to one complete snapshot instead of
    interleaved writes publishing a corrupt one."""
    # the codec lives with the front door (it also encodes gateway
    # envelopes); imported lazily to keep the engine package import-light
    from repro.frontdoor.snapshot_v5 import encode_snapshot

    data = encode_snapshot(state)
    tmp = f"{path}.tmp.{os.getpid()}.{threading.get_ident()}"
    try:
        with open(tmp, "wb") as f:
            f.write(data)
        os.replace(tmp, path)
    except BaseException:
        try:
            os.unlink(tmp)
        except OSError:
            pass
        raise
    return path


def load_session(path: str):
    """Read a session (or gateway) snapshot — v5 schema'd container, or a
    legacy v2-v4 pickle (sniffed by magic byte) migrated forward on
    restore.  Digest mismatches in a v5 file raise ``ValueError`` so the
    rotation reader falls back to the previous slot."""
    from repro.frontdoor.snapshot_v5 import decode_snapshot, is_v5_snapshot

    with open(path, "rb") as f:
        data = f.read()
    if is_v5_snapshot(data):
        return decode_snapshot(data)
    state = pickle.loads(data)                 # legacy: versioned pickle
    if not isinstance(state, SessionState):
        raise ValueError(f"{path!r} is not a repro session snapshot")
    return state


# ----------------------------------------------------- rotated snapshots
def _pid_alive(pid: int) -> bool:
    if pid == os.getpid():
        return True
    try:
        os.kill(pid, 0)                # signal 0: existence probe only
        return True
    except ProcessLookupError:
        return False
    except OSError:
        return True                    # EPERM etc: exists, not ours


def session_rotation(base: str) -> List[Tuple[int, str]]:
    """Existing rotation slots ``base.<seq>``, newest (highest seq) first."""
    d = os.path.dirname(os.path.abspath(base))
    prefix = os.path.basename(base) + "."
    out = []
    try:
        names = os.listdir(d)
    except OSError:
        return []
    for name in names:
        suffix = name[len(prefix):] if name.startswith(prefix) else ""
        if suffix.isdigit():
            out.append((int(suffix), os.path.join(d, name)))
    return sorted(out, reverse=True)


def sweep_session_tmps(base: str) -> int:
    """Sweep orphaned snapshot tmps of DEAD writers across *every*
    rotation slot of ``base`` (and the base path itself); returns the
    count removed.  The tmp name embeds the writer's pid, so a live
    concurrent writer (rolling restart: old and new process both
    snapshotting) keeps its in-flight tmp and its os.replace still lands.
    Called after each rotated write AND at startup
    (:func:`load_latest_session`) — a writer that crashed mid-write into a
    slot no later writer touches would otherwise leak its tmp forever."""
    d = os.path.dirname(os.path.abspath(base))
    prefix = os.path.basename(base) + "."
    swept = 0
    try:
        names = os.listdir(d)
    except OSError:
        return 0
    for name in names:
        if not (name.startswith(prefix) and ".tmp." in name):
            continue
        pid_s = name.rsplit(".tmp.", 1)[1].split(".", 1)[0]
        if pid_s.isdigit() and _pid_alive(int(pid_s)):
            continue
        try:
            os.unlink(os.path.join(d, name))
            swept += 1
        except OSError:
            pass
    return swept


def save_session_rotated(state, base: str, keep: int = 3) -> str:
    """Write the next rotation slot ``base.<seq>`` atomically and prune
    slots beyond the newest ``keep`` — the continuous-durability sink of
    ``serve_studies --snapshot-every``.  Readers (:func:`load_latest_session`)
    fall back through the rotation, so a crash mid-write (torn tmp, or a
    SIGKILL between write and rename) costs one slot, never the session."""
    slots = session_rotation(base)
    seq = (slots[0][0] + 1) if slots else 1
    path = save_session(state, f"{base}.{seq}")
    for _, stale in slots[max(0, keep - 1):]:
        try:
            os.unlink(stale)
        except OSError:
            pass
    sweep_session_tmps(base)
    return path


def load_latest_session(base: str) -> Tuple[SessionState, str]:
    """(state, path) from the newest *readable* rotation slot of ``base``.

    A truncated, corrupt or non-snapshot newest slot (the process died
    mid-publish, disk lost a tail) falls back to the previous slot —
    restore loses at most one snapshot interval.  Raises
    ``FileNotFoundError`` when no slot is readable."""
    # startup sweep: reclaim tmps a crashed writer left in ANY slot —
    # including slots the new process will never write again
    sweep_session_tmps(base)
    failures = []
    for _, path in session_rotation(base):
        try:
            return load_session(path), path
        except Exception as exc:  # truncation, bad pickle, foreign file
            failures.append(f"{path}: {type(exc).__name__}: {exc}")
    detail = ("; unreadable: " + "; ".join(failures)) if failures else ""
    raise FileNotFoundError(
        f"no readable session snapshot in rotation {base!r}.N{detail}")
