"""Event-driven execution engine package.

Layout (the former 326-line ``core/engine.py`` monolith, split):

* :mod:`~repro.core.engine.events`     — event heap + virtual clock,
* :mod:`~repro.core.engine.dispatch`   — scheduling rounds, chain
  assignment/truncation, worker-side execution,
* :mod:`~repro.core.engine.aggregator` — result recording, waiter wakeup,
  checkpoint GC,
* :mod:`~repro.core.engine.engine`     — the public :class:`ExecutionEngine`
  facade (API-compatible with the old module: same constructor, ``run()``,
  ``handle()``).
"""

from repro.core.engine.engine import (EngineStats, ExecutionEngine,
                                      StudyHandle, Tuner)
from repro.core.engine.events import Event, EventLoop
from repro.core.engine.dispatch import Dispatcher, Worker
from repro.core.engine.aggregator import Aggregator

__all__ = ["ExecutionEngine", "Tuner", "StudyHandle", "EngineStats",
           "Event", "EventLoop", "Dispatcher", "Worker", "Aggregator"]
