"""Event-driven execution engine package.

Layout (the former 326-line ``core/engine.py`` monolith, split):

* :mod:`~repro.core.engine.events`     — event heap + virtual clock,
* :mod:`~repro.core.engine.dispatch`   — scheduling rounds, chain
  assignment/truncation, worker-side execution,
* :mod:`~repro.core.engine.aggregator` — result recording, waiter wakeup,
  checkpoint GC,
* :mod:`~repro.core.engine.engine`     — the public :class:`ExecutionEngine`
  facade (API-compatible with the old module: same constructor, ``run()``,
  ``handle()``) plus the re-entrant session loop (``step`` / ``drain`` /
  ``admit`` / ``cancel_study`` / ``finish``) the service plane drives,
* :mod:`~repro.core.engine.session`    — durable session snapshots
  (:class:`SessionState`, capture/restore) behind
  ``StudyService.snapshot`` / ``StudyService.restore``.
"""

from repro.core.engine.engine import (EngineStats, ExecutionEngine,
                                      StudyHandle, StudyStats, Tuner)
from repro.core.engine.events import Event, EventLoop
from repro.core.engine.dispatch import Dispatcher, Worker
from repro.core.engine.aggregator import Aggregator
from repro.core.engine.session import (SessionState, capture_session,
                                       load_latest_session, load_session,
                                       migrate_session, restore_engine,
                                       save_session, save_session_rotated,
                                       session_rotation, sweep_session_tmps)

__all__ = ["ExecutionEngine", "Tuner", "StudyHandle", "EngineStats",
           "StudyStats", "Event", "EventLoop", "Dispatcher", "Worker",
           "Aggregator", "SessionState", "capture_session", "restore_engine",
           "migrate_session", "save_session", "load_session",
           "save_session_rotated", "load_latest_session", "session_rotation",
           "sweep_session_tmps"]
