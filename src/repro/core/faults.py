"""Fault plane — deterministic fault injection for the execution engine.

Hippo's stage trees make failure *expensive*: a stage executes once per
tree, so a lost stage forfeits work that many trials (and studies) were
going to share.  The fault plane turns failure into a first-class,
testable input: a seeded :class:`FaultInjector` drives reproducible fault
schedules through :class:`FaultyBackend` / :class:`FaultyStore` wrappers,
and the dispatcher's failure domains (``repro.core.engine.dispatch``)
absorb them — transient faults retry from the boundary checkpoint with
capped virtual-clock exponential backoff, repeatedly-crashing workers are
quarantined with probation re-admission, failed batched groups degrade to
per-member solo execution, and every failed attempt's cost lands in
``EngineStats.wasted_gpu_seconds`` (never split-charged to the sharing
studies' fair-share accounts).

Fault taxonomy (all derive from :class:`FaultError`, and deliberately NOT
from ``ValueError`` — the dispatcher and backends use ``ValueError`` as
the in-flight "fall back to unfused/unbatched execution" signal, which
must stay distinguishable from an injected failure):

* :class:`TransientStageError` — one execution attempt failed (flaky
  kernel, OOM race, preempted slice); retry is expected to succeed.
* :class:`WorkerCrashed` — the executing worker died mid-attempt; the
  work retries elsewhere and the worker's crash count feeds quarantine.
* :class:`StoreOutageError` — the checkpoint store refused a window of
  operations (network blip to the remote tier); transient.
* :class:`FatalStageError` — non-retryable (deterministic assertion,
  poison input); classified fatal and propagated after accounting.

Everything is deterministic: one ``random.Random(seed)`` stream, drawn in
the engine's (deterministic) execution order, so the same seed replays
the same fault schedule — the property the retry-bitwise tests and the CI
soak rely on.
"""

from __future__ import annotations

import random
from typing import Any, Dict, List, Optional

__all__ = [
    "FaultError", "TransientStageError", "WorkerCrashed", "StoreOutageError",
    "FatalStageError", "is_transient", "FaultInjector", "FaultyBackend",
    "FaultyStore", "raw_store",
]


# --------------------------------------------------------------- exceptions
class FaultError(Exception):
    """Base of all injected/recognized faults.

    ``transient`` marks whether a retry of the same work is expected to
    succeed; the dispatcher also honors a truthy ``transient`` attribute
    on foreign exception types (real backends can tag their own).
    """

    transient = True


class TransientStageError(FaultError):
    """One execution attempt failed; retrying from the boundary
    checkpoint is expected to succeed."""


class WorkerCrashed(TransientStageError):
    """The executing worker died mid-attempt.  The work retries like any
    transient fault; the worker additionally accrues a crash toward
    quarantine and its d2d cache entries are invalidated."""


class StoreOutageError(FaultError):
    """The checkpoint store refused an operation (outage window)."""


class FatalStageError(FaultError):
    """Non-retryable failure — propagated after the books are balanced."""

    transient = False


def is_transient(exc: BaseException) -> bool:
    """Classify an exception caught in a dispatcher failure domain."""
    return bool(getattr(exc, "transient", False))


# ----------------------------------------------------------------- injector
class FaultInjector:
    """Seeded, deterministic fault schedule.

    One ``random.Random(seed)`` stream is drawn at every injection site in
    execution order, so a given seed replays the identical schedule.  Per
    site one draw happens per *rate knob* (crash, stage, outage,
    straggler) whether or not it fires — rates can be tuned independently
    without perturbing each other's draw positions... within a fixed set
    of enabled knobs.

    ``outage_ops``: a fired store outage opens a window in which that many
    subsequent store operations also fail (one logical outage, counted
    once) — modelling a remote-tier blip rather than a single lost call.

    ``max_faults`` bounds the total injections (None = unbounded) so soak
    schedules terminate even at aggressive rates.
    """

    def __init__(self, seed: int = 0, *,
                 stage_fault_rate: float = 0.0,
                 crash_rate: float = 0.0,
                 outage_rate: float = 0.0,
                 straggler_rate: float = 0.0,
                 straggler_factor: float = 4.0,
                 admission_fault_rate: float = 0.0,
                 outage_ops: int = 3,
                 max_faults: Optional[int] = None):
        self.seed = seed
        self.stage_fault_rate = stage_fault_rate
        self.crash_rate = crash_rate
        self.outage_rate = outage_rate
        self.straggler_rate = straggler_rate
        self.straggler_factor = straggler_factor
        self.admission_fault_rate = admission_fault_rate
        self.outage_ops = outage_ops
        self.max_faults = max_faults
        self._rng = random.Random(seed)
        self._outage_left = 0
        self.injected = 0                      # faults fired (windows count 1)
        self.by_kind: Dict[str, int] = {}
        self.retries_verified = 0              # re-puts proven bit-identical
        self.log: List[Dict[str, Any]] = []    # one entry per fired fault

    # ------------------------------------------------------------- plumbing
    def _draw(self, rate: float) -> bool:
        if rate <= 0.0:
            return False
        hit = self._rng.random() < rate
        if hit and (self.max_faults is not None
                    and self.injected >= self.max_faults):
            return False
        return hit

    def _record(self, kind: str, site: str) -> None:
        self.injected += 1
        self.by_kind[kind] = self.by_kind.get(kind, 0) + 1
        self.log.append({"seed": self.seed, "n": self.injected,
                         "kind": kind, "site": site})

    # ------------------------------------------------------ injection sites
    def before_execute(self, site: str) -> None:
        """One backend execution attempt (stage/chain/batched group) is
        about to run: maybe crash the worker, maybe fail the attempt."""
        if self._draw(self.crash_rate):
            self._record("crash", site)
            raise WorkerCrashed(f"injected worker crash at {site}")
        if self._draw(self.stage_fault_rate):
            self._record("stage", site)
            raise TransientStageError(f"injected stage failure at {site}")

    def on_store_op(self, op: str, key: str) -> None:
        """One checkpoint-store get/put is about to run."""
        if self._outage_left > 0:
            self._outage_left -= 1
            raise StoreOutageError(
                f"injected store outage (window) at {op} {key}")
        if self._draw(self.outage_rate):
            self._record("outage", f"{op}:{key}")
            self._outage_left = max(0, self.outage_ops - 1)
            raise StoreOutageError(f"injected store outage at {op} {key}")

    def on_admission(self, site: str) -> bool:
        """One gateway admission decision is about to commit (front door).
        True = the admission is *deferred*: the control plane lost the
        request this round, the study stays queued (``queued_admission``)
        and is retried at the next admission pump — a transient
        control-plane fault, not lost work.  Drawn from the same seeded
        stream as the data-plane sites, so a gateway run with admission
        faults is exactly replayable."""
        if self._draw(self.admission_fault_rate):
            self._record("admission", site)
            return True
        return False

    # ---------------------------------------------------- stream snapshot
    def snapshot_state(self) -> Dict[str, Any]:
        """Picklable mid-run state of the fault schedule (front-door
        snapshots carry it so a restored gateway *continues* the schedule
        instead of replaying it from the seed)."""
        return {"rng": self._rng.getstate(), "outage_left": self._outage_left,
                "injected": self.injected, "by_kind": dict(self.by_kind),
                "retries_verified": self.retries_verified,
                "log": list(self.log)}

    def restore_state(self, state: Dict[str, Any]) -> None:
        self._rng.setstate(state["rng"])
        self._outage_left = state["outage_left"]
        self.injected = state["injected"]
        self.by_kind = dict(state["by_kind"])
        self.retries_verified = state["retries_verified"]
        self.log = list(state["log"])

    def straggle(self, seconds: Optional[float], site: str) -> Optional[float]:
        """Maybe stretch a stage's virtual duration (slow node, thermal
        throttle).  Stragglers complete — they are a performance fault,
        not a correctness one."""
        if seconds is None:
            return None
        if self._draw(self.straggler_rate):
            self._record("straggler", site)
            return seconds * self.straggler_factor
        return seconds


# ----------------------------------------------------------------- wrappers
class FaultyBackend:
    """Injects faults in front of a :class:`~repro.core.trainer.TrainerBackend`.

    Deliberately NOT a ``TrainerBackend`` subclass: the base class carries
    capability class attributes (``supports_batched_stages``,
    ``supports_chain_fusion``) whose defaults would shadow the inner
    backend's values behind ``__getattr__`` delegation.  Everything not
    explicitly overridden delegates to the wrapped backend.
    """

    def __init__(self, inner, injector: FaultInjector):
        self.inner = inner
        self.fault_injector = injector

    def __getattr__(self, name):
        return getattr(self.inner, name)

    # ------------------------------------------------------ execution sites
    def run_stage(self, state, ctx):
        self.fault_injector.before_execute(
            f"stage:{ctx.node_id}@{ctx.stop}")
        return self.inner.run_stage(state, ctx)

    def run_chain(self, state, ctxs):
        self.fault_injector.before_execute(
            f"chain:{ctxs[0].node_id}@{ctxs[0].start}-{ctxs[-1].stop}")
        return self.inner.run_chain(state, ctxs)

    def run_stages_batched(self, states, ctxs):
        self.fault_injector.before_execute(
            f"group:{ctxs[0].node_id}@{ctxs[0].stop}x{len(ctxs)}")
        return self.inner.run_stages_batched(states, ctxs)

    def run_chains_batched(self, states, ctx_chains):
        self.fault_injector.before_execute(
            f"group-chain:{ctx_chains[0][0].node_id}"
            f"@{ctx_chains[0][0].start}x{len(ctx_chains)}")
        return self.inner.run_chains_batched(states, ctx_chains)

    def stage_seconds(self, ctx):
        return self.fault_injector.straggle(
            self.inner.stage_seconds(ctx),
            f"stage:{ctx.node_id}@{ctx.stop}")


class FaultyStore:
    """Injects outages in front of a checkpoint store.

    Only ``get``/``put``/``put_async`` are injection sites — eviction, GC
    and ``flush`` stay reliable so fault schedules never corrupt the
    store's own invariants (an outage loses *access*, not data).
    ``put_async`` raises synchronously (the outage hits the enqueue), so
    failures surface inside the executing chain's failure domain instead
    of at the flush barrier.
    """

    def __init__(self, inner, injector: FaultInjector):
        self.inner = inner
        self.fault_injector = injector

    def __getattr__(self, name):
        return getattr(self.inner, name)

    def __len__(self):  # dunders bypass __getattr__
        return len(self.inner)

    def get(self, cid):
        self.fault_injector.on_store_op("get", cid)
        return self.inner.get(cid)

    def put(self, path_key, step, tree, parent_cid=None):
        self.fault_injector.on_store_op("put", f"{path_key}@{step}")
        return self.inner.put(path_key, step, tree, parent_cid=parent_cid)

    def put_async(self, path_key, step, tree, parent_cid=None):
        self.fault_injector.on_store_op("put", f"{path_key}@{step}")
        return self.inner.put_async(path_key, step, tree,
                                    parent_cid=parent_cid)


def raw_store(store):
    """The underlying store of a possibly-wrapped store (outage-free
    access for verification/GC paths that must not draw from the fault
    schedule)."""
    return store.inner if isinstance(store, FaultyStore) else store
