"""Search-plan database (Hippo §4.2) — the MySQL analogue.

Holds one :class:`SearchPlan` per study *key* — the (model, dataset,
hyper-parameter set) triple of §5.2.  Studies submitting under the same key
share a plan, which is the entire multi-study merging mechanism.  An
optional JSON journal persists plans across processes (swap-in point for a
real database in deployment).
"""

from __future__ import annotations

import json
import os
from typing import Dict, Optional, Tuple

from repro.core.searchplan import SearchPlan
from repro.utils import stable_hash

__all__ = ["SearchPlanDB", "study_key"]


def study_key(model: str, dataset: str, hp_set: Tuple[str, ...]) -> str:
    """Canonical study key: same (model, dataset, hp types) → same plan."""
    return stable_hash({"model": model, "dataset": dataset,
                        "hp_set": sorted(hp_set)})[:16]


class SearchPlanDB:
    def __init__(self, journal_dir: Optional[str] = None):
        self.journal_dir = journal_dir
        if journal_dir:
            os.makedirs(journal_dir, exist_ok=True)
        self._plans: Dict[str, SearchPlan] = {}

    def get(self, key: str) -> SearchPlan:
        if key not in self._plans:
            path = self._path(key)
            if path and os.path.exists(path):
                with open(path) as f:
                    self._plans[key] = SearchPlan.from_json(json.load(f))
            else:
                self._plans[key] = SearchPlan(key)
        return self._plans[key]

    def put(self, key: str, plan: SearchPlan) -> None:
        """Install a live plan under ``key`` (session restore: the revived
        plan object — revision map, pending index, running marks — replaces
        whatever a journal reload would have produced)."""
        self._plans[key] = plan

    def checkpoint(self, key: str) -> None:
        """Journal a plan to disk (called by the aggregator after updates)."""
        path = self._path(key)
        if not path:
            return
        tmp = path + ".tmp"
        with open(tmp, "w") as f:
            json.dump(self._plans[key].to_json(), f)
        os.replace(tmp, path)

    def keys(self):
        return list(self._plans)

    def _path(self, key: str) -> Optional[str]:
        if not self.journal_dir:
            return None
        return os.path.join(self.journal_dir, f"plan-{key}.json")
