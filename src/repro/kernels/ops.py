"""Jitted public wrappers for the Pallas kernels — the kernel plane's API.

``flash_attention`` / ``ssd_intra`` are what the model layer calls when
``use_kernel=True``.  On CPU (this container) the kernel bodies run in
``interpret=True`` mode for correctness validation; on TPU the same calls
compile to Mosaic.  Three properties make them safe inside the engine's
hot paths:

* **Differentiable through Pallas**: the ``custom_vjp`` backward is the
  FA2 recompute-tile kernel pair (``flash_attention_bwd``) and the SSD
  backward kernel (``ssd_intra_bwd_pallas``) — ``jax.grad`` inside a
  chunk executable stays on the kernel plane instead of detouring
  through the XLA reference.
* **Trial-stacked batching**: every kernel entry point carries a
  ``jax.custom_batching.custom_vmap`` rule that folds the vmapped member
  axis into the kernel's batch grid axis (``(M, B, …) → (M·B, …)``), so
  ``jax.vmap`` over sibling-group members launches ONE kernel over a
  larger grid rather than silently dropping to the oracle.  Unbatched
  operands are broadcast along the member axis first.  The rules live on
  the *raw* kernel launchers (called from inside the custom_vjp fwd/bwd,
  where no further AD happens), sidestepping ``custom_vmap``'s autodiff
  limitations.
* **Counted fallbacks**: a call that cannot use the kernel (non-TPU
  accelerator backend — Pallas TPU kernels don't lower on GPU) drops to
  the jnp oracle, increments ``KERNEL_STATS.fallbacks`` with a reason,
  and warns once per (kernel, reason) — no more silent oracle detours.
  ``KERNEL_STATS.calls`` counts kernel-plane *call sites traced* (a
  compiled executable does not re-run Python, so counters move at trace
  time — constant per distinct compilation, not per step).  Surfaced via
  ``JaxTrainer.kernel_calls`` / ``EngineStats.kernel_fallbacks`` and the
  ``bench_kernels`` fallback column.
"""

from __future__ import annotations

import functools
import warnings
from collections import Counter
from dataclasses import dataclass, field
from typing import Tuple

import jax
import jax.numpy as jnp
from jax.custom_batching import custom_vmap

from repro.kernels.flash_attention import (flash_attention_bwd,
                                           flash_attention_fwd)
from repro.kernels.ref import attention_ref, ssd_intra_ref
from repro.kernels.ssd_scan import ssd_intra_bwd_pallas, ssd_intra_pallas

__all__ = ["flash_attention", "ssd_intra", "KernelFallbackWarning",
           "KERNEL_STATS", "reset_kernel_stats", "note_call",
           "note_fallback", "unsupported_reason"]


class KernelFallbackWarning(UserWarning):
    """A kernel-plane call dropped to the jnp oracle."""


@dataclass
class KernelStats:
    """Module-global kernel-plane accounting (trace-time counters)."""
    calls: int = 0
    fallbacks: int = 0
    reasons: Counter = field(default_factory=Counter)

    def snapshot(self) -> Tuple[int, int]:
        return (self.calls, self.fallbacks)


KERNEL_STATS = KernelStats()
_WARNED: set = set()


def reset_kernel_stats() -> None:
    KERNEL_STATS.calls = 0
    KERNEL_STATS.fallbacks = 0
    KERNEL_STATS.reasons.clear()
    _WARNED.clear()


def note_call(kernel: str) -> None:
    KERNEL_STATS.calls += 1


def note_fallback(kernel: str, reason: str) -> None:
    KERNEL_STATS.fallbacks += 1
    KERNEL_STATS.reasons[f"{kernel}:{reason}"] += 1
    if (kernel, reason) not in _WARNED:
        _WARNED.add((kernel, reason))
        warnings.warn(
            f"pallas kernel {kernel!r} fell back to the jnp oracle "
            f"({reason}); the kernel plane is inactive for these calls",
            KernelFallbackWarning, stacklevel=3)


def unsupported_reason() -> str:
    """Why the current backend cannot run the TPU kernels (None-able str:
    empty == supported).  CPU runs them in interpret mode; GPU has no
    Mosaic lowering, so the oracle is the honest path there."""
    backend = jax.default_backend()
    if backend in ("cpu", "tpu"):
        return ""
    return f"backend:{backend}"


def _fold(axis_size: int, batched, *args):
    """Broadcast unbatched operands along the member axis, then fold the
    member axis into each operand's leading batch axis."""
    out = []
    for a, b in zip(args, batched):
        if not b:
            a = jnp.broadcast_to(a, (axis_size,) + a.shape)
        out.append(a.reshape((a.shape[0] * a.shape[1],) + a.shape[2:]))
    return out


def _unfold(m: int, x):
    return x.reshape((m, x.shape[0] // m) + x.shape[1:])


# ------------------------------------------------------- flash attention
@functools.lru_cache(maxsize=None)
def _fa_fwd_op(causal: bool, window: int):
    """Raw forward launcher (returns out + lse) with a member-folding
    batching rule; statics are closed over (one op per (causal, window))."""
    @custom_vmap
    def fwd(q, k, v):
        return flash_attention_fwd(q, k, v, causal=causal, window=window,
                                   return_lse=True)

    @fwd.def_vmap
    def _rule(axis_size, in_batched, q, k, v):
        q, k, v = _fold(axis_size, in_batched, q, k, v)
        out, lse = flash_attention_fwd(q, k, v, causal=causal,
                                       window=window, return_lse=True)
        return (_unfold(axis_size, out), _unfold(axis_size, lse)), \
            (True, True)

    return fwd


@functools.lru_cache(maxsize=None)
def _fa_bwd_op(causal: bool, window: int):
    @custom_vmap
    def bwd(q, k, v, out, lse, do):
        return flash_attention_bwd(q, k, v, out, lse, do, causal=causal,
                                   window=window)

    @bwd.def_vmap
    def _rule(axis_size, in_batched, *args):
        args = _fold(axis_size, in_batched, *args)
        dq, dk, dv = flash_attention_bwd(*args[:6], causal=causal,
                                         window=window)
        return tuple(_unfold(axis_size, x) for x in (dq, dk, dv)), \
            (True, True, True)

    return bwd


@functools.partial(jax.custom_vjp, nondiff_argnums=(3, 4))
def _fa(q, k, v, causal, window):
    out, _ = _fa_fwd_op(causal, window)(q, k, v)
    return out


def _fa_fwd(q, k, v, causal, window):
    out, lse = _fa_fwd_op(causal, window)(q, k, v)
    return out, (q, k, v, out, lse)


def _fa_bwd(causal, window, res, g):
    q, k, v, out, lse = res
    return _fa_bwd_op(causal, window)(q, k, v, out, lse, g)


_fa.defvjp(_fa_fwd, _fa_bwd)


def flash_attention(q: jnp.ndarray, k: jnp.ndarray, v: jnp.ndarray, *,
                    causal: bool = True, window: int = 0) -> jnp.ndarray:
    """(B,S,Hq,hd) GQA flash attention; differentiable (Pallas backward)
    and vmap-aware (member axis folds into the kernel grid)."""
    reason = unsupported_reason()
    if reason:
        note_fallback("flash_attention", reason)
        return attention_ref(q, k, v, causal=causal, window=window)
    note_call("flash_attention")
    return _fa(q, k, v, causal, window)


# ------------------------------------------------------------- ssd intra
@custom_vmap
def _ssd_fwd_op(xr, dtr, ltT, Br, Cr):
    return ssd_intra_pallas(xr, dtr, ltT, Br, Cr)


@_ssd_fwd_op.def_vmap
def _ssd_fwd_rule(axis_size, in_batched, *args):
    args = _fold(axis_size, in_batched, *args)
    return _unfold(axis_size, ssd_intra_pallas(*args)), True


@custom_vmap
def _ssd_bwd_op(xr, dtr, ltT, Br, Cr, g):
    return ssd_intra_bwd_pallas(xr, dtr, ltT, Br, Cr, g)


@_ssd_bwd_op.def_vmap
def _ssd_bwd_rule(axis_size, in_batched, *args):
    args = _fold(axis_size, in_batched, *args)
    grads = ssd_intra_bwd_pallas(*args)
    return tuple(_unfold(axis_size, x) for x in grads), (True,) * 5


@jax.custom_vjp
def _ssd(xr, dtr, ltT, Br, Cr):
    return _ssd_fwd_op(xr, dtr, ltT, Br, Cr)


def _ssd_fwd(xr, dtr, ltT, Br, Cr):
    return _ssd(xr, dtr, ltT, Br, Cr), (xr, dtr, ltT, Br, Cr)


def _ssd_bwd(res, g):
    return _ssd_bwd_op(*res, g)


_ssd.defvjp(_ssd_fwd, _ssd_bwd)


def ssd_intra(xr, dtr, ltT, Br, Cr):
    """Intra-chunk SSD term via the Pallas kernel (Pallas backward,
    member-folding vmap rule)."""
    reason = unsupported_reason()
    if reason:
        note_fallback("ssd_intra", reason)
        return ssd_intra_ref(xr, dtr, ltT, Br, Cr)
    note_call("ssd_intra")
    return _ssd(xr, dtr, ltT, Br, Cr)
