"""Jitted public wrappers for the Pallas kernels.

``flash_attention`` / ``ssd_intra`` are what the model layer calls when
``use_kernel=True``.  On CPU (this container) they run the kernel bodies in
``interpret=True`` mode for correctness validation; on TPU the same calls
compile to Mosaic.  Both fall back to the jnp oracle under ``vmap``/AD
transforms where the kernel is forward-only.
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp

from repro.kernels.flash_attention import flash_attention_fwd
from repro.kernels.ref import attention_ref, ssd_intra_ref
from repro.kernels.ssd_scan import ssd_intra_pallas

__all__ = ["flash_attention", "ssd_intra"]


@functools.partial(jax.custom_vjp, nondiff_argnums=(3, 4))
def _fa(q, k, v, causal, window):
    return flash_attention_fwd(q, k, v, causal=causal, window=window)


def _fa_fwd(q, k, v, causal, window):
    return _fa(q, k, v, causal, window), (q, k, v)


def _fa_bwd(causal, window, res, g):
    # backward through the reference (XLA) attention — the paper's workloads
    # serve/evaluate through the kernel; training backprop stays in XLA.
    q, k, v = res
    _, vjp = jax.vjp(lambda q_, k_, v_: attention_ref(
        q_, k_, v_, causal=causal, window=window), q, k, v)
    return vjp(g)


_fa.defvjp(_fa_fwd, _fa_bwd)


def flash_attention(q: jnp.ndarray, k: jnp.ndarray, v: jnp.ndarray, *,
                    causal: bool = True, window: int = 0) -> jnp.ndarray:
    """(B,S,Hq,hd) GQA flash attention; differentiable (XLA backward)."""
    return _fa(q, k, v, causal, window)


@functools.partial(jax.custom_vjp, nondiff_argnums=())
def _ssd(xr, dtr, ltT, Br, Cr):
    return ssd_intra_pallas(xr, dtr, ltT, Br, Cr)


def _ssd_fwd(xr, dtr, ltT, Br, Cr):
    return _ssd(xr, dtr, ltT, Br, Cr), (xr, dtr, ltT, Br, Cr)


def _ssd_bwd(res, g):
    xr, dtr, ltT, Br, Cr = res
    _, vjp = jax.vjp(ssd_intra_ref, xr, dtr, ltT, Br, Cr)
    return vjp(g)


_ssd.defvjp(_ssd_fwd, _ssd_bwd)


def ssd_intra(xr, dtr, ltT, Br, Cr):
    """Intra-chunk SSD term via the Pallas kernel (XLA backward)."""
    return _ssd(xr, dtr, ltT, Br, Cr)
