"""Pure-jnp oracles for the Pallas kernels (the allclose targets)."""

from __future__ import annotations

import jax
import jax.numpy as jnp

__all__ = ["attention_ref", "ssd_intra_ref"]


def attention_ref(q: jnp.ndarray, k: jnp.ndarray, v: jnp.ndarray, *,
                  causal: bool = True, window: int = 0) -> jnp.ndarray:
    """Naive softmax attention with GQA; q (B,Sq,Hq,hd), k/v (B,Sk,Hkv,hd)."""
    B, Sq, Hq, hd = q.shape
    _, Sk, Hkv, _ = k.shape
    group = Hq // Hkv
    qg = q.reshape(B, Sq, Hkv, group, hd)
    s = jnp.einsum("bsngh,btnh->bngst", qg.astype(jnp.float32),
                   k.astype(jnp.float32)) * hd ** -0.5
    q_pos = jnp.arange(Sq)[:, None]
    k_pos = jnp.arange(Sk)[None, :]
    mask = jnp.ones((Sq, Sk), bool)
    if causal:
        mask &= k_pos <= q_pos
    if window > 0:
        mask &= k_pos > q_pos - window
    s = jnp.where(mask[None, None, None], s, -1e30)
    p = jax.nn.softmax(s, axis=-1)
    out = jnp.einsum("bngst,btnh->bsngh", p, v.astype(jnp.float32))
    return out.reshape(B, Sq, Hq, hd).astype(q.dtype)


def ssd_intra_ref(xr: jnp.ndarray, dtr: jnp.ndarray, ltT: jnp.ndarray,
                  Br: jnp.ndarray, Cr: jnp.ndarray) -> jnp.ndarray:
    """Naive intra-chunk SSD: the masked-decay attention form.

    Shapes as :func:`repro.kernels.ssd_scan.ssd_intra_pallas`.
    """
    Q = xr.shape[2]
    cum = jnp.cumsum(ltT, axis=-1)                       # (B,nc,H,Q)
    seg = cum[..., :, None] - cum[..., None, :]          # (B,nc,H,Q,Q)
    tril = jnp.tril(jnp.ones((Q, Q), bool))
    decay = jnp.where(tril, jnp.exp(seg), 0.0)
    cb = jnp.einsum("bcin,bcjn->bcij", Cr.astype(jnp.float32),
                    Br.astype(jnp.float32))
    att = cb[:, :, None] * decay * jnp.moveaxis(dtr, -1, -2)[..., None, :]
    y = jnp.einsum("bchij,bcjhp->bcihp", att, xr.astype(jnp.float32))
    return y.astype(xr.dtype)
