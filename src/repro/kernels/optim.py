"""Fused trial-stacked optimizer-update Pallas kernel.

On the batched-sibling path the data plane vmaps the whole chunk body
over the member axis, so the optimizer update becomes ~4 XLA ops ×
parameter leaves × members.  This kernel fuses one leaf's update across
every member into a single launch: the member-stacked leaf is viewed as
``(M, R, 128)`` lanes, the grid is ``(M, R/BR)``, and the divergent
per-member hyper-parameters (lr, wd, momentum, b1/b2/eps) ride in as
``(M, 1)`` vector operands indexed by the member grid axis — exactly the
"divergent hp values, one compile per group" contract the data plane
already guarantees for the loss.

:func:`fused_apply_update` is a drop-in for
:func:`repro.train.optimizer.apply_update` (same signature, same
update formulas — sgd / momentum / adam / adamw with the same wd
coupling and bias correction, computed in f32 and cast back to the leaf
dtype).  Each per-leaf op carries a ``custom_vmap`` rule that folds the
vmapped member axis into the kernel's member grid axis, so the solo path
(M = 1) and the vmapped sibling-group path share one kernel.  Like the
attention/SSD wrappers in :mod:`repro.kernels.ops`, calls and fallbacks
are counted in ``KERNEL_STATS`` (reason-tagged, warn-once).

No custom_vjp is needed: the optimizer update sits outside
``value_and_grad`` in every chunk body.
"""

from __future__ import annotations

import functools
from typing import Any, Dict, Optional, Tuple

import jax
import jax.numpy as jnp
import numpy as np
from jax.custom_batching import custom_vmap
from jax.experimental import pallas as pl

from repro.kernels import ops as kops
from repro.train.optimizer import apply_update

__all__ = ["fused_apply_update"]

_LANE = 128      # f32 lane width: leaves are padded to lane multiples
_MAX_ROWS = 64   # block rows → ≤ 64·128 elements per grid step


def _sgd_kernel(p_ref, g_ref, lr_ref, wd_ref, o_ref):
    p = p_ref[0].astype(jnp.float32)
    g = g_ref[0].astype(jnp.float32)
    lr = lr_ref[0, 0]
    wd = wd_ref[0, 0]
    o_ref[0] = (p - lr * (g + wd * p)).astype(o_ref.dtype)


def _momentum_kernel(p_ref, g_ref, m_ref, lr_ref, wd_ref, mom_ref,
                     op_ref, om_ref):
    p = p_ref[0].astype(jnp.float32)
    g = g_ref[0].astype(jnp.float32)
    m = m_ref[0].astype(jnp.float32)
    lr = lr_ref[0, 0]
    wd = wd_ref[0, 0]
    mom = mom_ref[0, 0]
    m2 = mom * m + g
    om_ref[0] = m2.astype(om_ref.dtype)
    op_ref[0] = (p - lr * (m2 + wd * p)).astype(op_ref.dtype)


def _adam_kernel(p_ref, g_ref, m_ref, v_ref, lr_ref, wd_ref, b1_ref,
                 b2_ref, eps_ref, bc1_ref, bc2_ref, op_ref, om_ref, ov_ref,
                 *, decoupled: bool):
    p = p_ref[0].astype(jnp.float32)
    g = g_ref[0].astype(jnp.float32)
    m = m_ref[0].astype(jnp.float32)
    v = v_ref[0].astype(jnp.float32)
    lr = lr_ref[0, 0]
    wd = wd_ref[0, 0]
    b1 = b1_ref[0, 0]
    b2 = b2_ref[0, 0]
    eps = eps_ref[0, 0]
    m2 = b1 * m + (1 - b1) * g
    v2 = b2 * v + (1 - b2) * g * g
    om_ref[0] = m2.astype(om_ref.dtype)
    ov_ref[0] = v2.astype(ov_ref.dtype)
    mh = m2 / bc1_ref[0, 0]
    vh = v2 / bc2_ref[0, 0]
    if decoupled:   # adamw
        upd = p - lr * (mh / (jnp.sqrt(vh) + eps) + wd * p)
    else:           # adam: wd folded into the gradient (L2)
        upd = p - lr * mh / (jnp.sqrt(vh) + eps) - lr * wd * p
    op_ref[0] = upd.astype(op_ref.dtype)


# per optimizer: (kernel, #array operands, #scalar operands, #outputs)
_SPEC = {
    "sgd": (_sgd_kernel, 2, 2, 1),
    "momentum": (_momentum_kernel, 3, 3, 2),
    "adam": (functools.partial(_adam_kernel, decoupled=False), 4, 7, 3),
    "adamw": (functools.partial(_adam_kernel, decoupled=True), 4, 7, 3),
}


def _stacked_leaf_update(name: str, *args, interpret: Optional[bool] = None):
    """One member-stacked leaf update: ``args`` are ``narr`` arrays of
    shape (M, *leaf) followed by ``nscal`` per-member (M,) f32 scalars."""
    kernel, narr, nscal, nout = _SPEC[name]
    arrs, scals = args[:narr], args[narr:]
    assert len(scals) == nscal, (name, len(scals))
    M = arrs[0].shape[0]
    shape = arrs[0].shape[1:]
    L = int(np.prod(shape, dtype=np.int64)) if shape else 1

    R = -(-L // _LANE)
    br = min(R, _MAX_ROWS)
    Rp = -(-R // br) * br
    pad = Rp * _LANE - L

    def lanes(a):
        flat = a.reshape(M, L)
        if pad:
            flat = jnp.pad(flat, ((0, 0), (0, pad)))
        return flat.reshape(M, Rp, _LANE)

    if interpret is None:
        interpret = jax.default_backend() == "cpu"

    blk = pl.BlockSpec((1, br, _LANE), lambda i, j: (i, j, 0))
    sblk = pl.BlockSpec((1, 1), lambda i, j: (i, 0))
    out_shape = [jax.ShapeDtypeStruct((M, Rp, _LANE), arrs[i].dtype)
                 for i in range(nout)]
    outs = pl.pallas_call(
        kernel,
        grid=(M, Rp // br),
        in_specs=[blk] * narr + [sblk] * nscal,
        out_specs=[blk] * nout if nout > 1 else blk,
        out_shape=out_shape if nout > 1 else out_shape[0],
        interpret=interpret,
    )(*[lanes(a) for a in arrs],
      *[s.reshape(M, 1).astype(jnp.float32) for s in scals])

    def unlanes(o):
        flat = o.reshape(M, Rp * _LANE)
        if pad:
            flat = flat[:, :L]
        return flat.reshape((M,) + shape)

    if nout == 1:
        return unlanes(outs)
    return tuple(unlanes(o) for o in outs)


@functools.lru_cache(maxsize=None)
def _leaf_op(name: str):
    """Single-member leaf op with a member-folding batching rule: vmap
    over sibling-group members maps onto the kernel's member grid axis."""
    def run(args):
        if name in ("adam", "adamw"):
            # precompute the bias corrections on (M,) vectors in XLA —
            # args: p, g, m, v, lr, wd, b1, b2, eps, t
            *rest, b1, b2, eps, t = args
            tt = t.astype(jnp.float32) + 1.0
            bc1 = 1.0 - b1 ** tt
            bc2 = 1.0 - b2 ** tt
            args = (*rest, b1, b2, eps, bc1, bc2)
        return _stacked_leaf_update(name, *args)

    @custom_vmap
    def op(*args):
        outs = run(tuple(jnp.asarray(a)[None] for a in args))
        if isinstance(outs, tuple):
            return tuple(o[0] for o in outs)
        return outs[0]

    @op.def_vmap
    def _rule(axis_size, in_batched, *args):
        args = tuple(
            a if b else jnp.broadcast_to(jnp.asarray(a),
                                         (axis_size,) + jnp.shape(a))
            for a, b in zip(args, in_batched))
        outs = run(args)
        if isinstance(outs, tuple):
            return outs, tuple(True for _ in outs)
        return outs, True

    return op


def _pick(tree, i: int):
    return jax.tree.map(lambda t: t[i], tree,
                        is_leaf=lambda x: isinstance(x, tuple))


def fused_apply_update(name: str, params: Any, grads: Any,
                       state: Dict[str, Any], hp: Dict[str, jnp.ndarray],
                       step: jnp.ndarray) -> Tuple[Any, Dict[str, Any]]:
    """Drop-in for :func:`repro.train.optimizer.apply_update` running each
    leaf's update as one fused Pallas launch (member-stacked under vmap)."""
    reason = kops.unsupported_reason()
    if reason:
        kops.note_fallback("opt_update", reason)
        return apply_update(name, params, grads, state, hp, step)
    kops.note_call("opt_update")

    f32 = lambda x: jnp.asarray(x, jnp.float32)
    lr = f32(hp["lr"])
    wd = f32(hp.get("wd", 0.0))

    if name == "sgd":
        op = _leaf_op("sgd")
        new = jax.tree.map(lambda p, g: op(p, g, lr, wd), params, grads)
        return new, state

    if name == "momentum":
        mom = f32(hp.get("momentum", 0.9))
        op = _leaf_op("momentum")
        pairs = jax.tree.map(lambda p, g, m: op(p, g, m, lr, wd, mom),
                             params, grads, state["m"])
        return _pick(pairs, 0), {"m": _pick(pairs, 1)}

    if name in ("adam", "adamw"):
        b1 = f32(hp.get("b1", 0.9))
        b2 = f32(hp.get("b2", 0.999))
        eps = f32(hp.get("eps", 1e-8))
        t = f32(step)
        op = _leaf_op(name)
        trips = jax.tree.map(
            lambda p, g, m, v: op(p, g, m, v, lr, wd, b1, b2, eps, t),
            params, grads, state["m"], state["v"])
        return _pick(trips, 0), {"m": _pick(trips, 1), "v": _pick(trips, 2)}

    raise ValueError(name)
