"""Pallas TPU kernel for the SSD intra-chunk term (Mamba2 hot spot).

Within a chunk of ``Q`` steps the SSD output is an attention-like product::

    att[i, j] = (C_i · B_j) · exp(cum_i − cum_j) · dt_j     (j ≤ i)
    y[i]      = Σ_j att[i, j] · x_j

— two Q×N and one Q×Q matmul per (batch, chunk, head): exactly the MXU
shape the TPU wants when Q = N = 128 (mamba2-2.7b's configuration).  The
kernel computes one (batch, chunk, head) cell per grid step with all
operands resident in VMEM:

  VMEM working set = Q·N (C) + Q·N (B) + Q (cum) + Q (dt) + Q·P (x)
                   + Q·Q (att) + Q·P (y) ≈ 0.3 MB at Q=N=P=128 — far under
  the ~16 MB budget, leaving headroom for double-buffered pipelining.

The inter-chunk state hand-off stays in XLA (a ``lax.scan`` of rank-1
updates — bandwidth-bound, nothing for the MXU), mirroring how the paper's
CUDA SSD kernel splits intra/inter work.  Oracle: ``ref.ssd_intra_ref``.

The **backward** kernel (:func:`ssd_intra_bwd_pallas`) walks the same
(B·nc, H) grid.  Per cell it recomputes the forward tile (cb, decay, att)
and derives all five input cotangents; the B/C projections are shared
across heads, so their gradient contribution ``dcb = Σ_h datt_h · decay_h
· dt_h`` accumulates in a (Q, Q) VMEM scratch across the sequential
innermost head axis, and ``dB = dcbᵀC`` / ``dC = dcb·B`` are emitted once
at the last head step (the output block's index_map is constant in ``h``,
the legal TPU revisiting pattern).  The ``dcum → dltT`` suffix-sum (the
cumsum transpose) is O(Q) elementwise and stays in XLA, like the
inter-chunk scan.
"""

from __future__ import annotations

import functools
from typing import Optional, Tuple

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

__all__ = ["ssd_intra_pallas", "ssd_intra_bwd_pallas"]


def _ssd_kernel(x_ref, dt_ref, cum_ref, b_ref, c_ref, o_ref, *, q: int):
    x = x_ref[0, 0, :, 0].astype(jnp.float32)        # (Q, P)
    dt = dt_ref[0, 0, 0].astype(jnp.float32)         # (Q,)
    cum = cum_ref[0, 0, 0].astype(jnp.float32)       # (Q,)
    Bm = b_ref[0, 0].astype(jnp.float32)             # (Q, N)
    Cm = c_ref[0, 0].astype(jnp.float32)             # (Q, N)

    cb = jax.lax.dot_general(Cm, Bm, (((1,), (1,)), ((), ())))   # (Q, Q)
    seg = cum[:, None] - cum[None, :]                            # cum_i - cum_j
    i_pos = jax.lax.broadcasted_iota(jnp.int32, (q, q), 0)
    j_pos = jax.lax.broadcasted_iota(jnp.int32, (q, q), 1)
    tril = j_pos <= i_pos
    decay = jnp.where(tril, jnp.exp(seg), 0.0)
    att = cb * decay * dt[None, :]
    y = jax.lax.dot_general(att, x, (((1,), (0,)), ((), ())))    # (Q, P)
    o_ref[0, 0, :, 0] = y.astype(o_ref.dtype)


def ssd_intra_pallas(xr: jnp.ndarray, dtr: jnp.ndarray, ltT: jnp.ndarray,
                     Br: jnp.ndarray, Cr: jnp.ndarray,
                     interpret: Optional[bool] = None) -> jnp.ndarray:
    """Intra-chunk SSD term.

    xr  (B, nc, Q, H, P)  chunked head inputs
    dtr (B, nc, Q, H)     per-step dt
    ltT (B, nc, H, Q)     per-step log-decay (dt·A), head-major
    Br/Cr (B, nc, Q, N)   state in/out projections (shared across heads)
    → y (B, nc, Q, H, P)
    """
    B, nc, Q, H, P = xr.shape
    N = Br.shape[-1]
    cum = jnp.cumsum(ltT, axis=-1)                   # (B, nc, H, Q)

    # head-major layouts so each grid cell reads contiguous blocks
    x_hm = jnp.moveaxis(xr, 3, 2)                    # (B, nc, H, Q, P)
    dt_hm = jnp.moveaxis(dtr, 3, 2)                  # (B, nc, H, Q)

    if interpret is None:
        interpret = jax.default_backend() == "cpu"

    grid = (B * nc, H)
    out = pl.pallas_call(
        functools.partial(_ssd_kernel, q=Q),
        grid=grid,
        in_specs=[
            pl.BlockSpec((1, 1, Q, 1, P), lambda bc, h: (bc, h, 0, 0, 0)),
            pl.BlockSpec((1, 1, 1, Q), lambda bc, h: (bc, h, 0, 0)),
            pl.BlockSpec((1, 1, 1, Q), lambda bc, h: (bc, h, 0, 0)),
            pl.BlockSpec((1, 1, Q, N), lambda bc, h: (bc, 0, 0, 0)),
            pl.BlockSpec((1, 1, Q, N), lambda bc, h: (bc, 0, 0, 0)),
        ],
        out_specs=pl.BlockSpec((1, 1, Q, 1, P), lambda bc, h: (bc, h, 0, 0, 0)),
        out_shape=jax.ShapeDtypeStruct((B * nc, H, Q, 1, P), xr.dtype),
        interpret=interpret,
    )(
        x_hm.reshape(B * nc, H, Q, 1, P),
        dt_hm.reshape(B * nc, H, 1, Q),
        cum.reshape(B * nc, H, 1, Q),
        Br.reshape(B * nc, 1, Q, N),
        Cr.reshape(B * nc, 1, Q, N),
    )
    y = out.reshape(B, nc, H, Q, P)
    return jnp.moveaxis(y, 2, 3)                     # (B, nc, Q, H, P)


def _ssd_bwd_kernel(x_ref, dt_ref, cum_ref, b_ref, c_ref, g_ref,
                    dx_ref, ddt_ref, dcum_ref, db_ref, dc_ref,
                    dcb_scr, *, q: int):
    h = pl.program_id(1)
    nh = pl.num_programs(1)

    x = x_ref[0, 0, :, 0].astype(jnp.float32)        # (Q, P)
    dt = dt_ref[0, 0, 0].astype(jnp.float32)         # (Q,)
    cum = cum_ref[0, 0, 0].astype(jnp.float32)       # (Q,)
    Bm = b_ref[0, 0].astype(jnp.float32)             # (Q, N)
    Cm = c_ref[0, 0].astype(jnp.float32)             # (Q, N)
    g = g_ref[0, 0, :, 0].astype(jnp.float32)        # (Q, P)

    # recompute the forward tile
    cb = jax.lax.dot_general(Cm, Bm, (((1,), (1,)), ((), ())))   # (Q, Q)
    seg = cum[:, None] - cum[None, :]
    i_pos = jax.lax.broadcasted_iota(jnp.int32, (q, q), 0)
    j_pos = jax.lax.broadcasted_iota(jnp.int32, (q, q), 1)
    tril = j_pos <= i_pos
    decay = jnp.where(tril, jnp.exp(seg), 0.0)
    att = cb * decay * dt[None, :]

    # y = att @ x  ⇒  datt = g xᵀ, dx = attᵀ g
    datt = jax.lax.dot_general(g, x, (((1,), (1,)), ((), ())))   # (Q, Q)
    dx = jax.lax.dot_general(att, g, (((0,), (0,)), ((), ())))   # (Q, P)

    # att = cb · decay · dt[None, :]: product-rule splits, all masked by
    # decay (zero above the diagonal, so no tril re-mask needed)
    dad = datt * decay                                           # (Q, Q)
    ddt = jnp.sum(dad * cb, axis=0)                              # (Q,)
    dseg = dad * cb * dt[None, :]                                # through exp
    dcum = jnp.sum(dseg, axis=1) - jnp.sum(dseg, axis=0)         # (Q,)

    dx_ref[0, 0, :, 0] = dx.astype(dx_ref.dtype)
    ddt_ref[0, 0, 0] = ddt.astype(ddt_ref.dtype)
    dcum_ref[0, 0, 0] = dcum.astype(dcum_ref.dtype)

    # B/C are shared across heads: accumulate dcb over the sequential
    # innermost h axis, emit dB/dC once at the last head step
    dcb_h = dad * dt[None, :]

    @pl.when(h == 0)
    def _init():
        dcb_scr[...] = jnp.zeros_like(dcb_scr)

    dcb_scr[...] += dcb_h

    @pl.when(h == nh - 1)
    def _finish():
        dcb = dcb_scr[...]
        db_ref[0, 0] = jax.lax.dot_general(
            dcb, Cm, (((0,), (0,)), ((), ()))).astype(db_ref.dtype)
        dc_ref[0, 0] = jax.lax.dot_general(
            dcb, Bm, (((1,), (0,)), ((), ()))).astype(dc_ref.dtype)


def ssd_intra_bwd_pallas(xr: jnp.ndarray, dtr: jnp.ndarray, ltT: jnp.ndarray,
                         Br: jnp.ndarray, Cr: jnp.ndarray, g: jnp.ndarray,
                         interpret: Optional[bool] = None
                         ) -> Tuple[jnp.ndarray, jnp.ndarray, jnp.ndarray,
                                    jnp.ndarray, jnp.ndarray]:
    """Backward of :func:`ssd_intra_pallas` for cotangent ``g`` (the shape
    of ``y``).  Returns (dxr, ddtr, dltT, dBr, dCr) in input layouts."""
    B, nc, Q, H, P = xr.shape
    N = Br.shape[-1]
    cum = jnp.cumsum(ltT, axis=-1)                   # (B, nc, H, Q)

    x_hm = jnp.moveaxis(xr, 3, 2)                    # (B, nc, H, Q, P)
    dt_hm = jnp.moveaxis(dtr, 3, 2)                  # (B, nc, H, Q)
    g_hm = jnp.moveaxis(g, 3, 2)

    if interpret is None:
        interpret = jax.default_backend() == "cpu"

    grid = (B * nc, H)
    x_spec = pl.BlockSpec((1, 1, Q, 1, P), lambda bc, h: (bc, h, 0, 0, 0))
    row_spec = pl.BlockSpec((1, 1, 1, Q), lambda bc, h: (bc, h, 0, 0))
    bc_spec = pl.BlockSpec((1, 1, Q, N), lambda bc, h: (bc, 0, 0, 0))
    dx, ddt, dcum, db, dc = pl.pallas_call(
        functools.partial(_ssd_bwd_kernel, q=Q),
        grid=grid,
        in_specs=[x_spec, row_spec, row_spec, bc_spec, bc_spec, x_spec],
        out_specs=[x_spec, row_spec, row_spec, bc_spec, bc_spec],
        out_shape=[
            jax.ShapeDtypeStruct((B * nc, H, Q, 1, P), xr.dtype),
            jax.ShapeDtypeStruct((B * nc, H, 1, Q), dtr.dtype),
            jax.ShapeDtypeStruct((B * nc, H, 1, Q), jnp.float32),
            jax.ShapeDtypeStruct((B * nc, 1, Q, N), Br.dtype),
            jax.ShapeDtypeStruct((B * nc, 1, Q, N), Cr.dtype),
        ],
        scratch_shapes=[pltpu.VMEM((Q, Q), jnp.float32)],
        interpret=interpret,
    )(
        x_hm.reshape(B * nc, H, Q, 1, P),
        dt_hm.reshape(B * nc, H, 1, Q),
        cum.reshape(B * nc, H, 1, Q),
        Br.reshape(B * nc, 1, Q, N),
        Cr.reshape(B * nc, 1, Q, N),
        g_hm.reshape(B * nc, H, Q, 1, P),
    )

    dxr = jnp.moveaxis(dx.reshape(B, nc, H, Q, P), 2, 3)
    ddtr = jnp.moveaxis(ddt.reshape(B, nc, H, Q), 2, 3)
    # cum = cumsum(ltT) ⇒ dltT is the suffix sum (reversed cumsum) of dcum
    dcum = dcum.reshape(B, nc, H, Q)
    dltT = jnp.cumsum(dcum[..., ::-1], axis=-1)[..., ::-1].astype(ltT.dtype)
    dBr = db.reshape(B, nc, Q, N)
    dCr = dc.reshape(B, nc, Q, N)
    return dxr, ddtr, dltT, dBr, dCr
