"""Pallas TPU kernel for the SSD intra-chunk term (Mamba2 hot spot).

Within a chunk of ``Q`` steps the SSD output is an attention-like product::

    att[i, j] = (C_i · B_j) · exp(cum_i − cum_j) · dt_j     (j ≤ i)
    y[i]      = Σ_j att[i, j] · x_j

— two Q×N and one Q×Q matmul per (batch, chunk, head): exactly the MXU
shape the TPU wants when Q = N = 128 (mamba2-2.7b's configuration).  The
kernel computes one (batch, chunk, head) cell per grid step with all
operands resident in VMEM:

  VMEM working set = Q·N (C) + Q·N (B) + Q (cum) + Q (dt) + Q·P (x)
                   + Q·Q (att) + Q·P (y) ≈ 0.3 MB at Q=N=P=128 — far under
  the ~16 MB budget, leaving headroom for double-buffered pipelining.

The inter-chunk state hand-off stays in XLA (a ``lax.scan`` of rank-1
updates — bandwidth-bound, nothing for the MXU), mirroring how the paper's
CUDA SSD kernel splits intra/inter work.  Oracle: ``ref.ssd_intra_ref``.
"""

from __future__ import annotations

import functools
from typing import Optional

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

__all__ = ["ssd_intra_pallas"]


def _ssd_kernel(x_ref, dt_ref, cum_ref, b_ref, c_ref, o_ref, *, q: int):
    x = x_ref[0, 0, :, 0].astype(jnp.float32)        # (Q, P)
    dt = dt_ref[0, 0, 0].astype(jnp.float32)         # (Q,)
    cum = cum_ref[0, 0, 0].astype(jnp.float32)       # (Q,)
    Bm = b_ref[0, 0].astype(jnp.float32)             # (Q, N)
    Cm = c_ref[0, 0].astype(jnp.float32)             # (Q, N)

    cb = jax.lax.dot_general(Cm, Bm, (((1,), (1,)), ((), ())))   # (Q, Q)
    seg = cum[:, None] - cum[None, :]                            # cum_i - cum_j
    i_pos = jax.lax.broadcasted_iota(jnp.int32, (q, q), 0)
    j_pos = jax.lax.broadcasted_iota(jnp.int32, (q, q), 1)
    tril = j_pos <= i_pos
    decay = jnp.where(tril, jnp.exp(seg), 0.0)
    att = cb * decay * dt[None, :]
    y = jax.lax.dot_general(att, x, (((1,), (0,)), ((), ())))    # (Q, P)
    o_ref[0, 0, :, 0] = y.astype(o_ref.dtype)


def ssd_intra_pallas(xr: jnp.ndarray, dtr: jnp.ndarray, ltT: jnp.ndarray,
                     Br: jnp.ndarray, Cr: jnp.ndarray,
                     interpret: Optional[bool] = None) -> jnp.ndarray:
    """Intra-chunk SSD term.

    xr  (B, nc, Q, H, P)  chunked head inputs
    dtr (B, nc, Q, H)     per-step dt
    ltT (B, nc, H, Q)     per-step log-decay (dt·A), head-major
    Br/Cr (B, nc, Q, N)   state in/out projections (shared across heads)
    → y (B, nc, Q, H, P)
    """
    B, nc, Q, H, P = xr.shape
    N = Br.shape[-1]
    cum = jnp.cumsum(ltT, axis=-1)                   # (B, nc, H, Q)

    # head-major layouts so each grid cell reads contiguous blocks
    x_hm = jnp.moveaxis(xr, 3, 2)                    # (B, nc, H, Q, P)
    dt_hm = jnp.moveaxis(dtr, 3, 2)                  # (B, nc, H, Q)

    if interpret is None:
        interpret = jax.default_backend() == "cpu"

    grid = (B * nc, H)
    out = pl.pallas_call(
        functools.partial(_ssd_kernel, q=Q),
        grid=grid,
        in_specs=[
            pl.BlockSpec((1, 1, Q, 1, P), lambda bc, h: (bc, h, 0, 0, 0)),
            pl.BlockSpec((1, 1, 1, Q), lambda bc, h: (bc, h, 0, 0)),
            pl.BlockSpec((1, 1, 1, Q), lambda bc, h: (bc, h, 0, 0)),
            pl.BlockSpec((1, 1, Q, N), lambda bc, h: (bc, 0, 0, 0)),
            pl.BlockSpec((1, 1, Q, N), lambda bc, h: (bc, 0, 0, 0)),
        ],
        out_specs=pl.BlockSpec((1, 1, Q, 1, P), lambda bc, h: (bc, h, 0, 0, 0)),
        out_shape=jax.ShapeDtypeStruct((B * nc, H, Q, 1, P), xr.dtype),
        interpret=interpret,
    )(
        x_hm.reshape(B * nc, H, Q, 1, P),
        dt_hm.reshape(B * nc, H, 1, Q),
        cum.reshape(B * nc, H, 1, Q),
        Br.reshape(B * nc, 1, Q, N),
        Cr.reshape(B * nc, 1, Q, N),
    )
    y = out.reshape(B, nc, H, Q, P)
    return jnp.moveaxis(y, 2, 3)                     # (B, nc, Q, H, P)
