"""Pallas TPU flash attention (forward) with GQA, causal and window masks.

Blockwise online-softmax attention à la Flash-Attention-2, tiled for the
TPU memory hierarchy:

* grid = (batch, q_heads, Sq/BQ, Sk/BK); the KV dimension is the innermost
  (sequential on TPU), so the running (m, l, acc) statistics live in VMEM
  scratch across KV steps;
* ``BlockSpec`` tiles: Q block (BQ, hd), K/V blocks (BK, hd) — BQ = BK =
  128 by default, MXU-aligned; the working set per step is
  ``(BQ + 2·BK)·hd·4`` bytes ≪ 16 MB VMEM;
* GQA without materializing repeated KV heads: the K/V index_map sends
  query-head ``h`` to KV head ``h // group``;
* causal/sliding-window masking is applied per-tile from absolute
  positions; fully-masked tiles still execute (structured skipping via
  ``pl.when`` is a TPU-side optimization; on the interpret path we keep it
  simple and correct).

Validated against :mod:`repro.kernels.ref` in ``interpret=True`` mode
(kernel body executed step-by-step on CPU); on real TPUs the same code
compiles to Mosaic.
"""

from __future__ import annotations

import functools
from typing import Optional

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

__all__ = ["flash_attention_fwd"]

NEG_INF = -1e30


def _fa_kernel(q_ref, k_ref, v_ref, o_ref, m_scr, l_scr, acc_scr, *,
               scale: float, causal: bool, window: int, bq: int, bk: int,
               seq_k: int):
    qi = pl.program_id(2)
    ki = pl.program_id(3)
    nk = pl.num_programs(3)

    @pl.when(ki == 0)
    def _init():
        m_scr[...] = jnp.full_like(m_scr, NEG_INF)
        l_scr[...] = jnp.zeros_like(l_scr)
        acc_scr[...] = jnp.zeros_like(acc_scr)

    q = q_ref[0, 0].astype(jnp.float32)                      # (BQ, hd)
    k = k_ref[0, 0].astype(jnp.float32)                      # (BK, hd)
    v = v_ref[0, 0].astype(jnp.float32)                      # (BK, hd)

    s = jax.lax.dot_general(q, k, (((1,), (1,)), ((), ())))  # (BQ, BK)
    s = s * scale

    q_pos = qi * bq + jax.lax.broadcasted_iota(jnp.int32, (bq, bk), 0)
    k_pos = ki * bk + jax.lax.broadcasted_iota(jnp.int32, (bq, bk), 1)
    mask = k_pos < seq_k
    if causal:
        mask &= k_pos <= q_pos
    if window > 0:
        mask &= k_pos > q_pos - window
    s = jnp.where(mask, s, NEG_INF)

    m_prev = m_scr[...]                                      # (BQ, 1)
    m_cur = jnp.max(s, axis=1, keepdims=True)
    m_new = jnp.maximum(m_prev, m_cur)
    alpha = jnp.exp(m_prev - m_new)
    p = jnp.exp(s - m_new)                                   # (BQ, BK)

    l_new = alpha * l_scr[...] + jnp.sum(p, axis=1, keepdims=True)
    acc_scr[...] = acc_scr[...] * alpha + jax.lax.dot_general(
        p, v, (((1,), (0,)), ((), ())))
    m_scr[...] = m_new
    l_scr[...] = l_new

    @pl.when(ki == nk - 1)
    def _finish():
        # rows that saw no valid key (padding) get l = 0 → emit zeros
        l = l_scr[...]
        safe = jnp.where(l == 0.0, 1.0, l)
        o_ref[0, 0] = (acc_scr[...] / safe).astype(o_ref.dtype)


def flash_attention_fwd(q: jnp.ndarray, k: jnp.ndarray, v: jnp.ndarray, *,
                        causal: bool = True, window: int = 0,
                        block_q: int = 128, block_k: int = 128,
                        interpret: Optional[bool] = None) -> jnp.ndarray:
    """q: (B, Sq, Hq, hd); k, v: (B, Sk, Hkv, hd) → (B, Sq, Hq, hd).

    Hq must be a multiple of Hkv (GQA).  Sequences are padded to the block
    size internally; padded keys are masked out, padded queries dropped.
    """
    B, Sq, Hq, hd = q.shape
    _, Sk, Hkv, _ = k.shape
    assert Hq % Hkv == 0, (Hq, Hkv)
    group = Hq // Hkv
    scale = hd ** -0.5

    bq = min(block_q, max(8, Sq))
    bk = min(block_k, max(8, Sk))
    pq = (-Sq) % bq
    pk = (-Sk) % bk
    qp = jnp.pad(q, ((0, 0), (0, pq), (0, 0), (0, 0))) if pq else q
    kp = jnp.pad(k, ((0, 0), (0, pk), (0, 0), (0, 0))) if pk else k
    vp = jnp.pad(v, ((0, 0), (0, pk), (0, 0), (0, 0))) if pk else v
    Sqp, Skp = Sq + pq, Sk + pk

    # layout: (B, H, S, hd) for clean 2D blocks
    qt = qp.transpose(0, 2, 1, 3)
    kt = kp.transpose(0, 2, 1, 3)
    vt = vp.transpose(0, 2, 1, 3)

    grid = (B, Hq, Sqp // bq, Skp // bk)

    if interpret is None:
        interpret = jax.default_backend() == "cpu"

    out = pl.pallas_call(
        functools.partial(_fa_kernel, scale=scale, causal=causal,
                          window=window, bq=bq, bk=bk, seq_k=Sk),
        grid=grid,
        in_specs=[
            pl.BlockSpec((1, 1, bq, hd), lambda b, h, i, j: (b, h, i, 0)),
            pl.BlockSpec((1, 1, bk, hd), lambda b, h, i, j: (b, h // group, j, 0)),
            pl.BlockSpec((1, 1, bk, hd), lambda b, h, i, j: (b, h // group, j, 0)),
        ],
        out_specs=pl.BlockSpec((1, 1, bq, hd), lambda b, h, i, j: (b, h, i, 0)),
        out_shape=jax.ShapeDtypeStruct((B, Hq, Sqp, hd), q.dtype),
        scratch_shapes=[
            pltpu.VMEM((bq, 1), jnp.float32),
            pltpu.VMEM((bq, 1), jnp.float32),
            pltpu.VMEM((bq, hd), jnp.float32),
        ],
        interpret=interpret,
    )(qt, kt, vt)

    out = out.transpose(0, 2, 1, 3)
    return out[:, :Sq] if pq else out
