"""Pallas TPU flash attention (fwd + bwd) with GQA, causal and window masks.

Blockwise online-softmax attention à la Flash-Attention-2, tiled for the
TPU memory hierarchy:

* grid = (batch, q_heads, Sq/BQ, Sk/BK); the KV dimension is the innermost
  (sequential on TPU), so the running (m, l, acc) statistics live in VMEM
  scratch across KV steps;
* ``BlockSpec`` tiles: Q block (BQ, hd), K/V blocks (BK, hd) — BQ = BK =
  128 by default, MXU-aligned; the working set per step is
  ``(BQ + 2·BK)·hd·4`` bytes ≪ 16 MB VMEM;
* GQA without materializing repeated KV heads: the K/V index_map sends
  query-head ``h`` to KV head ``h // group``;
* causal/sliding-window masking is applied per-tile from absolute
  positions; fully-masked (Q, KV) tiles are *skipped* with ``pl.when``
  (the init/finish epilogues stay outside the predicate), cutting the
  causal forward to ~half the tiles and the windowed forward to
  O(window/BK) tiles per Q row.  ``count_tiles=True`` adds a scalar
  output with the number of executed tiles for the skip-accounting test;
  :func:`fa_tile_counts` is the analytic oracle (also used by the
  roofline model in ``benchmarks/bench_kernels``).

The backward pass is the FA2 recompute-tile scheme: the forward also
emits per-row LSE statistics (``lse = m + log l``), the launcher
precomputes ``delta = rowsum(dO · O)``, and two kernels recompute
``p = exp(s − lse)`` tile-by-tile:

* **dq**: grid (B, Hq, Sq/BQ, Sk/BK), KV innermost, dq accumulated in
  VMEM scratch across KV steps;
* **dk/dv**: grid (B, Hq, Sk/BK, Sq/BQ), Q innermost, dk/dv accumulated
  in scratch; GQA group reduction (summing query heads onto their shared
  KV head) happens outside the kernel as one XLA reshape-sum.

Both backward kernels reuse the forward's tile-skip predicate, so the
skipped work is symmetric.  Validated against :mod:`repro.kernels.ref`
in ``interpret=True`` mode (kernel body executed step-by-step on CPU);
on real TPUs the same code compiles to Mosaic.
"""

from __future__ import annotations

import functools
from typing import Optional, Tuple

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

__all__ = ["flash_attention_fwd", "flash_attention_bwd", "fa_tile_counts"]

NEG_INF = -1e30
# LSE filler for rows that saw no valid key (and for padded Q rows in the
# backward): exp(s - BIG) == 0 for any finite tile score s.
LSE_EMPTY = 1e30


def _tile_live(qi, ki, *, causal: bool, window: int, bq: int, bk: int,
               seq_k: int):
    """Traced predicate: does tile (qi, ki) contain any unmasked entry?

    Mirrors the in-tile mask exactly: a tile is dead when every (q_pos,
    k_pos) pair fails ``k_pos < seq_k`` / causal / window.  Python-static
    structure (causal/window are compile-time), traced program ids.
    """
    first_q = qi * bq
    last_q = first_q + bq - 1
    first_k = ki * bk
    last_k = first_k + bk - 1
    dead = first_k >= seq_k                       # whole KV tile is padding
    if causal:
        dead |= first_k > last_q                  # strictly above diagonal
    if window > 0:
        dead |= last_k <= first_q - window        # fell out of the window
    return jnp.logical_not(dead)


def fa_tile_counts(Sq: int, Sk: int, bq: int, bk: int, causal: bool,
                   window: int) -> Tuple[int, int]:
    """Analytic (executed, skipped) tile counts per (batch, head) for the
    skip predicate above — the oracle for the unit test and the tile term
    of the roofline FLOP model."""
    nq = -(-Sq // bq)
    nk = -(-Sk // bk)
    executed = 0
    for qi in range(nq):
        for ki in range(nk):
            first_q, last_q = qi * bq, qi * bq + bq - 1
            first_k, last_k = ki * bk, ki * bk + bk - 1
            dead = first_k >= Sk
            if causal:
                dead = dead or first_k > last_q
            if window > 0:
                dead = dead or last_k <= first_q - window
            executed += 0 if dead else 1
    return executed, nq * nk - executed


# --------------------------------------------------------------- forward
def _fa_kernel(q_ref, k_ref, v_ref, o_ref, lse_ref, tiles_ref,
               m_scr, l_scr, acc_scr, *,
               scale: float, causal: bool, window: int, bq: int, bk: int,
               seq_k: int):
    b = pl.program_id(0)
    h = pl.program_id(1)
    qi = pl.program_id(2)
    ki = pl.program_id(3)
    nk = pl.num_programs(3)

    @pl.when(ki == 0)
    def _init():
        m_scr[...] = jnp.full_like(m_scr, NEG_INF)
        l_scr[...] = jnp.zeros_like(l_scr)
        acc_scr[...] = jnp.zeros_like(acc_scr)

    @pl.when((b == 0) & (h == 0) & (qi == 0) & (ki == 0))
    def _zero_counter():
        tiles_ref[0, 0] = 0

    live = _tile_live(qi, ki, causal=causal, window=window, bq=bq, bk=bk,
                      seq_k=seq_k)

    @pl.when(live)
    def _tile():
        q = q_ref[0, 0].astype(jnp.float32)                      # (BQ, hd)
        k = k_ref[0, 0].astype(jnp.float32)                      # (BK, hd)
        v = v_ref[0, 0].astype(jnp.float32)                      # (BK, hd)

        s = jax.lax.dot_general(q, k, (((1,), (1,)), ((), ())))  # (BQ, BK)
        s = s * scale

        q_pos = qi * bq + jax.lax.broadcasted_iota(jnp.int32, (bq, bk), 0)
        k_pos = ki * bk + jax.lax.broadcasted_iota(jnp.int32, (bq, bk), 1)
        mask = k_pos < seq_k
        if causal:
            mask &= k_pos <= q_pos
        if window > 0:
            mask &= k_pos > q_pos - window
        s = jnp.where(mask, s, NEG_INF)

        m_prev = m_scr[...]                                      # (BQ, 1)
        m_cur = jnp.max(s, axis=1, keepdims=True)
        m_new = jnp.maximum(m_prev, m_cur)
        alpha = jnp.exp(m_prev - m_new)
        p = jnp.exp(s - m_new)                                   # (BQ, BK)

        l_new = alpha * l_scr[...] + jnp.sum(p, axis=1, keepdims=True)
        acc_scr[...] = acc_scr[...] * alpha + jax.lax.dot_general(
            p, v, (((1,), (0,)), ((), ())))
        m_scr[...] = m_new
        l_scr[...] = l_new
        tiles_ref[0, 0] += 1

    @pl.when(ki == nk - 1)
    def _finish():
        # rows that saw no valid key (padding) get l = 0 → emit zeros
        l = l_scr[...]
        safe = jnp.where(l == 0.0, 1.0, l)
        o_ref[0, 0] = (acc_scr[...] / safe).astype(o_ref.dtype)
        lse = jnp.where(l == 0.0, LSE_EMPTY, m_scr[...] + jnp.log(safe))
        lse_ref[0, 0] = lse[:, 0]


def flash_attention_fwd(q: jnp.ndarray, k: jnp.ndarray, v: jnp.ndarray, *,
                        causal: bool = True, window: int = 0,
                        block_q: int = 128, block_k: int = 128,
                        return_lse: bool = False, count_tiles: bool = False,
                        interpret: Optional[bool] = None):
    """q: (B, Sq, Hq, hd); k, v: (B, Sk, Hkv, hd) → (B, Sq, Hq, hd).

    Hq must be a multiple of Hkv (GQA).  Sequences are padded to the block
    size internally; padded keys are masked out, padded queries dropped.
    With ``return_lse`` also returns the per-row log-sum-exp statistics,
    shape (B, Hq, Sq) — the FA2 backward residual.  With ``count_tiles``
    additionally returns the number of executed (non-skipped) tiles.
    """
    B, Sq, Hq, hd = q.shape
    _, Sk, Hkv, _ = k.shape
    assert Hq % Hkv == 0, (Hq, Hkv)
    group = Hq // Hkv
    scale = hd ** -0.5

    bq = min(block_q, max(8, Sq))
    bk = min(block_k, max(8, Sk))
    pq = (-Sq) % bq
    pk = (-Sk) % bk
    qp = jnp.pad(q, ((0, 0), (0, pq), (0, 0), (0, 0))) if pq else q
    kp = jnp.pad(k, ((0, 0), (0, pk), (0, 0), (0, 0))) if pk else k
    vp = jnp.pad(v, ((0, 0), (0, pk), (0, 0), (0, 0))) if pk else v
    Sqp, Skp = Sq + pq, Sk + pk

    # layout: (B, H, S, hd) for clean 2D blocks
    qt = qp.transpose(0, 2, 1, 3)
    kt = kp.transpose(0, 2, 1, 3)
    vt = vp.transpose(0, 2, 1, 3)

    grid = (B, Hq, Sqp // bq, Skp // bk)

    if interpret is None:
        interpret = jax.default_backend() == "cpu"

    out, lse, tiles = pl.pallas_call(
        functools.partial(_fa_kernel, scale=scale, causal=causal,
                          window=window, bq=bq, bk=bk, seq_k=Sk),
        grid=grid,
        in_specs=[
            pl.BlockSpec((1, 1, bq, hd), lambda b, h, i, j: (b, h, i, 0)),
            pl.BlockSpec((1, 1, bk, hd), lambda b, h, i, j: (b, h // group, j, 0)),
            pl.BlockSpec((1, 1, bk, hd), lambda b, h, i, j: (b, h // group, j, 0)),
        ],
        out_specs=[
            pl.BlockSpec((1, 1, bq, hd), lambda b, h, i, j: (b, h, i, 0)),
            pl.BlockSpec((1, 1, bq), lambda b, h, i, j: (b, h, i)),
            pl.BlockSpec((1, 1), lambda b, h, i, j: (0, 0)),
        ],
        out_shape=[
            jax.ShapeDtypeStruct((B, Hq, Sqp, hd), q.dtype),
            jax.ShapeDtypeStruct((B, Hq, Sqp), jnp.float32),
            jax.ShapeDtypeStruct((1, 1), jnp.int32),
        ],
        scratch_shapes=[
            pltpu.VMEM((bq, 1), jnp.float32),
            pltpu.VMEM((bq, 1), jnp.float32),
            pltpu.VMEM((bq, hd), jnp.float32),
        ],
        interpret=interpret,
    )(qt, kt, vt)

    out = out.transpose(0, 2, 1, 3)
    if pq:
        out = out[:, :Sq]
        lse = lse[:, :, :Sq]
    res = (out,)
    if return_lse:
        res += (lse,)
    if count_tiles:
        res += (tiles[0, 0],)
    return res if len(res) > 1 else out


# -------------------------------------------------------------- backward
def _fa_bwd_dq_kernel(q_ref, k_ref, v_ref, do_ref, lse_ref, delta_ref,
                      dq_ref, dq_scr, *,
                      scale: float, causal: bool, window: int, bq: int,
                      bk: int, seq_k: int):
    qi = pl.program_id(2)
    ki = pl.program_id(3)
    nk = pl.num_programs(3)

    @pl.when(ki == 0)
    def _init():
        dq_scr[...] = jnp.zeros_like(dq_scr)

    live = _tile_live(qi, ki, causal=causal, window=window, bq=bq, bk=bk,
                      seq_k=seq_k)

    @pl.when(live)
    def _tile():
        q = q_ref[0, 0].astype(jnp.float32)                      # (BQ, hd)
        k = k_ref[0, 0].astype(jnp.float32)                      # (BK, hd)
        v = v_ref[0, 0].astype(jnp.float32)                      # (BK, hd)
        do = do_ref[0, 0].astype(jnp.float32)                    # (BQ, hd)
        lse = lse_ref[0, 0].astype(jnp.float32)[:, None]         # (BQ, 1)
        delta = delta_ref[0, 0].astype(jnp.float32)[:, None]     # (BQ, 1)

        s = jax.lax.dot_general(q, k, (((1,), (1,)), ((), ()))) * scale
        q_pos = qi * bq + jax.lax.broadcasted_iota(jnp.int32, (bq, bk), 0)
        k_pos = ki * bk + jax.lax.broadcasted_iota(jnp.int32, (bq, bk), 1)
        mask = k_pos < seq_k
        if causal:
            mask &= k_pos <= q_pos
        if window > 0:
            mask &= k_pos > q_pos - window
        s = jnp.where(mask, s, NEG_INF)

        p = jnp.exp(s - lse)                                     # (BQ, BK)
        dp = jax.lax.dot_general(do, v, (((1,), (1,)), ((), ())))
        ds = p * (dp - delta) * scale
        dq_scr[...] += jax.lax.dot_general(ds, k,
                                           (((1,), (0,)), ((), ())))

    @pl.when(ki == nk - 1)
    def _finish():
        dq_ref[0, 0] = dq_scr[...].astype(dq_ref.dtype)


def _fa_bwd_dkv_kernel(q_ref, k_ref, v_ref, do_ref, lse_ref, delta_ref,
                       dk_ref, dv_ref, dk_scr, dv_scr, *,
                       scale: float, causal: bool, window: int, bq: int,
                       bk: int, seq_k: int):
    ki = pl.program_id(2)
    qi = pl.program_id(3)
    nq = pl.num_programs(3)

    @pl.when(qi == 0)
    def _init():
        dk_scr[...] = jnp.zeros_like(dk_scr)
        dv_scr[...] = jnp.zeros_like(dv_scr)

    live = _tile_live(qi, ki, causal=causal, window=window, bq=bq, bk=bk,
                      seq_k=seq_k)

    @pl.when(live)
    def _tile():
        q = q_ref[0, 0].astype(jnp.float32)                      # (BQ, hd)
        k = k_ref[0, 0].astype(jnp.float32)                      # (BK, hd)
        v = v_ref[0, 0].astype(jnp.float32)                      # (BK, hd)
        do = do_ref[0, 0].astype(jnp.float32)                    # (BQ, hd)
        lse = lse_ref[0, 0].astype(jnp.float32)[:, None]         # (BQ, 1)
        delta = delta_ref[0, 0].astype(jnp.float32)[:, None]     # (BQ, 1)

        s = jax.lax.dot_general(q, k, (((1,), (1,)), ((), ()))) * scale
        q_pos = qi * bq + jax.lax.broadcasted_iota(jnp.int32, (bq, bk), 0)
        k_pos = ki * bk + jax.lax.broadcasted_iota(jnp.int32, (bq, bk), 1)
        mask = k_pos < seq_k
        if causal:
            mask &= k_pos <= q_pos
        if window > 0:
            mask &= k_pos > q_pos - window
        s = jnp.where(mask, s, NEG_INF)

        # padded Q rows carry lse = LSE_EMPTY → p == 0: no contribution
        p = jnp.exp(s - lse)                                     # (BQ, BK)
        dv_scr[...] += jax.lax.dot_general(p, do,
                                           (((0,), (0,)), ((), ())))
        dp = jax.lax.dot_general(do, v, (((1,), (1,)), ((), ())))
        ds = p * (dp - delta) * scale
        dk_scr[...] += jax.lax.dot_general(ds, q,
                                           (((0,), (0,)), ((), ())))

    @pl.when(qi == nq - 1)
    def _finish():
        dk_ref[0, 0] = dk_scr[...].astype(dk_ref.dtype)
        dv_ref[0, 0] = dv_scr[...].astype(dv_ref.dtype)


def flash_attention_bwd(q: jnp.ndarray, k: jnp.ndarray, v: jnp.ndarray,
                        out: jnp.ndarray, lse: jnp.ndarray,
                        do: jnp.ndarray, *,
                        causal: bool = True, window: int = 0,
                        block_q: int = 128, block_k: int = 128,
                        interpret: Optional[bool] = None
                        ) -> Tuple[jnp.ndarray, jnp.ndarray, jnp.ndarray]:
    """FA2 recompute-tile backward.  Residuals: ``out`` (B, Sq, Hq, hd)
    and ``lse`` (B, Hq, Sq) from the forward.  Returns (dq, dk, dv) in
    the input layouts/dtypes."""
    B, Sq, Hq, hd = q.shape
    _, Sk, Hkv, _ = k.shape
    assert Hq % Hkv == 0, (Hq, Hkv)
    group = Hq // Hkv
    scale = hd ** -0.5

    # delta_i = rowsum(dO_i · O_i) — cheap elementwise+reduce, precomputed
    # in XLA exactly like FA2 does in its preamble kernel
    delta = jnp.sum(do.astype(jnp.float32) * out.astype(jnp.float32),
                    axis=-1)                                     # (B, Sq, Hq)

    bq = min(block_q, max(8, Sq))
    bk = min(block_k, max(8, Sk))
    pq = (-Sq) % bq
    pk = (-Sk) % bk
    qp = jnp.pad(q, ((0, 0), (0, pq), (0, 0), (0, 0))) if pq else q
    dop = jnp.pad(do, ((0, 0), (0, pq), (0, 0), (0, 0))) if pq else do
    kp = jnp.pad(k, ((0, 0), (0, pk), (0, 0), (0, 0))) if pk else k
    vp = jnp.pad(v, ((0, 0), (0, pk), (0, 0), (0, 0))) if pk else v
    # padded Q rows: lse = LSE_EMPTY kills p; delta = 0 for symmetry
    lsep = jnp.pad(lse, ((0, 0), (0, 0), (0, pq)),
                   constant_values=LSE_EMPTY) if pq else lse
    deltap = jnp.pad(delta, ((0, 0), (0, pq), (0, 0))) if pq else delta
    Sqp, Skp = Sq + pq, Sk + pk

    qt = qp.transpose(0, 2, 1, 3)                                # (B,Hq,Sqp,hd)
    dot = dop.transpose(0, 2, 1, 3)
    kt = kp.transpose(0, 2, 1, 3)                                # (B,Hkv,Skp,hd)
    vt = vp.transpose(0, 2, 1, 3)
    deltat = deltap.transpose(0, 2, 1)                           # (B,Hq,Sqp)

    if interpret is None:
        interpret = jax.default_backend() == "cpu"

    q_spec = pl.BlockSpec((1, 1, bq, hd), lambda b, h, i, j: (b, h, i, 0))
    kv_spec_q = pl.BlockSpec((1, 1, bk, hd),
                             lambda b, h, i, j: (b, h // group, j, 0))
    row_spec = pl.BlockSpec((1, 1, bq), lambda b, h, i, j: (b, h, i))

    dq = pl.pallas_call(
        functools.partial(_fa_bwd_dq_kernel, scale=scale, causal=causal,
                          window=window, bq=bq, bk=bk, seq_k=Sk),
        grid=(B, Hq, Sqp // bq, Skp // bk),
        in_specs=[q_spec, kv_spec_q, kv_spec_q, q_spec, row_spec, row_spec],
        out_specs=q_spec,
        out_shape=jax.ShapeDtypeStruct((B, Hq, Sqp, hd), q.dtype),
        scratch_shapes=[pltpu.VMEM((bq, hd), jnp.float32)],
        interpret=interpret,
    )(qt, kt, vt, dot, lsep, deltat)

    # dk/dv: grid transposed (KV outer, Q innermost sequential); outputs
    # are per *query* head — the GQA group reduction onto the shared KV
    # head is one XLA reshape-sum below.
    q_spec_t = pl.BlockSpec((1, 1, bq, hd), lambda b, h, j, i: (b, h, i, 0))
    kv_spec_t = pl.BlockSpec((1, 1, bk, hd),
                             lambda b, h, j, i: (b, h // group, j, 0))
    kv_out_t = pl.BlockSpec((1, 1, bk, hd), lambda b, h, j, i: (b, h, j, 0))
    row_spec_t = pl.BlockSpec((1, 1, bq), lambda b, h, j, i: (b, h, i))

    dk_h, dv_h = pl.pallas_call(
        functools.partial(_fa_bwd_dkv_kernel, scale=scale, causal=causal,
                          window=window, bq=bq, bk=bk, seq_k=Sk),
        grid=(B, Hq, Skp // bk, Sqp // bq),
        in_specs=[q_spec_t, kv_spec_t, kv_spec_t, q_spec_t, row_spec_t,
                  row_spec_t],
        out_specs=[kv_out_t, kv_out_t],
        out_shape=[jax.ShapeDtypeStruct((B, Hq, Skp, hd), k.dtype),
                   jax.ShapeDtypeStruct((B, Hq, Skp, hd), v.dtype)],
        scratch_shapes=[pltpu.VMEM((bk, hd), jnp.float32),
                        pltpu.VMEM((bk, hd), jnp.float32)],
        interpret=interpret,
    )(qt, kt, vt, dot, lsep, deltat)

    dq = dq.transpose(0, 2, 1, 3)
    if pq:
        dq = dq[:, :Sq]
    dk = dk_h.reshape(B, Hkv, group, Skp, hd).sum(axis=2).astype(k.dtype)
    dv = dv_h.reshape(B, Hkv, group, Skp, hd).sum(axis=2).astype(v.dtype)
    dk = dk.transpose(0, 2, 1, 3)
    dv = dv.transpose(0, 2, 1, 3)
    if pk:
        dk = dk[:, :Sk]
        dv = dv[:, :Sk]
    return dq, dk, dv
