"""Pallas TPU kernels for the substrate's compute hot spots.

Hippo itself is an execution-layer contribution (no kernel of its own);
these kernels cover the two hot spots of the assigned-architecture
substrate: flash attention (dense/GQA families) and the SSD intra-chunk
term (Mamba2).  Layout: ``<name>.py`` (pl.pallas_call + BlockSpec),
``ops.py`` (jit'd wrappers + custom VJP), ``ref.py`` (pure-jnp oracles).
"""
