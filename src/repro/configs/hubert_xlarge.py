"""hubert-xlarge — encoder-only audio transformer [arXiv:2106.07447].

48 layers, d_model 1280, 16 heads (MHA kv=16, head_dim 80), d_ff 5120,
vocab 504 (framewise cluster targets).  The conv waveform feature extractor
is a stub per the carve-out: ``input_specs`` supplies frame embeddings
(dim 512).  Encoder-only → no decode shapes (DESIGN §4).
"""
from repro.models.config import ModelConfig

CONFIG = ModelConfig(
    name="hubert-xlarge", arch_type="audio",
    num_layers=48, d_model=1280, vocab_size=504,
    num_heads=16, num_kv_heads=16, head_dim=80,
    d_ff=5120, causal=False,
    frontend="audio", frontend_dim=512,
    norm_eps=1e-5,
)
