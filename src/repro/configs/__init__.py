"""Assigned-architecture registry: ``--arch <id>`` → ModelConfig.

Ten architectures spanning six families (see each module's citation), plus
the four assignment input shapes.  ``long_500k`` policy per DESIGN.md §4:
sub-quadratic archs run it natively; dense/VLM archs run a sliding-window
variant (window 8192); encoder-only (hubert) has no decode at all.
"""

from __future__ import annotations

import dataclasses
from typing import Dict, List

from repro.models.config import ModelConfig

from repro.configs import (granite_34b, grok_1_314b, hubert_xlarge,
                           mamba2_2p7b, qwen2_0p5b, qwen2_moe_a2p7b,
                           qwen2_vl_7b, qwen3_8b, recurrentgemma_2b, yi_34b)

__all__ = ["ARCHS", "SHAPES", "get_config", "list_archs", "shape_applicable",
           "config_for_shape", "InputShape"]

ARCHS: Dict[str, ModelConfig] = {
    c.name: c for c in [
        mamba2_2p7b.CONFIG, yi_34b.CONFIG, recurrentgemma_2b.CONFIG,
        qwen2_vl_7b.CONFIG, grok_1_314b.CONFIG, hubert_xlarge.CONFIG,
        qwen2_0p5b.CONFIG, qwen2_moe_a2p7b.CONFIG, qwen3_8b.CONFIG,
        granite_34b.CONFIG,
    ]
}


@dataclasses.dataclass(frozen=True)
class InputShape:
    name: str
    seq_len: int
    global_batch: int
    kind: str            # "train" | "prefill" | "decode"


SHAPES: Dict[str, InputShape] = {
    "train_4k":    InputShape("train_4k", 4_096, 256, "train"),
    "prefill_32k": InputShape("prefill_32k", 32_768, 32, "prefill"),
    "decode_32k":  InputShape("decode_32k", 32_768, 128, "decode"),
    "long_500k":   InputShape("long_500k", 524_288, 1, "decode"),
}

_SLIDING_WINDOW_500K = 8_192


def get_config(arch: str) -> ModelConfig:
    if arch not in ARCHS:
        raise KeyError(f"unknown arch {arch!r}; choose from {sorted(ARCHS)}")
    return ARCHS[arch]


def list_archs() -> List[str]:
    return sorted(ARCHS)


def shape_applicable(cfg: ModelConfig, shape: InputShape) -> bool:
    """Assignment rules: encoder-only archs skip decode; long_500k runs
    only sub-quadratically (natively or via the sliding-window variant)."""
    if shape.kind == "decode" and cfg.is_encoder_only:
        return False
    return True


def config_for_shape(cfg: ModelConfig, shape: InputShape) -> ModelConfig:
    """The config actually lowered for a shape — applies the sliding-window
    variant that makes ``long_500k`` legitimate for full-attention archs."""
    if (shape.name == "long_500k" and not cfg.subquadratic):
        return dataclasses.replace(cfg, sliding_window=_SLIDING_WINDOW_500K)
    return cfg
