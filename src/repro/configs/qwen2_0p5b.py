"""qwen2-0.5b — small dense GQA with QKV bias [arXiv:2407.10671].

24 layers, d_model 896, 14 heads / 2 KV (head_dim 64), d_ff 4864,
vocab 151936, tied embeddings.  Drives the ~100M-scale end-to-end example.
"""
from repro.models.config import ModelConfig

CONFIG = ModelConfig(
    name="qwen2-0.5b", arch_type="dense",
    num_layers=24, d_model=896, vocab_size=151936,
    num_heads=14, num_kv_heads=2, head_dim=64,
    d_ff=4864, qkv_bias=True, rope_theta=1e6,
    tie_embeddings=True,
    norm_eps=1e-6,
)
