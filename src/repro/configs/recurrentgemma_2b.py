"""recurrentgemma-2b — Griffin hybrid: RG-LRU + local attention, 1:2
[arXiv:2402.19427].

26 layers, d_model 2560, 10 heads (MQA kv=1, head_dim 256), d_ff 7680,
vocab 256000.  Pattern (rglru, rglru, local) × 8 + 2 trailing rglru;
local window 2048.  ``long_500k`` is native (O(1) recurrent state +
window-bounded local KV).
"""
from repro.models.config import ModelConfig

CONFIG = ModelConfig(
    name="recurrentgemma-2b", arch_type="hybrid",
    num_layers=26, d_model=2560, vocab_size=256000,
    num_heads=10, num_kv_heads=1, head_dim=256,
    d_ff=7680,
    layer_pattern=("rglru", "rglru", "local"),
    local_window=2048, rglru_width=2560, ssm_conv=4,
    tie_embeddings=True,
    norm_eps=1e-6,
)
