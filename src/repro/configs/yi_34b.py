"""yi-34b — llama-architecture dense with GQA [arXiv:2403.04652].

60 layers, d_model 7168, 56 heads / 8 KV heads (head_dim 128), d_ff 20480,
vocab 64000.  ``long_500k`` runs via the sliding-window variant (DESIGN §4).
"""
from repro.models.config import ModelConfig

CONFIG = ModelConfig(
    name="yi-34b", arch_type="dense",
    num_layers=60, d_model=7168, vocab_size=64000,
    num_heads=56, num_kv_heads=8, head_dim=128,
    d_ff=20480, rope_theta=5e6,
    norm_eps=1e-5,
)
