"""qwen2-vl-7b — VLM decoder with M-RoPE + dynamic resolution
[arXiv:2409.12191].

28 layers, d_model 3584, 28 heads / 4 KV (head_dim 128), d_ff 18944,
vocab 152064, QKV bias, M-RoPE sections (16, 24, 24).  The ViT/projector
frontend is a stub per the assignment carve-out: ``input_specs`` supplies
1024 precomputed patch embeddings (dim 1280) per sample.
"""
from repro.models.config import ModelConfig

CONFIG = ModelConfig(
    name="qwen2-vl-7b", arch_type="vlm",
    num_layers=28, d_model=3584, vocab_size=152064,
    num_heads=28, num_kv_heads=4, head_dim=128,
    d_ff=18944, qkv_bias=True,
    mrope_sections=(16, 24, 24), rope_theta=1e6,
    frontend="vision", frontend_dim=1280, frontend_tokens=1024,
    norm_eps=1e-6,
)
