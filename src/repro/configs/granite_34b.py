"""granite-34b — llama-architecture code model, deep-narrow MQA
[arXiv:2405.04324].

88 layers, d_model 6144, 48 heads / 1 KV (MQA, head_dim 128), d_ff 24576,
vocab 49152 (2-matrix GPTBigCode MLP).  Deepest assigned arch — the layer-scan keeps its HLO small.
"""
from repro.models.config import ModelConfig

CONFIG = ModelConfig(
    name="granite-34b", arch_type="dense",
    num_layers=88, d_model=6144, vocab_size=49152,
    num_heads=48, num_kv_heads=1, head_dim=128,
    d_ff=24576, mlp_gated=False,
    norm_eps=1e-5,
)
