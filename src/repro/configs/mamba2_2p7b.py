"""mamba2-2.7b — SSD (state-space duality), attention-free [arXiv:2405.21060].

64 layers, d_model 2560, vocab 50280, ssm_state 128; expand 2 → inner 5120,
head_dim 64 → 80 SSD heads.  No FFN (the Mamba2 block is the whole layer).
"""
from repro.models.config import ModelConfig

CONFIG = ModelConfig(
    name="mamba2-2.7b", arch_type="ssm",
    num_layers=64, d_model=2560, vocab_size=50280,
    d_ff=0, num_heads=0, num_kv_heads=0,
    ssm_state=128, ssm_heads=80, ssm_head_dim=64, ssm_expand=2,
    ssm_chunk=128, ssm_conv=4,
    layer_pattern=("ssm",),
    tie_embeddings=True,
    norm_eps=1e-5,
)
