"""grok-1-314b — 8-expert top-2 MoE [hf:xai-org/grok-1].

64 layers, d_model 6144, 48 heads / 8 KV (head_dim 128), expert d_ff 32768,
vocab 131072.  The largest dry-run case: ~314B parameters, fits 512 chips
only with expert-parallel + FSDP sharding.
"""
from repro.models.config import ModelConfig

CONFIG = ModelConfig(
    name="grok-1-314b", arch_type="moe",
    num_layers=64, d_model=6144, vocab_size=131072,
    num_heads=48, num_kv_heads=8, head_dim=128,
    n_experts=8, top_k=2, moe_d_ff=32768,
    capacity_factor=1.25,
    norm_eps=1e-5,
)
