"""qwen2-moe-a2.7b — 60 routed experts top-4 + 4 shared experts
[hf:Qwen/Qwen1.5-MoE-A2.7B].

24 layers, d_model 2048, 16 heads (MHA kv=16, head_dim 128), routed expert
d_ff 1408, shared-expert hidden 4×1408 = 5632, vocab 151936.
"""
from repro.models.config import ModelConfig

CONFIG = ModelConfig(
    name="qwen2-moe-a2.7b", arch_type="moe",
    num_layers=24, d_model=2048, vocab_size=151936,
    num_heads=16, num_kv_heads=16, head_dim=128,
    n_experts=60, top_k=4, moe_d_ff=1408,
    n_shared_experts=4, shared_d_ff=5632,
    qkv_bias=True, capacity_factor=1.25,
    norm_eps=1e-6,
)
