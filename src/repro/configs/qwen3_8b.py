"""qwen3-8b — dense GQA with per-head qk-norm [hf:Qwen/Qwen3-8B].

36 layers, d_model 4096, 32 heads / 8 KV (head_dim 128), d_ff 12288,
vocab 151936.
"""
from repro.models.config import ModelConfig

CONFIG = ModelConfig(
    name="qwen3-8b", arch_type="dense",
    num_layers=36, d_model=4096, vocab_size=151936,
    num_heads=32, num_kv_heads=8, head_dim=128,
    d_ff=12288, qk_norm=True, rope_theta=1e6,
    norm_eps=1e-6,
)
