"""Hyper-parameter-sequence-aware optimizers (SGD/momentum, Adam, AdamW).

Hippo's whole premise is that training knobs are *functions of the step*,
so every knob here (lr, momentum, weight decay) enters the update as a
**traced scalar argument** rather than a compile-time constant: one
compiled train step serves every stage of every trial regardless of its
hyper-parameter values — only *shape* changes (batch size) recompile.

The optimizer choice itself is a static hyper-parameter (paper Table 2
tunes {Adam, vanilla SGD, SGD+momentum}); switching optimizers mid-trial
would change the state pytree and is not part of the paper's search spaces.
"""

from __future__ import annotations

from typing import Any, Dict, Tuple

import jax
import jax.numpy as jnp

__all__ = ["init_opt_state", "apply_update", "OPTIMIZERS"]

OPTIMIZERS = ("sgd", "momentum", "adam", "adamw")


def init_opt_state(name: str, params: Any) -> Dict[str, Any]:
    zeros = lambda: jax.tree.map(jnp.zeros_like, params)
    if name == "sgd":
        return {}
    if name == "momentum":
        return {"m": zeros()}
    if name in ("adam", "adamw"):
        return {"m": zeros(), "v": zeros()}
    raise ValueError(f"unknown optimizer {name!r}; choose from {OPTIMIZERS}")


def apply_update(name: str, params: Any, grads: Any, state: Dict[str, Any],
                 hp: Dict[str, jnp.ndarray], step: jnp.ndarray
                 ) -> Tuple[Any, Dict[str, Any]]:
    """One optimizer update.  ``hp`` supplies traced scalars:
    lr (required), momentum (default .9), wd (default 0), b1/b2/eps."""
    lr = hp["lr"]
    wd = hp.get("wd", 0.0)

    if name == "sgd":
        new = jax.tree.map(
            lambda p, g: (p - lr * (g + wd * p)).astype(p.dtype), params, grads)
        return new, state

    if name == "momentum":
        mom = hp.get("momentum", 0.9)
        m = jax.tree.map(lambda m_, g: mom * m_ + g, state["m"], grads)
        new = jax.tree.map(
            lambda p, m_: (p - lr * (m_ + wd * p)).astype(p.dtype), params, m)
        return new, {"m": m}

    if name in ("adam", "adamw"):
        b1 = hp.get("b1", 0.9)
        b2 = hp.get("b2", 0.999)
        eps = hp.get("eps", 1e-8)
        t = step.astype(jnp.float32) + 1.0
        m = jax.tree.map(lambda m_, g: b1 * m_ + (1 - b1) * g,
                         state["m"], grads)
        v = jax.tree.map(lambda v_, g: b2 * v_ + (1 - b2) * g * g,
                         state["v"], grads)
        mh = jax.tree.map(lambda m_: m_ / (1 - b1 ** t), m)
        vh = jax.tree.map(lambda v_: v_ / (1 - b2 ** t), v)
        if name == "adamw":
            new = jax.tree.map(
                lambda p, m_, v_: (p - lr * (m_ / (jnp.sqrt(v_) + eps)
                                             + wd * p)).astype(p.dtype),
                params, mh, vh)
        else:  # adam: wd folded into the gradient (L2), paper-era behaviour
            new = jax.tree.map(
                lambda p, m_, v_: (p - lr * m_ / (jnp.sqrt(v_) + eps)
                                   - lr * wd * p).astype(p.dtype),
                params, mh, vh)
        return new, {"m": m, "v": v}

    raise ValueError(name)
