"""Real-training backend: Hippo stages driving a JAX model (the §5.2
``Trainer`` counterpart).

``JaxTrainer`` executes a stage by stepping the jitted update once per
training step, feeding each step its hyper-parameter values from the
stage's descriptor (the ``setup(hp)`` hot-update of Figure 9 becomes
"hp values are traced scalar inputs of the compiled step").  Everything a
resumed trial needs is in the state pytree:

    {"params", "opt", "data" (pipeline position), "step"}

so stage-based execution is *lossless*: training a prefix once and forking
the checkpoint yields bit-identical parameters to training each trial
straight through (asserted by ``tests/test_lossless.py``).

Batch-size sequences change the batch *shape* → new jit cache entry; the
compiled-executable cache makes revisiting a size free (DESIGN.md §3(b)).
"""

from __future__ import annotations

from typing import Any, Callable, Dict, Optional, Tuple

import jax
import jax.numpy as jnp
import numpy as np

from repro.core.trainer import StageContext, TrainerBackend
from repro.core.values import desc_static, desc_values
from repro.data.pipeline import DataPipeline
from repro.train.optimizer import apply_update, init_opt_state

__all__ = ["JaxTrainer"]


class JaxTrainer(TrainerBackend):
    """Stage executor over any task exposing ``init(rng)`` and
    ``loss(params, batch) -> (scalar, metrics)``."""

    def __init__(self, task, pipeline_factory: Callable[[], DataPipeline],
                 eval_batch: Dict[str, np.ndarray],
                 default_optimizer: str = "momentum", seed: int = 0,
                 objective_from: str = "acc"):
        self.task = task
        self.pipeline_factory = pipeline_factory
        self.eval_batch = {k: jnp.asarray(v) for k, v in eval_batch.items()}
        self.default_optimizer = default_optimizer
        self.seed = seed
        self.objective_from = objective_from
        self._step_fns: Dict[Tuple, Any] = {}
        self._eval_fn = jax.jit(self.task.loss)

    # ------------------------------------------------------------------ state
    def init_state(self) -> Dict[str, Any]:
        params = self.task.init(jax.random.PRNGKey(self.seed))
        pipe = self.pipeline_factory()
        return {
            "params": params,
            "opt": None,               # lazy: optimizer choice is a static hp
            "opt_name": None,
            "data": pipe.state(),
            "step": 0,
        }

    # ------------------------------------------------------------- step fn
    def _jitted_step(self, opt_name: str):
        key = ("step", opt_name)
        if key not in self._step_fns:
            def step_fn(params, opt, batch, hp, step):
                (loss, _), grads = jax.value_and_grad(
                    self.task.loss, has_aux=True)(params, batch)
                params, opt = apply_update(opt_name, params, grads, opt,
                                           hp, step)
                return params, opt, loss
            self._step_fns[key] = jax.jit(step_fn)
        return self._step_fns[key]

    # -------------------------------------------------------------- execute
    def run_stage(self, state: Dict[str, Any], ctx: StageContext
                  ) -> Dict[str, Any]:
        assert state["step"] == ctx.start, (state["step"], ctx.start)
        vals = desc_values(ctx.desc, ctx.node_start, ctx.start, ctx.stop)
        static = desc_static(ctx.desc)
        opt_name = static.get("optimizer", self.default_optimizer)

        params = state["params"]
        opt = state["opt"]
        if opt is None or state["opt_name"] != opt_name:
            opt = init_opt_state(opt_name, params)

        pipe = self.pipeline_factory()
        pipe.restore(state["data"])

        static_hp = {k: float(v) for k, v in static.items()
                     if isinstance(v, (int, float)) and not k.startswith("_")}
        step_fn = self._jitted_step(opt_name)

        names = [k for k in vals if k != "bs"]
        for i, step in enumerate(range(ctx.start, ctx.stop)):
            if "bs" in vals:
                pipe.set_batch_size(int(round(vals["bs"][i])))
            batch = pipe.next_batch()
            batch = {k: jnp.asarray(v) for k, v in batch.items()}
            hp = dict(static_hp)
            hp.update({k: vals[k][i] for k in names})
            params, opt, _ = step_fn(params, opt, batch, hp,
                                     jnp.int32(step))

        return {"params": params, "opt": opt, "opt_name": opt_name,
                "data": pipe.state(), "step": ctx.stop}

    # ------------------------------------------------------------- evaluate
    def evaluate(self, state: Dict[str, Any], ctx: StageContext
                 ) -> Dict[str, float]:
        loss, metrics = self._eval_fn(state["params"], self.eval_batch)
        out = {"loss": float(loss)}
        out["val_acc"] = float(metrics.get(self.objective_from, -loss))
        for k, v in metrics.items():
            out[k] = float(v)
        return out

    def stage_seconds(self, ctx: StageContext) -> Optional[float]:
        return None  # wall-clock measured by the engine
