"""Real-training backend: Hippo stages driving a JAX model (the §5.2
``Trainer`` counterpart), with a fused data plane.

``JaxTrainer`` executes a whole stage as a handful of *chunk executables*:
each chunk is one compiled XLA call covering up to ``chunk_steps`` training
steps, consuming a prefetched data slab (``DataPipeline.next_batches``) and
stacked per-step hyper-parameter arrays (the ``setup(hp)`` hot-update of
Figure 9 becomes "hp values are traced inputs of the compiled chunk").
Compiled executables are cached on ``(opt_name, chunk_len, batch_shape,
hp structure)``; stage lengths are split into descending power-of-two
chunks so any length reuses O(log chunk_steps) executables.  Cache misses
compile ahead-of-time (``jit(...).lower().compile()``) with the time
recorded in ``compile_seconds``, which the dispatcher subtracts from its
wall-clock stage measurement — one-time compilation never distorts
seconds/step profiles (critical-path priorities) or the virtual clock.

The chunk body is backend-gated:

* **CPU** — a *statically unrolled* scan, semantically
  ``lax.scan(step, carry, (hp, slab, steps), unroll=chunk_len)`` with
  static slab indexing.  We deliberately avoid ``lax.scan`` itself here:
  its dynamic slicing of the data slab changes XLA:CPU's
  convolution-gradient codegen by 1-2 ulps, which would break the
  bit-exactness contract below.
* **GPU/TPU** — a real ``lax.scan`` over the slab (small HLO, fast
  compiles, better vectorization), with ``vectorize_groups`` defaulting on
  so sibling groups run under ``jax.vmap``, and the carry ``(params,
  opt)`` donated end-to-end between chunks.  Bit-exactness vs the CPU
  reference relaxes to ~1-2 ulps on these backends.

The gate keys on ``jax.default_backend()``; tests inject ``backend=`` (and
``donate=False``, since XLA:CPU cannot honor donation) to structure-test
the accelerator path on the CPU container.

Chain fusion: :meth:`run_chain` executes an entire scheduler-extracted
chain with the ``(params, opt)`` carry and the data pipeline held live
across every stage boundary — no checkpoint round-trip, no slab
re-prefetch, no restack between consecutive stages — while still
returning a boundary snapshot per stage for the dispatcher's write-behind
checkpointing.  :meth:`run_chains_batched` is the batched flavour: a group
of parallel sibling chains advances one stage level per compiled call over
a member-stacked carry that itself persists across boundaries.

Sibling-trial batching: :meth:`run_stages_batched` executes a whole group
of sibling stages — same ``[start, stop)``, same static hps and batch-size
schedule, divergent hp *values* — as ONE compiled call over member-stacked
carries, hp arrays and data slabs.  ``vectorize_groups`` follows the same
backend gate: off on CPU (members unroll statically, bit-exact per
member), on for accelerator backends (``jax.vmap`` over the member axis —
better vectorization, bit-exactness relaxed to ~1 ulp); pass it explicitly
to override the gate.

Everything a resumed trial needs is in the state pytree:

    {"params", "opt", "data" (pipeline position), "step"}

so stage-based execution is *lossless*: training a prefix once and forking
the checkpoint yields bit-identical parameters to training each trial
straight through, and the fused / batched paths are bit-identical to the
seed per-step loop (kept as :meth:`run_stage_stepwise`) — all asserted by
``tests/test_lossless.py``.

Batch-size sequences change the batch *shape* → new executable cache entry;
revisiting a size is free.

Kernel plane: ``use_kernel`` routes the hot math through the Pallas
kernels — the task's attention/SSD forward+backward
(:mod:`repro.kernels.ops`, set via the task's ``use_kernel`` attribute
when it has one) and the fused trial-stacked optimizer update
(:func:`repro.kernels.optim.fused_apply_update`) in every chunk body.
The default follows the backend gate: on for TPU (Mosaic codegen), off
otherwise; pass ``use_kernel=True`` explicitly to exercise the kernels
in interpret mode on CPU (correct but interpreter-slow — tests only).
All four execution paths (``run_stage``, ``run_stages_batched``,
``run_chain``, ``run_chains_batched``) share the same chunk bodies, so
they are uniformly kernel-aware; on the vmapped sibling-group path the
kernels' batching rules fold the member axis into the kernel grid (one
launch per group).  ``kernel_calls`` / ``kernel_fallbacks`` expose the
kernel plane's trace-time counters (cumulative since this trainer's
construction) for ``EngineStats``.

Mesh workers (distribution plane v2): :meth:`set_mesh` binds the trainer
to the dispatching worker's :class:`~repro.dist.meshes.WorkerMesh` before
each work unit.  A ``None`` or 1-device mesh is the default path —
bit-identical to thread-worker execution.  On a wider mesh the fused
carry lives **sharded at rest**: ``(params, opt)`` is placed with
:func:`repro.dist.sharding.generic_param_specs` (largest dividing dim →
``fsdp`` axis, largest remaining → ``tp``; PR 3's divisibility gate);
every chunk executable is wrapped to all-gather the carry to replicated
before the arithmetic, and the output re-scatters to the at-rest
placement *between* executables (``device_put``) — sharding is pure data
movement, so on CPU the sharded path stays bit-identical to the
unsharded one while the carry demonstrably lives distributed between
chunks.  Sibling groups stack members on a leading axis that is never
sharded (``n_lead=1``), so trial-batching (vmap) and sharding compose as
two orthogonal parallelism axes.  Boundary snapshots are gathered to one
device before they leave the trainer — checkpoints and eval stay
unsharded.  The live mesh key joins every executable cache key.
"""

from __future__ import annotations

import time
from typing import Any, Callable, Dict, List, Optional, Sequence, Tuple

import jax
import jax.numpy as jnp
import numpy as np
from jax.sharding import NamedSharding, PartitionSpec as P

from repro.core.trainer import StageContext, TrainerBackend
from repro.core.values import desc_static, desc_values
from repro.data.pipeline import DataPipeline
from repro.dist.sharding import generic_param_specs
from repro.kernels import ops as kernel_ops
from repro.kernels.optim import fused_apply_update
from repro.train.checkpoint import stack_pytrees, unstack_pytree
from repro.train.optimizer import apply_update, init_opt_state

__all__ = ["JaxTrainer", "chunk_lengths"]


def chunk_lengths(n: int, max_chunk: int) -> List[int]:
    """Split ``n`` steps into descending power-of-two chunk lengths capped at
    ``max_chunk``, so every stage length reuses O(log max_chunk) compiled
    executables instead of compiling one per distinct length."""
    if max_chunk < 1:
        raise ValueError(f"max_chunk must be >= 1, got {max_chunk}")
    out: List[int] = []
    while n > 0:
        c = min(max_chunk, 1 << (n.bit_length() - 1))
        out.append(c)
        n -= c
    return out


class JaxTrainer(TrainerBackend):
    """Stage executor over any task exposing ``init(rng)`` and
    ``loss(params, batch) -> (scalar, metrics)``."""

    def __init__(self, task, pipeline_factory: Callable[[], DataPipeline],
                 eval_batch: Dict[str, np.ndarray],
                 default_optimizer: str = "momentum", seed: int = 0,
                 objective_from: str = "acc", fused: bool = True,
                 chunk_steps: int = 8,
                 vectorize_groups: Optional[bool] = None,
                 backend: Optional[str] = None,
                 donate: Optional[bool] = None,
                 use_kernel: Optional[bool] = None):
        self.task = task
        self.pipeline_factory = pipeline_factory
        self.eval_batch = {k: jnp.asarray(v) for k, v in eval_batch.items()}
        self.default_optimizer = default_optimizer
        self.seed = seed
        self.objective_from = objective_from
        self.fused = fused
        self.chunk_steps = int(chunk_steps)
        if self.chunk_steps < 1:
            raise ValueError(f"chunk_steps must be >= 1, got {chunk_steps}")
        # backend gate (see module docstring).  ``backend`` is injectable so
        # the accelerator path is structure-testable on the CPU container.
        self.backend = backend or jax.default_backend()
        accel = self.backend != "cpu"
        self.use_scan = accel                   # lax.scan chunk bodies
        self.vectorize_groups = accel if vectorize_groups is None \
            else vectorize_groups
        # kernel plane (see module docstring): TPU-on by default, explicit
        # True runs interpret-mode kernels on CPU (tests), False = oracle
        self.use_kernel = (self.backend == "tpu") if use_kernel is None \
            else bool(use_kernel)
        if self.use_kernel and hasattr(task, "use_kernel"):
            task.use_kernel = True
        self._kernel_stats0 = kernel_ops.KERNEL_STATS.snapshot()
        self._step_fns: Dict[Tuple, Any] = {}   # stepwise per-step executables
        self._chunk_fns: Dict[Tuple, Any] = {}  # fused / batched executables
        # buffer donation frees the carry between chunks; XLA:CPU does not
        # implement it (and warns per call), so gate on the backend
        self._donate = accel if donate is None else donate
        self._eval_fn = jax.jit(self.task.loss)
        # Cumulative seconds spent AOT-compiling chunk executables.  The
        # dispatcher subtracts the per-stage delta from its measured wall so
        # one-time compilation never pollutes seconds/step profiles or the
        # virtual clock (a deployment amortizes compiles across the study).
        self.compile_seconds = 0.0
        self.exec_calls = 0       # compiled-executable dispatches issued
        # -------- mesh plane (distribution plane v2; see module docstring)
        self._wmesh = None                      # live WorkerMesh (>1 device)
        self._mesh = None                       # its jax.sharding.Mesh
        self._mesh_key: Optional[Tuple] = None  # joins executable cache keys
        self._meshes: Dict[Tuple, Any] = {}     # WorkerMesh.key -> jax Mesh
        self._mesh_ok: Dict[Tuple, bool] = {}   # mesh_compatible verdicts

    # ------------------------------------------------- kernel-plane counters
    @property
    def kernel_calls(self) -> int:
        """Kernel-plane call sites traced since construction (counters move
        at trace time: constant per compilation, not per step)."""
        return kernel_ops.KERNEL_STATS.calls - self._kernel_stats0[0]

    @property
    def kernel_fallbacks(self) -> int:
        """Kernel→oracle fallbacks traced since construction."""
        return kernel_ops.KERNEL_STATS.fallbacks - self._kernel_stats0[1]

    # ------------------------------------------------------------ mesh plane
    def set_mesh(self, mesh) -> None:
        """Bind to the dispatching worker's mesh (None = thread worker).

        1-device meshes take the default path — no sharding, no new cache
        entries — so a 1-device-mesh fleet is bit- and stats-identical to
        a thread fleet.  Wider meshes build (and cache) the live
        ``jax.sharding.Mesh`` once per distinct ``WorkerMesh.key``."""
        if mesh is None or mesh.n_devices == 1:
            self._wmesh = self._mesh = self._mesh_key = None
            return
        key = mesh.key
        m = self._meshes.get(key)
        if m is None:
            m = mesh.jax_mesh()
            self._meshes[key] = m
        self._wmesh, self._mesh, self._mesh_key = mesh, m, key

    def mesh_compatible(self, mesh, ctxs) -> bool:
        """PR 3's divisibility gate as a placement gate: a >1-device mesh
        is only worth occupying when at least one parameter dimension
        actually shards under ``generic_param_specs`` — otherwise every
        leaf replicates and the extra devices buy nothing."""
        if mesh is None or mesh.n_devices == 1:
            return True
        ok = self._mesh_ok.get(mesh.key)
        if ok is None:
            shapes = jax.eval_shape(
                lambda: self.task.init(jax.random.PRNGKey(self.seed)))
            specs = generic_param_specs(shapes, mesh.rules, sizes=mesh.sizes)
            ok = any(any(ax is not None for ax in spec)
                     for spec in jax.tree.leaves(
                         specs, is_leaf=lambda x: isinstance(x, P)))
            self._mesh_ok[mesh.key] = ok
        return ok

    def clone_state(self, state):
        # jax array leaves are immutable — a fresh container tree is a
        # full-depth safe copy (the dispatcher's copy-on-fanout)
        return jax.tree.map(lambda x: x, state)

    def device_transfer(self, state, mesh):
        """Host-local handoff: re-home the device-resident leaves onto the
        consumer's first device inside a fresh container tree.  Declines
        (→ store fallback) when the mesh's devices are not visible to
        this process."""
        out = dict(state)
        if mesh is not None:
            try:
                dev = mesh.jax_mesh().devices.flat[0]
            except Exception:
                return None
            for k in ("params", "opt"):
                if out.get(k) is not None:
                    out[k] = jax.device_put(out[k], dev)
        return out

    def _carry_shardings(self, carry, n_lead: int):
        """NamedSharding tree for the at-rest carry placement (member-stack
        axis, when present, never shards)."""
        specs = generic_param_specs(carry, self._wmesh.rules,
                                    sizes=self._wmesh.sizes, n_lead=n_lead)
        return jax.tree.map(lambda s: NamedSharding(self._mesh, s), specs,
                            is_leaf=lambda x: isinstance(x, P))

    def _meshed_build(self, build, carry, n_lead: int):
        """Wrap a chunk-body builder for mesh execution: the carry enters
        sharded at rest and is gathered to replicated before the
        arithmetic — pure data movement, so the body stays CPU-bitwise
        vs the unsharded build.  The output deliberately carries NO
        sharding constraint: an in-program re-scatter back-propagates
        partitioning into the tail arithmetic (different reduction
        order → ±ulp drift), so the caller re-scatters outside the
        executable with ``device_put`` instead."""
        if self._mesh is None:
            return build
        shardings = self._carry_shardings(carry, n_lead)
        replicated = jax.tree.map(
            lambda _: NamedSharding(self._mesh, P()), shardings,
            is_leaf=lambda x: isinstance(x, NamedSharding))

        def wrapped_build():
            fn = build()

            def meshed(carry, *rest):
                carry = jax.lax.with_sharding_constraint(carry, replicated)
                return fn(carry, *rest)

            return meshed

        return wrapped_build

    @property
    def supports_batched_stages(self) -> bool:  # type: ignore[override]
        return self.fused

    @property
    def supports_chain_fusion(self) -> bool:  # type: ignore[override]
        return self.fused

    # ------------------------------------------------------------------ state
    def init_state(self) -> Dict[str, Any]:
        params = self.task.init(jax.random.PRNGKey(self.seed))
        pipe = self.pipeline_factory()
        return {
            "params": params,
            "opt": None,               # lazy: optimizer choice is a static hp
            "opt_name": None,
            "data": pipe.state(),
            "step": 0,
        }

    # -------------------------------------------------------------- stage prep
    def _stage_plan(self, ctx: StageContext):
        """Per-step value arrays, traced static hps, optimizer, hp names."""
        vals = desc_values(ctx.desc, ctx.node_start, ctx.start, ctx.stop)
        static = desc_static(ctx.desc)
        opt_name = static.get("optimizer", self.default_optimizer)
        static_hp = {k: float(v) for k, v in static.items()
                     if isinstance(v, (int, float)) and not k.startswith("_")}
        names = [k for k in vals if k != "bs"]
        return vals, static_hp, opt_name, names

    @staticmethod
    def _bs_runs(vals: Dict[str, List[float]], n: int
                 ) -> List[Tuple[int, int, Optional[int]]]:
        """Maximal runs ``[(i0, i1, bs)]`` of constant batch size; ``bs`` is
        None when the stage has no batch-size sequence (pipeline keeps its
        restored size)."""
        if "bs" not in vals:
            return [(0, n, None)]
        sizes = [int(round(v)) for v in vals["bs"]]
        runs, i0 = [], 0
        for i in range(1, n + 1):
            if i == n or sizes[i] != sizes[i0]:
                runs.append((i0, i, sizes[i0]))
                i0 = i
        return runs

    @staticmethod
    def _slab_sig(slab: Dict[str, np.ndarray]) -> Tuple:
        """Batch shape/dtype signature of a data slab (without the step axis)."""
        return tuple((k, tuple(v.shape[1:]), str(v.dtype))
                     for k, v in sorted(slab.items()))

    # ------------------------------------------------------------ executables
    def _make_chunk_body(self, opt_name: str, n_steps: int):
        """The fused stage body: ``n_steps`` training steps over the
        slab/hp/step arrays.  Statically unrolled on CPU (bit-exact vs the
        per-step loop), a real ``lax.scan`` on accelerator backends — see
        the module docstring for the gate's rationale."""
        task = self.task
        update = fused_apply_update if self.use_kernel else apply_update

        if self.use_scan:
            def chunk(carry, static_hp, hp_xs, slab, steps):
                def body(c, xs):
                    hp_i, batch, step = xs
                    params, opt = c
                    hp = dict(static_hp)
                    hp.update(hp_i)
                    (loss, _), grads = jax.value_and_grad(
                        task.loss, has_aux=True)(params, batch)
                    params, opt = update(opt_name, params, grads, opt,
                                         hp, step)
                    return (params, opt), loss

                carry, losses = jax.lax.scan(body, carry,
                                             (hp_xs, slab, steps))
                return carry, losses[-1]

            chunk.uses_scan = True
            return chunk

        def chunk(carry, static_hp, hp_xs, slab, steps):
            params, opt = carry
            loss = jnp.float32(0)
            for i in range(n_steps):
                hp = dict(static_hp)
                hp.update({k: v[i] for k, v in hp_xs.items()})
                batch = {k: v[i] for k, v in slab.items()}
                (loss, _), grads = jax.value_and_grad(
                    task.loss, has_aux=True)(params, batch)
                params, opt = update(opt_name, params, grads, opt,
                                     hp, steps[i])
            return (params, opt), loss

        chunk.uses_scan = False
        return chunk

    def _call_executable(self, key: Tuple, build, donate: bool, args: Tuple):
        """Invoke the cached executable for ``key``, AOT-compiling on miss.

        Ahead-of-time ``lower().compile()`` (instead of first-call jit
        compilation) lets compilation time be accounted separately in
        ``compile_seconds`` — the dispatcher's wall-clock stage timing
        subtracts it, keeping profiles and virtual time execution-only."""
        exe = self._chunk_fns.get(key)
        if exe is None:
            t0 = time.perf_counter()
            jitted = jax.jit(build(), donate_argnums=(0,) if donate else ())
            exe = jitted.lower(*args).compile()
            self.compile_seconds += time.perf_counter() - t0
            self._chunk_fns[key] = exe
        self.exec_calls += 1
        return exe(*args)

    def _call_fused(self, opt_name: str, n_steps: int, slab_sig: Tuple,
                    hp_sig: Tuple, donate: bool, args: Tuple):
        key = ("fused", opt_name, n_steps, slab_sig, hp_sig, donate,
               self._mesh_key, self.use_scan)
        build = self._meshed_build(
            lambda: self._make_chunk_body(opt_name, n_steps), args[0],
            n_lead=0)
        return self._call_executable(key, build, donate, args)

    def _call_group(self, opt_name: str, group: int, n_steps: int,
                    slab_sig: Tuple, hp_sig: Tuple, shared_slab: bool,
                    args: Tuple):
        """``shared_slab``: sibling groups forked from one checkpoint see
        the same data stream — the slab is gathered once and broadcast to
        every member inside the executable instead of stacked per member."""
        key = ("group", opt_name, group, n_steps, slab_sig, hp_sig,
               shared_slab, self._mesh_key, self.vectorize_groups,
               self.use_scan)

        def build():
            chunk = self._make_chunk_body(opt_name, n_steps)
            if self.vectorize_groups:
                return jax.vmap(chunk,
                                in_axes=(0, None, 0, None if shared_slab
                                         else 0, None))

            def grouped(carry, static_hp, hp_xs, slab, steps):
                outs, losses = [], []
                for g in range(group):
                    member = jax.tree.map(lambda x, g=g: x[g], carry)
                    hx = {k: v[g] for k, v in hp_xs.items()}
                    sl = slab if shared_slab else {k: v[g]
                                                   for k, v in slab.items()}
                    out, loss = chunk(member, static_hp, hx, sl, steps)
                    outs.append(out)
                    losses.append(loss)
                return stack_pytrees(outs), jnp.stack(losses)

            return grouped

        return self._call_executable(
            key, self._meshed_build(build, args[0], n_lead=1), self._donate,
            args)

    # -------------------------------------------------------------- execute
    def run_stage(self, state: Dict[str, Any], ctx: StageContext
                  ) -> Dict[str, Any]:
        if not self.fused:
            return self.run_stage_stepwise(state, ctx)
        return self._run_fused([state], [ctx])[0]

    def run_stages_batched(self, states: Sequence[Dict[str, Any]],
                           ctxs: Sequence[StageContext]
                           ) -> List[Dict[str, Any]]:
        if not self.fused:
            return [self.run_stage_stepwise(s, c)
                    for s, c in zip(states, ctxs)]
        return self._run_fused(list(states), list(ctxs))

    def run_chain(self, state: Dict[str, Any],
                  ctxs: Sequence[StageContext]) -> List[Dict[str, Any]]:
        """Chain-fused execution: the carry stays on device across every
        stage boundary (one persistent pipeline, no restack, no host
        round-trip) and a boundary snapshot is returned per stage — bit-
        identical to running :meth:`run_stage` per stage on CPU."""
        if not self.fused:
            return super().run_chain(state, ctxs)
        return self._run_fused_chain([state], [list(ctxs)])[0]

    def run_chains_batched(self, states: Sequence[Dict[str, Any]],
                           chains: Sequence[Sequence[StageContext]]
                           ) -> List[List[Dict[str, Any]]]:
        """Batched multi-stage chains: every stage level of a sibling-chain
        group executes as one compiled call over member-stacked carries,
        and the stack itself persists across stage boundaries."""
        if not self.fused:
            return [self.run_chain(s, c) for s, c in zip(states, chains)]
        return self._run_fused_chain(list(states),
                                     [list(c) for c in chains])

    def _run_fused(self, states: List[Dict[str, Any]],
                   ctxs: List[StageContext]) -> List[Dict[str, Any]]:
        return [b[-1] for b in
                self._run_fused_chain(states, [[c] for c in ctxs])]

    def _run_fused_chain(self, states: List[Dict[str, Any]],
                         chains: List[List[StageContext]]
                         ) -> List[List[Dict[str, Any]]]:
        """Run ``group`` parallel chains (one per member) of equal depth,
        returning ``[member][stage]`` boundary states.

        The carry — ``(params, opt)``, member-stacked for groups — and the
        data pipelines persist across stage boundaries; each boundary only
        snapshots the carry (for groups: per-member gathers off the stack)
        so the dispatcher can checkpoint it, then execution continues on
        device.  ``group == 1, depth == 1`` degenerates to the old fused
        single-stage path, ``group > 1, depth == 1`` to sibling batching."""
        group = len(states)
        depth = len(chains[0])
        for ch in chains[1:]:
            if len(ch) != depth:
                raise ValueError("batched chains must share their depth")
        plans = [[self._stage_plan(c) for c in ch] for ch in chains]
        for ch in chains:   # stages of one chain must be contiguous
            step = ch[0].start
            for c in ch:
                if c.start != step:
                    raise ValueError(
                        f"chain stages must be contiguous: stage starts at "
                        f"{c.start}, previous stopped at {step}")
                step = c.stop

        opt_name = plans[0][0][2]
        params_l, opt_l = [], []
        for s, ch in zip(states, chains):
            assert s["step"] == ch[0].start, (s["step"], ch[0].start)
            params_l.append(s["params"])
            opt = s["opt"]
            if opt is None or s["opt_name"] != opt_name:
                opt = init_opt_state(opt_name, s["params"])
            opt_l.append(opt)
        # siblings forked from one checkpoint share the data stream: one
        # pipeline (and one slab, broadcast in-executable) serves them all
        shared_data = group > 1 and all(
            tuple(s["data"]) == tuple(states[0]["data"]) for s in states[1:])
        pipes = []
        for s in (states[:1] if shared_data else states):
            pipe = self.pipeline_factory()
            pipe.restore(s["data"])
            pipes.append(pipe)

        if group == 1:
            carry = (params_l[0], opt_l[0])
        else:
            carry = (stack_pytrees(params_l), stack_pytrees(opt_l))
        n_lead = 0 if group == 1 else 1   # member-stack axis never shards
        carry_shd = None                  # at-rest NamedSharding tree
        if self._mesh is not None:
            carry_shd = self._carry_shardings(carry, n_lead)
            carry = jax.device_put(carry, carry_shd)
        boundaries: List[List[Dict[str, Any]]] = [[] for _ in range(group)]

        for j in range(depth):
            ctx0 = chains[0][j]
            n = ctx0.stop - ctx0.start
            vals0, static_hp0, stage_opt, names0 = plans[0][j]
            runs = self._bs_runs(vals0, n)
            for ch, pl in zip(chains[1:], plans[1:]):
                c = ch[j]
                vals, static_hp, opt_n, names = pl[j]
                if (c.start, c.stop) != (ctx0.start, ctx0.stop):
                    raise ValueError("batched stages must share [start, stop)")
                if opt_n != stage_opt or static_hp != static_hp0:
                    raise ValueError("batched stages must share static hps")
                if names != names0:
                    raise ValueError("batched stages must share hp names")
                if self._bs_runs(vals, n) != runs:
                    raise ValueError("batched stages must share the bs schedule")
            if j == 0 and runs and runs[0][2] is None and len(pipes) > 1:
                if len({p.batch_size for p in pipes}) > 1:
                    raise ValueError("batched stages must share the batch size")
            if stage_opt != opt_name:
                # optimizer switch at the boundary: fresh slots, exactly as
                # run_stage would re-init on the restored state
                carry = (carry[0], init_opt_state(stage_opt, carry[0]))
                opt_name = stage_opt
                if carry_shd is not None:    # fresh slots: back to at-rest
                    carry_shd = self._carry_shardings(carry, n_lead)
                    carry = jax.device_put(carry, carry_shd)
            hp_sig = (tuple(sorted(names0)), tuple(sorted(static_hp0)))

            # the previous boundary snapshot aliases the carry: the first
            # chunk after a snapshot (and the caller's state) is never
            # donated; later chunks within the stage own their carry
            first = True
            for i0, i1, bs in runs:
                if bs is not None:
                    for pipe in pipes:
                        pipe.set_batch_size(bs)
                w0 = i0
                for k_len in chunk_lengths(i1 - i0, self.chunk_steps):
                    w1 = w0 + k_len
                    slabs = [pipe.next_batches(k_len) for pipe in pipes]
                    steps = jnp.arange(ctx0.start + w0, ctx0.start + w1,
                                       dtype=jnp.int32)
                    if group == 1:
                        hp_xs = {k: np.asarray(vals0[k][w0:w1], np.float32)
                                 for k in names0}
                        carry, _ = self._call_fused(
                            opt_name, k_len, self._slab_sig(slabs[0]), hp_sig,
                            self._donate and not first,
                            (carry, static_hp0, hp_xs, slabs[0], steps))
                    else:
                        hp_xs = {k: np.asarray([pl[j][0][k][w0:w1]
                                                for pl in plans],
                                               np.float32)
                                 for k in names0}
                        slab = (slabs[0] if shared_data else
                                {k: np.stack([s[k] for s in slabs])
                                 for k in slabs[0]})
                        carry, _ = self._call_group(
                            opt_name, group, k_len, self._slab_sig(slabs[0]),
                            hp_sig, shared_data,
                            (carry, static_hp0, hp_xs, slab, steps))
                    if carry_shd is not None:
                        # re-scatter to the at-rest placement OUTSIDE the
                        # executable (see _meshed_build: an in-program
                        # output constraint would cost bit-exactness)
                        carry = jax.device_put(carry, carry_shd)
                    first = False
                    w0 = w1

            # ---- boundary snapshot: per-member state the dispatcher can
            # checkpoint; the carry itself stays on device for stage j+1
            if group == 1:
                params_out, opt_out = [carry[0]], [carry[1]]
            else:
                params_out = unstack_pytree(carry[0], group)
                opt_out = unstack_pytree(carry[1], group)
            if self._mesh is not None:
                # snapshots leave the trainer unsharded: checkpoints, eval
                # and cross-worker handoff all see single-device trees
                dev = self._mesh.devices.flat[0]
                params_out = [jax.device_put(p, dev) for p in params_out]
                opt_out = [jax.device_put(o, dev) for o in opt_out]
            datas = ([pipes[0].state()] * group if shared_data
                     else [p.state() for p in pipes])
            for m in range(group):
                boundaries[m].append(
                    {"params": params_out[m], "opt": opt_out[m],
                     "opt_name": opt_name, "data": datas[m],
                     "step": ctx0.stop})
        return boundaries

    # ----------------------------------------------- seed per-step reference
    def _jitted_step(self, opt_name: str):
        key = ("step", opt_name)
        if key not in self._step_fns:
            update = fused_apply_update if self.use_kernel else apply_update

            def step_fn(params, opt, batch, hp, step):
                (loss, _), grads = jax.value_and_grad(
                    self.task.loss, has_aux=True)(params, batch)
                params, opt = update(opt_name, params, grads, opt,
                                     hp, step)
                return params, opt, loss
            self._step_fns[key] = jax.jit(step_fn)
        return self._step_fns[key]

    def run_stage_stepwise(self, state: Dict[str, Any], ctx: StageContext
                           ) -> Dict[str, Any]:
        """The seed data plane: one jitted dispatch per training step, batch
        re-materialized on host each iteration.  Kept as the bit-exactness
        reference for the fused/batched paths and as the benchmark baseline
        (``benchmarks/bench_dataplane.py``)."""
        assert state["step"] == ctx.start, (state["step"], ctx.start)
        vals, static_hp, opt_name, names = self._stage_plan(ctx)

        params = state["params"]
        opt = state["opt"]
        if opt is None or state["opt_name"] != opt_name:
            opt = init_opt_state(opt_name, params)

        pipe = self.pipeline_factory()
        pipe.restore(state["data"])
        step_fn = self._jitted_step(opt_name)

        for i, step in enumerate(range(ctx.start, ctx.stop)):
            if "bs" in vals:
                pipe.set_batch_size(int(round(vals["bs"][i])))
            batch = pipe.next_batch()
            batch = {k: jnp.asarray(v) for k, v in batch.items()}
            hp = dict(static_hp)
            hp.update({k: vals[k][i] for k in names})
            params, opt, _ = step_fn(params, opt, batch, hp,
                                     jnp.int32(step))

        return {"params": params, "opt": opt, "opt_name": opt_name,
                "data": pipe.state(), "step": ctx.stop}

    # ------------------------------------------------------------- evaluate
    def evaluate(self, state: Dict[str, Any], ctx: StageContext
                 ) -> Dict[str, float]:
        loss, metrics = self._eval_fn(state["params"], self.eval_batch)
        out = {"loss": float(loss)}
        out["val_acc"] = float(metrics.get(self.objective_from, -loss))
        for k, v in metrics.items():
            out[k] = float(v)
        return out

    def stage_seconds(self, ctx: StageContext) -> Optional[float]:
        return None  # wall-clock measured by the engine
