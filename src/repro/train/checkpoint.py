"""Content-addressed checkpoint store (the GlusterFS analogue, §5 / §4.1).

Checkpoints are arbitrary pytrees (model params, optimizer state, data
pipeline cursor, PRNG key, simulated-trainer state, ...) addressed by the
*computation that produced them*: ``key = (search-plan path hash, step)``.
Any two trials — in the same study or different studies — whose
hyper-parameter values coincide up to ``step`` resolve to the same key and
therefore share the checkpoint, which is the entire reuse mechanism.

Checkpoint plane v2 — three composable layers over the same public API
(``put`` / ``put_async`` / ``get`` / ``evict`` / ``flush``):

**Delta encoding.**  Stage-tree siblings fork from shared prefixes, so
most committed checkpoints are near-duplicates of their fork-point parent.
``put(..., parent_cid=...)`` (threaded from the dispatcher, which knows
every boundary's fork point) splits each leaf into fixed-size chunks,
content-hashes them against the parent's chunk index, and commits only the
changed chunks plus a reference map.  Reconstruction resolves the delta
chain recursively; chains are bounded by ``max_delta_depth`` — a commit
whose parent already sits at the bound is *rebased* to a full snapshot, so
no read ever walks more than ``max_delta_depth`` ancestors.  A delta whose
parent has vanished reads as missing (``KeyError``) — recompute-on-miss
upstream makes that safe, exactly like any other lost blob.

**Zero-copy serializer.**  One file per cid: an 8-byte header length, a
JSON header (leaf dtypes/shapes + per-chunk digests + the pickled-treedef
length — the old ``.tree`` sidecar is folded in, removing a file and an
``os.replace`` per commit), the pickled treedef, then the inline chunk
payload written directly from each leaf's ``memoryview`` — no
``np.savez``, no ``BytesIO`` staging copy.  Reads are ``np.frombuffer``
views over the payload.  ``serializer_procs > 0`` moves chunk hashing +
encoding into a process pool so commits stop serializing on the writer
thread's GIL (at the cost of one buffer copy into the worker).

**Tiered backend.**  host LRU read cache → local disk → an injectable
remote :class:`ObjectStore` (directory-backed fake provided).  When
``disk_capacity_bytes`` is set and a remote tier is attached, the
background writer demotes least-recently-used blobs past the capacity to
the remote tier (the local file is dropped, the remote copy is the
replica); a read that misses disk fetches from remote and *promotes* the
blob back.  Every tier is safely lossy — recompute-on-miss upstream
re-derives anything a tier dropped — so demotion needs no correctness
machinery, only the ``tier_promotions`` / ``tier_demotions`` /
``remote_bytes_*`` counters.

Write-behind layer (chain-fused execution): :meth:`put_async` records the
checkpoint in a device-resident *pending* cache and hands the commit
(host transfer + serialization + disk write) to a background writer
thread, so stage boundaries inside a fused chain never stall on
checkpoint I/O.  Pending entries are indistinguishable from committed
ones to every reader — ``get`` / ``contains`` / ``__len__`` serve them,
and ``evict`` cancels them (a kill that races an in-flight write discards
the write instead of leaking the file).  :meth:`flush` is the barrier:
it blocks until every pending write has committed (engine shutdown, and
anything that needs the bytes durably on disk).

Directory hygiene: construction sweeps stale ``*.tmp`` files (a writer
thread reaped between serialize and publish leaks them) into
``tmp_reclaimed``, and builds the disk-cid index once — ``__len__`` /
``committed_ids`` never re-``listdir`` the directory; the index is
maintained incrementally by publish/evict/demote/promote.

Beyond-paper: reference-counted eviction (``evict``) with
recompute-on-miss handled upstream (the engine simply re-derives the stage
from the search plan if a resume checkpoint is gone).
"""

from __future__ import annotations

import hashlib
import json
import os
import pickle
import threading
from collections import OrderedDict, deque
from typing import Any, Dict, Iterable, List, Optional, Sequence, Tuple

import numpy as np

try:  # jax is always present in this repo, but the store works without it
    import jax
    _HAVE_JAX = True
except Exception:  # pragma: no cover
    _HAVE_JAX = False

__all__ = ["CheckpointStore", "ObjectStore", "DirectoryObjectStore",
           "stack_pytrees", "unstack_pytree"]

BLOB_FORMAT = 2                     # single-file header+payload layout
DEFAULT_CHUNK = 1 << 16             # 64 KiB content-hash granularity


def _tree_flatten(tree: Any):
    if _HAVE_JAX:
        leaves, treedef = jax.tree_util.tree_flatten(tree)
        return leaves, treedef
    raise RuntimeError("jax required for pytree checkpoints")


# ---------------------------------------------------------------------------
# stacked-trial helpers (sibling batching)
# ---------------------------------------------------------------------------


def stack_pytrees(trees: Sequence[Any]) -> Any:
    """Stack structurally-identical array pytrees along a new leading axis
    (trial axis of a batched sibling group)."""
    import jax.numpy as jnp
    return jax.tree.map(lambda *xs: jnp.stack(xs), *trees)


def unstack_pytree(tree: Any, n: int) -> List[Any]:
    """Split a leading-axis-stacked pytree back into ``n`` per-trial pytrees
    (the inverse of :func:`stack_pytrees`)."""
    return [jax.tree.map(lambda x, g=g: x[g], tree) for g in range(n)]


# ---------------------------------------------------------------------------
# remote tier interface
# ---------------------------------------------------------------------------


class ObjectStore:
    """Injectable remote-tier interface (S3/GCS in a deployment).

    Keys are checkpoint cids, values are opaque blob bytes.  ``get`` /
    ``delete`` raise ``KeyError`` for absent keys; ``keys()`` enumerates
    (used once at attach time to seed the remote index)."""

    def put(self, key: str, data: bytes) -> None:
        raise NotImplementedError

    def get(self, key: str) -> bytes:
        raise NotImplementedError

    def delete(self, key: str) -> None:
        raise NotImplementedError

    def contains(self, key: str) -> bool:
        raise NotImplementedError

    def keys(self) -> Iterable[str]:
        raise NotImplementedError


class DirectoryObjectStore(ObjectStore):
    """Directory-backed :class:`ObjectStore` fake — the test/dev stand-in
    for a real object store (atomic publish via tmp + rename)."""

    def __init__(self, directory: str):
        self.directory = directory
        os.makedirs(directory, exist_ok=True)

    def _p(self, key: str) -> str:
        return os.path.join(self.directory, key.replace("/", "_") + ".blob")

    def put(self, key: str, data: bytes) -> None:
        tmp = f"{self._p(key)}.{threading.get_ident()}.tmp"
        with open(tmp, "wb") as f:
            f.write(data)
        os.replace(tmp, self._p(key))

    def get(self, key: str) -> bytes:
        try:
            with open(self._p(key), "rb") as f:
                return f.read()
        except FileNotFoundError:
            raise KeyError(key)

    def delete(self, key: str) -> None:
        try:
            os.remove(self._p(key))
        except FileNotFoundError:
            raise KeyError(key)

    def contains(self, key: str) -> bool:
        return os.path.exists(self._p(key))

    def keys(self) -> Iterable[str]:
        return [f[:-len(".blob")] for f in os.listdir(self.directory)
                if f.endswith(".blob")]


# ---------------------------------------------------------------------------
# blob encoding (pure functions — shared by the inline and process-pool
# serializers; must stay module-level picklable)
# ---------------------------------------------------------------------------


def _leaf_view(x: Any) -> Tuple[np.ndarray, memoryview]:
    """Contiguous host array + zero-copy byte view of a pytree leaf."""
    arr = np.asarray(x)
    if not arr.flags["C_CONTIGUOUS"]:
        # NOT unconditional ascontiguousarray: it promotes 0-d scalars
        # to 1-d, corrupting the recorded leaf shape
        arr = np.ascontiguousarray(arr)
    return arr, memoryview(arr.reshape(-1).view(np.uint8))


def _digest(buf) -> str:
    return hashlib.blake2b(buf, digest_size=16).hexdigest()


def _encode_leaves(bufs: Sequence, dtypes: Sequence[str],
                   shapes: Sequence[tuple],
                   parent: Optional[List[List[Tuple[str, int]]]],
                   chunk: int):
    """Chunk + hash every leaf buffer; against ``parent`` (per-leaf chunk
    digest lists) emit references instead of inline bytes for unchanged
    chunks.  Returns ``(leaf_metas, parts, digests, any_ref, logical)``
    where ``parts`` are the inline payload buffers in write order."""
    leaf_metas, parts, digests = [], [], []
    any_ref, logical = False, 0
    for i, (buf, dt, shape) in enumerate(zip(bufs, dtypes, shapes)):
        size = len(buf)
        logical += size
        pdigs = (parent[i] if parent is not None and i < len(parent)
                 else None)
        chunks, ldigs = [], []
        for ci, off in enumerate(range(0, size, chunk)):
            n = min(chunk, size - off)
            piece = buf[off:off + n]
            h = _digest(piece)
            ldigs.append((h, n))
            if pdigs is not None and ci < len(pdigs) and pdigs[ci] == (h, n):
                chunks.append([h, n, 0])          # reference into parent
                any_ref = True
            else:
                chunks.append([h, n, 1])          # inline
                parts.append(piece)
        leaf_metas.append({"d": dt, "s": list(shape), "n": size,
                           "c": chunks})
        digests.append(ldigs)
    return leaf_metas, parts, digests, any_ref, logical


def _encode_leaves_pooled(bufs: List[bytes], dtypes: List[str],
                          shapes: List[tuple],
                          parent: Optional[List[List[Tuple[str, int]]]],
                          chunk: int):
    """Process-pool entry point: same as :func:`_encode_leaves` but ships
    one joined payload back (buffers don't survive pickling as views)."""
    parent = ([[tuple(c) for c in leaf] for leaf in parent]
              if parent is not None else None)
    leaf_metas, parts, digests, any_ref, logical = _encode_leaves(
        bufs, dtypes, shapes, parent, chunk)
    return leaf_metas, b"".join(parts), digests, any_ref, logical


class _Staged(tuple):
    """Serialized-but-unpublished commit: ``(kind, depth, digests,
    payload_len, logical_len, file_len, tmp_path)``."""
    __slots__ = ()

    kind = property(lambda s: s[0])
    depth = property(lambda s: s[1])
    digests = property(lambda s: s[2])
    payload_len = property(lambda s: s[3])
    logical_len = property(lambda s: s[4])
    file_len = property(lambda s: s[5])
    tmp = property(lambda s: s[6])


class CheckpointStore:
    """put/get pytrees by (path_key, step); optionally spill to tiers.

    ``read_cache_entries`` bounds the directory backend's LRU read cache
    (0 disables it); the in-memory backend needs no cache.  ``remote``
    attaches an :class:`ObjectStore` tier below the disk; with
    ``disk_capacity_bytes`` set, LRU blobs past the capacity demote to it
    in the background.  ``parent_cid`` on the put paths enables delta
    encoding (serialized tiers only — the in-memory backend stores live
    objects and needs no encoding)."""

    def __init__(self, directory: Optional[str] = None,
                 read_cache_entries: int = 32,
                 remote: Optional[ObjectStore] = None,
                 disk_capacity_bytes: Optional[int] = None,
                 max_delta_depth: int = 4,
                 chunk_bytes: int = DEFAULT_CHUNK,
                 serializer_procs: int = 0):
        self.directory = directory
        if directory:
            os.makedirs(directory, exist_ok=True)
        self._mem: Dict[str, Any] = {}
        self.remote = remote
        self.disk_capacity_bytes = disk_capacity_bytes
        self.max_delta_depth = int(max_delta_depth)
        self.chunk_bytes = int(chunk_bytes)
        # ---- traffic counters ----
        self.bytes_written = 0      # physical file bytes committed to disk
        self.bytes_read = 0         # physical file bytes read off disk
        self.logical_bytes = 0      # full-serialization-equivalent bytes
        self.delta_bytes = 0        # file bytes of delta-kind commits
        self.full_bytes = 0         # file bytes of full-kind commits
        self.delta_commits = 0
        self.full_commits = 0
        self.delta_rebases = 0      # depth-bound hits rebased to full
        self.delta_fallbacks = 0    # parent meta unavailable -> full
        self.puts = 0
        self.async_puts = 0
        self.gets = 0
        self.hits = 0
        # ---- per-tier read accounting ----
        self.mem_hits = 0           # pending cache / memory map / LRU cache
        self.disk_hits = 0
        self.remote_hits = 0
        self.store_misses = 0
        self.tier_promotions = 0
        self.tier_demotions = 0
        self.tier_demotion_errors = 0
        self.remote_bytes_read = 0
        self.remote_bytes_written = 0
        self.tmp_reclaimed = 0
        # ---- write-behind state (all guarded by _cv's lock) ----
        self._pending: Dict[str, Any] = {}   # cid -> tree awaiting commit
        self._pending_parent: Dict[str, Optional[str]] = {}
        self._work: deque = deque()          # commit order
        self._cancelled: set = set()         # evicted while commit in flight
        self._cv = threading.Condition()
        self._writer: Optional[threading.Thread] = None
        self._write_error: Optional[BaseException] = None
        # ---- directory read path ----
        self.read_cache_entries = int(read_cache_entries)
        self._read_cache: "OrderedDict[str, Any]" = OrderedDict()
        # ---- tier indexes (guarded by _cv) ----
        # disk index doubles as the demotion LRU: cid -> file bytes
        self._disk_cids: "OrderedDict[str, int]" = OrderedDict()
        self._disk_bytes = 0
        self._remote_cids: set = set()
        self._demoting: set = set()          # demotion uploads in flight
        # cid -> (delta depth, per-leaf chunk digests) for delta encoding
        self._blob_meta: Dict[str, Tuple[int, List[List[Tuple[str, int]]]]] = {}
        self._serializer_procs = int(serializer_procs)
        self._pool = None
        if directory:
            self._init_scan()
        if remote is not None:
            self._remote_cids.update(remote.keys())

    def _init_scan(self) -> None:
        """One-time directory scan: build the incremental disk-cid index
        and reap stale temp files a reaped writer thread left behind."""
        for f in sorted(os.listdir(self.directory)):
            p = os.path.join(self.directory, f)
            if f.endswith(".tmp"):
                try:
                    os.remove(p)
                    self.tmp_reclaimed += 1
                except OSError:  # pragma: no cover - racing sweeper
                    pass
            elif f.endswith(".ckpt"):
                try:
                    size = os.path.getsize(p)
                except OSError:  # pragma: no cover - racing eviction
                    continue
                self._disk_cids[f[:-len(".ckpt")]] = size
                self._disk_bytes += size

    # -------------------------------------------------------------- keys
    @staticmethod
    def ckpt_id(path_key: str, step: int) -> str:
        return f"{path_key}@{step}"

    @property
    def dedup_ratio(self) -> float:
        """Full-serialization bytes per physical byte written (>= 1 when
        delta encoding is saving storage; 1.0 with nothing written)."""
        return (self.logical_bytes / self.bytes_written
                if self.bytes_written else 1.0)

    # --------------------------------------------------------------- put
    def put(self, path_key: str, step: int, tree: Any,
            parent_cid: Optional[str] = None) -> str:
        cid = self.ckpt_id(path_key, step)
        self.puts += 1
        if self._revoke_or_dedup(cid):
            return cid  # content already produced by a sibling — dedup
        if self.directory:
            staged = self._serialize_disk(cid, tree, parent_cid)
            with self._cv:   # counters/publish shared with the writer thread
                self._publish_disk(cid, staged)
            self._demote_excess()
        else:
            self._mem[cid] = tree
        return cid

    def put_async(self, path_key: str, step: int, tree: Any,
                  parent_cid: Optional[str] = None) -> str:
        """Write-behind ``put``: the tree enters the pending cache (served
        to readers immediately) and the commit — host transfer, serialize,
        disk write — happens on the background writer thread.  Returns the
        cid exactly like :meth:`put`; :meth:`flush` is the durability
        barrier."""
        cid = self.ckpt_id(path_key, step)
        self.puts += 1
        if self._revoke_or_dedup(cid):
            return cid
        with self._cv:
            self._pending[cid] = tree
            self._pending_parent[cid] = parent_cid
            self._work.append(cid)
            self.async_puts += 1
            if self._writer is None:
                self._writer = threading.Thread(
                    target=self._writer_loop, name="ckpt-writer", daemon=True)
                self._writer.start()
            self._cv.notify_all()
        return cid

    def _revoke_or_dedup(self, cid: str) -> bool:
        """True when ``cid`` is already held (pending / committed) and the
        put can dedup.  A cid whose in-flight commit was cancelled by an
        eviction is NOT deduped — its disk bytes are about to be undone —
        but the cancellation is revoked so the undo never happens to the
        re-deposited content (same cid == same content)."""
        with self._cv:
            if cid in self._pending:
                return True
            if cid in self._cancelled:
                self._cancelled.discard(cid)
                return False
            if cid in self._disk_cids or cid in self._remote_cids:
                return True
        return cid in self._mem

    def _known(self, cid: str) -> bool:
        with self._cv:
            if cid in self._pending:
                return True
            if cid in self._cancelled:
                # an in-flight commit of this content is being undone; its
                # disk bytes are untrustworthy until the undo lands
                return False
            if cid in self._disk_cids or cid in self._remote_cids:
                return True
        return cid in self._mem

    # --------------------------------------------------------- writer thread
    _IDLE_EXIT_SECONDS = 5.0   # idle writer threads retire themselves

    def _writer_loop(self) -> None:
        cid = None
        try:
            while True:
                cid = None
                with self._cv:
                    while not self._work:
                        if not self._cv.wait(timeout=self._IDLE_EXIT_SECONDS):
                            if not self._work:
                                # idle too long: retire so the thread (and
                                # the store it pins) can be reclaimed;
                                # put_async spawns a fresh writer on the
                                # next deposit
                                self._writer = None
                                return
                    cid = self._work.popleft()
                    tree = self._pending.get(cid)
                    parent_cid = self._pending_parent.get(cid)
                if tree is None:
                    continue  # superseded (a revoked re-put already committed)
                try:
                    staged = (self._serialize_disk(cid, tree, parent_cid)
                              if self.directory else None)
                except BaseException as e:  # surfaced at the next flush()
                    with self._cv:
                        self._write_error = e
                        self._pending.pop(cid, None)
                        self._pending_parent.pop(cid, None)
                        self._cancelled.discard(cid)
                        self._cv.notify_all()
                    continue
                with self._cv:
                    try:
                        if cid in self._cancelled:
                            # evicted while serializing: the commit never
                            # publishes — the final path is untouched, only
                            # temps to discard
                            self._cancelled.discard(cid)
                            if staged is not None:
                                os.remove(staged.tmp)
                        else:
                            # publish + state transition in ONE critical
                            # section so __len__ never sees a cid as both
                            # pending and on disk
                            if staged is not None:
                                self._publish_disk(cid, staged)
                            elif cid in self._pending:
                                self._mem[cid] = tree
                            self._pending.pop(cid, None)
                            self._pending_parent.pop(cid, None)
                    except BaseException as e:
                        # a publish/cancel failure must never strand the
                        # cid in _pending/_cancelled: flush() would
                        # deadlock instead of surfacing the error
                        self._write_error = e
                        self._pending.pop(cid, None)
                        self._pending_parent.pop(cid, None)
                        self._cancelled.discard(cid)
                    finally:
                        self._cv.notify_all()
                self._demote_excess()
        except BaseException as e:
            # unexpected thread death (anything the per-item handlers above
            # did not catch): surface at the next flush() and make sure the
            # in-flight cid is not stranded in _pending/_cancelled
            with self._cv:
                self._write_error = e
                if cid is not None:
                    self._pending.pop(cid, None)
                    self._pending_parent.pop(cid, None)
                    self._cancelled.discard(cid)
        finally:
            # thread exit — expected (idle retire) or not — must never leave
            # self._writer pointing at a dead thread: put_async would skip
            # spawning a replacement and flush() would hang on the queue
            with self._cv:
                if self._writer is threading.current_thread():
                    self._writer = None
                    if self._work:
                        self._writer = threading.Thread(
                            target=self._writer_loop, name="ckpt-writer",
                            daemon=True)
                        self._writer.start()
                self._cv.notify_all()

    def flush(self) -> None:
        """Block until every pending write has committed and every
        cancelled in-flight commit has been undone.  Raises if the writer
        thread failed."""
        with self._cv:
            while self._pending or self._cancelled:
                self._cv.wait()
            if self._write_error is not None:
                err, self._write_error = self._write_error, None
                raise RuntimeError("checkpoint write-behind failed") from err

    def close(self) -> None:
        """Flush, then release the optional serializer process pool."""
        self.flush()
        if self._pool is not None:
            self._pool.shutdown()
            self._pool = None

    @property
    def pending_writes(self) -> int:
        with self._cv:
            return len(self._pending)

    # --------------------------------------------------------------- get
    def get(self, cid: str) -> Any:
        """The pytree committed under ``cid`` (any tier), or ``KeyError``.

        Returned trees are SHARED — with the pending/in-memory map on the
        memory paths and with the LRU read cache on the serialized paths —
        so treat them as read-only (disk-restored leaves are enforced
        read-only ``np.frombuffer`` views); copy before mutating.  Trainers
        are functional (stages return new state), so nothing in the engine
        mutates a restored tree in place."""
        self.gets += 1
        with self._cv:
            tree = self._pending.get(cid)
            cancelled = cid in self._cancelled
        if tree is not None:        # in-flight write: serve the live object
            self.hits += 1
            self.mem_hits += 1
            return tree
        if cancelled:               # evicted mid-commit: gone to readers
            self.store_misses += 1
            raise KeyError(f"checkpoint {cid!r} not in store")
        if cid in self._mem:
            self.hits += 1
            self.mem_hits += 1
            return self._mem[cid]
        if self.directory:
            cached = self._read_cache.get(cid)
            if cached is not None:
                self._read_cache.move_to_end(cid)
                self.hits += 1
                self.mem_hits += 1
                return cached
            try:
                tree = self._read_disk(cid)
            except KeyError:
                self.store_misses += 1
                raise
            self.hits += 1
            self._cache_read(cid, tree)
            return tree
        self.store_misses += 1
        raise KeyError(f"checkpoint {cid!r} not in store")

    def contains(self, cid: str) -> bool:
        return self._known(cid)

    # ---------------------------------------------------- session persistence
    def committed_ids(self) -> set:
        """Ids of every durably-committed checkpoint (session snapshots:
        call :meth:`flush` first so nothing is left pending).  Served from
        the incrementally-maintained tier indexes — no directory scan."""
        with self._cv:
            ids = set(self._pending) - self._cancelled
            ids |= set(self._disk_cids)
            ids |= self._remote_cids
        ids |= set(self._mem)
        return ids

    def snapshot_trees(self) -> Optional[Dict[str, Any]]:
        """In-memory backend only: the committed cid→tree map, for
        embedding into a session snapshot (a directory backend returns
        None — its blobs are already durable on disk)."""
        return None if self.directory else dict(self._mem)

    def load_trees(self, trees: Dict[str, Any]) -> None:
        """Seed the in-memory backend from a session snapshot."""
        self._mem.update(trees)

    def _cache_read(self, cid: str, tree: Any) -> None:
        if self.read_cache_entries <= 0:
            return
        self._read_cache[cid] = tree
        self._read_cache.move_to_end(cid)
        while len(self._read_cache) > self.read_cache_entries:
            self._read_cache.popitem(last=False)

    # ------------------------------------------------------------- evict
    def evict(self, cid: str) -> bool:
        with self._cv:
            if cid in self._pending:
                del self._pending[cid]
                self._pending_parent.pop(cid, None)
                try:
                    # not yet picked up by the writer: nothing to undo
                    self._work.remove(cid)
                except ValueError:
                    # commit in flight: the writer undoes it on completion
                    self._cancelled.add(cid)
                self._cv.notify_all()
                return True
        self._read_cache.pop(cid, None)
        if cid in self._mem:
            del self._mem[cid]
            return True
        removed = False
        with self._cv:
            self._blob_meta.pop(cid, None)
            size = self._disk_cids.pop(cid, None)
            if size is not None:
                self._disk_bytes -= size
                removed = True
            on_remote = cid in self._remote_cids
            self._remote_cids.discard(cid)
        if size is not None:
            try:
                os.remove(self._path(cid))
            except FileNotFoundError:  # pragma: no cover - demote race
                pass
        if on_remote:
            try:
                self.remote.delete(cid)
                removed = True
            except KeyError:  # pragma: no cover - external cleanup
                pass
        return removed

    def __len__(self) -> int:
        # one critical section: publish + pending-removal are atomic on the
        # writer side, so a cid is never counted as both pending and on disk
        with self._cv:
            n = len(self._mem) + len(self._pending)
            if self.directory or self.remote is not None:
                n += len(self._disk_cids.keys() | self._remote_cids)
        return n

    # ---------------------------------------------------------- disk I/O
    def _path(self, cid: str) -> str:
        safe = cid.replace("/", "_")
        return os.path.join(self.directory, safe + ".ckpt")

    def _parent_meta(self, parent_cid: Optional[str]):
        """(depth, chunk digests) of a committed parent blob, for delta
        encoding — from the in-memory meta map, else recovered from the
        parent's on-disk header (a restored process deltas against blobs
        it never wrote).  None when the parent can't serve as a base."""
        if parent_cid is None:
            return None
        with self._cv:
            meta = self._blob_meta.get(parent_cid)
            on_disk = parent_cid in self._disk_cids
            on_remote = parent_cid in self._remote_cids
        if meta is None and on_disk:
            try:
                hdr = self._read_header(parent_cid)
            except (KeyError, OSError, ValueError):
                return None
            if hdr.get("chunk") != self.chunk_bytes:
                # parent was encoded at a different chunk size (store
                # reopened with another chunk_bytes): its digests index
                # different byte ranges, so a digest match at chunk ci
                # would splice the WRONG parent offset — degrade to full
                return None
            meta = (hdr["depth"],
                    [[(h, n) for h, n, _ in leaf["c"]]
                     for leaf in hdr["leaves"]])
            with self._cv:
                self._blob_meta[parent_cid] = meta
        if meta is None or not (on_disk or on_remote):
            return None
        return meta

    def _serialize_disk(self, cid: str, tree: Any,
                        parent_cid: Optional[str] = None) -> _Staged:
        """Serialize to a thread-unique temp file (no lock held; the final
        path is untouched).  Delta-encodes against ``parent_cid`` when its
        chunk index is available and its delta chain is under the depth
        bound; otherwise commits a full snapshot."""
        leaves, treedef = _tree_flatten(tree)
        tree_blob = pickle.dumps(treedef)
        arrs, views = zip(*(_leaf_view(x) for x in leaves)) if leaves else ((), ())
        dtypes = [a.dtype.str for a in arrs]
        shapes = [a.shape for a in arrs]

        parent = self._parent_meta(parent_cid)
        depth = 0
        if parent is not None and parent[0] >= self.max_delta_depth:
            self.delta_rebases += 1     # chain at the bound: rebase to full
            parent = None
        elif parent_cid is not None and parent is None:
            self.delta_fallbacks += 1   # parent gone / unreadable / pending
        pdigs = parent[1] if parent is not None else None

        if self._serializer_procs > 0 and views:
            pool = self._ensure_pool()
            fut = pool.submit(_encode_leaves_pooled,
                              [bytes(v) for v in views], dtypes, shapes,
                              pdigs, self.chunk_bytes)
            leaf_metas, payload, digests, any_ref, logical = fut.result()
            parts = [payload]
        else:
            leaf_metas, parts, digests, any_ref, logical = _encode_leaves(
                views, dtypes, shapes, pdigs, self.chunk_bytes)

        if any_ref:
            kind, depth = "delta", parent[0] + 1
        else:
            # nothing referenced (fully divergent, or no usable parent):
            # commit as a self-contained snapshot with no chain dependency
            kind, depth, parent_cid = "full", 0, None
            for leaf in leaf_metas:
                for c in leaf["c"]:
                    c[2] = 1

        header = json.dumps({
            "v": BLOB_FORMAT, "kind": kind, "parent": parent_cid,
            "depth": depth, "chunk": self.chunk_bytes,
            "tree_len": len(tree_blob),
            "leaves": leaf_metas}).encode("utf-8")
        path = self._path(cid)
        tmp = f"{path}.{threading.get_ident()}.tmp"
        payload_len = 0
        with open(tmp, "wb") as f:
            f.write(len(header).to_bytes(8, "little"))
            f.write(header)
            f.write(tree_blob)
            for piece in parts:
                f.write(piece)          # direct memoryview write, no staging
                payload_len += len(piece)
        file_len = 8 + len(header) + len(tree_blob) + payload_len
        # logical = what a *full* commit of this state would have written
        # (same header/treedef framing, every chunk inline), so
        # logical/physical is exactly 1.0 without deltas and the dedup
        # ratio isolates the delta layer's savings
        logical_len = 8 + len(header) + len(tree_blob) + logical
        return _Staged((kind, depth, digests, payload_len,
                        logical_len, file_len, tmp))

    def _ensure_pool(self):
        if self._pool is None:
            import multiprocessing
            from concurrent.futures import ProcessPoolExecutor
            # spawn, not fork: the host process has live JAX/writer threads
            # and forking a multithreaded process can deadlock the children.
            self._pool = ProcessPoolExecutor(
                max_workers=self._serializer_procs,
                mp_context=multiprocessing.get_context("spawn"))
        return self._pool

    def _publish_disk(self, cid: str, staged: _Staged) -> None:
        """Atomically publish a staged temp file (caller holds ``_cv``):
        one ``os.replace`` — header, treedef and payload travel in a single
        blob, so a crash (or the daemon writer being reaped at interpreter
        exit) can never leave a half-written file at the address readers
        probe."""
        path = self._path(cid)
        os.replace(staged.tmp, path)
        prev = self._disk_cids.pop(cid, None)
        if prev is not None:
            self._disk_bytes -= prev
        self._disk_cids[cid] = staged.file_len
        self._disk_bytes += staged.file_len
        self._blob_meta[cid] = (staged.depth, staged.digests)
        self.bytes_written += staged.file_len
        self.logical_bytes += staged.logical_len
        if staged.kind == "delta":
            self.delta_bytes += staged.file_len
            self.delta_commits += 1
        else:
            self.full_bytes += staged.file_len
            self.full_commits += 1

    # ------------------------------------------------------------ tiering
    def _demote_excess(self) -> None:
        """Move LRU disk blobs past ``disk_capacity_bytes`` to the remote
        tier (remote copy lands *before* the local file goes, so readers
        always find the blob somewhere).

        Best-effort and concurrency-safe: a failing ``remote.put`` (or an
        unreadable local file) is counted in ``tier_demotion_errors`` and
        demotion stops for this pass — it must never propagate into the
        writer thread, a synchronous put, or a promoting read.  Cids with
        a demotion in flight are claimed in ``_demoting`` so two
        concurrent passes never double-demote (and double-count) the same
        blob, and an eviction landing mid-demotion wins: the freshly
        uploaded remote copy is deleted instead of indexed, so evicted
        checkpoints are never resurrected."""
        if self.remote is None or not self.disk_capacity_bytes:
            return
        while True:
            with self._cv:
                if self._disk_bytes <= self.disk_capacity_bytes:
                    return
                cid = next((c for c in self._disk_cids
                            if c not in self._demoting), None)
                if cid is None or len(self._disk_cids) <= 1:
                    return
                self._demoting.add(cid)
            try:
                try:
                    with open(self._path(cid), "rb") as f:
                        data = f.read()
                except FileNotFoundError:  # pragma: no cover - evict race
                    with self._cv:
                        prev = self._disk_cids.pop(cid, None)
                        if prev is not None:
                            self._disk_bytes -= prev
                    continue
                except OSError:  # pragma: no cover - unreadable, not absent
                    with self._cv:
                        self.tier_demotion_errors += 1
                    return
                try:
                    self.remote.put(cid, data)
                except Exception:
                    # remote outage: keep the blob local (capacity is
                    # temporarily exceeded) and stop demoting this pass
                    with self._cv:
                        self.tier_demotion_errors += 1
                    return
                with self._cv:
                    evicted = cid not in self._disk_cids
                    if not evicted:
                        self._remote_cids.add(cid)
                        self._disk_bytes -= self._disk_cids.pop(cid)
                        self.tier_demotions += 1
                        self.remote_bytes_written += len(data)
                if evicted:
                    # evict() removed the cid while the upload was in
                    # flight: honor the eviction — drop the remote copy
                    try:
                        self.remote.delete(cid)
                    except KeyError:  # pragma: no cover - already gone
                        pass
                    continue
                try:
                    os.remove(self._path(cid))
                except FileNotFoundError:  # pragma: no cover - evict race
                    pass
            finally:
                with self._cv:
                    self._demoting.discard(cid)

    def _fetch_blob(self, cid: str, count_hit: bool = False) -> bytearray:
        """Raw blob bytes from the disk tier, else the remote tier (with
        promotion back to disk).  Raises ``KeyError`` when no tier holds
        the cid."""
        with self._cv:
            on_disk = cid in self._disk_cids
        if on_disk:
            try:
                with open(self._path(cid), "rb") as f:
                    data = bytearray(f.read())
                with self._cv:
                    self.bytes_read += len(data)
                    if cid in self._disk_cids:
                        self._disk_cids.move_to_end(cid)
                    if count_hit:
                        self.disk_hits += 1
                return data
            except FileNotFoundError:
                pass        # demoted (or evicted) underfoot: try remote
        if self.remote is not None:
            with self._cv:
                on_remote = cid in self._remote_cids
            if on_remote:
                try:
                    data = bytearray(self.remote.get(cid))
                except KeyError:
                    raise KeyError(f"checkpoint {cid!r} not in store")
                with self._cv:
                    self.remote_bytes_read += len(data)
                    if count_hit:
                        self.remote_hits += 1
                self._promote(cid, data)
                return data
        raise KeyError(f"checkpoint {cid!r} not in store")

    def _promote(self, cid: str, data: bytes) -> None:
        """Write a remote-fetched blob back to the disk tier (the remote
        copy stays — it is the replica)."""
        path = self._path(cid)
        tmp = f"{path}.promote.{threading.get_ident()}.tmp"
        with open(tmp, "wb") as f:
            f.write(data)
        with self._cv:
            os.replace(tmp, path)
            prev = self._disk_cids.pop(cid, None)
            if prev is not None:
                self._disk_bytes -= prev
            self._disk_cids[cid] = len(data)
            self._disk_bytes += len(data)
            self.tier_promotions += 1
        self._demote_excess()

    # ----------------------------------------------------------- disk read
    @staticmethod
    def _parse_header(data: bytes) -> Tuple[dict, int]:
        """(header dict, offset of the treedef pickle).  Raises KeyError
        for blobs this format cannot read (legacy v1 files degrade to
        recompute-on-miss instead of crashing)."""
        hlen = int.from_bytes(data[:8], "little")
        try:
            hdr = json.loads(data[8:8 + hlen])
        except Exception:
            raise KeyError("unreadable checkpoint header")
        if not isinstance(hdr, dict) or hdr.get("v") != BLOB_FORMAT:
            raise KeyError(
                f"checkpoint blob format {hdr.get('v') if isinstance(hdr, dict) else '?'}"
                f" != {BLOB_FORMAT}")
        return hdr, 8 + hlen

    def _read_header(self, cid: str) -> dict:
        """Header only (no payload decode) — delta-encoding recovery."""
        with open(self._path(cid), "rb") as f:
            hlen = int.from_bytes(f.read(8), "little")
            hdr, _ = self._parse_header(
                hlen.to_bytes(8, "little") + f.read(hlen))
        return hdr

    def _leaf_buffers(self, cid: str, depth_left: int,
                      count_hit: bool = False) -> List:
        """Raw per-leaf byte buffers of ``cid``, resolving delta chains
        recursively (bounded by ``depth_left``)."""
        if depth_left < 0:
            raise KeyError(f"delta chain under {cid!r} exceeds the depth "
                           "bound — refusing to recurse")
        data = self._fetch_blob(cid, count_hit=count_hit)
        hdr, off = self._parse_header(data)
        payload = memoryview(data)[off + hdr["tree_len"]:]
        if hdr["kind"] == "full":
            out, pos = [], 0
            for leaf in hdr["leaves"]:
                out.append(payload[pos:pos + leaf["n"]])
                pos += leaf["n"]
            return out
        parent_bufs = self._leaf_buffers(hdr["parent"], depth_left - 1)
        out, pos = [], 0
        for i, leaf in enumerate(hdr["leaves"]):
            buf = bytearray(leaf["n"])
            loff = 0
            for h, n, inline in leaf["c"]:
                if inline:
                    buf[loff:loff + n] = payload[pos:pos + n]
                    pos += n
                else:
                    buf[loff:loff + n] = parent_bufs[i][loff:loff + n]
                loff += n
            out.append(buf)
        return out

    def _read_disk(self, cid: str) -> Any:
        """Reconstruct the pytree of ``cid`` from the serialized tiers
        (delta chains resolved against ancestors; leaves are zero-copy
        ``np.frombuffer`` views over the blob payload)."""
        data = self._fetch_blob(cid, count_hit=True)
        hdr, off = self._parse_header(data)
        treedef = pickle.loads(data[off:off + hdr["tree_len"]])
        payload = memoryview(data)[off + hdr["tree_len"]:]
        if hdr["kind"] == "full":
            bufs, pos = [], 0
            for leaf in hdr["leaves"]:
                bufs.append(payload[pos:pos + leaf["n"]])
                pos += leaf["n"]
        else:
            parent_bufs = self._leaf_buffers(hdr["parent"],
                                             self.max_delta_depth)
            bufs, pos = [], 0
            for i, leaf in enumerate(hdr["leaves"]):
                buf = bytearray(leaf["n"])
                loff = 0
                for h, n, inline in leaf["c"]:
                    if inline:
                        buf[loff:loff + n] = payload[pos:pos + n]
                        pos += n
                    else:
                        buf[loff:loff + n] = parent_bufs[i][loff:loff + n]
                    loff += n
                bufs.append(buf)
        leaves = []
        for leaf, buf in zip(hdr["leaves"], bufs):
            dt = np.dtype(leaf["d"])
            arr = np.frombuffer(buf, dtype=dt,
                                count=leaf["n"] // dt.itemsize)
            # the reconstruction is shared via the read cache: read-only
            # leaves keep in-place mutation from corrupting the cached copy
            # every later get(cid) would serve (trainers are functional —
            # they return new state — so nothing needs writable leaves)
            arr.flags.writeable = False
            leaves.append(arr.reshape(leaf["s"]))
        return jax.tree_util.tree_unflatten(treedef, leaves)
