"""Content-addressed checkpoint store (the GlusterFS analogue, §5 / §4.1).

Checkpoints are arbitrary pytrees (model params, optimizer state, data
pipeline cursor, PRNG key, simulated-trainer state, ...) addressed by the
*computation that produced them*: ``key = (search-plan path hash, step)``.
Any two trials — in the same study or different studies — whose
hyper-parameter values coincide up to ``step`` resolve to the same key and
therefore share the checkpoint, which is the entire reuse mechanism.

Two backends:

* in-memory (default) — for tests, simulation and single-process studies;
* directory spill     — ``.npz``-serialized leaves + JSON treedef, the
  layout a real deployment would put on a distributed file system.

Beyond-paper: reference-counted eviction (``evict``) with
recompute-on-miss handled upstream (the engine simply re-derives the stage
from the search plan if a resume checkpoint is gone).
"""

from __future__ import annotations

import io
import json
import os
from typing import Any, Dict, List, Optional, Sequence, Tuple

import numpy as np

try:  # jax is always present in this repo, but the store works without it
    import jax
    _HAVE_JAX = True
except Exception:  # pragma: no cover
    _HAVE_JAX = False

__all__ = ["CheckpointStore", "stack_pytrees", "unstack_pytree"]


def _tree_flatten(tree: Any):
    if _HAVE_JAX:
        leaves, treedef = jax.tree_util.tree_flatten(tree)
        return leaves, treedef
    raise RuntimeError("jax required for pytree checkpoints")


# ---------------------------------------------------------------------------
# stacked-trial helpers (sibling batching)
# ---------------------------------------------------------------------------


def stack_pytrees(trees: Sequence[Any]) -> Any:
    """Stack structurally-identical array pytrees along a new leading axis
    (trial axis of a batched sibling group)."""
    import jax.numpy as jnp
    return jax.tree.map(lambda *xs: jnp.stack(xs), *trees)


def unstack_pytree(tree: Any, n: int) -> List[Any]:
    """Split a leading-axis-stacked pytree back into ``n`` per-trial pytrees
    (the inverse of :func:`stack_pytrees`)."""
    return [jax.tree.map(lambda x, g=g: x[g], tree) for g in range(n)]


class CheckpointStore:
    """put/get pytrees by (path_key, step); optionally spill to a directory."""

    def __init__(self, directory: Optional[str] = None):
        self.directory = directory
        if directory:
            os.makedirs(directory, exist_ok=True)
        self._mem: Dict[str, Any] = {}
        self.bytes_written = 0
        self.puts = 0
        self.gets = 0
        self.hits = 0

    # -------------------------------------------------------------- keys
    @staticmethod
    def ckpt_id(path_key: str, step: int) -> str:
        return f"{path_key}@{step}"

    # --------------------------------------------------------------- put
    def put(self, path_key: str, step: int, tree: Any) -> str:
        cid = self.ckpt_id(path_key, step)
        self.puts += 1
        if cid in self._mem or (self.directory and os.path.exists(self._path(cid))):
            return cid  # content already produced by a sibling — dedup
        if self.directory:
            self._write_disk(cid, tree)
        else:
            self._mem[cid] = tree
        return cid

    def put_stacked(self, entries: Sequence[Tuple[str, int, Any]]) -> List[str]:
        """Deposit the unstacked results of one batched sibling execution:
        ``entries`` is ``[(path_key, step, state), ...]`` — one per group
        member.  Content addressing dedups exactly as per-stage ``put``."""
        return [self.put(path_key, step, state)
                for path_key, step, state in entries]

    # --------------------------------------------------------------- get
    def get(self, cid: str) -> Any:
        self.gets += 1
        if cid in self._mem:
            self.hits += 1
            return self._mem[cid]
        if self.directory:
            p = self._path(cid)
            if os.path.exists(p):
                self.hits += 1
                return self._read_disk(cid)
        raise KeyError(f"checkpoint {cid!r} not in store")

    def contains(self, cid: str) -> bool:
        return cid in self._mem or (
            self.directory is not None and os.path.exists(self._path(cid)))

    # ------------------------------------------------------------- evict
    def evict(self, cid: str) -> bool:
        if cid in self._mem:
            del self._mem[cid]
            return True
        if self.directory:
            p = self._path(cid)
            if os.path.exists(p):
                os.remove(p)
                return True
        return False

    def __len__(self) -> int:
        n = len(self._mem)
        if self.directory:
            n += sum(1 for f in os.listdir(self.directory) if f.endswith(".ckpt"))
        return n

    # ---------------------------------------------------------- disk I/O
    def _path(self, cid: str) -> str:
        safe = cid.replace("/", "_")
        return os.path.join(self.directory, safe + ".ckpt")

    def _write_disk(self, cid: str, tree: Any) -> None:
        leaves, treedef = _tree_flatten(tree)
        buf = io.BytesIO()
        arrs = {f"leaf{i}": np.asarray(x) for i, x in enumerate(leaves)}
        np.savez(buf, **arrs)
        payload = buf.getvalue()
        meta = json.dumps({"treedef": str(treedef), "n": len(leaves)})
        with open(self._path(cid), "wb") as f:
            header = meta.encode("utf-8")
            f.write(len(header).to_bytes(8, "little"))
            f.write(header)
            f.write(payload)
        # treedef structure is re-derivable only with the original aux data;
        # store a pickled treedef alongside for exact reconstruction.
        import pickle
        with open(self._path(cid) + ".tree", "wb") as f:
            pickle.dump(treedef, f)
        self.bytes_written += len(payload)

    def _read_disk(self, cid: str) -> Any:
        import pickle
        with open(self._path(cid), "rb") as f:
            hlen = int.from_bytes(f.read(8), "little")
            f.read(hlen)  # meta (informational)
            payload = f.read()
        with open(self._path(cid) + ".tree", "rb") as f:
            treedef = pickle.load(f)
        with np.load(io.BytesIO(payload)) as z:
            leaves = [z[f"leaf{i}"] for i in range(len(z.files))]
        return jax.tree_util.tree_unflatten(treedef, leaves)
