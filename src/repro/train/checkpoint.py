"""Content-addressed checkpoint store (the GlusterFS analogue, §5 / §4.1).

Checkpoints are arbitrary pytrees (model params, optimizer state, data
pipeline cursor, PRNG key, simulated-trainer state, ...) addressed by the
*computation that produced them*: ``key = (search-plan path hash, step)``.
Any two trials — in the same study or different studies — whose
hyper-parameter values coincide up to ``step`` resolve to the same key and
therefore share the checkpoint, which is the entire reuse mechanism.

Two backends:

* in-memory (default) — for tests, simulation and single-process studies;
* directory spill     — ``.npz``-serialized leaves + JSON treedef, the
  layout a real deployment would put on a distributed file system.

Write-behind layer (chain-fused execution): :meth:`put_async` records the
checkpoint in a device-resident *pending* cache and hands the commit
(host transfer + serialization + disk write) to a background writer
thread, so stage boundaries inside a fused chain never stall on
checkpoint I/O.  Pending entries are indistinguishable from committed
ones to every reader — ``get`` / ``contains`` / ``__len__`` serve them,
and ``evict`` cancels them (a kill that races an in-flight write discards
the write instead of leaking the file).  :meth:`flush` is the barrier:
it blocks until every pending write has committed (engine shutdown, and
anything that needs the bytes durably on disk).

Directory-backend read path: a bounded LRU cache keeps the most recently
``get``-ed trees deserialized (repeated resumes of a hot checkpoint no
longer re-read and re-unpickle the ``.npz`` each time), ``bytes_read``
counts actual disk traffic, and the ``__len__`` disk scan is cached and
maintained incrementally instead of re-running ``os.listdir`` per call.

Beyond-paper: reference-counted eviction (``evict``) with
recompute-on-miss handled upstream (the engine simply re-derives the stage
from the search plan if a resume checkpoint is gone).
"""

from __future__ import annotations

import io
import json
import os
import threading
from collections import OrderedDict, deque
from typing import Any, Dict, List, Optional, Sequence

import numpy as np

try:  # jax is always present in this repo, but the store works without it
    import jax
    _HAVE_JAX = True
except Exception:  # pragma: no cover
    _HAVE_JAX = False

__all__ = ["CheckpointStore", "stack_pytrees", "unstack_pytree"]


def _tree_flatten(tree: Any):
    if _HAVE_JAX:
        leaves, treedef = jax.tree_util.tree_flatten(tree)
        return leaves, treedef
    raise RuntimeError("jax required for pytree checkpoints")


# ---------------------------------------------------------------------------
# stacked-trial helpers (sibling batching)
# ---------------------------------------------------------------------------


def stack_pytrees(trees: Sequence[Any]) -> Any:
    """Stack structurally-identical array pytrees along a new leading axis
    (trial axis of a batched sibling group)."""
    import jax.numpy as jnp
    return jax.tree.map(lambda *xs: jnp.stack(xs), *trees)


def unstack_pytree(tree: Any, n: int) -> List[Any]:
    """Split a leading-axis-stacked pytree back into ``n`` per-trial pytrees
    (the inverse of :func:`stack_pytrees`)."""
    return [jax.tree.map(lambda x, g=g: x[g], tree) for g in range(n)]


class CheckpointStore:
    """put/get pytrees by (path_key, step); optionally spill to a directory.

    ``read_cache_entries`` bounds the directory backend's LRU read cache
    (0 disables it); the in-memory backend needs no cache.
    """

    def __init__(self, directory: Optional[str] = None,
                 read_cache_entries: int = 32):
        self.directory = directory
        if directory:
            os.makedirs(directory, exist_ok=True)
        self._mem: Dict[str, Any] = {}
        self.bytes_written = 0
        self.bytes_read = 0
        self.puts = 0
        self.async_puts = 0
        self.gets = 0
        self.hits = 0
        # ---- write-behind state (all guarded by _cv's lock) ----
        self._pending: Dict[str, Any] = {}   # cid -> tree awaiting commit
        self._work: deque = deque()          # commit order
        self._cancelled: set = set()         # evicted while commit in flight
        self._cv = threading.Condition()
        self._writer: Optional[threading.Thread] = None
        self._write_error: Optional[BaseException] = None
        # ---- directory read path ----
        self.read_cache_entries = int(read_cache_entries)
        self._read_cache: "OrderedDict[str, Any]" = OrderedDict()
        self._disk_count: Optional[int] = None   # cached __len__ scan

    # -------------------------------------------------------------- keys
    @staticmethod
    def ckpt_id(path_key: str, step: int) -> str:
        return f"{path_key}@{step}"

    # --------------------------------------------------------------- put
    def put(self, path_key: str, step: int, tree: Any) -> str:
        cid = self.ckpt_id(path_key, step)
        self.puts += 1
        if self._revoke_or_dedup(cid):
            return cid  # content already produced by a sibling — dedup
        if self.directory:
            self._write_disk(cid, tree)
        else:
            self._mem[cid] = tree
        return cid

    def put_async(self, path_key: str, step: int, tree: Any) -> str:
        """Write-behind ``put``: the tree enters the pending cache (served
        to readers immediately) and the commit — host transfer, serialize,
        disk write — happens on the background writer thread.  Returns the
        cid exactly like :meth:`put`; :meth:`flush` is the durability
        barrier."""
        cid = self.ckpt_id(path_key, step)
        self.puts += 1
        if self._revoke_or_dedup(cid):
            return cid
        with self._cv:
            self._pending[cid] = tree
            self._work.append(cid)
            self.async_puts += 1
            if self._writer is None:
                self._writer = threading.Thread(
                    target=self._writer_loop, name="ckpt-writer", daemon=True)
                self._writer.start()
            self._cv.notify_all()
        return cid

    def _revoke_or_dedup(self, cid: str) -> bool:
        """True when ``cid`` is already held (pending / committed) and the
        put can dedup.  A cid whose in-flight commit was cancelled by an
        eviction is NOT deduped — its disk bytes are about to be undone —
        but the cancellation is revoked so the undo never happens to the
        re-deposited content (same cid == same content)."""
        with self._cv:
            if cid in self._pending:
                return True
            if cid in self._cancelled:
                self._cancelled.discard(cid)
                return False
        return cid in self._mem or (
            self.directory is not None and os.path.exists(self._path(cid)))

    def _known(self, cid: str) -> bool:
        with self._cv:
            if cid in self._pending:
                return True
            if cid in self._cancelled:
                # an in-flight commit of this content is being undone; its
                # disk bytes are untrustworthy until the undo lands
                return False
        return cid in self._mem or (
            self.directory is not None and os.path.exists(self._path(cid)))

    # --------------------------------------------------------- writer thread
    _IDLE_EXIT_SECONDS = 5.0   # idle writer threads retire themselves

    def _writer_loop(self) -> None:
        while True:
            with self._cv:
                while not self._work:
                    if not self._cv.wait(timeout=self._IDLE_EXIT_SECONDS):
                        if not self._work:
                            # idle too long: retire so the thread (and the
                            # store it pins) can be reclaimed; put_async
                            # spawns a fresh writer on the next deposit
                            self._writer = None
                            return
                cid = self._work.popleft()
                tree = self._pending.get(cid)
            if tree is None:
                continue  # superseded (a revoked re-put already committed)
            try:
                staged = (self._serialize_disk(cid, tree)
                          if self.directory else None)
            except BaseException as e:  # surfaced at the next flush()
                with self._cv:
                    self._write_error = e
                    self._pending.pop(cid, None)
                    self._cancelled.discard(cid)
                    self._cv.notify_all()
                continue
            with self._cv:
                try:
                    if cid in self._cancelled:
                        # evicted while serializing: the commit never
                        # publishes — the final path is untouched, only
                        # temps to discard
                        self._cancelled.discard(cid)
                        if staged is not None:
                            for tmp in staged[1:]:
                                os.remove(tmp)
                    else:
                        # publish + state transition in ONE critical
                        # section so __len__ never sees a cid as both
                        # pending and on disk
                        if staged is not None:
                            self._publish_disk(cid, *staged)
                        elif cid in self._pending:
                            self._mem[cid] = tree
                        self._pending.pop(cid, None)
                except BaseException as e:
                    # a publish/cancel failure must never strand the cid in
                    # _pending/_cancelled: flush() would deadlock instead
                    # of surfacing the error
                    self._write_error = e
                    self._pending.pop(cid, None)
                    self._cancelled.discard(cid)
                finally:
                    self._cv.notify_all()

    def flush(self) -> None:
        """Block until every pending write has committed and every
        cancelled in-flight commit has been undone.  Raises if the writer
        thread failed."""
        with self._cv:
            while self._pending or self._cancelled:
                self._cv.wait()
            if self._write_error is not None:
                err, self._write_error = self._write_error, None
                raise RuntimeError("checkpoint write-behind failed") from err

    @property
    def pending_writes(self) -> int:
        with self._cv:
            return len(self._pending)

    # --------------------------------------------------------------- get
    def get(self, cid: str) -> Any:
        self.gets += 1
        with self._cv:
            tree = self._pending.get(cid)
            cancelled = cid in self._cancelled
        if tree is not None:        # in-flight write: serve the live object
            self.hits += 1
            return tree
        if cancelled:               # evicted mid-commit: gone to readers
            raise KeyError(f"checkpoint {cid!r} not in store")
        if cid in self._mem:
            self.hits += 1
            return self._mem[cid]
        if self.directory:
            cached = self._read_cache.get(cid)
            if cached is not None:
                self._read_cache.move_to_end(cid)
                self.hits += 1
                return cached
            p = self._path(cid)
            if os.path.exists(p):
                try:
                    tree = self._read_disk(cid)
                except FileNotFoundError:
                    # concurrently evicted between exists() and open():
                    # missing, not corrupt — callers key recompute-on-miss
                    # off KeyError
                    raise KeyError(f"checkpoint {cid!r} not in store")
                self.hits += 1
                self._cache_read(cid, tree)
                return tree
        raise KeyError(f"checkpoint {cid!r} not in store")

    def contains(self, cid: str) -> bool:
        return self._known(cid)

    # ---------------------------------------------------- session persistence
    def committed_ids(self) -> set:
        """Ids of every durably-committed checkpoint (session snapshots:
        call :meth:`flush` first so nothing is left pending)."""
        with self._cv:
            ids = set(self._pending) - self._cancelled
        ids |= set(self._mem)
        if self.directory:
            ids |= {f[:-len(".ckpt")] for f in os.listdir(self.directory)
                    if f.endswith(".ckpt")}
        return ids

    def snapshot_trees(self) -> Optional[Dict[str, Any]]:
        """In-memory backend only: the committed cid→tree map, for
        embedding into a session snapshot (a directory backend returns
        None — its blobs are already durable on disk)."""
        return None if self.directory else dict(self._mem)

    def load_trees(self, trees: Dict[str, Any]) -> None:
        """Seed the in-memory backend from a session snapshot."""
        self._mem.update(trees)

    def _cache_read(self, cid: str, tree: Any) -> None:
        if self.read_cache_entries <= 0:
            return
        self._read_cache[cid] = tree
        self._read_cache.move_to_end(cid)
        while len(self._read_cache) > self.read_cache_entries:
            self._read_cache.popitem(last=False)

    # ------------------------------------------------------------- evict
    def evict(self, cid: str) -> bool:
        with self._cv:
            if cid in self._pending:
                del self._pending[cid]
                try:
                    # not yet picked up by the writer: nothing to undo
                    self._work.remove(cid)
                except ValueError:
                    # commit in flight: the writer undoes it on completion
                    self._cancelled.add(cid)
                self._cv.notify_all()
                return True
        self._read_cache.pop(cid, None)
        if cid in self._mem:
            del self._mem[cid]
            return True
        if self.directory and os.path.exists(self._path(cid)):
            self._remove_disk(cid)
            return True
        return False

    def __len__(self) -> int:
        # one critical section: publish + pending-removal are atomic on the
        # writer side, so a cid is never counted as both pending and on disk
        with self._cv:
            n = len(self._mem) + len(self._pending)
            if self.directory:
                if self._disk_count is None:
                    self._disk_count = sum(
                        1 for f in os.listdir(self.directory)
                        if f.endswith(".ckpt"))
                n += self._disk_count
        return n

    # ---------------------------------------------------------- disk I/O
    def _path(self, cid: str) -> str:
        safe = cid.replace("/", "_")
        return os.path.join(self.directory, safe + ".ckpt")

    def _write_disk(self, cid: str, tree: Any) -> None:
        staged = self._serialize_disk(cid, tree)
        with self._cv:   # counters/publish shared with the writer thread
            self._publish_disk(cid, *staged)

    def _serialize_disk(self, cid: str, tree: Any) -> tuple:
        """Serialize to thread-unique temp files (no lock held; the final
        path is untouched).  Returns ``(payload_len, tmp, tree_tmp)`` for
        :meth:`_publish_disk`."""
        leaves, treedef = _tree_flatten(tree)
        buf = io.BytesIO()
        arrs = {f"leaf{i}": np.asarray(x) for i, x in enumerate(leaves)}
        np.savez(buf, **arrs)
        payload = buf.getvalue()
        meta = json.dumps({"treedef": str(treedef), "n": len(leaves)})
        path = self._path(cid)
        tid = threading.get_ident()
        tmp, tree_tmp = f"{path}.{tid}.tmp", f"{path}.tree.{tid}.tmp"
        with open(tmp, "wb") as f:
            header = meta.encode("utf-8")
            f.write(len(header).to_bytes(8, "little"))
            f.write(header)
            f.write(payload)
        # treedef structure is re-derivable only with the original aux data;
        # store a pickled treedef alongside for exact reconstruction.
        import pickle
        with open(tree_tmp, "wb") as f:
            pickle.dump(treedef, f)
        return len(payload), tmp, tree_tmp

    def _publish_disk(self, cid: str, payload_len: int, tmp: str,
                      tree_tmp: str) -> None:
        """Atomically publish staged temp files (caller holds ``_cv``):
        rename the sidecar first and the payload last, so a crash (or the
        daemon writer being reaped at interpreter exit) can never leave a
        half-written file at the address readers probe with exists()."""
        path = self._path(cid)
        existed = os.path.exists(path)
        os.replace(tree_tmp, path + ".tree")
        os.replace(tmp, path)
        self.bytes_written += payload_len
        if self._disk_count is not None and not existed:
            self._disk_count += 1

    def _remove_disk(self, cid: str) -> None:
        os.remove(self._path(cid))
        tree_file = self._path(cid) + ".tree"
        if os.path.exists(tree_file):
            os.remove(tree_file)
        self._read_cache.pop(cid, None)
        with self._cv:
            if self._disk_count is not None:
                self._disk_count -= 1

    def _read_disk(self, cid: str) -> Any:
        import pickle
        with open(self._path(cid), "rb") as f:
            hlen = int.from_bytes(f.read(8), "little")
            f.read(hlen)  # meta (informational)
            payload = f.read()
        with open(self._path(cid) + ".tree", "rb") as f:
            treedef = pickle.load(f)
        with self._cv:
            self.bytes_read += len(payload)
        with np.load(io.BytesIO(payload)) as z:
            leaves = [z[f"leaf{i}"] for i in range(len(z.files))]
        return jax.tree_util.tree_unflatten(treedef, leaves)
