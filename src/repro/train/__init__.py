"""Training substrate: optimizers, checkpoint store, jitted step functions."""
