"""Canonical jitted step functions (train / prefill / serve) with sharding.

Used by the multi-pod dry-run and the launcher.  The train step is the
full production update: loss (with MoE aux), grads, AdamW update — all
hyper-parameters as traced scalars (the Hippo requirement), parameters and
optimizer state sharded per :mod:`repro.dist.sharding`.
"""

from __future__ import annotations

import functools
from typing import Any, Dict, Optional, Tuple

import jax
import jax.numpy as jnp
from jax.sharding import NamedSharding, PartitionSpec as P

from repro.dist.sharding import (ShardingRules, batch_specs, cache_specs,
                                 param_specs, seq_constrainer)
from repro.models.config import ModelConfig
from repro.models.transformer import LM
from repro.train.optimizer import apply_update, init_opt_state

__all__ = ["build_train_step", "build_prefill_step", "build_serve_step",
           "shardings_for", "seq_constrainer"]


def shardings_for(mesh, tree_of_specs):
    """Spec tree → ``NamedSharding`` tree on ``mesh`` (P leaves preserved)."""
    return jax.tree.map(lambda s: NamedSharding(mesh, s), tree_of_specs,
                        is_leaf=lambda x: isinstance(x, P))


def build_train_step(model: LM, optimizer: str = "adamw"):
    """(params, opt, batch, step) → (params, opt, loss).  hp scalars are
    closed over as traced defaults — lr enters as an argument so one
    executable serves every stage."""

    def train_step(params, opt, batch, lr, step):
        def loss_fn(p):
            loss, metrics = model.loss(p, batch)
            return loss, metrics

        (loss, _), grads = jax.value_and_grad(loss_fn, has_aux=True)(params)
        hp = {"lr": lr, "wd": 0.1, "b1": 0.9, "b2": 0.95}
        params, opt = apply_update(optimizer, params, grads, opt, hp, step)
        return params, opt, loss

    return train_step


def build_prefill_step(model: LM):
    def prefill_step(params, batch):
        logits, _ = model.forward(params, batch)
        # serving returns only the last-position logits (next-token)
        return logits[:, -1]

    return prefill_step


def build_serve_step(model: LM):
    def serve_step(params, cache, tokens, index):
        logits, cache = model.decode_step(params, cache, tokens, index)
        next_tok = jnp.argmax(logits[:, -1], axis=-1).astype(jnp.int32)
        return next_tok, cache

    return serve_step
