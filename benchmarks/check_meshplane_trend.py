"""CI trend gate for the mesh plane (mirrors check_ckptplane_trend).

Compares the current ``BENCH_meshplane.json`` against the committed
baseline (``benchmarks/baseline_meshplane.json``) and fails when:

* any mesh row lost leaf bit-identity with the thread fleet
  (``bitwise_identical`` false) — sharded execution that drifts is
  corruption, not a perf trade;
* a mesh row stopped handing off device-to-device (``d2d_handoffs`` 0)
  or touched the store's read tiers (``store_read_hits`` > 0) — the
  same-host boundary handoff must perform zero store round-trips;
* ``steps_run`` differs across fleets within a group width — the stage
  forest and schedule are fleet-invariant by construction;
* a width-1 mesh fleet falls below ``MESH1_RATE_FLOOR`` of the thread
  fleet's throughput — width-1 meshes are pure bookkeeping and must stay
  near parity;
* a sharded fleet's throughput *relative to the thread fleet on the same
  machine* regresses more than ``RATE_THRESHOLD`` vs the baseline's
  relative throughput (absolute rates are machine-speed; the ratio
  tracks the plane's own overhead).

Usage: ``python benchmarks/check_meshplane_trend.py [current] [baseline]``.
"""

from __future__ import annotations

import json
import sys

MESH1_RATE_FLOOR = 0.5   # min mesh1 throughput as a fraction of threads
RATE_THRESHOLD = 3.0     # max relative-throughput regression vs baseline


def _row(rows, fleet: str, width: int) -> dict:
    for r in rows:
        if r["fleet"] == fleet and r["group_width"] == width:
            return r
    raise SystemExit(f"benchmark row ({fleet}, width {width}) missing")


def main(current_path: str = "BENCH_meshplane.json",
         baseline_path: str = "benchmarks/baseline_meshplane.json") -> None:
    with open(current_path) as f:
        cur = json.load(f)["rows"]
    with open(baseline_path) as f:
        base = json.load(f)["rows"]

    widths = sorted({r["group_width"] for r in cur})
    mesh_fleets = sorted({r["fleet"] for r in cur if r["fleet"] != "threads"})

    # ---- losslessness + handoff invariants: non-negotiable on every row
    for r in cur:
        where = f"{r['fleet']} x{r['group_width']}"
        if not r.get("bitwise_identical"):
            raise SystemExit(
                f"{where}: leaves are NOT bit-identical to the thread "
                "fleet — the sharded path is corrupting")
        if r["fleet"] == "threads":
            continue
        if r["d2d_handoffs"] <= 0:
            raise SystemExit(f"{where}: no device-to-device handoff — "
                             "resumes went through the store")
        if r["store_read_hits"] > 0:
            raise SystemExit(
                f"{where}: {r['store_read_hits']} store reads — same-host "
                "handoff must perform zero store round-trips")
    print("bit-identity + zero-read d2d handoff OK on all rows")

    # ---- the forest and schedule are fleet-invariant
    for w in widths:
        steps = {r["steps_run"] for r in cur if r["group_width"] == w}
        if len(steps) != 1:
            raise SystemExit(
                f"width {w}: steps_run differs across fleets ({steps}) — "
                "mesh placement changed the schedule")
    print("fleet-invariant schedules OK")

    # ---- width-1 meshes are bookkeeping: near-parity with threads
    for w in widths:
        rate = _row(cur, "mesh1", w)["rate_vs_threads"]
        print(f"mesh1 x{w}: {rate}x thread throughput "
              f"(floor {MESH1_RATE_FLOOR})")
        if rate < MESH1_RATE_FLOOR:
            raise SystemExit(
                f"mesh1 x{w}: width-1 mesh fleet runs at {rate}x the "
                f"thread fleet (floor {MESH1_RATE_FLOOR}) — the default "
                "path is paying for the mesh plane")

    # ---- sharded overhead, tracked relative to threads on each machine
    for fleet in mesh_fleets:
        for w in widths:
            cur_rel = _row(cur, fleet, w)["rate_vs_threads"]
            base_rel = _row(base, fleet, w)["rate_vs_threads"]
            ratio = base_rel / max(cur_rel, 1e-9)
            print(f"{fleet} x{w}: relative throughput {cur_rel} vs "
                  f"baseline {base_rel} -> regression x{ratio:.2f} "
                  f"(limit {RATE_THRESHOLD:.1f})")
            if ratio > RATE_THRESHOLD:
                raise SystemExit(
                    f"{fleet} x{w}: relative throughput regressed "
                    f"{ratio:.2f}x vs the committed baseline "
                    f"(limit {RATE_THRESHOLD:.1f}x)")
    print("trend OK")


if __name__ == "__main__":
    argv = sys.argv[1:]
    main(*(argv[:2]))
