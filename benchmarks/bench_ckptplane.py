"""Checkpoint plane v2 benchmark: bytes written + commit wall on a
sibling-heavy stage forest.

Builds the workload the delta layer is designed for: a depth-D stage tree
where every node forks B siblings, each sibling's state mutating only a
fraction of its parent's parameters (the shared-prefix structure stage
trees guarantee — siblings differ by the few steps since the fork).  The
same forest of states is committed through

* ``full``  — every checkpoint serialized in full (``parent_cid`` never
  passed; the pre-delta behavior), and
* ``delta`` — each child committed with its fork-point parent cid, so
  unchanged chunks are stored as references,

both over the v2 zero-copy single-file serializer, plus a ``delta+pool``
row with the process-pool serializer.  Reports physical bytes written,
the dedup ratio (logical/physical), and commit wall (puts + flush).  The
``restore_identical`` flag asserts in-bench that every delta-encoded
checkpoint reads back bit-identical to its full-serialization twin —
compression that loses bits would be worse than no compression.

Rows land in ``BENCH_ckptplane.json`` via ``benchmarks/run.py`` and are
gated by ``check_ckptplane_trend.py`` (dedup floor + commit-wall
regression vs the committed baseline).
"""

from __future__ import annotations

import json
import tempfile
import time
from typing import Dict, List, Optional, Tuple

import numpy as np

from repro.train.checkpoint import CheckpointStore

DEPTH = 3            # stage levels below the root
BRANCH = 3           # siblings forked at every boundary
STATE_BYTES = 1 << 20        # ~1 MiB per state (two leaves)
MUTATE_FRAC = 0.25   # fraction of the big leaf a stage advance touches


def build_forest(depth: int = DEPTH, branch: int = BRANCH,
                 state_bytes: int = STATE_BYTES,
                 mutate_frac: float = MUTATE_FRAC):
    """(node_id, parent_id | None, state) in commit order (parents first).

    States are two-leaf pytrees (~``state_bytes``); each child copies its
    parent and perturbs a distinct ``mutate_frac`` slice of the big leaf —
    the sibling-divergence pattern of a stage tree (same fork point,
    different few-step suffixes).
    """
    n = state_bytes // 8  # two float32 leaves of n and n//63 elements
    rng = np.random.default_rng(0)
    root = {"w": rng.standard_normal(n * 2 - n // 8).astype(np.float32),
            "opt": rng.standard_normal(n // 8).astype(np.float32)}
    nodes: List[Tuple[str, Optional[str], Dict[str, np.ndarray]]] = [
        ("n0", None, root)]
    frontier = [("n0", root)]
    for d in range(depth):
        nxt = []
        for pid, pstate in frontier:
            for b in range(branch):
                w = pstate["w"].copy()
                span = int(len(w) * mutate_frac)
                off = (b * span) % max(1, len(w) - span)
                w[off:off + span] += np.float32(0.01 * (b + 1) * (d + 1))
                opt = pstate["opt"].copy()
                opt[: len(opt) // 4] *= np.float32(0.9)
                nid = f"{pid}.{b}"
                state = {"w": w, "opt": opt}
                nodes.append((nid, pid, state))
                nxt.append((nid, state))
        frontier = nxt
    return nodes


def commit_forest(nodes, use_delta: bool, directory: str,
                  serializer_procs: int = 0):
    """Commit every forest node write-behind; returns (store, wall)."""
    store = CheckpointStore(directory, serializer_procs=serializer_procs)
    cids: Dict[str, str] = {}
    t0 = time.perf_counter()
    for nid, pid, state in nodes:
        parent = cids.get(pid) if (use_delta and pid is not None) else None
        cids[nid] = store.put_async(nid, 0, state, parent_cid=parent)
    store.flush()
    wall = time.perf_counter() - t0
    store.close()
    return store, cids, wall


def verify_restores(nodes, store: CheckpointStore, cids: Dict[str, str],
                    sample: int = 0) -> bool:
    """Bit-identity of restored states vs the in-memory originals (every
    node when ``sample`` is 0, else every ``sample``-th)."""
    store._read_cache.clear()
    for i, (nid, _, state) in enumerate(nodes):
        if sample and i % sample:
            continue
        got = store.get(cids[nid])
        for k in state:
            if np.asarray(got[k]).tobytes() != state[k].tobytes():
                return False
    return True


def main(csv: bool = True):
    nodes = build_forest()
    logical = sum(s["w"].nbytes + s["opt"].nbytes for _, _, s in nodes)
    rows = []
    variants = [("full", False, 0), ("delta", True, 0),
                ("delta+pool", True, 2)]
    full_bytes = full_wall = None
    for label, use_delta, procs in variants:
        with tempfile.TemporaryDirectory() as d:
            store, cids, wall = commit_forest(nodes, use_delta, d,
                                              serializer_procs=procs)
            identical = verify_restores(nodes, store, cids)
        row = {
            "path": label,
            "nodes": len(nodes),
            "state_mb": round(logical / len(nodes) / 1e6, 2),
            "bytes_written": store.bytes_written,
            "dedup_ratio": round(store.dedup_ratio, 2),
            "delta_commits": store.delta_commits,
            "full_commits": store.full_commits,
            "commit_wall_s": round(wall, 3),
            "restore_identical": identical,
        }
        if label == "full":
            full_bytes, full_wall = store.bytes_written, wall
        else:
            row["bytes_reduction"] = round(full_bytes
                                           / store.bytes_written, 2)
            row["wall_vs_full"] = round(wall / full_wall, 2)
        rows.append(row)
        assert identical, f"{label}: delta restore diverged from original"
    delta = next(r for r in rows if r["path"] == "delta")
    assert delta["bytes_reduction"] >= 2.0, (
        f"delta encoding wrote only {delta['bytes_reduction']}x fewer "
        "bytes than full serialization on the sibling-heavy forest "
        "(acceptance floor 2.0x)")
    if csv:
        keys = ["path", "nodes", "state_mb", "bytes_written", "dedup_ratio",
                "delta_commits", "full_commits", "commit_wall_s",
                "bytes_reduction", "wall_vs_full", "restore_identical"]
        print(",".join(keys))
        for r in rows:
            print(",".join(str(r.get(k, "")) for k in keys))
    return rows


def dump_json(rows, path: str = "BENCH_ckptplane.json") -> None:
    with open(path, "w") as f:
        json.dump({"bench": "ckptplane", "rows": rows}, f, indent=2)
    print(f"[wrote {path}]")


if __name__ == "__main__":
    dump_json(main())
