"""CI perf-trend gate for the data plane (mirrors check_stagetree_trend).

Compares the current ``BENCH_dataplane.json`` against the committed
baseline (``benchmarks/baseline_dataplane.json``) and fails when:

* the fused throughput regresses more than ``2x`` — normalized by the
  ``stepwise`` row, a cache-free per-step workload that tracks overall
  machine speed, so raw steps/sec stay comparable across machines;
* the batched width rows stop being (noise-gated) monotone: each wider
  group must keep at least ``WIDTH_NOISE`` of the previous width's
  steps/sec, and ``trial_steps_per_dispatch`` — a hardware-independent
  count — must be strictly increasing;
* chain-fused execution at the deepest measured chain stops beating the
  per-stage dispatch loop by at least ``CHAIN_FLOOR`` (the committed
  baseline shows >= 1.5x; the floor leaves noise headroom).  The chain
  rows are gated ONLY on this same-machine ratio: both sides pay the
  same store/disk contention, so it stays meaningful under CI load where
  stepwise-normalized absolute throughput does not (the stepwise
  calibration is pure compute and cannot see I/O contention).

Usage: ``python benchmarks/check_dataplane_trend.py [current] [baseline]``.
"""

from __future__ import annotations

import json
import sys

THRESHOLD = 2.0      # max normalized throughput regression
WIDTH_NOISE = 0.6    # wider batched row may dip to 60% of the previous one
CHAIN_FLOOR = 1.25   # min chain-fused speedup over per-stage at max depth


def _row(rows, path: str) -> dict:
    for r in rows:
        if r["path"] == path:
            return r
    raise SystemExit(f"benchmark row {path!r} missing")


def _check_regression(cur, base, path: str, calib: float,
                      threshold: float) -> None:
    cur_sps = _row(cur, path)["steps_per_sec"] * calib
    base_sps = _row(base, path)["steps_per_sec"]
    ratio = base_sps / cur_sps
    print(f"{path}: {cur_sps:.0f} steps/s normalized vs baseline "
          f"{base_sps:.0f} -> ratio {ratio:.2f} (limit {threshold:.1f})")
    if ratio > threshold:
        raise SystemExit(
            f"perf regression: {path} throughput is {ratio:.2f}x below the "
            f"committed baseline (limit {threshold:.1f}x)")


def main(current_path: str = "BENCH_dataplane.json",
         baseline_path: str = "benchmarks/baseline_dataplane.json",
         threshold: float = THRESHOLD) -> None:
    with open(current_path) as f:
        cur = json.load(f)["rows"]
    with open(baseline_path) as f:
        base = json.load(f)["rows"]

    calib = (_row(base, "stepwise")["steps_per_sec"]
             / _row(cur, "stepwise")["steps_per_sec"])
    print(f"machine calibration x{calib:.2f} (stepwise row)")
    _check_regression(cur, base, "fused", calib, threshold)

    # ---- batched width rows: noise-gated monotone scaling
    widths = sorted((r for r in cur if r["path"].startswith("batched x")),
                    key=lambda r: r["width"])
    if len(widths) < 2:
        raise SystemExit("batched width rows missing")
    for a, b in zip(widths, widths[1:]):
        if b["steps_per_sec"] < a["steps_per_sec"] * WIDTH_NOISE:
            raise SystemExit(
                f"width scaling broke: {b['path']} at {b['steps_per_sec']} "
                f"steps/s vs {a['path']} at {a['steps_per_sec']} "
                f"(noise gate {WIDTH_NOISE})")
        if b["trial_steps_per_dispatch"] <= a["trial_steps_per_dispatch"]:
            raise SystemExit(
                f"{b['path']} trial_steps_per_dispatch must exceed "
                f"{a['path']}'s — dispatch amortization regressed")
    print(f"width rows monotone within noise gate {WIDTH_NOISE}: "
          + ", ".join(f"x{r['width']}={r['steps_per_sec']}" for r in widths))

    # ---- chain fusion: must keep beating per-stage dispatch at max depth
    chains = [r for r in cur if r["path"].startswith("chain_fused")]
    if not chains:
        raise SystemExit("chain_fused rows missing")
    deepest = max(chains, key=lambda r: r["depth"])
    sp = deepest["speedup_vs_perstage"]
    print(f"{deepest['path']}: {sp:.2f}x over per-stage dispatch "
          f"(floor {CHAIN_FLOOR:.2f})")
    if sp < CHAIN_FLOOR:
        raise SystemExit(
            f"chain fusion regressed: {sp:.2f}x over per-stage dispatch at "
            f"depth {deepest['depth']} (floor {CHAIN_FLOOR:.2f}x)")
    print("trend OK")


if __name__ == "__main__":
    argv = sys.argv[1:]
    main(*(argv[:2]))
