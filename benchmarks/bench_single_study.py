"""Single-study benchmark — the paper's Figure 12 / Table 5.

Runs each of the four studies under (a) trial-based execution (the
Ray Tune / Hippo-trial baseline: identical engine, merging disabled) and
(b) Hippo's stage-based execution, on a simulated 40-GPU cluster, and
reports GPU-hours, end-to-end time, and the savings ratios next to the
study's merge rate p.

Paper expectations validated here (EXPERIMENTS.md §Claims):
* grid-search GPU-hour saving ≈ p;
* SHA/ASHA savings exceed p (early-stopping concentrates the explored
  sub-space on high-merge prefixes);
* end-to-end ≤ GPU-hour saving (bounded by cluster parallelism).
"""

from __future__ import annotations

import math
import tempfile
from typing import Dict

from benchmarks.spaces import STUDIES
from repro.core import SearchPlanDB, Study, merge_rate
from repro.core.trainer import SimulatedTrainer
from repro.core.tuners import ASHATuner, GridTuner, SHATuner
from repro.train.checkpoint import CheckpointStore

N_WORKERS = 40                      # the paper's 40-GPU cluster
SEC_PER_STEP = 60.0                 # 1 epoch ≈ 1 virtual minute


def make_tuner(spec: Dict):
    trials = spec["space"]().trials(spec["max_steps"])
    if spec["algo"] == "grid":
        return GridTuner(trials)
    if spec["algo"] == "sha":
        return SHATuner(trials, min_steps=spec["min_steps"],
                        max_steps=spec["max_steps"], eta=spec["eta"])
    if spec["algo"] == "asha":
        return ASHATuner(trials, min_steps=spec["min_steps"],
                         max_steps=spec["max_steps"], eta=spec["eta"])
    raise ValueError(spec["algo"])


def run_study(name: str, spec: Dict, share: bool):
    db = SearchPlanDB()
    study = Study.create(db, name, "cifar10", ("lr", "bs"))
    backend = SimulatedTrainer(base_seconds_per_step=SEC_PER_STEP
                               / spec.get("gpus", 1),
                               horizon=spec["max_steps"],
                               lr0=spec.get("lr0", 0.1),
                               load_seconds=10.0, save_seconds=10.0,
                               eval_seconds=30.0)
    tuner = make_tuner(spec)
    # a real (directory) store so the storage columns measure physical
    # bytes: boundary checkpoints delta-encode against their fork points
    with tempfile.TemporaryDirectory() as d:
        stats = study.run(tuner, backend,
                          n_workers=spec.get("workers", N_WORKERS),
                          gpus_per_worker=spec.get("gpus", 1), share=share,
                          store=CheckpointStore(d))
    best = getattr(tuner, "best_score", None)
    if best is None or best == -math.inf:
        best = float("nan")
    return stats, best


def main(csv: bool = True):
    rows = []
    for name, spec in STUDIES.items():
        trials = spec["space"]().trials(spec["max_steps"])
        p = merge_rate(trials)
        trial_stats, trial_best = run_study(name, spec, share=False)
        stage_stats, stage_best = run_study(name, spec, share=True)
        rows.append({
            "study": name, "n_trials": len(trials), "p": round(p, 3),
            "gpuh_trial": round(trial_stats.gpu_hours, 2),
            "gpuh_stage": round(stage_stats.gpu_hours, 2),
            "gpuh_saving": round(trial_stats.gpu_seconds
                                 / stage_stats.gpu_seconds, 2),
            "e2e_trial_h": round(trial_stats.end_to_end / 3600, 2),
            "e2e_stage_h": round(stage_stats.end_to_end / 3600, 2),
            "e2e_saving": round(trial_stats.end_to_end
                                / stage_stats.end_to_end, 2),
            "best_trial": round(trial_best, 4),
            "best_stage": round(stage_best, 4),
            # real (wall) seconds spent in store puts/gets — the boundary
            # cost the chain-fused path hides behind write-behind saves
            "ckpt_save_s": round(stage_stats.ckpt_save_seconds, 3),
            "ckpt_load_s": round(stage_stats.ckpt_load_seconds, 3),
            # storage trajectory: physical bytes committed by the stage
            # run and its delta-dedup factor (logical/physical)
            "bytes_written": stage_stats.ckpt_bytes_written,
            "dedup_ratio": round(stage_stats.dedup_ratio, 2),
        })
    if csv:
        keys = list(rows[0])
        print(",".join(keys))
        for r in rows:
            print(",".join(str(r[k]) for k in keys))
    return rows


if __name__ == "__main__":
    main()
