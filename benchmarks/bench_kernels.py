"""Kernel benchmarks: Pallas (interpret on CPU) vs jnp reference.

On this CPU container the interesting column is max|Δ| (correctness);
wall times are reported for completeness but reflect the interpreter, not
TPU Mosaic codegen.
"""

from __future__ import annotations

import json
import time

import jax
import jax.numpy as jnp
import numpy as np

from repro.kernels.ops import flash_attention, ssd_intra
from repro.kernels.ref import attention_ref, ssd_intra_ref


def bench(fn, *args, n=3):
    out = fn(*args)
    jax.block_until_ready(out)
    best = float("inf")
    for _ in range(n):
        t0 = time.perf_counter()
        jax.block_until_ready(fn(*args))
        best = min(best, time.perf_counter() - t0)
    return best, out


def main(csv: bool = True):
    key = jax.random.PRNGKey(0)
    rows = []

    for (B, S, Hq, Hkv, hd) in [(1, 256, 8, 2, 64), (2, 512, 4, 1, 64)]:
        ks = jax.random.split(key, 3)
        q = jax.random.normal(ks[0], (B, S, Hq, hd))
        k = jax.random.normal(ks[1], (B, S, Hkv, hd))
        v = jax.random.normal(ks[2], (B, S, Hkv, hd))
        t_k, out = bench(lambda *a: flash_attention(*a, causal=True), q, k, v)
        t_r, ref = bench(lambda *a: attention_ref(*a, causal=True), q, k, v)
        rows.append({"kernel": "flash_attention",
                     "shape": f"B{B}S{S}H{Hq}/{Hkv}d{hd}",
                     "pallas_ms": round(t_k * 1e3, 2),
                     "ref_ms": round(t_r * 1e3, 2),
                     "max_abs_err": float(np.abs(np.asarray(out)
                                                 - np.asarray(ref)).max())})

    for (B, nc, Q, H, P, N) in [(1, 4, 64, 4, 32, 32), (2, 8, 32, 8, 16, 16)]:
        ks = jax.random.split(key, 5)
        xr = jax.random.normal(ks[0], (B, nc, Q, H, P))
        dtr = jax.nn.softplus(jax.random.normal(ks[1], (B, nc, Q, H)))
        ltT = -jnp.abs(jax.random.normal(ks[2], (B, nc, H, Q))) * 0.1
        Br = jax.random.normal(ks[3], (B, nc, Q, N))
        Cr = jax.random.normal(ks[4], (B, nc, Q, N))
        t_k, out = bench(ssd_intra, xr, dtr, ltT, Br, Cr)
        t_r, ref = bench(ssd_intra_ref, xr, dtr, ltT, Br, Cr)
        rows.append({"kernel": "ssd_intra",
                     "shape": f"B{B}c{nc}Q{Q}H{H}P{P}N{N}",
                     "pallas_ms": round(t_k * 1e3, 2),
                     "ref_ms": round(t_r * 1e3, 2),
                     "max_abs_err": float(np.abs(np.asarray(out)
                                                 - np.asarray(ref)).max())})

    if csv:
        keys = list(rows[0])
        print(",".join(keys))
        for r in rows:
            print(",".join(str(r[k]) for k in keys))
    return rows


def dump_json(rows, path: str = "BENCH_kernels.json") -> None:
    with open(path, "w") as f:
        json.dump({"bench": "kernels", "backend": jax.default_backend(),
                   "rows": rows}, f, indent=2)
    print(f"[wrote {path}]")


if __name__ == "__main__":
    dump_json(main())
