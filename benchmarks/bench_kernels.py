"""Kernel benchmarks: Pallas (interpret on CPU) vs jnp reference.

Covers the full kernel plane: forward kernels, the custom_vjp backward
kernels (via ``jax.grad`` so the measured path is exactly what training
runs), and the fused trial-stacked optimizer update.

On this CPU container the interesting columns are max|Δ| (correctness)
and ``fallbacks`` (must stay 0 — the kernel plane really ran); wall
times reflect the Pallas interpreter, not TPU Mosaic codegen, and
``pct_of_peak`` is therefore honest-but-tiny here.  The %-of-peak column
uses the same hardware model as :mod:`repro.analysis.roofline`:

    bound_s     = max(flops / HW.peak_flops, bytes / HW.hbm_bw)
    pct_of_peak = 100 * bound_s / measured_s

i.e. what fraction of the roofline-bound time the measured launch
achieves.  On TPU this is the number to watch; the CI gate
(``check_kernels_trend.py``) only requires the column to be present and
positive, plus correctness ceilings and zero fallbacks.
"""

from __future__ import annotations

import json
import time

import jax
import jax.numpy as jnp
import numpy as np

from repro.analysis.roofline import HW
from repro.kernels.ops import (KERNEL_STATS, flash_attention,
                               reset_kernel_stats, ssd_intra)
from repro.kernels.optim import fused_apply_update
from repro.kernels.ref import attention_ref, ssd_intra_ref
from repro.train.optimizer import apply_update, init_opt_state


def bench(fn, *args, n=3):
    out = fn(*args)
    jax.block_until_ready(out)
    best = float("inf")
    for _ in range(n):
        t0 = time.perf_counter()
        jax.block_until_ready(fn(*args))
        best = min(best, time.perf_counter() - t0)
    return best, out


def _max_err(a, b) -> float:
    la, lb = jax.tree.leaves(a), jax.tree.leaves(b)
    return max(float(np.abs(np.asarray(x) - np.asarray(y)).max())
               for x, y in zip(la, lb))


def _row(name, shape, t_k, t_r, err, flops, bytes_, fallbacks):
    bound_s = max(flops / HW["peak_flops"], bytes_ / HW["hbm_bw"])
    return {"kernel": name, "shape": shape,
            "pallas_ms": round(t_k * 1e3, 2),
            "ref_ms": round(t_r * 1e3, 2),
            "max_abs_err": err,
            "pct_of_peak": round(100.0 * bound_s / t_k, 6),
            "fallbacks": fallbacks}


# ---- analytic roofline numerators (f32 elements, 4 bytes) -----------------

def _fa_cost(B, S, Hq, Hkv, hd, causal=True, bwd=False):
    flops = 4.0 * B * Hq * S * S * hd * (0.5 if causal else 1.0)
    bytes_ = 4.0 * (2 * B * S * Hq * hd + 2 * B * S * Hkv * hd)
    if bwd:     # 5 matmuls vs 2; reads q,k,v,o,do + writes dq,dk,dv
        flops *= 2.5
        bytes_ *= 2.5
    return flops, bytes_


def _ssd_cost(B, nc, Q, H, P, N, bwd=False):
    flops = B * nc * H * (2.0 * Q * Q * (N + P) + 6.0 * Q * Q)
    bytes_ = 4.0 * (2 * B * nc * Q * H * P + 2 * B * nc * Q * H
                    + 2 * B * nc * Q * N)
    if bwd:     # datt/dx/dB/dC matmuls + fwd recompute
        flops *= 3.0
        bytes_ *= 2.0
    return flops, bytes_


def _opt_cost(name, M, L):
    n_arrays = {"sgd": 3, "momentum": 5, "adam": 7, "adamw": 7}[name]
    n_flops = {"sgd": 4, "momentum": 6, "adam": 14, "adamw": 14}[name]
    return float(n_flops * M * L), 4.0 * n_arrays * M * L


def main(csv: bool = True):
    key = jax.random.PRNGKey(0)
    rows = []
    reset_kernel_stats()

    # ---- flash attention forward
    for (B, S, Hq, Hkv, hd) in [(1, 256, 8, 2, 64), (2, 512, 4, 1, 64)]:
        ks = jax.random.split(key, 3)
        q = jax.random.normal(ks[0], (B, S, Hq, hd))
        k = jax.random.normal(ks[1], (B, S, Hkv, hd))
        v = jax.random.normal(ks[2], (B, S, Hkv, hd))
        fb0 = KERNEL_STATS.fallbacks
        t_k, out = bench(lambda *a: flash_attention(*a, causal=True), q, k, v)
        t_r, ref = bench(lambda *a: attention_ref(*a, causal=True), q, k, v)
        fl, by = _fa_cost(B, S, Hq, Hkv, hd)
        rows.append(_row("flash_attention_fwd", f"B{B}S{S}H{Hq}/{Hkv}d{hd}",
                         t_k, t_r, _max_err(out, ref), fl, by,
                         KERNEL_STATS.fallbacks - fb0))

    # ---- flash attention backward (the path jax.grad takes in training)
    for (B, S, Hq, Hkv, hd) in [(1, 128, 4, 2, 64)]:
        ks = jax.random.split(key, 3)
        q = jax.random.normal(ks[0], (B, S, Hq, hd))
        k = jax.random.normal(ks[1], (B, S, Hkv, hd))
        v = jax.random.normal(ks[2], (B, S, Hkv, hd))
        g_k = jax.jit(jax.grad(
            lambda *a: flash_attention(*a, causal=True).sum(),
            argnums=(0, 1, 2)))
        g_r = jax.jit(jax.grad(
            lambda *a: attention_ref(*a, causal=True).sum(),
            argnums=(0, 1, 2)))
        fb0 = KERNEL_STATS.fallbacks
        t_k, out = bench(g_k, q, k, v)
        t_r, ref = bench(g_r, q, k, v)
        fl, by = _fa_cost(B, S, Hq, Hkv, hd, bwd=True)
        rows.append(_row("flash_attention_bwd", f"B{B}S{S}H{Hq}/{Hkv}d{hd}",
                         t_k, t_r, _max_err(out, ref), fl, by,
                         KERNEL_STATS.fallbacks - fb0))

    # ---- ssd forward
    for (B, nc, Q, H, P, N) in [(1, 4, 64, 4, 32, 32), (2, 8, 32, 8, 16, 16)]:
        ks = jax.random.split(key, 5)
        xr = jax.random.normal(ks[0], (B, nc, Q, H, P))
        dtr = jax.nn.softplus(jax.random.normal(ks[1], (B, nc, Q, H)))
        ltT = -jnp.abs(jax.random.normal(ks[2], (B, nc, H, Q))) * 0.1
        Br = jax.random.normal(ks[3], (B, nc, Q, N))
        Cr = jax.random.normal(ks[4], (B, nc, Q, N))
        fb0 = KERNEL_STATS.fallbacks
        t_k, out = bench(ssd_intra, xr, dtr, ltT, Br, Cr)
        t_r, ref = bench(ssd_intra_ref, xr, dtr, ltT, Br, Cr)
        fl, by = _ssd_cost(B, nc, Q, H, P, N)
        rows.append(_row("ssd_intra_fwd", f"B{B}c{nc}Q{Q}H{H}P{P}N{N}",
                         t_k, t_r, _max_err(out, ref), fl, by,
                         KERNEL_STATS.fallbacks - fb0))

    # ---- ssd backward (all five cotangents)
    for (B, nc, Q, H, P, N) in [(1, 4, 64, 4, 32, 32)]:
        ks = jax.random.split(key, 5)
        xr = jax.random.normal(ks[0], (B, nc, Q, H, P))
        dtr = jax.nn.softplus(jax.random.normal(ks[1], (B, nc, Q, H)))
        ltT = -jnp.abs(jax.random.normal(ks[2], (B, nc, H, Q))) * 0.1
        Br = jax.random.normal(ks[3], (B, nc, Q, N))
        Cr = jax.random.normal(ks[4], (B, nc, Q, N))
        g_k = jax.jit(jax.grad(lambda *a: ssd_intra(*a).sum(),
                               argnums=(0, 1, 2, 3, 4)))
        g_r = jax.jit(jax.grad(lambda *a: ssd_intra_ref(*a).sum(),
                               argnums=(0, 1, 2, 3, 4)))
        fb0 = KERNEL_STATS.fallbacks
        t_k, out = bench(g_k, xr, dtr, ltT, Br, Cr)
        t_r, ref = bench(g_r, xr, dtr, ltT, Br, Cr)
        fl, by = _ssd_cost(B, nc, Q, H, P, N, bwd=True)
        rows.append(_row("ssd_intra_bwd", f"B{B}c{nc}Q{Q}H{H}P{P}N{N}",
                         t_k, t_r, _max_err(out, ref), fl, by,
                         KERNEL_STATS.fallbacks - fb0))

    # ---- fused trial-stacked optimizer update (vmapped over M members)
    M, L = 4, 4096
    for name in ("momentum", "adamw"):
        ks = jax.random.split(key, 2)
        params = {"w": jax.random.normal(ks[0], (M, L))}
        grads = {"w": jax.random.normal(ks[1], (M, L)) * 0.01}
        state = jax.vmap(lambda _: init_opt_state(
            name, {"w": jnp.zeros((L,))}))(jnp.arange(M))
        hp = {"lr": jnp.full((M,), 0.1), "wd": jnp.full((M,), 1e-4)}
        step = jnp.zeros((M,), jnp.int32)
        fused = jax.jit(jax.vmap(
            lambda p, g, s, h, t: fused_apply_update(name, p, g, s, h, t)))
        ref_fn = jax.jit(jax.vmap(
            lambda p, g, s, h, t: apply_update(name, p, g, s, h, t)))
        fb0 = KERNEL_STATS.fallbacks
        t_k, out = bench(fused, params, grads, state, hp, step)
        t_r, ref = bench(ref_fn, params, grads, state, hp, step)
        fl, by = _opt_cost(name, M, L)
        rows.append(_row(f"opt_update_{name}", f"M{M}L{L}",
                         t_k, t_r, _max_err(out, ref), fl, by,
                         KERNEL_STATS.fallbacks - fb0))

    if csv:
        keys = list(rows[0])
        print(",".join(keys))
        for r in rows:
            print(",".join(str(r[k]) for k in keys))
    return rows


def dump_json(rows, path: str = "BENCH_kernels.json") -> None:
    with open(path, "w") as f:
        json.dump({"bench": "kernels", "backend": jax.default_backend(),
                   "rows": rows}, f, indent=2)
    print(f"[wrote {path}]")


if __name__ == "__main__":
    dump_json(main())
