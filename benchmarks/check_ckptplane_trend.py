"""CI trend gate for the checkpoint plane (mirrors check_dataplane_trend).

Compares the current ``BENCH_ckptplane.json`` against the committed
baseline (``benchmarks/baseline_ckptplane.json``) and fails when:

* any row lost restore bit-identity (``restore_identical`` false) —
  compression that loses bits is corruption, not a perf trade;
* the delta row's ``bytes_reduction`` over full serialization drops below
  ``DEDUP_FLOOR`` (the acceptance criterion: the sibling-heavy forest
  must keep writing >= 2x fewer physical bytes than the full path);
* the delta commit wall regresses more than ``WALL_THRESHOLD`` vs the
  baseline, normalized by the ``full`` row — full serialization of the
  same forest is the machine-speed calibration (same disk, same CPU), so
  the gate tracks the *relative* cost of delta encoding, which stays
  meaningful on slower CI machines.

Usage: ``python benchmarks/check_ckptplane_trend.py [current] [baseline]``.
"""

from __future__ import annotations

import json
import sys

DEDUP_FLOOR = 2.0      # min bytes_reduction of delta vs full (acceptance)
WALL_THRESHOLD = 2.0   # max calibrated commit-wall regression


def _row(rows, path: str) -> dict:
    for r in rows:
        if r["path"] == path:
            return r
    raise SystemExit(f"benchmark row {path!r} missing")


def main(current_path: str = "BENCH_ckptplane.json",
         baseline_path: str = "benchmarks/baseline_ckptplane.json") -> None:
    with open(current_path) as f:
        cur = json.load(f)["rows"]
    with open(baseline_path) as f:
        base = json.load(f)["rows"]

    # ---- bit-identity: non-negotiable on every row
    for r in cur:
        if not r.get("restore_identical"):
            raise SystemExit(
                f"{r['path']}: restored checkpoints are NOT bit-identical "
                "to the committed states — the delta path is corrupting")
    print("restore bit-identity OK on all rows")

    # ---- dedup floor (the PR's acceptance criterion, kept as a gate)
    delta = _row(cur, "delta")
    print(f"delta: {delta['bytes_reduction']}x fewer bytes than full "
          f"(floor {DEDUP_FLOOR}), dedup_ratio {delta['dedup_ratio']}")
    if delta["bytes_reduction"] < DEDUP_FLOOR:
        raise SystemExit(
            f"delta encoding writes only {delta['bytes_reduction']}x fewer "
            f"bytes than full serialization (floor {DEDUP_FLOOR}x)")

    # ---- commit wall, calibrated by the full row on the same machine
    calib = (_row(base, "full")["commit_wall_s"]
             / max(_row(cur, "full")["commit_wall_s"], 1e-9))
    cur_wall = delta["commit_wall_s"] * calib
    base_wall = _row(base, "delta")["commit_wall_s"]
    ratio = cur_wall / max(base_wall, 1e-9)
    print(f"machine calibration x{calib:.2f} (full row); delta commit wall "
          f"{cur_wall:.3f}s calibrated vs baseline {base_wall:.3f}s "
          f"-> ratio {ratio:.2f} (limit {WALL_THRESHOLD:.1f})")
    if ratio > WALL_THRESHOLD:
        raise SystemExit(
            f"commit-wall regression: delta commits are {ratio:.2f}x the "
            f"committed baseline (limit {WALL_THRESHOLD:.1f}x)")
    print("trend OK")


if __name__ == "__main__":
    argv = sys.argv[1:]
    main(*(argv[:2]))
