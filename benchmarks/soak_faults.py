"""Fault-plane soak: K seeded fault schedules against a multi-study session.

Each seed drives a full fair-share session (two studies, staggered
arrival) through an aggressive schedule of worker crashes, transient
stage failures and store outages.  Per seed the soak asserts, in-bench:

* **completion** — every study finishes (no hang; the CI step additionally
  wraps the whole soak in a wall-clock ``timeout``);
* **losslessness** — every final leaf checkpoint is bitwise-identical to
  the fault-free reference run (faults move work around, they never
  change what it computes);
* **no quarantined-forever fleet** — quarantine is probation, not
  banishment: a session that ends with every worker quarantined would
  deadlock a longer workload.

Outputs:

* ``FAULT_SOAK.json``   — one row per seed (counters + wall time),
* ``FAULT_LOG_faults.jsonl`` — the concatenated deterministic fault logs
  (one JSON object per injected fault), uploaded as a CI artifact so a
  failing seed's schedule can be replayed exactly.
"""

from __future__ import annotations

import json
import time

SEEDS = tuple(range(6))
STEPS = 80
WORKERS = 4
RATES = dict(stage_fault_rate=0.25, crash_rate=0.15, outage_rate=0.03,
             outage_ops=2)
MAX_FAULTS = 64          # terminate even under pathological schedules


def _session(injector):
    from repro.core import SearchPlanDB, StudyService, StudySpec
    from repro.core.faults import raw_store
    from repro.core.hpseq import Constant, Exponential, StepLR, Warmup
    from repro.core.trainer import SimulatedTrainer
    from repro.core.tuners import GridSearchSpace, GridTuner

    space = GridSearchSpace(
        fns={"lr": [StepLR(0.1, 0.1, [30]), StepLR(0.1, 0.1, [40]),
                    Warmup(5, 0.1, Exponential(0.1, 0.95))],
             "bs": [Constant(64), Constant(128)]})
    spec = StudySpec("m", "d", ("lr", "bs"))
    svc = StudyService(SearchPlanDB(), SimulatedTrainer(horizon=STEPS),
                       n_workers=WORKERS, policy="fair_share",
                       fault_injector=injector)
    futures = [svc.submit(spec, GridTuner(space.trials(STEPS))),
               svc.submit(spec, GridTuner(space.trials(STEPS)[:4]),
                          at=200.0)]
    stats = svc.close()
    eng = svc._engine
    store = raw_store(eng.store)
    leaves = {}
    for nid, node in eng.plan.nodes.items():
        for step, cid in node.ckpts.items():
            if store.contains(cid):
                leaves[(nid, step)] = store.get(cid)
    return stats, leaves, futures, eng


def _leaves_equal(a, b):
    import numpy as np
    if set(a) != set(b):
        return False
    for k in a:
        if set(a[k]) != set(b[k]):
            return False
        for name in a[k]:
            if not np.array_equal(np.asarray(a[k][name]),
                                  np.asarray(b[k][name])):
                return False
    return True


def main():
    from repro.core import FaultInjector

    ref_stats, ref_leaves, ref_futures, _ = _session(None)
    assert all(f.done() for f in ref_futures)

    rows, fault_log = [], []
    for seed in SEEDS:
        inj = FaultInjector(seed, max_faults=MAX_FAULTS, **RATES)
        t0 = time.perf_counter()
        stats, leaves, futures, eng = _session(inj)
        wall = time.perf_counter() - t0

        assert all(f.done() for f in futures), f"seed {seed}: study hung"
        assert stats.steps_run == ref_stats.steps_run, \
            f"seed {seed}: {stats.steps_run} != {ref_stats.steps_run} steps"
        assert _leaves_equal(ref_leaves, leaves), \
            f"seed {seed}: leaves diverged from the fault-free run"
        stuck = [w.wid for w in eng.workers
                 if w.quarantined_until > eng.time]
        assert len(stuck) < len(eng.workers), \
            f"seed {seed}: whole fleet quarantined at session end"

        fault_log.extend(inj.log)
        rows.append({
            "seed": seed,
            "wall_s": round(wall, 3),
            "faults_injected": stats.faults_injected,
            "by_kind": dict(inj.by_kind),
            "stage_failures": stats.stage_failures,
            "stage_retries": stats.stage_retries,
            "workers_quarantined": stats.workers_quarantined,
            "groups_degraded": stats.groups_degraded,
            "wasted_gpu_seconds": stats.wasted_gpu_seconds,
            "retries_verified": inj.retries_verified,
            "steps_run": stats.steps_run,
            "lossless": True,
        })
        print(f"seed {seed}: {stats.faults_injected:3d} faults, "
              f"{stats.stage_retries:3d} retries, "
              f"{stats.workers_quarantined} quarantines, "
              f"{stats.wasted_gpu_seconds:7.1f} GPU-s wasted, "
              f"lossless, {wall:.2f}s wall")

    assert any(r["faults_injected"] for r in rows), \
        "soak injected zero faults across every seed — rates misconfigured"
    return {"rates": RATES, "max_faults": MAX_FAULTS, "steps": STEPS,
            "workers": WORKERS, "rows": rows, "fault_log": fault_log}


def dump_json(result, path="FAULT_SOAK.json",
              log_path="FAULT_LOG_faults.jsonl"):
    log = result.pop("fault_log")
    with open(log_path, "w") as f:
        for entry in log:
            f.write(json.dumps(entry) + "\n")
    with open(path, "w") as f:
        json.dump(result, f, indent=2)
    print(f"wrote {path} ({len(result['rows'])} seeds) and "
          f"{log_path} ({len(log)} fault records)")


if __name__ == "__main__":
    dump_json(main())
