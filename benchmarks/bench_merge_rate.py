"""Merge-rate table — the paper's Table 1 analogue.

Computes p for each single-study search space and pairwise/k-wise q for
the multi-study spaces.  Pure control-plane arithmetic (no simulation).
"""

from __future__ import annotations

from benchmarks.spaces import (STUDIES, resnet20_space_high_merge,
                               resnet20_space_low_merge)
from repro.core import k_wise_merge_rate, merge_rate


def main(csv: bool = True):
    rows = []
    for name, spec in STUDIES.items():
        trials = spec["space"]().trials(spec["max_steps"])
        rows.append({"space": name, "n_trials": len(trials),
                     "metric": "p", "value": round(merge_rate(trials), 3)})
    for label, fn in (("resnet20-high", resnet20_space_high_merge),
                      ("resnet20-low", resnet20_space_low_merge)):
        for S in (2, 4, 8):
            sets = [fn(seed=i).trials(160) for i in range(S)]
            rows.append({"space": label, "n_trials": sum(map(len, sets)),
                         "metric": f"q{S}",
                         "value": round(k_wise_merge_rate(sets), 3)})
    if csv:
        keys = list(rows[0])
        print(",".join(keys))
        for r in rows:
            print(",".join(str(r[k]) for k in keys))
    return rows


if __name__ == "__main__":
    main()
