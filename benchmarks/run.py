"""Benchmark harness entry point: one section per paper table/figure.

``PYTHONPATH=src python -m benchmarks.run`` runs everything and prints CSV
blocks; individual benches are importable modules with ``main()``.  The
control-plane rows land in ``BENCH_stagetree.json`` (gated against the
committed baseline by ``check_stagetree_trend.py``), the data-plane rows
in ``BENCH_dataplane.json`` (gated by ``check_dataplane_trend.py``), the
Pallas kernel rows in ``BENCH_kernels.json``, the checkpoint-plane rows
in ``BENCH_ckptplane.json`` (gated by ``check_ckptplane_trend.py``), the
mesh-plane fleet sweep in ``BENCH_meshplane.json`` (gated by
``check_meshplane_trend.py``), the front-door fleet comparison in
``BENCH_frontdoor.json`` (gated by ``check_frontdoor_trend.py``) and the
multi-study upfront/staggered rows in ``BENCH_multistudy.json``, so the
perf trajectory is tracked across PRs (CI uploads all seven as
artifacts).
"""

from __future__ import annotations

import json
import sys


def dump_stagetree_json(rows, path: str = "BENCH_stagetree.json") -> None:
    with open(path, "w") as f:
        json.dump({"bench": "stagetree", "rows": rows}, f, indent=2)
    print(f"[wrote {path}]")


def main() -> None:
    from benchmarks import (bench_ckptplane, bench_dataplane,
                            bench_frontdoor, bench_kernels,
                            bench_merge_rate, bench_meshplane,
                            bench_multi_study, bench_single_study,
                            bench_stagetree)

    sections = [
        ("merge-rate table (paper Table 1)", bench_merge_rate),
        ("control-plane microbench (§4.3 stateless scheduler)",
         bench_stagetree),
        ("data plane: per-step loop vs fused chunks vs batched siblings",
         bench_dataplane),
        ("kernel allclose + timing", bench_kernels),
        ("checkpoint plane: full vs delta-encoded commits on a "
         "sibling-heavy forest", bench_ckptplane),
        ("mesh plane: group-width x mesh-width fleet sweep + d2d handoff",
         bench_meshplane),
        ("front door: rebalanced shared fleet vs static partition",
         bench_frontdoor),
        ("single-study: trial vs stage (Figure 12 / Table 5)",
         bench_single_study),
        ("multi-study S1/S2/S4/S8 + staggered service (Figures 13-14)",
         bench_multi_study),
    ]
    for title, mod in sections:
        print(f"\n## {title}")
        sys.stdout.flush()
        rows = mod.main()
        if mod is bench_stagetree:
            dump_stagetree_json(rows)
        elif rows and hasattr(mod, "dump_json"):
            mod.dump_json(rows)


if __name__ == "__main__":
    main()
