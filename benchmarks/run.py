"""Benchmark harness entry point: one section per paper table/figure.

``PYTHONPATH=src python -m benchmarks.run`` runs everything and prints CSV
blocks; individual benches are importable modules with ``main()``.
"""

from __future__ import annotations

import sys


def main() -> None:
    from benchmarks import (bench_kernels, bench_merge_rate,
                            bench_multi_study, bench_single_study,
                            bench_stagetree)

    sections = [
        ("merge-rate table (paper Table 1)", bench_merge_rate),
        ("control-plane microbench (§4.3 stateless scheduler)",
         bench_stagetree),
        ("kernel allclose + timing", bench_kernels),
        ("single-study: trial vs stage (Figure 12 / Table 5)",
         bench_single_study),
        ("multi-study S1/S2/S4/S8 (Figures 13-14)", bench_multi_study),
    ]
    for title, mod in sections:
        print(f"\n## {title}")
        sys.stdout.flush()
        mod.main()


if __name__ == "__main__":
    main()
