"""Paper-faithful search spaces (Tables 2-4), scaled for the simulator.

Step counts are scaled (1 "step" = 1 epoch for the CIFAR studies, 100 BERT
steps) — merge rates and the trial/stage computation ratios are invariant
to the time unit, which is what the paper's tables measure.
"""

from __future__ import annotations

from repro.core.hpseq import (Constant, CosineWarmRestarts, Cyclic,
                              Exponential, Linear, MultiStep, Seq, StepLR,
                              Warmup)
from repro.core.tuners import GridSearchSpace

__all__ = ["resnet56_space", "mobilenetv2_space", "bert_space",
           "resnet20_space_high_merge", "resnet20_space_low_merge",
           "STUDIES"]


def resnet56_space() -> GridSearchSpace:
    """Table 2 families with the milestone/period variations that give the
    paper its 448-trial space: each family contributes several members
    sharing long prefixes (the same-family members diverge only at their
    first differing milestone)."""
    lr = [
        # StepLR family: shares [0, first milestone)
        StepLR(0.1, 0.1, [90, 135]),
        StepLR(0.1, 0.1, [100, 150]),
        StepLR(0.1, 0.1, [80, 120]),
        # warm-up + StepLR: all share the 5-step ramp, then [5, 5+m0)
        Warmup(5, 0.1, StepLR(0.1, 0.1, [90, 135])),
        Warmup(5, 0.1, StepLR(0.1, 0.1, [100, 150])),
        # warm-up + exponential: shares the ramp with the family above
        Warmup(5, 0.1, Exponential(0.1, 0.95)),
        Warmup(10, 0.1, CosineWarmRestarts(0.1, t_0=20)),
        Cyclic(0.001, 0.1, step_size_up=20),
    ]
    bs = [Constant(128), MultiStep(128, [70], values=[128, 256])]
    momentum = [Constant(0.9),
                MultiStep(0.9, [40, 80], values=[0.9, 0.8, 0.7])]
    return GridSearchSpace(
        fns={"lr": lr, "bs": bs, "momentum": momentum},
        static={"wd": [1e-4, 1e-3],
                "optimizer": ["momentum", "adam"]})


def mobilenetv2_space() -> GridSearchSpace:
    """Table 3: 5 lr families × 2 initial lr × 2 bs × 3 cutout."""
    def lr_fams(init):
        return [
            StepLR(init, 0.1, [100, 150]),
            Warmup(10, init, StepLR(init, 0.1, [100, 150])),
            Warmup(10, init, Exponential(init, 0.95)),
            Warmup(10, init, CosineWarmRestarts(init, t_0=20)),
            Cyclic(0.001, init, step_size_up=20),
        ]
    lr = lr_fams(0.1) + lr_fams(0.05)
    bs = [Constant(128), MultiStep(128, [100], values=[128, 256])]
    cutout = [Constant(16),
              MultiStep(16, [80, 100], values=[16, 18, 20]),
              MultiStep(16, [100], values=[16, 20])]
    return GridSearchSpace(
        fns={"lr": lr, "bs": bs, "cutout": cutout},
        static={"optimizer": ["momentum"], "wd": [4e-5, 1e-4, 2e-5, 5e-5]})


def bert_space(total=270) -> GridSearchSpace:
    """Table 4 (steps ÷100): linear lr ± warmup × seq-length schedule,
    widened over initial lr as the paper's 40-trial space was."""
    lr = []
    for init in (5e-5, 3e-5, 2e-5, 1e-5, 7e-5):
        lr.append(Linear(init, total + 30))
        lr.append(Warmup(30, init, Linear(init, total + 30)))
    seq = [Constant(384), MultiStep(384, [210], values=[384, 512])]
    return GridSearchSpace(
        fns={"lr": lr, "seq_len": seq},
        static={"optimizer": ["adam"], "wd": [0.01, 0.0]})


def _resnet20_lrs(inits, milestones_list):
    out = []
    for init in inits:
        for ms in milestones_list:
            out.append(StepLR(init, 0.1, ms))
    return out


def resnet20_space_high_merge(seed: int = 0) -> GridSearchSpace:
    """§6.2 space 1: high intra/inter-study merge — few initial values,
    milestone variations behind long shared prefixes."""
    lr = _resnet20_lrs([0.1, 0.05],
                       [[80, 120], [90, 130], [100, 140]])
    lr += [Warmup(5 + seed % 3, 0.1, StepLR(0.1, 0.1, [80, 120]))]
    bs = [Constant(128), MultiStep(128, [60 + 10 * (seed % 2)],
                                   values=[128, 256])]
    return GridSearchSpace(fns={"lr": lr, "bs": bs},
                           static={"wd": [1e-4, 1e-3, 5e-4]})


def resnet20_space_low_merge(seed: int = 0) -> GridSearchSpace:
    """§6.2 space 2: low merge — diverse initial values diverge at step 0,
    and each study perturbs its initial-value set so little is shared
    *across* studies either (paper: q ∈ [1.19, 1.66])."""
    d = 0.002 * seed
    lr = _resnet20_lrs([0.1 + d, 0.09 + d, 0.08 + d, 0.07 + d,
                        0.06 + d, 0.05 + d],
                       [[80, 120], [85 + seed % 5, 125]])
    bs = [Constant(128), MultiStep(128, [60], values=[128, 256]),
          MultiStep(128, [80], values=[128, 256])]
    return GridSearchSpace(fns={"lr": lr, "bs": bs},
                           static={"wd": [1e-4, 1e-3]})


# workers/gpus mirror the paper's cluster use: CIFAR trials take 1 GPU
# (40 workers); BERT-Base trials train data-parallel on 4 GPUs (10 workers
# of 4 GPUs each on the same 40-GPU cluster).
STUDIES = {
    "resnet56-sha":  dict(space=resnet56_space, algo="sha", max_steps=120,
                          min_steps=15, eta=4, workers=40, gpus=1),
    "resnet56-asha": dict(space=resnet56_space, algo="asha", max_steps=120,
                          min_steps=15, eta=4, workers=40, gpus=1),
    "mobilenetv2-grid": dict(space=mobilenetv2_space, algo="grid",
                             max_steps=120, workers=40, gpus=1),
    "bert-grid": dict(space=bert_space, algo="grid", max_steps=270,
                      workers=10, gpus=4, lr0=5e-5),
}
