"""Data-plane microbenchmark: per-step loop vs fused chunks vs batched
siblings.

Measures training throughput (steps/sec) of the three ``JaxTrainer``
execution paths on a small reference task where dispatch overhead matters
(the regime HPO studies actually run tiny proxy models in):

* ``stepwise`` — the seed data plane: one jitted dispatch per training
  step, batch re-materialized on host each iteration
  (``run_stage_stepwise``);
* ``fused``    — whole-stage chunk executables over a prefetched data slab
  (``run_stage``);
* ``batched×G`` — G divergent sibling stages executed as ONE compiled call
  (``run_stages_batched``); throughput counts all G trials' steps.

All three produce bit-identical states (asserted here on the first member,
and exhaustively in ``tests/test_lossless.py``), so the speedup is free.

Two scaling metrics for batching: wall-clock ``steps_per_sec`` (on a CPU
the member computations serialize inside the executable, so this stays
near the fused rate — real accelerators are where the stacked member axis
vectorizes) and ``trial_steps_per_dispatch`` (hardware-independent: how
much training one compiled-call round-trip advances — grows linearly with
group width, which is what batching buys the control plane: G× fewer
dispatches, checkpoint loads and scheduling rounds for the same work).
Rows land in ``BENCH_dataplane.json`` (CI artifact) via ``benchmarks.run``
or by running this module directly.
"""

from __future__ import annotations

import json
import time

import jax
import jax.numpy as jnp
import numpy as np

from repro.core.trainer import StageContext
from repro.data.pipeline import DataPipeline
from repro.train.jax_trainer import JaxTrainer

STEPS = 64          # steps per measured stage
BATCH = 16
DIM = 32
CLASSES = 10
WIDTHS = (2, 4, 8)  # sibling-group sizes
REPEATS = 3


class TinyMLP:
    """Small one-hidden-layer classifier: the dispatch-overhead-dominated
    proxy-model regime of early HPO rungs."""

    def __init__(self, dim: int = DIM, hidden: int = 64,
                 classes: int = CLASSES):
        self.dim, self.hidden, self.classes = dim, hidden, classes

    def init(self, rng):
        k1, k2 = jax.random.split(rng)
        return {"w1": 0.1 * jax.random.normal(k1, (self.dim, self.hidden)),
                "b1": jnp.zeros((self.hidden,)),
                "w2": 0.1 * jax.random.normal(k2, (self.hidden, self.classes)),
                "b2": jnp.zeros((self.classes,))}

    def loss(self, params, batch):
        h = jax.nn.relu(batch["x"] @ params["w1"] + params["b1"])
        logits = h @ params["w2"] + params["b2"]
        logp = jax.nn.log_softmax(logits)
        nll = -jnp.take_along_axis(logp, batch["y"][:, None], axis=1).mean()
        acc = (jnp.argmax(logits, -1) == batch["y"]).mean()
        return nll, {"acc": acc}


def dataset(n: int = 4096, seed: int = 0):
    rng = np.random.default_rng(seed)
    return {"x": rng.normal(0, 1, (n, DIM)).astype(np.float32),
            "y": rng.integers(0, CLASSES, n).astype(np.int32)}


def make_backend(fused: bool) -> JaxTrainer:
    data = dataset()
    return JaxTrainer(TinyMLP(), lambda: DataPipeline(data, batch_size=BATCH,
                                                      seed=3),
                      dataset(256, seed=1), default_optimizer="momentum",
                      fused=fused, chunk_steps=32,
                      # the bench asserts stepwise/fused bit-equality, a
                      # contract only the CPU unrolled chunk body makes
                      backend="cpu")


def ctx_for(lr: float, i: int = 0) -> StageContext:
    desc = {"hps": {"lr": {"kind": "const", "value": lr}}, "static": {}}
    return StageContext(node_id=f"n{i}", desc=desc, node_start=0, start=0,
                        stop=STEPS, path_key=f"pk{i}")


def timeit(fn, repeats: int = REPEATS) -> float:
    fn()  # warmup: compile + caches
    best = float("inf")
    for _ in range(repeats):
        t0 = time.perf_counter()
        out = fn()
        jax.block_until_ready(out)
        best = min(best, time.perf_counter() - t0)
    return best


def main(csv: bool = True):
    stepwise = make_backend(fused=False)
    fused = make_backend(fused=True)
    ctx = ctx_for(0.05)
    # model/pipeline init happens once per trial in a study, not per stage —
    # keep it out of the timed region (states are read-only to run_stage)
    state_s = stepwise.init_state()
    state_f = fused.init_state()

    t_step = timeit(lambda: stepwise.run_stage_stepwise(state_s,
                                                        ctx)["params"])
    t_fused = timeit(lambda: fused.run_stage(state_f, ctx)["params"])

    # sanity: the paths agree bit for bit (the lossless tests do this
    # exhaustively; the bench refuses to report an unsound speedup)
    a = stepwise.run_stage_stepwise(stepwise.init_state(), ctx)
    b = fused.run_stage(fused.init_state(), ctx)
    for x, y in zip(jax.tree.leaves(a["params"]), jax.tree.leaves(b["params"])):
        np.testing.assert_array_equal(np.asarray(x), np.asarray(y))

    def dispatches(fn):
        c0 = fused.exec_calls
        fn()
        return fused.exec_calls - c0

    base = STEPS / t_step
    n_fused = dispatches(lambda: fused.run_stage(state_f, ctx))
    rows = [
        {"path": "stepwise", "width": 1,
         "steps_per_sec": round(base, 1), "speedup_vs_stepwise": 1.0,
         "trial_steps_per_dispatch": 1.0},   # one jitted call per step
        {"path": "fused", "width": 1,
         "steps_per_sec": round(STEPS / t_fused, 1),
         "speedup_vs_stepwise": round(t_step / t_fused, 2),
         "trial_steps_per_dispatch": round(STEPS / n_fused, 1)},
    ]

    for g in WIDTHS:
        ctxs = [ctx_for(0.05 - 0.004 * i, i) for i in range(g)]
        states = [state_f] * g   # siblings fork the same checkpoint

        def run_group(ctxs=ctxs, states=states):
            return fused.run_stages_batched(states, ctxs)[0]["params"]

        t_g = timeit(run_group)
        n_g = dispatches(run_group)
        rows.append({"path": f"batched x{g}", "width": g,
                     "steps_per_sec": round(g * STEPS / t_g, 1),
                     "speedup_vs_stepwise": round((g * STEPS / t_g) / base,
                                                  2),
                     "trial_steps_per_dispatch": round(g * STEPS / n_g, 1)})

    if csv:
        keys = list(rows[0])
        print(",".join(keys))
        for r in rows:
            print(",".join(str(r[k]) for k in keys))
    return rows


def dump_json(rows, path: str = "BENCH_dataplane.json") -> None:
    with open(path, "w") as f:
        json.dump({"bench": "dataplane", "steps": STEPS, "batch": BATCH,
                   "rows": rows}, f, indent=2)
    print(f"[wrote {path}]")


if __name__ == "__main__":
    dump_json(main())
