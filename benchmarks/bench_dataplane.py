"""Data-plane microbenchmark: per-step loop vs fused chunks vs batched
siblings vs chain-fused multi-stage execution.

Measures training throughput (steps/sec) of the ``JaxTrainer`` execution
paths on a small reference task where dispatch overhead matters (the
regime HPO studies actually run tiny proxy models in):

* ``stepwise`` — the seed data plane: one jitted dispatch per training
  step, batch re-materialized on host each iteration
  (``run_stage_stepwise``);
* ``fused``    — whole-stage chunk executables over a prefetched data slab
  (``run_stage``);
* ``batched×G`` — G divergent sibling stages executed as ONE compiled call
  (``run_stages_batched``); throughput counts all G trials' steps;
* ``per_stage dD`` / ``chain_fused dD`` — a depth-D chain executed the way
  the dispatcher would: per-stage ``run_stage`` calls with a *synchronous*
  directory-store ``put`` at every boundary, vs ONE ``run_chain`` call
  with the carry held on device and every boundary checkpoint deposited
  *write-behind* (``put_async``; the host commit overlaps the next
  stage's compute on the background writer thread).

All paths produce bit-identical states (asserted here on representative
members, and exhaustively in ``tests/test_lossless.py``), so the speedup
is free.

Timing is median-of-``REPEATS`` (single-pass timing made the width curve
non-monotonic purely from scheduler noise); ``check_dataplane_trend.py``
gates the committed rows against ``benchmarks/baseline_dataplane.json``
in CI.  Rows land in ``BENCH_dataplane.json`` (CI artifact) via
``benchmarks.run`` or by running this module directly.
"""

from __future__ import annotations

import itertools
import json
import statistics
import tempfile
import time

import jax
import jax.numpy as jnp
import numpy as np

from repro.core.trainer import StageContext
from repro.data.pipeline import DataPipeline
from repro.train.checkpoint import CheckpointStore
from repro.train.jax_trainer import JaxTrainer

STEPS = 64          # steps per measured stage
BATCH = 16
DIM = 32
CLASSES = 10
WIDTHS = (2, 4, 8)  # sibling-group sizes
REPEATS = 7         # median-of-N (see module docstring)
CHAIN_DEPTHS = (2, 4)
CHAIN_STAGE_STEPS = 8    # short stages: the boundary-dominated HPO regime


class TinyMLP:
    """Small one-hidden-layer classifier: the dispatch-overhead-dominated
    proxy-model regime of early HPO rungs."""

    def __init__(self, dim: int = DIM, hidden: int = 64,
                 classes: int = CLASSES):
        self.dim, self.hidden, self.classes = dim, hidden, classes

    def init(self, rng):
        k1, k2 = jax.random.split(rng)
        return {"w1": 0.1 * jax.random.normal(k1, (self.dim, self.hidden)),
                "b1": jnp.zeros((self.hidden,)),
                "w2": 0.1 * jax.random.normal(k2, (self.hidden, self.classes)),
                "b2": jnp.zeros((self.classes,))}

    def loss(self, params, batch):
        h = jax.nn.relu(batch["x"] @ params["w1"] + params["b1"])
        logits = h @ params["w2"] + params["b2"]
        logp = jax.nn.log_softmax(logits)
        nll = -jnp.take_along_axis(logp, batch["y"][:, None], axis=1).mean()
        acc = (jnp.argmax(logits, -1) == batch["y"]).mean()
        return nll, {"acc": acc}


def dataset(n: int = 4096, seed: int = 0):
    rng = np.random.default_rng(seed)
    return {"x": rng.normal(0, 1, (n, DIM)).astype(np.float32),
            "y": rng.integers(0, CLASSES, n).astype(np.int32)}


def make_backend(fused: bool) -> JaxTrainer:
    data = dataset()
    return JaxTrainer(TinyMLP(), lambda: DataPipeline(data, batch_size=BATCH,
                                                      seed=3),
                      dataset(256, seed=1), default_optimizer="momentum",
                      fused=fused, chunk_steps=32,
                      # the bench asserts stepwise/fused bit-equality, a
                      # contract only the CPU unrolled chunk body makes
                      backend="cpu")


def ctx_for(lr: float, i: int = 0) -> StageContext:
    desc = {"hps": {"lr": {"kind": "const", "value": lr}}, "static": {}}
    return StageContext(node_id=f"n{i}", desc=desc, node_start=0, start=0,
                        stop=STEPS, path_key=f"pk{i}")


def timeit(fn, repeats: int = REPEATS) -> float:
    fn()  # warmup: compile + caches
    times = []
    for _ in range(repeats):
        t0 = time.perf_counter()
        out = fn()
        jax.block_until_ready(out)
        times.append(time.perf_counter() - t0)
    return statistics.median(times)


# ---------------------------------------------------------------------------
# chain-depth sweep: per-stage dispatch (sync boundary puts) vs run_chain
# (device-resident carry + write-behind puts)
# ---------------------------------------------------------------------------

_uniq = itertools.count()


def chain_ctx(pk: str, j: int, lr: float = 0.05) -> StageContext:
    desc = {"hps": {"lr": {"kind": "const", "value": lr}}, "static": {}}
    return StageContext(node_id=pk, desc=desc, node_start=0,
                        start=j * CHAIN_STAGE_STEPS,
                        stop=(j + 1) * CHAIN_STAGE_STEPS, path_key=pk)


def chain_rows(fused: JaxTrainer, state0, base: float):
    """Rows for each chain depth: the dispatcher's former per-stage loop
    (synchronous directory-store put at every boundary) vs chain-fused
    execution.  Fresh content-addresses per run keep the store dedup from
    short-circuiting the writes being measured."""
    rows = []
    with tempfile.TemporaryDirectory() as tmp:
        store = CheckpointStore(tmp)
        for depth in CHAIN_DEPTHS:
            def run_per_stage(depth=depth):
                pk = f"ps{next(_uniq)}"
                state = state0
                for j in range(depth):
                    ctx = chain_ctx(pk, j)
                    state = fused.run_stage(state, ctx)
                    store.put(pk, ctx.stop, state)
                return state["params"]

            def run_chain_fused(depth=depth):
                pk = f"cf{next(_uniq)}"
                ctxs = [chain_ctx(pk, j) for j in range(depth)]
                outs = fused.run_chain(state0, ctxs)
                for ctx, s in zip(ctxs, outs):
                    store.put_async(pk, ctx.stop, s)
                return outs[-1]["params"]

            # bit-equality before timing: the chain path must not be a
            # different computation
            a, b = run_per_stage(), run_chain_fused()
            for x, y in zip(jax.tree.leaves(a), jax.tree.leaves(b)):
                np.testing.assert_array_equal(np.asarray(x), np.asarray(y))

            # drain write-behind backlog between windows: the per-stage
            # timing must not absorb the chain path's draining commits
            # (a chain repeat overlapping its own backlog is steady state
            # and stays in its window)
            store.flush()
            t_ps = timeit(run_per_stage)
            store.flush()
            t_cf = timeit(run_chain_fused)
            store.flush()
            steps = depth * CHAIN_STAGE_STEPS
            rows.append({"path": f"per_stage d{depth}", "depth": depth,
                         "steps_per_sec": round(steps / t_ps, 1),
                         "speedup_vs_stepwise": round((steps / t_ps) / base,
                                                      2)})
            rows.append({"path": f"chain_fused d{depth}", "depth": depth,
                         "steps_per_sec": round(steps / t_cf, 1),
                         "speedup_vs_stepwise": round((steps / t_cf) / base,
                                                      2),
                         "speedup_vs_perstage": round(t_ps / t_cf, 2)})
        store.flush()
    return rows


def main(csv: bool = True):
    stepwise = make_backend(fused=False)
    fused = make_backend(fused=True)
    ctx = ctx_for(0.05)
    # model/pipeline init happens once per trial in a study, not per stage —
    # keep it out of the timed region (states are read-only to run_stage)
    state_s = stepwise.init_state()
    state_f = fused.init_state()

    t_step = timeit(lambda: stepwise.run_stage_stepwise(state_s,
                                                        ctx)["params"])
    t_fused = timeit(lambda: fused.run_stage(state_f, ctx)["params"])

    # sanity: the paths agree bit for bit (the lossless tests do this
    # exhaustively; the bench refuses to report an unsound speedup)
    a = stepwise.run_stage_stepwise(stepwise.init_state(), ctx)
    b = fused.run_stage(fused.init_state(), ctx)
    for x, y in zip(jax.tree.leaves(a["params"]), jax.tree.leaves(b["params"])):
        np.testing.assert_array_equal(np.asarray(x), np.asarray(y))

    def dispatches(fn):
        c0 = fused.exec_calls
        fn()
        return fused.exec_calls - c0

    base = STEPS / t_step
    n_fused = dispatches(lambda: fused.run_stage(state_f, ctx))
    rows = [
        {"path": "stepwise", "width": 1,
         "steps_per_sec": round(base, 1), "speedup_vs_stepwise": 1.0,
         "trial_steps_per_dispatch": 1.0},   # one jitted call per step
        {"path": "fused", "width": 1,
         "steps_per_sec": round(STEPS / t_fused, 1),
         "speedup_vs_stepwise": round(t_step / t_fused, 2),
         "trial_steps_per_dispatch": round(STEPS / n_fused, 1)},
    ]

    for g in WIDTHS:
        ctxs = [ctx_for(0.05 - 0.004 * i, i) for i in range(g)]
        states = [state_f] * g   # siblings fork the same checkpoint

        def run_group(ctxs=ctxs, states=states):
            return fused.run_stages_batched(states, ctxs)[0]["params"]

        t_g = timeit(run_group)
        n_g = dispatches(run_group)
        rows.append({"path": f"batched x{g}", "width": g,
                     "steps_per_sec": round(g * STEPS / t_g, 1),
                     "speedup_vs_stepwise": round((g * STEPS / t_g) / base,
                                                  2),
                     "trial_steps_per_dispatch": round(g * STEPS / n_g, 1)})

    rows.extend(chain_rows(fused, state_f, base))

    if csv:
        keys = []
        for r in rows:
            keys.extend(k for k in r if k not in keys)
        print(",".join(keys))
        for r in rows:
            print(",".join(str(r.get(k, "")) for k in keys))
    return rows


def dump_json(rows, path: str = "BENCH_dataplane.json") -> None:
    with open(path, "w") as f:
        json.dump({"bench": "dataplane", "steps": STEPS, "batch": BATCH,
                   "repeats": REPEATS,
                   "chain_stage_steps": CHAIN_STAGE_STEPS,
                   "rows": rows}, f, indent=2)
    print(f"[wrote {path}]")


if __name__ == "__main__":
    dump_json(main())
