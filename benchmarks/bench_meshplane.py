"""Mesh-plane benchmark: sibling-group width x worker-mesh width sweep.

Distribution plane v2 gives a worker a device *set* (``WorkerMesh``): a
stage's carry shards over the mesh (fsdp over the ``data`` axis) while a
sibling-chain group vmaps across trials within it — two orthogonal
parallelism axes.  This bench drives the full engine (scheduler,
dispatcher, checkpoint plane) over a small reference task on every fleet
shape and asserts the plane's two claims *in-bench*:

* **lossless**: leaf checkpoints of every mesh fleet are bit-identical
  to the thread-worker fleet (same forest, same schedules);
* **zero store round-trips on same-host handoff**: resumes between
  stages on a mesh fleet are served device-to-device (``d2d_handoffs``
  counts them) and the store's read-tier counters stay at zero — only
  durability writes touch it.  The thread fleet, by contrast, pays a
  store read per resume (``mem_hits``).

Runs in a subprocess with ``--xla_force_host_platform_device_count=4``
(the flag must precede the jax import, and the parent process — the
benchmark harness — has usually imported jax already).  Rows land in
``BENCH_meshplane.json`` via ``benchmarks/run.py`` and are gated by
``check_meshplane_trend.py``.
"""

from __future__ import annotations

import json
import os
import subprocess
import sys
import time

ROOT = os.path.dirname(os.path.dirname(os.path.abspath(__file__)))

WIDTHS = (2, 4)          # sibling-group sizes (trials vmapped per group)
MESHES = (0, 1, 2, 4)    # devices per worker (0 = thread fleet)
STEPS = 24               # per trial; siblings fork at STEPS // 2
_MARK = "BENCH_MESHPLANE_JSON="


# ---------------------------------------------------------------------------
# child: the measured sweep (runs under forced host devices)
# ---------------------------------------------------------------------------


def _measure():
    import jax
    import jax.numpy as jnp
    import numpy as np

    from repro.core import SearchPlanDB, Study
    from repro.core.hpseq import HpConfig, MultiStep
    from repro.core.trial import Trial
    from repro.core.tuners import GridTuner
    from repro.data.pipeline import DataPipeline
    from repro.dist.meshes import WorkerMesh
    from repro.train.jax_trainer import JaxTrainer

    assert jax.device_count() >= max(MESHES), (
        f"need {max(MESHES)} host devices, have {jax.device_count()}")

    class BenchTask:
        """Linear softmax with mesh-divisible dims (32 x 8: every mesh
        width in the sweep shards the weight's leading dim)."""

        def init(self, rng):
            k1, _ = jax.random.split(rng)
            return {"w": 0.1 * jax.random.normal(k1, (32, 8)),
                    "b": jnp.zeros((8,))}

        def loss(self, params, batch):
            logits = batch["x"] @ params["w"] + params["b"]
            logp = jax.nn.log_softmax(logits)
            nll = -jnp.take_along_axis(
                logp, batch["y"][:, None], axis=1).mean()
            return nll, {"acc": (jnp.argmax(logits, -1)
                                 == batch["y"]).mean()}

    rng = np.random.default_rng(0)
    data = {"x": rng.normal(0, 1, (256, 32)).astype(np.float32),
            "y": rng.integers(0, 8, 256).astype(np.int32)}
    eval_data = {"x": rng.normal(0, 1, (64, 32)).astype(np.float32),
                 "y": rng.integers(0, 8, 64).astype(np.int32)}

    def make_backend():
        return JaxTrainer(BenchTask(),
                          lambda: DataPipeline(data, batch_size=16, seed=3),
                          eval_data, default_optimizer="momentum",
                          backend="cpu", vectorize_groups=True)

    def trials(width):
        fork = STEPS // 2
        return [Trial(HpConfig({"lr": MultiStep(
            0.1, [fork], values=[0.1, 0.05 / (i + 1)])}), STEPS)
            for i in range(width)]

    def run_fleet(devices, width, backend):
        """One engine run; a single worker so the fork checkpoint lands a
        round before the tails — the sibling group then forms and resumes
        through the d2d path (mesh fleets) or the store (threads)."""
        mesh = (None if devices == 0
                else WorkerMesh.build(list(range(devices))))
        db = SearchPlanDB()
        study = Study.create(db, "m", "d", ("lr",))
        # the chain cap stops round 1 at the fork, so round 2 resumes the
        # FULL sibling set as one vmapped group (and the resume itself is
        # the handoff under measurement)
        eng = study.engine(backend, n_workers=1, batch_siblings=True,
                           max_steps_per_chain=STEPS // 2,
                           worker_meshes=None if mesh is None else [mesh])
        t0 = time.perf_counter()
        stats = eng.run([GridTuner(trials(width))])
        wall = time.perf_counter() - t0
        return db.get(study.key), eng, stats, wall

    def leaf_states(plan, eng, width):
        out = []
        for t in trials(width):
            leaf = plan.trial_paths[t.trial_id][-1]
            out.append(eng.store.get(plan.nodes[leaf].ckpts[STEPS]))
        return out

    def bitwise(a, b):
        for sa, sb in zip(a, b):
            for x, y in zip(jax.tree.leaves(sa["params"]),
                            jax.tree.leaves(sb["params"])):
                if np.asarray(x).tobytes() != np.asarray(y).tobytes():
                    return False
        return True

    rows = []
    for width in WIDTHS:
        ref_states = None
        ref_rate = None
        for devices in MESHES:
            backend = make_backend()
            run_fleet(devices, width, backend)            # compile warmup
            # best-of-3: single runs are ~10ms and scheduler-noise bound
            plan, eng, stats, wall = min(
                (run_fleet(devices, width, backend) for _ in range(3)),
                key=lambda r: r[3])
            states = leaf_states(plan, eng, width)
            reads = (stats.ckpt_mem_hits + stats.ckpt_disk_hits
                     + stats.ckpt_remote_hits)
            row = {
                "fleet": f"mesh{devices}" if devices else "threads",
                "group_width": width,
                "devices": max(devices, 1),
                "steps_run": stats.steps_run,
                "wall_s": round(wall, 4),
                "steps_per_s": round(stats.steps_run / wall, 1),
                "batched_groups": stats.batched_groups,
                "mesh_placements": stats.mesh_placements,
                "placement_rejections": stats.placement_rejections,
                "ckpt_loads": stats.ckpt_loads,
                "d2d_handoffs": stats.d2d_handoffs,
                "store_read_hits": reads,
            }
            if devices == 0:
                ref_states, ref_rate = states, row["steps_per_s"]
                row["bitwise_identical"] = True
                # threads pay the store for every resume
                assert stats.d2d_handoffs == 0
                assert reads > 0, "thread fleet never read the store?"
            else:
                row["bitwise_identical"] = bitwise(states, ref_states)
                row["rate_vs_threads"] = round(
                    row["steps_per_s"] / ref_rate, 3)
                # the plane's core claims, asserted where they're measured
                assert row["bitwise_identical"], (
                    f"mesh{devices} x{width}: sharded leaves diverged "
                    "from the thread fleet")
                assert stats.mesh_placements > 0
                assert stats.d2d_handoffs > 0, (
                    f"mesh{devices} x{width}: no d2d handoff happened")
                assert reads == 0, (
                    f"mesh{devices} x{width}: {reads} store reads — "
                    "same-host handoff must bypass the store entirely")
            assert stats.batched_groups > 0, "sibling group never formed"
            rows.append(row)
        # the forest and schedule are fleet-invariant
        assert len({r["steps_run"] for r in rows
                    if r["group_width"] == width}) == 1
    return rows


def _child():
    print(_MARK + json.dumps(_measure()))


# ---------------------------------------------------------------------------
# parent: re-exec under forced host devices
# ---------------------------------------------------------------------------


def main(csv: bool = True):
    env = dict(os.environ)
    env["XLA_FLAGS"] = ("--xla_force_host_platform_device_count="
                        f"{max(MESHES)} " + env.get("XLA_FLAGS", ""))
    env["JAX_PLATFORMS"] = "cpu"
    env["PYTHONPATH"] = os.pathsep.join(
        [os.path.join(ROOT, "src"), ROOT]
        + ([env["PYTHONPATH"]] if env.get("PYTHONPATH") else []))
    proc = subprocess.run(
        [sys.executable, "-c",
         "from benchmarks.bench_meshplane import _child; _child()"],
        env=env, cwd=ROOT, capture_output=True, text=True, timeout=900)
    if proc.returncode != 0:
        sys.stderr.write(proc.stdout[-2000:] + proc.stderr[-4000:])
        raise SystemExit("bench_meshplane child failed")
    line = next(l for l in proc.stdout.splitlines() if l.startswith(_MARK))
    rows = json.loads(line[len(_MARK):])
    if csv:
        keys = ["fleet", "group_width", "devices", "steps_run", "wall_s",
                "steps_per_s", "rate_vs_threads", "batched_groups",
                "mesh_placements", "ckpt_loads", "d2d_handoffs",
                "store_read_hits", "bitwise_identical"]
        print(",".join(keys))
        for r in rows:
            print(",".join(str(r.get(k, "")) for k in keys))
    return rows


def dump_json(rows, path: str = "BENCH_meshplane.json") -> None:
    with open(path, "w") as f:
        json.dump({"bench": "meshplane", "rows": rows}, f, indent=2)
    print(f"[wrote {path}]")


if __name__ == "__main__":
    dump_json(main())
