"""Front-door benchmark: rebalanced shared fleet vs static partition.

Mixed-key staggered traffic — three plan keys whose demand peaks at
different times — served two ways over the same 8-worker fleet:

* **static**: the operator pre-partitions the fleet per key (the best
  guess available before traffic arrives: near-equal shares) and each
  key runs its own :class:`StudyService`.  Workers parked on a key whose
  studies haven't arrived yet — or have already drained — idle while
  another key's queue is deep.
* **rebalanced**: one :class:`~repro.frontdoor.StudyGateway` owns the
  fleet and leases workers to whichever sessions have live demand,
  revoking at chain boundaries as forests drain.

Both configurations run identical per-key stage forests (admission per
key is the same), so the comparison isolates the lease manager: the
makespan gap is pure fleet-shape adaptation.  All times are virtual
(SimulatedTrainer), so rows are machine-independent and the trend gate
(``check_frontdoor_trend.py``) can hold tight bounds.  Rows land in
``BENCH_frontdoor.json`` via ``benchmarks/run.py`` (CI artifact).
"""

from __future__ import annotations

import json
import time

from benchmarks.spaces import resnet20_space_high_merge
from repro.core import SearchPlanDB, StudyService, StudySpec
from repro.core.trainer import SimulatedTrainer
from repro.core.tuners import GridTuner
from repro.frontdoor import StudyGateway

N_WORKERS = 8
MAX_STEPS = 160
SEC_PER_STEP = 60.0

# three keys whose demand peaks in distinct phases (arrival gaps on the
# order of a phase's full-fleet drain time): while key 0's forest is the
# only live demand a static partition can use just its own share of the
# fleet and parks the rest on keys whose studies haven't arrived — the
# gateway leases the whole fleet to whoever is busy *now*
TRAFFIC = [
    (StudySpec("resnet20", "cifar10", ("lr", "bs")),
     [0.0, 1800.0, 3600.0]),
    (StudySpec("wrn28", "cifar10", ("lr", "bs")),
     [100_000.0, 101_800.0]),
    (StudySpec("vgg16", "cifar10", ("lr", "bs")),
     [200_000.0]),
]


def _backend():
    return SimulatedTrainer(base_seconds_per_step=SEC_PER_STEP,
                            horizon=MAX_STEPS, load_seconds=30.0,
                            save_seconds=30.0, eval_seconds=60.0)


def _tuners():
    """seed -> tuner, seeded per (key, arrival) so spaces differ."""
    out = []
    seed = 0
    for spec, arrivals in TRAFFIC:
        for at in arrivals:
            out.append((spec, at,
                        GridTuner(resnet20_space_high_merge(
                            seed=seed).trials(MAX_STEPS))))
            seed += 1
    return out


def _partition(n: int, k: int):
    """Near-equal static split: the fairest guess with no traffic model."""
    base, extra = divmod(n, k)
    return [base + (1 if i < extra else 0) for i in range(k)]


def run_static():
    """One fixed-size service per key; fleet pre-partitioned."""
    shares = _partition(N_WORKERS, len(TRAFFIC))
    t0 = time.perf_counter()
    per_key = []
    for (spec, _), n in zip(TRAFFIC, shares):
        svc = StudyService(SearchPlanDB(), _backend(), n_workers=n)
        for s, at, tuner in _tuners():
            if s.key == spec.key:
                svc.submit(spec, tuner, at=at)
        per_key.append(svc.close())
    wall = time.perf_counter() - t0
    return per_key, wall


def run_rebalanced():
    """One gateway, one fleet, leases follow demand."""
    t0 = time.perf_counter()
    gw = StudyGateway(SearchPlanDB(), _backend(), n_slots=N_WORKERS)
    for spec, at, tuner in _tuners():
        gw.submit(spec, tuner, at=at)
    archive = gw.close()
    wall = time.perf_counter() - t0
    return [stats for _, stats in archive], wall


def _row(config: str, per_key, wall: float) -> dict:
    return {
        "config": config,
        "workers": N_WORKERS,
        "keys": len(TRAFFIC),
        "studies": sum(len(s.by_study) for s in per_key),
        # arrivals are absolute virtual times, so each session's
        # end_to_end IS its drain time; the deployment's makespan is the
        # latest drain across keys
        "makespan_s": round(max(s.end_to_end for s in per_key), 1),
        "gpu_seconds": round(sum(s.gpu_seconds for s in per_key), 1),
        "steps_run": sum(s.steps_run for s in per_key),
        "wall_s": round(wall, 4),
    }


def dump_json(rows, path: str = "BENCH_frontdoor.json") -> None:
    with open(path, "w") as f:
        json.dump({"bench": "frontdoor", "rows": rows}, f, indent=2)
    print(f"[wrote {path}]")


def main():
    rows = [_row("static", *run_static()),
            _row("rebalanced", *run_rebalanced())]
    print("config,workers,studies,makespan_s,gpu_seconds,steps_run")
    for r in rows:
        print(f"{r['config']},{r['workers']},{r['studies']},"
              f"{r['makespan_s']},{r['gpu_seconds']},{r['steps_run']}")
    static, reb = rows
    print(f"# rebalanced speedup: "
          f"{static['makespan_s'] / reb['makespan_s']:.2f}x makespan")
    return rows


if __name__ == "__main__":
    dump_json(main())
