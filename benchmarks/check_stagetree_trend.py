"""CI perf-trend gate for the incremental control plane.

Compares the current ``BENCH_stagetree.json`` against the committed
baseline (``benchmarks/baseline_stagetree.json``) and fails when the
steady-state incremental scheduling round regresses more than ``2x``.

Raw microseconds are meaningless across machines, so the comparison is
normalized by the from-scratch ``build_stage_tree`` row — a pure-Python
workload with no incremental caches that tracks overall machine speed:

    normalized_cur = cur(steady_round_incremental)
                     * base(build_stage_tree) / cur(build_stage_tree)

Usage: ``python benchmarks/check_stagetree_trend.py [current] [baseline]``.
"""

from __future__ import annotations

import json
import sys

THRESHOLD = 2.0


def _row(rows, op: str) -> dict:
    for r in rows:
        if r["op"] == op:
            return r
    raise SystemExit(f"benchmark row {op!r} missing")


def main(current_path: str = "BENCH_stagetree.json",
         baseline_path: str = "benchmarks/baseline_stagetree.json",
         threshold: float = THRESHOLD) -> None:
    with open(current_path) as f:
        cur = json.load(f)["rows"]
    with open(baseline_path) as f:
        base = json.load(f)["rows"]

    calib = (_row(base, "build_stage_tree")["us_per_op"]
             / _row(cur, "build_stage_tree")["us_per_op"])
    cur_us = _row(cur, "steady_round_incremental")["us_per_op"] * calib
    base_us = _row(base, "steady_round_incremental")["us_per_op"]
    ratio = cur_us / base_us
    print(f"steady_round_incremental: {cur_us:.1f}us normalized "
          f"(machine calib x{calib:.2f}) vs baseline {base_us:.1f}us "
          f"-> ratio {ratio:.2f} (limit {threshold:.1f})")
    if ratio > threshold:
        raise SystemExit(
            f"perf regression: steady incremental round is {ratio:.2f}x the "
            f"committed baseline (limit {threshold:.1f}x)")
    print("trend OK")


if __name__ == "__main__":
    argv = sys.argv[1:]
    main(*(argv[:2]))
