"""CI gate for the kernel plane (mirrors check_dataplane_trend).

Compares the current ``BENCH_kernels.json`` against the committed
baseline (``benchmarks/baseline_kernels.json``) and fails when:

* any baseline (kernel, shape) row is missing — coverage can only grow;
* any row reports ``fallbacks != 0`` — the kernel plane must actually
  run on the CI backend (CPU interpret mode), not detour to the oracle;
* ``max_abs_err`` exceeds ``max(ERR_SLACK x baseline, ERR_FLOOR)`` — the
  kernels must stay numerically glued to the jnp reference;
* ``pct_of_peak`` is missing or non-positive — the roofline column is
  part of the report contract (on TPU it is the headline number; on CPU
  interpret it is tiny but must exist and be > 0);
* the pallas/ref wall-time ratio blows up more than ``TIME_SLACK`` x over
  the baseline ratio.  Absolute interpret-mode times are meaningless
  across machines, but the *ratio* against the jnp reference on the same
  machine is stable; this catches a catastrophic interpret-path
  regression (e.g. an accidental per-element fori_loop) without flaking
  on CI load.

Usage: ``python benchmarks/check_kernels_trend.py [current] [baseline]``.
"""

from __future__ import annotations

import json
import sys

ERR_SLACK = 10.0     # current err may be up to 10x the baseline err
ERR_FLOOR = 1e-5     # ...but never gated below this absolute floor
TIME_SLACK = 10.0    # pallas/ref ratio may grow up to 10x vs baseline


def _row(rows, kernel: str, shape: str) -> dict:
    for r in rows:
        if r["kernel"] == kernel and r["shape"] == shape:
            return r
    raise SystemExit(f"benchmark row ({kernel}, {shape}) missing")


def main(current_path: str = "BENCH_kernels.json",
         baseline_path: str = "benchmarks/baseline_kernels.json") -> None:
    with open(current_path) as f:
        cur = json.load(f)["rows"]
    with open(baseline_path) as f:
        base = json.load(f)["rows"]

    for b in base:
        r = _row(cur, b["kernel"], b["shape"])
        tag = f"{b['kernel']}[{b['shape']}]"

        fb = r.get("fallbacks")
        if fb != 0:
            raise SystemExit(f"{tag}: {fb} kernel fallbacks (must be 0 — "
                             "the kernel plane did not run)")

        ceil = max(ERR_SLACK * b["max_abs_err"], ERR_FLOOR)
        if not (r["max_abs_err"] <= ceil):
            raise SystemExit(
                f"{tag}: max_abs_err {r['max_abs_err']:.3e} exceeds ceiling "
                f"{ceil:.3e} (baseline {b['max_abs_err']:.3e})")

        pct = r.get("pct_of_peak")
        if pct is None or not (pct > 0):
            raise SystemExit(f"{tag}: pct_of_peak missing or non-positive "
                             f"({pct!r})")

        cur_ratio = r["pallas_ms"] / max(r["ref_ms"], 1e-3)
        base_ratio = b["pallas_ms"] / max(b["ref_ms"], 1e-3)
        if cur_ratio > base_ratio * TIME_SLACK:
            raise SystemExit(
                f"{tag}: pallas/ref wall-time ratio {cur_ratio:.1f} is "
                f">{TIME_SLACK:.0f}x the baseline ratio {base_ratio:.1f}")

        print(f"{tag}: err {r['max_abs_err']:.2e} (ceil {ceil:.2e}), "
              f"fallbacks 0, pct_of_peak {pct}, ratio {cur_ratio:.1f} "
              f"(base {base_ratio:.1f})")

    print("kernel trend OK")


if __name__ == "__main__":
    argv = sys.argv[1:]
    main(*(argv[:2]))
