"""Control-plane microbenchmarks: plan insertion, Algorithm 1, scheduling.

The paper's system must regenerate a stage tree from the search plan on
*every* scheduling round (stateless scheduler, §4.3) — this measures that
path at realistic study sizes (hundreds of trials).
"""

from __future__ import annotations

import time

from benchmarks.spaces import resnet56_space
from repro.core import CriticalPathScheduler, SearchPlan, build_stage_tree


def timeit(fn, n=5):
    best = float("inf")
    for _ in range(n):
        t0 = time.perf_counter()
        out = fn()
        best = min(best, time.perf_counter() - t0)
    return best, out


def main(csv: bool = True):
    trials = resnet56_space().trials(120)
    rows = []

    def insert_all():
        plan = SearchPlan()
        for t in trials:
            plan.submit(t)
        return plan

    dt, plan = timeit(insert_all)
    rows.append({"op": "plan_insert", "n": len(trials),
                 "us_per_op": round(dt / len(trials) * 1e6, 1)})

    dt, tree = timeit(lambda: build_stage_tree(plan))
    rows.append({"op": "build_stage_tree", "n": len(tree),
                 "us_per_op": round(dt / max(1, len(tree)) * 1e6, 1)})

    sched = CriticalPathScheduler()
    dt, paths = timeit(lambda: sched.assign(plan, build_stage_tree(plan), 40))
    rows.append({"op": "schedule_40_workers", "n": len(paths),
                 "us_per_op": round(dt * 1e6 / max(1, len(paths)), 1)})

    dt, _ = timeit(lambda: SearchPlan.from_json(plan.to_json()))
    rows.append({"op": "plan_json_roundtrip", "n": len(plan.nodes),
                 "us_per_op": round(dt / len(plan.nodes) * 1e6, 1)})

    if csv:
        keys = list(rows[0])
        print(",".join(keys))
        for r in rows:
            print(",".join(str(r[k]) for k in keys))
    return rows


if __name__ == "__main__":
    main()
