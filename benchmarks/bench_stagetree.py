"""Control-plane microbenchmarks: plan insertion, Algorithm 1, scheduling.

The paper's system regenerates a stage tree from the search plan on *every*
scheduling round (stateless scheduler, §4.3) — this measures that path at
realistic study sizes (hundreds of trials), plus the **steady-state round**
that motivates the incremental control plane: a warm 120-trial plan where a
few fresh trials arrive.  The full-rebuild path (the seed implementation:
full pending-request scan + from-scratch Algorithm 1) is O(plan); the
revision-memoized :class:`StageTreeBuilder` is O(changed requests).
"""

from __future__ import annotations

import time

from benchmarks.spaces import resnet56_space
from repro.core import (CriticalPathScheduler, SearchPlan, StageTreeBuilder,
                        build_stage_tree, stage_trees_equal)
from repro.core.hpseq import Constant, HpConfig
from repro.core.stagetree import _emit_tree, _find_latest_checkpoint
from repro.core.trial import Trial


def timeit(fn, n=5):
    best = float("inf")
    for _ in range(n):
        t0 = time.perf_counter()
        out = fn()
        best = min(best, time.perf_counter() - t0)
    return best, out


def full_rebuild(plan: SearchPlan):
    """The pre-incremental scheduling round: full scan + scratch Algorithm 1."""
    pending = plan.pending_requests_scan()
    lookup = {}
    for req in pending:
        _find_latest_checkpoint(plan, req, lookup)
    return _emit_tree(plan, lookup, pending)


def make_warm_plan(trials, rungs=(30, 60, None)) -> SearchPlan:
    """Submit + fully execute ``trials`` at SHA-style rung milestones:
    every request satisfied, every stage checkpointed — the long-lived,
    request-dense plan a production study becomes (the full-rebuild scan
    revisits every one of those satisfied requests forever after)."""
    plan = SearchPlan()
    for t in trials:
        for upto in rungs:
            plan.submit(t, upto=upto)
    while True:
        tree = build_stage_tree(plan)
        if not tree.stages:
            break
        for st in tree.stages.values():  # parents emitted before children
            plan.record_result(
                st.node_id, st.stop, f"ck-{st.node_id}@{st.stop}",
                {"val_acc": 0.5} if st.report else None)
    assert plan.pending_requests() == []
    return plan


def fresh_trial(k: int) -> Trial:
    return Trial(HpConfig({"lr": Constant(0.001 + 1e-5 * k),
                           "bs": Constant(128)}), 120)


def bench_steady_state(trials, rounds: int = 30):
    """Steady-state scheduling rounds: one fresh trial lands per round.

    Returns per-round seconds for (full rebuild, incremental builder); both
    plans see identical submissions and the produced trees are verified
    structurally identical every round.
    """
    plan_full = make_warm_plan(trials)
    plan_inc = make_warm_plan(trials)
    builder = StageTreeBuilder(plan_inc)
    builder.build()                       # warm the memo (steady state)

    full_times, inc_times = [], []
    for k in range(rounds):
        t = fresh_trial(k)
        plan_full.submit(t)
        t0 = time.perf_counter()
        tree_f = full_rebuild(plan_full)
        full_times.append(time.perf_counter() - t0)

        plan_inc.submit(t)
        t0 = time.perf_counter()
        tree_i = builder.build()
        inc_times.append(time.perf_counter() - t0)
        assert stage_trees_equal(tree_i, tree_f)

        # satisfy the new request so the next round is steady-state again
        for st in tree_i.stages.values():
            plan_full.record_result(st.node_id, st.stop, "ck", {"val_acc": 0.5})
            plan_inc.record_result(st.node_id, st.stop, "ck", {"val_acc": 0.5})
    # best-of-n, like timeit() above: scheduler-noise-robust per-round cost
    return min(full_times), min(inc_times)


def main(csv: bool = True):
    trials = resnet56_space().trials(120)
    rows = []

    def insert_all():
        plan = SearchPlan()
        for t in trials:
            plan.submit(t)
        return plan

    dt, plan = timeit(insert_all)
    rows.append({"op": "plan_insert", "n": len(trials),
                 "us_per_op": round(dt / len(trials) * 1e6, 1)})

    dt, tree = timeit(lambda: build_stage_tree(plan))
    rows.append({"op": "build_stage_tree", "n": len(tree),
                 "us_per_op": round(dt / max(1, len(tree)) * 1e6, 1)})

    sched = CriticalPathScheduler()
    dt, paths = timeit(lambda: sched.assign(plan, build_stage_tree(plan), 40))
    rows.append({"op": "schedule_40_workers", "n": len(paths),
                 "us_per_op": round(dt * 1e6 / max(1, len(paths)), 1)})

    dt, _ = timeit(lambda: SearchPlan.from_json(plan.to_json()))
    rows.append({"op": "plan_json_roundtrip", "n": len(plan.nodes),
                 "us_per_op": round(dt / len(plan.nodes) * 1e6, 1)})

    # ---- steady-state scheduling round on a warm 120-trial plan ----
    per_full, per_inc = bench_steady_state(trials)
    rows.append({"op": "steady_round_full_rebuild", "n": len(trials),
                 "us_per_op": round(per_full * 1e6, 1)})
    rows.append({"op": "steady_round_incremental", "n": len(trials),
                 "us_per_op": round(per_inc * 1e6, 1)})
    rows.append({"op": "steady_round_speedup", "n": len(trials),
                 "us_per_op": round(per_full / per_inc, 1)})

    if csv:
        keys = list(rows[0])
        print(",".join(keys))
        for r in rows:
            print(",".join(str(r[k]) for k in keys))
    return rows


if __name__ == "__main__":
    main()
