"""CI trend gate for the front door (mirrors check_meshplane_trend).

Compares the current ``BENCH_frontdoor.json`` against the committed
baseline (``benchmarks/baseline_frontdoor.json``) and fails when:

* either configuration lost studies (``studies`` shrank) — the gateway
  must serve everything the static deployment serves;
* total ``gpu_seconds``/``steps_run`` differ between the two configs —
  both run identical per-key stage forests, so any gap means the lease
  plane changed *what* ran, not just *where*;
* the rebalanced fleet stops beating the static partition by at least
  ``SPEEDUP_FLOOR`` on makespan — the front door's reason to exist;
* the rebalanced makespan regresses more than ``MAKESPAN_THRESHOLD``
  vs the baseline.  All times are virtual (simulator), so this bound
  is machine-independent and deliberately tight.

Usage: ``python benchmarks/check_frontdoor_trend.py [current] [baseline]``.
"""

from __future__ import annotations

import json
import sys

SPEEDUP_FLOOR = 1.10        # min static/rebalanced makespan ratio
MAKESPAN_THRESHOLD = 1.02   # max rebalanced-makespan growth vs baseline


def _row(rows, config: str) -> dict:
    for r in rows:
        if r["config"] == config:
            return r
    raise SystemExit(f"benchmark row {config!r} missing")


def main(current_path: str = "BENCH_frontdoor.json",
         baseline_path: str = "benchmarks/baseline_frontdoor.json") -> None:
    with open(current_path) as f:
        cur = json.load(f)["rows"]
    with open(baseline_path) as f:
        base = json.load(f)["rows"]

    static, reb = _row(cur, "static"), _row(cur, "rebalanced")
    base_reb = _row(base, "rebalanced")

    for r in cur:
        if r["studies"] < _row(base, r["config"])["studies"]:
            raise SystemExit(
                f"{r['config']}: served {r['studies']} studies, baseline "
                f"served {_row(base, r['config'])['studies']} — work lost")

    # identical logical work: the lease plane only moves workers
    for field in ("gpu_seconds", "steps_run"):
        if static[field] != reb[field]:
            raise SystemExit(
                f"{field} differs between configs (static {static[field]}, "
                f"rebalanced {reb[field]}) — rebalancing changed the "
                "forests, not just the fleet shape")

    speedup = static["makespan_s"] / reb["makespan_s"]
    if speedup < SPEEDUP_FLOOR:
        raise SystemExit(
            f"rebalanced makespan speedup {speedup:.2f}x is below the "
            f"{SPEEDUP_FLOOR:.2f}x floor (static {static['makespan_s']}s, "
            f"rebalanced {reb['makespan_s']}s)")

    growth = reb["makespan_s"] / base_reb["makespan_s"]
    if growth > MAKESPAN_THRESHOLD:
        raise SystemExit(
            f"rebalanced makespan regressed {growth:.3f}x vs baseline "
            f"({base_reb['makespan_s']}s -> {reb['makespan_s']}s; virtual "
            f"time, so this is a scheduling change, not machine noise)")

    print(f"frontdoor trend OK: speedup {speedup:.2f}x "
          f"(floor {SPEEDUP_FLOOR:.2f}x), rebalanced makespan "
          f"{reb['makespan_s']}s vs baseline {base_reb['makespan_s']}s")


if __name__ == "__main__":
    main(*sys.argv[1:3])
