"""Multi-study benchmark — the paper's Figures 13/14 (§6.2), plus the
service plane's staggered-arrival scenario.

Upfront: S ∈ {1, 2, 4, 8} studies over the same (model, dataset, hp-set)
submitted concurrently; studies share one search plan, so inter-study
redundancy is eliminated.  Two space families: high merge (Figure 13) and
low merge (Figure 14).  Reports k-wise merge rate q and trial/stage
savings.

Staggered: the same S=4 high-merge studies submitted to ONE long-lived
:class:`StudyService` with one arrival per simulated hour — the
continuous-traffic setting (PipeTune-style dynamic job arrival).  Late
arrivals must merge into the in-flight stage forest: the staggered
``gpuh_saving`` stays close to the upfront row's, and the salted baseline
shows what a batch-only API would cost.  Rows land in
``BENCH_multistudy.json`` via ``benchmarks/run.py`` (CI artifact).
"""

from __future__ import annotations

import json
import tempfile
from typing import Callable, List

from benchmarks.spaces import (resnet20_space_high_merge,
                               resnet20_space_low_merge)
from repro.core import (SearchPlanDB, Study, StudyService, StudySpec,
                        k_wise_merge_rate, run_studies)
from repro.core.trainer import SimulatedTrainer
from repro.core.tuners import GridTuner

N_WORKERS = 40
MAX_STEPS = 160
SEC_PER_STEP = 60.0
ARRIVAL_GAP = 3600.0   # staggered scenario: one study per simulated hour
SPEC = StudySpec("resnet20", "cifar10", ("lr", "bs"))


def _backend():
    return SimulatedTrainer(base_seconds_per_step=SEC_PER_STEP,
                            horizon=MAX_STEPS, load_seconds=30.0,
                            save_seconds=30.0, eval_seconds=60.0)


def run_multi(space_fn: Callable, n_studies: int, share: bool):
    db = SearchPlanDB()
    pairs = []
    for i in range(n_studies):
        st = Study.create(db, "resnet20", "cifar10", ("lr", "bs"))
        pairs.append((st, GridTuner(space_fn(seed=i).trials(MAX_STEPS))))
    # directory store: the storage columns measure physical delta-encoded
    # bytes, not just virtual time
    with tempfile.TemporaryDirectory() as d:
        from repro.train.checkpoint import CheckpointStore
        return run_studies(pairs, _backend(), n_workers=N_WORKERS,
                           share=share, store=CheckpointStore(d))


def run_staggered(space_fn: Callable, n_studies: int, share: bool,
                  gap: float = ARRIVAL_GAP):
    """One long-lived service session; study i arrives at virtual i*gap."""
    db = SearchPlanDB()
    with tempfile.TemporaryDirectory() as d:
        from repro.train.checkpoint import CheckpointStore
        svc = StudyService(db, _backend(), n_workers=N_WORKERS, share=share,
                           store=CheckpointStore(d))
        futs = [svc.submit(SPEC,
                           GridTuner(space_fn(seed=i).trials(MAX_STEPS)),
                           at=i * gap)
                for i in range(n_studies)]
        stats = svc.close()
    assert all(f.done() for f in futs)
    return stats


def _row(label: str, scenario: str, S: int, trial_sets: List, t, s):
    return {
        "space": label, "scenario": scenario, "S": S,
        "n_trials": sum(len(x) for x in trial_sets),
        "q": round(k_wise_merge_rate(trial_sets), 3),
        "gpuh_trial": round(t.gpu_hours, 1),
        "gpuh_stage": round(s.gpu_hours, 1),
        "gpuh_saving": round(t.gpu_seconds / s.gpu_seconds, 2),
        "e2e_saving": round(t.end_to_end / s.end_to_end, 2),
        # storage trajectory of the stage run (delta-encoded commits)
        "bytes_written": s.ckpt_bytes_written,
        "dedup_ratio": round(s.dedup_ratio, 2),
    }


def main(csv: bool = True):
    rows = []
    for label, space_fn in (("high-merge", resnet20_space_high_merge),
                            ("low-merge", resnet20_space_low_merge)):
        for S in (1, 2, 4, 8):
            trial_sets: List = [space_fn(seed=i).trials(MAX_STEPS)
                                for i in range(S)]
            t = run_multi(space_fn, S, share=False)
            s = run_multi(space_fn, S, share=True)
            rows.append(_row(label, "upfront", S, trial_sets, t, s))
    # staggered arrivals through the service session (S=4, high merge): the
    # reuse the live forest retains for late arrivals vs the salted baseline
    S = 4
    trial_sets = [resnet20_space_high_merge(seed=i).trials(MAX_STEPS)
                  for i in range(S)]
    t = run_staggered(resnet20_space_high_merge, S, share=False)
    s = run_staggered(resnet20_space_high_merge, S, share=True)
    rows.append(_row("high-merge", "staggered", S, trial_sets, t, s))
    if csv:
        keys = list(rows[0])
        print(",".join(keys))
        for r in rows:
            print(",".join(str(r[k]) for k in keys))
    return rows


def dump_json(rows, path: str = "BENCH_multistudy.json") -> None:
    with open(path, "w") as f:
        json.dump({"bench": "multistudy", "rows": rows}, f, indent=2)
    print(f"[wrote {path}]")


if __name__ == "__main__":
    main()
