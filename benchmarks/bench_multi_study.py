"""Multi-study benchmark — the paper's Figures 13/14 (§6.2).

S ∈ {1, 2, 4, 8} studies over the same (model, dataset, hp-set) submitted
concurrently; studies share one search plan, so inter-study redundancy is
eliminated.  Two space families: high merge (Figure 13) and low merge
(Figure 14).  Reports k-wise merge rate q and trial/stage savings.
"""

from __future__ import annotations

from typing import Callable, List

from benchmarks.spaces import (resnet20_space_high_merge,
                               resnet20_space_low_merge)
from repro.core import SearchPlanDB, Study, k_wise_merge_rate, run_studies
from repro.core.trainer import SimulatedTrainer
from repro.core.tuners import GridTuner

N_WORKERS = 40
MAX_STEPS = 160
SEC_PER_STEP = 60.0


def run_multi(space_fn: Callable, n_studies: int, share: bool):
    db = SearchPlanDB()
    pairs = []
    for i in range(n_studies):
        st = Study.create(db, "resnet20", "cifar10", ("lr", "bs"))
        pairs.append((st, GridTuner(space_fn(seed=i).trials(MAX_STEPS))))
    backend = SimulatedTrainer(base_seconds_per_step=SEC_PER_STEP,
                               horizon=MAX_STEPS, load_seconds=30.0,
                               save_seconds=30.0, eval_seconds=60.0)
    return run_studies(pairs, backend, n_workers=N_WORKERS, share=share)


def main(csv: bool = True):
    rows = []
    for label, space_fn in (("high-merge", resnet20_space_high_merge),
                            ("low-merge", resnet20_space_low_merge)):
        for S in (1, 2, 4, 8):
            trial_sets: List = [space_fn(seed=i).trials(MAX_STEPS)
                                for i in range(S)]
            q = k_wise_merge_rate(trial_sets)
            t = run_multi(space_fn, S, share=False)
            s = run_multi(space_fn, S, share=True)
            rows.append({
                "space": label, "S": S,
                "n_trials": sum(len(x) for x in trial_sets),
                "q": round(q, 3),
                "gpuh_trial": round(t.gpu_hours, 1),
                "gpuh_stage": round(s.gpu_hours, 1),
                "gpuh_saving": round(t.gpu_seconds / s.gpu_seconds, 2),
                "e2e_saving": round(t.end_to_end / s.end_to_end, 2),
            })
    if csv:
        keys = list(rows[0])
        print(",".join(keys))
        for r in rows:
            print(",".join(str(r[k]) for k in keys))
    return rows


if __name__ == "__main__":
    main()
