"""Quickstart: a Hippo study in ~40 lines (simulated cluster).

Defines a search space of learning-rate *sequences* (Figure 10 style) and
submits it to a :class:`StudyService` session on a simulated 8-GPU cluster
twice — trial-based (the Ray Tune baseline) and stage-based (Hippo) — and
prints the savings.  The service is the long-lived entry point; a one-shot
study is just a session with a single submission.

    PYTHONPATH=src python examples/quickstart.py
"""

from repro.core import (Constant, Exponential, MultiStep, SearchPlanDB,
                        StepLR, StudyService, StudySpec, Warmup, merge_rate)
from repro.core.trainer import SimulatedTrainer
from repro.core.tuners import GridSearchSpace, GridTuner


def main():
    space = GridSearchSpace(
        fns={
            "lr": [StepLR(0.1, 0.1, [90, 135]),
                   StepLR(0.1, 0.1, [100, 150]),
                   Warmup(5, 0.1, StepLR(0.1, 0.1, [90, 135])),
                   Warmup(5, 0.1, Exponential(0.1, 0.95))],
            "bs": [Constant(128), MultiStep(128, [70], values=[128, 256])],
        },
        static={"wd": [1e-4, 1e-3]},
    )
    trials = space.trials(200)
    print(f"{len(trials)} trials, merge rate p = {merge_rate(trials):.3f}")

    spec = StudySpec("resnet56", "cifar10", ("lr", "bs", "wd"))
    for share, label in ((False, "trial-based (Ray Tune analogue)"),
                         (True, "stage-based (Hippo)")):
        db = SearchPlanDB()
        svc = StudyService(db, SimulatedTrainer(base_seconds_per_step=60),
                           n_workers=8, share=share)
        fut = svc.submit(spec, GridTuner(list(trials)))
        stats = svc.close()
        assert fut.done()
        print(f"{label:35s} GPU-hours {stats.gpu_hours:7.2f}   "
              f"end-to-end {stats.end_to_end / 3600:5.2f} h   "
              f"steps trained {stats.steps_run}")


if __name__ == "__main__":
    main()
