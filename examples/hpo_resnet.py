"""End-to-end driver: a REAL hyper-parameter optimization study.

Trains a CIFAR-shaped ResNet (the paper's model family) with real JAX
training through the full Hippo stack — search plan, stage tree,
critical-path scheduler, checkpoint store, SHA tuner — and compares
stage-based against trial-based execution on actual wall-clock compute.

Sized for this CPU container (~2-4 minutes).  On a cluster the same code
runs with ``n_workers=40`` and the full ResNet56 (``ResNet(n=9)``).

    PYTHONPATH=src python examples/hpo_resnet.py
"""

import time

from repro.core import (Constant, MultiStep, SearchPlanDB, Study, merge_rate)
from repro.core.tuners import GridSearchSpace, SHATuner
from repro.data import DataPipeline, synthetic_cifar
from repro.models.resnet import ResNet
from repro.train.jax_trainer import JaxTrainer


def make_backend():
    data = synthetic_cifar(2048, seed=0)
    eval_data = synthetic_cifar(512, seed=1)
    return JaxTrainer(ResNet(n=1, width=16),
                      lambda: DataPipeline(data, batch_size=64, seed=3),
                      eval_data, default_optimizer="momentum")


def space():
    return GridSearchSpace(fns={
        "lr": [Constant(0.05),
               MultiStep(0.05, [40], values=[0.05, 0.005]),
               MultiStep(0.05, [40], values=[0.05, 0.02]),
               MultiStep(0.05, [60], values=[0.05, 0.005]),
               MultiStep(0.05, [60, 80], values=[0.05, 0.02, 0.002]),
               MultiStep(0.05, [80], values=[0.05, 0.01])],
        "bs": [Constant(64)]})


def main():
    trials = space().trials(100)
    print(f"{len(trials)} trials × 100 steps, p = {merge_rate(trials):.2f}")

    results = {}
    for share, label in ((True, "stage"), (False, "trial")):
        db = SearchPlanDB()
        study = Study.create(db, "resnet8", "synthetic-cifar", ("lr", "bs"))
        tuner = SHATuner(space().trials(100), min_steps=25, max_steps=100,
                         eta=2)
        t0 = time.time()
        stats = study.run(tuner, make_backend(), n_workers=2)
        wall = time.time() - t0
        results[label] = (stats, tuner, wall)
        print(f"{label}-based: best val_acc {tuner.best_score:.4f}  "
              f"steps trained {stats.steps_run}  wall {wall:.1f}s")

    s, t = results["stage"][0], results["trial"][0]
    print(f"\nstage-based trained {t.steps_run / s.steps_run:.2f}x fewer "
          f"steps for the same search"
          f" (best acc stage {results['stage'][1].best_score:.4f} "
          f"vs trial {results['trial'][1].best_score:.4f})")


if __name__ == "__main__":
    main()
