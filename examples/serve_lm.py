"""Serve a small LM with batched requests (decode path demo).

Loads a reduced qwen2-0.5b-family model, prefills a batch of prompts and
serves new tokens with the ring-buffer KV cache — the same ``serve_step``
the multi-pod dry-run lowers for ``decode_32k`` / ``long_500k``.

    PYTHONPATH=src python examples/serve_lm.py [--arch mamba2-2.7b]
"""

import argparse
import time

import jax
import jax.numpy as jnp

from repro.configs import get_config
from repro.models import LM
from repro.train.step import build_serve_step


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="qwen2-0.5b")
    ap.add_argument("--batch", type=int, default=4)
    ap.add_argument("--prompt-len", type=int, default=32)
    ap.add_argument("--new-tokens", type=int, default=16)
    args = ap.parse_args()

    cfg = get_config(args.arch).reduced(d_model=256)
    model = LM(cfg)
    params = model.init(jax.random.PRNGKey(0))
    print(f"{cfg.name}: {cfg.param_count()/1e6:.1f}M params "
          f"({cfg.arch_type}); batch={args.batch}")

    # ---- prefill: feed the prompts token-by-token through the cache
    prompts = jax.random.randint(jax.random.PRNGKey(1),
                                 (args.batch, args.prompt_len), 0,
                                 cfg.vocab_size)
    cache = model.init_cache(args.batch,
                             args.prompt_len + args.new_tokens + 8)
    serve = jax.jit(build_serve_step(model))

    t0 = time.time()
    tok = prompts[:, :1]
    for i in range(args.prompt_len):
        tok = prompts[:, i:i + 1]
        next_tok, cache = serve(params, cache, tok, jnp.int32(i))
    print(f"prefilled {args.prompt_len} positions in {time.time()-t0:.2f}s")

    # ---- decode: batched generation
    t0 = time.time()
    out = []
    tok = next_tok[:, None]
    for i in range(args.new_tokens):
        next_tok, cache = serve(params, cache, tok,
                                jnp.int32(args.prompt_len + i))
        tok = next_tok[:, None]
        out.append(next_tok)
    dt = time.time() - t0
    gen = jnp.stack(out, axis=1)
    print(f"generated {args.new_tokens} tokens/request in {dt:.2f}s "
          f"({args.batch * args.new_tokens / dt:.1f} tok/s batched)")
    print("sampled continuations (greedy):")
    for b in range(args.batch):
        print(f"  req{b}: {list(map(int, gen[b][:10]))} ...")


if __name__ == "__main__":
    main()
