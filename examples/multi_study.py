"""Multi-study merging under continuous traffic (§6.2, service plane).

Four teams submit near-identical ResNet20 studies to ONE long-lived
:class:`StudyService` — not upfront, but staggered over (virtual) time, the
way studies arrive at a production cluster.  Late arrivals merge into the
in-flight stage forest; Hippo dedups across them.  Compare against the
same four studies run trial-based (salted, zero cross-study reuse).

    PYTHONPATH=src python examples/multi_study.py
"""

import os
import sys
sys.path.insert(0, os.path.join(os.path.dirname(__file__), ".."))

from benchmarks.spaces import resnet20_space_high_merge
from repro.core import (SearchPlanDB, StudyService, StudySpec,
                        k_wise_merge_rate)
from repro.core.trainer import SimulatedTrainer
from repro.core.tuners import GridTuner

S, STEPS = 4, 160
ARRIVAL_GAP = 3600.0          # one study arrives every simulated hour
SPEC = StudySpec("resnet20", "cifar10", ("lr", "bs"))


def run(share: bool):
    db = SearchPlanDB()
    backend = SimulatedTrainer(base_seconds_per_step=60, horizon=STEPS)
    svc = StudyService(db, backend, n_workers=40, share=share,
                       policy="fair_share")
    futs = [svc.submit(SPEC, GridTuner(
                resnet20_space_high_merge(seed=i).trials(STEPS)),
                at=i * ARRIVAL_GAP)
            for i in range(S)]
    stats = svc.close()
    assert all(f.done() for f in futs)
    return stats


def main():
    sets = [resnet20_space_high_merge(seed=i).trials(STEPS) for i in range(S)]
    print(f"{S} studies arriving {ARRIVAL_GAP / 3600:.0f}h apart, "
          f"{sum(map(len, sets))} trials total, "
          f"k-wise merge rate q = {k_wise_merge_rate(sets):.2f}")
    trial = run(share=False)
    stage = run(share=True)
    print(f"trial-based: {trial.gpu_hours:8.1f} GPU-h   "
          f"e2e {trial.end_to_end/3600:6.2f} h")
    print(f"stage-based: {stage.gpu_hours:8.1f} GPU-h   "
          f"e2e {stage.end_to_end/3600:6.2f} h")
    print(f"savings: {trial.gpu_seconds/stage.gpu_seconds:.2f}x GPU-hours, "
          f"{trial.end_to_end/stage.end_to_end:.2f}x end-to-end")
    print("\nper-study split-credited execution (stage-based):")
    for sid, ss in sorted(stage.by_study.items()):
        print(f"  {sid}: {ss.gpu_seconds/3600:7.1f} GPU-h  "
              f"{ss.steps_run:6d} steps served  "
              f"{ss.instant_results:3d} instant results")


if __name__ == "__main__":
    main()
