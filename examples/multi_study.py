"""Multi-study merging (§6.2): 4 studies share one search plan.

Four teams submit near-identical ResNet20 studies; Hippo dedups across
them.  Compare against the same four studies run trial-based.

    PYTHONPATH=src python examples/multi_study.py
"""

import os
import sys
sys.path.insert(0, os.path.join(os.path.dirname(__file__), ".."))

from benchmarks.spaces import resnet20_space_high_merge
from repro.core import SearchPlanDB, Study, k_wise_merge_rate, run_studies
from repro.core.trainer import SimulatedTrainer
from repro.core.tuners import GridTuner

S, STEPS = 4, 160


def run(share: bool):
    db = SearchPlanDB()
    pairs = []
    for i in range(S):
        st = Study.create(db, "resnet20", "cifar10", ("lr", "bs"))
        pairs.append((st, GridTuner(
            resnet20_space_high_merge(seed=i).trials(STEPS))))
    backend = SimulatedTrainer(base_seconds_per_step=60, horizon=STEPS)
    return run_studies(pairs, backend, n_workers=40, share=share)


def main():
    sets = [resnet20_space_high_merge(seed=i).trials(STEPS) for i in range(S)]
    print(f"{S} studies, {sum(map(len, sets))} trials total, "
          f"k-wise merge rate q = {k_wise_merge_rate(sets):.2f}")
    trial = run(share=False)
    stage = run(share=True)
    print(f"trial-based: {trial.gpu_hours:8.1f} GPU-h   "
          f"e2e {trial.end_to_end/3600:6.2f} h")
    print(f"stage-based: {stage.gpu_hours:8.1f} GPU-h   "
          f"e2e {stage.end_to_end/3600:6.2f} h")
    print(f"savings: {trial.gpu_seconds/stage.gpu_seconds:.2f}x GPU-hours, "
          f"{trial.end_to_end/stage.end_to_end:.2f}x end-to-end")


if __name__ == "__main__":
    main()
