"""Write-behind checkpoint plane + directory read path.

``put_async`` entries live in a pending cache until a background writer
commits them; readers (``get`` / ``contains`` / ``__len__``) must be
unable to tell pending from committed, ``evict`` must cancel in-flight
writes, and ``flush`` is the durability barrier (and the channel for
writer failures).  The directory backend additionally keeps a bounded LRU
read cache (``bytes_read`` counts actual disk traffic) and caches the
``__len__`` disk scan.
"""

import os
import types

import numpy as np
import pytest

from repro.train import checkpoint as ckpt_mod
from repro.train.checkpoint import CheckpointStore


def tree(i: int):
    return {"w": np.arange(4, dtype=np.float32) + i, "step": np.int32(i)}


def assert_tree_equal(a, b):
    np.testing.assert_array_equal(np.asarray(a["w"]), np.asarray(b["w"]))
    assert int(a["step"]) == int(b["step"])


def stall_writer(monkeypatch):
    """Keep put_async entries pending forever: the writer thread is
    replaced by a no-op, so tests can observe the pending state
    deterministically."""
    monkeypatch.setattr(
        ckpt_mod.threading, "Thread",
        lambda **kw: types.SimpleNamespace(start=lambda: None))


# ---------------------------------------------------------------------------
# pending entries are indistinguishable from committed ones
# ---------------------------------------------------------------------------


def test_pending_served_to_readers_before_commit(monkeypatch, tmp_path):
    stall_writer(monkeypatch)
    store = CheckpointStore(str(tmp_path))
    cid = store.put_async("pk", 3, tree(3))
    assert store.pending_writes == 1
    assert not os.path.exists(store._path(cid))   # nothing on disk yet
    assert store.contains(cid)
    assert_tree_equal(store.get(cid), tree(3))
    assert len(store) == 1


def test_put_async_dedups_against_pending_and_disk(monkeypatch, tmp_path):
    stall_writer(monkeypatch)
    store = CheckpointStore(str(tmp_path))
    store.put("pk", 1, tree(1))                   # committed synchronously
    assert store.put_async("pk", 1, tree(1)) == store.ckpt_id("pk", 1)
    assert store.pending_writes == 0              # disk dedup
    store.put_async("pk", 2, tree(2))
    store.put_async("pk", 2, tree(2))             # pending dedup
    assert store.pending_writes == 1
    assert store.async_puts == 1
    assert store.puts == 4


def test_evict_cancels_pending_write(monkeypatch, tmp_path):
    stall_writer(monkeypatch)
    store = CheckpointStore(str(tmp_path))
    cid = store.put_async("pk", 5, tree(5))
    assert store.evict(cid) is True
    assert store.pending_writes == 0
    assert not store.contains(cid)
    assert len(store) == 0
    store.flush()                                 # nothing left: no hang


# ---------------------------------------------------------------------------
# flush barrier
# ---------------------------------------------------------------------------


def test_flush_commits_everything_to_disk(tmp_path):
    store = CheckpointStore(str(tmp_path))
    cids = [store.put_async("pk", i, tree(i)) for i in range(8)]
    store.flush()
    assert store.pending_writes == 0
    for i, cid in enumerate(cids):
        assert os.path.exists(store._path(cid))
        assert_tree_equal(store.get(cid), tree(i))
    assert len(store) == 8
    assert store.bytes_written > 0


def test_flush_commits_in_memory_backend(tmp_path):
    store = CheckpointStore()                      # in-memory
    cid = store.put_async("pk", 1, tree(1))
    assert_tree_equal(store.get(cid), tree(1))     # served pending or committed
    store.flush()
    assert store.pending_writes == 0
    assert cid in store._mem
    assert_tree_equal(store.get(cid), tree(1))


def test_flush_surfaces_writer_failure(tmp_path):
    d = tmp_path / "gone"
    store = CheckpointStore(str(d))
    os.rmdir(str(d))                               # commit target vanishes
    store.put_async("pk", 1, tree(1))
    with pytest.raises(RuntimeError, match="write-behind"):
        store.flush()
    store.flush()                                  # error is one-shot


# ---------------------------------------------------------------------------
# directory read path: LRU cache, bytes_read, cached __len__
# ---------------------------------------------------------------------------


def test_read_cache_bounds_and_bytes_read(tmp_path):
    store = CheckpointStore(str(tmp_path), read_cache_entries=2)
    cids = [store.put("pk", i, tree(i)) for i in range(3)]
    assert store.bytes_read == 0

    store.get(cids[0])
    after_first = store.bytes_read
    assert after_first > 0
    store.get(cids[0])                             # cache hit: no disk read
    assert store.bytes_read == after_first

    store.get(cids[1])                             # cache: {0, 1}
    store.get(cids[2])                             # evicts 0 (bound 2)
    assert len(store._read_cache) == 2
    b = store.bytes_read
    store.get(cids[0])                             # re-read from disk
    assert store.bytes_read > b


def test_evicted_checkpoint_leaves_read_cache(tmp_path):
    store = CheckpointStore(str(tmp_path))
    cid = store.put("pk", 1, tree(1))
    store.get(cid)
    assert store.evict(cid)
    with pytest.raises(KeyError):
        store.get(cid)


def test_disk_index_is_incremental_no_rescans(tmp_path, monkeypatch):
    """The disk-cid index is built once at construction and maintained
    incrementally: ``__len__``/``committed_ids`` never re-``listdir``."""
    seed = CheckpointStore(str(tmp_path))
    for i in range(3):
        seed.put("pk", i, tree(i))

    scans = {"n": 0}
    real_listdir = os.listdir

    def counting_listdir(path):
        scans["n"] += 1
        return real_listdir(path)

    monkeypatch.setattr(ckpt_mod.os, "listdir", counting_listdir)
    store = CheckpointStore(str(tmp_path))      # re-open over existing blobs
    assert scans["n"] == 1                      # the one init-time scan
    assert len(store) == 3
    assert len(store.committed_ids()) == 3
    store.put("pk", 3, tree(3))                 # incremental maintenance
    assert len(store) == 4
    store.evict(store.ckpt_id("pk", 0))
    assert len(store) == 3
    cid = store.put_async("pk", 9, tree(9))
    store.flush()
    assert len(store) == 4
    assert cid in store.committed_ids()
    assert scans["n"] == 1                      # still only the init scan


def test_single_file_commit_no_sidecar_and_tmp_sweep(tmp_path):
    """v2 blobs carry the treedef in the header — a commit is exactly one
    file, and evict removes exactly it.  Stale temp files (a writer reaped
    between serialize and publish) are swept at construction and counted."""
    store = CheckpointStore(str(tmp_path))
    cid = store.put("pk", 1, tree(1))
    assert not os.path.exists(store._path(cid) + ".tree")
    assert os.listdir(str(tmp_path)) == [os.path.basename(store._path(cid))]
    store.evict(cid)
    assert os.listdir(str(tmp_path)) == []

    # simulate a writer thread reaped mid-commit: orphaned temp files
    cid2 = store.put("pk", 2, tree(2))
    for j in range(2):
        with open(store._path(cid) + f".{j}.tmp", "wb") as f:
            f.write(b"partial")
    reopened = CheckpointStore(str(tmp_path))
    assert reopened.tmp_reclaimed == 2
    assert not any(f.endswith(".tmp") for f in os.listdir(str(tmp_path)))
    assert len(reopened) == 1                   # the committed blob survives
    assert_tree_equal(reopened.get(cid2), tree(2))


def test_evict_then_reput_of_same_content_survives(monkeypatch, tmp_path):
    """Kill-then-recompute of the same content: an eviction that cancels an
    in-flight commit must not undo a subsequent re-put of the same cid
    (content addressing: same cid == same content)."""
    store = CheckpointStore(str(tmp_path))
    cid = store.put_async("pk", 1, tree(1))
    store.flush()
    assert store.evict(cid)
    # re-deposit the identical content (a later round re-derived the stage)
    assert store.put_async("pk", 1, tree(1)) == cid
    store.flush()
    assert os.path.exists(store._path(cid))
    assert_tree_equal(store.get(cid), tree(1))


def test_disk_files_published_atomically(tmp_path):
    """No half-written .ckpt is ever visible at the probed path: every
    .ckpt that exists must be fully readable, and no temp files survive a
    flush."""
    store = CheckpointStore(str(tmp_path))
    cids = [store.put_async("pk", i, tree(i)) for i in range(6)]
    store.flush()
    for f in os.listdir(str(tmp_path)):
        assert not f.endswith(".tmp"), f
    for i, cid in enumerate(cids):
        assert_tree_equal(store._read_disk(cid), tree(i))


def test_idle_writer_retires_and_respawns(tmp_path):
    import time
    store = CheckpointStore(str(tmp_path))
    store._IDLE_EXIT_SECONDS = 0.05
    store.put_async("pk", 1, tree(1))
    store.flush()
    deadline = time.time() + 2.0
    while store._writer is not None and time.time() < deadline:
        time.sleep(0.02)
    assert store._writer is None          # thread retired, store unpinned
    cid = store.put_async("pk", 2, tree(2))   # respawns a fresh writer
    store.flush()
    assert os.path.exists(store._path(cid))
