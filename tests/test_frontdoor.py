"""Front door: multi-tenant gateway, admission control, worker leases,
and the schema'd v5 snapshot format (PR 10)."""

import dataclasses
import os
import pickle

import pytest

from repro.core import (Constant, Exponential, FaultInjector, MultiStep,
                        PlanKeyMismatch, SearchPlanDB, StepLR, StudyService,
                        StudySpec, Warmup)
from repro.core.engine.session import (load_latest_session, load_session,
                                       save_session, save_session_rotated,
                                       sweep_session_tmps)
from repro.core.hpseq import HpConfig
from repro.core.scheduler import FairShareScheduler
from repro.core.trainer import SimulatedTrainer
from repro.core.trial import Trial
from repro.core.tuners import GridSearchSpace, GridTuner
from repro.dist.meshes import plan_worker_meshes
from repro.frontdoor import (AdmissionQueueFull, CapacityError, GatewayState,
                             StudyGateway, TenantQuota, WorkerLeaseManager,
                             decode_snapshot, encode_snapshot,
                             is_v5_snapshot)
from repro.frontdoor.leases import Lease

A = StudySpec("m", "d", ("lr", "bs"))
B = StudySpec("m2", "d", ("lr", "bs"))
C = StudySpec("m3", "d", ("lr", "bs"))


def det(stats):
    """Deterministic view of EngineStats (see test_service.det)."""
    return dataclasses.replace(
        stats, ckpt_save_seconds=0.0, ckpt_load_seconds=0.0,
        ckpt_delta_bytes=0, ckpt_full_bytes=0, ckpt_logical_bytes=0,
        ckpt_bytes_written=0, ckpt_delta_commits=0, ckpt_delta_rebases=0,
        ckpt_mem_hits=0, ckpt_disk_hits=0, ckpt_remote_hits=0,
        ckpt_store_misses=0, ckpt_tier_promotions=0, ckpt_tier_demotions=0,
        ckpt_tmp_reclaimed=0, d2d_handoffs=0)


def space():
    return GridSearchSpace(
        fns={"lr": [Constant(0.1), StepLR(0.1, 0.1, [100, 150]),
                    Warmup(5, 0.1, StepLR(0.1, 0.1, [90, 135])),
                    Warmup(5, 0.1, Exponential(0.1, 0.95))],
             "bs": [Constant(128), MultiStep(128, [70], values=[128, 256])]})


def tuner(steps=150):
    return GridTuner(space().trials(steps))


def mk(lr, steps):
    return Trial(HpConfig({"lr": lr}), steps)


# ---------------------------------------------------------------------------
# routing: per-key sessions, same-key merging
# ---------------------------------------------------------------------------


def test_two_keys_run_concurrently_in_isolated_sessions():
    """The headline scenario: two different-key studies plus two same-key
    tenants through ONE gateway — concurrent, isolated forests."""
    gw = StudyGateway(SearchPlanDB(), SimulatedTrainer(), n_slots=4)
    f1 = gw.submit(A, tuner(), tenant="alice")
    f2 = gw.submit(A, tuner(), tenant="bob")       # same key: merges
    f3 = gw.submit(B, tuner(120), tenant="bob")    # different key: isolated
    assert len(gw.sessions) == 2                   # one session per key
    # both sessions hold leased workers concurrently (fleet is split)
    assert gw.leases.held(A.key) and gw.leases.held(B.key)
    gw.join()
    assert f1.done() and f2.done() and f3.done()
    archive = dict(gw.close())
    assert set(archive) == {A.key, B.key}
    # same-key studies merged into one forest: the second tenant's
    # identical space was answered with zero fresh training
    a = archive[A.key]
    assert a.by_study["study-1"].instant_results > 0 or \
        sum(s.steps_run for s in a.by_study.values()) > a.steps_run
    # different-key forests never mix accounting
    assert set(archive[B.key].by_study) == {"study-2"}
    assert set(a.by_study) == {"study-0", "study-1"}


def test_same_key_same_stats_as_single_service():
    """Routing through the gateway adds no physical work: a single-key
    workload matches the plain StudyService run event-for-event."""
    def via_service():
        svc = StudyService(SearchPlanDB(), SimulatedTrainer(), n_workers=4)
        svc.submit(A, tuner())
        svc.submit(A, tuner(120), at=80.0)
        return svc.close()

    def via_gateway():
        gw = StudyGateway(SearchPlanDB(), SimulatedTrainer(), n_slots=4)
        gw.submit(A, tuner())
        gw.submit(A, tuner(120), at=80.0)
        return dict(gw.close())[A.key]

    assert det(via_service()) == det(via_gateway())


def test_retired_key_respawns_fresh_session():
    gw = StudyGateway(SearchPlanDB(), SimulatedTrainer(), n_slots=2)
    f1 = gw.submit(A, tuner(100))
    f1.result()
    gw.join()                                # drain trailing idle events
    assert A.key not in gw.sessions          # drained forest retired
    f2 = gw.submit(A, tuner(100))            # same key arrives again
    assert A.key in gw.sessions              # fresh session spawned
    f2.result()
    # the plan survived in the db: the respawned forest answers instantly
    assert f2.stats.instant_results == 8
    gw.close()


def test_plan_key_mismatch_is_structured_and_gateway_reroutes():
    # the error carries both keys (no string matching needed to route)
    svc = StudyService(SearchPlanDB(), SimulatedTrainer(), n_workers=2)
    svc.submit(A, tuner(60))
    with pytest.raises(PlanKeyMismatch) as ei:
        svc.submit(B, tuner(60))
    assert ei.value.session_key == A.key
    assert ei.value.submitted_key == B.key
    assert isinstance(ei.value, ValueError)    # backward compatible

    # gateway catch-and-route: corrupt the routing table so B's slot
    # holds A's session — the structured error re-files it and the
    # submission still lands in a correct fresh session
    gw = StudyGateway(SearchPlanDB(), SimulatedTrainer(), n_slots=2)
    gw.submit(A, tuner(60))
    gw._sessions[B.key] = gw._sessions.pop(A.key)   # corruption
    fut = gw.submit(B, tuner(60))
    assert gw.sessions[A.key].key == A.key          # re-filed
    assert gw.sessions[B.key].key == B.key          # fresh, correct
    fut.result()
    gw.join()
    gw.close()


# ---------------------------------------------------------------------------
# admission control
# ---------------------------------------------------------------------------


def test_capacity_gate_refuses_unplaceable_work():
    gw = StudyGateway(SearchPlanDB(), SimulatedTrainer(),
                      slot_meshes=plan_worker_meshes(2, 2))
    with pytest.raises(CapacityError, match="widest fleet slot has 2"):
        gw.submit(A, tuner(), min_devices=4)
    gw0 = StudyGateway(SearchPlanDB(), SimulatedTrainer(), n_slots=0)
    with pytest.raises(CapacityError, match="no worker slots"):
        gw0.submit(A, tuner())


def test_max_concurrent_queues_at_the_door_and_drains():
    gw = StudyGateway(SearchPlanDB(), SimulatedTrainer(), n_slots=2,
                      max_concurrent=1)
    f1 = gw.submit(A, tuner(100))
    f2 = gw.submit(B, tuner(100))
    assert f1.status == "queued"               # admitted into a session
    assert f2.status == "queued_admission"     # waiting at the door
    assert len(gw.sessions) == 1
    gw.join()
    assert f1.done() and f2.done()
    gw.close()
    assert gw.admission.admission_faults == 0


def test_bounded_queue_raises_admission_queue_full():
    gw = StudyGateway(SearchPlanDB(), SimulatedTrainer(), n_slots=2,
                      max_concurrent=1,
                      quotas={"t": TenantQuota(max_queued=1)})
    gw.submit(A, tuner(100), tenant="t")
    gw.submit(B, tuner(100), tenant="t")       # 1 queued: at the bound
    with pytest.raises(AdmissionQueueFull, match="admission queue is full"):
        gw.submit(C, tuner(100), tenant="t")
    gw.join()
    gw.close()


def test_weighted_fair_share_admits_starved_tenant_first():
    """The starved-tenant acceptance test: when a running slot frees, the
    tenant with the least weighted usage is admitted ahead of earlier and
    higher-priority submissions from a tenant that already consumed."""
    gw = StudyGateway(SearchPlanDB(), SimulatedTrainer(), n_slots=2,
                      max_concurrent=1,
                      quotas={"greedy": TenantQuota(weight=1.0),
                              "starved": TenantQuota(weight=1.0)})
    first = gw.submit(A, tuner(100), tenant="greedy")
    g2 = gw.submit(B, tuner(100), tenant="greedy", priority=5)
    s1 = gw.submit(C, tuner(100), tenant="starved", priority=0)
    assert g2.status == s1.status == "queued_admission"
    first.result()
    gw._pump()
    # greedy's usage > 0, starved's == 0 → starved wins despite arriving
    # later with lower priority
    assert s1.status in ("queued", "running", "done")
    assert g2.status == "queued_admission"
    gw.join()
    assert g2.done() and s1.done()
    gw.close()


def test_quota_weight_scales_share_inside_shared_session():
    """Tenant weights flow into the session's FairShareScheduler: the
    weighted tenant's studies are charged less weighted-usage, so the
    dequeue keeps favoring light users (weights must be > 0)."""
    with pytest.raises(ValueError, match="weight must be > 0"):
        TenantQuota(weight=0.0)
    sched = FairShareScheduler()
    sched.set_study_weights({"s1": 2.0})
    sched.usage = {"s1": 100.0, "s2": 60.0}
    # raw usage ranks s2 first, weighted usage ranks s1 first (100/2=50)
    assert sched._weighted_usage("s1") == 50.0
    assert sched._weighted_usage("s2") == 60.0
    with pytest.raises(ValueError):
        sched.set_study_weights({"s1": -1.0})


def test_v4_unpickled_scheduler_lacks_weights_attr():
    """A FairShareScheduler pickled before PR 10 restores without
    ``weights`` (pickle skips __init__) — the weight hooks must tolerate
    that and backfill."""
    sched = FairShareScheduler()
    del sched.weights                 # simulate a pre-PR-10 pickle
    revived = pickle.loads(pickle.dumps(sched))
    assert not hasattr(revived, "weights")
    assert revived._weighted_usage("s") == 0.0     # defensive getattr
    revived.set_study_weights({"s": 2.0})          # backfills the dict
    assert revived.weights == {"s": 2.0}


def test_priority_breaks_ties_within_equal_usage():
    gw = StudyGateway(SearchPlanDB(), SimulatedTrainer(), n_slots=2,
                      max_concurrent=1)
    first = gw.submit(A, tuner(100), tenant="t")
    low = gw.submit(B, tuner(100), tenant="t", priority=0)
    high = gw.submit(C, tuner(100), tenant="t", priority=9)
    first.result()
    gw._pump()
    assert high.status != "queued_admission"   # admitted first
    assert low.status == "queued_admission"
    gw.join()
    gw.close()


def test_cancel_queued_admission_withdraws_at_the_door():
    gw = StudyGateway(SearchPlanDB(), SimulatedTrainer(), n_slots=2,
                      max_concurrent=1)
    f1 = gw.submit(A, tuner(100))
    f2 = gw.submit(B, tuner(100))
    assert f2.status == "queued_admission"
    assert f2.cancel()
    assert f2.cancelled() and f2.cancel()      # idempotent
    assert not gw.admission.queue
    gw.join()
    assert f1.done()
    archive = gw.close()
    assert [k for k, _ in archive] == [A.key]  # B's session never spawned


# ---------------------------------------------------------------------------
# worker leases
# ---------------------------------------------------------------------------


def test_lease_targets_largest_remainder_with_min_one():
    mgr = WorkerLeaseManager([None] * 10)
    # proportional with floor+remainder, sums to the fleet
    assert mgr.targets({"a": 3, "b": 1}) == {"a": 7, "b": 3}
    # every demanding key gets at least one slot when the fleet allows
    t = mgr.targets({"a": 100, "b": 1, "c": 1})
    assert t["b"] >= 1 and t["c"] >= 1 and sum(t.values()) == 10
    assert mgr.targets({"a": 2, "b": 0}) == {"a": 10, "b": 0}
    assert mgr.targets({"a": 0, "b": 0}) == {"a": 0, "b": 0}


def test_rebalance_moves_workers_as_forests_drain():
    """Fleet follows demand: when one session's forest drains, its slots
    migrate to the other live session at chain boundaries."""
    gw = StudyGateway(SearchPlanDB(), SimulatedTrainer(), n_slots=4)
    fa = gw.submit(A, tuner(100))
    fb = gw.submit(B, tuner(300))
    assert len(gw.leases.held(A.key)) == 2
    assert len(gw.leases.held(B.key)) == 2
    fa.result()                         # drain A's forest
    while A.key in gw.sessions and gw.step():
        pass                            # trailing idle events settle
    assert A.key not in gw.sessions     # retired, leases released
    # B eventually owns the whole fleet (revocations land at boundaries)
    peak = len(gw.leases.held(B.key))
    while not fb.done() and gw.step():
        peak = max(peak, len(gw.leases.held(B.key)))
    assert peak == 4
    gw.join()
    gw.close()


def test_revoke_busy_worker_drains_at_chain_boundary():
    gw = StudyGateway(SearchPlanDB(), SimulatedTrainer(), n_slots=2)
    fut = gw.submit(A, tuner(200))
    # step until both workers are mid-chain
    eng = gw.sessions[A.key].engine
    while not any(not w.idle for w in eng.workers):
        gw.step()
    busy = [l for l in gw.leases.held(A.key)
            if not eng.worker(l.wid).idle][0]
    assert gw.leases.revoke(busy, eng) is False    # drains, not instant
    assert busy.draining and eng.worker(busy.wid).draining
    assert busy.slot in gw.leases.leases           # slot not yet free
    # the boundary passes; reap frees the slot, rebalance re-grants it
    while eng.worker(busy.wid) is not None:
        gw.step()
    gw._pump()
    assert not gw.leases.leases.get(busy.slot, Lease(0, "", 0)).draining
    fut.result()
    gw.close()


def test_granted_worker_cannot_start_in_the_past():
    gw = StudyGateway(SearchPlanDB(), SimulatedTrainer(), n_slots=2)
    gw.submit(A, tuner(200))
    gw.run_until(60.0)
    assert gw.time > 0
    gw.submit(B, tuner(100))        # forces a rebalance at global time
    # the revoked worker drains to its chain boundary, then migrates
    while not gw.leases.held(B.key) and gw.step():
        pass
    moved = gw.leases.held(B.key)
    assert moved
    eng_b = gw.sessions[B.key].engine
    for lease in moved:
        assert eng_b.worker(lease.wid).busy_until >= gw.time
    gw.join()
    gw.close()


# ---------------------------------------------------------------------------
# v5 snapshots
# ---------------------------------------------------------------------------


def _mid_run_gateway():
    gw = StudyGateway(SearchPlanDB(), SimulatedTrainer(), n_slots=4,
                      quotas={"alice": TenantQuota(weight=2.0),
                              "bob": TenantQuota()})
    gw.submit(A, tuner(200), tenant="alice")
    gw.submit(A, tuner(160), tenant="bob", at=80.0)
    gw.submit(B, tuner(120), tenant="bob", at=40.0)
    gw.run_until(150.0)
    assert not gw.quiescent
    return gw


def test_gateway_snapshot_restore_identical(tmp_path):
    """The SIGKILL acceptance test: every session restored from one v5
    gateway envelope finishes with EngineStats (by_study included) and a
    tenant ledger identical to the uninterrupted run."""
    gw = _mid_run_gateway()
    path = str(tmp_path / "gw.snap")
    gw.snapshot(path)
    gw.join()
    ref = {k: det(s) for k, s in gw.close()}
    ref_ledger = gw.tenant_ledger()

    gw2 = StudyGateway.restore(SearchPlanDB(), path, SimulatedTrainer())
    assert len(gw2.sessions) == 2
    assert [f.status for f in gw2.futures] == ["running"] * 3
    gw2.join()
    res = {k: det(s) for k, s in gw2.close()}
    assert res == ref
    assert gw2.tenant_ledger() == ref_ledger


def test_gateway_restore_preserves_queued_admissions(tmp_path):
    gw = StudyGateway(SearchPlanDB(), SimulatedTrainer(), n_slots=2,
                      max_concurrent=1)
    gw.submit(A, tuner(100))
    queued = gw.submit(B, tuner(100), priority=3)
    assert queued.status == "queued_admission"
    gw.run_until(50.0)
    path = str(tmp_path / "gw.snap")
    gw.snapshot(path)

    gw2 = StudyGateway.restore(SearchPlanDB(), path, SimulatedTrainer())
    q2 = [f for f in gw2.futures if f.status == "queued_admission"]
    assert len(q2) == 1
    assert q2[0].submission.priority == 3
    assert q2[0].submission.tuner is not None      # tuner rode along
    gw2.join()
    assert all(f.done() for f in gw2.futures)
    gw2.close()


def test_v5_container_sniff_and_digest_detection(tmp_path):
    svc = StudyService(SearchPlanDB(), SimulatedTrainer(), n_workers=2)
    svc.submit(A, tuner(100))
    svc.run_until(60.0)
    path = str(tmp_path / "s.snap")
    svc.snapshot(path)
    data = (tmp_path / "s.snap").read_bytes()
    assert is_v5_snapshot(data)
    assert not data.startswith(b"\x80")            # no longer a bare pickle
    assert not is_v5_snapshot(b"\x80\x04whatever")
    # flip one payload byte: the record digest catches it as ValueError
    # (NOT an unpickle crash), so rotation readers can fall back
    torn = bytearray(data)
    torn[-1] ^= 0xFF
    with open(path, "wb") as f:
        f.write(bytes(torn))
    with pytest.raises(ValueError, match="digest|truncated"):
        load_session(path)
    svc.close()


def test_corrupt_newest_rotation_slot_falls_back(tmp_path):
    from repro.core.engine.session import capture_session
    base = str(tmp_path / "rot.snap")
    svc = StudyService(SearchPlanDB(), SimulatedTrainer(), n_workers=2)
    svc.submit(A, tuner(100))
    svc.run_until(40.0)
    save_session_rotated(capture_session(
        svc._engine, service={"futures": svc._futures}), base)
    svc.run_until(80.0)
    save_session_rotated(capture_session(
        svc._engine, service={"futures": svc._futures}), base)
    # corrupt the newest slot's tail
    newest = sorted(tmp_path.iterdir())[-1]
    data = bytearray(newest.read_bytes())
    data[-1] ^= 0xFF
    newest.write_bytes(bytes(data))
    state, path = load_latest_session(base)
    assert path.endswith(".1")          # fell back past the torn slot
    svc.close()


def test_v4_pickle_snapshot_migrates_forward(tmp_path):
    """A pre-PR-10 session snapshot (bare versioned pickle, 7-field worker
    rows) still restores: sniffed by magic byte, migrated in place."""
    from repro.core.engine.session import capture_session
    db = SearchPlanDB()
    svc = StudyService(db, SimulatedTrainer(), n_workers=4)
    svc.submit(A, tuner(200))
    svc.run_until(150.0)
    state = capture_session(svc._engine, service={"futures": svc._futures})
    reference = svc.close()

    state.version = 4
    state.workers = [tuple(row)[:7] for row in state.workers]
    legacy = str(tmp_path / "v4.pkl")
    with open(legacy, "wb") as f:
        pickle.dump(state, f)          # exactly what v4 save_session wrote

    svc2 = StudyService.restore(SearchPlanDB(), legacy, SimulatedTrainer())
    assert all(not w.draining for w in svc2.engine.workers)
    resumed = svc2.close()
    assert det(resumed) == det(reference)


def test_session_and_gateway_restores_reject_each_other(tmp_path):
    gw = _mid_run_gateway()
    gpath = str(tmp_path / "gw.snap")
    gw.snapshot(gpath)
    with pytest.raises(ValueError, match="gateway envelope"):
        StudyService.restore(SearchPlanDB(), gpath, SimulatedTrainer())

    svc = StudyService(SearchPlanDB(), SimulatedTrainer(), n_workers=2)
    svc.submit(A, tuner(100))
    svc.run_until(50.0)
    spath = str(tmp_path / "s.snap")
    svc.snapshot(spath)
    with pytest.raises(ValueError, match="single session"):
        StudyGateway.restore(SearchPlanDB(), spath, SimulatedTrainer())
    gw.close()
    svc.close()


def test_encode_decode_roundtrip_types(tmp_path):
    gw = _mid_run_gateway()
    state = gw._capture()
    data = encode_snapshot(state)
    back = decode_snapshot(data)
    assert isinstance(back, GatewayState)
    assert back.time == state.time
    assert back.quotas == state.quotas
    assert [k for k, _ in back.sessions] == [k for k, _ in state.sessions]
    assert back.leases == state.leases
    with pytest.raises(TypeError, match="cannot snapshot"):
        encode_snapshot({"not": "a state"})
    gw.close()


def test_startup_sweep_reclaims_crashed_writer_in_unused_slot(tmp_path):
    """The satellite: a writer that crashed mid-write into a rotation slot
    no later writer touches leaves a tmp that only the STARTUP sweep can
    reclaim (per-write sweeps happen after writes; slot .1 is never
    written again once .2+ exist)."""
    base = str(tmp_path / "rot.snap")
    from repro.core.engine.session import capture_session
    svc = StudyService(SearchPlanDB(), SimulatedTrainer(), n_workers=2)
    svc.submit(A, tuner(100))
    svc.run_until(40.0)
    state = capture_session(svc._engine, service={"futures": svc._futures})
    save_session_rotated(state, base)
    save_session_rotated(state, base)
    # a dead writer's torn tmp in slot .1 — pid 1 is init, never ours;
    # use a pid that cannot be alive (beyond pid_max)
    dead = tmp_path / "rot.snap.1.tmp.999999999.140000000000"
    dead.write_bytes(b"torn")
    # and a LIVE writer's tmp, which must survive the sweep
    live = tmp_path / f"rot.snap.2.tmp.{os.getpid()}.1"
    live.write_bytes(b"in flight")
    state2, _ = load_latest_session(base)      # startup path sweeps
    assert not dead.exists()
    assert live.exists()
    assert sweep_session_tmps(base) == 0       # nothing else to reclaim
    svc.close()


# ---------------------------------------------------------------------------
# faults + accounting reconciliation
# ---------------------------------------------------------------------------


def test_ledger_reconciles_with_by_study_under_faults_and_cancel():
    """The satellite: per-tenant ledger GPU-seconds are exactly the
    split-charged ``EngineStats.by_study`` totals — under injected faults
    (whose waste lands in ``wasted_gpu_seconds``, never in any tenant's
    bill) and mid-run cancellation."""
    inj = FaultInjector(7, stage_fault_rate=0.05, crash_rate=0.02)
    gw = StudyGateway(SearchPlanDB(), SimulatedTrainer(), n_slots=4,
                      quotas={"alice": TenantQuota(weight=2.0),
                              "bob": TenantQuota()},
                      fault_injector=inj)
    fa = gw.submit(A, tuner(200), tenant="alice")
    fb = gw.submit(A, tuner(160), tenant="bob", at=40.0)
    fc = gw.submit(B, tuner(160), tenant="bob", at=40.0)
    gw.run_until(120.0)
    assert fb.cancel()                 # bob walks away mid-run
    gw.join()
    assert fa.done() and fc.done() and fb.cancelled()
    archive = gw.close()
    stats = dict(archive)
    assert inj.injected > 0
    total_wasted = sum(s.wasted_gpu_seconds for s in stats.values())
    assert total_wasted > 0
    ledger = gw.tenant_ledger()
    # ledger == by_study, summed across every session
    by_study_total = sum(ss.gpu_seconds for s in stats.values()
                         for ss in s.by_study.values())
    ledger_total = sum(e["gpu_seconds"] for e in ledger.values())
    assert ledger_total == pytest.approx(by_study_total)
    # split-charged + never-billed waste stays within the engine totals
    engine_total = sum(s.gpu_seconds for s in stats.values())
    assert by_study_total <= engine_total + 1e-6
    for s in stats.values():
        assert sum(ss.gpu_seconds for ss in s.by_study.values()) \
            <= s.gpu_seconds + 1e-6    # waste never split-charged


def test_admission_faults_defer_but_never_lose_studies():
    inj = FaultInjector(3, admission_fault_rate=1.0, max_faults=2)
    gw = StudyGateway(SearchPlanDB(), SimulatedTrainer(), n_slots=2,
                      fault_injector=inj)
    futs = [gw.submit(A, tuner(100)), gw.submit(B, tuner(100))]
    assert gw.admission.admission_faults >= 1     # at least one deferral
    gw.join()
    assert all(f.done() for f in futs)            # retried, none lost
    assert inj.by_kind.get("admission", 0) >= 1
    gw.close()


def test_faulty_gateway_snapshot_restore_identical(tmp_path):
    """Fault schedules survive the envelope: a restored gateway CONTINUES
    the captured mid-run fault stream (same final stats as uninterrupted),
    rather than replaying it from the seed."""
    def build(inj):
        gw = StudyGateway(SearchPlanDB(), SimulatedTrainer(), n_slots=4,
                          fault_injector=inj)
        gw.submit(A, tuner(200))
        gw.submit(B, tuner(160), at=40.0)
        return gw

    gw = build(FaultInjector(11, stage_fault_rate=0.05, crash_rate=0.02))
    gw.run_until(150.0)
    path = str(tmp_path / "gw.snap")
    gw.snapshot(path)
    gw.join()
    ref = {k: det(s) for k, s in gw.close()}

    inj2 = FaultInjector(11, stage_fault_rate=0.05, crash_rate=0.02)
    gw2 = StudyGateway.restore(SearchPlanDB(), path, SimulatedTrainer(),
                               fault_injector=inj2)
    gw2.join()
    res = {k: det(s) for k, s in gw2.close()}
    assert res == ref
