"""Merge rates p and q (§6, "Merge rate").

The property half needs ``hypothesis``; without it the same bounds are
still exercised on a deterministic fixed-seed corpus (one visible skip
marks the missing randomized half).
"""

import random

import pytest

try:
    from hypothesis import given, settings, strategies as st
except ImportError:            # deterministic fallbacks below still run
    given = None

from repro.core.hpseq import Constant, HpConfig, MultiStep, StepLR
from repro.core.merge import (k_wise_merge_rate, merge_rate, total_steps,
                              unique_steps)
from repro.core.trial import Trial


def mk(lr, steps):
    return Trial(HpConfig({"lr": lr}), steps)


def test_identical_trials_merge_rate_is_n():
    """'if there are N identical trials, the merge rate p is N'."""
    trials = [mk(Constant(0.1), 100) for _ in range(5)]
    assert merge_rate(trials) == pytest.approx(5.0)


def test_disjoint_trials_merge_rate_is_one():
    trials = [mk(Constant(0.1), 100), mk(Constant(0.01), 100)]
    assert merge_rate(trials) == pytest.approx(1.0)


def test_partial_prefix():
    # share [0,100): unique = 100 + 100 + 100 = 300, total = 400
    trials = [mk(MultiStep(0.1, [100], values=[0.1, 0.05]), 200),
              mk(MultiStep(0.1, [100], values=[0.1, 0.01]), 200)]
    assert merge_rate(trials) == pytest.approx(400 / 300)


def test_nested_milestone_overlap():
    a = mk(StepLR(0.1, 0.1, [90, 135]), 200)
    b = mk(StepLR(0.1, 0.1, [100, 150]), 200)
    # share [0,90): unique = 100 + (200-90) + (200-100)... compute:
    # root [0,100) serves both prefixes (split at 90 for a): unique =
    # 100 (root span) + 110 (a's tail) + 100 (b's tail) = 310
    assert unique_steps([a, b]) == 310
    assert total_steps([a, b]) == 400


def test_k_wise_merge_rate():
    s1 = [mk(Constant(0.1), 100), mk(Constant(0.01), 100)]
    s2 = [mk(Constant(0.1), 100), mk(Constant(0.001), 100)]
    # jointly: 0.1 shared across studies → unique 300, total 400
    assert k_wise_merge_rate([s1, s2]) == pytest.approx(400 / 300)


def _check_merge_rate_bounds(trials):
    """1 ≤ p ≤ n, and unique ≤ total always."""
    u, t = unique_steps(trials), total_steps(trials)
    assert 0 < u <= t
    assert 1.0 <= merge_rate(trials) <= len(trials) + 1e-9


def _random_trials(rng):
    def fn():
        if rng.random() < 0.5:
            return Constant(rng.choice([0.1, 0.05, 0.01]))
        return StepLR(0.1, 0.1, [rng.randint(10, 90)])
    return [mk(fn(), rng.randint(10, 150))
            for _ in range(rng.randint(1, 6))]


@pytest.mark.parametrize("case", range(40))
def test_merge_rate_bounds_fixed_seed(case):
    """Deterministic stand-in for the hypothesis property (same sample
    space, fixed seed) — runs whether or not hypothesis is installed."""
    _check_merge_rate_bounds(_random_trials(random.Random(case)))


if given is not None:
    lr_strat = st.one_of(
        st.builds(Constant, st.sampled_from([0.1, 0.05, 0.01])),
        st.builds(lambda m: StepLR(0.1, 0.1, [m]), st.integers(10, 90)),
    )

    @settings(max_examples=40, deadline=None)
    @given(st.lists(st.builds(lambda f, n: mk(f, n), lr_strat,
                              st.integers(10, 150)), min_size=1, max_size=6))
    def test_merge_rate_bounds(trials):
        _check_merge_rate_bounds(trials)
else:
    def test_merge_rate_bounds():
        pytest.skip("property half needs hypothesis; fixed-seed cases ran")
