"""Search plan (§3.2): prefix merging, stage splits, request handling."""

import pytest

from repro.core.hpseq import Constant, HpConfig, MultiStep, StepLR
from repro.core.searchplan import Request, SearchPlan
from repro.core.trial import Trial


def mk(lr, steps, **static):
    return Trial(HpConfig({"lr": lr}, static or None), steps)


def test_figure3_merging():
    """The paper's Figure 3/4 study: four trials sharing lr=0.1 prefixes."""
    plan = SearchPlan()
    t1 = mk(MultiStep(0.1, [200], values=[0.1, 0.01]), 300)
    t2 = mk(MultiStep(0.1, [100, 200], values=[0.1, 0.05, 0.02]), 300)
    t3 = mk(MultiStep(0.1, [100], values=[0.1, 0.05]), 300)
    t4 = mk(MultiStep(0.1, [100, 200], values=[0.1, 0.02, 0.01]), 300)
    for t in (t1, t2, t3, t4):
        plan.submit(t)
    # one shared root holding lr=0.1 (stage A1 of Figure 4)
    roots = plan.children[None]
    assert len(roots) == 1
    root = plan.nodes[roots[0]]
    assert root.trials == {t1.trial_id, t2.trial_id, t3.trial_id, t4.trial_id}
    # t2 and t3 share the lr=0.05 @100 node (stage B1)
    kids = {plan.nodes[c].desc["hps"]["lr"]["value"]: plan.nodes[c]
            for c in plan.children[root.node_id]}
    assert set(kids) == {0.05, 0.02, 0.01}
    assert kids[0.05].trials == {t2.trial_id, t3.trial_id}


def test_trial5_split_adds_request_not_node_removal():
    """Figure 5: a trial with a boundary at 150 reuses the @100 node — the
    split is a new *request*, not a tree rewrite."""
    plan = SearchPlan()
    t1 = mk(MultiStep(0.1, [200], values=[0.1, 0.01]), 300)
    plan.submit(t1)
    n_nodes = len(plan.nodes)
    t5 = mk(MultiStep(0.1, [150], values=[0.1, 0.02]), 300)
    node5, step5, sat = plan.submit(t5)
    # the shared lr=0.1 root gained no replacement; one new leaf for 0.02@150
    assert len(plan.nodes) == n_nodes + 1
    root = plan.nodes[plan.children[None][0]]
    assert t5.trial_id in root.trials


def test_submit_returns_satisfied_when_metrics_exist():
    plan = SearchPlan()
    t = mk(Constant(0.1), 100)
    node, step, sat = plan.submit(t)
    assert not sat and step == 100
    plan.record_result(node.node_id, 100, "ckpt-x", {"val_acc": 0.9})
    t_same = mk(Constant(0.1), 100)
    node2, step2, sat2 = plan.submit(t_same)
    assert sat2 and node2.node_id == node.node_id
    assert plan.metrics_for(node.node_id, 100) == {"val_acc": 0.9}


def test_pending_excludes_running_and_done():
    plan = SearchPlan()
    t = mk(Constant(0.1), 100)
    node, _, _ = plan.submit(t)
    assert plan.pending_requests() == [Request(node.node_id, 100)]
    plan.mark_running([Request(node.node_id, 100)])
    assert plan.pending_requests() == []
    plan.record_result(node.node_id, 100, "c", {"m": 1.0})
    assert plan.pending_requests() == []


def test_static_hp_prevents_merge():
    plan = SearchPlan()
    plan.submit(mk(Constant(0.1), 100, wd=1e-4))
    plan.submit(mk(Constant(0.1), 100, wd=1e-3))
    assert len(plan.children[None]) == 2       # no shared prefix


def test_path_key_identifies_value_trajectory():
    plan = SearchPlan()
    a = mk(Constant(0.1), 100)
    b = mk(StepLR(0.1, 0.1, [100]), 200)       # same values on [0,100)
    na, _, _ = plan.submit(a)
    nb, _, _ = plan.submit(b)
    # both route through the same root → same path prefix
    assert plan.path_to_root(nb.node_id)[0].node_id == na.node_id


def test_release_trial_refcounts():
    plan = SearchPlan()
    a = mk(Constant(0.1), 100)
    b = mk(StepLR(0.1, 0.1, [100]), 200)
    na, _, _ = plan.submit(a)
    plan.submit(b)
    dead = plan.release_trial(a.trial_id)
    assert dead == []                          # root still referenced by b
    dead = plan.release_trial(b.trial_id)
    assert len(dead) >= 1                      # now everything is orphaned


def test_json_roundtrip():
    plan = SearchPlan("k")
    t = mk(StepLR(0.1, 0.1, [60]), 120)
    node, _, _ = plan.submit(t)
    plan.record_result(node.node_id, 120, "ck", {"val_acc": 0.5})
    plan.record_profile(node.node_id, 0.25)
    plan2 = SearchPlan.from_json(plan.to_json())
    assert set(plan2.nodes) == set(plan.nodes)
    n2 = plan2.nodes[node.node_id]
    assert n2.ckpts == {120: "ck"}
    assert n2.metrics[120] == {"val_acc": 0.5}
    # resubmitting the same trial into the restored plan dedups
    node3, _, sat = plan2.submit(mk(StepLR(0.1, 0.1, [60]), 120))
    assert sat
