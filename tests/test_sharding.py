"""Sharding rules: every arch's full-size param tree gets valid, divisible
specs on the production meshes (no device allocation — eval_shape only)."""

import jax
import pytest
from jax.sharding import PartitionSpec as P

pytest.importorskip(
    "repro.dist.sharding",
    reason="repro.dist not present in this checkout (sharding rules pending)")
from repro.configs import SHAPES, config_for_shape, get_config, list_archs
from repro.dist.sharding import (MESH_SIZES, ShardingRules, _axis_size,
                                 batch_specs, cache_specs, param_specs)
from repro.launch.specs import batch_struct
from repro.models import LM


def _check_divisible(shapes, specs):
    def chk(leaf, spec):
        assert isinstance(spec, P)
        assert len(spec) <= leaf.ndim, (leaf.shape, spec)
        for dim, ax in zip(leaf.shape, tuple(spec) + (None,) * leaf.ndim):
            if ax is None:
                continue
            assert dim % _axis_size(ax, MESH_SIZES) == 0, (leaf.shape, spec)
    jax.tree.map(chk, shapes, specs,
                 is_leaf=lambda x: isinstance(x, P))


@pytest.mark.parametrize("arch", list_archs())
@pytest.mark.parametrize("multi_pod", [False, True])
def test_param_specs_divisible(arch, multi_pod):
    cfg = get_config(arch)
    rules = ShardingRules.for_mesh(multi_pod)
    shapes = jax.eval_shape(LM(cfg).init, jax.random.PRNGKey(0))
    specs = param_specs(shapes, rules)
    _check_divisible(shapes, specs)


@pytest.mark.parametrize("arch", list_archs())
def test_weight_matrices_are_sharded(arch):
    """The big tensors must not silently fall back to replication."""
    cfg = get_config(arch)
    rules = ShardingRules.for_mesh(False)
    shapes = jax.eval_shape(LM(cfg).init, jax.random.PRNGKey(0))
    specs = param_specs(shapes, rules)
    leaves = list(zip(jax.tree.leaves(shapes),
                      jax.tree.leaves(specs, is_leaf=lambda x: isinstance(x, P))))
    big = [(l, s) for l, s in leaves if l.size >= 1_000_000]
    assert big
    for leaf, spec in big:
        n_axes = sum(1 for a in spec if a is not None)
        assert n_axes >= 1, (leaf.shape, spec)


@pytest.mark.parametrize("arch", list_archs())
@pytest.mark.parametrize("shape_name", ["train_4k", "prefill_32k"])
def test_batch_specs_divisible(arch, shape_name):
    cfg = get_config(arch)
    shape = SHAPES[shape_name]
    rules = ShardingRules.for_mesh(True)
    batch = batch_struct(cfg, shape.global_batch, shape.seq_len)
    specs = batch_specs(cfg, batch, rules)
    _check_divisible(batch, specs)


@pytest.mark.parametrize("arch", ["yi-34b", "mamba2-2.7b",
                                  "recurrentgemma-2b", "grok-1-314b"])
@pytest.mark.parametrize("shape_name", ["decode_32k", "long_500k"])
def test_cache_specs_divisible(arch, shape_name):
    shape = SHAPES[shape_name]
    cfg = config_for_shape(get_config(arch), shape)
    rules = ShardingRules.for_mesh(False)
    model = LM(cfg)
    cache = jax.eval_shape(
        lambda: model.init_cache(shape.global_batch, shape.seq_len))
    specs = cache_specs(cfg, cache, rules, shape.global_batch)
    _check_divisible(cache, specs)


def test_expert_parallel_only_on_multipod():
    cfg = get_config("grok-1-314b")
    shapes = jax.eval_shape(LM(cfg).init, jax.random.PRNGKey(0))
    sp_single = param_specs(shapes, ShardingRules.for_mesh(False))
    sp_multi = param_specs(shapes, ShardingRules.for_mesh(True))
    wi_single = sp_single["cycles"][0]["ffn"]["wi"]
    wi_multi = sp_multi["cycles"][0]["ffn"]["wi"]
    assert wi_single[1] is None                     # expert dim unsharded
    assert wi_multi[1] == "pod"                     # expert-parallel over pod


def test_vocab_not_sharded_when_indivisible():
    cfg = get_config("mamba2-2.7b")                 # vocab 50280 % 16 != 0
    shapes = jax.eval_shape(LM(cfg).init, jax.random.PRNGKey(0))
    specs = param_specs(shapes, ShardingRules.for_mesh(False))
    assert specs["embed"][0] is None
    assert specs["embed"][1] == "data"              # d_model still FSDP
