"""Sharding rules: every arch's full-size param tree gets valid, divisible
specs on the production meshes (no device allocation — eval_shape only).

The grok-1-314b / yi-34b full-size param trees are the costly cases and are
marked ``slow`` (tier-1 deselects them via ``addopts = -m "not slow"``; CI
runs the full matrix in a separate ``-m slow`` step).  Reduced-config
equivalents of the slow cases keep the same properties in tier-1.
"""

import functools

import jax
import pytest
from jax.sharding import PartitionSpec as P

from repro.configs import SHAPES, config_for_shape, get_config, list_archs
from repro.dist.sharding import (MESH_SIZES, ShardingRules, _axis_size,
                                 batch_specs, cache_specs, param_specs)
from repro.launch.specs import batch_struct
from repro.models import LM

# full-size param trees that dominate the module's runtime → CI-only
SLOW_ARCHS = ("grok-1-314b", "yi-34b")


def _arch_params(archs=None):
    return [pytest.param(a, marks=pytest.mark.slow) if a in SLOW_ARCHS else a
            for a in (archs or list_archs())]


@functools.lru_cache(maxsize=None)
def _param_shapes(arch, reduced=False):
    cfg = get_config(arch)
    if reduced:
        cfg = cfg.reduced()
    return jax.eval_shape(LM(cfg).init, jax.random.PRNGKey(0))


def _check_divisible(shapes, specs):
    def chk(leaf, spec):
        assert isinstance(spec, P)
        assert len(spec) <= leaf.ndim, (leaf.shape, spec)
        for dim, ax in zip(leaf.shape, tuple(spec) + (None,) * leaf.ndim):
            if ax is None:
                continue
            assert dim % _axis_size(ax, MESH_SIZES) == 0, (leaf.shape, spec)
    jax.tree.map(chk, shapes, specs,
                 is_leaf=lambda x: isinstance(x, P))


@pytest.mark.parametrize("arch", _arch_params())
@pytest.mark.parametrize("multi_pod", [False, True])
def test_param_specs_divisible(arch, multi_pod):
    rules = ShardingRules.for_mesh(multi_pod)
    shapes = _param_shapes(arch)
    specs = param_specs(shapes, rules)
    _check_divisible(shapes, specs)


@pytest.mark.parametrize("arch", sorted(SLOW_ARCHS))
@pytest.mark.parametrize("multi_pod", [False, True])
def test_param_specs_divisible_reduced(arch, multi_pod):
    """Tier-1 stand-in for the slow full-size trees: the same rules on the
    reduced variant of the same family must stay divisible too."""
    rules = ShardingRules.for_mesh(multi_pod)
    shapes = _param_shapes(arch, reduced=True)
    _check_divisible(shapes, param_specs(shapes, rules))


@pytest.mark.parametrize("arch", _arch_params())
def test_weight_matrices_are_sharded(arch):
    """The big tensors must not silently fall back to replication."""
    rules = ShardingRules.for_mesh(False)
    shapes = _param_shapes(arch)
    specs = param_specs(shapes, rules)
    leaves = list(zip(jax.tree.leaves(shapes),
                      jax.tree.leaves(specs, is_leaf=lambda x: isinstance(x, P))))
    big = [(l, s) for l, s in leaves if l.size >= 1_000_000]
    assert big
    for leaf, spec in big:
        n_axes = sum(1 for a in spec if a is not None)
        assert n_axes >= 1, (leaf.shape, spec)


@pytest.mark.parametrize("arch", list_archs())
@pytest.mark.parametrize("shape_name", ["train_4k", "prefill_32k"])
def test_batch_specs_divisible(arch, shape_name):
    cfg = get_config(arch)
    shape = SHAPES[shape_name]
    rules = ShardingRules.for_mesh(True)
    batch = batch_struct(cfg, shape.global_batch, shape.seq_len)
    specs = batch_specs(cfg, batch, rules)
    _check_divisible(batch, specs)


@pytest.mark.parametrize("arch", ["yi-34b", "mamba2-2.7b",
                                  "recurrentgemma-2b", "grok-1-314b"])
@pytest.mark.parametrize("shape_name", ["decode_32k", "long_500k"])
def test_cache_specs_divisible(arch, shape_name):
    shape = SHAPES[shape_name]
    cfg = config_for_shape(get_config(arch), shape)
    rules = ShardingRules.for_mesh(False)
    model = LM(cfg)
    cache = jax.eval_shape(
        lambda: model.init_cache(shape.global_batch, shape.seq_len))
    specs = cache_specs(cfg, cache, rules, shape.global_batch)
    _check_divisible(cache, specs)


@pytest.mark.slow
def test_expert_parallel_only_on_multipod():
    shapes = _param_shapes("grok-1-314b")
    sp_single = param_specs(shapes, ShardingRules.for_mesh(False))
    sp_multi = param_specs(shapes, ShardingRules.for_mesh(True))
    wi_single = sp_single["cycles"][0]["ffn"]["wi"]
    wi_multi = sp_multi["cycles"][0]["ffn"]["wi"]
    assert wi_single[1] is None                     # expert dim unsharded
    assert wi_multi[1] == "pod"                     # expert-parallel over pod



def test_expert_parallel_only_on_multipod_reduced():
    """Same property on the reduced grok (4 experts, still pod-divisible)."""
    shapes = _param_shapes("grok-1-314b", reduced=True)
    sp_single = param_specs(shapes, ShardingRules.for_mesh(False))
    sp_multi = param_specs(shapes, ShardingRules.for_mesh(True))
    assert sp_single["cycles"][0]["ffn"]["wi"][1] is None
    assert sp_multi["cycles"][0]["ffn"]["wi"][1] == "pod"


def test_vocab_not_sharded_when_indivisible():
    shapes = _param_shapes("mamba2-2.7b")           # vocab 50280 % 16 != 0
    specs = param_specs(shapes, ShardingRules.for_mesh(False))
    assert specs["embed"][0] is None
    assert specs["embed"][1] == "data"              # d_model still FSDP


def test_optimizer_state_mirrors_param_specs():
    """Adam moments live under {"m","v"} but mirror the param tree — the
    same rules must shard them identically (the launcher relies on this)."""
    from repro.train.optimizer import init_opt_state
    shapes = _param_shapes("qwen2-0.5b", reduced=True)
    opt = jax.eval_shape(lambda p: init_opt_state("adamw", p), shapes)
    rules = ShardingRules.for_mesh(False)
    pspec = param_specs(shapes, rules)
    ospec = param_specs(opt, rules)
    assert ospec["m"] == pspec and ospec["v"] == pspec


def test_local_mesh_sizes_override():
    """Passing the live mesh's sizes relaxes the gate to that mesh — on a
    1-device mesh every proposed axis survives."""
    shapes = _param_shapes("mamba2-2.7b", reduced=True)
    rules = ShardingRules(fsdp="data", tp="model", dp=("data",))
    specs = param_specs(shapes, rules, sizes={"data": 1, "model": 1})
    assert specs["embed"] == P("model", "data")     # vocab % 1 == 0
