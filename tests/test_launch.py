"""Launcher smoke tests: ``launch.train --reduced`` and ``launch.dryrun
--reduced`` must run end-to-end on a local 1-device mesh.

These drive the same ``main()`` code paths the CLI uses (monkeypatched
argv), which exercises the full rules → specs → NamedSharding → jit wiring
of :mod:`repro.dist.sharding` with real (tiny) compiles.
"""

import sys

import jax
import pytest

# Pin the backend to the real 1-device topology up front: the production
# (non---reduced) dry-run sets XLA_FLAGS=--xla_force_host_platform_device_count=512
# inside main(), and jax locks the device count at first backend init —
# initializing here guarantees these smoke tests always see the real mesh.
assert jax.devices()

from repro.launch import dryrun, serve_studies, train  # noqa: E402


def _run_main(monkeypatch, module, argv):
    monkeypatch.setattr(sys, "argv", argv)
    module.main()


def test_launch_train_reduced(monkeypatch, capsys):
    _run_main(monkeypatch, train,
              ["train", "--arch", "qwen2-0.5b", "--reduced",
               "--steps", "3", "--batch", "4", "--seq", "32"])
    out = capsys.readouterr().out
    assert "training qwen2-0.5b-smoke" in out
    assert "done: 3 steps" in out


def test_launch_train_reduced_use_kernel(monkeypatch, capsys):
    """--use-kernel trains through the Pallas kernel plane (interpret mode
    on CPU) and reports its call/fallback accounting."""
    from repro.kernels.ops import reset_kernel_stats
    reset_kernel_stats()       # the printed accounting is module-global
    _run_main(monkeypatch, train,
              ["train", "--arch", "qwen2-0.5b", "--reduced", "--use-kernel",
               "--steps", "2", "--batch", "2", "--seq", "16"])
    out = capsys.readouterr().out
    assert "done: 2 steps" in out
    assert "kernel plane:" in out
    assert "0 fallbacks" in out


def test_launch_train_rejects_frontend_archs(monkeypatch):
    with pytest.raises(SystemExit):
        _run_main(monkeypatch, train,
                  ["train", "--arch", "qwen2-vl-7b", "--reduced",
                   "--steps", "1"])


def test_launch_dryrun_reduced_train(monkeypatch, capsys):
    _run_main(monkeypatch, dryrun,
              ["dryrun", "--reduced", "--arch", "qwen2-0.5b",
               "--shape", "train_4k"])
    out = capsys.readouterr().out
    assert "1 ok, 0 skipped" in out and "0 errors" in out


def test_launch_dryrun_reduced_decode(monkeypatch, capsys, tmp_path):
    out_file = tmp_path / "dryrun.jsonl"
    _run_main(monkeypatch, dryrun,
              ["dryrun", "--reduced", "--arch", "mamba2-2.7b",
               "--shape", "decode_32k", "--out", str(out_file)])
    out = capsys.readouterr().out
    assert "1 ok, 0 skipped" in out and "0 errors" in out
    assert out_file.exists()


def test_launch_dryrun_reduced_skips_encoder_decode(monkeypatch, capsys):
    """Assignment-mandated skips stay skips (exit 0, not errors)."""
    _run_main(monkeypatch, dryrun,
              ["dryrun", "--reduced", "--arch", "hubert-xlarge",
               "--shape", "decode_32k"])
    out = capsys.readouterr().out
    assert "1 skipped (by design), 0 errors" in out


def test_launch_serve_studies_snapshot_resume(monkeypatch, capsys, tmp_path):
    """The service launcher's kill-and-restore path prints the same served
    totals an uninterrupted session would (simulator backend)."""
    base = ["serve_studies", "--studies", "2", "--workers", "4",
            "--steps", "60", "--arrival-gap", "600", "--sec-per-step", "10"]
    _run_main(monkeypatch, serve_studies, base)
    uninterrupted = capsys.readouterr().out
    _run_main(monkeypatch, serve_studies,
              base + ["--snapshot-at", "700",
                      "--session", str(tmp_path / "s.pkl")])
    resumed = capsys.readouterr().out
    assert "snapshot at t=" in resumed
    served = [l for l in uninterrupted.splitlines() if l.startswith("served")]
    assert served and served[0] in resumed


def test_launch_serve_studies_multi_tenant(monkeypatch, capsys, tmp_path):
    """The multi-tenant flags end-to-end: two plan keys, weighted quotas
    with a bounded queue, an admission cap, and the per-tenant ledger in
    the report."""
    _run_main(monkeypatch, serve_studies,
              ["serve_studies", "--studies", "4", "--keys", "2",
               "--workers", "4", "--steps", "60", "--arrival-gap", "600",
               "--sec-per-step", "10", "--max-concurrent", "2",
               "--tenant-quota", "alice:2.0",
               "--tenant-quota", "bob:1.0:8:2"])
    out = capsys.readouterr().out
    assert out.count("session ") == 2          # one report per plan key
    assert out.count("served:") == 2
    assert "tenant alice:" in out and "tenant bob:" in out
    assert "still queued at the door" in out


def test_launch_serve_studies_help_has_examples(monkeypatch, capsys):
    with pytest.raises(SystemExit) as ei:
        _run_main(monkeypatch, serve_studies, ["serve_studies", "--help"])
    assert ei.value.code == 0
    out = capsys.readouterr().out
    assert "examples:" in out
    assert "--tenant-quota alice:2.0" in out
    assert "--max-concurrent" in out


def test_launch_serve_studies_rejects_bad_quota(monkeypatch, capsys):
    with pytest.raises(SystemExit):
        _run_main(monkeypatch, serve_studies,
                  ["serve_studies", "--tenant-quota", "alice"])
    assert "NAME:WEIGHT" in capsys.readouterr().err


def test_dryrun_reduced_rejects_multipod(monkeypatch):
    with pytest.raises(SystemExit):
        _run_main(monkeypatch, dryrun,
                  ["dryrun", "--reduced", "--multi-pod"])
