"""Fast JAX data plane: slab prefetch, fused chunks, sibling batching,
recompute-on-miss, and the compacted incremental control plane.

Bit-exactness of the fused/batched paths on the reference ResNet lives in
``test_lossless.py``; here a tiny linear task keeps compile times low while
exercising every data-plane mechanism, plus the control-plane satellites
(per-node revision map, incremental emission, checkpoint-miss recovery).
"""

import jax
import jax.numpy as jnp
import numpy as np

from repro.core import (Constant, HpConfig, MultiStep, SearchPlanDB, Study,
                        sibling_groups, build_stage_tree, StageTreeBuilder)
from repro.core.engine import Tuner
from repro.core.searchplan import SearchPlan
from repro.core.trainer import SimulatedTrainer, StageContext
from repro.core.trial import Trial
from repro.core.tuners import GridTuner
from repro.data import DataPipeline
from repro.train.jax_trainer import JaxTrainer, chunk_lengths


# ---------------------------------------------------------------------------
# tiny reference task (fast to compile)
# ---------------------------------------------------------------------------


class TinyTask:
    """Linear softmax classifier exposing the ``init``/``loss`` protocol."""

    def __init__(self, dim: int = 16, classes: int = 4):
        self.dim, self.classes = dim, classes

    def init(self, rng):
        k1, _ = jax.random.split(rng)
        return {"w": 0.1 * jax.random.normal(k1, (self.dim, self.classes)),
                "b": jnp.zeros((self.classes,))}

    def loss(self, params, batch):
        logits = batch["x"] @ params["w"] + params["b"]
        logp = jax.nn.log_softmax(logits)
        nll = -jnp.take_along_axis(logp, batch["y"][:, None], axis=1).mean()
        acc = (jnp.argmax(logits, -1) == batch["y"]).mean()
        return nll, {"acc": acc}


def tiny_dataset(n: int = 128, dim: int = 16, classes: int = 4, seed: int = 0):
    rng = np.random.default_rng(seed)
    return {"x": rng.normal(0, 1, (n, dim)).astype(np.float32),
            "y": rng.integers(0, classes, n).astype(np.int32)}


def tiny_backend(fused: bool = True, chunk_steps: int = 8, **kw) -> JaxTrainer:
    data = tiny_dataset()
    eval_data = tiny_dataset(seed=1)
    # default to the CPU reference path regardless of the host's backend;
    # the scan-variant tests inject backend="tpu" explicitly
    kw.setdefault("backend", "cpu")
    return JaxTrainer(TinyTask(), lambda: DataPipeline(data, batch_size=8,
                                                       seed=3),
                      eval_data, default_optimizer="momentum", fused=fused,
                      chunk_steps=chunk_steps, **kw)


def assert_states_identical(a, b):
    assert a["step"] == b["step"]
    assert tuple(a["data"]) == tuple(b["data"])
    for ta, tb in ((a["params"], b["params"]), (a["opt"], b["opt"])):
        for x, y in zip(jax.tree.leaves(ta), jax.tree.leaves(tb)):
            np.testing.assert_array_equal(np.asarray(x), np.asarray(y))


# ---------------------------------------------------------------------------
# slab prefetch
# ---------------------------------------------------------------------------


def test_next_batches_matches_next_batch_across_epoch_wrap():
    data = tiny_dataset(n=50)
    a = DataPipeline(data, batch_size=8, seed=7)
    b = DataPipeline(data, batch_size=8, seed=7)
    # 20 batches of 8 over 50 rows: several epoch wraps (6 batches/epoch)
    slab = a.next_batches(20)
    singles = [b.next_batch() for _ in range(20)]
    assert slab["x"].shape == (20, 8, 16)
    for i, s in enumerate(singles):
        np.testing.assert_array_equal(slab["x"][i], s["x"])
        np.testing.assert_array_equal(slab["y"][i], s["y"])
    assert a.state() == b.state()


def test_next_batches_after_batch_size_change():
    data = tiny_dataset(n=64)
    a = DataPipeline(data, batch_size=8, seed=7)
    b = DataPipeline(data, batch_size=8, seed=7)
    a.next_batches(3)
    for _ in range(3):
        b.next_batch()
    a.set_batch_size(16)
    b.set_batch_size(16)
    slab = a.next_batches(5)
    for i in range(5):
        s = b.next_batch()
        np.testing.assert_array_equal(slab["x"][i], s["x"])
    assert a.state() == b.state()


def test_chunk_lengths_power_of_two_cover():
    assert chunk_lengths(0, 8) == []
    assert chunk_lengths(13, 8) == [8, 4, 1]
    assert chunk_lengths(24, 8) == [8, 8, 8]
    assert chunk_lengths(5, 32) == [4, 1]
    for n in range(60):
        assert sum(chunk_lengths(n, 8)) == n
        assert all(c <= 8 for c in chunk_lengths(n, 8))


# ---------------------------------------------------------------------------
# fused execution — mid-stage bs change
# ---------------------------------------------------------------------------


def test_fused_stage_with_mid_stage_bs_change_is_bitwise_exact():
    """A bs piece whose value changes *inside* one stage splits the chunk
    sequence into constant-shape runs (new executable cache entry per
    shape) and must stay bit-identical to the per-step loop."""
    fused = tiny_backend(fused=True, chunk_steps=8)
    stepwise = tiny_backend(fused=False)
    bs_fn = MultiStep(8, [5], values=[8, 16])
    desc = {"hps": {"lr": {"kind": "const", "value": 0.1},
                    "bs": {"kind": bs_fn.kind, "fn": bs_fn.to_json(),
                           "offset": 0}},
            "static": {}}
    ctx = StageContext(node_id="n0", desc=desc, node_start=0, start=0,
                       stop=12, path_key="pk")
    out_f = fused.run_stage(fused.init_state(), ctx)
    out_s = stepwise.run_stage_stepwise(stepwise.init_state(), ctx)
    assert_states_identical(out_f, out_s)
    assert out_f["data"][3] == 16
    shapes = {key[3] for key in fused._chunk_fns if key[0] == "fused"}
    batch_dims = {dict((k, s) for k, s, _ in sig)["x"][0] for sig in shapes}
    assert batch_dims == {8, 16}


def test_batched_group_equals_solo_fused():
    """run_stages_batched over divergent-lr siblings == member-by-member
    fused execution, bit for bit."""
    backend = tiny_backend()
    descs = [{"hps": {"lr": {"kind": "const", "value": v}}, "static": {}}
             for v in (0.1, 0.05, 0.02)]
    ctxs = [StageContext(f"n{i}", d, 0, 0, 10, f"pk{i}")
            for i, d in enumerate(descs)]
    states = [backend.init_state() for _ in ctxs]
    batched = backend.run_stages_batched(states, ctxs)
    for st, ctx, got in zip(states, ctxs, batched):
        solo = backend.run_stage(backend.init_state(), ctx)
        assert_states_identical(got, solo)


# ---------------------------------------------------------------------------
# backend-gated fused scan (accelerator path, structure-tested on CPU via
# backend injection; donate=False because XLA:CPU cannot honor donation)
# ---------------------------------------------------------------------------


def test_backend_gate_defaults():
    cpu = tiny_backend()                        # container default: CPU
    assert cpu.backend == "cpu"
    assert not cpu.use_scan and not cpu.vectorize_groups and not cpu._donate
    assert cpu._make_chunk_body("momentum", 4).uses_scan is False
    accel = tiny_backend(backend="tpu", donate=False)
    assert accel.use_scan and accel.vectorize_groups
    assert accel._make_chunk_body("momentum", 4).uses_scan is True
    # explicit knobs still override the gate
    pinned = tiny_backend(backend="tpu", vectorize_groups=False, donate=False)
    assert pinned.use_scan and not pinned.vectorize_groups


def test_scan_variant_matches_unrolled_numerics():
    """The lax.scan chunk body must agree with the unrolled CPU reference
    (to float tolerance — the scan path does not promise bit-exactness)."""
    ctx = StageContext("n0", {"hps": {"lr": {"kind": "const", "value": 0.1}},
                              "static": {}}, 0, 0, 13, "pk")
    unrolled = tiny_backend()
    scan = tiny_backend(backend="tpu", donate=False)
    out_u = unrolled.run_stage(unrolled.init_state(), ctx)
    out_s = scan.run_stage(scan.init_state(), ctx)
    assert out_s["step"] == out_u["step"] == 13
    assert tuple(out_s["data"]) == tuple(out_u["data"])
    for x, y in zip(jax.tree.leaves(out_u["params"]),
                    jax.tree.leaves(out_s["params"])):
        np.testing.assert_allclose(np.asarray(x), np.asarray(y),
                                   rtol=1e-5, atol=1e-6)
    assert any(k[0] == "fused" and k[-1] for k in scan._chunk_fns)


def test_scan_variant_batched_group_matches_unrolled():
    """Batched siblings on the injected accelerator backend run vmap-over-
    scan and must match the CPU member-unrolled group to float tolerance."""
    descs = [{"hps": {"lr": {"kind": "const", "value": v}}, "static": {}}
             for v in (0.1, 0.05, 0.02)]
    ctxs = [StageContext(f"n{i}", d, 0, 0, 10, f"pk{i}")
            for i, d in enumerate(descs)]
    cpu = tiny_backend()
    accel = tiny_backend(backend="tpu", donate=False)
    out_c = cpu.run_stages_batched([cpu.init_state() for _ in ctxs], ctxs)
    out_a = accel.run_stages_batched([accel.init_state() for _ in ctxs], ctxs)
    for a, c in zip(out_a, out_c):
        assert a["step"] == c["step"]
        for x, y in zip(jax.tree.leaves(c["params"]),
                        jax.tree.leaves(a["params"])):
            np.testing.assert_allclose(np.asarray(x), np.asarray(y),
                                       rtol=1e-5, atol=1e-6)
    assert any(k[0] == "group" and k[-1] and k[-2] for k in accel._chunk_fns)


# ---------------------------------------------------------------------------
# sibling grouping
# ---------------------------------------------------------------------------


def sib_trial(tail_lr, total=40):
    return Trial(HpConfig({"lr": MultiStep(0.1, [20],
                                           values=[0.1, tail_lr])}), total)


def test_sibling_groups_collects_ready_divergent_siblings():
    plan = SearchPlan()
    sibs = [sib_trial(v) for v in (0.05, 0.02, 0.01)]
    for t in sibs:
        plan.submit(t)
    other = Trial(HpConfig({"lr": Constant(0.3)}), 60)
    plan.submit(other)

    # round 1: everything is fresh — the sibling tails chain after their
    # shared prefix stage, so no ready group exists yet
    tree = build_stage_tree(plan)
    assert sibling_groups(plan, tree) == []

    # checkpoint the shared prefix at the fork: the tails become ready
    # resume stages with identical (start, stop, static, hp names)
    shared = plan.trial_paths[sibs[0].trial_id][0]
    assert all(plan.trial_paths[t.trial_id][0] == shared for t in sibs)
    plan.record_result(shared, 20, "ck@20", None)
    tree = build_stage_tree(plan)
    groups = sibling_groups(plan, tree)
    assert len(groups) == 1
    group = groups[0]
    assert len(group) == 3
    assert {(s.start, s.stop) for s in group} == {(20, 40)}
    assert all(s.resume == (shared, 20) for s in group)


def test_sibling_groups_respects_static_hps():
    """Different static hps (optimizer choice, share=False trial salts)
    never group — they would need different executables/state shapes."""
    plan = SearchPlan()
    for i, opt in enumerate(["momentum", "momentum", "adam"]):
        t = Trial(HpConfig({"lr": MultiStep(0.1, [20],
                                            values=[0.1, 0.01 * (i + 1)])},
                           {"optimizer": opt}), 40)
        plan.submit(t)
        plan.record_result(plan.trial_paths[t.trial_id][0], 20,
                           f"ck{i}", None)
    tree = build_stage_tree(plan)
    groups = sibling_groups(plan, tree)
    assert len(groups) == 1                     # only the two momentum tails
    assert len(groups[0]) == 2


def test_forced_batching_on_simulator_matches_sequential():
    """batch_siblings=True on a sequential backend uses the default
    member-loop run_stages_batched: same results, batching stats count the
    grouped dispatches."""
    def run(batch):
        db = SearchPlanDB()
        st = Study.create(db, "m", "d", ("lr",))
        tuner = GridTuner([sib_trial(v) for v in (0.05, 0.02, 0.01)])
        eng = st.engine(SimulatedTrainer(), n_workers=1,
                        batch_siblings=batch)
        stats = eng.run([tuner])
        plan = db.get(st.key)
        metrics = sorted(
            plan.nodes[plan.trial_paths[t.trial_id][-1]].metrics[40]["val_acc"]
            for t in tuner.trials)
        return stats, metrics

    s_seq, m_seq = run(False)
    s_bat, m_bat = run(True)
    assert s_seq.batched_groups == 0
    assert s_bat.batched_groups >= 1 and s_bat.batched_stages >= 2
    assert m_seq == m_bat
    assert s_seq.steps_run == s_bat.steps_run


# ---------------------------------------------------------------------------
# recompute-on-miss
# ---------------------------------------------------------------------------


class EvictingTuner(Tuner):
    """Promotes its trial to a second rung after dropping every checkpoint
    blob from the *store* (behind the plan's back) — the external-eviction
    scenario the dispatcher must degrade to recompute."""

    def __init__(self, trial, store, evict: bool = True):
        self.trial = trial
        self.store = store
        self.evict = evict
        self.final_metrics = None

    def start(self, handle):
        self.handle = handle
        handle.submit(self.trial, upto=10)

    def on_result(self, trial, step, metrics):
        if step == 10:
            if self.evict:
                for cid in list(self.store._mem):
                    self.store.evict(cid)
            self.handle.submit(self.trial, upto=20)
        elif step == 20:
            self.final_metrics = metrics

    def is_done(self):
        return self.final_metrics is not None


def test_recompute_on_miss_mid_study():
    def run(evict):
        db = SearchPlanDB()
        st = Study.create(db, "m", "d", ("lr",))
        eng = st.engine(SimulatedTrainer(), n_workers=2)
        tuner = EvictingTuner(Trial(HpConfig({"lr": Constant(0.1)}), 20),
                              eng.store, evict=evict)
        stats = eng.run([tuner])
        return stats, tuner.final_metrics

    stats_ok, metrics_ok = run(evict=False)
    stats_miss, metrics_miss = run(evict=True)
    assert stats_ok.ckpt_misses == 0
    assert stats_miss.ckpt_misses == 1     # one eviction counts exactly once
    # degraded to recompute: the dropped rung-1 checkpoint is retrained
    assert stats_miss.steps_run == stats_ok.steps_run + 10
    # ... and the result is exactly what the undisturbed study reports
    assert metrics_miss == metrics_ok


# ---------------------------------------------------------------------------
# compacted change tracking + incremental emission
# ---------------------------------------------------------------------------


def test_changes_since_is_bounded_and_ordered():
    plan = SearchPlan()
    t1 = Trial(HpConfig({"lr": Constant(0.1)}), 100)
    t2 = Trial(HpConfig({"lr": Constant(0.2)}), 100)
    plan.submit(t1)
    plan.submit(t2)
    n1 = plan.trial_paths[t1.trial_id][-1]
    n2 = plan.trial_paths[t2.trial_id][-1]

    plan.record_result(n1, 50, "ck", None)
    rev_after_n1 = plan.revision
    plan.record_result(n2, 50, "ck", None)
    plan.record_result(n1, 100, "ck", {"val_acc": 0.5})

    _, dirty_all = plan.changes_since(0)
    assert dirty_all == {n1, n2}
    _, dirty_tail = plan.changes_since(rev_after_n1)
    assert dirty_tail == {n1, n2}
    rev_now, dirty_none = plan.changes_since(plan.revision)
    assert rev_now == plan.revision and dirty_none == set()

    # bounded: repeated mutations keep one entry per node, not a log
    for _ in range(50):
        plan.record_result(n1, 100, "ck", {"val_acc": 0.5})
    assert len(plan._node_rev) == 2


def test_emission_reused_when_resolutions_unchanged():
    """A revision bump that changes no resolution (re-submitting an already
    known trial) must return the cached forest without re-emitting."""
    plan = SearchPlan()
    t = Trial(HpConfig({"lr": Constant(0.1)}), 100)
    plan.submit(t)
    builder = StageTreeBuilder(plan, verify=True)
    t1 = builder.build()
    plan.submit(Trial(HpConfig({"lr": Constant(0.1)}), 100))  # same node
    t2 = builder.build()
    assert builder.tree_cache_hits == 0          # revision DID change
    assert builder.forest_reuses == 1
    assert t2 is t1
    plan.submit(Trial(HpConfig({"lr": Constant(0.1)}), 100))
    assert builder.build() is t1                 # reused again
    assert builder.forest_reuses == 2
    # a real change (new divergent trial) must rebuild the forest
    plan.submit(Trial(HpConfig({"lr": Constant(0.7)}), 100))
    t3 = builder.build()
    assert t3 is not t1 and len(t3) == len(t1) + 1


# ---------------------------------------------------------------------------
# chain fusion: device-resident carries across stage boundaries
# ---------------------------------------------------------------------------


def const_ctx(start, stop, lr=0.05, bs=None, nid="n0", pk="pk"):
    hps = {"lr": {"kind": "const", "value": lr}}
    if bs is not None:
        hps["bs"] = {"kind": "const", "value": bs}
    return StageContext(nid, {"hps": hps, "static": {}}, 0, start, stop, pk)


def test_run_chain_equals_per_stage_loop_bitwise():
    """run_chain keeps (params, opt) and the pipeline live across stage
    boundaries; every boundary snapshot must be bit-identical to the
    per-stage run_stage loop — including across an epoch wrap (dataset 128
    / bs 8 wraps at step 16) and a boundary batch-size change."""
    fused = tiny_backend()
    ctxs = [const_ctx(0, 7, bs=8), const_ctx(7, 18, bs=8),
            const_ctx(18, 27, bs=16)]
    chain_out = fused.run_chain(fused.init_state(), ctxs)
    state = fused.init_state()
    for ctx, got in zip(ctxs, chain_out):
        state = fused.run_stage(state, ctx)
        assert_states_identical(got, state)


def test_run_chain_zero_step_stage_passes_through():
    fused = tiny_backend()
    ctxs = [const_ctx(0, 8, bs=8), const_ctx(8, 8, bs=8),
            const_ctx(8, 12, bs=8)]
    outs = fused.run_chain(fused.init_state(), ctxs)
    assert outs[1]["step"] == 8
    assert_states_identical(outs[0], outs[1])
    assert outs[2]["step"] == 12


def test_run_chains_batched_equals_member_sequential():
    fused = tiny_backend()
    chains = [[const_ctx(0, 9, 0.05 - 0.01 * i, nid=f"n{i}", pk=f"pk{i}"),
               const_ctx(9, 20, 0.05 - 0.01 * i, nid=f"n{i}", pk=f"pk{i}")]
              for i in range(3)]
    states = [fused.init_state() for _ in range(3)]
    outs = fused.run_chains_batched(states, chains)
    solo = tiny_backend()
    for st, ch, out in zip(states, chains, outs):
        ref = solo.run_chain(st, ch)
        assert len(out) == len(ref) == 2
        for x, y in zip(out, ref):
            assert_states_identical(x, y)


def test_run_chains_batched_rejects_ragged_depth():
    fused = tiny_backend()
    chains = [[const_ctx(0, 8, 0.05, nid="n0", pk="p0"),
               const_ctx(8, 16, 0.05, nid="n0", pk="p0")],
              [const_ctx(0, 8, 0.04, nid="n1", pk="p1")]]
    states = [fused.init_state(), fused.init_state()]
    import pytest
    with pytest.raises(ValueError, match="depth"):
        fused.run_chains_batched(states, chains)


def test_run_chain_rejects_non_contiguous_stages():
    fused = tiny_backend()
    import pytest
    with pytest.raises(ValueError, match="contiguous"):
        fused.run_chain(fused.init_state(),
                        [const_ctx(0, 8), const_ctx(10, 16)])
