"""Per-arch smoke tests (reduced variants) + decode consistency.

Assignment requirement: for each of the 10 architectures, instantiate a
REDUCED variant of the same family (2 layers, d_model ≤ 512, ≤ 4 experts)
and run one forward/train step on CPU asserting output shapes + no NaNs.
"""

import dataclasses

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs import ARCHS, SHAPES, get_config, list_archs
from repro.models import LM
from repro.train.optimizer import apply_update, init_opt_state

B, S = 2, 32
RNG = jax.random.PRNGKey(0)


def reduced(arch):
    return get_config(arch).reduced(d_model=128)


def make_batch(cfg, batch=B, seq=S):
    if cfg.frontend == "audio":
        return {"features": jnp.ones((batch, seq, cfg.frontend_dim)),
                "labels": jnp.zeros((batch, seq), jnp.int32)}
    if cfg.frontend == "vision":
        P = cfg.frontend_tokens
        return {"tokens": jnp.zeros((batch, seq - P), jnp.int32),
                "patches": jnp.ones((batch, P, cfg.frontend_dim)),
                "positions": jnp.broadcast_to(
                    jnp.arange(seq, dtype=jnp.int32)[None, None],
                    (3, batch, seq))}
    return {"tokens": jax.random.randint(RNG, (batch, seq), 0,
                                         cfg.vocab_size)}


@pytest.mark.parametrize("arch", list_archs())
def test_smoke_forward_shapes_and_finite(arch):
    cfg = reduced(arch)
    assert cfg.num_layers <= 3 and cfg.d_model <= 512
    assert cfg.n_experts <= 4
    m = LM(cfg)
    params = m.init(RNG)
    batch = make_batch(cfg)
    logits, aux = jax.jit(m.forward)(params, batch)
    assert logits.shape == (B, S, cfg.vocab_size)
    assert bool(jnp.isfinite(logits).all())


@pytest.mark.parametrize("arch", list_archs())
def test_smoke_param_count_exact(arch):
    cfg = reduced(arch)
    params = LM(cfg).init(RNG)
    n = sum(x.size for x in jax.tree.leaves(params))
    assert n == cfg.param_count()


@pytest.mark.parametrize("arch", list_archs())
def test_smoke_one_train_step(arch):
    cfg = reduced(arch)
    m = LM(cfg)
    params = m.init(RNG)
    opt = init_opt_state("adamw", params)
    batch = make_batch(cfg)

    @jax.jit
    def step(params, opt, batch):
        (loss, _), grads = jax.value_and_grad(m.loss, has_aux=True)(params, batch)
        params, opt = apply_update("adamw", params, grads, opt,
                                   {"lr": 1e-3}, jnp.int32(0))
        return params, opt, loss

    params2, opt2, loss = step(params, opt, batch)
    assert bool(jnp.isfinite(loss))
    for x in jax.tree.leaves(params2):
        assert bool(jnp.isfinite(x).all())
    # params actually moved
    moved = any(
        not np.array_equal(np.asarray(a), np.asarray(b))
        for a, b in zip(jax.tree.leaves(params), jax.tree.leaves(params2)))
    assert moved


@pytest.mark.parametrize("arch", [a for a in list_archs()
                                  if not ARCHS[a].is_encoder_only])
def test_smoke_decode_step(arch):
    cfg = reduced(arch)
    m = LM(cfg)
    params = m.init(RNG)
    cache = m.init_cache(B, 64)
    logits, cache2 = jax.jit(m.decode_step)(
        params, cache, jnp.zeros((B, 1), jnp.int32), jnp.int32(3))
    assert logits.shape == (B, 1, cfg.vocab_size)
    assert bool(jnp.isfinite(logits).all())


def test_encoder_only_has_no_decode():
    cfg = reduced("hubert-xlarge")
    assert cfg.is_encoder_only
    m = LM(cfg)
    with pytest.raises(AssertionError):
        m.decode_step(m.init(RNG), m.init_cache(B, 8),
                      jnp.zeros((B, 1), jnp.int32), jnp.int32(0))


@pytest.mark.parametrize("arch", ["qwen2-0.5b", "mamba2-2.7b",
                                  "recurrentgemma-2b", "qwen2-moe-a2.7b"])
def test_decode_matches_forward(arch):
    """Token-by-token decode reproduces the teacher-forced forward pass.

    MoE capacity dropping depends on the token-group size, which differs
    between a 32-token forward and 1-token decode — so the MoE case runs
    drop-free (high capacity factor), matching how serving engines disable
    token dropping at inference."""
    cfg = reduced(arch)
    if cfg.n_experts:
        cfg = dataclasses.replace(cfg, capacity_factor=16.0)
    tol = 5e-3
    m = LM(cfg)
    params = m.init(RNG)
    S_ = 32
    toks = jax.random.randint(jax.random.PRNGKey(7), (1, S_), 0,
                              cfg.vocab_size)
    full, _ = m.forward(params, {"tokens": toks})
    cache = m.init_cache(1, 64)
    outs = []
    dec = jax.jit(m.decode_step)
    for i in range(S_):
        lg, cache = dec(params, cache, toks[:, i:i + 1], jnp.int32(i))
        outs.append(lg[:, 0])
    got = jnp.stack(outs, 1)
    np.testing.assert_allclose(np.asarray(full), np.asarray(got), atol=tol)


def test_sliding_window_variant_for_long_context():
    from repro.configs import config_for_shape
    cfg = get_config("yi-34b")
    long = SHAPES["long_500k"]
    v = config_for_shape(cfg, long)
    assert v.sliding_window > 0 and v.subquadratic
    # and train shape keeps full attention
    assert config_for_shape(cfg, SHAPES["train_4k"]).sliding_window == 0


def test_shape_applicability_rules():
    from repro.configs import shape_applicable
    hub = get_config("hubert-xlarge")
    assert not shape_applicable(hub, SHAPES["decode_32k"])
    assert not shape_applicable(hub, SHAPES["long_500k"])
    assert shape_applicable(hub, SHAPES["train_4k"])
    for a in list_archs():
        assert shape_applicable(get_config(a), SHAPES["train_4k"])
