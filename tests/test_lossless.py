"""THE correctness property of stage-based execution: it is lossless.

Training a shared prefix once and forking the checkpoint must produce
bit-identical parameters and metrics to training every trial straight
through (real JAX training, deterministic pipeline, CPU floats).

The fused data plane adds two more execution paths — whole-stage chunk
executables and batched sibling groups — and both must stay bit-identical
to the seed per-step loop (``run_stage_stepwise``), including across
mid-chunk batch-size changes that force a fresh executable cache entry.
"""

import jax
import numpy as np
import pytest

from repro.core import (Constant, HpConfig, MultiStep, SearchPlanDB, StepLR,
                        Study)
from repro.core.searchplan import SearchPlan
from repro.core.trainer import StageContext
from repro.core.trial import Trial
from repro.core.tuners import GridTuner
from repro.data import DataPipeline, synthetic_cifar
from repro.models.resnet import ResNet
from repro.train.jax_trainer import JaxTrainer


@pytest.fixture(scope="module")
def setup():
    data = synthetic_cifar(256, seed=0)
    eval_data = synthetic_cifar(128, seed=1)
    task = ResNet(n=1, width=8)
    def pipe():
        return DataPipeline(data, batch_size=32, seed=3)
    # pin the CPU reference path: on an accelerator dev box the backend
    # gate would otherwise swap in the lax.scan body, which only promises
    # ~1-2 ulp — these tests assert bit equality
    backend = JaxTrainer(task, pipe, eval_data, default_optimizer="momentum",
                         backend="cpu")
    return backend


def straight_through(backend, trial, steps):
    """Run a trial solo, stage by stage along its own path."""
    plan = SearchPlan("solo-" + trial.trial_id)
    node, _, _ = plan.submit(trial, steps)
    state = backend.init_state()
    path = plan.path_to_root(node.node_id)
    for i, n in enumerate(path):
        stop = steps if i == len(path) - 1 else path[i + 1].start
        ctx = StageContext(n.node_id, n.desc, n.start, n.start, stop,
                           plan.path_key(n.node_id))
        state = backend.run_stage(state, ctx)
    return state, backend.evaluate(state, None)


def test_stage_execution_is_bitwise_lossless(setup):
    backend = setup
    trials = [
        Trial(HpConfig({"lr": Constant(0.05), "bs": Constant(32)}), 24),
        Trial(HpConfig({"lr": MultiStep(0.05, [12], values=[0.05, 0.005]),
                        "bs": Constant(32)}), 24),
        Trial(HpConfig({"lr": MultiStep(0.05, [12], values=[0.05, 0.01]),
                        "bs": MultiStep(32, [18], values=[32, 64])}), 24),
    ]

    db = SearchPlanDB()
    study = Study.create(db, "resnet8", "synth", ("lr", "bs"))
    eng = study.engine(backend, n_workers=2)
    eng.run([GridTuner(list(trials))])
    plan = db.get(study.key)

    for t in trials:
        leaf = plan.nodes[plan.trial_paths[t.trial_id][-1]]
        merged_metrics = leaf.metrics[24]
        cid = leaf.ckpts[24]
        merged_params = eng.store.get(cid)["params"]

        solo_state, solo_metrics = straight_through(backend, t, 24)
        assert merged_metrics["loss"] == solo_metrics["loss"], t
        assert merged_metrics["val_acc"] == solo_metrics["val_acc"], t
        for a, b in zip(jax.tree.leaves(merged_params),
                        jax.tree.leaves(solo_state["params"])):
            np.testing.assert_array_equal(np.asarray(a), np.asarray(b))


def test_shared_prefix_checkpoint_is_shared(setup):
    backend = setup
    a = Trial(HpConfig({"lr": Constant(0.05), "bs": Constant(32)}), 20)
    b = Trial(HpConfig({"lr": MultiStep(0.05, [10], values=[0.05, 0.005]),
                        "bs": Constant(32)}), 20)
    db = SearchPlanDB()
    study = Study.create(db, "resnet8", "synth", ("lr", "bs"))
    eng = study.engine(backend, n_workers=2)
    stats = eng.run([GridTuner([a, b])])
    # shared prefix [0,10) trained once: total steps < 40
    assert stats.steps_run == 30


def test_batch_size_change_resumes_pipeline_position(setup):
    """bs sequence changes batch shape mid-trial; the pipeline cursor must
    carry across the boundary (paper §5.1)."""
    backend = setup
    t = Trial(HpConfig({"lr": Constant(0.05),
                        "bs": MultiStep(32, [8], values=[32, 64])}), 16)
    state, metrics = straight_through(backend, t, 16)
    assert state["step"] == 16
    assert state["data"][3] == 64              # final batch size
    assert np.isfinite(metrics["loss"])


# ---------------------------------------------------------------------------
# fused data plane: all execution paths bit-identical to the per-step loop
# ---------------------------------------------------------------------------


def assert_states_identical(a, b):
    assert a["step"] == b["step"]
    assert tuple(a["data"]) == tuple(b["data"])
    for tree_a, tree_b in ((a["params"], b["params"]), (a["opt"], b["opt"])):
        la, lb = jax.tree.leaves(tree_a), jax.tree.leaves(tree_b)
        assert len(la) == len(lb)
        for x, y in zip(la, lb):
            np.testing.assert_array_equal(np.asarray(x), np.asarray(y))


def test_fused_scan_equals_stepwise_bitwise(setup):
    """Whole-stage fused execution == seed per-step loop, bit for bit —
    including a mid-chunk bs change (boundary at step 10, chunk length 8)
    that re-batches the pipeline and forces a new executable cache entry
    for the (64, ...) batch shape."""
    fused = setup
    assert fused.fused and fused.chunk_steps == 8
    stepwise = JaxTrainer(fused.task, fused.pipeline_factory,
                          {k: np.asarray(v) for k, v in fused.eval_batch.items()},
                          default_optimizer="momentum", fused=False,
                          backend="cpu")
    trials = [
        Trial(HpConfig({"lr": MultiStep(0.05, [7], values=[0.05, 0.01]),
                        "bs": Constant(32)}), 19),
        Trial(HpConfig({"lr": Constant(0.05),
                        "bs": MultiStep(32, [10], values=[32, 64])}), 16),
    ]
    for t in trials:
        fused_state, fused_metrics = straight_through(fused, t, t.total_steps)
        step_state, step_metrics = straight_through(stepwise, t, t.total_steps)
        assert_states_identical(fused_state, step_state)
        assert fused_metrics == step_metrics
    # the bs change split the stage into constant-shape runs: one executable
    # cache entry per batch shape
    batch_dims = set()
    for key in fused._chunk_fns:
        if key[0] == "fused":
            slab_sig = key[3]
            batch_dims.add({k: shape for k, shape, _ in slab_sig}["images"][0])
    assert {32, 64} <= batch_dims


def test_chain_fused_depth4_equals_stepwise_bitwise(setup):
    """Chain-fused execution (device-resident carry across stage
    boundaries, write-behind checkpoints) == seed per-step loop, bit for
    bit, on a depth-4 chain that includes a mid-chain ``report`` boundary
    (step 12) and a mid-chain batch-size change (step 16)."""
    fused = setup
    stepwise = JaxTrainer(fused.task, fused.pipeline_factory,
                          {k: np.asarray(v) for k, v in fused.eval_batch.items()},
                          default_optimizer="momentum", fused=False,
                          backend="cpu")

    trial = Trial(HpConfig({"lr": MultiStep(0.05, [8, 16],
                                            values=[0.05, 0.02, 0.01]),
                            "bs": MultiStep(32, [16], values=[32, 64])}), 24)

    class MidChainReportTuner(GridTuner):
        # both requests pending up front -> ONE chain with a report
        # boundary at 12 (stages [0,8)[8,12)*[12,16)[16,24)*)
        def start(self, handle):
            self.handle = handle
            for t in self.trials:
                handle.submit(t, upto=12)
                handle.submit(t)

        def on_result(self, t, step, metrics):
            if step == t.total_steps:
                super().on_result(t, step, metrics)

    db = SearchPlanDB()
    study = Study.create(db, "resnet8", "synth", ("lr", "bs"))
    eng = study.engine(fused, n_workers=1)
    assert eng.chain_fusion
    tuner = MidChainReportTuner([trial])
    stats = eng.run([tuner])
    assert stats.chain_fused_stages >= 4
    assert stats.ckpt_async_writes >= 4
    assert eng.store.pending_writes == 0       # shutdown flush barrier

    plan = db.get(study.key)
    leaf = plan.nodes[plan.trial_paths[trial.trial_id][-1]]
    merged_params = eng.store.get(leaf.ckpts[24])["params"]
    solo_state, solo_metrics = straight_through(stepwise, trial, 24)
    assert leaf.metrics[24]["loss"] == solo_metrics["loss"]
    assert leaf.metrics[24]["val_acc"] == solo_metrics["val_acc"]
    for a, b in zip(jax.tree.leaves(merged_params),
                    jax.tree.leaves(solo_state["params"])):
        np.testing.assert_array_equal(np.asarray(a), np.asarray(b))
    # the mid-chain report observed the state a stepwise run sees at 12
    mid = plan.nodes[plan.trial_paths[trial.trial_id][1]]
    _, mid_metrics = straight_through(stepwise, trial, 12)
    assert mid.metrics[12] == mid_metrics


def test_batched_siblings_equal_stepwise_bitwise(setup):
    """Sibling-trial batching: a group of divergent siblings executed as ONE
    compiled call must reproduce each member's straight-through per-step
    training exactly."""
    fused = setup
    stepwise = JaxTrainer(fused.task, fused.pipeline_factory,
                          {k: np.asarray(v) for k, v in fused.eval_batch.items()},
                          default_optimizer="momentum", fused=False,
                          backend="cpu")
    trials = [
        Trial(HpConfig({"lr": MultiStep(0.05, [12], values=[0.05, v]),
                        "bs": Constant(32)}), 24)
        for v in (0.02, 0.01, 0.005)
    ]
    db = SearchPlanDB()
    study = Study.create(db, "resnet8", "synth", ("lr", "bs"))
    # one worker: the prefix chain carries one sibling tail with it; the
    # other two meet as ready resume stages and batch as one group
    eng = study.engine(fused, n_workers=1)
    stats = eng.run([GridTuner(list(trials))])
    assert stats.batched_groups >= 1
    assert stats.batched_stages >= 2

    plan = db.get(study.key)
    for t in trials:
        leaf = plan.nodes[plan.trial_paths[t.trial_id][-1]]
        merged_params = eng.store.get(leaf.ckpts[24])["params"]
        solo_state, solo_metrics = straight_through(stepwise, t, 24)
        assert leaf.metrics[24]["loss"] == solo_metrics["loss"]
        for a, b in zip(jax.tree.leaves(merged_params),
                        jax.tree.leaves(solo_state["params"])):
            np.testing.assert_array_equal(np.asarray(a), np.asarray(b))


def test_delta_encoded_store_is_bitwise_lossless(setup, tmp_path):
    """Checkpoint plane v2: real training through a directory store with
    delta-encoded boundary checkpoints (the dispatcher threads each
    boundary's fork-point cid as the delta parent) must restore leaves
    bit-identical to the per-step straight-through run — delta chains and
    zero-copy reads included."""
    fused = setup
    stepwise = JaxTrainer(fused.task, fused.pipeline_factory,
                          {k: np.asarray(v) for k, v in fused.eval_batch.items()},
                          default_optimizer="momentum", fused=False,
                          backend="cpu")
    trials = [
        Trial(HpConfig({"lr": MultiStep(0.05, [8], values=[0.05, v]),
                        "bs": Constant(32)}), 16)
        for v in (0.02, 0.01)
    ]
    from repro.train.checkpoint import CheckpointStore
    store = CheckpointStore(str(tmp_path / "ckpts"))
    db = SearchPlanDB()
    study = Study.create(db, "resnet8", "synth", ("lr", "bs"))
    eng = study.engine(fused, n_workers=2, store=store)
    stats = eng.run([GridTuner(list(trials))])
    # sibling forks off a shared prefix -> boundary commits are deltas
    # (byte *reduction* is the bench's claim on partially-mutated states;
    # SGD touches every chunk, so here only the encoding path is asserted)
    assert store.delta_commits > 0
    assert stats.ckpt_delta_commits == store.delta_commits

    # cold reads straight off the blobs: drop every warm cache first
    store._read_cache.clear()
    plan = db.get(study.key)
    for t in trials:
        leaf = plan.nodes[plan.trial_paths[t.trial_id][-1]]
        restored = store.get(leaf.ckpts[16])
        solo_state, solo_metrics = straight_through(stepwise, t, 16)
        assert leaf.metrics[16]["loss"] == solo_metrics["loss"]
        assert_states_identical(
            {k: restored[k] for k in ("step", "data", "params", "opt")},
            solo_state)


def test_one_device_mesh_workers_bitwise_equal_thread_workers(setup):
    """Distribution plane v2: a fleet of width-1 worker meshes takes the
    backend's default (unsharded) execution path — leaf checkpoints and
    metrics are bit-identical to plain thread workers, while the engine
    still counts the mesh placements (and serves same-host resumes
    device-to-device)."""
    backend = setup
    trials = [
        Trial(HpConfig({"lr": MultiStep(0.05, [8], values=[0.05, v]),
                        "bs": Constant(32)}), 16)
        for v in (0.02, 0.005)
    ]

    def run(meshes):
        db = SearchPlanDB()
        study = Study.create(db, "resnet8", "synth", ("lr", "bs"))
        eng = study.engine(backend, n_workers=2, worker_meshes=meshes)
        stats = eng.run([GridTuner(list(trials))])
        return db.get(study.key), eng, stats

    from repro.dist.meshes import plan_worker_meshes
    plan_t, eng_t, stats_t = run(None)
    plan_m, eng_m, stats_m = run(plan_worker_meshes(2, 1))

    assert stats_m.mesh_placements > 0
    assert stats_t.mesh_placements == 0
    assert stats_m.steps_run == stats_t.steps_run
    for t in trials:
        leaf = plan_m.trial_paths[t.trial_id][-1]
        assert plan_m.nodes[leaf].metrics[16] == plan_t.nodes[leaf].metrics[16]
        assert_states_identical(eng_m.store.get(plan_m.nodes[leaf].ckpts[16]),
                                eng_t.store.get(plan_t.nodes[leaf].ckpts[16]))
