"""THE correctness property of stage-based execution: it is lossless.

Training a shared prefix once and forking the checkpoint must produce
bit-identical parameters and metrics to training every trial straight
through (real JAX training, deterministic pipeline, CPU floats).
"""

import jax
import numpy as np
import pytest

from repro.core import (Constant, HpConfig, MultiStep, SearchPlanDB, StepLR,
                        Study)
from repro.core.searchplan import SearchPlan
from repro.core.trainer import StageContext
from repro.core.trial import Trial
from repro.core.tuners import GridTuner
from repro.data import DataPipeline, synthetic_cifar
from repro.models.resnet import ResNet
from repro.train.jax_trainer import JaxTrainer


@pytest.fixture(scope="module")
def setup():
    data = synthetic_cifar(256, seed=0)
    eval_data = synthetic_cifar(128, seed=1)
    task = ResNet(n=1, width=8)
    def pipe():
        return DataPipeline(data, batch_size=32, seed=3)
    backend = JaxTrainer(task, pipe, eval_data, default_optimizer="momentum")
    return backend


def straight_through(backend, trial, steps):
    """Run a trial solo, stage by stage along its own path."""
    plan = SearchPlan("solo-" + trial.trial_id)
    node, _, _ = plan.submit(trial, steps)
    state = backend.init_state()
    path = plan.path_to_root(node.node_id)
    for i, n in enumerate(path):
        stop = steps if i == len(path) - 1 else path[i + 1].start
        ctx = StageContext(n.node_id, n.desc, n.start, n.start, stop,
                           plan.path_key(n.node_id))
        state = backend.run_stage(state, ctx)
    return state, backend.evaluate(state, None)


def test_stage_execution_is_bitwise_lossless(setup):
    backend = setup
    trials = [
        Trial(HpConfig({"lr": Constant(0.05), "bs": Constant(32)}), 24),
        Trial(HpConfig({"lr": MultiStep(0.05, [12], values=[0.05, 0.005]),
                        "bs": Constant(32)}), 24),
        Trial(HpConfig({"lr": MultiStep(0.05, [12], values=[0.05, 0.01]),
                        "bs": MultiStep(32, [18], values=[32, 64])}), 24),
    ]

    db = SearchPlanDB()
    study = Study.create(db, "resnet8", "synth", ("lr", "bs"))
    eng = study.engine(backend, n_workers=2)
    eng.run([GridTuner(list(trials))])
    plan = db.get(study.key)

    for t in trials:
        leaf = plan.nodes[plan.trial_paths[t.trial_id][-1]]
        merged_metrics = leaf.metrics[24]
        cid = leaf.ckpts[24]
        merged_params = eng.store.get(cid)["params"]

        solo_state, solo_metrics = straight_through(backend, t, 24)
        assert merged_metrics["loss"] == solo_metrics["loss"], t
        assert merged_metrics["val_acc"] == solo_metrics["val_acc"], t
        for a, b in zip(jax.tree.leaves(merged_params),
                        jax.tree.leaves(solo_state["params"])):
            np.testing.assert_array_equal(np.asarray(a), np.asarray(b))


def test_shared_prefix_checkpoint_is_shared(setup):
    backend = setup
    a = Trial(HpConfig({"lr": Constant(0.05), "bs": Constant(32)}), 20)
    b = Trial(HpConfig({"lr": MultiStep(0.05, [10], values=[0.05, 0.005]),
                        "bs": Constant(32)}), 20)
    db = SearchPlanDB()
    study = Study.create(db, "resnet8", "synth", ("lr", "bs"))
    eng = study.engine(backend, n_workers=2)
    stats = eng.run([GridTuner([a, b])])
    # shared prefix [0,10) trained once: total steps < 40
    assert stats.steps_run == 30


def test_batch_size_change_resumes_pipeline_position(setup):
    """bs sequence changes batch shape mid-trial; the pipeline cursor must
    carry across the boundary (paper §5.1)."""
    backend = setup
    t = Trial(HpConfig({"lr": Constant(0.05),
                        "bs": MultiStep(32, [8], values=[32, 64])}), 16)
    state, metrics = straight_through(backend, t, 16)
    assert state["step"] == 16
    assert state["data"][3] == 64              # final batch size
    assert np.isfinite(metrics["loss"])
