"""Descriptor→value reconstruction: workers see exactly the user's sequence.

The search plan stores offset-normalized piece descriptors; the trainer
reconstructs per-step values from them.  This property test guarantees the
round-trip is exact for every function family and any segmentation — the
load-bearing invariant behind lossless stage sharing.

The randomized half needs ``hypothesis``; a deterministic corpus covering
every function family runs regardless (one visible skip marks the missing
randomized half).
"""

import pytest

try:
    from hypothesis import given, settings, strategies as st
except ImportError:            # deterministic fallbacks below still run
    given = None

from repro.core.hpseq import (Constant, Cosine, Cyclic, Exponential, HpConfig,
                              Linear, MultiStep, Seq, Warmup)
from repro.core.trial import Trial
from repro.core.values import desc_value_at, desc_values

# one representative per function family (the hypothesis strategies sample
# the same families with randomized parameters)
FN_CORPUS = [
    Constant(0.3),
    MultiStep(0.5, [7, 40]),
    Exponential(0.8, 0.93),
    Linear(0.4, 33),
    Cosine(0.9, 61),
    Cyclic(0.001, 0.1, 12),
    Warmup(6, 0.2, Exponential(0.2, 0.95)),
]


def _check_reconstructs(fn, total):
    trial = Trial(HpConfig({"lr": fn}), total)
    for seg in trial.segments():
        vals = desc_values(seg.desc, seg.start, seg.start, seg.stop)["lr"]
        for i, step in enumerate(range(seg.start, seg.stop)):
            assert vals[i] == pytest.approx(fn.value(step), rel=1e-12), (
                fn, seg.start, step)


def _check_seq_extension(prefix, cont, total, at):
    if at >= total:
        at = total - 1
    f = Seq((prefix, at), (cont, None))
    trial = Trial(HpConfig({"lr": f}), total)
    for seg in trial.segments():
        for step in (seg.start, max(seg.start, seg.stop - 1)):
            v = desc_value_at(seg.desc, seg.start, step)["lr"]
            assert v == pytest.approx(f.value(step), rel=1e-12)


@pytest.mark.parametrize("fn", FN_CORPUS, ids=lambda f: type(f).__name__)
@pytest.mark.parametrize("total", [5, 37, 100])
def test_segment_descriptors_reconstruct_values_fixed(fn, total):
    _check_reconstructs(fn, total)


@pytest.mark.parametrize("i", range(len(FN_CORPUS)))
def test_seq_extension_reconstructs_fixed(i):
    prefix = FN_CORPUS[i]
    cont = FN_CORPUS[(i + 3) % len(FN_CORPUS)]
    _check_seq_extension(prefix, cont, total=60, at=25)


def test_static_values_survive():
    trial = Trial(HpConfig({"lr": Constant(0.1)},
                           {"wd": 1e-4, "optimizer": "adam"}), 10)
    seg = trial.segments()[0]
    assert seg.desc["static"] == {"optimizer": "adam", "wd": 1e-4}


if given is not None:
    hp_fn = st.one_of(
        st.builds(Constant, st.floats(0.001, 1.0)),
        st.builds(lambda b, m: MultiStep(b, sorted(set(m))),
                  st.floats(0.01, 1.0),
                  st.lists(st.integers(1, 90), min_size=1, max_size=3)),
        st.builds(Exponential, st.floats(0.01, 1.0), st.floats(0.8, 0.999)),
        st.builds(Linear, st.floats(0.01, 1.0), st.integers(1, 90)),
        st.builds(Cosine, st.floats(0.01, 1.0), st.integers(1, 90)),
        st.builds(Cyclic, st.floats(0.0001, 0.01), st.floats(0.05, 0.2),
                  st.integers(5, 30)),
        st.builds(lambda d, t: Warmup(d, t, Exponential(t, 0.95)),
                  st.integers(1, 20), st.floats(0.01, 0.5)),
    )

    @settings(max_examples=60, deadline=None)
    @given(hp_fn, st.integers(5, 100))
    def test_segment_descriptors_reconstruct_values(fn, total):
        _check_reconstructs(fn, total)

    @settings(max_examples=40, deadline=None)
    @given(hp_fn, hp_fn, st.integers(10, 80), st.integers(5, 40))
    def test_seq_extension_reconstructs(prefix, cont, total, at):
        """PBT-style Seq((prefix, at), (cont, None)) descriptors reconstruct."""
        _check_seq_extension(prefix, cont, total, at)
else:
    def test_values_property_half():
        pytest.skip("property half needs hypothesis; fixed corpus ran")
