"""The kernel plane wired into the execution path: ``use_kernel=True``
training through :class:`JaxTrainer` must match the oracle path on every
execution tier — solo stages, chain-fused runs, and vmapped sibling
groups — with ``kernel_fallbacks == 0`` (the kernels really ran).

Documented tolerance
--------------------
With the **momentum** optimizer the fused optimizer kernel performs the
identical f32 operations in the same order as ``apply_update``, so the
kernel path is *bitwise identical* to the oracle on CPU — these tests
assert exact equality.  With **adam/adamw** the kernel's fused
``sqrt``/divide sequence differs from XLA's by ~1 ulp per step
(measured: 3.6e-7 after 1 step); training dynamics amplify that seed
chaotically (~8.6e-6 after 2 steps, ~1e-3 by step 3 on ResNet at
lr=0.05), which is divergence between two correct implementations, not
kernel error.  The adam test therefore runs a short horizon (2 steps)
and asserts the measured per-step agreement with slack (1e-4).
"""

import jax
import numpy as np
import pytest

from repro.core import Constant, HpConfig, SearchPlanDB, Study
from repro.core.trainer import StageContext
from repro.core.trial import Trial
from repro.core.tuners import GridTuner
from repro.data import DataPipeline, synthetic_cifar
from repro.models.resnet import ResNet
from repro.train.jax_trainer import JaxTrainer

DATA = synthetic_cifar(128, seed=0)
EVAL = synthetic_cifar(64, seed=1)


def make_trainer(use_kernel, optimizer="momentum", **kw):
    return JaxTrainer(ResNet(n=1, width=8),
                      lambda: DataPipeline(DATA, batch_size=16, seed=3),
                      EVAL, default_optimizer=optimizer, backend="cpu",
                      use_kernel=use_kernel, **kw)


def desc(lr):
    return {"hps": {"bs": {"kind": "const", "value": 16.0},
                    "lr": {"kind": "const", "value": lr}}, "static": {}}


def max_param_err(a, b):
    return max(float(jax.numpy.abs(x - y).max())
               for x, y in zip(jax.tree.leaves(a["params"]),
                               jax.tree.leaves(b["params"])))


def test_solo_stage_bitwise_with_momentum():
    ctx = StageContext("n0", desc(0.05), 0, 0, 6, "k0")
    kern = make_trainer(True)
    s_k = kern.run_stage(kern.init_state(), ctx)
    # counters are global deltas from each trainer's construction snapshot,
    # so build the oracle trainer after the kernel run
    orac = make_trainer(False)
    s_o = orac.run_stage(orac.init_state(), ctx)
    assert max_param_err(s_k, s_o) == 0.0
    assert kern.kernel_calls > 0
    assert kern.kernel_fallbacks == 0
    assert orac.kernel_calls == 0          # oracle path never hits kernels


def test_solo_stage_adam_short_horizon():
    """Adam: per-step kernel agreement (see module docstring — longer
    horizons diverge chaotically from the ~1-ulp sqrt/divide seed)."""
    ctx = StageContext("n0", desc(0.05), 0, 0, 2, "k0")
    kern = make_trainer(True, optimizer="adam")
    orac = make_trainer(False, optimizer="adam")
    s_k = kern.run_stage(kern.init_state(), ctx)
    s_o = orac.run_stage(orac.init_state(), ctx)
    assert max_param_err(s_k, s_o) < 1e-4
    assert kern.kernel_fallbacks == 0


def test_chain_fused_bitwise_with_momentum():
    ctxs = [StageContext("n0", desc(0.05), 0, 0, 4, "k0"),
            StageContext("n1", desc(0.02), 0, 4, 8, "k0/n1")]
    kern = make_trainer(True)
    orac = make_trainer(False)
    b_k = kern.run_chain(kern.init_state(), ctxs)
    b_o = orac.run_chain(orac.init_state(), ctxs)
    assert max_param_err(b_k[-1], b_o[-1]) == 0.0
    assert kern.kernel_calls > 0
    assert kern.kernel_fallbacks == 0


def test_vmapped_sibling_group_bitwise_with_momentum():
    """Divergent per-member lrs ride the kernel grid as vector operands;
    each member still reproduces its oracle run exactly."""
    ctxs = [StageContext(f"m{i}", desc(0.05 * (1 + 0.1 * i)), 0, 0, 5,
                         f"k{i}") for i in range(3)]
    kern = make_trainer(True, vectorize_groups=True)
    orac = make_trainer(False, vectorize_groups=True)
    outs_k = kern.run_stages_batched([kern.init_state() for _ in ctxs], ctxs)
    outs_o = orac.run_stages_batched([orac.init_state() for _ in ctxs], ctxs)
    for s_k, s_o in zip(outs_k, outs_o):
        assert max_param_err(s_k, s_o) == 0.0
    assert kern.kernel_calls > 0
    assert kern.kernel_fallbacks == 0


def test_engine_stats_surface_kernel_counters():
    """A full engine run over a kernel-plane backend mirrors the trainer's
    counters into EngineStats — and matches the oracle engine bitwise."""
    def run(backend):
        trial = Trial(HpConfig({"lr": Constant(0.05), "bs": Constant(16)}), 8)
        db = SearchPlanDB()
        study = Study.create(db, "resnet8", "synth", ("lr", "bs"))
        eng = study.engine(backend, n_workers=1)
        stats = eng.run([GridTuner([trial])])
        plan = db.get(study.key)
        leaf = plan.nodes[plan.trial_paths[trial.trial_id][-1]]
        return stats, eng.store.get(leaf.ckpts[8])["params"]

    kern = make_trainer(True)
    stats_k, params_k = run(kern)
    assert stats_k.kernel_calls > 0
    assert stats_k.kernel_fallbacks == 0

    orac = make_trainer(False)
    stats_o, params_o = run(orac)
    assert stats_o.kernel_calls == 0

    # same final params, bit for bit (momentum — see module docstring)
    for x, y in zip(jax.tree.leaves(params_k), jax.tree.leaves(params_o)):
        np.testing.assert_array_equal(np.asarray(x), np.asarray(y))


def test_backend_gated_default():
    """use_kernel=None resolves from the backend: off on CPU (interpret
    mode is a test vehicle, not a perf win), on for TPU."""
    t = make_trainer(None)
    assert t.use_kernel is False
    assert jax.default_backend() == "cpu"
