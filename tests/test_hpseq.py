"""Unit + property tests for hyper-parameter sequence functions (§2.1).

The property half needs ``hypothesis``; a deterministic fixed-seed corpus
exercises the same invariants regardless (one visible skip marks the
missing randomized half).
"""

import random

import pytest

try:
    from hypothesis import given, settings, strategies as st
except ImportError:            # deterministic fallbacks below still run
    given = None

from repro.core.hpseq import (Constant, Cosine, CosineWarmRestarts, Cyclic,
                              Exponential, HpConfig, Linear, MultiStep,
                              Piecewise, Seq, StepLR, Warmup, from_json)


# ---------------------------------------------------------------------- unit

def test_constant():
    f = Constant(0.1)
    assert f.value(0) == f.value(1000) == 0.1
    assert f.boundaries(100) == []


def test_multistep_values_and_boundaries():
    f = StepLR(0.1, 0.1, [90, 135])          # paper Table 2 row 1
    assert f.value(0) == pytest.approx(0.1)
    assert f.value(89) == pytest.approx(0.1)
    assert f.value(90) == pytest.approx(0.01)
    assert f.value(135) == pytest.approx(0.001)
    assert f.boundaries(200) == [90, 135]
    assert f.boundaries(100) == [90]


def test_multistep_explicit_values():
    f = MultiStep(128, [40], values=[128, 256])  # Figure 10 batch size
    assert f.value(39) == 128 and f.value(40) == 256


def test_warmup_composition():
    f = Warmup(5, 0.1, StepLR(0.1, 0.1, [90, 135]))
    assert f.value(0) == 0.0
    assert f.value(4) == pytest.approx(0.08)
    assert f.value(5) == pytest.approx(0.1)      # hand-off to StepLR local 0
    assert f.value(94) == pytest.approx(0.1)     # StepLR local 89
    assert f.value(95) == pytest.approx(0.01)    # StepLR local 90
    assert 5 in f.boundaries(200)
    assert 95 in f.boundaries(200)


def test_exponential_and_cosine():
    e = Exponential(0.1, 0.95)
    assert e.value(10) == pytest.approx(0.1 * 0.95 ** 10)
    c = Cosine(1.0, 100)
    assert c.value(0) == pytest.approx(1.0)
    assert c.value(100) == pytest.approx(0.0)
    assert c.value(50) == pytest.approx(0.5)


def test_cosine_warm_restarts_periodicity():
    f = CosineWarmRestarts(1.0, t_0=20)
    assert f.value(0) == pytest.approx(f.value(20))
    assert f.value(5) == pytest.approx(f.value(25))
    assert f.boundaries(60) == [20, 40]


def test_cyclic():
    f = Cyclic(0.001, 0.1, step_size_up=20)
    assert f.value(0) == pytest.approx(0.001)
    assert f.value(20) == pytest.approx(0.1)
    assert f.value(40) == pytest.approx(0.001)


def test_piecewise():
    f = Piecewise([(0, 0.1), (100, 0.01)])
    assert f.value(99) == 0.1 and f.value(100) == 0.01
    assert f.boundaries(200) == [100]


# ------------------------------------------------------------------ equality

def test_prefix_equal_constant_vs_multistep():
    """Figure 1: constant lr and a decayed lr share the pre-decay prefix."""
    a, b = Constant(0.1), StepLR(0.1, 0.1, [100])
    assert a.prefix_equal(b, 100)
    assert not a.prefix_equal(b, 101)


def test_prefix_equal_different_milestones():
    a, b = StepLR(0.1, 0.1, [90, 135]), StepLR(0.1, 0.1, [100, 150])
    assert a.prefix_equal(b, 90)
    assert not a.prefix_equal(b, 91)


def test_seq_extension_shares_prefix():
    base = StepLR(0.1, 0.1, [50])
    ext = Seq((base, 80), (Constant(0.5), None))     # PBT-style exploit
    assert base.prefix_equal(ext, 80)
    assert not base.prefix_equal(ext, 81)


# ------------------------------------------------------- property invariants


def _check_json_roundtrip(f):
    g = from_json(f.to_json())
    assert g == f
    for s in (0, 1, 7, 50, 199):
        assert g.value(s) == pytest.approx(f.value(s), nan_ok=False)


def _check_prefix_equal_implies_pointwise(f, g, upto):
    """Soundness: structural prefix equality never lies about values."""
    if f.prefix_equal(g, upto):
        for s in range(0, upto, max(1, upto // 20)):
            assert f.value(s) == pytest.approx(g.value(s))


def _check_boundaries_within_range(f, total):
    for b in f.boundaries(total):
        assert 0 < b < total


def _random_fn(rng):
    kind = rng.randrange(5)
    if kind == 0:
        return Constant(rng.uniform(0.001, 1.0))
    if kind == 1:
        ms = sorted({rng.randint(1, 200)
                     for _ in range(rng.randint(1, 3))})
        return MultiStep(rng.uniform(0.01, 1.0), ms, rng.uniform(0.1, 0.9))
    if kind == 2:
        return Exponential(rng.uniform(0.01, 1.0), rng.uniform(0.8, 0.999))
    if kind == 3:
        return Linear(rng.uniform(0.01, 1.0), rng.randint(1, 200))
    return Cosine(rng.uniform(0.01, 1.0), rng.randint(1, 200))


@pytest.mark.parametrize("case", range(50))
def test_invariants_fixed_seed(case):
    """Deterministic stand-in for the hypothesis properties (same families,
    fixed seed) — runs whether or not hypothesis is installed."""
    rng = random.Random(case)
    f, g = _random_fn(rng), _random_fn(rng)
    _check_json_roundtrip(f)
    assert f.prefix_equal(f, rng.randint(1, 200))
    _check_prefix_equal_implies_pointwise(f, g, rng.randint(1, 120))
    _check_boundaries_within_range(f, rng.randint(2, 150))


if given is not None:
    hp_fn = st.one_of(
        st.builds(Constant, st.floats(0.001, 1.0, allow_nan=False)),
        st.builds(lambda b, m, g: MultiStep(b, sorted(set(m)), g),
                  st.floats(0.01, 1.0), st.lists(st.integers(1, 200),
                                                 min_size=1, max_size=3),
                  st.floats(0.1, 0.9)),
        st.builds(Exponential, st.floats(0.01, 1.0), st.floats(0.8, 0.999)),
        st.builds(Linear, st.floats(0.01, 1.0), st.integers(1, 200)),
        st.builds(Cosine, st.floats(0.01, 1.0), st.integers(1, 200)),
    )

    @settings(max_examples=50, deadline=None)
    @given(hp_fn)
    def test_json_roundtrip(f):
        _check_json_roundtrip(f)

    @settings(max_examples=50, deadline=None)
    @given(hp_fn, st.integers(1, 200))
    def test_prefix_equal_reflexive(f, upto):
        assert f.prefix_equal(f, upto)

    @settings(max_examples=50, deadline=None)
    @given(hp_fn, hp_fn, st.integers(1, 120))
    def test_prefix_equal_implies_pointwise(f, g, upto):
        _check_prefix_equal_implies_pointwise(f, g, upto)

    @settings(max_examples=50, deadline=None)
    @given(hp_fn, st.integers(2, 150))
    def test_boundaries_within_range(f, total):
        _check_boundaries_within_range(f, total)
else:
    def test_hpseq_property_half():
        pytest.skip("property half needs hypothesis; fixed-seed cases ran")


def test_hpconfig_prefix_and_hash():
    c1 = HpConfig({"lr": Constant(0.1)}, {"wd": 1e-4})
    c2 = HpConfig({"lr": StepLR(0.1, 0.1, [60])}, {"wd": 1e-4})
    c3 = HpConfig({"lr": Constant(0.1)}, {"wd": 1e-3})
    assert c1.prefix_equal(c2, 60)
    assert not c1.prefix_equal(c2, 61)
    assert not c1.prefix_equal(c3, 1)        # static hp differs → no sharing
    assert hash(c1) == hash(HpConfig({"lr": Constant(0.1)}, {"wd": 1e-4}))
