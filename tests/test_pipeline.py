"""Checkpointable data pipeline (§5.1).

The property half needs ``hypothesis``; fixed (bs, warm) grid cases cover
the same round-trip regardless (one visible skip marks the missing
randomized half).
"""

import numpy as np
import pytest

try:
    from hypothesis import given, settings, strategies as st
except ImportError:            # deterministic fallbacks below still run
    given = None

from repro.data import DataPipeline, synthetic_cifar, synthetic_lm_dataset


def make(bs=8, seed=0, n=64):
    return DataPipeline(synthetic_lm_dataset(n, 16, 100, seed=1),
                        batch_size=bs, seed=seed)


def test_deterministic_stream():
    a, b = make(), make()
    for _ in range(20):
        np.testing.assert_array_equal(a.next_batch()["tokens"],
                                      b.next_batch()["tokens"])


def test_resume_from_state_is_exact():
    """The §5.1 requirement: position in the permutation is part of the
    checkpoint; resuming replays the same sample stream."""
    a = make()
    for _ in range(11):
        a.next_batch()
    state = a.state()
    want = [a.next_batch()["tokens"] for _ in range(7)]

    b = make()
    b.restore(state)
    got = [b.next_batch()["tokens"] for _ in range(7)]
    for w, g in zip(want, got):
        np.testing.assert_array_equal(w, g)


def test_epoch_reshuffles():
    a = make(bs=32, n=64)                    # 2 batches per epoch
    e0 = [a.next_batch()["tokens"].copy() for _ in range(2)]
    e1 = [a.next_batch()["tokens"].copy() for _ in range(2)]
    assert not all(np.array_equal(x, y) for x, y in zip(e0, e1))
    # but each epoch is a permutation: same multiset of rows
    rows0 = np.sort(np.concatenate(e0), axis=0)
    rows1 = np.sort(np.concatenate(e1), axis=0)
    np.testing.assert_array_equal(rows0, rows1)


def test_batch_size_change_preserves_position():
    a = make(bs=8)
    a.next_batch()
    state_before = a.state()
    a.set_batch_size(16)
    b16 = a.next_batch()["tokens"]
    assert b16.shape[0] == 16
    # the first 8 rows are what a bs=8 pipeline would have served next
    c = make(bs=8)
    c.restore(state_before)
    np.testing.assert_array_equal(b16[:8], c.next_batch()["tokens"])


def _check_state_roundtrip(bs, warm):
    a = make(bs=bs)
    for _ in range(warm):
        a.next_batch()
    st_ = a.state()
    b = make(bs=1)
    b.restore(st_)
    np.testing.assert_array_equal(a.next_batch()["tokens"],
                                  b.next_batch()["tokens"])


@pytest.mark.parametrize("bs,warm", [(1, 0), (1, 40), (3, 7), (5, 13),
                                     (8, 11), (8, 33), (13, 1), (16, 40)])
def test_state_roundtrip_fixed(bs, warm):
    """Deterministic grid over the property's (bs, warm) space — runs
    whether or not hypothesis is installed."""
    _check_state_roundtrip(bs, warm)


if given is not None:
    @settings(max_examples=20, deadline=None)
    @given(st.integers(1, 16), st.integers(0, 40))
    def test_state_roundtrip_property(bs, warm):
        _check_state_roundtrip(bs, warm)
else:
    def test_state_roundtrip_property():
        pytest.skip("property half needs hypothesis; fixed grid ran")


def test_synthetic_cifar_shapes():
    d = synthetic_cifar(32)
    assert d["images"].shape == (32, 32, 32, 3)
    assert d["labels"].shape == (32,)
    assert d["labels"].min() >= 0 and d["labels"].max() < 10
